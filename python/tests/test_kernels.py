"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (including non-multiples of the block sizes, which
exercises the NodePad-style padding paths) and asserts allclose against
`kernels/ref.py`. This is the core Layer-1 correctness signal.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import attention, quant, ref, sage, stagr, tiling

SET = settings(max_examples=25, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])

dims = st.integers(min_value=1, max_value=70)
blocks = st.sampled_from([8, 16, 32])


def _mk(rng_seed, *shape):
    rng = np.random.default_rng(rng_seed)
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# Tiled MatMul substrate
# ---------------------------------------------------------------------------
class TestTiledMatmul:
    @SET
    @given(m=dims, k=dims, n=dims, b=blocks, seed=st.integers(0, 2**16))
    def test_matches_jnp(self, m, k, n, b, seed):
        x = _mk(seed, m, k)
        w = _mk(seed + 1, k, n)
        got = tiling.matmul(jnp.array(x), jnp.array(w), bm=b, bn=b, bk=b)
        assert_allclose(np.asarray(got), x @ w, rtol=1e-4, atol=1e-4)

    def test_pad_to_roundtrip(self):
        x = np.arange(12, dtype=np.float32).reshape(3, 4)
        p = tiling.pad_to(jnp.array(x), (8, 8))
        assert p.shape == (8, 8)
        assert_allclose(np.asarray(p)[:3, :4], x)
        assert float(np.abs(np.asarray(p)[3:]).sum()) == 0.0

    def test_identity(self):
        x = _mk(3, 33, 33)
        got = tiling.matmul(jnp.eye(33), jnp.array(x), bm=16, bn=16, bk=16)
        assert_allclose(np.asarray(got), x, rtol=1e-6)

    def test_vmem_budget_of_default_blocks(self):
        # DESIGN.md §8: stationary norm tile + streaming operand + output
        # must fit a 2 MiB VMEM budget at the default 128³ tiling.
        footprint = tiling.vmem_bytes(
            [(tiling.NPU_BM, tiling.NPU_BK), (tiling.NPU_BK, tiling.NPU_BN),
             (tiling.NPU_BM, tiling.NPU_BN)])
        assert footprint <= 2 * 1024 * 1024


# ---------------------------------------------------------------------------
# StaGr / PreG
# ---------------------------------------------------------------------------
class TestStaGr:
    @SET
    @given(n=dims, f=dims, b=blocks, seed=st.integers(0, 2**16))
    def test_aggregate(self, n, f, b, seed):
        norm = _mk(seed, n, n)
        x = _mk(seed + 1, n, f)
        got = stagr.stagr_aggregate(jnp.array(norm), jnp.array(x),
                                    bm=b, bn=b, bk=b)
        want = ref.stagr_aggregate(jnp.array(norm), jnp.array(x))
        assert_allclose(np.asarray(got), np.asarray(want),
                        rtol=1e-4, atol=1e-4)

    @SET
    @given(n=dims, f=dims, fp=dims, b=blocks, seed=st.integers(0, 2**16))
    def test_fused_layer(self, n, f, fp, b, seed):
        norm = _mk(seed, n, n)
        x = _mk(seed + 1, n, f)
        w = _mk(seed + 2, f, fp)
        bias = _mk(seed + 3, fp)
        got = stagr.gcn_layer(jnp.array(norm), jnp.array(x), jnp.array(w),
                              jnp.array(bias), bm=b, bn=b, bk=b)
        want = ref.gcn_layer(jnp.array(norm), jnp.array(x), jnp.array(w),
                             jnp.array(bias))
        assert_allclose(np.asarray(got), np.asarray(want),
                        rtol=2e-4, atol=2e-4)

    def test_bias_applied_once(self):
        # k-grid > 1 must not re-add the bias per k block.
        n, f, fp = 48, 48, 16
        norm = np.zeros((n, n), np.float32)
        x = np.zeros((n, f), np.float32)
        w = np.zeros((f, fp), np.float32)
        bias = np.full(fp, 3.0, np.float32)
        got = stagr.gcn_layer(jnp.array(norm), jnp.array(x), jnp.array(w),
                              jnp.array(bias), bm=16, bn=16, bk=16)
        assert_allclose(np.asarray(got), np.full((n, fp), 3.0), rtol=1e-6)


# ---------------------------------------------------------------------------
# GAT attention (EffOp + GrAx1 + GrAx2)
# ---------------------------------------------------------------------------
def _adj(seed, n, p=0.15):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < p).astype(np.float32)
    np.fill_diagonal(a, 1.0)
    return a


class TestAttention:
    @SET
    @given(n=st.integers(2, 60), f=st.integers(1, 40), b=blocks,
           seed=st.integers(0, 2**16))
    def test_kernel_vs_grax_oracle(self, n, f, b, seed):
        h = _mk(seed, n, f)
        a_src = _mk(seed + 1, f)
        a_dst = _mk(seed + 2, f)
        neg_bias = ((1.0 - _adj(seed + 3, n)) * ref.NEG_MASK).astype(np.float32)
        got = attention.gat_attention(jnp.array(h), jnp.array(a_src),
                                      jnp.array(a_dst), jnp.array(neg_bias),
                                      bm=b)
        want = ref.gat_attention_grax(jnp.array(h), jnp.array(a_src),
                                      jnp.array(a_dst), jnp.array(neg_bias))
        assert_allclose(np.asarray(got), np.asarray(want),
                        rtol=5e-4, atol=5e-5)

    @SET
    @given(n=st.integers(2, 50), f=st.integers(1, 30),
           seed=st.integers(0, 2**16))
    def test_effop_equals_baseline(self, n, f, seed):
        """EffOp is exact: mask-multiply masking == Select masking."""
        h = _mk(seed, n, f)
        a_src = _mk(seed + 1, f)
        a_dst = _mk(seed + 2, f)
        adj = _adj(seed + 3, n)
        base = ref.gat_attention_baseline(jnp.array(h), jnp.array(a_src),
                                          jnp.array(a_dst), jnp.array(adj))
        eff = ref.gat_attention_effop(jnp.array(h), jnp.array(a_src),
                                      jnp.array(a_dst), jnp.array(adj))
        assert_allclose(np.asarray(base), np.asarray(eff),
                        rtol=1e-4, atol=1e-5)

    @SET
    @given(n=st.integers(2, 50), f=st.integers(1, 30),
           seed=st.integers(0, 2**16))
    def test_grax1_close_to_baseline(self, n, f, seed):
        """GrAx1's additive mask is an approximation — bounded drift."""
        h = _mk(seed, n, f)
        a_src = _mk(seed + 1, f)
        a_dst = _mk(seed + 2, f)
        adj = _adj(seed + 3, n)
        neg_bias = ((1.0 - adj) * ref.NEG_MASK).astype(np.float32)
        base = ref.gat_attention_baseline(jnp.array(h), jnp.array(a_src),
                                          jnp.array(a_dst), jnp.array(adj))
        grax = ref.gat_attention_grax(jnp.array(h), jnp.array(a_src),
                                      jnp.array(a_dst), jnp.array(neg_bias))
        # off-edge mass after softmax is ≤ e^(raw - 1e9 - max) ≈ 0; on-edge
        # logits are unchanged (LeakyReLU then +0), so results match tightly.
        assert_allclose(np.asarray(base), np.asarray(grax),
                        rtol=1e-3, atol=1e-4)

    def test_rows_sum_to_one_effect(self):
        """Attention output of constant features must be those constants."""
        n, f = 30, 8
        h = np.ones((n, f), np.float32) * 2.5
        a_src = _mk(1, f)
        a_dst = _mk(2, f)
        neg_bias = ((1.0 - _adj(5, n)) * ref.NEG_MASK).astype(np.float32)
        got = attention.gat_attention(jnp.array(h), jnp.array(a_src),
                                      jnp.array(a_dst), jnp.array(neg_bias),
                                      bm=16)
        assert_allclose(np.asarray(got), h, rtol=1e-5)


# ---------------------------------------------------------------------------
# SAGE aggregation (GrAx3 + mean), dense and gathered forms
# ---------------------------------------------------------------------------
class TestSage:
    @SET
    @given(n=st.integers(2, 60), f=st.integers(1, 40), b=blocks,
           seed=st.integers(0, 2**16))
    def test_max_kernel_vs_oracle(self, n, f, b, seed):
        mask = _adj(seed, n)
        h = np.abs(_mk(seed + 1, n, f))
        got = sage.sage_max(jnp.array(mask), jnp.array(h), bm=b, bk=b)
        want = ref.sage_max_grax3(jnp.array(mask), jnp.array(h))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    @SET
    @given(n=st.integers(2, 60), f=st.integers(1, 40),
           seed=st.integers(0, 2**16))
    def test_mean_kernel_vs_oracle(self, n, f, seed):
        mask = _adj(seed, n)
        h = _mk(seed + 1, n, f)
        got = sage.sage_mean(jnp.array(mask), jnp.array(h))
        want = ref.sage_mean(jnp.array(mask), jnp.array(h))
        assert_allclose(np.asarray(got), np.asarray(want),
                        rtol=1e-4, atol=1e-5)

    @SET
    @given(n=st.integers(2, 50), f=st.integers(1, 30),
           seed=st.integers(0, 2**16))
    def test_grax3_exact_on_nonneg(self, n, f, seed):
        """GrAx3 == baseline SAGE-max when features are non-negative and
        every row has a zero entry or only non-negative candidates."""
        mask = _adj(seed, n)
        h = np.abs(_mk(seed + 1, n, f))
        base = ref.sage_max_baseline(jnp.array(mask), jnp.array(h))
        grax = ref.sage_max_grax3(jnp.array(mask), jnp.array(h))
        assert_allclose(np.asarray(base), np.asarray(grax), rtol=1e-6)

    @SET
    @given(n=st.integers(3, 60), f=st.integers(1, 30), k=st.integers(1, 8),
           seed=st.integers(0, 2**16))
    def test_gathered_equivalence(self, n, f, k, seed):
        """Dense-mask and gathered formulations agree on the same sample."""
        rng = np.random.default_rng(seed)
        idx = np.full((n, k + 1), n, dtype=np.int32)
        idx[:, 0] = np.arange(n)
        for i in range(n):
            # draw neighbors distinct from self: the dense mask dedupes a
            # repeated self entry, the gathered form would double-count it
            candidates = np.delete(np.arange(n), i)
            # keep ≥1 zero entry per dense row: GrAx3's clip-at-zero is
            # only equivalent when some mask*h product is 0 (kernels/ref.py
            # documents this precondition; always true at dataset scale)
            deg = int(rng.integers(0, max(min(k, n - 2), 0) + 1))
            if deg:
                idx[i, 1:1 + deg] = rng.choice(candidates, size=deg,
                                               replace=False)
        mask = np.zeros((n, n), np.float32)
        for i in range(n):
            for j in idx[i]:
                if j < n:
                    mask[i, j] = 1.0
        h = _mk(seed + 1, n, f)
        dense_mean = ref.sage_mean(jnp.array(mask), jnp.array(h))
        gath_mean = ref.sage_mean_gathered(jnp.array(idx), jnp.array(h))
        # dense mask dedupes repeated indices; gathered doesn't — only
        # compare when idx rows are unique, which they are by construction.
        assert_allclose(np.asarray(dense_mean), np.asarray(gath_mean),
                        rtol=1e-5, atol=1e-6)
        dense_max = ref.sage_max_grax3(jnp.array(mask), jnp.array(h))
        gath_max = ref.sage_max_grax3_gathered(jnp.array(idx), jnp.array(h))
        assert_allclose(np.asarray(dense_max), np.asarray(gath_max),
                        rtol=1e-6)

    def test_no_neighbor_row_yields_zero(self):
        n, f = 8, 4
        idx = np.full((n, 3), n, dtype=np.int32)  # not even self
        h = _mk(0, n, f)
        out = ref.sage_max_gathered(jnp.array(idx), jnp.array(h))
        assert_allclose(np.asarray(out), np.zeros((n, f)))


# ---------------------------------------------------------------------------
# QuantGr
# ---------------------------------------------------------------------------
class TestQuant:
    @SET
    @given(m=dims, k=dims, n=dims, b=blocks, seed=st.integers(0, 2**16))
    def test_kernel_vs_oracle(self, m, k, n, b, seed):
        rng = np.random.default_rng(seed)
        xq = rng.integers(-127, 128, (m, k)).astype(np.int8)
        wq = rng.integers(-127, 128, (k, n)).astype(np.int8)
        got = quant.quant_matmul(jnp.array(xq), jnp.array(wq), 0.013, 0.07,
                                 bm=b, bn=b, bk=b)
        want = ref.quant_matmul(jnp.array(xq), jnp.array(wq), 0.013, 0.07)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_int32_accumulation_exact(self):
        """Large-k dot products must not lose integer precision (the FP32
        accumulator failure mode the kernel exists to avoid)."""
        k = 4096
        xq = np.full((1, k), 127, np.int8)
        wq = np.full((k, 1), 127, np.int8)
        got = quant.quant_matmul(jnp.array(xq), jnp.array(wq), 1.0, 1.0)
        assert float(np.asarray(got)[0, 0]) == 127.0 * 127.0 * k

    @SET
    @given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 10.0))
    def test_quant_roundtrip_error_bound(self, seed, scale):
        x = _mk(seed, 23, 17) * scale
        s = ref.quant_scale(float(np.abs(x).max()))
        q = ref.quantize(jnp.array(x), s)
        back = ref.dequantize(q, s)
        assert float(np.abs(np.asarray(back) - x).max()) <= s / 2 + 1e-7

    def test_symmetric_range(self):
        x = np.array([[-5.0, 5.0]], np.float32)
        s = ref.quant_scale(5.0)
        q = np.asarray(ref.quantize(jnp.array(x), s))
        assert q.min() == -127 and q.max() == 127
