"""Shared fixtures for the L1/L2 test suite."""

import os
import sys

import numpy as np
import pytest

# Make `compile` importable when pytest is run from python/ or the repo root.
_HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)


@pytest.fixture(scope="session")
def cora():
    from compile import datasets
    return datasets.cora_twin()


@pytest.fixture(scope="session")
def citeseer():
    from compile import datasets
    return datasets.citeseer_twin()


def small_graph(rng, n=40, p=0.12):
    """Random small graph fixture pieces: adjacency with self loops."""
    adj = (rng.random((n, n)) < p).astype(np.float32)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 1.0)
    return adj
