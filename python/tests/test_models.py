"""L2 model-variant equivalence: the optimization ladder must preserve
numerics up to the documented approximations (paper: "negligible loss")."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import datasets
from compile.models import HIDDEN, gat, gcn, sage_net

N, F, C = 60, 33, 5


@pytest.fixture(scope="module")
def tiny():
    """A small synthetic graph exercising all derived matrices."""
    spec = dict(name="tiny", n=N, m=140, classes=C, features=F,
                train=20, val=15, test=15, seed=99)
    return datasets.make_twin(spec)


@pytest.fixture(scope="module")
def gcn_params():
    return gcn.init_params(jax.random.key(0), F, HIDDEN, C)


@pytest.fixture(scope="module")
def gat_params():
    return gat.init_params(jax.random.key(1), F, HIDDEN, C)


@pytest.fixture(scope="module")
def sage_params():
    return sage_net.init_params(jax.random.key(2), F, HIDDEN, C)


class TestGCNVariants:
    def test_baseline_equals_stagr(self, tiny, gcn_params):
        """Scatter aggregation + on-device norm == PreG dense MatMul."""
        x = jnp.asarray(tiny.features)
        base = gcn.apply_baseline(gcn_params, jnp.asarray(tiny.edges), x)
        stag = gcn.apply_stagr_ref(gcn_params, jnp.asarray(tiny.norm_adjacency()), x)
        assert_allclose(np.asarray(base), np.asarray(stag),
                        rtol=1e-4, atol=1e-5)

    def test_pallas_path_equals_ref(self, tiny, gcn_params):
        norm = jnp.asarray(tiny.norm_adjacency())
        x = jnp.asarray(tiny.features)
        kern = gcn.apply_stagr(gcn_params, norm, x)
        ref_ = gcn.apply_stagr_ref(gcn_params, norm, x)
        assert_allclose(np.asarray(kern), np.asarray(ref_),
                        rtol=1e-4, atol=1e-4)

    def test_nodepad_preserves_real_nodes(self, tiny, gcn_params):
        """NodePad: padded execution == unpadded on the real rows."""
        cap = N + 17
        norm = jnp.asarray(tiny.norm_adjacency())
        x = jnp.asarray(tiny.features)
        normp = jnp.asarray(tiny.norm_adjacency(pad_to=cap))
        xp = jnp.asarray(tiny.padded_features(cap))
        out = gcn.apply_stagr_ref(gcn_params, norm, x)
        outp = gcn.apply_stagr_ref(gcn_params, normp, xp)
        assert_allclose(np.asarray(outp)[:N], np.asarray(out),
                        rtol=1e-4, atol=1e-5)

    def test_quant_argmax_mostly_agrees(self, tiny, gcn_params):
        from compile import quantize
        norm = jnp.asarray(tiny.norm_adjacency())
        x = jnp.asarray(tiny.features)
        scales = quantize.calibrate_gcn(gcn_params, norm, x)
        err = quantize.quant_error(gcn_params, norm, x, scales)
        assert err["argmax_agreement"] > 0.9
        assert err["rel_err"] < 0.1

    def test_quant_kernel_path_equals_ref(self, tiny, gcn_params):
        from compile import quantize
        norm = jnp.asarray(tiny.norm_adjacency())
        x = jnp.asarray(tiny.features)
        scales = quantize.calibrate_gcn(gcn_params, norm, x)
        kern = gcn.apply_quant(gcn_params, norm, x, scales)
        ref_ = gcn.apply_quant_ref(gcn_params, norm, x, scales)
        assert_allclose(np.asarray(kern), np.asarray(ref_),
                        rtol=1e-4, atol=1e-4)


class TestGATVariants:
    def test_effop_equals_baseline(self, tiny, gat_params):
        adj = jnp.asarray(tiny.adjacency())
        x = jnp.asarray(tiny.features)
        base = gat.apply_baseline(gat_params, adj, x)
        eff = gat.apply_effop(gat_params, adj, x)
        assert_allclose(np.asarray(base), np.asarray(eff),
                        rtol=1e-4, atol=1e-5)

    def test_grax_close_to_baseline(self, tiny, gat_params):
        adj = tiny.adjacency()
        neg_bias = jnp.asarray(((1.0 - adj) * -1e9).astype(np.float32))
        x = jnp.asarray(tiny.features)
        base = gat.apply_baseline(gat_params, jnp.asarray(adj), x)
        grax = gat.apply_grax_ref(gat_params, neg_bias, x)
        assert_allclose(np.asarray(base), np.asarray(grax),
                        rtol=1e-3, atol=1e-4)

    def test_grax_kernel_equals_ref(self, tiny, gat_params):
        adj = tiny.adjacency()
        neg_bias = jnp.asarray(((1.0 - adj) * -1e9).astype(np.float32))
        x = jnp.asarray(tiny.features)
        kern = gat.apply_grax(gat_params, neg_bias, x)
        ref_ = gat.apply_grax_ref(gat_params, neg_bias, x)
        assert_allclose(np.asarray(kern), np.asarray(ref_),
                        rtol=5e-4, atol=5e-5)

    def test_argmax_stable_under_grax(self, tiny, gat_params):
        """Predictions (what accuracy measures) survive GrAx1+2."""
        adj = tiny.adjacency()
        neg_bias = jnp.asarray(((1.0 - adj) * -1e9).astype(np.float32))
        x = jnp.asarray(tiny.features)
        base = np.asarray(gat.apply_baseline(gat_params, jnp.asarray(adj), x))
        grax = np.asarray(gat.apply_grax_ref(gat_params, neg_bias, x))
        agree = (base.argmax(-1) == grax.argmax(-1)).mean()
        assert agree > 0.98


class TestSAGEVariants:
    K = 6

    def test_mean_dense_equals_gathered(self, tiny, sage_params):
        mask = jnp.asarray(tiny.sampled_adjacency(self.K))
        idx = jnp.asarray(tiny.sampled_neighbors(self.K))
        x = jnp.asarray(tiny.features)
        dense = sage_net.apply_mean_ref(sage_params, mask, x)
        gath = sage_net.apply_mean_gathered(sage_params, idx, x)
        assert_allclose(np.asarray(dense), np.asarray(gath),
                        rtol=1e-4, atol=1e-5)

    def test_max_grax3_dense_equals_gathered(self, tiny, sage_params):
        mask = jnp.asarray(tiny.sampled_adjacency(self.K))
        idx = jnp.asarray(tiny.sampled_neighbors(self.K))
        x = jnp.asarray(tiny.features)
        dense = sage_net.apply_max_grax3_ref(sage_params, mask, x)
        gath = sage_net.apply_max_grax3_gathered(sage_params, idx, x)
        assert_allclose(np.asarray(dense), np.asarray(gath),
                        rtol=1e-5, atol=1e-6)

    def test_grax3_equals_baseline_on_nonneg_features(self, tiny, sage_params):
        """Bag-of-words features are ≥0 and layer-2 inputs are post-ReLU,
        so GrAx3 degrades nothing except negative layer-2 maxima clipping;
        check argmax agreement stays high."""
        idx = jnp.asarray(tiny.sampled_neighbors(self.K))
        x = jnp.asarray(tiny.features)
        base = np.asarray(sage_net.apply_max_baseline_gathered(
            sage_params, idx, x))
        grax = np.asarray(sage_net.apply_max_grax3_gathered(
            sage_params, idx, x))
        agree = (base.argmax(-1) == grax.argmax(-1)).mean()
        assert agree > 0.9

    def test_mean_kernel_equals_ref(self, tiny, sage_params):
        mask = jnp.asarray(tiny.sampled_adjacency(self.K))
        x = jnp.asarray(tiny.features)
        kern = sage_net.apply_mean(sage_params, mask, x)
        ref_ = sage_net.apply_mean_ref(sage_params, mask, x)
        assert_allclose(np.asarray(kern), np.asarray(ref_),
                        rtol=1e-4, atol=1e-4)

    def test_max_kernel_equals_ref(self, tiny, sage_params):
        mask = jnp.asarray(tiny.sampled_adjacency(self.K))
        x = jnp.asarray(tiny.features)
        kern = sage_net.apply_max_grax3(sage_params, mask, x)
        ref_ = sage_net.apply_max_grax3_ref(sage_params, mask, x)
        assert_allclose(np.asarray(kern), np.asarray(ref_),
                        rtol=1e-4, atol=1e-4)
