"""Dataset twin properties: matched statistics, determinism, derived masks."""

import numpy as np
import pytest

from compile import datasets


class TestSpecs:
    def test_cora_matches_published_stats(self, cora):
        assert cora.num_nodes == 2708
        assert cora.num_edges == 5429
        assert cora.num_features == 1433
        assert cora.num_classes == 7
        assert cora.train_mask.sum() == 140
        assert cora.val_mask.sum() == 500
        assert cora.test_mask.sum() == 1000

    def test_citeseer_matches_published_stats(self, citeseer):
        assert citeseer.num_nodes == 3327
        assert citeseer.num_edges == 4732
        assert citeseer.num_features == 3703
        assert citeseer.num_classes == 6

    def test_deterministic(self, cora):
        again = datasets.cora_twin()
        np.testing.assert_array_equal(cora.edges, again.edges)
        np.testing.assert_array_equal(cora.features, again.features)
        np.testing.assert_array_equal(cora.labels, again.labels)

    def test_feature_density_cora_like(self, cora):
        density = float((cora.features > 0).mean())
        assert 0.005 < density < 0.03  # Cora's ~1.27%

    def test_splits_disjoint(self, cora):
        overlap = (cora.train_mask & cora.val_mask) | \
                  (cora.train_mask & cora.test_mask) | \
                  (cora.val_mask & cora.test_mask)
        assert not overlap.any()

    def test_homophily_planted(self, cora):
        s, d = cora.edges[:, 0], cora.edges[:, 1]
        same = (cora.labels[s] == cora.labels[d]).mean()
        assert same > 0.6  # planted at 0.72 + random intra hits

    def test_edges_canonical(self, cora):
        s, d = cora.edges[:, 0], cora.edges[:, 1]
        assert (s < d).all()  # src < dst, no self loops
        keys = set(map(tuple, cora.edges.tolist()))
        assert len(keys) == cora.num_edges  # no duplicates

    def test_unknown_dataset_raises(self):
        with pytest.raises(KeyError):
            datasets.load("pubmed")


class TestDerivedMatrices:
    def test_adjacency_symmetric_with_self_loops(self, cora):
        a = cora.adjacency()
        assert (a == a.T).all()
        assert (np.diag(a) == 1.0).all()
        # m undirected edges → 2m off-diagonal ones + n self loops
        assert int(a.sum()) == 2 * cora.num_edges + cora.num_nodes

    def test_norm_rows_match_symmetric_normalization(self, cora):
        norm = cora.norm_adjacency()
        a = cora.adjacency()
        deg = a.sum(axis=1)
        i, j = 17, int(np.flatnonzero(a[17])[0])
        expected = a[i, j] / np.sqrt(deg[i] * deg[j])
        assert abs(norm[i, j] - expected) < 1e-6

    def test_nodepad_padding_isolated(self, cora):
        cap = 3000
        a = cora.adjacency(pad_to=cap)
        assert a.shape == (cap, cap)
        assert a[cora.num_nodes:, :].sum() == 0  # padded rows disconnected
        assert a[:, cora.num_nodes:].sum() == 0
        norm = cora.norm_adjacency(pad_to=cap)
        assert np.isfinite(norm).all()  # no div-by-zero on degree-0 rows
        assert norm[cora.num_nodes:, :].sum() == 0

    def test_padded_features_zero_tail(self, cora):
        xp = cora.padded_features(3000)
        assert xp.shape == (3000, cora.num_features)
        assert np.abs(xp[cora.num_nodes:]).sum() == 0

    def test_pad_below_n_raises(self, cora):
        with pytest.raises(ValueError):
            cora.adjacency(pad_to=10)
        with pytest.raises(ValueError):
            cora.padded_features(10)

    def test_sampled_neighbors_structure(self, cora):
        k = 10
        idx = cora.sampled_neighbors(k)
        n = cora.num_nodes
        assert idx.shape == (n, k + 1)
        assert (idx[:, 0] == np.arange(n)).all()  # self first
        assert idx.max() <= n  # sentinel is n
        # every non-sentinel entry is a real neighbor
        nbrs = cora.neighbor_lists()
        for i in [0, 5, 100, n - 1]:
            for j in idx[i, 1:]:
                if j < n:
                    assert int(j) in nbrs[i]

    def test_sampled_neighbors_capped(self, cora):
        idx = cora.sampled_neighbors(10)
        valid = (idx < cora.num_nodes).sum(axis=1)
        assert valid.max() <= 11

    def test_sampled_adjacency_consistent_with_idx(self, cora):
        k = 10
        idx = cora.sampled_neighbors(k)
        mask = cora.sampled_adjacency(k)
        n = cora.num_nodes
        rebuilt = np.zeros((n, n), np.float32)
        for i in range(n):
            for j in idx[i]:
                if j < n:
                    rebuilt[i, j] = 1.0
        np.testing.assert_array_equal(mask, rebuilt)

    def test_sampled_adjacency_deterministic_per_seed(self, cora):
        a = cora.sampled_adjacency(5, seed=3)
        b = cora.sampled_adjacency(5, seed=3)
        np.testing.assert_array_equal(a, b)
        c = cora.sampled_adjacency(5, seed=4)
        assert (a != c).any()
