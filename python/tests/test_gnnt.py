"""`.gnnt` container: roundtrip across all dtypes, format errors."""

import numpy as np
import pytest

from compile import gnnt


class TestRoundtrip:
    def test_all_dtypes(self, tmp_path, rng):
        path = str(tmp_path / "t.gnnt")
        tensors = {
            "f32": rng.standard_normal((3, 4)).astype(np.float32),
            "i8": rng.integers(-127, 127, (5,)).astype(np.int8),
            "i32": rng.integers(-1000, 1000, (2, 2, 2)).astype(np.int32),
            "u8": rng.integers(0, 2, (7,)).astype(np.uint8),
            "scalar": np.float32(3.25).reshape(()),
        }
        gnnt.write(path, tensors)
        back = gnnt.read(path)
        assert set(back) == set(tensors)
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])
            assert back[k].dtype == tensors[k].dtype

    def test_f16_via_u16_bits(self, tmp_path):
        path = str(tmp_path / "h.gnnt")
        x = np.array([1.5, -2.25], np.float16)
        gnnt.write(path, {"h": x})
        back = gnnt.read(path)["h"]
        np.testing.assert_array_equal(back.view(np.float16), x)

    def test_empty_file(self, tmp_path):
        path = str(tmp_path / "e.gnnt")
        gnnt.write(path, {})
        assert gnnt.read(path) == {}

    def test_unicode_names(self, tmp_path):
        path = str(tmp_path / "u.gnnt")
        gnnt.write(path, {"wéights/λ1": np.zeros(2, np.float32)})
        assert "wéights/λ1" in gnnt.read(path)

    def test_large_tensor_preserved(self, tmp_path, rng):
        path = str(tmp_path / "big.gnnt")
        x = rng.standard_normal((500, 300)).astype(np.float32)
        gnnt.write(path, {"x": x})
        np.testing.assert_array_equal(gnnt.read(path)["x"], x)


class TestErrors:
    def test_bad_magic(self, tmp_path):
        path = tmp_path / "bad.gnnt"
        path.write_bytes(b"NOPE" + b"\x00" * 16)
        with pytest.raises(ValueError, match="magic"):
            gnnt.read(str(path))

    def test_bad_version(self, tmp_path):
        path = tmp_path / "v.gnnt"
        path.write_bytes(b"GNNT" + (99).to_bytes(4, "little")
                         + (0).to_bytes(4, "little"))
        with pytest.raises(ValueError, match="version"):
            gnnt.read(str(path))

    def test_unsupported_dtype_write(self, tmp_path):
        with pytest.raises(TypeError):
            gnnt.write(str(tmp_path / "d.gnnt"),
                       {"x": np.zeros(3, np.float64)})
