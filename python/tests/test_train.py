"""Training substrate: Adam, loss descent, above-chance accuracy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, train
from compile.models import HIDDEN


@pytest.fixture(scope="module")
def tiny():
    spec = dict(name="tiny", n=80, m=220, classes=4, features=48,
                train=32, val=20, test=20, seed=7)
    return datasets.make_twin(spec)


class TestAdam:
    def test_quadratic_convergence(self):
        """Adam must drive a simple quadratic to its minimum."""
        params = {"w": jnp.array([5.0, -3.0])}
        state = train.adam_init(params)
        target = jnp.array([1.0, 2.0])
        for _ in range(400):
            grads = {"w": 2 * (params["w"] - target)}
            params, state = train.adam_step(params, grads, state, lr=0.05)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_step_counter_advances(self):
        params = {"w": jnp.zeros(3)}
        state = train.adam_init(params)
        _, state = train.adam_step(params, {"w": jnp.ones(3)}, state)
        assert int(state["t"]) == 1


class TestCrossEntropy:
    def test_perfect_logits_near_zero_loss(self):
        labels = jnp.array([0, 1, 2])
        logits = jax.nn.one_hot(labels, 3) * 100.0
        mask = jnp.ones(3)
        assert float(train.cross_entropy(logits, labels, mask)) < 1e-3

    def test_mask_excludes_nodes(self):
        labels = jnp.array([0, 1])
        logits = jnp.array([[10.0, 0.0], [10.0, 0.0]])  # node 1 is wrong
        only_first = jnp.array([1.0, 0.0])
        assert float(train.cross_entropy(logits, labels, only_first)) < 1e-3

    def test_accuracy_helper(self):
        logits = np.array([[1.0, 0.0], [0.0, 1.0], [1.0, 0.0]])
        labels = np.array([0, 1, 1])
        mask = np.array([True, True, True])
        assert train.accuracy(logits, labels, mask) == pytest.approx(2 / 3)


class TestTrainers:
    @pytest.mark.parametrize("model", ["gcn", "gat", "sage_mean", "sage_max"])
    def test_loss_decreases_and_above_chance(self, tiny, model):
        params, report = train.TRAINERS[model](tiny, epochs=30)
        assert report["loss"][-1] < report["loss"][0]
        # 4 classes → chance is 0.25; a planted-partition twin must beat it.
        assert report["test_acc"] > 0.4, f"{model} barely learned"

    def test_gcn_deterministic_given_seed(self, tiny):
        _, r1 = train.TRAINERS["gcn"](tiny, seed=3, epochs=5)
        _, r2 = train.TRAINERS["gcn"](tiny, seed=3, epochs=5)
        assert r1["loss"] == r2["loss"]
