"""AOT export path: HLO lowering sanity + artifact/manifest coherence.

Full `make artifacts` output is exercised end-to-end by the rust
integration tests; here we lower small-scale twins of each export and
verify the HLO text is loadable-shaped (entry computation, parameter
count, no serialized-proto interchange).
"""

import os

import jax
import numpy as np
import pytest

from compile import aot, datasets, quantize, train
from compile.models import HIDDEN, gcn


@pytest.fixture(scope="module")
def tiny():
    spec = dict(name="tiny", n=30, m=70, classes=3, features=24,
                train=12, val=9, test=9, seed=5)
    return datasets.make_twin(spec)


@pytest.fixture(scope="module")
def tiny_scales(tiny):
    import jax.numpy as jnp
    params = gcn.init_params(jax.random.key(0), tiny.num_features, HIDDEN,
                             tiny.num_classes)
    return quantize.calibrate_gcn(params, jnp.asarray(tiny.norm_adjacency()),
                                  jnp.asarray(tiny.features))


def _check_hlo(text: str, n_params: int):
    assert "ENTRY" in text, "missing entry computation"
    assert "parameter(" in text
    found = max(int(tok.split("parameter(")[1].split(")")[0])
                for tok in text.split("\n") if "parameter(" in tok)
    assert found == n_params - 1, f"expected {n_params} params, max id {found}"


class TestLowering:
    def test_gcn_exports_lower(self, tiny, tiny_scales):
        n, f, c = tiny.num_nodes, tiny.num_features, tiny.num_classes
        for name, fn, specs, inames in aot.gcn_exports(n, f, c, n + 10,
                                                       tiny_scales):
            text = aot.lower(fn, *specs)
            _check_hlo(text, len(specs))
            assert len(inames) == len(specs)

    def test_gat_exports_lower(self, tiny):
        n, f, c = tiny.num_nodes, tiny.num_features, tiny.num_classes
        for name, fn, specs, inames in aot.gat_exports(n, f, c):
            text = aot.lower(fn, *specs)
            _check_hlo(text, len(specs))

    def test_sage_exports_lower(self, tiny):
        n, f, c = tiny.num_nodes, tiny.num_features, tiny.num_classes
        for name, fn, specs, inames in aot.sage_exports(n, f, c, 5):
            text = aot.lower(fn, *specs)
            _check_hlo(text, len(specs))

    def test_lowered_text_is_hlo_not_proto(self, tiny, tiny_scales):
        """Interchange must be HLO text (xla_extension 0.5.1 gotcha)."""
        n, f, c = tiny.num_nodes, tiny.num_features, tiny.num_classes
        name, fn, specs, _ = aot.gcn_exports(n, f, c, n, tiny_scales)[0]
        text = aot.lower(fn, *specs)
        assert text.startswith("HloModule"), "expected textual HLO module"
        assert "\x00" not in text


class TestManifestRun:
    def test_skip_hlo_run_writes_dataset_weights_manifest(self, tmp_path,
                                                          monkeypatch):
        """A fast (--skip-hlo, tiny-epochs) run of the full driver."""
        monkeypatch.setattr(aot, "CAPACITY", {"cora": 3000})
        out = str(tmp_path)
        aot.run(out, ["cora"], epochs=2, skip_hlo=True)
        assert os.path.exists(os.path.join(out, "cora.gnnt"))
        assert os.path.exists(os.path.join(out, "weights_gcn_cora.gnnt"))
        assert os.path.exists(os.path.join(out, "manifest.toml"))
        manifest = open(os.path.join(out, "manifest.toml")).read()
        assert "[dataset.cora]" in manifest
        assert "[weights.gcn_cora]" in manifest

    def test_dataset_gnnt_contents(self, tmp_path):
        from compile import gnnt
        spec = dict(name="tiny2", n=25, m=40, classes=3, features=12,
                    train=9, val=8, test=8, seed=11)
        ds = datasets.make_twin(spec)
        aot.export_dataset(ds, str(tmp_path))
        back = gnnt.read(str(tmp_path / "tiny2.gnnt"))
        assert back["features"].shape == (25, 12)
        assert back["edges"].shape == (40, 2)
        assert back["nbr_idx"].shape == (25, train.SAGE_MAX_NEIGHBORS + 1)
        assert back["labels"].dtype == np.int32
