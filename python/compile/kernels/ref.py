"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal for Layer 1: `python/tests/` sweeps
shapes and dtypes with hypothesis and asserts `assert_allclose` between each
Pallas kernel (interpret=True) and its oracle here. The oracles are also
used by the L2 model code when a variant does not route through a kernel
(e.g. the control-heavy *baseline* mappings, kept for accuracy parity).

Numerics conventions shared with the rust reference executor
(`rust/src/ops/exec.rs`):
- LeakyReLU slope 0.2 (GAT paper default).
- GrAx1 additive mask constant −1e9.
- SAGE-max assumes non-negative features (post-ReLU), per paper Fig. 18.
"""

from __future__ import annotations

import jax.numpy as jnp

LEAKY_SLOPE = 0.2
NEG_MASK = -1.0e9


# ---------------------------------------------------------------------------
# StaGr / PreG: aggregation as dense MatMul against the precomputed mask.
# ---------------------------------------------------------------------------
def stagr_aggregate(norm: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """StaGr aggregation: ``norm @ x`` (norm = D^-1/2 (A+I) D^-1/2)."""
    return norm @ x


def gcn_layer(norm: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
              b: jnp.ndarray) -> jnp.ndarray:
    """One GraphConv layer with PreG folding: ``norm @ (x @ w) + b``.

    Combination first (x@w shrinks the feature dim from f to f'), then
    aggregation — the cheaper association order for f >> f'.
    """
    return norm @ (x @ w) + b


# ---------------------------------------------------------------------------
# GAT attention (single head, as in the paper's GraphAttn layer).
# ---------------------------------------------------------------------------
def gat_scores(h: jnp.ndarray, a_src: jnp.ndarray,
               a_dst: jnp.ndarray) -> jnp.ndarray:
    """Raw pre-mask attention logits e[i, j] = LeakyReLU(s_i + t_j)."""
    s = h @ a_src  # (n,)
    t = h @ a_dst  # (n,)
    e = s[:, None] + t[None, :]
    return jnp.where(e > 0, e, LEAKY_SLOPE * e)


def gat_attention_baseline(h: jnp.ndarray, a_src: jnp.ndarray,
                           a_dst: jnp.ndarray, adj: jnp.ndarray) -> jnp.ndarray:
    """Baseline mapping: Select(adj, e, -inf) → SoftMax → aggregate.

    The Select/where is the control-heavy op that lands on the DSP in the
    out-of-the-box NPU mapping (paper Fig. 5). Rows with no edges (padded
    nodes) would produce NaN through softmax(-inf row); real graphs always
    have self loops, and padded rows are sliced away by the caller.
    """
    e = gat_scores(h, a_src, a_dst)
    e = jnp.where(adj > 0, e, -jnp.inf)
    attn = jnp.exp(e - e.max(axis=1, keepdims=True))
    attn = jnp.where(jnp.isnan(attn), 0.0, attn)
    denom = attn.sum(axis=1, keepdims=True)
    attn = attn / jnp.maximum(denom, 1e-30)
    return attn @ h


def gat_attention_effop(h: jnp.ndarray, a_src: jnp.ndarray,
                        a_dst: jnp.ndarray, adj: jnp.ndarray) -> jnp.ndarray:
    """EffOp mapping: Select replaced by mask-multiply + complement bias.

    e_masked = e * adj + (1 - adj) * (−1e9): pure elementwise DPU ops.
    """
    e = gat_scores(h, a_src, a_dst)
    e = e * adj + (1.0 - adj) * NEG_MASK
    attn = jnp.exp(e - e.max(axis=1, keepdims=True))
    attn = attn / attn.sum(axis=1, keepdims=True)
    return attn @ h


def gat_attention_grax(h: jnp.ndarray, a_src: jnp.ndarray,
                       a_dst: jnp.ndarray, neg_bias: jnp.ndarray) -> jnp.ndarray:
    """GrAx1 (+GrAx2) mapping: additive mask, no masking multiplications.

    ``neg_bias`` is the precomputed (1 − adj) * (−1e9) matrix; masking is a
    single elementwise add (paper Fig. 16). GrAx2 restructures the
    broadcast-add of s_i + t_j to add-then-broadcast (paper Fig. 17) — the
    same arithmetic with fewer transposes/copies, so the oracle differs
    from EffOp only in using the additive mask. Note the approximation:
    on-edge logits keep their raw value instead of e*1, and off-edge logits
    become e − 1e9 instead of exactly −1e9 — negligible after SoftMax.
    """
    e = gat_scores(h, a_src, a_dst)
    e = e + neg_bias
    attn = jnp.exp(e - e.max(axis=1, keepdims=True))
    attn = attn / attn.sum(axis=1, keepdims=True)
    return attn @ h


# ---------------------------------------------------------------------------
# GraphSAGE aggregation.
# ---------------------------------------------------------------------------
def sage_mean(mask: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Mean over the sampled neighborhood: rows of ``mask`` are 0/1."""
    deg = mask.sum(axis=1, keepdims=True)
    return (mask @ h) / jnp.maximum(deg, 1.0)


def sage_max_baseline(mask: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Baseline SAGE-max: per-row select of neighbor features, then max.

    Mirrors the sequential DSP gather: non-neighbors are masked to −inf so
    they never win the max; rows with no neighbors yield 0.
    """
    sel = jnp.where(mask[:, :, None] > 0, h[None, :, :], -jnp.inf)
    out = sel.max(axis=1)
    return jnp.where(jnp.isfinite(out), out, 0.0)


def sage_max_grax3(mask: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """GrAx3: mask-multiply + max-pool on the DPU (paper Fig. 18).

    out[i] = max_j mask[i,j] * h[j].  Exact when features are ≥ 0 (the
    layer input is post-ReLU); a node with no sampled neighbors yields 0,
    and any negative maxima are clipped to 0 — this is the approximation.
    """
    prod = mask[:, :, None] * h[None, :, :]
    return prod.max(axis=1)


# ---------------------------------------------------------------------------
# GraphSAGE, gathered formulation (the ≤10-sampled-neighbor structure).
#
# ``idx`` is (n, k+1) int32 from datasets.sampled_neighbors: column 0 = self,
# sentinel ``n`` marks unused slots. These are numerically *exactly* related
# to the dense-mask forms above (same sample): in particular
#     sage_max_grax3(mask, h) == maximum(sage_max_gathered(idx, h), 0)
# because every row of the sampled mask has at least one zero entry at
# Cora-scale sparsity, so the mask-multiply's zero always competes in the
# row max. The equivalence is asserted in python/tests/test_kernels.py and
# lets full-scale exports avoid n²·f intermediates.
# ---------------------------------------------------------------------------
def _gathered(idx: jnp.ndarray, h: jnp.ndarray,
              fill: float) -> jnp.ndarray:
    """(n, k+1, f) neighbor features with sentinel rows set to ``fill``."""
    phantom = jnp.full((1, h.shape[1]), fill, h.dtype)
    h_ext = jnp.concatenate([h, phantom], axis=0)
    return h_ext[idx]


def sage_max_gathered(idx: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Exact SAGE-max over the sampled neighborhood (baseline numerics)."""
    g = _gathered(idx, h, -jnp.inf)
    out = g.max(axis=1)
    return jnp.where(jnp.isfinite(out), out, 0.0)


def sage_max_grax3_gathered(idx: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """GrAx3 numerics via gather: max(sage_max, 0). See block comment."""
    return jnp.maximum(sage_max_gathered(idx, h), 0.0)


def sage_mean_gathered(idx: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Mean over the sampled neighborhood, sentinel slots excluded."""
    g = _gathered(idx, h, 0.0)
    valid = (idx < h.shape[0]).astype(h.dtype)  # (n, k+1)
    cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1.0)
    return g.sum(axis=1) / cnt


# ---------------------------------------------------------------------------
# QuantGr: symmetric static INT8.
# ---------------------------------------------------------------------------
def quant_scale(x_absmax: float) -> float:
    """Symmetric scale mapping |x| ≤ absmax onto int8 [−127, 127]."""
    return float(x_absmax) / 127.0 if x_absmax > 0 else 1.0


def quantize(x: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Symmetric static quantization to int8 with round-to-nearest."""
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: float) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def quant_matmul(xq: jnp.ndarray, wq: jnp.ndarray, x_scale: float,
                 w_scale: float) -> jnp.ndarray:
    """INT8×INT8 → INT32 accumulate → FP32 dequantize (QuantGr datapath)."""
    acc = jnp.matmul(xq.astype(jnp.int32), wq.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (x_scale * w_scale)
