"""StaGr / PreG / GrAd Pallas kernels: aggregation as dense MatMul.

StaGr (paper Fig. 9) turns node aggregation into a MatMul against a
precomputed mask; PreG (Fig. 14) folds the D^-1/2 normalization into that
mask so no sqrt/div ever reaches the DSP. GrAd (Fig. 11) is the same kernel
with the mask arriving as a runtime *input* instead of a baked constant —
at kernel level the two are identical; the difference lives in how aot.py
closes over the mask when lowering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def stagr_aggregate(norm: jnp.ndarray, x: jnp.ndarray, *, bm: int = tiling.BM,
                    bn: int = tiling.BN, bk: int = tiling.BK) -> jnp.ndarray:
    """StaGr aggregation ``norm @ x`` as an output-stationary tiled kernel."""
    return tiling.matmul(norm, x, bm=bm, bn=bn, bk=bk)


def _gcn_fused_kernel(norm_ref, xw_ref, b_ref, o_ref, *, nk: int):
    """Aggregate + bias in one pass: o = norm_blk @ xw_blk (+ b on last k).

    The norm tile is the stationary operand (the CacheG insight at kernel
    scale: the normalization matrix is reused across every feature column
    block, so it earns VMEM residency).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(norm_ref[...], xw_ref[...],
                          preferred_element_type=o_ref.dtype)

    @pl.when(pl.program_id(2) == nk - 1)
    def _bias():
        o_ref[...] += b_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gcn_layer(norm: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
              b: jnp.ndarray, bm: int = tiling.BM, bn: int = tiling.BN,
              bk: int = tiling.BK) -> jnp.ndarray:
    """One PreG-folded GraphConv layer: ``norm @ (x @ w) + b``.

    Combination (x @ w) runs first through the shared tiled MatMul —
    shrinking features from f to f' before the n×n aggregation — then the
    fused aggregate+bias kernel applies ``norm`` and the bias.
    """
    xw = tiling.matmul(x, w, bm=bm, bn=bn, bk=bk)  # (n, f')
    n, fp = xw.shape
    normp = tiling.pad_to(norm, (bm, bk))
    xwp = tiling.pad_to(xw, (bk, bn))
    bp = tiling.pad_to(b.reshape(1, -1), (1, bn))
    np_, kp = normp.shape
    _, fpp = xwp.shape
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_gcn_fused_kernel, nk=nk),
        grid=(np_ // bm, fpp // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, fpp), x.dtype),
        interpret=True,
    )(normp, xwp, bp)
    return out[:n, :fp]
