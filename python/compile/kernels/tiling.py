"""Shared tiling helpers for the Pallas kernels.

All kernels run with ``interpret=True`` (the CPU PJRT client cannot execute
Mosaic custom-calls — see /opt/xla-example/README.md), so these helpers are
about *structure*, not wall-clock: block shapes are chosen for the VMEM /
MXU analysis recorded in DESIGN.md §8, and the same tilings drive the NPU
simulator's DMA model on the rust side.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# NPU/TPU analysis tiling: 128 matches both the MXU systolic tile and the
# DPU tile width of the FlexNN-like NPU (M*N = 4*32 = 128 MACs per row).
# DESIGN.md §8's VMEM-budget analysis uses these.
NPU_BM = NPU_BN = NPU_BK = 128

# Default execution tiling: artifacts run through the CPU PJRT client in
# interpret mode, where per-grid-step overhead dominates — 512-cube tiles
# (L2-resident on the host) cut the grid iteration count ~64x with
# identical numerics. The NPU mapping keeps the 128-cube analysis above.
BM = 512
BN = 512
BK = 512


def pad_to(x: jnp.ndarray, multiples: tuple[int, ...]) -> jnp.ndarray:
    """Zero-pad each dim of ``x`` up to the next multiple (NodePad-style)."""
    pads = []
    for dim, mult in zip(x.shape, multiples):
        target = -(-dim // mult) * mult
        pads.append((0, target - dim))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _mm_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """Output-stationary tiled MatMul: accumulate k-blocks into o_ref."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jnp.ndarray, w: jnp.ndarray, bm: int = BM, bn: int = BN,
           bk: int = BK) -> jnp.ndarray:
    """Tiled Pallas MatMul ``x @ w`` with zero-padding to block multiples.

    The grid order (m, n, k) with the k-accumulate pattern mirrors the
    output-stationary dataflow of the paper's DPU: each output tile stays
    resident while operand tiles stream through.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    xp = pad_to(x, (bm, bk))
    wp = pad_to(w, (bk, bn))
    mp, kp = xp.shape
    _, np_ = wp.shape
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def vmem_bytes(block_shapes: list[tuple[int, ...]], dtype_bytes: int = 4) -> int:
    """VMEM footprint of a set of resident blocks — used by DESIGN.md §8
    analysis and asserted against the 2 MiB budget in tests."""
    total = 0
    for shape in block_shapes:
        size = dtype_bytes
        for d in shape:
            size *= d
        total += size
    return total
