"""QuantGr Pallas kernel: symmetric static INT8 MatMul.

INT8 halves DMA traffic versus FP16 and doubles DPU MACs/cycle (paper:
2× TOPS, 4× TOPS/W). The datapath is INT8×INT8 → INT32 accumulate →
FP32 dequantize with calibration-time scales (symmetric: zero-point 0).

The INT32 accumulator is mandatory: with k up to 3703 and |q| ≤ 127 the
dot product reaches ~6e7, beyond FP32's 2^24 exact-integer range — an FP32
accumulator would silently round. The kernel therefore carries an int32
output block through the k-grid and dequantizes outside.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _qmm_kernel(xq_ref, wq_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(xq_ref[...].astype(jnp.int32),
                          wq_ref[...].astype(jnp.int32),
                          preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def quant_matmul(xq: jnp.ndarray, wq: jnp.ndarray, x_scale: float,
                 w_scale: float, bm: int = tiling.BM, bn: int = tiling.BN,
                 bk: int = tiling.BK) -> jnp.ndarray:
    """Dequantized product ``(xq @ wq) * x_scale * w_scale`` (fp32)."""
    m, k = xq.shape
    _, n = wq.shape
    xp = tiling.pad_to(xq, (bm, bk))
    wp = tiling.pad_to(wq, (bk, bn))
    mp, kp = xp.shape
    _, np_ = wp.shape
    acc = pl.pallas_call(
        _qmm_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(xp, wp)
    return acc[:m, :n].astype(jnp.float32) * (x_scale * w_scale)
