"""Fused GAT attention Pallas kernel (EffOp + GrAx1 + GrAx2 datapath).

The out-of-the-box GraphAttn mapping spends ~30% of compute time in
Select / Greater / SoftMax / Elu on the DSP (paper Fig. 5). This kernel is
the DPU-friendly rewrite: the whole attention row —

    e[i, :] = LeakyReLU(s_i + t)            (GrAx2: add, then broadcast)
    e[i, :] += neg_bias[i, :]               (GrAx1: additive mask)
    attn[i, :] = softmax(e[i, :])
    out[i, :] = attn[i, :] @ h

— is computed branch-free over row blocks, with the node-feature matrix
``h`` held stationary (it is reused by every row block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling

LEAKY_SLOPE = 0.2


def _attention_kernel(h_rows_ref, h_all_ref, a_src_ref, a_dst_ref,
                      neg_bias_ref, o_ref):
    """One row-block of fused masked attention.

    Shapes (bm = row block, n = padded node count, f = head dim):
      h_rows   (bm, f)   — the block's own features
      h_all    (n, f)    — stationary full feature matrix
      a_src    (f, 1), a_dst (f, 1)
      neg_bias (bm, n)   — (1 − adj) * (−1e9), precomputed on CPU
      o        (bm, f)
    """
    h_rows = h_rows_ref[...]
    h_all = h_all_ref[...]
    # GrAx2: compute the two projections separately and broadcast once.
    s = jnp.dot(h_rows, a_src_ref[...],
                preferred_element_type=h_rows.dtype)  # (bm, 1)
    t = jnp.dot(h_all, a_dst_ref[...],
                preferred_element_type=h_rows.dtype)  # (n, 1)
    e = s + t.T  # (bm, n) — single broadcast-add, no transpose of data
    # LeakyReLU without Select: max(x, 0) + slope * min(x, 0).
    e = jnp.maximum(e, 0.0) + LEAKY_SLOPE * jnp.minimum(e, 0.0)
    # GrAx1: additive mask instead of multiplicative masking.
    e = e + neg_bias_ref[...]
    # Numerically-stable row softmax, all elementwise/reduction DPU ops.
    m = jnp.max(e, axis=1, keepdims=True)
    p = jnp.exp(e - m)
    attn = p / jnp.sum(p, axis=1, keepdims=True)
    o_ref[...] = jnp.dot(attn, h_all, preferred_element_type=h_rows.dtype)


@functools.partial(jax.jit, static_argnames=("bm",))
def gat_attention(h: jnp.ndarray, a_src: jnp.ndarray, a_dst: jnp.ndarray,
                  neg_bias: jnp.ndarray, bm: int = tiling.BM) -> jnp.ndarray:
    """Fused masked-softmax attention aggregation: returns attn @ h.

    ``neg_bias`` rows for padded nodes should be 0 at their own diagonal
    (or anywhere) so softmax stays finite; the caller slices padded rows.
    """
    n, f = h.shape
    hp = tiling.pad_to(h, (bm, 1))
    np_ = hp.shape[0]
    nb = tiling.pad_to(neg_bias, (bm, 1))
    # Pad mask columns for phantom rows with the mask value so phantom
    # columns never attract attention mass.
    if np_ > n:
        pad_cols = jnp.full((nb.shape[0], np_ - n), -1.0e9, dtype=h.dtype)
        nb = jnp.concatenate([nb[:, :n], pad_cols], axis=1)
    out = pl.pallas_call(
        _attention_kernel,
        grid=(np_ // bm,),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
            pl.BlockSpec((np_, f), lambda i: (0, 0)),
            pl.BlockSpec((f, 1), lambda i: (0, 0)),
            pl.BlockSpec((f, 1), lambda i: (0, 0)),
            pl.BlockSpec((bm, np_), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, f), h.dtype),
        interpret=True,
    )(hp, hp, a_src.reshape(-1, 1), a_dst.reshape(-1, 1), nb)
    return out[:n]
