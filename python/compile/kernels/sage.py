"""GraphSAGE aggregation Pallas kernels (GrAx3 + mean).

SAGE-max traditionally gathers each node's sampled neighbors sequentially
on the DSP. GrAx3 (paper Fig. 18) replaces this with a mask-multiply
followed by max-pooling — dense, branch-free DPU work. The mean aggregator
is a MatMul against the row-normalized sampled adjacency (StaGr-style).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _sage_max_kernel(mask_ref, h_ref, o_ref):
    """Running max over neighbor blocks.

    Grid is (row blocks, neighbor blocks); for each (i, k):
      o[i] = max(o[i], max_j mask[i, jk] * h[jk])
    The first neighbor block initializes o directly, so the result equals
    max over *all* j of mask * h — exactly the GrAx3 oracle, including its
    clipping behaviour for all-non-positive rows.
    """
    prod = mask_ref[...][:, :, None] * h_ref[...][None, :, :]
    blk_max = prod.max(axis=1)  # (bm, f)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = blk_max

    @pl.when(pl.program_id(1) != 0)
    def _fold():
        o_ref[...] = jnp.maximum(o_ref[...], blk_max)


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def sage_max(mask: jnp.ndarray, h: jnp.ndarray, bm: int = tiling.BM,
             bk: int = tiling.BK) -> jnp.ndarray:
    """GrAx3 max aggregation: out[i] = max_j mask[i,j] * h[j].

    Padded (phantom) neighbor columns carry mask 0 and features 0, so the
    padded blocks contribute ``0`` to the running max — identical to the
    oracle's behaviour on the unpadded mask, whose every row contains a
    self-loop zero-or-positive entry.
    """
    n, f = h.shape
    maskp = tiling.pad_to(mask, (bm, bk))
    hp = tiling.pad_to(h, (bk, 1))
    np_, kp = maskp.shape
    out = pl.pallas_call(
        _sage_max_kernel,
        grid=(np_ // bm, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, f), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, f), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, f), h.dtype),
        interpret=True,
    )(maskp, hp)
    return out[:n]


def sage_mean(mask: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Mean aggregation as a StaGr MatMul against the normalized mask.

    The row normalization (divide by sampled degree) happens *outside* the
    MatMul on precomputed degrees — PreG's trick applied to SAGE — so the
    NPU never executes a division per element.
    """
    deg = mask.sum(axis=1, keepdims=True)
    norm_mask = mask / jnp.maximum(deg, 1.0)
    return tiling.matmul(norm_mask, h)
