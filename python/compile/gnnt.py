"""`.gnnt` — the flat tensor container shared between python and rust.

`aot.py` writes model weights, dataset twins, masks and quantization scales
into this format; `rust/src/runtime/io.rs` implements the mirror reader (and
a writer, used by rust-side tests). Keep the two in sync.

Layout (little-endian):

    magic   : 4 bytes  b"GNNT"
    version : u32      (currently 1)
    count   : u32      number of tensors
    then per tensor:
        name_len : u16
        name     : utf-8 bytes
        dtype    : u8   (0=f32, 1=i8, 2=i32, 3=u8, 4=f16-as-u16)
        ndim     : u8
        dims     : ndim * u32
        data     : prod(dims) * sizeof(dtype) bytes

No alignment padding; readers stream sequentially.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"GNNT"
VERSION = 1

_DTYPES: dict[int, np.dtype] = {
    0: np.dtype("<f4"),
    1: np.dtype("i1"),
    2: np.dtype("<i4"),
    3: np.dtype("u1"),
    4: np.dtype("<u2"),  # raw f16 bits
}
_CODES = {v: k for k, v in _DTYPES.items()}


def _code_for(arr: np.ndarray) -> int:
    dt = arr.dtype
    if dt == np.float32:
        return 0
    if dt == np.int8:
        return 1
    if dt == np.int32:
        return 2
    if dt == np.uint8:
        return 3
    if dt == np.float16 or dt == np.uint16:
        return 4
    raise TypeError(f"unsupported dtype {dt} for .gnnt")


def write(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write named tensors to ``path`` in .gnnt format."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _code_for(arr)
            if code == 4 and arr.dtype == np.float16:
                arr = arr.view(np.uint16)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype.newbyteorder("<"), copy=False).tobytes())


def read(path: str) -> dict[str, np.ndarray]:
    """Read a .gnnt file back into named numpy arrays."""
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version, count = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = _DTYPES[code]
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * dt.itemsize), dtype=dt)
            out[name] = data.reshape(dims).copy()
    return out
