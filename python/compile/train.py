"""Train the GNN models on the synthetic dataset twins (build-time only).

The paper deploys *pre-trained* models (PyTorch + PyG, lr 0.01, weight
decay 5e-4, 100 epochs — §V); GraNNite itself never retrains. This module
is our stand-in for that training step: pure-JAX full-batch training with
a hand-rolled Adam (optax is unavailable offline). Trained weights are
serialized to `.gnnt` by aot.py and consumed by the rust runtime.

Training always goes through the *reference* (pure-jnp) forward paths —
gradients through interpret-mode Pallas are slow and pointless at build
time; kernel/oracle agreement is separately enforced by the test suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets
from .models import HIDDEN, gat, gcn, sage_net

LR = 0.01
WEIGHT_DECAY = 5e-4
EPOCHS = 100
SAGE_MAX_NEIGHBORS = 10  # paper §V


# ---------------------------------------------------------------------------
# Minimal Adam (the optimizer substrate — no optax offline).
# ---------------------------------------------------------------------------
def adam_init(params: dict) -> dict:
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adam_step(params: dict, grads: dict, state: dict, lr: float = LR,
              b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    scale = lr * jnp.sqrt(1 - b2 ** tf) / (1 - b1 ** tf)
    new_params = jax.tree.map(
        lambda p, m_, v_: p - scale * m_ / (jnp.sqrt(v_) + eps), params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def accuracy(logits: np.ndarray, labels: np.ndarray,
             mask: np.ndarray) -> float:
    pred = np.asarray(logits).argmax(axis=-1)
    sel = np.asarray(mask, bool)
    return float((pred[sel] == np.asarray(labels)[sel]).mean())


# ---------------------------------------------------------------------------
# Per-model training drivers.
# ---------------------------------------------------------------------------
def _train(apply_fn, params: dict, inputs: tuple, labels: np.ndarray,
           train_mask: np.ndarray, val_mask: np.ndarray,
           epochs: int = EPOCHS, verbose: bool = False):
    labels_j = jnp.asarray(labels)
    tr = jnp.asarray(train_mask, jnp.float32)

    def loss_fn(p):
        logits = apply_fn(p, *inputs)
        l2 = sum(jnp.sum(w * w) for w in jax.tree.leaves(p))
        return cross_entropy(logits, labels_j, tr) + WEIGHT_DECAY * l2

    @jax.jit
    def step(p, s):
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p, s = adam_step(p, grads, s)
        return p, s, loss

    state = adam_init(params)
    history = []
    for epoch in range(epochs):
        params, state, loss = step(params, state)
        if verbose and (epoch % 10 == 0 or epoch == epochs - 1):
            logits = apply_fn(params, *inputs)
            va = accuracy(np.asarray(logits), labels, val_mask)
            print(f"  epoch {epoch:3d} loss {float(loss):.4f} val_acc {va:.3f}")
        history.append(float(loss))
    return params, history


def train_gcn(ds: datasets.GraphDataset, seed: int = 0, epochs: int = EPOCHS,
              verbose: bool = False):
    norm = jnp.asarray(ds.norm_adjacency())
    x = jnp.asarray(ds.features)
    params = gcn.init_params(jax.random.key(seed), ds.num_features, HIDDEN,
                             ds.num_classes)
    params, hist = _train(gcn.apply_stagr_ref, params, (norm, x), ds.labels,
                          ds.train_mask, ds.val_mask, epochs, verbose)
    logits = gcn.apply_stagr_ref(params, norm, x)
    return params, {
        "loss": hist,
        "test_acc": accuracy(np.asarray(logits), ds.labels, ds.test_mask),
    }


# single-head GAT needs a longer schedule than GCN to escape the uniform-
# attention plateau (see EXPERIMENTS.md §Datasets)
GAT_EPOCHS = 300


def train_gat(ds: datasets.GraphDataset, seed: int = 0, epochs: int = EPOCHS,
              verbose: bool = False):
    if epochs == EPOCHS:
        epochs = GAT_EPOCHS
    adj = jnp.asarray(ds.adjacency())
    x = jnp.asarray(ds.features)
    params = gat.init_params(jax.random.key(seed), ds.num_features, HIDDEN,
                             ds.num_classes)
    params, hist = _train(gat.apply_effop, params, (adj, x), ds.labels,
                          ds.train_mask, ds.val_mask, epochs, verbose)
    logits = gat.apply_effop(params, adj, x)
    return params, {
        "loss": hist,
        "test_acc": accuracy(np.asarray(logits), ds.labels, ds.test_mask),
    }


def train_sage(ds: datasets.GraphDataset, aggregator: str = "mean",
               seed: int = 0, epochs: int = EPOCHS, verbose: bool = False):
    idx = jnp.asarray(ds.sampled_neighbors(SAGE_MAX_NEIGHBORS))
    x = jnp.asarray(ds.features)
    params = sage_net.init_params(jax.random.key(seed), ds.num_features,
                                  HIDDEN, ds.num_classes)
    apply_fn = (sage_net.apply_mean_gathered if aggregator == "mean"
                else sage_net.apply_max_grax3_gathered)
    params, hist = _train(apply_fn, params, (idx, x), ds.labels,
                          ds.train_mask, ds.val_mask, epochs, verbose)
    logits = apply_fn(params, idx, x)
    return params, {
        "loss": hist,
        "test_acc": accuracy(np.asarray(logits), ds.labels, ds.test_mask),
    }


TRAINERS = {
    "gcn": train_gcn,
    "gat": train_gat,
    "sage_mean": functools.partial(train_sage, aggregator="mean"),
    "sage_max": functools.partial(train_sage, aggregator="max"),
}


if __name__ == "__main__":
    ds = datasets.cora_twin()
    for name, trainer in TRAINERS.items():
        print(f"training {name} on {ds.name}")
        _, report = trainer(ds, verbose=True)
        print(f"  {name}: test_acc={report['test_acc']:.3f}")
