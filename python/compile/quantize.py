"""QuantGr calibration: symmetric static INT8 scales (build-time).

Static quantization precomputes scale/zero-point during model calibration
(paper §IV-C): we run the FP32 model once over the calibration inputs,
record per-tensor absolute maxima for weights and activations, and derive
symmetric scales (zero point 0, equal positive/negative range). The scales
ship with the weights in the `.gnnt` artifact and stay fixed at runtime.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernels import ref


def absmax_scale(x: np.ndarray, percentile: float = 100.0) -> float:
    """Symmetric scale from the |x| distribution.

    ``percentile < 100`` clips outliers — the standard calibration trick;
    the default keeps exact absmax, which suffices at GNN scale.
    """
    a = np.abs(np.asarray(x, dtype=np.float32)).reshape(-1)
    if a.size == 0:
        return 1.0
    m = float(np.percentile(a, percentile)) if percentile < 100.0 \
        else float(a.max())
    return ref.quant_scale(m)


def calibrate_gcn(params: dict, norm: jnp.ndarray, x: jnp.ndarray,
                  percentile: float = 100.0) -> dict[str, float]:
    """Record activation/weight scales for both GCN layers."""
    from .models import gcn

    # Layer-1 activation input is x itself; layer-2's is the post-ReLU h1.
    h1 = jnp.maximum(ref.gcn_layer(norm, x, params["w1"], params["b1"]), 0.0)
    return {
        "act1": absmax_scale(np.asarray(x), percentile),
        "w1": absmax_scale(np.asarray(params["w1"]), percentile),
        "act2": absmax_scale(np.asarray(h1), percentile),
        "w2": absmax_scale(np.asarray(params["w2"]), percentile),
    }


def quantize_weights(params: dict, scales: dict[str, float]) -> dict:
    """INT8 weight tensors for the .gnnt artifact (w1/w2 only)."""
    return {
        "w1q": np.asarray(ref.quantize(params["w1"], scales["w1"])),
        "w2q": np.asarray(ref.quantize(params["w2"], scales["w2"])),
    }


def quant_error(params: dict, norm: jnp.ndarray, x: jnp.ndarray,
                scales: dict[str, float]) -> dict[str, float]:
    """Logit-level error of the INT8 path vs FP32 — sanity telemetry."""
    from .models import gcn

    fp = np.asarray(gcn.apply_stagr_ref(params, norm, x))
    q = np.asarray(gcn.apply_quant_ref(params, norm, x, scales))
    denom = float(np.abs(fp).max()) or 1.0
    agree = float((fp.argmax(-1) == q.argmax(-1)).mean())
    return {
        "max_abs_err": float(np.abs(fp - q).max()),
        "rel_err": float(np.abs(fp - q).max()) / denom,
        "argmax_agreement": agree,
    }
