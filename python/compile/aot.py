"""AOT export: lower every model variant to HLO text + pack .gnnt artifacts.

This is the single build-time entry point (`make artifacts`). It:

1. builds the synthetic dataset twins and packs them into
   `artifacts/<dataset>.gnnt` (features/labels/masks/edges — derived
   matrices like the PreG norm are computed *in rust*, on the CPU side of
   GraphSplit, which is exactly where the paper puts them);
2. trains all four models per dataset, runs QuantGr calibration, and packs
   weights + scales into `artifacts/weights_<model>_<dataset>.gnnt`;
3. lowers every (model, variant) pair to `artifacts/<name>.hlo.txt` via the
   HLO-text interchange (xla_extension 0.5.1 rejects jax≥0.5 serialized
   protos — see /opt/xla-example/README.md);
4. writes `artifacts/manifest.toml` describing every artifact (inputs,
   shapes, dtypes) for the rust runtime's registry.

All big tensors (norm matrix, features, weights) are runtime *inputs* to
the lowered computations, never baked constants: HLO text constants at
2708² scale would be ~100 MB, and — more importantly — mask-as-input is
GrAd itself. The StaGr/GrAd distinction (precompute-once vs per-request
mask) lives in the rust coordinator's state manager.

Python never runs on the request path: after this script completes, the
rust binary is self-contained.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, gnnt, quantize, train
from .models import HIDDEN, gat, gcn, sage_net

# NodePad capacities (paper §V: Cora padded +292 nodes to a static 3000).
CAPACITY = {"cora": 3000, "citeseer": 3500}
DATASETS = ("cora", "citeseer")


# ---------------------------------------------------------------------------
# HLO-text lowering (the aot_recipe / load_hlo bridge).
# ---------------------------------------------------------------------------
def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def f32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def i8(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int8)


# ---------------------------------------------------------------------------
# Model-variant export table.
# ---------------------------------------------------------------------------
def gcn_exports(n: int, f: int, c: int, cap: int, scales: dict):
    """(name, fn, specs, input names) for every GCN variant."""
    h = HIDDEN
    w_specs = [f32(f, h), f32(h), f32(h, c), f32(c)]
    w_names = ["w1", "b1", "w2", "b2"]

    def stagr(norm, x, w1, b1, w2, b2):
        return gcn.apply_stagr({"w1": w1, "b1": b1, "w2": w2, "b2": b2},
                               norm, x)

    def baseline(edges, x, w1, b1, w2, b2):
        return gcn.apply_baseline({"w1": w1, "b1": b1, "w2": w2, "b2": b2},
                                  edges, x)

    def quant(norm, x, w1q, b1, w2q, b2):
        # Weights arrive pre-quantized (int8); activations are quantized
        # in-graph with the baked static scales — QuantGr's static scheme.
        from .kernels import quant as quant_k
        from .kernels import ref
        from .kernels import stagr as stagr_k
        xq = ref.quantize(x, scales["act1"])
        hw = quant_k.quant_matmul(xq, w1q, scales["act1"], scales["w1"])
        h1 = jax.nn.relu(stagr_k.stagr_aggregate(norm, hw) + b1)
        h1q = ref.quantize(h1, scales["act2"])
        hw2 = quant_k.quant_matmul(h1q, w2q, scales["act2"], scales["w2"])
        return stagr_k.stagr_aggregate(norm, hw2) + b2

    m = 5429 if n == 2708 else 4732
    qw_specs = [i8(f, h), f32(h), i8(h, c), f32(c)]
    qw_names = ["w1q", "b1", "w2q", "b2"]
    return [
        ("gcn_stagr", stagr, [f32(n, n), f32(n, f)] + w_specs,
         ["norm", "x"] + w_names),
        ("gcn_grad", stagr, [f32(cap, cap), f32(cap, f)] + w_specs,
         ["norm_pad", "x_pad"] + w_names),
        ("gcn_baseline", baseline, [i32(m, 2), f32(n, f)] + w_specs,
         ["edges", "x"] + w_names),
        ("gcn_quant", quant, [f32(n, n), f32(n, f)] + qw_specs,
         ["norm", "x"] + qw_names),
        ("gcn_quant_grad", quant, [f32(cap, cap), f32(cap, f)] + qw_specs,
         ["norm_pad", "x_pad"] + qw_names),
    ]


def gat_exports(n: int, f: int, c: int):
    h = HIDDEN
    w_specs = [f32(f, h), f32(h), f32(h), f32(h),
               f32(h, c), f32(c), f32(c), f32(c)]
    w_names = ["w1", "a1_src", "a1_dst", "b1",
               "w2", "a2_src", "a2_dst", "b2"]

    def pack(w1, a1s, a1d, b1, w2, a2s, a2d, b2):
        return {"w1": w1, "a1_src": a1s, "a1_dst": a1d, "b1": b1,
                "w2": w2, "a2_src": a2s, "a2_dst": a2d, "b2": b2}

    def baseline(adj, x, *w):
        return gat.apply_baseline(pack(*w), adj, x)

    def effop(adj, x, *w):
        return gat.apply_effop(pack(*w), adj, x)

    def grax(neg_bias, x, *w):
        return gat.apply_grax(pack(*w), neg_bias, x)

    return [
        ("gat_baseline", baseline, [f32(n, n), f32(n, f)] + w_specs,
         ["adj", "x"] + w_names),
        ("gat_effop", effop, [f32(n, n), f32(n, f)] + w_specs,
         ["adj", "x"] + w_names),
        ("gat_grax", grax, [f32(n, n), f32(n, f)] + w_specs,
         ["neg_bias", "x"] + w_names),
    ]


def sage_exports(n: int, f: int, c: int, k: int):
    """SAGE variants over the gathered (n, k+1) neighbor-index input.

    The dense-mask Pallas sage_max kernel is exported separately at
    event-vision scale (``sage_exports_small``); at Cora scale the gathered
    formulation is numerically identical (kernels/ref.py) and avoids n²·f
    intermediates in the lowered HLO.
    """
    h = HIDDEN
    w_specs = [f32(f, h), f32(f, h), f32(h),
               f32(h, c), f32(h, c), f32(c)]
    w_names = ["w1_self", "w1_neigh", "b1", "w2_self", "w2_neigh", "b2"]

    def pack(w1s, w1n, b1, w2s, w2n, b2):
        return {"w1_self": w1s, "w1_neigh": w1n, "b1": b1,
                "w2_self": w2s, "w2_neigh": w2n, "b2": b2}

    def mean(idx, x, *w):
        return sage_net.apply_mean_gathered(pack(*w), idx, x)

    def max_base(idx, x, *w):
        return sage_net.apply_max_baseline_gathered(pack(*w), idx, x)

    def max_grax3(idx, x, *w):
        return sage_net.apply_max_grax3_gathered(pack(*w), idx, x)

    specs = [i32(n, k + 1), f32(n, f)] + w_specs
    names = ["nbr_idx", "x"] + w_names
    return [
        ("sage_mean", mean, specs, names),
        ("sage_max_baseline", max_base, specs, names),
        ("sage_max_grax3", max_grax3, specs, names),
    ]


# Event-vision example scale (examples/event_vision.rs): small sliding
# graphs where the dense-mask Pallas kernels are the right mapping.
EV_NODES, EV_FEATURES, EV_CLASSES = 1024, 16, 4


def sage_exports_small():
    """Dense-mask SAGE-max via the real Pallas GrAx3 kernel (small scale)."""
    n, f, c = EV_NODES, EV_FEATURES, EV_CLASSES
    h = HIDDEN
    w_specs = [f32(f, h), f32(f, h), f32(h),
               f32(h, c), f32(h, c), f32(c)]
    w_names = ["w1_self", "w1_neigh", "b1", "w2_self", "w2_neigh", "b2"]

    def pack(w1s, w1n, b1, w2s, w2n, b2):
        return {"w1_self": w1s, "w1_neigh": w1n, "b1": b1,
                "w2_self": w2s, "w2_neigh": w2n, "b2": b2}

    def max_grax3(mask, x, *w):
        return sage_net.apply_max_grax3(pack(*w), mask, x)

    specs = [f32(n, n), f32(n, f)] + w_specs
    names = ["mask", "x"] + w_names
    return [("sage_max_grax3_ev", max_grax3, specs, names)]


# ---------------------------------------------------------------------------
# Driver.
# ---------------------------------------------------------------------------
def export_dataset(ds, out_dir: str) -> dict:
    path = os.path.join(out_dir, f"{ds.name}.gnnt")
    gnnt.write(path, {
        "features": ds.features,
        "labels": ds.labels.astype(np.int32),
        "edges": ds.edges.astype(np.int32),
        "train_mask": ds.train_mask.astype(np.uint8),
        "val_mask": ds.val_mask.astype(np.uint8),
        "test_mask": ds.test_mask.astype(np.uint8),
        # The exact neighbor sample used at train/export time, so the rust
        # coordinator feeds byte-identical gather indices to the artifacts.
        "nbr_idx": ds.sampled_neighbors(train.SAGE_MAX_NEIGHBORS),
    })
    return {"path": os.path.basename(path), "nodes": ds.num_nodes,
            "edges": ds.num_edges, "features": ds.num_features,
            "classes": ds.num_classes,
            "capacity": CAPACITY.get(ds.name, ds.num_nodes)}


def run(out_dir: str, names: list[str], epochs: int,
        skip_hlo: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest: list[str] = ["# generated by python -m compile.aot", ""]

    for ds_name in names:
        t0 = time.time()
        ds = datasets.load(ds_name)
        info = export_dataset(ds, out_dir)
        n, f, c, cap = (ds.num_nodes, ds.num_features, ds.num_classes,
                        CAPACITY.get(ds_name, ds.num_nodes))
        manifest += [f"[dataset.{ds_name}]"] + [
            f"{k} = {v!r}" if isinstance(v, str) else f"{k} = {v}"
            for k, v in info.items()] + [""]
        print(f"[{ds_name}] dataset packed ({time.time() - t0:.1f}s)")

        # --- training + calibration ------------------------------------
        norm = jnp.asarray(ds.norm_adjacency())
        x = jnp.asarray(ds.features)
        trained: dict[str, dict] = {}
        for model in ("gcn", "gat", "sage_mean", "sage_max"):
            t1 = time.time()
            params, report = train.TRAINERS[model](ds, epochs=epochs)
            trained[model] = params
            tensors = {k: np.asarray(v) for k, v in params.items()}
            tensors["loss_history"] = np.asarray(report["loss"], np.float32)
            tensors["test_acc"] = np.asarray([report["test_acc"]], np.float32)
            if model == "gcn":
                scales = quantize.calibrate_gcn(params, norm, x)
                qw = quantize.quantize_weights(params, scales)
                tensors.update(qw)
                tensors["scales"] = np.asarray(
                    [scales["act1"], scales["w1"], scales["act2"],
                     scales["w2"]], np.float32)
                err = quantize.quant_error(params, norm, x, scales)
                print(f"[{ds_name}] quant: rel_err={err['rel_err']:.4f} "
                      f"argmax_agree={err['argmax_agreement']:.3f}")
            wpath = os.path.join(out_dir, f"weights_{model}_{ds_name}.gnnt")
            gnnt.write(wpath, tensors)
            manifest += [f"[weights.{model}_{ds_name}]",
                         f"path = {os.path.basename(wpath)!r}",
                         f"test_acc = {report['test_acc']:.4f}", ""]
            print(f"[{ds_name}] trained {model}: "
                  f"test_acc={report['test_acc']:.3f} "
                  f"({time.time() - t1:.1f}s)")

        if skip_hlo:
            continue

        # --- HLO lowering ------------------------------------------------
        gcn_scales = quantize.calibrate_gcn(trained["gcn"], norm, x)
        exports = (gcn_exports(n, f, c, cap, gcn_scales)
                   + gat_exports(n, f, c)
                   + sage_exports(n, f, c, train.SAGE_MAX_NEIGHBORS))
        if ds_name == names[0]:
            exports = exports + sage_exports_small()
            # Random-init weights for the event-vision demo model (the demo
            # measures latency/throughput, not accuracy).
            ev_params = sage_net.init_params(
                jax.random.key(42), EV_FEATURES, HIDDEN, EV_CLASSES)
            gnnt.write(os.path.join(out_dir, "weights_sage_ev.gnnt"),
                       {k: np.asarray(v) for k, v in ev_params.items()})
            manifest += ["[weights.sage_ev]",
                         "path = 'weights_sage_ev.gnnt'", ""]
        for name, fn, specs, input_names in exports:
            t1 = time.time()
            text = lower(fn, *specs)
            fname = f"{name}_{ds_name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as fh:
                fh.write(text)
            model = ("sage_mean" if name.startswith("sage_mean")
                     else "sage_max" if name.startswith("sage_max")
                     else name.split("_")[0])
            # recorded explicitly so the rust runtime rebuilds the exact
            # op-graph variant instead of re-deriving it from the name
            variant = name[len(model):].lstrip("_")
            manifest += [
                f"[artifact.{name}_{ds_name}]",
                f"path = {fname!r}",
                f"model = {model!r}",
                f"variant = {variant!r}",
                f"dataset = {ds_name!r}",
                "inputs = " + repr(",".join(input_names)),
                "shapes = " + repr(";".join(
                    "x".join(str(d) for d in s.shape) for s in specs)),
                "dtypes = " + repr(",".join(
                    str(s.dtype.name) for s in specs)),
                "",
            ]
            print(f"[{ds_name}] lowered {name} "
                  f"({len(text) / 1e6:.2f} MB, {time.time() - t1:.1f}s)")

    with open(os.path.join(out_dir, "manifest.toml"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print(f"manifest written: {os.path.join(out_dir, 'manifest.toml')}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--datasets", default="cora,citeseer")
    p.add_argument("--epochs", type=int,
                   default=int(os.environ.get("GRANNITE_EPOCHS", train.EPOCHS)))
    p.add_argument("--skip-hlo", action="store_true",
                   help="only datasets + weights (fast test mode)")
    args = p.parse_args()
    run(args.out, args.datasets.split(","), args.epochs, args.skip_hlo)


if __name__ == "__main__":
    sys.exit(main())
