"""2-layer single-head GAT — baseline, EffOp, and GrAx1/GrAx2 variants.

    h1     = ELU( attn(norm-mask, x @ W1) + b1 )
    logits =      attn(norm-mask, h1 @ W2) + b2

where ``attn`` is masked-softmax attention with LeakyReLU(0.2) logits.

Variant ladder (paper Figs. 12, 16, 17):
- ``apply_baseline``: Select(adj, e, −inf) masking — the DSP-bound mapping.
- ``apply_effop``:    mask-multiply + complement bias (DPU elementwise).
- ``apply_grax``:     additive −1e9 mask (GrAx1) with add-then-broadcast
                      score assembly (GrAx2), fused in the Pallas kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import attention as attn_k
from ..kernels import ref


def init_params(rng: jax.Array, num_features: int, hidden: int,
                num_classes: int) -> dict:
    k1, k2, k3, k4, k5, k6 = jax.random.split(rng, 6)
    s1 = jnp.sqrt(6.0 / (num_features + hidden))
    s2 = jnp.sqrt(6.0 / (hidden + num_classes))

    def u(key, shape, s):
        return jax.random.uniform(key, shape, jnp.float32, -s, s)

    return {
        "w1": u(k1, (num_features, hidden), s1),
        "a1_src": u(k2, (hidden,), 0.1),
        "a1_dst": u(k3, (hidden,), 0.1),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": u(k4, (hidden, num_classes), s2),
        "a2_src": u(k5, (num_classes,), 0.1),
        "a2_dst": u(k6, (num_classes,), 0.1),
        "b2": jnp.zeros((num_classes,), jnp.float32),
    }


def _forward(params: dict, x: jnp.ndarray, attn_fn) -> jnp.ndarray:
    h = x @ params["w1"]
    h = attn_fn(h, params["a1_src"], params["a1_dst"]) + params["b1"]
    h = jax.nn.elu(h)
    g = h @ params["w2"]
    return attn_fn(g, params["a2_src"], params["a2_dst"]) + params["b2"]


def apply_baseline(params: dict, adj: jnp.ndarray,
                   x: jnp.ndarray) -> jnp.ndarray:
    return _forward(
        params, x,
        lambda h, a_s, a_d: ref.gat_attention_baseline(h, a_s, a_d, adj))


def apply_effop(params: dict, adj: jnp.ndarray,
                x: jnp.ndarray) -> jnp.ndarray:
    return _forward(
        params, x,
        lambda h, a_s, a_d: ref.gat_attention_effop(h, a_s, a_d, adj))


def apply_grax(params: dict, neg_bias: jnp.ndarray,
               x: jnp.ndarray) -> jnp.ndarray:
    """GrAx1+GrAx2 via the fused Pallas kernel.

    ``neg_bias = (1 − adj) * (−1e9)`` is precomputed on the CPU
    (GraphSplit places it there) and fed as a runtime input (GrAd).
    """
    return _forward(
        params, x,
        lambda h, a_s, a_d: attn_k.gat_attention(h, a_s, a_d, neg_bias))


def apply_grax_ref(params: dict, neg_bias: jnp.ndarray,
                   x: jnp.ndarray) -> jnp.ndarray:
    return _forward(
        params, x,
        lambda h, a_s, a_d: ref.gat_attention_grax(h, a_s, a_d, neg_bias))
