"""2-layer GCN (Kipf & Welling) — baseline and StaGr/PreG/GrAd variants.

    h1     = ReLU( norm @ x @ W1 + b1 )
    logits =       norm @ h1 @ W2 + b2

- ``apply_baseline``: edge-list scatter aggregation + on-device degree
  normalization (sqrt/div per node) — the control-heavy out-of-the-box
  mapping that lands on the DSP (paper Figs. 4/5).
- ``apply_stagr``: dense MatMul against the precomputed PreG norm matrix,
  via the Layer-1 Pallas kernel. With the matrix baked as a constant this
  is StaGr; passed as a runtime input it is GrAd (+NodePad when padded).
- ``apply_quant``: QuantGr — INT8 symmetric static quantization of both
  MatMul operands in each layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import quant as quant_k
from ..kernels import ref
from ..kernels import stagr as stagr_k


def init_params(rng: jax.Array, num_features: int, hidden: int,
                num_classes: int) -> dict:
    k1, k2 = jax.random.split(rng)
    # Glorot init, as in the Kipf reference implementation.
    s1 = jnp.sqrt(6.0 / (num_features + hidden))
    s2 = jnp.sqrt(6.0 / (hidden + num_classes))
    return {
        "w1": jax.random.uniform(k1, (num_features, hidden), jnp.float32, -s1, s1),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.uniform(k2, (hidden, num_classes), jnp.float32, -s2, s2),
        "b2": jnp.zeros((num_classes,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Baseline: scatter aggregation + on-device normalization.
# ---------------------------------------------------------------------------
def _scatter_aggregate(edges: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Sum neighbor features via Gather/Scatter over the edge list.

    ``edges`` is (m, 2) undirected; both directions plus self loops are
    accumulated. This is the irregular-memory-access pattern the paper's
    Fig. 3 preprocessing produces, kept here for numerical parity checks.
    """
    n = x.shape[0]
    src, dst = edges[:, 0], edges[:, 1]
    agg = jnp.zeros_like(x)
    agg = agg.at[dst].add(x[src])
    agg = agg.at[src].add(x[dst])
    return agg + x  # self loops


def _degrees(edges: jnp.ndarray, n: int) -> jnp.ndarray:
    deg = jnp.ones((n,), jnp.float32)  # self loop
    deg = deg.at[edges[:, 0]].add(1.0)
    deg = deg.at[edges[:, 1]].add(1.0)
    return deg


def apply_baseline(params: dict, edges: jnp.ndarray,
                   x: jnp.ndarray) -> jnp.ndarray:
    """Out-of-the-box mapping: normalization computed on device per layer."""
    n = x.shape[0]
    deg = _degrees(edges, n)
    inv_sqrt = 1.0 / jnp.sqrt(deg)  # the DSP sqrt/div PreG eliminates

    def layer(h, w, b):
        h = h * inv_sqrt[:, None]
        h = _scatter_aggregate(edges, h)
        h = h * inv_sqrt[:, None]
        return h @ w + b

    h1 = jax.nn.relu(layer(x, params["w1"], params["b1"]))
    return layer(h1, params["w2"], params["b2"])


# ---------------------------------------------------------------------------
# StaGr / PreG / GrAd: dense precomputed-mask aggregation (Pallas kernel).
# ---------------------------------------------------------------------------
def apply_stagr(params: dict, norm: jnp.ndarray,
                x: jnp.ndarray) -> jnp.ndarray:
    h1 = jax.nn.relu(stagr_k.gcn_layer(norm, x, params["w1"], params["b1"]))
    return stagr_k.gcn_layer(norm, h1, params["w2"], params["b2"])


def apply_stagr_ref(params: dict, norm: jnp.ndarray,
                    x: jnp.ndarray) -> jnp.ndarray:
    """Oracle-path twin of ``apply_stagr`` (pure jnp, no Pallas)."""
    h1 = jax.nn.relu(ref.gcn_layer(norm, x, params["w1"], params["b1"]))
    return ref.gcn_layer(norm, h1, params["w2"], params["b2"])


# ---------------------------------------------------------------------------
# QuantGr: INT8 symmetric static quantization.
# ---------------------------------------------------------------------------
def apply_quant(params: dict, norm: jnp.ndarray, x: jnp.ndarray,
                scales: dict) -> jnp.ndarray:
    """QuantGr datapath with calibration-time static scales.

    Combination MatMuls run INT8×INT8→INT32 on quantized activations and
    weights; aggregation keeps the FP norm matrix (its values are ≤1 and
    dominated by memory, not MACs). Scales come from `quantize.calibrate`.
    """

    def qlayer(h, w, b, s_act, s_w):
        hq = ref.quantize(h, s_act)
        wq = ref.quantize(w, s_w)
        hw = quant_k.quant_matmul(hq, wq, s_act, s_w)
        return stagr_k.stagr_aggregate(norm, hw) + b

    h1 = jax.nn.relu(qlayer(x, params["w1"], params["b1"],
                            scales["act1"], scales["w1"]))
    return qlayer(h1, params["w2"], params["b2"],
                  scales["act2"], scales["w2"])


def apply_quant_ref(params: dict, norm: jnp.ndarray, x: jnp.ndarray,
                    scales: dict) -> jnp.ndarray:
    def qlayer(h, w, b, s_act, s_w):
        hq = ref.quantize(h, s_act)
        wq = ref.quantize(w, s_w)
        hw = ref.quant_matmul(hq, wq, s_act, s_w)
        return ref.stagr_aggregate(norm, hw) + b

    h1 = jax.nn.relu(qlayer(x, params["w1"], params["b1"],
                            scales["act1"], scales["w1"]))
    return qlayer(h1, params["w2"], params["b2"],
                  scales["act2"], scales["w2"])
