"""2-layer GraphSAGE — mean and max aggregators, baseline and GrAx3.

    h1     = ReLU( x  @ W1_self + agg(mask, x)  @ W1_neigh + b1 )
    logits =       h1 @ W2_self + agg(mask, h1) @ W2_neigh + b2

``mask`` is the sampled adjacency (≤10 random neighbors + self, paper §V),
precomputed on the CPU and reused across inferences (StaGr for SAGE).

- mean: agg = row-normalized mask MatMul (always DPU-friendly).
- max, baseline: per-row neighbor select + max — sequential DSP work.
- max, GrAx3:    mask-multiply + max-pool Pallas kernel (paper Fig. 18);
                 exact for the post-ReLU (≥0) features of layer 2, and for
                 layer 1 whenever raw features are non-negative (bag-of-
                 words features are), else a documented approximation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ref
from ..kernels import sage as sage_k


def init_params(rng: jax.Array, num_features: int, hidden: int,
                num_classes: int) -> dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s1 = jnp.sqrt(6.0 / (num_features + hidden))
    s2 = jnp.sqrt(6.0 / (hidden + num_classes))

    def u(key, shape, s):
        return jax.random.uniform(key, shape, jnp.float32, -s, s)

    return {
        "w1_self": u(k1, (num_features, hidden), s1),
        "w1_neigh": u(k2, (num_features, hidden), s1),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2_self": u(k3, (hidden, num_classes), s2),
        "w2_neigh": u(k4, (hidden, num_classes), s2),
        "b2": jnp.zeros((num_classes,), jnp.float32),
    }


def _forward(params: dict, x: jnp.ndarray, agg_fn) -> jnp.ndarray:
    h1 = jax.nn.relu(x @ params["w1_self"] + agg_fn(x) @ params["w1_neigh"]
                     + params["b1"])
    return (h1 @ params["w2_self"] + agg_fn(h1) @ params["w2_neigh"]
            + params["b2"])


def apply_mean(params: dict, mask: jnp.ndarray,
               x: jnp.ndarray) -> jnp.ndarray:
    """SAGE-mean via the StaGr-style normalized-mask MatMul kernel."""
    return _forward(params, x, lambda h: sage_k.sage_mean(mask, h))


def apply_mean_ref(params: dict, mask: jnp.ndarray,
                   x: jnp.ndarray) -> jnp.ndarray:
    return _forward(params, x, lambda h: ref.sage_mean(mask, h))


def apply_max_baseline(params: dict, mask: jnp.ndarray,
                       x: jnp.ndarray) -> jnp.ndarray:
    """Sequential select-then-max mapping (DSP-bound out of the box)."""
    return _forward(params, x, lambda h: ref.sage_max_baseline(mask, h))


def apply_max_grax3(params: dict, mask: jnp.ndarray,
                    x: jnp.ndarray) -> jnp.ndarray:
    """GrAx3 mask-multiply + max-pool via the Pallas kernel."""
    return _forward(params, x, lambda h: sage_k.sage_max(mask, h))


def apply_max_grax3_ref(params: dict, mask: jnp.ndarray,
                        x: jnp.ndarray) -> jnp.ndarray:
    return _forward(params, x, lambda h: ref.sage_max_grax3(mask, h))


# ---------------------------------------------------------------------------
# Gathered (index-matrix) forms — the full-scale/deployment formulation.
# ``idx`` is (n, k+1) int32 from datasets.sampled_neighbors; numerically
# equivalent to the dense-mask forms above (see kernels/ref.py).
# ---------------------------------------------------------------------------
def apply_mean_gathered(params: dict, idx: jnp.ndarray,
                        x: jnp.ndarray) -> jnp.ndarray:
    return _forward(params, x, lambda h: ref.sage_mean_gathered(idx, h))


def apply_max_baseline_gathered(params: dict, idx: jnp.ndarray,
                                x: jnp.ndarray) -> jnp.ndarray:
    """Gather + sequential max — the control-heavy DSP mapping."""
    return _forward(params, x, lambda h: ref.sage_max_gathered(idx, h))


def apply_max_grax3_gathered(params: dict, idx: jnp.ndarray,
                             x: jnp.ndarray) -> jnp.ndarray:
    """GrAx3 numerics at deployment scale (= max(baseline, 0))."""
    return _forward(params, x, lambda h: ref.sage_max_grax3_gathered(idx, h))
