"""Layer-2 JAX model definitions (build-time only).

Each model module exposes:
    init_params(rng, num_features, hidden, num_classes) -> dict[str, array]
    apply_<variant>(params, inputs...) -> logits

Variants mirror the paper's optimization ladder: ``baseline`` is the
out-of-the-box mapping (control-heavy ops kept); the optimized variants
route through the Layer-1 Pallas kernels. All variants of a model are
numerically interchangeable up to the documented approximations, which is
asserted in python/tests/test_models.py.
"""

from . import gat, gcn, sage_net  # noqa: F401

HIDDEN = 64  # paper's layer config: 1433 -> 64 -> classes
