"""Synthetic dataset twins for Cora and Citeseer.

The paper evaluates on Cora (2708 nodes, 5429 edges, 7 classes, 1433
features) and Citeseer (3327 nodes, 4732 edges, 6 classes, 3703 features),
fetched by PyG over the network. This environment is offline, so we build
*deterministic synthetic twins* with matched statistics:

- planted-partition topology (intra-class edge preference) with exactly the
  published node/edge counts,
- class-correlated sparse bag-of-words features at Cora-like density
  (~1.3% of entries non-zero),
- Planetoid-style splits (140/500/1000 for Cora; 120/500/1000 for Citeseer).

Every GraNNite result depends on the datasets only through size, sparsity,
degree structure and class separability — all of which are matched. See
DESIGN.md §2.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Published statistics we mirror (paper §V).
CORA_SPEC = dict(name="cora", n=2708, m=5429, classes=7, features=1433,
                 train=140, val=500, test=1000, seed=0x5EED_C08A)
CITESEER_SPEC = dict(name="citeseer", n=3327, m=4732, classes=6,
                     features=3703, train=120, val=500, test=1000,
                     seed=0x5EED_C17E)

# Fraction of candidate edges drawn within the same class, and the
# signature-word likelihood boost. Tuned (see EXPERIMENTS.md §Datasets)
# so a 2-layer GCN lands in the paper's 75-82% Top-1 band: homophily 0.72
# + boost 3.0 gives GCN ≈ 0.815 vs the paper's 0.808 on real Cora.
HOMOPHILY = 0.72
# Feature density of Cora's bag-of-words matrix (~1.27% non-zeros).
FEATURE_DENSITY = 0.0127
# Number of "signature" words per class; signature words fire ~3x more.
SIGNATURE_WORDS_FRAC = 0.08
SIGNATURE_BOOST = 3.0


@dataclasses.dataclass
class GraphDataset:
    """An attributed graph for node classification.

    Attributes:
        name: dataset identifier ("cora", "citeseer", ...).
        edges: (m, 2) int32 array of undirected edges, each stored once
            with src < dst; no self loops, no duplicates.
        features: (n, f) float32 row-normalized bag-of-words matrix.
        labels: (n,) int32 class ids in [0, classes).
        train_mask / val_mask / test_mask: (n,) bool Planetoid-style splits.
    """

    name: str
    edges: np.ndarray
    features: np.ndarray
    labels: np.ndarray
    train_mask: np.ndarray
    val_mask: np.ndarray
    test_mask: np.ndarray

    @property
    def num_nodes(self) -> int:
        return self.features.shape[0]

    @property
    def num_edges(self) -> int:
        return self.edges.shape[0]

    @property
    def num_features(self) -> int:
        return self.features.shape[1]

    @property
    def num_classes(self) -> int:
        return int(self.labels.max()) + 1

    # ------------------------------------------------------------------
    # Derived matrices used by the GraNNite techniques.
    # ------------------------------------------------------------------
    def adjacency(self, pad_to: int | None = None) -> np.ndarray:
        """Dense symmetric adjacency with self loops (A + I).

        ``pad_to`` implements NodePad: absent nodes contribute all-zero
        rows/cols ("0" = no edge, per the paper), and crucially do NOT get
        self loops — a padded node must stay disconnected.
        """
        n = self.num_nodes
        cap = pad_to if pad_to is not None else n
        if cap < n:
            raise ValueError(f"pad_to={cap} < num_nodes={n}")
        a = np.zeros((cap, cap), dtype=np.float32)
        s, d = self.edges[:, 0], self.edges[:, 1]
        a[s, d] = 1.0
        a[d, s] = 1.0
        a[np.arange(n), np.arange(n)] = 1.0  # self loops on real nodes only
        return a

    def norm_adjacency(self, pad_to: int | None = None) -> np.ndarray:
        """PreG: the precomputed GraphConv normalization matrix.

        D^{-1/2} (A + I) D^{-1/2}, computed once on the CPU so the NPU only
        sees a dense MatMul (paper Fig. 14). Zero-degree (padded) nodes get
        a zero normalization row instead of a division by zero.
        """
        a = self.adjacency(pad_to)
        deg = a.sum(axis=1)
        inv_sqrt = np.where(deg > 0, 1.0 / np.sqrt(np.maximum(deg, 1e-12)), 0.0)
        return (a * inv_sqrt[:, None] * inv_sqrt[None, :]).astype(np.float32)

    def padded_features(self, pad_to: int) -> np.ndarray:
        """NodePad: zero-pad the feature matrix to the compiled capacity."""
        n, f = self.features.shape
        if pad_to < n:
            raise ValueError(f"pad_to={pad_to} < num_nodes={n}")
        out = np.zeros((pad_to, f), dtype=np.float32)
        out[:n] = self.features
        return out

    def neighbor_lists(self) -> list[list[int]]:
        """Adjacency lists (undirected, no self entry)."""
        n = self.num_nodes
        neighbors: list[list[int]] = [[] for _ in range(n)]
        for s, d in self.edges:
            neighbors[int(s)].append(int(d))
            neighbors[int(d)].append(int(s))
        return neighbors

    def sampled_neighbors(self, max_neighbors: int, seed: int = 7) -> np.ndarray:
        """GraphSAGE sampled neighborhood as a gather-index matrix.

        Returns (n, max_neighbors + 1) int32: column 0 is the node itself,
        the rest are ≤ max_neighbors sampled neighbors; unused slots hold
        the sentinel index ``n`` (callers append a phantom row to ``h``).
        The same (seed-deterministic) sample backs the dense
        ``sampled_adjacency`` mask, so the two formulations agree exactly.
        """
        n = self.num_nodes
        rng = np.random.default_rng(seed)
        idx = np.full((n, max_neighbors + 1), n, dtype=np.int32)
        for i, nbrs in enumerate(self.neighbor_lists()):
            if len(nbrs) > max_neighbors:
                nbrs = list(rng.choice(nbrs, size=max_neighbors,
                                       replace=False))
            idx[i, 0] = i
            idx[i, 1:1 + len(nbrs)] = nbrs
        return idx

    def sampled_adjacency(self, max_neighbors: int, seed: int = 7,
                          pad_to: int | None = None) -> np.ndarray:
        """GraphSAGE sampled adjacency mask (paper: ≤10 random neighbors).

        Row i has ones at up to ``max_neighbors`` sampled neighbors plus
        itself. Used by SAGE mean/max aggregation and by GrAx3.
        """
        n = self.num_nodes
        cap = pad_to if pad_to is not None else n
        idx = self.sampled_neighbors(max_neighbors, seed)
        mask = np.zeros((cap, cap + 1), dtype=np.float32)
        rows = np.repeat(np.arange(n), idx.shape[1])
        cols = idx.reshape(-1)
        # route sentinel entries (== n) into the scratch column cap, then drop
        cols = np.where(cols == n, cap, cols)
        mask[rows, cols] = 1.0
        return mask[:, :cap]


def _planted_partition_edges(n: int, m: int, classes: int, labels: np.ndarray,
                             rng: np.random.Generator) -> np.ndarray:
    """Draw exactly ``m`` distinct undirected edges with planted homophily."""
    by_class = [np.flatnonzero(labels == c) for c in range(classes)]
    seen: set[tuple[int, int]] = set()
    edges = np.empty((m, 2), dtype=np.int32)
    count = 0
    # Rejection-sample; expected acceptance is high because the graph is
    # extremely sparse (5429 edges over ~3.7M candidate pairs).
    while count < m:
        if rng.random() < HOMOPHILY:
            c = int(rng.integers(classes))
            members = by_class[c]
            if len(members) < 2:
                continue
            u, v = rng.choice(members, size=2, replace=False)
        else:
            u, v = rng.integers(n, size=2)
        u, v = int(u), int(v)
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key in seen:
            continue
        seen.add(key)
        edges[count] = key
        count += 1
    return edges


def _class_features(n: int, f: int, classes: int, labels: np.ndarray,
                    rng: np.random.Generator) -> np.ndarray:
    """Sparse bag-of-words features with per-class signature words."""
    sig_words = max(4, int(f * SIGNATURE_WORDS_FRAC))
    # Disjoint signature vocabularies per class, carved from the front.
    signatures = [
        np.arange(c * sig_words, (c + 1) * sig_words) % f
        for c in range(classes)
    ]
    base_p = FEATURE_DENSITY
    feats = np.zeros((n, f), dtype=np.float32)
    for i in range(n):
        c = int(labels[i])
        # Keep overall density ≈ base_p: boost signature words, damp the rest.
        p = np.full(f, base_p * 0.55)
        p[signatures[c]] = min(0.9, base_p * SIGNATURE_BOOST)
        feats[i] = (rng.random(f) < p).astype(np.float32)
    # Row-normalize like PyG's NormalizeFeatures transform.
    row_sum = feats.sum(axis=1, keepdims=True)
    feats = np.where(row_sum > 0, feats / np.maximum(row_sum, 1e-12), 0.0)
    return feats.astype(np.float32)


def _planetoid_splits(n: int, classes: int, labels: np.ndarray, train: int,
                      val: int, test: int, rng: np.random.Generator):
    """Planetoid-style split: balanced train nodes, then val/test blocks."""
    train_mask = np.zeros(n, dtype=bool)
    per_class = train // classes
    for c in range(classes):
        members = np.flatnonzero(labels == c)
        pick = rng.choice(members, size=min(per_class, len(members)),
                          replace=False)
        train_mask[pick] = True
    remaining = np.flatnonzero(~train_mask)
    remaining = rng.permutation(remaining)
    val_mask = np.zeros(n, dtype=bool)
    test_mask = np.zeros(n, dtype=bool)
    val_mask[remaining[:val]] = True
    test_mask[remaining[val:val + test]] = True
    return train_mask, val_mask, test_mask


def make_twin(spec: dict) -> GraphDataset:
    """Build a deterministic synthetic twin from a published-stats spec."""
    rng = np.random.default_rng(spec["seed"])
    n, m, classes = spec["n"], spec["m"], spec["classes"]
    # Slightly unbalanced class sizes, like real citation data.
    raw = rng.dirichlet(np.full(classes, 8.0))
    sizes = np.maximum((raw * n).astype(int), 2)
    while sizes.sum() != n:  # fix rounding drift
        sizes[int(rng.integers(classes))] += 1 if sizes.sum() < n else -1
    labels = np.repeat(np.arange(classes, dtype=np.int32), sizes)
    labels = rng.permutation(labels)
    edges = _planted_partition_edges(n, m, classes, labels, rng)
    feats = _class_features(n, spec["features"], classes, labels, rng)
    tr, va, te = _planetoid_splits(n, classes, labels, spec["train"],
                                   spec["val"], spec["test"], rng)
    return GraphDataset(spec["name"], edges, feats, labels, tr, va, te)


def cora_twin() -> GraphDataset:
    return make_twin(CORA_SPEC)


def citeseer_twin() -> GraphDataset:
    return make_twin(CITESEER_SPEC)


def load(name: str) -> GraphDataset:
    if name == "cora":
        return cora_twin()
    if name == "citeseer":
        return citeseer_twin()
    raise KeyError(f"unknown dataset {name!r}")
