//! Zero-steady-state-allocation proof for the planned engine.
//!
//! This test binary installs the counting global allocator and holds a
//! SINGLE test function, so no unrelated concurrent test can pollute the
//! counter. The claim under test: after warmup (arena slabs allocated,
//! INT8 weight caches populated, scratch capacity grown),
//! `PlanInstance::run` performs **zero** heap allocations — including
//! with **disabled telemetry** in the loop: a disabled recorder's
//! `now_us`/`record`/`sampled` calls and a `None` plan profiler must add
//! no clock reads that allocate, no locks, and no heap traffic, which is
//! the overhead contract `[telemetry] enabled = false` advertises. The
//! disabled monitor pulse (`[monitor]` absent) rides the same contract:
//! the shard loop's `touch()` heartbeat and `pressure_boost()` read are
//! counted here too and must be branch-only.

use std::collections::BTreeMap;
use std::sync::Arc;

use grannite::engine::{PlanInstance, WorkerPool};
use grannite::ops::build::{self, GnnDims, QuantScales};
use grannite::ops::exec::Bindings;
use grannite::ops::plan::ExecPlan;
use grannite::storage::{spill_path, FeatureSource, PagedFeatures, PagedStore};
use grannite::tensor::{Mat, Tensor};
use grannite::util::alloc::{allocation_count, CountingAlloc};
use grannite::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn bindings_for(d: GnnDims, quant: bool, seed: u64) -> Bindings {
    let mut rng = Rng::new(seed);
    let mut rand = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.8 - 0.4) as f32)
    };
    let mut b: Bindings = BTreeMap::new();
    b.insert("norm".into(), Tensor::from_mat(&rand(d.n, d.n)));
    b.insert("x".into(), Tensor::from_mat(&rand(d.n, d.f)));
    b.insert("b1".into(), Tensor::from_mat(&rand(1, d.hidden)));
    b.insert("b2".into(), Tensor::from_mat(&rand(1, d.classes)));
    if quant {
        let mut qrng = Rng::new(seed ^ 9);
        let mut ints = |r: usize, c: usize| {
            Mat::from_fn(r, c, |_, _| (qrng.usize(255) as i32 - 127) as f32)
        };
        b.insert("w1q".into(), Tensor::from_mat(&ints(d.f, d.hidden)));
        b.insert("w2q".into(), Tensor::from_mat(&ints(d.hidden, d.classes)));
    } else {
        b.insert("w1".into(), Tensor::from_mat(&rand(d.f, d.hidden)));
        b.insert("w2".into(), Tensor::from_mat(&rand(d.hidden, d.classes)));
    }
    b
}

#[test]
fn steady_state_run_allocates_nothing() {
    let d = GnnDims::model(64, 200, 32, 5);
    // disabled-telemetry handles, created BEFORE counting starts: the
    // hub itself allocates (Arc), but every use below must not
    let telemetry = grannite::telemetry::Telemetry::disabled();
    let recorder = telemetry.recorder(0);
    assert!(!recorder.enabled());
    // a disabled monitor's pulse, like the disabled recorder: every
    // per-round call the shard loop makes through it must be inert
    let pulse = grannite::monitor::Pulse::disabled();
    assert!(!pulse.enabled());
    for (label, graph, quant) in [
        ("gcn_stagr", build::gcn_stagr(d, "stagr"), false),
        ("gcn_quant", build::gcn_quant(d, QuantScales::default()), true),
    ] {
        let bindings = bindings_for(d, quant, 11);
        let plan = Arc::new(ExecPlan::compile(&graph).unwrap());
        // serial pool: the parallel pool's dispatch is also alloc-free,
        // but worker threads would race the global counter
        let mut inst = PlanInstance::new(Arc::clone(&plan), Arc::new(WorkerPool::serial()));
        // a disabled hub hands out no profiler, so attaching is the
        // engine's no-telemetry configuration (profiler = None)
        let profiler = telemetry.plan_profiler(0, &plan);
        assert!(profiler.is_none(), "disabled hub must not profile");
        inst.attach_profiler(profiler);
        // warmup: arena already sized; INT8 conversion + scratch growth
        inst.run(&bindings).unwrap();
        inst.run(&bindings).unwrap();
        let reference = inst.output_mat(0).unwrap();
        // the slabs the steady state reuses are cache-line aligned — the
        // base-address guarantee the SIMD microkernels stream against
        assert!(
            inst.arena_aligned(grannite::util::aligned::SLAB_ALIGN),
            "{label}: arena slab misaligned"
        );

        let before = allocation_count();
        for i in 0..10u64 {
            // the disabled-recorder calls the shard hot loop makes per
            // round, inside the counted region: all branch-only no-ops
            let t = recorder.now_us();
            let _ = recorder.sampled(i);
            pulse.touch();
            assert_eq!(pulse.pressure_boost(), 0);
            recorder.record(
                i,
                grannite::telemetry::SpanKind::EngineRound,
                "round",
                t,
                0.0,
                0,
            );
            inst.run(&bindings).unwrap();
        }
        let allocs = allocation_count() - before;
        assert_eq!(
            allocs, 0,
            "{label}: {allocs} allocations across 10 steady-state runs \
             (disabled telemetry must add none)"
        );
        assert_eq!(inst.output_mat(0).unwrap(), reference, "{label} drifted");
    }

    // --- fully-warm page cache: a zero-mutation round's layer-0 gather
    // through the paged feature source is allocation-free too (cold
    // misses are exempt — they fill the page slab). NON-prefetching
    // source on purpose: the prefetch worker thread would race the
    // global counter, and a warm cache hands it nothing anyway.
    let feats = Mat::from_fn(64, 32, |i, j| (i * 31 + j) as f32 * 0.01);
    let mut store =
        PagedStore::create_from_mat(&spill_path("plan-alloc"), &feats, 64).unwrap();
    store.set_delete_on_drop(true);
    let mut src = PagedFeatures::new(Arc::new(store), 8, 64);
    let ring: Vec<usize> = (0..64).collect();
    let mut out = vec![0.0f32; 64 * 32];
    src.stage(&ring);
    src.gather(&ring, &mut out).unwrap(); // cold round: every page faults
    let want = out.clone();
    let _ = src.take_stats();

    let before = allocation_count();
    for _ in 0..10 {
        src.stage(&ring);
        src.gather(&ring, &mut out).unwrap();
    }
    let allocs = allocation_count() - before;
    assert_eq!(
        allocs, 0,
        "warm paged gather: {allocs} allocations across 10 zero-mutation rounds"
    );
    let stats = src.take_stats();
    assert!(
        stats.hits > 0 && stats.faults == 0,
        "rounds were not warm: {stats:?}"
    );
    assert_eq!(out, want, "warm paged gather drifted");
}
