//! Artifact-free integration tests: the rust op-graph executor is a third
//! implementation of every model (next to the jnp oracle and the Pallas
//! kernels), and the GraNNite variants must agree with each other on it —
//! exactly the equivalences the paper's techniques claim.

use std::collections::BTreeMap;

use grannite::graph::datasets::synthesize;
use grannite::graph::Graph;
use grannite::ops::build::{self, GatVariant, GnnDims, QuantScales};
use grannite::ops::exec::{execute_mat, Bindings};
use grannite::ops::rewrite;
use grannite::tensor::{Mat, Tensor};
use grannite::util::propcheck::forall;
use grannite::util::Rng;

const N: usize = 28;
const F: usize = 18;
const H: usize = 10;
const C: usize = 4;

struct Fixture {
    graph: Graph,
    dims: GnnDims,
    bindings: Bindings,
}

fn fixture(seed: u64) -> Fixture {
    let ds = synthesize("eq", N, 3 * N, C, F, seed);
    let graph = ds.graph.clone();
    let dims = GnnDims {
        n: N,
        m: graph.num_edges(),
        f: F,
        hidden: H,
        classes: C,
        k: 5,
        layers: 2,
    };
    let mut rng = Rng::new(seed ^ 0xAB);
    let mut rand = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.8 - 0.4) as f32)
    };
    let mut b: Bindings = BTreeMap::new();
    // graph-side inputs
    b.insert("x".into(), Tensor::from_mat(&ds.features));
    b.insert("norm".into(), Tensor::from_mat(&graph.norm_adjacency(N)));
    b.insert("adj".into(), Tensor::from_mat(&graph.adjacency(N)));
    b.insert("neg_bias".into(), Tensor::from_mat(&graph.neg_bias(N)));
    b.insert(
        "mask".into(),
        Tensor::from_mat(&graph.sampled_adjacency(4, 7, N)),
    );
    let idx = graph.sampled_neighbors(4, 7);
    let mut idx_data = Vec::new();
    for row in &idx {
        for &j in row {
            idx_data.push(j as i32);
        }
    }
    b.insert(
        "nbr_idx".into(),
        Tensor::I32 { shape: vec![N, 5], data: idx_data },
    );
    let mut edges = Vec::new();
    for &(s, d) in graph.edges() {
        edges.push(s as i32);
        edges.push(d as i32);
    }
    b.insert(
        "edges".into(),
        Tensor::I32 { shape: vec![graph.num_edges(), 2], data: edges },
    );
    // weights (shared across all variants of a family)
    for (name, r, c) in [
        ("w1", F, H),
        ("w2", H, C),
        ("w1_self", F, H),
        ("w1_neigh", F, H),
        ("w2_self", H, C),
        ("w2_neigh", H, C),
    ] {
        b.insert(name.into(), Tensor::from_mat(&rand(r, c)));
    }
    for (name, c) in [("b1", H), ("b2", C)] {
        b.insert(name.into(), Tensor::from_mat(&rand(1, c)));
    }
    for (name, r) in [("a1_src", H), ("a1_dst", H), ("a2_src", C), ("a2_dst", C)] {
        b.insert(name.into(), Tensor::from_mat(&rand(r, 1)));
    }
    Fixture { graph, dims, bindings: b }
}

#[test]
fn gcn_baseline_equals_stagr_on_executor() {
    // PreG/StaGr is numerically exact: on-device norm construction and
    // the precomputed-mask MatMul compute the same function.
    forall("gcn baseline == stagr", 8, |g| {
        let fx = fixture(g.usize(0, 1 << 30) as u64);
        let base = execute_mat(&build::gcn_baseline(fx.dims), &fx.bindings).unwrap();
        let stagr = execute_mat(&build::gcn_stagr(fx.dims, "stagr"), &fx.bindings).unwrap();
        assert!(
            base.max_abs_diff(&stagr) < 1e-4,
            "diff {}",
            base.max_abs_diff(&stagr)
        );
    });
}

#[test]
fn gat_effop_equals_baseline_on_executor() {
    forall("gat effop == baseline", 6, |g| {
        let fx = fixture(g.usize(0, 1 << 30) as u64);
        let base = execute_mat(&build::gat(fx.dims, GatVariant::BaselineMasked), &fx.bindings).unwrap();
        let eff = execute_mat(&build::gat(fx.dims, GatVariant::EffOp), &fx.bindings).unwrap();
        assert!(base.max_abs_diff(&eff) < 1e-3, "diff {}", base.max_abs_diff(&eff));
    });
}

#[test]
fn gat_grax_predictions_match_baseline() {
    forall("gat grax ≈ baseline predictions", 6, |g| {
        let fx = fixture(g.usize(0, 1 << 30) as u64);
        let base = execute_mat(&build::gat(fx.dims, GatVariant::BaselineMasked), &fx.bindings).unwrap();
        let grax = execute_mat(&build::gat(fx.dims, GatVariant::Grax), &fx.bindings).unwrap();
        let agree = base
            .argmax_rows()
            .iter()
            .zip(grax.argmax_rows())
            .filter(|(a, b)| **a == *b)
            .count();
        assert!(agree as f64 >= 0.95 * N as f64, "agreement {agree}/{N}");
    });
}

#[test]
fn gat_buildadj_variant_equals_masked_variant() {
    // the on-device preprocessing (Fig. 4 baseline) computes the same
    // adjacency the CPU-prepared mask provides
    let fx = fixture(11);
    let on_device = execute_mat(&build::gat(fx.dims, GatVariant::Baseline), &fx.bindings).unwrap();
    let masked = execute_mat(&build::gat(fx.dims, GatVariant::BaselineMasked), &fx.bindings).unwrap();
    assert!(on_device.max_abs_diff(&masked) < 1e-5);
}

#[test]
fn sage_grax3_equals_baseline_on_nonneg_inputs() {
    // features from `synthesize` are non-negative bag-of-words rows: the
    // layer-1 GrAx3 precondition holds; layer-2 may clip negatives, so
    // compare predictions (what accuracy measures)
    forall("sage grax3 ≈ gather baseline", 6, |g| {
        let fx = fixture(g.usize(0, 1 << 30) as u64);
        let base = execute_mat(&build::sage_max_baseline(fx.dims), &fx.bindings).unwrap();
        let grax = execute_mat(&build::sage_max_grax3(fx.dims), &fx.bindings).unwrap();
        let agree = base
            .argmax_rows()
            .iter()
            .zip(grax.argmax_rows())
            .filter(|(a, b)| **a == *b)
            .count();
        assert!(agree as f64 >= 0.85 * N as f64, "agreement {agree}/{N}");
    });
}

#[test]
fn quant_gcn_close_to_fp32() {
    let fx = fixture(3);
    let fp = execute_mat(&build::gcn_stagr(fx.dims, "stagr"), &fx.bindings).unwrap();
    // calibrate scales from the actual tensors like quantize.py does
    let x = fx.bindings["x"].to_mat().unwrap();
    let w1 = fx.bindings["w1"].to_mat().unwrap();
    let w2 = fx.bindings["w2"].to_mat().unwrap();
    let s = QuantScales {
        act1: grannite::quant::calibrate(&x, 100.0),
        w1: grannite::quant::calibrate(&w1, 100.0),
        act2: 0.05,
        w2: grannite::quant::calibrate(&w2, 100.0),
    };
    let mut b = fx.bindings.clone();
    b.insert(
        "w1q".into(),
        Tensor::from_mat(&Mat::from_vec(
            F,
            H,
            grannite::quant::quantize(&w1, s.w1)
                .into_iter()
                .map(|v| v as f32)
                .collect(),
        )),
    );
    b.insert(
        "w2q".into(),
        Tensor::from_mat(&Mat::from_vec(
            H,
            C,
            grannite::quant::quantize(&w2, s.w2)
                .into_iter()
                .map(|v| v as f32)
                .collect(),
        )),
    );
    let q = execute_mat(&build::gcn_quant(fx.dims, s), &b).unwrap();
    let err = grannite::quant::quant_error(&fp, &q);
    assert!(err.argmax_agreement > 0.85, "agreement {}", err.argmax_agreement);
}

#[test]
fn rewrite_pipeline_baseline_to_grax_matches_built_grax() {
    // the pass pipeline (effop → grax1 → grax2) applied to the deployed
    // baseline graph must behave like the directly-built grax graph
    let fx = fixture(21);
    let base = build::gat(fx.dims, GatVariant::BaselineMasked);
    let stepped = rewrite::grax2(&rewrite::grax1(&rewrite::effop(&base).unwrap()).unwrap()).unwrap();
    stepped.validate().unwrap();
    let built = build::gat(fx.dims, GatVariant::Grax);
    let a = execute_mat(&stepped, &fx.bindings).unwrap();
    let b = execute_mat(&built, &fx.bindings).unwrap();
    assert!(a.max_abs_diff(&b) < 1e-3, "pipeline vs builder diff {}", a.max_abs_diff(&b));
}

#[test]
fn grad_mask_update_equals_fresh_graph_inference() {
    // GrAd invariant: inference after incremental updates == inference on
    // a freshly-built graph with the same edges
    let fx = fixture(31);
    let mut dg = grannite::graph::DynamicGraph::new(&fx.graph, N).unwrap();
    // materialize the dense mask first so the updates below exercise the
    // in-place incremental maintenance, not a lazy rebuild
    let _ = dg.norm();
    dg.add_edge(0, N - 1).unwrap();
    dg.remove_edge(
        fx.graph.edges()[0].0 as usize,
        fx.graph.edges()[0].1 as usize,
    )
    .unwrap();
    let mut b1 = fx.bindings.clone();
    b1.insert("norm".into(), Tensor::from_mat(dg.norm()));
    let incremental = execute_mat(&build::gcn_stagr(fx.dims, "stagr"), &b1).unwrap();

    let fresh = dg.snapshot().norm_adjacency(N);
    let mut b2 = fx.bindings.clone();
    b2.insert("norm".into(), Tensor::from_mat(&fresh));
    let rebuilt = execute_mat(&build::gcn_stagr(fx.dims, "stagr"), &b2).unwrap();
    assert!(incremental.max_abs_diff(&rebuilt) < 1e-5);
}

#[test]
fn symg_matmul_usable_in_aggregation() {
    // SymG packed storage must drive the same aggregation result
    let fx = fixture(41);
    let norm = fx.graph.norm_adjacency(N);
    let sym = grannite::graph::SymG::pack(&norm, 0.0);
    let h = Mat::from_fn(N, H, |i, j| ((i * H + j) % 7) as f32 * 0.1);
    let dense = norm.matmul(&h);
    let packed = sym.matmul(&h);
    assert!(dense.max_abs_diff(&packed) < 1e-5);
    assert!(sym.bytes() < norm.bytes() * 51 / 100 + 64);
}
