//! Sparse-aggregation equivalence suite: the CSR `SpMM` path must match
//! the dense oracle (≤ 1e-4, and bitwise in practice — both kernels
//! accumulate in the same k-order) through every execution layer:
//!
//! 1. the reference executor (`ops::exec`, which densifies CSR),
//! 2. the planned engine (`engine` running a compiled SpMM plan),
//! 3. the incremental engine's CSR tile gathers,
//! 4. a 3-shard plan-backed fleet,
//! 5. the INT8 SpMM kernel vs the QMatMul oracle.

use std::sync::Arc;

use grannite::engine::{kernels, run_graph_mat, WorkerPool};
use grannite::fleet::engine::synthesize_weights;
use grannite::graph::{datasets::synthesize, pad_features, Graph};
use grannite::incremental::{IncrementalConfig, IncrementalEngine};
use grannite::ops::build::{self, Aggregation, GnnDims};
use grannite::ops::exec::{self, Bindings};
use grannite::serve::{
    DataSource, Deployment, DeploymentSpec, EngineSpec, Serving, Topology,
};
use grannite::server::{InferenceEngine, Update};
use grannite::tensor::{CsrMat, Mat, Tensor};
use grannite::util::propcheck::forall;

fn serial() -> Arc<WorkerPool> {
    Arc::new(WorkerPool::serial())
}

/// Random-graph GCN across densities: the sparse graph + CSR binding must
/// match the dense graph + dense binding through both the reference
/// executor and the planned engine, and the dense-binding fallback on the
/// sparse plan must agree bitwise.
#[test]
fn prop_spmm_matches_dense_oracle_through_exec_and_plan() {
    forall("spmm == dense oracle (exec + plan)", 30, |g| {
        let n = g.dim(40).max(2);
        // sweep density: from near-empty to ~60% of all possible edges
        let max_edges = n * (n - 1) / 2;
        let m = g.usize(0, max_edges.max(1));
        let edges: Vec<(u32, u32)> = (0..m)
            .map(|_| (g.rng().usize(n) as u32, g.rng().usize(n) as u32))
            .collect();
        let graph = Graph::new(n, &edges);
        let f = g.dim(10);
        let hidden = g.dim(8);
        let classes = g.usize(2, 6);
        let d = GnnDims {
            n,
            m: graph.num_edges().max(1),
            f,
            hidden,
            classes,
            k: 5,
            layers: 2,
        };

        let norm = graph.norm_adjacency(n);
        let csr = graph.norm_csr(n);
        assert_eq!(csr.to_dense(), norm, "CSR build != dense norm");

        let mut dense_b: Bindings = Bindings::new();
        dense_b.insert("norm".into(), Tensor::from_mat(&norm));
        dense_b.insert(
            "x".into(),
            Tensor::from_mat(&Mat::from_vec(n, f, g.vec_f32(n * f))),
        );
        dense_b.insert(
            "w1".into(),
            Tensor::from_mat(&Mat::from_vec(f, hidden, g.vec_f32(f * hidden))),
        );
        dense_b.insert(
            "b1".into(),
            Tensor::from_mat(&Mat::from_vec(1, hidden, g.vec_f32(hidden))),
        );
        dense_b.insert(
            "w2".into(),
            Tensor::from_mat(&Mat::from_vec(
                hidden,
                classes,
                g.vec_f32(hidden * classes),
            )),
        );
        dense_b.insert(
            "b2".into(),
            Tensor::from_mat(&Mat::from_vec(1, classes, g.vec_f32(classes))),
        );
        let mut csr_b = dense_b.clone();
        csr_b.insert("norm".into(), Tensor::from_csr(csr));

        let dense_g = build::gcn_stagr(d, "stagr");
        let sparse_g = build::gcn_stagr_with(d, "stagr", Aggregation::Sparse);

        let want = exec::execute_mat(&dense_g, &dense_b).unwrap();
        // 1. reference executor on the sparse graph (densifying oracle)
        let via_exec = exec::execute_mat(&sparse_g, &csr_b).unwrap();
        assert!(
            want.max_abs_diff(&via_exec) < 1e-4,
            "exec drift {}",
            want.max_abs_diff(&via_exec)
        );
        // 2. planned engine running the real SpMM kernel
        let via_plan = run_graph_mat(&sparse_g, &csr_b).unwrap();
        assert!(
            want.max_abs_diff(&via_plan) < 1e-4,
            "plan drift {}",
            want.max_abs_diff(&via_plan)
        );
        // dense binding on the sparse plan (above-threshold fallback)
        let via_fallback = run_graph_mat(&sparse_g, &dense_b).unwrap();
        assert_eq!(via_fallback, via_plan, "fallback must agree bitwise");
    });
}

/// The SAGE-mean sampled mask through SpMM matches its dense twin.
#[test]
fn sage_mean_spmm_matches_dense() {
    let ds = synthesize("spmm-sage", 30, 80, 4, 12, 5);
    let n = 30;
    let mask = ds
        .graph
        .sampled_adjacency(grannite::SAGE_MAX_NEIGHBORS, 7, n);
    // row-normalize like the artifact pipeline's norm_mask
    let mut norm_mask = mask.clone();
    for i in 0..n {
        let s: f32 = norm_mask.row(i).iter().sum();
        if s > 0.0 {
            for v in norm_mask.row_mut(i) {
                *v /= s;
            }
        }
    }
    let d = GnnDims::model(n, ds.graph.num_edges(), ds.num_features(), 4);
    let dense_g = build::sage_mean(d);
    let sparse_g = build::sage_mean_with(d, Aggregation::Sparse);
    let mut b: Bindings = Bindings::new();
    b.insert("norm_mask".into(), Tensor::from_mat(&norm_mask));
    b.insert("x".into(), Tensor::from_mat(&ds.features));
    let mut rng = grannite::util::Rng::new(11);
    let mut rand = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.6 - 0.3) as f32)
    };
    for l in 1..=2 {
        let (in_w, out_w) = if l == 1 {
            (ds.num_features(), grannite::HIDDEN)
        } else {
            (grannite::HIDDEN, 4)
        };
        b.insert(format!("w{l}_self"), Tensor::from_mat(&rand(in_w, out_w)));
        b.insert(format!("w{l}_neigh"), Tensor::from_mat(&rand(in_w, out_w)));
        b.insert(format!("b{l}"), Tensor::from_mat(&rand(1, out_w)));
    }
    let want = exec::execute_mat(&dense_g, &b).unwrap();
    let mut sb = b.clone();
    sb.insert(
        "norm_mask".into(),
        Tensor::from_csr(CsrMat::from_dense(&norm_mask)),
    );
    let got = run_graph_mat(&sparse_g, &sb).unwrap();
    assert!(want.max_abs_diff(&got) < 1e-4, "{}", want.max_abs_diff(&got));
}

/// Incremental engine: CSR tile gathers == dense tile gathers == the
/// full-graph oracle, across random churn interleavings.
#[test]
fn incremental_csr_tiles_match_oracle_under_churn() {
    let n0 = 50;
    let cap = 56;
    let classes = 4;
    let ds = synthesize("spmm-inc", n0, 80, classes, 10, 13);
    let cfg = |agg| IncrementalConfig {
        cost_margin: f64::INFINITY, // force the frontier path
        tile_min: 8,
        aggregation: agg,
    };
    let mut sparse =
        IncrementalEngine::full(&ds, cap, serial(), cfg(Aggregation::Sparse)).unwrap();
    let mut dense =
        IncrementalEngine::full(&ds, cap, serial(), cfg(Aggregation::Dense)).unwrap();

    // mirror the live edge set so the oracle sees the same graph
    let mut edges: std::collections::BTreeSet<(u32, u32)> =
        ds.graph.edges().iter().copied().collect();
    let mut nodes = n0;
    let mut rng = grannite::util::Rng::new(99);
    let mut apply_all = |u: &Update,
                         sparse: &mut IncrementalEngine,
                         dense: &mut IncrementalEngine,
                         edges: &mut std::collections::BTreeSet<(u32, u32)>,
                         nodes: &mut usize| {
        sparse.apply(u).unwrap();
        dense.apply(u).unwrap();
        match *u {
            Update::AddEdge(a, b) => {
                edges.insert((a.min(b) as u32, a.max(b) as u32));
            }
            Update::RemoveEdge(a, b) => {
                edges.remove(&(a.min(b) as u32, a.max(b) as u32));
            }
            Update::AddNode => *nodes += 1,
        }
    };

    for round in 0..6 {
        // a burst of churn, then a compared inference
        for _ in 0..3 {
            let a = rng.usize(nodes);
            let b = (a + 1 + rng.usize(nodes - 2)) % nodes;
            let (a, b) = (a.min(b), a.max(b));
            let u = if rng.chance(0.3) && edges.contains(&(a as u32, b as u32)) {
                Update::RemoveEdge(a, b)
            } else {
                Update::AddEdge(a, b)
            };
            apply_all(&u, &mut sparse, &mut dense, &mut edges, &mut nodes);
        }
        if round == 2 && nodes < cap {
            apply_all(&Update::AddNode, &mut sparse, &mut dense, &mut edges, &mut nodes);
        }
        let a = sparse.infer().unwrap();
        let b = dense.infer().unwrap();
        assert_eq!(a, b, "round {round}: sparse vs dense tile gathers diverged");

        // full-graph oracle at the mirrored structure
        let edge_list: Vec<(u32, u32)> = edges.iter().copied().collect();
        let graph = Graph::new(nodes, &edge_list);
        let dims = GnnDims::model(cap, graph.num_edges().max(1), 10, classes);
        let og = build::gcn_stagr(dims, "grad");
        let mut ob = synthesize_weights(10, classes, cap);
        ob.insert("norm".into(), Tensor::from_mat(&graph.norm_adjacency(cap)));
        ob.insert("x".into(), Tensor::from_mat(&pad_features(&ds.features, cap)));
        let want_full = exec::execute_mat(&og, &ob).unwrap();
        for i in 0..nodes {
            for j in 0..classes {
                let diff = (want_full[(i, j)] - a[(i, j)]).abs();
                assert!(diff < 1e-4, "round {round} node {i} class {j}: drift {diff}");
            }
        }
    }
}

/// 3-shard sparse fleet == 1-shard dense fleet == oracle predictions.
#[test]
fn sparse_fleet_matches_dense_fleet_and_oracle() {
    let ds = synthesize("spmm-fleet", 48, 110, 4, 12, 21);
    let cap = 54;
    let churn = [
        Update::AddEdge(0, 31),
        Update::AddEdge(7, 40),
        Update::AddNode,
        Update::AddEdge(48, 3),
        Update::RemoveEdge(0, 31),
    ];
    let run = |shards: usize, agg: Aggregation| -> Vec<i32> {
        let spec = DeploymentSpec {
            engine: EngineSpec::named("plan"),
            topology: Topology::homogeneous(shards),
            capacity: cap,
            aggregation: agg,
            ..DeploymentSpec::default()
        };
        let fleet =
            Deployment::launch(&spec, &DataSource::Dataset(ds.clone())).unwrap();
        for u in &churn {
            fleet.update(u.clone()).unwrap();
        }
        let preds: Vec<i32> = (0..49)
            .map(|node| fleet.query_wait(Some(node)).unwrap().prediction)
            .collect();
        // sparse shards report real dma savings through the merged gauges
        let snap = fleet.metrics();
        if agg == Aggregation::Sparse {
            assert!(snap.dma_bytes_dense > 0, "no mask traffic recorded");
            assert!(snap.dma_bytes_saved() > 0, "no savings credited");
        }
        fleet.shutdown().unwrap();
        preds
    };
    let sparse3 = run(3, Aggregation::Sparse);
    let dense1 = run(1, Aggregation::Dense);
    assert_eq!(sparse3, dense1, "3-shard sparse != 1-shard dense");

    // oracle predictions at the churned structure
    let mut edges: Vec<(u32, u32)> = ds.graph.edges().to_vec();
    edges.push((0, 31));
    edges.push((7, 40));
    edges.push((3, 48));
    edges.retain(|&e| e != (0, 31));
    let graph = Graph::new(49, &edges);
    let dims = GnnDims::model(cap, graph.num_edges(), 12, 4);
    let og = build::gcn_stagr(dims, "grad");
    let mut ob = synthesize_weights(12, 4, cap);
    ob.insert("norm".into(), Tensor::from_mat(&graph.norm_adjacency(cap)));
    ob.insert("x".into(), Tensor::from_mat(&pad_features(&ds.features, cap)));
    let logits = exec::execute_mat(&og, &ob).unwrap();
    let want: Vec<i32> = (0..49)
        .map(|i| {
            let row = Mat::from_vec(1, 4, logits.row(i).to_vec());
            row.argmax_rows()[0] as i32
        })
        .collect();
    assert_eq!(sparse3, want, "fleet diverged from the exec oracle");
}

/// INT8 SpMM vs the QMatMul oracle across densities: quantized CSR
/// values × i8 activations with i32 accumulation must equal the f64
/// oracle on the densified operand, exactly.
#[test]
fn prop_int8_spmm_matches_qmatmul_oracle() {
    forall("int8 spmm == qmatmul oracle", 40, |g| {
        let m = g.dim(24).max(1);
        let k = g.dim(24).max(1);
        let n = g.dim(8).max(1);
        let keep = [0.02, 0.1, 0.5, 1.0][g.usize(0, 4)];
        let dense = Mat::from_fn(m, k, |_, _| {
            if g.rng().chance(keep) {
                (g.rng().usize(255) as i32 - 127) as f32
            } else {
                0.0
            }
        });
        let csr = CsrMat::from_dense(&dense);
        let v8: Vec<i8> = csr.values.iter().map(|&v| v as i8).collect();
        let rhs8: Vec<i8> =
            (0..k * n).map(|_| (g.rng().usize(255) as i32 - 127) as i8).collect();
        let rhs_f: Vec<f32> = rhs8.iter().map(|&v| v as f32).collect();
        let scale = 0.03125f32;
        let pool = WorkerPool::serial();
        let mut got = vec![0.0f32; m * n];
        kernels::spmm_i8(
            &pool, &csr.indptr, &csr.indices, &v8, m, &rhs8, n, scale, &mut got,
        );
        let mut want = vec![0.0f32; m * n];
        kernels::qmatmul_acc64(
            &pool,
            &kernels::QOperand::F32(&dense.data),
            &kernels::QOperand::F32(&rhs_f),
            m,
            k,
            n,
            scale,
            &mut want,
        );
        assert_eq!(got, want, "INT8 SpMM drifted from the QMatMul oracle");
    });
}
