//! Kernel-equivalence property suite: every dispatch configuration of
//! the engine's microkernels — SIMD on/off, any degree-bin count, serial
//! or parallel pool, any density hint — must agree **bitwise** with the
//! scalar fallback, and the whole family must agree with the `ops::exec`
//! interpreter oracle to ≤ 1e-4. The CacheG reordering pass is pure
//! relabeling: a permuted run restored through the inverse permutation
//! must match the unordered oracle too.

use std::collections::BTreeMap;
use std::sync::Arc;

use grannite::engine::{kernels, PlanInstance, WorkerPool};
use grannite::ops::build::{self, Aggregation, GnnDims};
use grannite::ops::exec::{self, Bindings};
use grannite::ops::plan::{ExecPlan, KernelConfig, ReorderMode, Reordering, SimdMode};
use grannite::ops::{OpGraph, OpKind, Stage};
use grannite::tensor::{CsrMat, DensityHint, Mat, Tensor};
use grannite::util::propcheck::forall;
use grannite::util::Rng;

/// `ops::exec` result of one dense `(m,k) @ (k,n)` MatMul.
fn exec_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut g = OpGraph::new("oracle");
    let x = g.input("x", &[a.rows, a.cols], grannite::tensor::DType::F32, Stage::Compute);
    let w = g.input("w", &[b.rows, b.cols], grannite::tensor::DType::F32, Stage::Compute);
    let o = g.op(OpKind::MatMul, &[x, w], &[a.rows, b.cols], Stage::Compute);
    g.set_output(o);
    let mut bind: Bindings = BTreeMap::new();
    bind.insert("x".into(), Tensor::from_mat(a));
    bind.insert("w".into(), Tensor::from_mat(b));
    exec::execute_mat(&g, &bind).unwrap()
}

fn pools() -> [Arc<WorkerPool>; 2] {
    [Arc::new(WorkerPool::serial()), Arc::new(WorkerPool::new(4))]
}

#[test]
fn prop_matmul_paths_agree_with_exec_oracle() {
    let pools = pools();
    forall("matmul dispatch equivalence", 24, |g| {
        let m = g.dim(33);
        let k = g.dim(40);
        let n = g.dim(37);
        let density = [0.05, 0.3, 1.0][g.usize(0, 3)];
        let mut a = Mat::from_fn(m, k, |_, _| 0.0);
        for v in a.data.iter_mut() {
            if g.chance(density) {
                *v = g.small_f32();
            }
        }
        let b = Mat::from_fn(k, n, |i, j| ((i * 7 + j * 3) % 11) as f32 * 0.25 - 1.0);
        let want = exec_matmul(&a, &b);

        let mut reference: Option<Vec<f32>> = None;
        for pool in &pools {
            for simd in [false, true] {
                for hint in [DensityHint::Sample, DensityHint::Skip, DensityHint::NoSkip] {
                    let mut out = vec![0.0f32; m * n];
                    kernels::matmul_with(
                        pool, &a.data, m, k, &b.data, n, &mut out, hint, simd,
                    );
                    match &reference {
                        None => reference = Some(out.clone()),
                        Some(r) => assert_eq!(
                            r, &out,
                            "simd={simd} hint={hint:?} diverged bitwise"
                        ),
                    }
                    let got = Mat::from_vec(m, n, out);
                    let diff = want.max_abs_diff(&got);
                    assert!(diff < 1e-4, "oracle diff {diff} (simd={simd})");
                }
            }
        }
    });
}

/// A power-law CSR: early rows are hubs (degree up to the full column
/// count), the tail is sparse, and every 5th row is empty.
fn power_law_csr(g: &mut grannite::util::propcheck::Gen, rows: usize, cols: usize) -> CsrMat {
    let mut indptr = vec![0u32];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    for i in 0..rows {
        if i % 5 != 3 {
            // ~cols/(i+1) targets, deduped by stepping a stride
            let degree = (cols / (i + 1)).clamp(1, cols);
            let stride = (cols / degree).max(1);
            let offset = g.usize(0, stride);
            let mut c = offset;
            while c < cols && (indices.len() - *indptr.last().unwrap() as usize) < degree {
                indices.push(c as u32);
                values.push((g.rng().f64() * 2.0 - 1.0) as f32);
                c += stride;
            }
        }
        indptr.push(indices.len() as u32);
    }
    CsrMat { rows, cols, indptr, indices, values }
}

#[test]
fn prop_spmm_paths_agree_on_power_law_graphs() {
    let pools = pools();
    forall("spmm dispatch equivalence", 16, |g| {
        let rows = g.usize(40, 120);
        let cols = g.usize(20, 60);
        let n = g.dim(33);
        let csr = power_law_csr(g, rows, cols);
        let rhs = Mat::from_fn(cols, n, |i, j| ((i * 13 + j * 5) % 9) as f32 * 0.5 - 2.0);
        let want = exec_matmul(&csr.to_dense(), &rhs);

        let mut reference: Option<Vec<f32>> = None;
        for pool in &pools {
            for simd in [false, true] {
                for bins in [1usize, 4, 16] {
                    let mut out = vec![0.0f32; rows * n];
                    kernels::spmm_with(
                        pool,
                        &csr.indptr,
                        &csr.indices,
                        &csr.values,
                        rows,
                        &rhs.data,
                        n,
                        &mut out,
                        bins,
                        simd,
                    );
                    match &reference {
                        None => reference = Some(out.clone()),
                        Some(r) => assert_eq!(
                            r, &out,
                            "simd={simd} bins={bins} diverged bitwise"
                        ),
                    }
                    let got = Mat::from_vec(rows, n, out);
                    let diff = want.max_abs_diff(&got);
                    assert!(diff < 1e-4, "oracle diff {diff} (simd={simd} bins={bins})");
                }
            }
        }
    });
}

#[test]
fn prop_int8_paths_agree_bitwise() {
    let pools = pools();
    forall("int8 dispatch equivalence", 16, |g| {
        let m = g.dim(22);
        let k = g.dim(30);
        let n = g.dim(26);
        let x: Vec<i8> = (0..m * k).map(|_| (g.rng().usize(255) as i32 - 127) as i8).collect();
        let w: Vec<i8> = (0..k * n).map(|_| (g.rng().usize(255) as i32 - 127) as i8).collect();
        let scale = 0.25f32;
        let mut scalar = vec![0.0f32; m * n];
        kernels::qmatmul_i8_with(&pools[0], &x, &w, m, k, n, scale, &mut scalar, false);
        for pool in &pools {
            let mut simd = vec![0.0f32; m * n];
            kernels::qmatmul_i8_with(pool, &x, &w, m, k, n, scale, &mut simd, true);
            assert_eq!(scalar, simd, "qmatmul_i8 SIMD diverged");
        }

        // i8 SpMM from the same operand interpreted sparsely (i32
        // accumulation is associative, so every schedule is exact)
        let mut indptr = vec![0u32];
        let (mut indices, mut values) = (Vec::new(), Vec::new());
        for row in x.chunks(k) {
            for (c, &v) in row.iter().enumerate() {
                if v != 0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        let mut sp_scalar = vec![0.0f32; m * n];
        kernels::spmm_i8_with(
            &pools[0], &indptr, &indices, &values, m, &w, n, scale, &mut sp_scalar, 4, false,
        );
        assert_eq!(scalar, sp_scalar, "sparse i8 path diverged from dense");
        for pool in &pools {
            for bins in [1usize, 8] {
                let mut sp = vec![0.0f32; m * n];
                kernels::spmm_i8_with(
                    pool, &indptr, &indices, &values, m, &w, n, scale, &mut sp, bins, true,
                );
                assert_eq!(sp_scalar, sp, "spmm_i8 SIMD/bins={bins} diverged");
            }
        }
    });
}

#[test]
fn reordered_plan_matches_exec_oracle_and_roundtrips() {
    let d = GnnDims { n: 30, m: 55, f: 9, hidden: 7, classes: 4, k: 5, layers: 2 };
    let ds = grannite::graph::datasets::synthesize("reorder", d.n, d.m, d.classes, d.f, 41);
    let norm_dense = ds.graph.norm_adjacency(d.n);
    let norm = CsrMat::from_dense(&norm_dense);
    let mut rng = Rng::new(0xC0FFEE);
    let mut rand = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.8 - 0.4) as f32)
    };
    let (w1, b1) = (rand(d.f, d.hidden), rand(1, d.hidden));
    let (w2, b2) = (rand(d.hidden, d.classes), rand(1, d.classes));

    // unordered oracle: the interpreter over the dense graph
    let g_dense = build::gcn_stagr(d, "stagr");
    let mut b: Bindings = BTreeMap::new();
    b.insert("x".into(), Tensor::from_mat(&ds.features));
    b.insert("norm".into(), Tensor::from_mat(&norm_dense));
    b.insert("w1".into(), Tensor::from_mat(&w1));
    b.insert("b1".into(), Tensor::from_mat(&b1));
    b.insert("w2".into(), Tensor::from_mat(&w2));
    b.insert("b2".into(), Tensor::from_mat(&b2));
    let want = exec::execute_mat(&g_dense, &b).unwrap();

    let g_sparse = build::gcn_stagr_with(d, "stagr", Aggregation::Sparse);
    for mode in [ReorderMode::Degree, ReorderMode::Rcm] {
        let r = Reordering::compute(mode, &norm.indptr, &norm.indices).unwrap();
        // node-indexed bindings permuted; weights/biases are not
        // node-indexed and pass through untouched
        let mut bp = b.clone();
        bp.insert("x".into(), Tensor::from_mat(&r.permute_rows(&ds.features)));
        bp.insert("norm".into(), Tensor::from_csr(r.permute_csr(&norm)));
        let plan = Arc::new(
            ExecPlan::compile_with(
                &g_sparse,
                KernelConfig { reorder: mode, ..KernelConfig::default() },
            )
            .unwrap(),
        );
        let mut inst = PlanInstance::new(plan, Arc::new(WorkerPool::new(3)));
        inst.run(&bp).unwrap();
        let permuted_out = inst.output_mat(0).unwrap();
        let restored = r.restore_rows(&permuted_out);
        let diff = want.max_abs_diff(&restored);
        assert!(diff < 1e-4, "{mode:?}: reordered run drifted {diff}");
        // permutation ∘ inverse = identity on served outputs, bitwise
        assert_eq!(r.permute_rows(&restored), permuted_out, "{mode:?}");
        assert_eq!(
            r.restore_rows(&r.permute_rows(&want)),
            want,
            "{mode:?}: restore∘permute must be the identity"
        );
    }
}

#[test]
fn simd_modes_dispatch_identically_through_plans() {
    // compile the same graph at every SimdMode: Off is the oracle path,
    // Auto/On must reproduce it bitwise end to end
    let d = GnnDims { n: 21, m: 34, f: 8, hidden: 6, classes: 3, k: 4, layers: 2 };
    let ds = grannite::graph::datasets::synthesize("modes", d.n, d.m, d.classes, d.f, 13);
    let mut rng = Rng::new(99);
    let mut rand = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.8 - 0.4) as f32)
    };
    let mut b: Bindings = BTreeMap::new();
    b.insert("x".into(), Tensor::from_mat(&ds.features));
    b.insert("norm".into(), Tensor::from_mat(&ds.graph.norm_adjacency(d.n)));
    b.insert("w1".into(), Tensor::from_mat(&rand(d.f, d.hidden)));
    b.insert("b1".into(), Tensor::from_mat(&rand(1, d.hidden)));
    b.insert("w2".into(), Tensor::from_mat(&rand(d.hidden, d.classes)));
    b.insert("b2".into(), Tensor::from_mat(&rand(1, d.classes)));
    let g = build::gcn_stagr(d, "stagr");
    let outs: Vec<Mat> = [SimdMode::Off, SimdMode::Auto, SimdMode::On]
        .into_iter()
        .map(|simd| {
            let plan = Arc::new(
                ExecPlan::compile_with(&g, KernelConfig { simd, ..KernelConfig::default() })
                    .unwrap(),
            );
            let mut inst = PlanInstance::new(plan, Arc::new(WorkerPool::new(2)));
            inst.run(&b).unwrap();
            inst.output_mat(0).unwrap()
        })
        .collect();
    assert_eq!(outs[0], outs[1], "auto diverged from the scalar oracle");
    assert_eq!(outs[0], outs[2], "on diverged from the scalar oracle");
    let want = exec::execute_mat(&g, &b).unwrap();
    assert!(want.max_abs_diff(&outs[0]) < 1e-4);
}
