//! Out-of-core serving equivalence suite (ISSUE 10 acceptance):
//!
//! 1. **Property test** — over randomized interleavings of structure
//!    churn (`AddEdge`/`RemoveEdge`/`AddNode`), feature churn
//!    (`write_features`), and queries, the paged incremental engine
//!    matches the in-memory incremental engine *and* a full `ops::exec`
//!    recompute to ≤ 1e-4, across page/cache geometries that include
//!    capacities small enough to force mid-round eviction.
//! 2. **Stale-read check** — a fully-warm page cache must not serve a
//!    page its own `write_features` dirtied; the warm round's storage
//!    gauges must show genuine hits.
//! 3. **Deployment equivalence** — `[storage] backend = "paged"` through
//!    `Deployment::launch` answers identically to `backend = "memory"`
//!    at 1 and 3 shards, on planted-partition and power-law graphs.

use std::sync::Arc;

use anyhow::Result;

use grannite::coordinator::ModelState;
use grannite::engine::WorkerPool;
use grannite::fleet::synthesize_weights;
use grannite::graph::datasets::{
    synthesize, synthesize_power_law, synthesize_power_law_headless, Dataset,
};
use grannite::incremental::{IncrementalConfig, IncrementalEngine};
use grannite::ops::build::{self, GnnDims};
use grannite::ops::exec;
use grannite::serve::{
    DataSource, Deployment, DeploymentSpec, EngineSpec, Serving, Topology,
};
use grannite::server::{InferenceEngine, Update};
use grannite::storage::{spill_path, PagedFeatures, PagedStore};
use grannite::tensor::Mat;
use grannite::util::propcheck::forall;

fn serial() -> Arc<WorkerPool> {
    Arc::new(WorkerPool::serial())
}

fn apply_state(state: &mut ModelState, u: &Update) -> Result<()> {
    match u {
        Update::AddEdge(a, b) => {
            state.add_edge(*a, *b)?;
        }
        Update::RemoveEdge(a, b) => {
            state.remove_edge(*a, *b)?;
        }
        Update::AddNode => {
            state.add_node()?;
        }
    }
    Ok(())
}

/// Full-recompute oracle with feature-churn support. `ModelState`
/// caches the `x_pad` binding across structure changes, so a feature
/// write rebuilds the state from the mutated base dataset and replays
/// the structural history — the slow-but-obviously-correct path the
/// page cache's epoch invalidation must agree with.
struct Oracle {
    base: Dataset,
    applied: Vec<Update>,
    state: ModelState,
    weights: exec::Bindings,
    capacity: usize,
    classes: usize,
}

impl Oracle {
    fn new(ds: &Dataset, capacity: usize) -> Oracle {
        let capacity = capacity.max(ds.num_nodes());
        let classes = ds.num_classes().max(2);
        Oracle {
            base: ds.clone(),
            applied: Vec::new(),
            state: ModelState::from_dataset(ds.clone(), capacity).unwrap(),
            weights: synthesize_weights(ds.num_features(), classes, capacity),
            capacity,
            classes,
        }
    }

    fn apply(&mut self, u: &Update) -> Result<()> {
        self.applied.push(u.clone());
        apply_state(&mut self.state, u)
    }

    fn write_features(&mut self, node: usize, values: &[f32]) -> Result<()> {
        self.base.features.row_mut(node).copy_from_slice(values);
        self.state = ModelState::from_dataset(self.base.clone(), self.capacity)?;
        let applied = self.applied.clone();
        for u in &applied {
            apply_state(&mut self.state, u)?;
        }
        Ok(())
    }

    fn logits(&mut self) -> Mat {
        let ds = &self.state.dataset;
        let dims = GnnDims::model(
            self.capacity,
            ds.graph.num_edges(),
            ds.num_features(),
            self.classes,
        );
        let g = build::gcn_stagr(dims, "grad");
        let mut b = self.weights.clone();
        b.insert("norm".into(), self.state.binding("norm_pad", "gcn").unwrap());
        b.insert("x".into(), self.state.binding("x_pad", "gcn").unwrap());
        let full = exec::execute_mat(&g, &b).unwrap();
        let n = self.state.num_active_nodes();
        Mat::from_fn(n, full.cols, |i, j| full[(i, j)])
    }
}

/// Build a paged engine over a fresh temp store holding `ds.features`
/// zero-padded to `cap` rows.
fn paged_engine(
    ds: &Dataset,
    cap: usize,
    cfg: IncrementalConfig,
    page_rows: usize,
    cache_pages: usize,
) -> IncrementalEngine {
    let mut store =
        PagedStore::create_from_mat(&spill_path("stor-eq"), &ds.features, cap).unwrap();
    store.set_delete_on_drop(true);
    let features = Box::new(PagedFeatures::new(Arc::new(store), page_rows, cache_pages));
    IncrementalEngine::shard_with_source(ds, cap, 0..cap, serial(), cfg, features)
        .unwrap()
}

#[derive(Debug, Clone)]
enum Ev {
    Up(Update),
    Write(usize, Vec<f32>),
    Query,
}

#[test]
fn prop_paged_matches_memory_and_oracle() {
    forall("paged == memory == ops::exec", 10, |gen| {
        let n0 = gen.usize(8, 20);
        let m0 = gen.usize(n0 / 2, 2 * n0);
        let spare = gen.usize(1, 4);
        let cap = n0 + spare;
        let f = 6;
        let ds =
            synthesize("stor-eq", n0, m0, 4, f, 2000 + n0 as u64 * 13 + m0 as u64);

        // one event script, replayed against every cache geometry
        let mut events: Vec<Ev> = Vec::new();
        let mut nodes = n0;
        for _ in 0..gen.usize(8, 20) {
            match gen.usize(0, 12) {
                0 if nodes < cap => {
                    events.push(Ev::Up(Update::AddNode));
                    nodes += 1;
                }
                1..=3 => {
                    let u = gen.rng().usize(nodes);
                    let v = gen.rng().usize(nodes);
                    if u != v {
                        events.push(Ev::Up(Update::AddEdge(u, v)));
                    }
                }
                4..=5 => {
                    let u = gen.rng().usize(nodes);
                    let v = gen.rng().usize(nodes);
                    if u != v {
                        events.push(Ev::Up(Update::RemoveEdge(u, v)));
                    }
                }
                6..=7 => {
                    // feature churn against an original node: dirties one
                    // page, which the cache must invalidate precisely
                    let node = gen.rng().usize(n0);
                    let vals: Vec<f32> =
                        (0..f).map(|_| gen.rng().usize(100) as f32 / 100.0).collect();
                    events.push(Ev::Write(node, vals));
                }
                _ => events.push(Ev::Query),
            }
        }
        events.push(Ev::Query); // always end on a comparison

        let cfg = IncrementalConfig::default();
        // geometries: generous (everything resident after round one),
        // one-slot (every admission duels, constant mid-round eviction),
        // and single-row pages with a 2-slot cache
        for (page_rows, cache_pages) in [(4usize, 64usize), (2, 1), (1, 2)] {
            let mut paged = paged_engine(&ds, cap, cfg, page_rows, cache_pages);
            let mut mem = IncrementalEngine::full(&ds, cap, serial(), cfg).unwrap();
            let mut oracle = Oracle::new(&ds, cap);
            for ev in &events {
                match ev {
                    Ev::Up(u) => {
                        paged.apply(u).unwrap();
                        mem.apply(u).unwrap();
                        oracle.apply(u).unwrap();
                    }
                    Ev::Write(node, vals) => {
                        paged.write_features(*node, vals).unwrap();
                        mem.write_features(*node, vals).unwrap();
                        oracle.write_features(*node, vals).unwrap();
                    }
                    Ev::Query => {
                        let got_p = paged.infer().unwrap();
                        let got_m = mem.infer().unwrap();
                        let want = oracle.logits();
                        let dp = want.max_abs_diff(&got_p);
                        let dm = want.max_abs_diff(&got_m);
                        assert!(
                            dp < 1e-4,
                            "paged ({page_rows}-row pages, {cache_pages} slots) \
                             diverged from oracle by {dp}"
                        );
                        assert!(dm < 1e-4, "in-memory diverged from oracle by {dm}");
                    }
                }
            }
        }
    });
}

#[test]
fn warm_page_writes_are_not_served_stale() {
    // warm the whole cache, overwrite one node's features, and require
    // the next round to see the new values — an unversioned cache would
    // answer from the stale page
    let ds = synthesize("stor-stale", 30, 70, 4, 8, 7);
    let cap = 32;
    let cfg = IncrementalConfig::default();
    let mut paged = paged_engine(&ds, cap, cfg, 4, 64); // all pages fit
    let mut mem = IncrementalEngine::full(&ds, cap, serial(), cfg).unwrap();

    let cold = paged.infer().unwrap();
    let warm = paged.infer().unwrap();
    assert!(cold.max_abs_diff(&warm) < 1e-6, "warm replay must be stable");
    let rs = paged.last_round().expect("round stats").clone();
    assert!(rs.page_hits > 0, "warm round recorded no page hits");
    assert_eq!(rs.page_faults, 0, "warm round faulted {} pages", rs.page_faults);
    let _ = mem.infer().unwrap();

    let vals = vec![0.5f32; 8];
    paged.write_features(3, &vals).unwrap();
    mem.write_features(3, &vals).unwrap();
    let got_p = paged.infer().unwrap();
    let got_m = mem.infer().unwrap();
    assert!(
        got_m.max_abs_diff(&got_p) < 1e-4,
        "post-write paged answer diverged by {}",
        got_m.max_abs_diff(&got_p)
    );
    assert!(
        got_m.max_abs_diff(&cold) > 1e-6,
        "the write changed nothing — stale-read check is vacuous"
    );
    let rs = paged.last_round().expect("round stats").clone();
    assert!(rs.page_faults > 0, "the dirtied page was never re-read from disk");
}

/// Churn that crosses shard boundaries, interleaved with queries.
fn churn_script(
    n: usize,
    mut apply: impl FnMut(Update),
    mut query: impl FnMut(usize),
) {
    for i in 0..8 {
        apply(Update::AddEdge(i, n - 1 - i));
        query(i);
        query(n - 1 - i);
    }
    apply(Update::RemoveEdge(0, n - 1));
    apply(Update::AddNode);
    for q in (0..n).step_by(5) {
        query(q);
    }
}

fn run_deployment(ds: &Dataset, shards: usize, backend: &str) -> Vec<(usize, i32)> {
    let mut spec = DeploymentSpec {
        engine: EngineSpec::named("incremental"),
        topology: Topology::homogeneous(shards),
        capacity: ds.num_nodes() + 4,
        ..DeploymentSpec::default()
    };
    spec.storage.backend = backend.into();
    // tiny cache (3 slots of 4-row pages) so every round evicts mid-gather
    spec.storage.page_rows = 4;
    spec.storage.cache_pages = 3;
    let fleet = Deployment::launch(&spec, &DataSource::Dataset(ds.clone())).unwrap();
    let mut preds = Vec::new();
    churn_script(
        ds.num_nodes(),
        |u| fleet.update(u).unwrap(),
        |n| preds.push((n, fleet.query_wait(Some(n)).unwrap().prediction)),
    );
    let agg = fleet.metrics();
    if backend == "paged" {
        assert!(
            agg.page_hits + agg.page_faults > 0,
            "paged deployment reported no storage traffic"
        );
        assert!(agg.storage_bytes_read > 0);
    } else {
        assert_eq!(agg.page_faults, 0, "memory backend touched the disk tier");
    }
    fleet.shutdown().unwrap();
    preds
}

#[test]
fn paged_deployment_matches_memory_at_1_and_3_shards() {
    let ds = synthesize("stor-fleet", 60, 140, 4, 12, 29);
    let reference = run_deployment(&ds, 1, "memory");
    for shards in [1usize, 3] {
        for backend in ["memory", "paged"] {
            let got = run_deployment(&ds, shards, backend);
            assert_eq!(
                reference, got,
                "{shards}-shard {backend} deployment diverged"
            );
        }
    }
}

#[test]
fn headless_dataset_with_empty_store_path_refuses_to_launch() {
    // spilling a headless dataset would build an all-zero store and
    // silently serve zero features — the launcher must refuse instead
    let ds = synthesize_power_law_headless("pl-headless", 120, 6, 4, 24, 11);
    let mut spec = DeploymentSpec {
        engine: EngineSpec::named("incremental"),
        topology: Topology::homogeneous(1),
        capacity: ds.num_nodes() + 4,
        ..DeploymentSpec::default()
    };
    spec.storage.backend = "paged".into();
    spec.storage.page_rows = 4;
    spec.storage.cache_pages = 3;
    let err = Deployment::launch(&spec, &DataSource::Dataset(ds))
        .err()
        .expect("headless spill launch must fail");
    let err = format!("{err:#}");
    assert!(err.contains("headless"), "error not actionable: {err}");
    assert!(err.contains("path"), "error should point at [storage] path: {err}");
}

#[test]
fn power_law_paged_deployment_matches_memory() {
    // the heavy-tailed degree distribution concentrates gathers on hub
    // pages — the admission sketch's favorite case — and must stay exact
    let ds = synthesize_power_law("pl-paged", 400, 6, 4, 24, 11);
    let mem = run_deployment(&ds, 2, "memory");
    let paged = run_deployment(&ds, 2, "paged");
    assert_eq!(mem, paged, "power-law paged deployment diverged");
}
