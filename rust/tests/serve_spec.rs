//! The unified serving API, end to end: spec parsing round-trips,
//! actionable rejection of every invalid combination, topology
//! equivalence (the same `DeploymentSpec` through the 1-shard
//! `Serving` and the N-shard `Serving` answers identically), metrics
//! consistency through the merge, deadline shedding, and registry
//! extension with a test-only engine that touches neither `server/`,
//! `fleet/`, nor `main.rs`.

use std::time::Duration;

use grannite::config::parse::Value;
use grannite::graph::datasets::{synthesize, Dataset};
use grannite::serve::{
    DataSource, Deployment, DeploymentSpec, EngineFactory, EngineInit,
    EngineRegistry, EngineSpec, KernelSpec, LaunchContext, Serving,
    ShardFactory, TelemetrySpec, Topology,
};
use grannite::server::{InferenceEngine, QueryResponse, Update};
use grannite::tensor::Mat;
use grannite::util::Rng;

fn twin() -> Dataset {
    synthesize("serve-spec", 60, 150, 4, 12, 23)
}

fn spec(engine: &str, shards: usize) -> DeploymentSpec {
    DeploymentSpec {
        engine: EngineSpec::named(engine),
        topology: Topology::zoo(shards),
        capacity: 64,
        ..DeploymentSpec::default()
    }
}

// ---------------------------------------------------------------------------
// spec parsing: round trip + rejections
// ---------------------------------------------------------------------------

#[test]
fn full_spec_round_trips_through_toml() {
    let mut spec = DeploymentSpec {
        model: "gcn".into(),
        capacity: 4096,
        aggregation: grannite::ops::build::Aggregation::Sparse,
        quant: true,
        engine: EngineSpec::named("plan")
            .with_option("cost_margin", Value::Float(0.5))
            .with_option("tile_min", Value::Int(64))
            .with_option("artifact", Value::Str("gcn_grad_cora".into())),
        topology: Topology {
            shards: 3,
            devices: vec!["series2".into(), "cpu".into()],
            dtype_bytes: 1,
        },
        ..DeploymentSpec::default()
    };
    spec.batch.max_batch = 32;
    spec.batch.max_wait_us = 750;
    spec.admission.max_pending = 9;
    spec.telemetry = TelemetrySpec {
        enabled: true,
        ring_capacity: 512,
        sample_rate: 0.25,
    };
    spec.slo.enabled = true;
    spec.slo.latency_us = 25_000;
    spec.slo.quantile = 0.99;
    spec.slo.availability = 0.995;
    spec.slo.fast_window_ms = 2_000;
    spec.slo.slow_window_ms = 30_000;
    spec.slo.burn_threshold = 3.5;
    spec.slo.pressure = false;
    spec.monitor.enabled = true;
    spec.monitor.interval_ms = 100;
    spec.monitor.history = 600;
    spec.monitor.addr = "127.0.0.1:9890".into();
    spec.kernels = KernelSpec {
        simd: "off".into(),
        reorder: "rcm".into(),
        degree_bins: 4,
    };

    let text = spec.to_toml();
    let parsed = DeploymentSpec::parse_toml(&text).unwrap();
    assert_eq!(parsed, spec, "to_toml → parse_toml must be the identity:\n{text}");

    // and the emitted form is stable (parse → emit → parse fixed point)
    assert_eq!(parsed.to_toml(), text);
}

#[test]
fn checked_in_example_specs_parse_and_validate() {
    let reg = EngineRegistry::builtin();
    for name in [
        "single_leader_plan.toml",
        "incremental_4shard_sparse.toml",
        "int8_fleet.toml",
        "self_tuning_auto.toml",
        "monitored_fleet.toml",
        "paged_10m.toml",
    ] {
        let path = std::path::Path::new("../examples/specs").join(name);
        let spec = DeploymentSpec::load(&path)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        spec.validate_with(&reg)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
    }
}

#[test]
fn tuning_section_round_trips_and_validates() {
    let mut s = spec("auto", 2);
    s.tuning.objective = "throughput".into();
    s.tuning.probe_budget = 128;
    s.tuning.top_k = 5;
    s.tuning.hysteresis_low = 0.5;
    s.tuning.hysteresis_high = 12.0;
    s.tuning.cooldown_rounds = 7;

    let text = s.to_toml();
    assert!(text.contains("[tuning]"), "{text}");
    let parsed = DeploymentSpec::parse_toml(&text).unwrap();
    assert_eq!(parsed, s, "to_toml → parse_toml must keep [tuning]:\n{text}");
    parsed.validate_with(&EngineRegistry::builtin()).unwrap();
}

#[test]
fn storage_section_round_trips_and_validates() {
    let mut s = spec("incremental", 2);
    s.storage.backend = "paged".into();
    s.storage.page_rows = 128;
    s.storage.cache_pages = 256;
    s.storage.path = "/tmp/features.gnnt".into();

    let text = s.to_toml();
    assert!(text.contains("[storage]"), "{text}");
    let parsed = DeploymentSpec::parse_toml(&text).unwrap();
    assert_eq!(parsed, s, "to_toml → parse_toml must keep [storage]:\n{text}");
    parsed.validate_with(&EngineRegistry::builtin()).unwrap();
}

#[test]
fn paged_backend_rejected_by_dense_engines() {
    // engines that bind the full feature matrix into a compiled plan
    // must refuse a disk tier up front, pointing at the one that works
    let reg = EngineRegistry::builtin();
    for engine in ["local", "plan", "auto"] {
        let mut s = spec(engine, 1);
        s.storage.backend = "paged".into();
        let err = s.validate_with(&reg).unwrap_err().to_string();
        assert!(err.contains("incremental"), "{engine}: {err}");
        assert!(err.contains("paged"), "{engine}: {err}");
    }
}

#[test]
fn bad_tuning_values_are_rejected_actionably() {
    // an unknown objective names the two valid ones
    let mut s = spec("auto", 1);
    s.tuning.objective = "speed".into();
    let err = s.validate().unwrap_err().to_string();
    assert!(err.contains("tuning.objective"), "{err}");
    assert!(err.contains("latency") && err.contains("throughput"), "{err}");

    // a zero-query probe can never rank candidates
    let mut s = spec("auto", 1);
    s.tuning.probe_budget = 0;
    let err = s.validate().unwrap_err().to_string();
    assert!(err.contains("tuning.probe_budget must be ≥ 1 (got 0)"), "{err}");

    // inverted / degenerate / non-finite hysteresis bands would pin or
    // flap the auto engine
    for (lo, hi) in [(8.0, 1.0), (3.0, 3.0), (-1.0, 2.0), (1.0, f64::NAN)] {
        let mut s = spec("auto", 1);
        s.tuning.hysteresis_low = lo;
        s.tuning.hysteresis_high = hi;
        let err = s.validate().unwrap_err().to_string();
        assert!(
            err.contains("hysteresis band must satisfy"),
            "(low {lo}, high {hi}): {err}"
        );
    }
}

#[test]
fn typoed_engine_option_gets_a_did_you_mean() {
    let mut s = spec("incremental", 1);
    s.engine = EngineSpec::named("incremental")
        .with_option("cost_margen", Value::Float(0.5));
    let err = format!("{:#}", s.validate_with(&EngineRegistry::builtin()).unwrap_err());
    assert!(err.contains("did you mean \"cost_margin\"?"), "{err}");
    assert!(err.contains("tile_min"), "must still list every option: {err}");
}

#[test]
fn registry_surfaces_each_engines_accepted_options() {
    let reg = EngineRegistry::builtin();
    assert_eq!(reg.options_for("incremental").unwrap(), ["cost_margin", "tile_min"]);
    // the auto engine forwards the same knobs to its incremental half
    assert_eq!(reg.options_for("auto").unwrap(), ["cost_margin", "tile_min"]);
    assert_eq!(reg.options_for("coordinator").unwrap(), ["artifact"]);
    assert!(reg.options_for("plan").unwrap().is_empty());
    assert!(reg.options_for("local").unwrap().is_empty());
    let err = format!("{:#}", reg.options_for("warp-drive").unwrap_err());
    assert!(err.contains("warp-drive"), "{err}");
}

#[test]
fn zero_shards_is_rejected_with_guidance() {
    let mut s = spec("local", 1);
    s.topology.shards = 0;
    let err = s.validate().unwrap_err().to_string();
    assert!(err.contains("topology.shards"), "{err}");
    assert!(err.contains("shards = 1"), "{err}");
}

#[test]
fn unknown_engine_lists_registered_engines() {
    let s = spec("warp-drive", 2);
    let err = format!("{:#}", s.validate_with(&EngineRegistry::builtin()).unwrap_err());
    assert!(err.contains("warp-drive"), "{err}");
    for known in ["coordinator", "incremental", "local", "plan"] {
        assert!(err.contains(known), "missing {known} in: {err}");
    }
}

#[test]
fn zero_telemetry_ring_is_rejected_with_guidance() {
    let mut s = spec("local", 1);
    s.telemetry.enabled = true;
    s.telemetry.ring_capacity = 0;
    let err = s.validate().unwrap_err().to_string();
    assert!(err.contains("ring_capacity"), "{err}");
    assert!(err.contains("enabled = false"), "{err}");
}

#[test]
fn out_of_range_sample_rate_is_rejected() {
    for bad in [0.0, -0.5, 1.5] {
        let mut s = spec("local", 1);
        s.telemetry.sample_rate = bad;
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("sample_rate"), "rate {bad}: {err}");
    }
}

#[test]
fn bad_slo_values_are_rejected_actionably() {
    // quantiles and availabilities live strictly inside (0, 1)
    for bad in [0.0, 1.0, -0.5, 1.5] {
        let mut s = spec("local", 1);
        s.slo.quantile = bad;
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("slo.quantile"), "quantile {bad}: {err}");

        let mut s = spec("local", 1);
        s.slo.availability = bad;
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("slo.availability"), "availability {bad}: {err}");
    }

    // a zero-microsecond objective is unmeetable
    let mut s = spec("local", 1);
    s.slo.latency_us = 0;
    let err = s.validate().unwrap_err().to_string();
    assert!(err.contains("slo.latency_us"), "{err}");
    assert!(err.contains("enabled = false"), "must point at the off switch: {err}");

    // zero-length windows can never accumulate a burn rate
    for (fast, slow) in [(0usize, 60_000usize), (5_000, 0)] {
        let mut s = spec("local", 1);
        s.slo.fast_window_ms = fast;
        s.slo.slow_window_ms = slow;
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("slo windows"), "({fast}, {slow}): {err}");
    }

    // the fast window must actually be faster
    for (fast, slow) in [(60_000usize, 5_000usize), (5_000, 5_000)] {
        let mut s = spec("local", 1);
        s.slo.fast_window_ms = fast;
        s.slo.slow_window_ms = slow;
        let err = s.validate().unwrap_err().to_string();
        assert!(
            err.contains("slo.fast_window_ms") && err.contains("shorter"),
            "({fast}, {slow}): {err}"
        );
    }

    // a threshold ≤ 1 fires on exactly-on-budget behavior
    for bad in [1.0, 0.5, f64::NAN] {
        let mut s = spec("local", 1);
        s.slo.burn_threshold = bad;
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("slo.burn_threshold"), "threshold {bad}: {err}");
    }
}

#[test]
fn bad_monitor_values_are_rejected_actionably() {
    // a zero interval would make the sampler spin and the watchdog
    // flag every healthy shard
    let mut s = spec("local", 1);
    s.monitor.interval_ms = 0;
    let err = s.validate().unwrap_err().to_string();
    assert!(err.contains("monitor.interval_ms"), "{err}");
    assert!(err.contains("enabled = false"), "{err}");

    // windowed rates difference adjacent samples: need at least two
    for bad in [0usize, 1] {
        let mut s = spec("local", 1);
        s.monitor.history = bad;
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("monitor.history"), "history {bad}: {err}");
        assert!(err.contains("two samples"), "{err}");
    }

    // a malformed bind address fails at validation, not at launch
    for bad in ["localhost", "127.0.0.1", "not-an-addr:xyz"] {
        let mut s = spec("local", 1);
        s.monitor.addr = bad.into();
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("monitor.addr"), "addr {bad:?}: {err}");
    }

    // an enabled [slo] or a bind address implies an active monitor even
    // with [monitor] enabled left false
    let mut s = spec("local", 1);
    assert!(!s.monitor_active(), "defaults must keep the monitor off");
    s.slo.enabled = true;
    assert!(s.monitor_active(), "an enabled SLO needs the sampler");
    let mut s = spec("local", 1);
    s.monitor.addr = "127.0.0.1:0".into();
    assert!(s.monitor_active(), "a scrape address needs the sampler");
}

#[test]
fn kernels_section_round_trips_and_lowers() {
    let mut s = spec("plan", 2);
    s.kernels = KernelSpec {
        simd: "on".into(),
        reorder: "none".into(),
        degree_bins: 4,
    };
    let text = s.to_toml();
    assert!(text.contains("[kernels]"), "{text}");
    let parsed = DeploymentSpec::parse_toml(&text).unwrap();
    assert_eq!(parsed, s, "to_toml → parse_toml must keep [kernels]:\n{text}");
    parsed.validate_with(&EngineRegistry::builtin()).unwrap();

    // the strings lower to the typed engine knobs exactly once, here
    let cfg = parsed.kernels.kernel_config().unwrap();
    assert_eq!(cfg.simd, grannite::ops::plan::SimdMode::On);
    assert_eq!(cfg.reorder, grannite::ops::plan::ReorderMode::None);
    assert_eq!(cfg.degree_bins, 4);
}

#[test]
fn bad_kernel_values_are_rejected_actionably() {
    // an unknown SIMD mode names all three and what each means
    let mut s = spec("plan", 1);
    s.kernels.simd = "fast".into();
    let err = s.validate().unwrap_err().to_string();
    assert!(err.contains("kernels.simd"), "{err}");
    assert!(err.contains("auto") && err.contains("off"), "{err}");
    assert!(err.contains("oracle"), "must explain the off path: {err}");

    // an unknown reorder mode names the two passes
    let mut s = spec("plan", 1);
    s.kernels.reorder = "cacheg".into();
    let err = s.validate().unwrap_err().to_string();
    assert!(err.contains("kernels.reorder"), "{err}");
    assert!(err.contains("degree") && err.contains("rcm"), "{err}");

    // zero bins would starve the nnz-balanced dispenser
    let mut s = spec("plan", 1);
    s.kernels.degree_bins = 0;
    let err = s.validate().unwrap_err().to_string();
    assert!(err.contains("kernels.degree_bins must be ≥ 1 (got 0)"), "{err}");

    // a typoed key inside [kernels] is loud, like every other section
    let err = DeploymentSpec::parse_toml("[kernels]\nbins = 4")
        .unwrap_err()
        .to_string();
    assert!(err.contains("[kernels]"), "{err}");
    assert!(err.contains("degree_bins"), "must list the valid keys: {err}");
}

#[test]
fn serving_engines_reject_compile_time_reorder() {
    // the degree/rcm locality passes permute node ids at plan-compile
    // time; serving shards bind live mutable graphs, so every factory
    // that dispatches microkernels must refuse — pointing at the
    // static-plan API instead of silently ignoring the knob
    for engine in ["plan", "incremental", "auto"] {
        let mut s = spec(engine, 1);
        s.kernels.reorder = "rcm".into();
        let err =
            format!("{:#}", s.validate_with(&EngineRegistry::builtin()).unwrap_err());
        assert!(err.contains("kernels.reorder"), "{engine}: {err}");
        assert!(err.contains("\"none\""), "{engine}: must point at the fix: {err}");
        assert!(
            err.contains("Reordering"),
            "{engine}: must point at the static-plan API: {err}"
        );
    }

    // engines with no microkernel dispatch ignore [kernels] entirely
    let mut s = spec("local", 1);
    s.kernels.reorder = "rcm".into();
    s.validate_with(&EngineRegistry::builtin()).unwrap();
}

#[test]
fn unknown_aggregation_string_is_rejected_at_parse() {
    let err = DeploymentSpec::parse_toml("aggregation = \"csr\"")
        .unwrap_err()
        .to_string();
    assert!(err.contains("dense|sparse|auto"), "{err}");
}

#[test]
fn unknown_device_lists_the_valid_names() {
    let mut s = spec("local", 2);
    s.topology.devices = vec!["series2".into(), "tpu".into()];
    let err = format!("{:#}", s.validate().unwrap_err());
    assert!(err.contains("tpu"), "{err}");
    assert!(err.contains("entry 1"), "which roster entry was wrong: {err}");
    for known in ["series2", "series1", "cpu", "gpu"] {
        assert!(err.contains(known), "missing {known} in: {err}");
    }
}

#[test]
fn incremental_dense_capacity_overflow_is_rejected() {
    let mut s = spec("incremental", 2);
    s.aggregation = grannite::ops::build::Aggregation::Dense;
    s.capacity = 20_000; // 20000² × 4B = 1.6 GB dense mask
    let err = format!("{:#}", s.validate_with(&EngineRegistry::builtin()).unwrap_err());
    assert!(err.contains("dense"), "{err}");
    assert!(err.contains("sparse"), "must point at the fix: {err}");
    assert!(err.contains("20000"), "must name the capacity: {err}");

    // auto never materializes the dense mask at this scale → accepted
    s.aggregation = grannite::ops::build::Aggregation::Auto;
    s.validate_with(&EngineRegistry::builtin()).unwrap();
}

#[test]
fn quant_on_non_plan_engines_is_rejected() {
    for engine in ["local", "incremental"] {
        let mut s = spec(engine, 1);
        s.quant = true;
        let err =
            format!("{:#}", s.validate_with(&EngineRegistry::builtin()).unwrap_err());
        assert!(err.contains("plan"), "{engine}: must point at plan: {err}");
    }
}

#[test]
fn wrong_option_types_are_loud() {
    let mut s = spec("incremental", 1);
    s.engine = EngineSpec::named("incremental")
        .with_option("cost_margin", Value::Str("high".into()));
    let err = format!("{:#}", s.validate_with(&EngineRegistry::builtin()).unwrap_err());
    assert!(err.contains("cost_margin"), "{err}");

    let mut s = spec("incremental", 1);
    s.engine =
        EngineSpec::named("incremental").with_option("tile_size", Value::Int(8));
    let err = format!("{:#}", s.validate_with(&EngineRegistry::builtin()).unwrap_err());
    assert!(err.contains("tile_size") && err.contains("tile_min"), "{err}");

    // engines with a closed (empty) option set reject strays too —
    // an option must never silently become a no-op
    let mut s = spec("plan", 1);
    s.engine = EngineSpec::named("plan").with_option("cost_margin", Value::Float(0.5));
    let err = format!("{:#}", s.validate_with(&EngineRegistry::builtin()).unwrap_err());
    assert!(err.contains("no [engine] options"), "{err}");

    // a wrong-typed coordinator artifact is loud, not a silent default
    let mut s = spec("coordinator", 1);
    s.engine = EngineSpec::named("coordinator").with_option("artifact", Value::Int(42));
    let err = format!("{:#}", s.validate_with(&EngineRegistry::builtin()).unwrap_err());
    assert!(err.contains("artifact") && err.contains("string"), "{err}");
}

#[test]
fn capacity_below_graph_size_is_rejected_at_launch() {
    let ds = twin(); // 60 nodes
    let mut s = spec("local", 1);
    s.capacity = 10;
    let err = format!(
        "{:#}",
        Deployment::launch(&s, &DataSource::Dataset(ds)).unwrap_err()
    );
    assert!(err.contains("capacity 10"), "{err}");
    assert!(err.contains("60"), "{err}");
}

#[test]
fn coordinator_without_artifacts_fails_actionably() {
    let err = format!(
        "{:#}",
        DataSource::Artifacts {
            dir: "does-not-exist".into(),
            dataset: "cora".into(),
        }
        .dataset()
        .unwrap_err()
    );
    assert!(err.contains("make artifacts"), "{err}");

    // and from a Dataset source, the coordinator factory itself objects
    let err = format!(
        "{:#}",
        Deployment::launch(&spec("coordinator", 1), &DataSource::Dataset(twin()))
            .unwrap_err()
    );
    assert!(err.contains("DataSource::Artifacts"), "{err}");
}

// ---------------------------------------------------------------------------
// topology equivalence: 1 shard (ServerHandle) vs N shards (Fleet)
// ---------------------------------------------------------------------------

/// Drive a deterministic churn/query script and return (predictions,
/// queries issued).
fn drive(serving: &dyn Serving, nodes: usize) -> (Vec<(usize, i32)>, usize) {
    let mut rng = Rng::new(41);
    let mut preds = Vec::new();
    let mut queries = 0usize;
    for step in 0..120 {
        if step % 3 == 0 {
            let u = rng.usize(nodes);
            let v = (u + 1 + rng.usize(nodes - 1)) % nodes;
            serving.update(Update::AddEdge(u.min(v), u.max(v))).unwrap();
        } else {
            let n = rng.usize(nodes);
            preds.push((n, serving.query_wait(Some(n)).unwrap().prediction));
            queries += 1;
        }
    }
    (preds, queries)
}

#[test]
fn same_spec_serves_identically_at_one_and_n_shards() {
    let ds = twin();
    // every offline engine family, and the INT8 plan variant
    for (engine, quant) in [("local", false), ("plan", false), ("plan", true),
                            ("incremental", false)] {
        let mut reference: Option<Vec<(usize, i32)>> = None;
        for shards in [1usize, 3] {
            let mut s = spec(engine, shards);
            s.quant = quant;
            let serving =
                Deployment::launch(&s, &DataSource::Dataset(ds.clone())).unwrap();
            assert_eq!(serving.num_shards(), shards);
            let (preds, queries) = drive(serving.as_ref(), 60);

            // merged-metrics consistency: the deployment-wide snapshot
            // counts exactly the issued queries, and equals the per-shard
            // sum whatever the topology
            let total = serving.metrics();
            assert_eq!(total.queries, queries, "{engine}×{shards}");
            let per: usize = serving.shard_metrics().iter().map(|s| s.queries).sum();
            assert_eq!(per, total.queries, "{engine}×{shards} shard sum");
            assert_eq!(serving.shard_metrics().len(), shards);

            match &reference {
                None => reference = Some(preds),
                Some(r) => assert_eq!(
                    r, &preds,
                    "{engine} (quant {quant}): {shards}-shard answers diverged \
                     from the single leader"
                ),
            }
            serving.shutdown().unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// query_wait / query_deadline (trait-provided waits)
// ---------------------------------------------------------------------------

/// An engine whose inference blocks long enough to trip deadlines.
struct Slow {
    nodes: usize,
    delay: Duration,
}

impl InferenceEngine for Slow {
    fn apply(&mut self, _u: &Update) -> anyhow::Result<u64> {
        Ok(0)
    }
    fn infer(&mut self) -> anyhow::Result<Mat> {
        std::thread::sleep(self.delay);
        Ok(Mat::zeros(self.nodes, 2))
    }
    fn num_nodes(&self) -> usize {
        self.nodes
    }
}

/// Registry factory for [`Slow`] — registered from this test file only.
struct SlowFactory {
    delay: Duration,
}

impl EngineFactory for SlowFactory {
    fn name(&self) -> &str {
        "slow"
    }
    fn prepare(&self, ctx: &LaunchContext) -> anyhow::Result<ShardFactory> {
        let nodes = ctx.dataset.num_nodes();
        let delay = self.delay;
        Ok(Box::new(move |_s: &grannite::fleet::ShardSpec| -> EngineInit {
            Box::new(move || {
                Ok(Box::new(Slow { nodes, delay })
                    as Box<dyn InferenceEngine>)
            })
        }))
    }
}

fn slow_registry(delay: Duration) -> EngineRegistry {
    let mut reg = EngineRegistry::builtin();
    reg.register(Box::new(SlowFactory { delay }));
    reg
}

#[test]
fn query_deadline_sheds_and_counts_on_both_topologies() {
    let ds = twin();
    for shards in [1usize, 2] {
        let reg = slow_registry(Duration::from_millis(300));
        let serving = Deployment::launch_with(
            &reg,
            &spec("slow", shards),
            &DataSource::Dataset(ds.clone()),
        )
        .unwrap();
        let err = serving
            .query_deadline(Some(3), Duration::from_millis(10))
            .unwrap_err()
            .to_string();
        assert!(err.contains("deadline"), "{shards} shards: {err}");
        // the abandoned query lands in the admission accounting
        assert!(
            serving.metrics().rejected >= 1,
            "{shards} shards: shed not counted"
        );
        // a generous deadline answers normally
        let r: QueryResponse = serving
            .query_deadline(Some(3), Duration::from_secs(30))
            .unwrap();
        assert_eq!(r.prediction, 0);
        serving.shutdown().unwrap();
    }
}

// ---------------------------------------------------------------------------
// registry extension: a dummy engine, zero edits to server/fleet/main
// ---------------------------------------------------------------------------

/// Test-only engine: prediction = (node + version) % 4, like the
/// in-tree mocks — everything it needs comes through the registry.
struct Dummy {
    nodes: usize,
    version: u64,
}

impl InferenceEngine for Dummy {
    fn apply(&mut self, _u: &Update) -> anyhow::Result<u64> {
        self.version += 1;
        Ok(self.version)
    }
    fn infer(&mut self) -> anyhow::Result<Mat> {
        let mut m = Mat::zeros(self.nodes, 4);
        for i in 0..self.nodes {
            m[(i, (i + self.version as usize) % 4)] = 1.0;
        }
        Ok(m)
    }
    fn num_nodes(&self) -> usize {
        self.nodes
    }
}

struct DummyFactory;

impl EngineFactory for DummyFactory {
    fn name(&self) -> &str {
        "dummy"
    }
    fn validate(&self, spec: &DeploymentSpec) -> anyhow::Result<()> {
        if spec.quant {
            anyhow::bail!("engine \"dummy\" has no INT8 path");
        }
        Ok(())
    }
    fn prepare(&self, ctx: &LaunchContext) -> anyhow::Result<ShardFactory> {
        let nodes = ctx.dataset.num_nodes();
        Ok(Box::new(move |_s: &grannite::fleet::ShardSpec| -> EngineInit {
            Box::new(move || {
                Ok(Box::new(Dummy { nodes, version: 0 })
                    as Box<dyn InferenceEngine>)
            })
        }))
    }
}

#[test]
fn dummy_engine_registers_and_serves_both_topologies() {
    let ds = twin();
    let mut reg = EngineRegistry::builtin();
    reg.register(Box::new(DummyFactory));
    assert!(reg.names().contains(&"dummy".to_string()));

    for shards in [1usize, 3] {
        let serving = Deployment::launch_with(
            &reg,
            &spec("dummy", shards),
            &DataSource::Dataset(ds.clone()),
        )
        .unwrap();
        serving.update(Update::AddNode).unwrap(); // version 1
        let r = serving.query_wait(Some(5)).unwrap();
        assert_eq!(r.prediction, (5 + 1) % 4, "{shards} shards");
        serving.shutdown().unwrap();
    }

    // its validate hook runs through the same path as the built-ins
    let mut s = spec("dummy", 1);
    s.quant = true;
    let err = format!("{:#}", s.validate_with(&reg).unwrap_err());
    assert!(err.contains("dummy"), "{err}");
}
