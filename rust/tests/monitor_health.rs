//! Operational-monitor integration: the stall watchdog must flag a
//! wedged shard within one sampling interval while its healthy peers
//! keep serving, a panicked shard must leave an ordered breadcrumb
//! trail in the flight recorder's post-mortem, an induced SLO breach
//! must surface through `Serving::health()`, and the scrape endpoint of
//! a **launched deployment** must serve validating Prometheus text and
//! health JSON over a real socket.

use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use grannite::fleet::{AdmissionConfig, Router, ShardConfig, ShardWorker};
use grannite::graph::datasets::{synthesize, Dataset};
use grannite::monitor::{EventKind, Monitor, MonitorConfig};
use grannite::serve::{
    DataSource, Deployment, DeploymentSpec, EngineSpec, Serving, Topology,
};
use grannite::server::{InferenceEngine, ServerConfig, Update};
use grannite::tensor::Mat;

const INTERVAL: Duration = Duration::from_millis(40);

/// Fast engine: answers immediately, so its shard beats continuously.
struct Echo {
    nodes: usize,
}

impl InferenceEngine for Echo {
    fn apply(&mut self, _: &Update) -> anyhow::Result<u64> {
        Ok(0)
    }
    fn infer(&mut self) -> anyhow::Result<Mat> {
        let mut m = Mat::zeros(self.nodes, 4);
        for i in 0..self.nodes {
            m[(i, i % 4)] = 1.0;
        }
        Ok(m)
    }
    fn num_nodes(&self) -> usize {
        self.nodes
    }
}

/// Engine that blocks inside `infer` until the test releases it — a
/// deterministic stand-in for a wedged kernel: the shard loop stops
/// touching its heartbeat pulse mid-iteration, exactly like a hang.
struct Stall {
    nodes: usize,
    release: Receiver<()>,
}

impl InferenceEngine for Stall {
    fn apply(&mut self, _: &Update) -> anyhow::Result<u64> {
        Ok(0)
    }
    fn infer(&mut self) -> anyhow::Result<Mat> {
        let _ = self.release.recv_timeout(Duration::from_secs(5));
        Ok(Mat::zeros(self.nodes, 4))
    }
    fn num_nodes(&self) -> usize {
        self.nodes
    }
}

fn monitor() -> Monitor {
    Monitor::new(MonitorConfig {
        interval: INTERVAL,
        history: 64,
        slo: None,
        pressure: true,
        events: 64,
    })
}

fn cfg(monitor: &Monitor) -> ShardConfig {
    ShardConfig {
        batch: ServerConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        admission: AdmissionConfig::unbounded(),
        halo: None,
        telemetry: grannite::telemetry::Telemetry::disabled(),
        monitor: monitor.clone(),
    }
}

fn kinds(monitor: &Monitor) -> Vec<EventKind> {
    monitor.events().iter().map(|e| e.kind).collect()
}

#[test]
fn watchdog_flags_a_wedged_shard_while_its_peer_keeps_serving() {
    let m = monitor();
    let (release, rx_release) = channel::<()>();
    let owner: Vec<usize> = (0..10).map(|n| usize::from(n >= 5)).collect();
    let shards = vec![
        ShardWorker::spawn(0, || Ok(Echo { nodes: 10 }), cfg(&m)),
        ShardWorker::spawn(
            1,
            move || Ok(Stall { nodes: 10, release: rx_release }),
            cfg(&m),
        ),
    ];
    let router = Router::new(owner, shards);

    // both shards alive and beating before the hang
    let answered = router.query_wait(Some(2)).unwrap();
    assert_eq!(answered.shard, 0);
    m.sample_now();
    let health = m.health().expect("enabled monitor must report");
    assert!(health.healthy, "no shard has hung yet: {health:?}");

    // wedge shard 1: its engine blocks inside infer, the heartbeat
    // goes stale, and one interval later the watchdog must notice
    let pending = router.query(Some(7)).unwrap();
    std::thread::sleep(INTERVAL * 3);
    m.sample_now();
    let health = m.health().unwrap();
    assert!(!health.healthy, "hung shard left the fleet healthy");
    assert!(!health.panicked, "a hang is not a panic");
    let by_id = |id: usize| health.shards.iter().find(|s| s.id == id).unwrap();
    assert!(by_id(1).wedged, "shard 1 is mid-infer with a stale beat");
    assert!(
        by_id(1).beat_age_ms > INTERVAL.as_millis() as u64,
        "wedge threshold is one sampling interval: {:?}",
        by_id(1)
    );
    assert!(!by_id(0).wedged, "shard 0 never stopped beating");
    assert!(
        kinds(&m).contains(&EventKind::ShardWedged),
        "no wedge breadcrumb in {:?}",
        m.events()
    );

    // the healthy peer still answers while its neighbor hangs
    let alive = router.query_wait(Some(3)).unwrap();
    assert_eq!(alive.shard, 0);

    // release the stall: the pending query completes, the heartbeat
    // resumes, and the next tick records the recovery transition
    release.send(()).unwrap();
    assert!(pending.recv().unwrap().is_ok(), "released query must answer");
    std::thread::sleep(Duration::from_millis(10));
    m.sample_now();
    let health = m.health().unwrap();
    assert!(health.healthy, "recovered fleet still unhealthy: {health:?}");
    assert!(
        kinds(&m).contains(&EventKind::ShardRecovered),
        "no recovery breadcrumb in {:?}",
        m.events()
    );

    router.shutdown().unwrap();
}

#[test]
fn panicked_shard_leaves_ordered_breadcrumbs_in_the_post_mortem() {
    struct Bomb;
    impl InferenceEngine for Bomb {
        fn apply(&mut self, _: &Update) -> anyhow::Result<u64> {
            Ok(0)
        }
        fn infer(&mut self) -> anyhow::Result<Mat> {
            panic!("kernel scratch overflow");
        }
        fn num_nodes(&self) -> usize {
            10
        }
    }

    let m = monitor();
    let owner: Vec<usize> = (0..10).map(|n| usize::from(n >= 5)).collect();
    let shards = vec![
        ShardWorker::spawn(0, || Ok(Echo { nodes: 10 }), cfg(&m)),
        ShardWorker::spawn(1, || Ok(Bomb), cfg(&m)),
    ];
    let router = Router::new(owner, shards);

    // trip the bomb; the crash path stamps a ShardPanic breadcrumb
    let err = router.query_wait(Some(7)).unwrap_err().to_string();
    assert!(err.contains("panicked"), "{err}");
    m.sample_now();

    let health = m.health().unwrap();
    assert!(health.panicked, "recorded panic must flip the report");
    assert!(!health.healthy);

    let events = m.events();
    assert!(
        events.iter().any(|e| {
            e.kind == EventKind::ShardPanic
                && e.shard == Some(1)
                && e.detail.contains("kernel scratch overflow")
        }),
        "no panic breadcrumb in {events:?}"
    );
    // breadcrumbs are a timeline: timestamps never run backwards
    for pair in events.windows(2) {
        assert!(
            pair[0].at_ms <= pair[1].at_ms,
            "flight recorder out of order: {events:?}"
        );
    }
    let post = m.post_mortem();
    assert!(post.contains("flight recorder"), "{post}");
    assert!(post.contains("shard_panic"), "{post}");
    assert!(post.contains("kernel scratch overflow"), "{post}");

    // the surviving shard is shut down cleanly; the dead one reports
    let err = router.shutdown().unwrap_err().to_string();
    assert!(err.contains("panicked"), "{err}");
}

fn twin() -> Dataset {
    synthesize("monitor", 64, 160, 4, 12, 29)
}

fn monitored_spec(shards: usize) -> DeploymentSpec {
    let mut s = DeploymentSpec {
        engine: EngineSpec::named("incremental"),
        topology: Topology::homogeneous(shards),
        capacity: 72,
        ..DeploymentSpec::default()
    };
    s.monitor.enabled = true;
    s.monitor.interval_ms = 25;
    s.monitor.history = 64;
    s
}

#[test]
fn induced_slo_breach_surfaces_through_serving_health() {
    let ds = twin();
    let mut spec = monitored_spec(2);
    spec.slo.enabled = true;
    spec.slo.availability = 0.9; // budget: 10% of answers may fail
    spec.slo.latency_us = 60_000_000; // latency can never breach here
    spec.slo.fast_window_ms = 150;
    spec.slo.slow_window_ms = 300;
    spec.slo.burn_threshold = 2.0;
    let serving =
        Deployment::launch(&spec, &DataSource::Dataset(ds)).unwrap();
    let m = serving.monitor().expect("slo spec must activate the monitor");

    // a clean warmup: some answered queries, zero sheds
    for n in 0..8 {
        serving.query_wait(Some(n)).unwrap();
    }
    std::thread::sleep(Duration::from_millis(60));
    m.sample_now();
    let health = serving.health().unwrap();
    assert!(health.healthy, "clean workload breached: {health:?}");
    let slo = health.slo.as_ref().expect("slo configured");
    assert!(!slo.breached);

    // burn the availability budget: every request sheds, across both
    // windows — the breach must surface through Serving::health()
    let deadline = Instant::now() + Duration::from_secs(3);
    let breached = loop {
        for _ in 0..20 {
            serving.record_shed(Some(1));
        }
        std::thread::sleep(Duration::from_millis(30));
        m.sample_now();
        let health = serving.health().unwrap();
        if health.slo.as_ref().is_some_and(|s| s.breached) {
            assert!(!health.healthy, "breach must unhealthy the report");
            break true;
        }
        if Instant::now() > deadline {
            break false;
        }
    };
    assert!(breached, "sustained 100% shed never tripped the SLO");
    let slo = serving.health().unwrap().slo.unwrap();
    assert!(
        slo.fast.availability_burn > spec.slo.burn_threshold
            && slo.slow.availability_burn > spec.slo.burn_threshold,
        "breach requires both windows over threshold: {slo:?}"
    );
    assert!(
        m.events().iter().any(|e| e.kind == EventKind::SloBreach),
        "no slo_breach breadcrumb in {:?}",
        m.events()
    );

    serving.shutdown().unwrap();
}

/// Minimal HTTP GET over a raw socket: `(status line, body)`.
fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let status = raw.lines().next().unwrap_or("").to_string();
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

#[test]
fn launched_deployment_serves_a_validating_scrape_endpoint() {
    let ds = twin();
    let mut spec = monitored_spec(4);
    spec.telemetry.enabled = true;
    spec.monitor.addr = "127.0.0.1:0".to_string();
    let serving =
        Deployment::launch(&spec, &DataSource::Dataset(ds)).unwrap();
    let m = serving.monitor().unwrap();
    let addr = m.addr().expect("spec addr must bind at launch");

    // put real traffic on the rings before scraping
    for step in 0..24usize {
        serving.update(Update::AddEdge(step % 64, (step + 37) % 64)).unwrap();
        serving.query_wait(Some((step * 5) % 64)).unwrap();
    }
    m.sample_now();

    let (status, body) = http_get(addr, "/metrics");
    assert!(status.contains("200"), "{status}");
    let samples =
        grannite::telemetry::export::validate_prometheus(&body).unwrap();
    assert!(samples > 0, "scrape served an empty exposition");
    assert!(
        body.contains("grannite_queries_total"),
        "no per-shard query counter in:\n{body}"
    );

    let (status, body) = http_get(addr, "/health");
    assert!(status.contains("200"), "healthy fleet must 200: {status}");
    assert!(body.contains("\"healthy\":true"), "{body}");
    assert!(body.contains("\"shards\""), "{body}");

    let (status, body) = http_get(addr, "/traces");
    assert!(status.contains("200"), "{status}");
    let lines =
        grannite::telemetry::export::validate_json_lines(&body).unwrap();
    assert!(lines > 0, "enabled telemetry must export trace lines");

    let (status, _) = http_get(addr, "/nope");
    assert!(status.contains("404"), "{status}");

    serving.shutdown().unwrap();
    // the listener dies with the deployment: connects stop succeeding
    let gone = std::net::TcpStream::connect_timeout(
        &addr,
        Duration::from_millis(200),
    );
    // (a TIME_WAIT accept can race one last connect; only assert that
    // a successful connect no longer yields a response)
    if let Ok(mut s) = gone {
        use std::io::{Read, Write};
        let _ = write!(s, "GET /health HTTP/1.1\r\n\r\n");
        let mut raw = String::new();
        s.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        let n = s.read_to_string(&mut raw).unwrap_or(0);
        assert_eq!(n, 0, "stopped monitor still answered: {raw}");
    }
}
