//! Incremental-engine equivalence suite (ISSUE 3 acceptance):
//!
//! 1. **Property test** — over randomized interleaved
//!    `AddEdge`/`RemoveEdge`/`AddNode`/`Query` sequences, the
//!    delta-driven [`IncrementalEngine`] matches a full-graph
//!    `ops::exec` recompute to ≤ 1e-4, for the default cost-model
//!    config *and* both forced sides of the fallback crossover.
//! 2. **Frontier soundness** — brute-force before/after output diffing:
//!    every row a mutation actually changed lies inside the k-hop ball
//!    the frontier expansion reports.
//! 3. **Fleet boundary invalidation** — a sharded incremental fleet
//!    agrees with the single-leader incremental server under churn that
//!    crosses shard boundaries, while its metrics show genuine reuse.

use std::sync::Arc;

use anyhow::Result;

use grannite::coordinator::ModelState;
use grannite::engine::WorkerPool;
use grannite::fleet::synthesize_weights;
use grannite::graph::datasets::{synthesize, Dataset};
use grannite::incremental::{Frontier, IncrementalConfig, IncrementalEngine};
use grannite::ops::build::{self, GnnDims};
use grannite::ops::exec;
use grannite::serve::{
    DataSource, Deployment, DeploymentSpec, EngineSpec, Serving, Topology,
};
use grannite::server::{InferenceEngine, ServerConfig, ServerHandle, Update};
use grannite::tensor::Mat;
use grannite::util::propcheck::forall;

/// Full-recompute oracle: the same GrAd state driven through
/// `ops::exec` on the full-capacity `gcn_grad` graph with
/// snapshot-rebuilt masks — the path the incremental engine replaces.
struct Oracle {
    state: ModelState,
    weights: exec::Bindings,
    capacity: usize,
    classes: usize,
}

impl Oracle {
    fn new(ds: &Dataset, capacity: usize) -> Oracle {
        let capacity = capacity.max(ds.num_nodes());
        let classes = ds.num_classes().max(2);
        Oracle {
            state: ModelState::from_dataset(ds.clone(), capacity).unwrap(),
            weights: synthesize_weights(ds.num_features(), classes, capacity),
            capacity,
            classes,
        }
    }

    fn apply(&mut self, u: &Update) -> Result<()> {
        match u {
            Update::AddEdge(a, b) => {
                self.state.add_edge(*a, *b)?;
            }
            Update::RemoveEdge(a, b) => {
                self.state.remove_edge(*a, *b)?;
            }
            Update::AddNode => {
                self.state.add_node()?;
            }
        }
        Ok(())
    }

    fn logits(&mut self) -> Mat {
        let ds = &self.state.dataset;
        let dims = GnnDims::model(
            self.capacity,
            ds.graph.num_edges(),
            ds.num_features(),
            self.classes,
        );
        let g = build::gcn_stagr(dims, "grad");
        let mut b = self.weights.clone();
        b.insert("norm".into(), self.state.binding("norm_pad", "gcn").unwrap());
        b.insert("x".into(), self.state.binding("x_pad", "gcn").unwrap());
        let full = exec::execute_mat(&g, &b).unwrap();
        let n = self.state.num_active_nodes();
        Mat::from_fn(n, full.cols, |i, j| full[(i, j)])
    }
}

fn serial() -> Arc<WorkerPool> {
    Arc::new(WorkerPool::serial())
}

#[derive(Debug, Clone)]
enum Ev {
    Up(Update),
    Query,
}

#[test]
fn prop_incremental_matches_full_recompute() {
    forall("incremental == ops::exec full recompute", 15, |gen| {
        let n0 = gen.usize(8, 24);
        let m0 = gen.usize(n0 / 2, 2 * n0);
        let spare = gen.usize(1, 5);
        let cap = n0 + spare;
        let ds = synthesize("inc-eq", n0, m0, 4, 6, 1000 + n0 as u64 * 7 + m0 as u64);

        // one event script, replayed against every config
        let mut events: Vec<Ev> = Vec::new();
        let mut nodes = n0;
        for _ in 0..gen.usize(8, 24) {
            match gen.usize(0, 10) {
                0 if nodes < cap => {
                    events.push(Ev::Up(Update::AddNode));
                    nodes += 1;
                }
                1..=4 => {
                    let u = gen.rng().usize(nodes);
                    let v = gen.rng().usize(nodes);
                    if u != v {
                        events.push(Ev::Up(Update::AddEdge(u, v)));
                    }
                }
                5..=6 => {
                    let u = gen.rng().usize(nodes);
                    let v = gen.rng().usize(nodes);
                    if u != v {
                        events.push(Ev::Up(Update::RemoveEdge(u, v)));
                    }
                }
                _ => events.push(Ev::Query),
            }
        }
        events.push(Ev::Query); // always end on a comparison

        // default margin exercises the crossover; 0.0 forces the full
        // path every round; ∞ forces the frontier path every round
        let configs = [
            IncrementalConfig::default(),
            IncrementalConfig { cost_margin: 0.0, tile_min: 8, ..Default::default() },
            IncrementalConfig {
                cost_margin: f64::INFINITY,
                tile_min: 8,
                ..Default::default()
            },
        ];
        for cfg in configs {
            let mut eng = IncrementalEngine::full(&ds, cap, serial(), cfg).unwrap();
            let mut oracle = Oracle::new(&ds, cap);
            for ev in &events {
                match ev {
                    Ev::Up(u) => {
                        eng.apply(u).unwrap();
                        oracle.apply(u).unwrap();
                    }
                    Ev::Query => {
                        let got = eng.infer().unwrap();
                        let want = oracle.logits();
                        let d = want.max_abs_diff(&got);
                        assert!(
                            d < 1e-4,
                            "margin {} diverged by {d} ({} nodes)",
                            cfg.cost_margin,
                            got.rows
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn frontier_contains_every_row_a_mutation_changes() {
    forall("frontier ⊇ brute-force dirty rows", 12, |gen| {
        let n = gen.usize(10, 28);
        let m = gen.usize(n, 3 * n);
        let cap = n + 2;
        let ds = synthesize("inc-fr", n, m, 4, 6, 500 + (n * m) as u64);
        let mut oracle = Oracle::new(&ds, cap);
        let before = oracle.logits();

        // one structural mutation
        let u = gen.rng().usize(n);
        let mut v = gen.rng().usize(n);
        if v == u {
            v = (v + 1) % n;
        }
        let update = if gen.bool() {
            Update::AddEdge(u, v)
        } else {
            Update::RemoveEdge(u, v)
        };
        oracle.apply(&update).unwrap();
        let after = oracle.logits();

        // frontier over the *post-mutation* graph, k = 2 layers
        let mut f = Frontier::new(cap);
        f.note(&update, None);
        let balls = f.balls(2, |node, visit| {
            for &nb in oracle.state.neighbors(node) {
                visit(nb);
            }
        });
        let dirty = &balls[2];
        for i in 0..n {
            let mut changed = false;
            for j in 0..after.cols {
                if (before[(i, j)] - after[(i, j)]).abs() > 1e-9 {
                    changed = true;
                }
            }
            if changed {
                assert!(
                    dirty.contains(&(i as u32)),
                    "row {i} changed but is outside the {}-node frontier \
                     of {update:?}",
                    dirty.len()
                );
            }
        }
    });
}

/// Churn that repeatedly crosses shard boundaries (low node ids ↔ high
/// node ids), interleaved with queries so incremental rounds actually
/// run between mutations.
fn boundary_churn(mut apply: impl FnMut(Update), mut query: impl FnMut(usize)) {
    for i in 0..12 {
        apply(Update::AddEdge(i, 59 - i));
        query(i);
        query(59 - i);
    }
    apply(Update::RemoveEdge(0, 59));
    apply(Update::AddNode);
    apply(Update::AddEdge(60, 30));
    for n in (0..61).step_by(7) {
        query(n);
    }
}

#[test]
fn incremental_fleet_matches_single_leader_under_boundary_churn() {
    let ds = synthesize("inc-fleet", 60, 140, 4, 12, 17);
    let cfg = IncrementalConfig::default();

    // single leader
    let ds2 = ds.clone();
    let server = ServerHandle::spawn(
        move || IncrementalEngine::full(&ds2, 64, serial(), cfg),
        ServerConfig::default(),
    );
    let mut leader_preds: Vec<(usize, i32)> = Vec::new();
    boundary_churn(
        |u| server.update(u).unwrap(),
        |n| leader_preds.push((n, server.query_wait(Some(n)).unwrap().prediction)),
    );
    let leader_metrics = server.metrics.snapshot();
    server.shutdown().unwrap();

    // 3-shard incremental fleet over the same script, launched through
    // the unified front door (same IncrementalConfig defaults)
    let spec = DeploymentSpec {
        engine: EngineSpec::named("incremental"),
        topology: Topology::homogeneous(3),
        capacity: 64,
        ..DeploymentSpec::default()
    };
    let fleet = Deployment::launch(&spec, &DataSource::Dataset(ds.clone())).unwrap();
    let mut fleet_preds: Vec<(usize, i32)> = Vec::new();
    boundary_churn(
        |u| fleet.update(u).unwrap(),
        |n| fleet_preds.push((n, fleet.query_wait(Some(n)).unwrap().prediction)),
    );
    assert_eq!(
        leader_preds, fleet_preds,
        "boundary mutations must invalidate neighbor-shard cache rows"
    );

    // the gauges must show genuine incremental behavior fleet-wide
    let agg = fleet.metrics();
    assert!(agg.eligible_rows > 0, "round stats were never recorded");
    assert!(
        agg.recompute_ratio() < 1.0,
        "ratio {} — no cached serving happened",
        agg.recompute_ratio()
    );
    assert!(agg.cache_hit_rate() > 0.0);
    assert!(agg.frontier.is_some(), "frontier histogram missing");
    // per-shard labeled snapshots carry the gauges too
    for snap in fleet.shard_metrics() {
        assert!(snap.shard.is_some());
        if snap.queries > 0 {
            assert!(snap.eligible_rows > 0);
        }
    }
    fleet.shutdown().unwrap();

    // the leader records the same accounting through the shard worker
    assert!(leader_metrics.eligible_rows > 0);
    assert!(leader_metrics.recompute_ratio() < 1.0);
}

#[test]
fn fallback_threshold_crossover_stays_correct() {
    // tiny graph, huge churn: the default cost model must take the full
    // path (no regression), and results must still match the oracle
    let ds = synthesize("inc-x", 16, 30, 3, 5, 9);
    let mut eng =
        IncrementalEngine::full(&ds, 20, serial(), IncrementalConfig::default())
            .unwrap();
    let mut oracle = Oracle::new(&ds, 20);
    let _ = eng.infer().unwrap();
    let _ = eng.round_stats();

    // dirty most of the graph between queries
    for i in 0..14 {
        let u = Update::AddEdge(i, (i + 5) % 16);
        eng.apply(&u).unwrap();
        oracle.apply(&u).unwrap();
    }
    let got = eng.infer().unwrap();
    let rs = eng.round_stats().unwrap();
    assert_eq!(
        rs.recomputed_rows, rs.eligible_rows,
        "graph-wide churn must cross the fallback threshold"
    );
    let want = oracle.logits();
    assert!(want.max_abs_diff(&got) < 1e-4);

    // and a single follow-up mutation drops back under it — verified on
    // a sparser, wider graph where the frontier is genuinely small
    let ds = synthesize("inc-x2", 120, 150, 4, 48, 9);
    let mut eng =
        IncrementalEngine::full(&ds, 128, serial(), IncrementalConfig::default())
            .unwrap();
    let mut oracle = Oracle::new(&ds, 128);
    let _ = eng.infer().unwrap();
    let _ = eng.round_stats();
    let u = Update::AddEdge(3, 90);
    eng.apply(&u).unwrap();
    oracle.apply(&u).unwrap();
    let got = eng.infer().unwrap();
    let rs = eng.round_stats().unwrap();
    assert!(
        rs.recomputed_rows < rs.eligible_rows,
        "single-edge churn recomputed {} of {} rows",
        rs.recomputed_rows,
        rs.eligible_rows
    );
    let want = oracle.logits();
    assert!(want.max_abs_diff(&got) < 1e-4);
}

#[test]
fn incremental_engine_reports_halo_through_the_trait() {
    // trait-level halo contract used by the fleet's shard workers
    let ds = synthesize("inc-halo", 40, 90, 4, 8, 3);
    let eng: Box<dyn InferenceEngine> = Box::new(
        IncrementalEngine::shard(&ds, 44, 0..20, serial(),
                                 IncrementalConfig::default())
            .unwrap(),
    );
    assert!(eng.halo_imports().unwrap() > 0);
    assert_eq!(eng.num_nodes(), 40);
}
