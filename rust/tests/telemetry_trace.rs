//! End-to-end telemetry: a fleet query must stitch into ONE trace
//! (router route + shard admission/queue/batch/engine-round/halo/per-op
//! spans under the query id), the spans must be well-nested against the
//! measured latency, the calibration report must cover every op kind
//! the engines actually executed, and a disabled hub must record
//! nothing while serving identical answers.

use std::collections::BTreeSet;

use grannite::graph::datasets::{synthesize, Dataset};
use grannite::serve::{
    DataSource, Deployment, DeploymentSpec, EngineSpec, Serving, Topology,
};
use grannite::server::Update;
use grannite::telemetry::{SpanKind, ROUTER_SHARD};

const EPS_US: f64 = 1e-3;

fn twin() -> Dataset {
    synthesize("telemetry", 64, 160, 4, 12, 29)
}

fn spec(engine: &str, shards: usize, enabled: bool) -> DeploymentSpec {
    let mut s = DeploymentSpec {
        engine: EngineSpec::named(engine),
        topology: Topology::homogeneous(shards),
        capacity: 72,
        ..DeploymentSpec::default()
    };
    s.telemetry.enabled = enabled;
    s
}

/// Boundary-crossing churn + a query sweep; returns `(query id,
/// prediction, measured latency µs)` per answered query.
fn drive(serving: &dyn Serving, nodes: usize) -> Vec<(u64, i32, f64)> {
    let mut out = Vec::new();
    for step in 0..40usize {
        let u = (step * 7) % nodes;
        serving.update(Update::AddEdge(u, (u + 37) % nodes)).unwrap();
        let n = (step * 5) % nodes;
        let r = serving.query_wait(Some(n)).unwrap();
        out.push((r.id, r.prediction, r.latency_us));
    }
    out
}

#[test]
fn fleet_trace_stitches_shards_and_spans_are_well_nested() {
    let ds = twin();
    let serving = Deployment::launch(
        &spec("incremental", 4, true),
        &DataSource::Dataset(ds.clone()),
    )
    .unwrap();
    assert_eq!(serving.num_shards(), 4);
    let answered = drive(serving.as_ref(), 64);
    let tel = serving.telemetry().expect("fleet must expose its hub");
    assert!(tel.enabled());

    let traces = tel.traces();
    assert!(!traces.is_empty(), "enabled telemetry recorded no traces");

    // every span kind the shard loop emits shows up somewhere
    let kinds: BTreeSet<&'static str> = traces
        .iter()
        .flat_map(|t| t.spans.iter())
        .map(|s| s.kind.name())
        .collect();
    for required in ["route", "admission", "queue", "batch", "engine_round", "op"]
    {
        assert!(kinds.contains(required), "no {required} span in {kinds:?}");
    }
    // halo spans mirror the halo metric exactly (both fire iff bytes > 0)
    if serving.metrics().halo_bytes > 0 {
        assert!(kinds.contains("halo"), "halo charged but never traced");
    }

    // a fleet query stitches router + owning shard under ONE trace id
    let stitched = traces.iter().any(|t| {
        let router = t.spans.iter().any(|s| s.shard == ROUTER_SHARD);
        router && t.shard_count() >= 1
    });
    assert!(stitched, "no trace combines router and shard rings");
    // and the workload landed on more than one shard overall
    let shards: BTreeSet<usize> = traces
        .iter()
        .flat_map(|t| t.spans.iter())
        .map(|s| s.shard)
        .filter(|&s| s != ROUTER_SHARD)
        .collect();
    assert!(shards.len() >= 2, "all spans on one shard: {shards:?}");

    // well-nesting + coverage, per answered query
    let mut checked = 0usize;
    let mut op_bearing = 0usize;
    for (id, _pred, latency_us) in &answered {
        let Some(tr) = traces.iter().find(|t| t.trace_id == *id) else {
            continue; // evicted from the ring (not at this workload size)
        };
        let queue: Vec<_> = tr
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::Queue)
            .collect();
        let round: Vec<_> = tr
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::EngineRound)
            .collect();
        assert_eq!(queue.len(), 1, "trace {id} queue spans");
        assert_eq!(round.len(), 1, "trace {id} engine-round spans");
        let (q, r) = (queue[0], round[0]);
        // queue ends exactly where the engine round starts
        assert!(
            (q.start_us + q.dur_us - r.start_us).abs() < EPS_US,
            "trace {id}: queue end {} != round start {}",
            q.start_us + q.dur_us,
            r.start_us
        );
        // the engine-round span IS the measured latency
        assert!(
            (r.dur_us - latency_us).abs() < EPS_US,
            "trace {id}: round span {} vs measured {latency_us}",
            r.dur_us
        );
        // stitched spans cover ≥ measured latency minus queue time
        assert!(
            tr.latency_us() + EPS_US >= latency_us - q.dur_us,
            "trace {id}: spans cover {} < {latency_us} - {}",
            tr.latency_us(),
            q.dur_us
        );
        // per-op spans nest inside the engine round and never overrun it
        let ops: Vec<_> =
            tr.spans.iter().filter(|s| s.kind == SpanKind::Op).collect();
        if !ops.is_empty() {
            op_bearing += 1;
            let op_total: f64 = ops.iter().map(|s| s.dur_us).sum();
            assert!(
                op_total <= r.dur_us + EPS_US,
                "trace {id}: op spans total {op_total} > round {}",
                r.dur_us
            );
            for op in &ops {
                assert!(
                    op.start_us + EPS_US >= r.start_us
                        && op.start_us + op.dur_us
                            <= r.start_us + r.dur_us + EPS_US,
                    "trace {id}: op span [{}, {}] outside round [{}, {}]",
                    op.start_us,
                    op.start_us + op.dur_us,
                    r.start_us,
                    r.start_us + r.dur_us
                );
            }
        }
        checked += 1;
    }
    assert!(checked >= answered.len() / 2, "only {checked} traces retained");
    assert!(op_bearing > 0, "no trace carries per-op kernel spans");

    // calibration covers exactly the op kinds the engines executed
    // (the Op spans and the calibration rows feed from the same sinks)
    let cal = tel.calibration();
    assert!(!cal.rows.is_empty(), "no calibration rows after {checked} rounds");
    let executed: BTreeSet<&'static str> = traces
        .iter()
        .flat_map(|t| t.spans.iter())
        .filter(|s| s.kind == SpanKind::Op)
        .map(|s| s.label)
        .collect();
    let calibrated: BTreeSet<&str> =
        cal.rows.iter().map(|r| r.kind.as_str()).collect();
    for kind in &executed {
        assert!(
            calibrated.contains(*kind),
            "executed op kind {kind} missing from calibration {calibrated:?}"
        );
    }
    for row in &cal.rows {
        assert!(row.runs > 0, "{}: zero runs", row.kind);
        assert!(row.predicted_us > 0.0, "{}: no prediction", row.kind);
        assert!(row.observed_us > 0.0, "{}: no observation", row.kind);
        assert!(row.ratio_p50 > 0.0, "{}: degenerate ratio", row.kind);
    }
    // the fitted scales move the cost model toward the observations
    let scales = cal.scales();
    assert!(!scales.is_empty());
    for (kind, factor) in scales.iter() {
        assert!(
            factor.is_finite() && factor > 0.0,
            "{kind}: bad scale {factor}"
        );
    }

    serving.shutdown().unwrap();
}

#[test]
fn disabled_telemetry_records_nothing_and_answers_identically() {
    let ds = twin();
    let on = Deployment::launch(
        &spec("incremental", 4, true),
        &DataSource::Dataset(ds.clone()),
    )
    .unwrap();
    let off = Deployment::launch(
        &spec("incremental", 4, false),
        &DataSource::Dataset(ds.clone()),
    )
    .unwrap();
    let a: Vec<i32> =
        drive(on.as_ref(), 64).into_iter().map(|(_, p, _)| p).collect();
    let b: Vec<i32> =
        drive(off.as_ref(), 64).into_iter().map(|(_, p, _)| p).collect();
    assert_eq!(a, b, "telemetry must never change predictions");

    let hub = off.telemetry().expect("hub handle exists even when disabled");
    assert!(!hub.enabled());
    assert!(hub.traces().is_empty(), "disabled hub retained traces");
    assert_eq!(hub.span_counts(), (0, 0), "disabled hub counted spans");
    assert!(
        hub.calibration().rows.is_empty(),
        "disabled hub calibrated ops"
    );

    on.shutdown().unwrap();
    off.shutdown().unwrap();
}

#[test]
fn single_leader_plan_engine_traces_and_calibrates_too() {
    // the 1-shard topology (ServerHandle) threads the same hub — this is
    // what `grannite trace --spec examples/specs/single_leader_plan.toml`
    // exercises in CI
    let ds = twin();
    let serving =
        Deployment::launch(&spec("plan", 1, true), &DataSource::Dataset(ds))
            .unwrap();
    let answered = drive(serving.as_ref(), 64);
    assert_eq!(answered.len(), 40);
    let tel = serving.telemetry().unwrap();
    let traces = tel.traces();
    assert!(!traces.is_empty());
    let kinds: BTreeSet<&'static str> = traces
        .iter()
        .flat_map(|t| t.spans.iter())
        .map(|s| s.kind.name())
        .collect();
    // no router and no halo on a single leader, but the rest is there
    for required in ["admission", "queue", "batch", "engine_round", "op"] {
        assert!(kinds.contains(required), "no {required} span in {kinds:?}");
    }
    assert!(!kinds.contains("route"), "single leader has no router");
    let cal = tel.calibration();
    assert!(!cal.rows.is_empty(), "plan engine produced no calibration");
    serving.shutdown().unwrap();
}

#[test]
fn sample_rate_thins_traces_deterministically() {
    let ds = twin();
    let mut s = spec("plan", 1, true);
    s.telemetry.sample_rate = 0.25;
    let run = |s: &DeploymentSpec| -> Vec<u64> {
        let serving =
            Deployment::launch(s, &DataSource::Dataset(ds.clone())).unwrap();
        drive(serving.as_ref(), 64);
        let tel = serving.telemetry().unwrap();
        // traces() orders by measured latency, which is not reproducible
        // across runs — compare the *set* of kept trace ids instead
        let mut ids: Vec<u64> =
            tel.traces().iter().map(|t| t.trace_id).collect();
        ids.sort_unstable();
        serving.shutdown().unwrap();
        ids
    };
    let thin = run(&s);
    assert!(
        !thin.is_empty() && thin.len() < 40,
        "rate 0.25 kept {} of 40 traces",
        thin.len()
    );
    // same spec, same workload → the sample is a pure function of ids
    assert_eq!(thin, run(&s), "sampling must be deterministic");
}
