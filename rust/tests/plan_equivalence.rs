//! Plan-vs-oracle equivalence: every compiled [`ExecPlan`] must compute
//! exactly what the reference executor computes, within 1e-4, on
//! arbitrary valid op graphs — including arena-reuse-heavy graphs where
//! a stale-buffer bug would show, and repeat runs on a warm instance
//! where leftover slab contents would show.

use std::collections::BTreeMap;
use std::sync::Arc;

use grannite::engine::{run_graph_mat, PlanInstance, WorkerPool};
use grannite::graph::datasets::synthesize;
use grannite::ops::build::{self, GatVariant, GnnDims, QuantScales};
use grannite::ops::exec::{self, Bindings};
use grannite::ops::plan::ExecPlan;
use grannite::ops::{OpGraph, OpId, OpKind, Stage};
use grannite::tensor::{DType, Mat, Tensor};
use grannite::util::propcheck::{forall, Gen};

// ---------------------------------------------------------------------------
// random-graph generator
// ---------------------------------------------------------------------------

struct Builder {
    g: OpGraph,
    bindings: Bindings,
    /// f32 value-bearing nodes: (id, rows, cols)
    vals: Vec<(OpId, usize, usize)>,
    next_input: usize,
}

#[derive(Clone, Copy)]
enum Fill {
    /// ±2 with exact zeros mixed in (exercises the zero-skip kernel).
    Tame,
    /// Strictly positive, bounded away from zero (safe Div rhs).
    Positive,
    /// Integral in [-127, 127] (QMatMul weights → real INT8 path).
    Integral,
    /// 0/1 mask values.
    Mask,
}

impl Builder {
    fn new(name: String) -> Builder {
        Builder {
            g: OpGraph::new(name),
            bindings: BTreeMap::new(),
            vals: Vec::new(),
            next_input: 0,
        }
    }

    fn f32_input(&mut self, gen: &mut Gen, r: usize, c: usize, fill: Fill) -> OpId {
        let name = format!("in{}", self.next_input);
        self.next_input += 1;
        let id = self.g.input(&name, &[r, c], DType::F32, Stage::Compute);
        let data: Vec<f32> = (0..r * c)
            .map(|_| match fill {
                Fill::Tame => {
                    if gen.chance(0.25) {
                        0.0
                    } else {
                        (gen.rng().f64() * 4.0 - 2.0) as f32
                    }
                }
                Fill::Positive => (gen.rng().f64() * 2.0 + 0.5) as f32,
                Fill::Integral => (gen.rng().usize(255) as i32 - 127) as f32,
                Fill::Mask => {
                    if gen.chance(0.4) {
                        1.0
                    } else {
                        0.0
                    }
                }
            })
            .collect();
        self.bindings
            .insert(name, Tensor::F32 { shape: vec![r, c], data });
        id
    }

    fn i32_input(&mut self, r: usize, c: usize, data: Vec<i32>) -> OpId {
        let name = format!("in{}", self.next_input);
        self.next_input += 1;
        let id = self.g.input(&name, &[r, c], DType::I32, Stage::Compute);
        self.bindings
            .insert(name, Tensor::I32 { shape: vec![r, c], data });
        id
    }

    fn push_val(&mut self, id: OpId, r: usize, c: usize) {
        self.vals.push((id, r, c));
    }

    fn pick(&self, gen: &mut Gen) -> (OpId, usize, usize) {
        self.vals[gen.usize(0, self.vals.len())]
    }
}

/// Grow the graph by one random production (pushed onto `b.vals`).
fn grow(b: &mut Builder, gen: &mut Gen) {
    let (src, r, c) = b.pick(gen);
    let st = Stage::Compute;
    match gen.usize(0, 12) {
        // unary elementwise (fusible — feeds chain building)
        0 => {
            let kind = match gen.usize(0, 4) {
                0 => OpKind::Relu,
                1 => OpKind::LeakyRelu(0.2),
                2 => OpKind::Scale(0.5),
                _ => OpKind::AddConst(-0.3),
            };
            let id = b.g.op(kind, &[src], &[r, c], st);
            b.push_val(id, r, c);
        }
        // binary elementwise with broadcast variants
        1 | 2 => {
            let kind = match gen.usize(0, 3) {
                0 => OpKind::Add,
                1 => OpKind::Sub,
                _ => OpKind::Mul,
            };
            let rhs = match gen.usize(0, 3) {
                0 => b.f32_input(gen, r, c, Fill::Tame),
                1 => b.f32_input(gen, 1, c, Fill::Tame),
                _ => b.f32_input(gen, r, 1, Fill::Tame),
            };
            let id = b.g.op(kind, &[src, rhs], &[r, c], st);
            b.push_val(id, r, c);
        }
        // Div with a safe rhs
        3 => {
            let rhs = match gen.usize(0, 3) {
                0 => b.f32_input(gen, r, c, Fill::Positive),
                1 => b.f32_input(gen, 1, c, Fill::Positive),
                _ => b.f32_input(gen, r, 1, Fill::Positive),
            };
            let id = b.g.op(OpKind::Div, &[src, rhs], &[r, c], st);
            b.push_val(id, r, c);
        }
        // dense MatMul against a fresh weight input
        4 => {
            let n = gen.dim(6);
            let w = b.f32_input(gen, c, n, Fill::Tame);
            let id = b.g.op(OpKind::MatMul, &[src, w], &[r, n], st);
            b.push_val(id, r, n);
        }
        // Quantize → QMatMul with integral weights (the INT8 path)
        5 => {
            let n = gen.dim(6);
            let scale = 0.05 + gen.rng().f32() * 0.1;
            let q = b.g.op(OpKind::Quantize { scale }, &[src], &[r, c], st);
            let w = b.f32_input(gen, c, n, Fill::Integral);
            let id = b.g.op(
                OpKind::QMatMul { x_scale: scale, w_scale: 0.01 },
                &[q, w],
                &[r, n],
                st,
            );
            b.push_val(id, r, n);
        }
        // Transpose
        6 => {
            let id = b.g.op(OpKind::Transpose, &[src], &[c, r], st);
            b.push_val(id, c, r);
        }
        // Softmax
        7 => {
            let id = b.g.op(OpKind::Softmax, &[src], &[r, c], st);
            b.push_val(id, r, c);
        }
        // reduce, then sometimes broadcast back (classic EffOp shape)
        8 => {
            let kind = if gen.bool() {
                OpKind::ReduceSumRows
            } else {
                OpKind::ReduceMaxRows
            };
            let red = b.g.op(kind, &[src], &[r, 1], st);
            if gen.bool() {
                let bc = b.g.op(OpKind::BroadcastCol, &[red], &[r, c], st);
                let id = b.g.op(OpKind::Mul, &[src, bc], &[r, c], st);
                b.push_val(id, r, c);
            } else {
                b.push_val(red, r, 1);
            }
        }
        // Greater + Select
        9 => {
            let other = b.f32_input(gen, r, c, Fill::Tame);
            let cond = b.g.op(OpKind::Greater, &[src, other], &[r, c], st);
            let id = b.g.op(OpKind::Select, &[cond, src, other], &[r, c], st);
            b.push_val(id, r, c);
        }
        // MaskedMaxPool over a fresh 0/1 mask
        10 => {
            let m = gen.dim(6);
            let mask = b.f32_input(gen, m, r, Fill::Mask);
            let id = b.g.op(OpKind::MaskedMaxPool, &[mask, src], &[m, c], st);
            b.push_val(id, m, c);
        }
        // sentinel-aware neighbor gather
        _ => {
            let w = gen.dim(4);
            let data: Vec<i32> = (0..r * w)
                .map(|_| gen.rng().usize(r + 1) as i32) // r == sentinel
                .collect();
            let idx = b.i32_input(r, w, data);
            let kind = if gen.bool() {
                OpKind::NeighborGatherMax
            } else {
                OpKind::NeighborGatherMean
            };
            let id = b.g.op(kind, &[idx, src], &[r, c], st);
            b.push_val(id, r, c);
        }
    }
}

fn random_graph(gen: &mut Gen, tag: usize) -> (OpGraph, Bindings) {
    let mut b = Builder::new(format!("prop{tag}"));
    let r = gen.dim(9);
    let c = gen.dim(9);
    let x = b.f32_input(gen, r, c, Fill::Tame);
    b.push_val(x, r, c);
    if gen.bool() {
        let r2 = gen.dim(9);
        let c2 = gen.dim(9);
        let y = b.f32_input(gen, r2, c2, Fill::Tame);
        b.push_val(y, r2, c2);
    }
    let steps = gen.usize(3, 11);
    for _ in 0..steps {
        grow(&mut b, gen);
    }
    // output must not be a raw input: cap with a cheap op if needed
    let (mut out, r, c) = *b.vals.last().unwrap();
    if b.g.ops[out].kind == OpKind::Input {
        out = b.g.op(OpKind::Relu, &[out], &[r, c], Stage::Compute);
    }
    b.g.set_output(out);
    (b.g, b.bindings)
}

// ---------------------------------------------------------------------------
// properties
// ---------------------------------------------------------------------------

#[test]
fn random_graphs_match_reference_executor() {
    forall("plan == exec on random graphs", 60, |gen| {
        let tag = gen.usize(0, 1 << 20);
        let (g, bindings) = random_graph(gen, tag);
        g.validate().unwrap();
        let want = exec::execute_mat(&g, &bindings).unwrap();
        let got = run_graph_mat(&g, &bindings).unwrap();
        let diff = want.max_abs_diff(&got);
        assert!(
            diff < 1e-4,
            "graph {} drifted {diff} from the oracle",
            g.name
        );
    });
}

#[test]
fn warm_instances_match_on_repeat_runs() {
    // arena-reuse stress: run every random graph twice on ONE instance —
    // stale slab contents or a bad liveness assignment would surface as
    // drift between run 1 and run 2
    forall("warm plan re-run is stable", 30, |gen| {
        let tag = gen.usize(0, 1 << 20);
        let (g, bindings) = random_graph(gen, tag);
        let plan = Arc::new(ExecPlan::compile(&g).unwrap());
        let threads = if gen.bool() { 1 } else { 3 };
        let mut inst = PlanInstance::new(plan, Arc::new(WorkerPool::new(threads)));
        inst.run(&bindings).unwrap();
        let first = inst.output_mat(0).unwrap();
        inst.run(&bindings).unwrap();
        let second = inst.output_mat(0).unwrap();
        assert_eq!(first, second, "graph {} unstable across runs", g.name);
        let oracle = exec::execute_mat(&g, &bindings).unwrap();
        assert!(oracle.max_abs_diff(&second) < 1e-4);
    });
}

#[test]
fn deep_chain_exercises_arena_reuse() {
    // a long alternating chain forces maximal slab sharing
    let mut b = Builder::new("deep".into());
    let mut gen = Gen::new(grannite::util::Rng::new(77));
    let x = b.f32_input(&mut gen, 12, 7, Fill::Tame);
    b.push_val(x, 12, 7);
    let mut cur = x;
    for i in 0..40 {
        let kind = match i % 4 {
            0 => OpKind::Relu,
            1 => OpKind::AddConst(0.125),
            2 => OpKind::Scale(0.75),
            _ => OpKind::LeakyRelu(0.2),
        };
        cur = b.g.op(kind, &[cur], &[12, 7], Stage::Compute);
    }
    b.g.set_output(cur);
    let plan = ExecPlan::compile(&b.g).unwrap();
    // the whole run materializes almost nothing: one output slab
    assert!(plan.fused_away >= 39, "fused {} of 40", plan.fused_away);
    assert_eq!(plan.slab_elems.len(), 1);
    let want = exec::execute_mat(&b.g, &b.bindings).unwrap();
    let got = run_graph_mat(&b.g, &b.bindings).unwrap();
    assert!(want.max_abs_diff(&got) < 1e-5);
}

// ---------------------------------------------------------------------------
// model-level equivalence (the builders the serving path actually runs)
// ---------------------------------------------------------------------------

fn model_fixture(seed: u64) -> (GnnDims, Bindings) {
    const N: usize = 26;
    const F: usize = 14;
    const H: usize = 8;
    const C: usize = 4;
    let ds = synthesize("plan-eq", N, 3 * N, C, F, seed);
    let graph = ds.graph.clone();
    let dims = GnnDims { n: N, m: graph.num_edges(), f: F, hidden: H, classes: C, k: 5, layers: 2 };
    let mut rng = grannite::util::Rng::new(seed ^ 0xAB);
    let mut rand = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.8 - 0.4) as f32)
    };
    let mut b: Bindings = BTreeMap::new();
    b.insert("x".into(), Tensor::from_mat(&ds.features));
    b.insert("norm".into(), Tensor::from_mat(&graph.norm_adjacency(N)));
    b.insert("adj".into(), Tensor::from_mat(&graph.adjacency(N)));
    b.insert("neg_bias".into(), Tensor::from_mat(&graph.neg_bias(N)));
    b.insert("mask".into(), Tensor::from_mat(&graph.sampled_adjacency(4, 7, N)));
    b.insert("norm_mask".into(), Tensor::from_mat(&graph.sampled_adjacency(4, 7, N)));
    let idx = graph.sampled_neighbors(4, 7);
    let mut idx_data = Vec::new();
    for row in &idx {
        for &j in row {
            idx_data.push(j as i32);
        }
    }
    b.insert("nbr_idx".into(), Tensor::I32 { shape: vec![N, 5], data: idx_data });
    let mut edges = Vec::new();
    for &(s, d) in graph.edges() {
        edges.push(s as i32);
        edges.push(d as i32);
    }
    b.insert(
        "edges".into(),
        Tensor::I32 { shape: vec![graph.num_edges(), 2], data: edges },
    );
    for (name, r, c) in [
        ("w1", F, H),
        ("w2", H, C),
        ("w1_self", F, H),
        ("w1_neigh", F, H),
        ("w2_self", H, C),
        ("w2_neigh", H, C),
    ] {
        b.insert(name.into(), Tensor::from_mat(&rand(r, c)));
    }
    for (name, c) in [("b1", H), ("b2", C)] {
        b.insert(name.into(), Tensor::from_mat(&rand(1, c)));
    }
    for (name, r) in [("a1_src", H), ("a1_dst", H), ("a2_src", C), ("a2_dst", C)] {
        b.insert(name.into(), Tensor::from_mat(&rand(r, 1)));
    }
    // integral QuantGr weights
    let mut qrng = grannite::util::Rng::new(seed ^ 0x5151);
    let mut qrand = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (qrng.usize(255) as i32 - 127) as f32)
    };
    b.insert("w1q".into(), Tensor::from_mat(&qrand(F, H)));
    b.insert("w2q".into(), Tensor::from_mat(&qrand(H, C)));
    (dims, b)
}

#[test]
fn every_model_variant_matches_reference() {
    forall("plan == exec on model builders", 6, |gen| {
        let seed = gen.usize(0, 1 << 30) as u64;
        let (dims, bindings) = model_fixture(seed);
        for (m, v) in [
            ("gcn", "baseline"),
            ("gcn", "stagr"),
            ("gcn", "quant"),
            ("gat", "effop"),
            ("gat", "grax"),
            ("sage_mean", "stagr"),
            ("sage_max", "baseline"),
            ("sage_max", "grax3"),
        ] {
            let g = build::build(m, v, dims).unwrap();
            let want = exec::execute_mat(&g, &bindings).unwrap();
            let got = run_graph_mat(&g, &bindings).unwrap();
            let diff = want.max_abs_diff(&got);
            assert!(diff < 1e-4, "{m}/{v} drifted {diff}");
        }
    });
}

#[test]
fn gat_baseline_masked_matches_reference() {
    let (dims, bindings) = model_fixture(5);
    let g = build::gat(dims, GatVariant::BaselineMasked);
    let want = exec::execute_mat(&g, &bindings).unwrap();
    let got = run_graph_mat(&g, &bindings).unwrap();
    assert!(want.max_abs_diff(&got) < 1e-4);
}

#[test]
fn quant_scales_roundtrip_through_plan() {
    // calibrated (non-default) scales flow through the planned INT8 path
    let (dims, bindings) = model_fixture(9);
    let s = QuantScales { act1: 0.02, w1: 0.004, act2: 0.07, w2: 0.012 };
    let g = build::gcn_quant(dims, s);
    let want = exec::execute_mat(&g, &bindings).unwrap();
    let got = run_graph_mat(&g, &bindings).unwrap();
    assert!(want.max_abs_diff(&got) < 1e-4);
}
