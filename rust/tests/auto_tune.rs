//! The self-tuning surface end to end: the runtime-adaptive `auto`
//! engine crosses its hysteresis band under a churn-burst stream
//! without changing answers (and reports the switch through the exact
//! merged metrics), and `Deployment::autotune` returns a winner that
//! launches and answers identically to the same spec written by hand.

use grannite::graph::datasets::{synthesize, Dataset};
use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
use grannite::serve::{
    DataSource, Deployment, DeploymentSpec, EngineSpec, Serving, Topology,
};
use grannite::server::Update;

fn twin() -> Dataset {
    synthesize("auto-serve", 40, 90, 4, 12, 7)
}

/// An `auto`-engine spec with a tight hysteresis band and a short
/// cooldown, so the burst phase of the script below forces at least one
/// strategy switch within the script's length.
fn auto_spec(shards: usize) -> DeploymentSpec {
    let mut s = DeploymentSpec {
        engine: EngineSpec::named("auto"),
        topology: Topology::homogeneous(shards),
        capacity: 48,
        ..DeploymentSpec::default()
    };
    s.tuning.hysteresis_low = 1.0;
    s.tuning.hysteresis_high = 4.0;
    s.tuning.cooldown_rounds = 2;
    s
}

/// Quiet phase (exactly 1 mutation per query — the churn EWMA settles
/// below the low threshold) followed by a burst phase (24 mutations,
/// then 2 queries, per cycle — the EWMA jumps past the high threshold).
/// Deterministic for the fixed seeds; the stream capacities stay below
/// the spec capacity (48) so `AddNode` events from both phases fit.
fn churn_burst_script() -> Vec<GraphEvent> {
    let mut events: Vec<GraphEvent> =
        KnowledgeGraphStream::with_churn(40, 44, 1.0, 9).take(24).collect();
    events.extend(
        KnowledgeGraphStream::with_churn(40, 44, 12.0, 33)
            .with_burst(2)
            .take(40),
    );
    events
}

/// Replay the script against a deployment, answering each `Query` event
/// at a deterministic node id; returns the `(node, prediction)` log.
fn replay(serving: &dyn Serving, script: &[GraphEvent]) -> Vec<(usize, i32)> {
    let mut preds = Vec::new();
    let mut q = 0usize;
    for ev in script {
        match ev {
            GraphEvent::AddEdge(u, v) => {
                serving.update(Update::AddEdge(*u, *v)).unwrap()
            }
            GraphEvent::RemoveEdge(u, v) => {
                serving.update(Update::RemoveEdge(*u, *v)).unwrap()
            }
            GraphEvent::AddNode => serving.update(Update::AddNode).unwrap(),
            GraphEvent::Query => {
                let node = (q * 7) % 40;
                q += 1;
                preds.push((node, serving.query_wait(Some(node)).unwrap().prediction));
            }
        }
    }
    preds
}

#[test]
fn auto_engine_switches_under_burst_without_changing_answers() {
    let ds = twin();
    let script = churn_burst_script();

    // reference: the static plan engine over the same script
    let plan_spec = DeploymentSpec {
        engine: EngineSpec::named("plan"),
        capacity: 48,
        ..DeploymentSpec::default()
    };
    let reference = {
        let serving =
            Deployment::launch(&plan_spec, &DataSource::Dataset(ds.clone())).unwrap();
        let preds = replay(serving.as_ref(), &script);
        serving.shutdown().unwrap();
        preds
    };
    assert!(!reference.is_empty(), "script produced no queries");

    let serving =
        Deployment::launch(&auto_spec(1), &DataSource::Dataset(ds.clone())).unwrap();
    let preds = replay(serving.as_ref(), &script);
    assert_eq!(
        preds, reference,
        "the auto engine changed answers while switching strategies"
    );

    // the switch is observable through the exact merged metrics: at
    // least one incremental→plan transition when the burst lands, and
    // the burst tail leaves the plan strategy active
    let snap = serving.metrics();
    assert!(
        snap.engine_switches >= 1,
        "no strategy switch recorded under the burst: {snap:?}"
    );
    assert_eq!(
        snap.active_strategy.as_deref(),
        Some("plan"),
        "burst tail should leave the planned strategy active"
    );
    serving.shutdown().unwrap();
}

#[test]
fn auto_fleet_switches_and_matches_the_plan_reference() {
    let ds = twin();
    let script = churn_burst_script();

    let plan_spec = DeploymentSpec {
        engine: EngineSpec::named("plan"),
        capacity: 48,
        ..DeploymentSpec::default()
    };
    let reference = {
        let serving =
            Deployment::launch(&plan_spec, &DataSource::Dataset(ds.clone())).unwrap();
        let preds = replay(serving.as_ref(), &script);
        serving.shutdown().unwrap();
        preds
    };

    let serving =
        Deployment::launch(&auto_spec(2), &DataSource::Dataset(ds.clone())).unwrap();
    assert_eq!(serving.num_shards(), 2);
    let preds = replay(serving.as_ref(), &script);
    assert_eq!(
        preds, reference,
        "the 2-shard auto fleet diverged from the plan reference"
    );

    let snap = serving.metrics();
    assert!(
        snap.engine_switches >= 1,
        "no shard switched strategy under the burst: {snap:?}"
    );
    // shards see different query/churn interleavings, so the fleet-wide
    // gauge may be a single strategy or "mixed" — but never absent
    assert!(
        snap.active_strategy.is_some(),
        "adaptive engine must report an active strategy: {snap:?}"
    );
    // per-shard gauges merge exactly: the deployment-wide switch count
    // is the sum of the shard counts
    let per: usize = serving
        .shard_metrics()
        .iter()
        .map(|s| s.engine_switches)
        .sum();
    assert_eq!(per, snap.engine_switches, "shard sum vs merged snapshot");
    serving.shutdown().unwrap();
}

#[test]
fn autotune_winner_launches_and_matches_the_hand_written_equivalent() {
    let ds = synthesize("auto-tune-accept", 40, 90, 4, 12, 11);
    let data = DataSource::Dataset(ds.clone());
    let mut base = DeploymentSpec { capacity: 48, ..DeploymentSpec::default() };
    base.tuning.probe_budget = 6;
    base.tuning.top_k = 1;

    let tuned = Deployment::autotune(&base, &data).unwrap();
    assert!(
        !tuned.report.rows.is_empty(),
        "tuning report lists no candidates"
    );
    assert!(
        tuned.report.rows[0].observed.is_some(),
        "the winner must have been confirmed by a live probe"
    );
    let rendered = tuned.report.render();
    assert!(rendered.contains("objective: latency"), "{rendered}");

    // "a user copying the winning spec by hand" is the TOML round trip:
    // the emitted spec parses back to exactly the tuned value
    let hand_written = DeploymentSpec::parse_toml(&tuned.spec.to_toml()).unwrap();
    assert_eq!(hand_written, tuned.spec);

    let script = churn_burst_script();
    let a = tuned.launch(&data).unwrap();
    let b = Deployment::launch(&hand_written, &DataSource::Dataset(ds.clone())).unwrap();
    let pa = replay(a.as_ref(), &script);
    let pb = replay(b.as_ref(), &script);
    assert_eq!(
        pa, pb,
        "autotuned winner must answer exactly like its hand-written twin"
    );
    a.shutdown().unwrap();
    b.shutdown().unwrap();
}
