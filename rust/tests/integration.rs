//! Integration tests over the full stack: AOT artifacts → PJRT execution
//! → coordinator state → serving. All tests skip gracefully when
//! `make artifacts` has not run (CI bootstrap), and exercise the real
//! thing when it has.

use std::path::Path;

use grannite::coordinator::Coordinator;
use grannite::graph::datasets::Dataset;
use grannite::serve::{DataSource, Deployment, DeploymentSpec, EngineSpec, Serving};
use grannite::server::Update;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.toml").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[test]
fn dataset_twin_statistics_match_paper() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load_gnnt(dir, "cora").unwrap();
    assert_eq!(ds.num_nodes(), 2708);
    assert_eq!(ds.graph.num_edges(), 5429);
    assert_eq!(ds.num_features(), 1433);
    assert_eq!(ds.num_classes(), 7);
    let ds = Dataset::load_gnnt(dir, "citeseer").unwrap();
    assert_eq!(ds.num_nodes(), 3327);
    assert_eq!(ds.num_features(), 3703);
}

#[test]
fn gcn_stagr_reaches_trained_accuracy() {
    let Some(dir) = artifacts() else { return };
    let mut c = Coordinator::open(dir, "cora").unwrap();
    let trained = c.state.trained_accuracy("gcn").unwrap() as f64;
    let acc = c.evaluate("gcn_stagr_cora").unwrap();
    // rust CPU preprocessing + PJRT must reproduce the python-side result
    assert!(
        (acc - trained).abs() < 0.01,
        "PJRT accuracy {acc:.3} vs training-time {trained:.3}"
    );
    assert!(acc > 0.70, "cora GCN should be in the paper's band: {acc}");
}

#[test]
fn grad_padded_artifact_matches_unpadded() {
    let Some(dir) = artifacts() else { return };
    let mut c = Coordinator::open(dir, "cora").unwrap();
    let a = c.evaluate("gcn_stagr_cora").unwrap();
    let b = c.evaluate("gcn_grad_cora").unwrap(); // NodePad capacity 3000
    assert!(
        (a - b).abs() < 0.005,
        "NodePad must not change real-node predictions: {a:.3} vs {b:.3}"
    );
}

#[test]
fn quantgr_negligible_quality_loss() {
    let Some(dir) = artifacts() else { return };
    let mut c = Coordinator::open(dir, "cora").unwrap();
    let fp = c.evaluate("gcn_stagr_cora").unwrap();
    let q = c.evaluate("gcn_quant_cora").unwrap();
    // paper: INT8 with "negligible quality loss"
    assert!(fp - q < 0.02, "quant dropped too much: {fp:.3} → {q:.3}");
}

#[test]
fn gat_variants_agree_with_each_other() {
    let Some(dir) = artifacts() else { return };
    let mut c = Coordinator::open(dir, "cora").unwrap();
    let base = c.evaluate("gat_baseline_cora").unwrap();
    let eff = c.evaluate("gat_effop_cora").unwrap();
    let grax = c.evaluate("gat_grax_cora").unwrap();
    assert!((base - eff).abs() < 0.005, "EffOp is exact: {base} vs {eff}");
    assert!((base - grax).abs() < 0.02, "GrAx1+2 negligible: {base} vs {grax}");
}

#[test]
fn sage_grax3_negligible_loss() {
    let Some(dir) = artifacts() else { return };
    let mut c = Coordinator::open(dir, "cora").unwrap();
    let base = c.evaluate("sage_max_baseline_cora").unwrap();
    let grax = c.evaluate("sage_max_grax3_cora").unwrap();
    assert!((base - grax).abs() < 0.03, "GrAx3: {base} vs {grax}");
}

#[test]
fn sage_mean_works() {
    let Some(dir) = artifacts() else { return };
    let mut c = Coordinator::open(dir, "cora").unwrap();
    let acc = c.evaluate("sage_mean_cora").unwrap();
    assert!(acc > 0.5, "sage_mean accuracy {acc}");
}

#[test]
fn grad_updates_change_predictions_without_recompile() {
    let Some(dir) = artifacts() else { return };
    let mut c = Coordinator::open(dir, "cora").unwrap();
    let before = c.infer("gcn_grad_cora").unwrap();
    // densely rewire node 0's neighborhood
    for v in 100..140 {
        c.state.add_edge(0, v).unwrap();
    }
    let t0 = std::time::Instant::now();
    let after = c.infer("gcn_grad_cora").unwrap();
    let us = t0.elapsed().as_secs_f64() * 1e6;
    assert!(before.max_abs_diff(&after) > 1e-6, "graph change must matter");
    // "no recompile": the warm re-inference is fast (well under a second)
    assert!(us < 5_000_000.0, "re-inference took {us} µs");
}

#[test]
fn citeseer_artifacts_execute() {
    let Some(dir) = artifacts() else { return };
    let mut c = Coordinator::open(dir, "citeseer").unwrap();
    let acc = c.evaluate("gcn_stagr_citeseer").unwrap();
    assert!(acc > 0.6, "citeseer GCN {acc}");
}

#[test]
fn serving_stack_end_to_end() {
    let Some(dir) = artifacts() else { return };
    // the production path: a coordinator deployment (single leader)
    // launched from a spec through the unified front door
    let spec = DeploymentSpec {
        engine: EngineSpec::named("coordinator"),
        capacity: 3000,
        ..DeploymentSpec::default()
    };
    let data = DataSource::Artifacts { dir: dir.to_path_buf(), dataset: "cora".into() };
    let server = Deployment::launch(&spec, &data).unwrap();
    // interleave updates and queries
    server.update(Update::AddEdge(1, 2000)).unwrap();
    let r1 = server.query_wait(Some(5)).unwrap();
    assert!(r1.prediction >= 0);
    server.update(Update::AddNode).unwrap();
    let r2 = server.query_wait(Some(2708)).unwrap(); // the new node
    assert!(r2.prediction >= 0);
    let snap = server.metrics();
    assert_eq!(snap.queries, 2);
    assert_eq!(snap.mask_updates, 2);
    server.shutdown().unwrap();
}

#[test]
fn executor_matches_pjrt_numerics() {
    // the rust reference executor and the PJRT artifact must agree on
    // the same weights + masks (three implementations, one answer)
    let Some(dir) = artifacts() else { return };
    use grannite::ops::build::{gcn_stagr, GnnDims};
    use grannite::ops::exec;
    let mut c = Coordinator::open(dir, "cora").unwrap();
    let pjrt = c.infer("gcn_stagr_cora").unwrap();

    let ds = &c.state.dataset;
    let dims = GnnDims::model(ds.num_nodes(), ds.graph.num_edges(),
                              ds.num_features(), ds.num_classes());
    let g = gcn_stagr(dims, "stagr");
    let mut bindings = exec::Bindings::new();
    let info = c.runtime.artifact("gcn_stagr_cora").unwrap().clone();
    for (i, name) in info.inputs.iter().enumerate() {
        let t = c.state.bindings_for(&info).unwrap()[i].clone();
        // executor wants biases as (1, n)
        let t = match &t {
            grannite::tensor::Tensor::F32 { shape, data } if shape.len() == 1 => {
                grannite::tensor::Tensor::F32 {
                    shape: vec![1, shape[0]],
                    data: data.clone(),
                }
            }
            other => other.clone(),
        };
        bindings.insert(name.clone(), t);
    }
    let ours = exec::execute_mat(&g, &bindings).unwrap();
    let diff = ours.max_abs_diff(&pjrt);
    assert!(diff < 2e-3, "executor vs PJRT drift {diff}");
}
