//! Graph substrate: storage, the paper's CPU-side preprocessing
//! techniques, datasets, and dynamic-graph streams.
//!
//! Everything here is "the CPU half of GraphSplit": the control-heavy,
//! irregular work (edge bookkeeping, degree math, normalization, padding,
//! mask regeneration) that the paper deliberately keeps off the NPU.

pub mod csr;
pub mod datasets;
pub mod dynamic;
pub mod sparsity;
pub mod stream;
pub mod symg;

use crate::tensor::Mat;

pub use csr::Csr;
pub use datasets::Dataset;
pub use dynamic::DynamicGraph;
pub use symg::SymG;

/// An undirected graph: canonical edge list (src < dst, deduped) over `n`
/// nodes. The shared core of datasets, dynamic graphs and streams.
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    num_nodes: usize,
    edges: Vec<(u32, u32)>,
}

impl Graph {
    /// Build from an arbitrary edge list: self loops dropped, duplicates
    /// merged, endpoints canonicalized to (min, max).
    pub fn new(num_nodes: usize, raw_edges: &[(u32, u32)]) -> Graph {
        let mut edges: Vec<(u32, u32)> = raw_edges
            .iter()
            .filter(|(s, d)| s != d)
            .map(|&(s, d)| (s.min(d), s.max(d)))
            .collect();
        for &(s, d) in &edges {
            assert!(
                (d as usize) < num_nodes,
                "edge ({s},{d}) out of range for n={num_nodes}"
            );
        }
        edges.sort_unstable();
        edges.dedup();
        Graph { num_nodes, edges }
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn edges(&self) -> &[(u32, u32)] {
        &self.edges
    }

    /// Node degrees including the self loop (as GraphConv counts them).
    pub fn degrees_with_self(&self) -> Vec<f32> {
        let mut deg = vec![1.0f32; self.num_nodes];
        for &(s, d) in &self.edges {
            deg[s as usize] += 1.0;
            deg[d as usize] += 1.0;
        }
        deg
    }

    /// Adjacency lists (undirected, no self entry), sorted.
    pub fn neighbor_lists(&self) -> Vec<Vec<u32>> {
        let mut nbrs = vec![Vec::new(); self.num_nodes];
        for &(s, d) in &self.edges {
            nbrs[s as usize].push(d);
            nbrs[d as usize].push(s);
        }
        for l in &mut nbrs {
            l.sort_unstable();
        }
        nbrs
    }

    // ------------------------------------------------------------------
    // Dense derived matrices — the precomputed masks of StaGr/PreG/GrAx1.
    // All accept a NodePad capacity: rows/cols ≥ num_nodes are zero
    // (padded nodes get no self loop — they must stay disconnected).
    // ------------------------------------------------------------------

    /// Dense symmetric adjacency with self loops, A + I (paper Fig. 9).
    pub fn adjacency(&self, capacity: usize) -> Mat {
        let n = self.num_nodes;
        assert!(capacity >= n, "NodePad capacity {capacity} < n {n}");
        let mut a = Mat::zeros(capacity, capacity);
        for &(s, d) in &self.edges {
            a[(s as usize, d as usize)] = 1.0;
            a[(d as usize, s as usize)] = 1.0;
        }
        for i in 0..n {
            a[(i, i)] = 1.0;
        }
        a
    }

    /// PreG: the precomputed GraphConv normalization matrix
    /// `D^{-1/2} (A + I) D^{-1/2}` (paper Fig. 14). Built directly from
    /// the edge list — O(n + m) work instead of an n² matrix pipeline —
    /// and identical (same f32 operations) to the python twin's
    /// `norm_adjacency`, so PJRT artifacts see byte-equivalent masks.
    pub fn norm_adjacency(&self, capacity: usize) -> Mat {
        let n = self.num_nodes;
        assert!(capacity >= n, "NodePad capacity {capacity} < n {n}");
        let deg = self.degrees_with_self();
        let inv_sqrt: Vec<f32> =
            deg.iter().map(|&d| 1.0 / d.sqrt()).collect();
        let mut out = Mat::zeros(capacity, capacity);
        for &(s, d) in &self.edges {
            let (s, d) = (s as usize, d as usize);
            let v = inv_sqrt[s] * inv_sqrt[d];
            out[(s, d)] = v;
            out[(d, s)] = v;
        }
        for i in 0..n {
            out[(i, i)] = inv_sqrt[i] * inv_sqrt[i];
        }
        out
    }

    /// PreG norm as a first-class sparse operand: the same
    /// `D^{-1/2} (A + I) D^{-1/2}` values as [`Graph::norm_adjacency`]
    /// (bitwise — both compute `inv_sqrt[s] * inv_sqrt[d]`), stored CSR
    /// so the SpMM aggregation path costs O(nnz·d) instead of O(n²·d).
    /// Rows ≥ `num_nodes` are empty (NodePad rows stay disconnected).
    pub fn norm_csr(&self, capacity: usize) -> crate::tensor::CsrMat {
        let n = self.num_nodes;
        assert!(capacity >= n, "NodePad capacity {capacity} < n {n}");
        let deg = self.degrees_with_self();
        let inv_sqrt: Vec<f32> = deg.iter().map(|&d| 1.0 / d.sqrt()).collect();
        let nbrs = self.neighbor_lists();
        let mut indptr = Vec::with_capacity(capacity + 1);
        let mut indices = Vec::with_capacity(2 * self.edges.len() + n);
        let mut values = Vec::with_capacity(2 * self.edges.len() + n);
        indptr.push(0u32);
        for i in 0..n {
            // merge the sorted neighbor list with the diagonal entry
            let mut self_done = false;
            for &j in &nbrs[i] {
                if !self_done && (j as usize) > i {
                    indices.push(i as u32);
                    values.push(inv_sqrt[i] * inv_sqrt[i]);
                    self_done = true;
                }
                indices.push(j);
                values.push(inv_sqrt[i] * inv_sqrt[j as usize]);
            }
            if !self_done {
                indices.push(i as u32);
                values.push(inv_sqrt[i] * inv_sqrt[i]);
            }
            indptr.push(indices.len() as u32);
        }
        for _ in n..capacity {
            indptr.push(indices.len() as u32);
        }
        crate::tensor::CsrMat {
            rows: capacity,
            cols: capacity,
            indptr,
            indices,
            values,
        }
    }

    /// GrAx1: the additive attention mask `(1 - (A+I)) * (-1e9)`
    /// (paper Fig. 16). Padded columns keep the large negative bias so
    /// phantom nodes never attract attention mass; padded *rows* are
    /// zero at their diagonal (softmax stays finite) and sliced away.
    pub fn neg_bias(&self, capacity: usize) -> Mat {
        let n = self.num_nodes;
        let adj = self.adjacency(capacity);
        let mut out = Mat::filled(capacity, capacity, crate::ops::NEG_MASK);
        for i in 0..capacity {
            for j in 0..capacity {
                if adj[(i, j)] > 0.0 {
                    out[(i, j)] = 0.0;
                }
            }
        }
        for i in n..capacity {
            out[(i, i)] = 0.0;
        }
        out
    }

    /// GraphSAGE sampled neighborhood as a gather-index matrix:
    /// (n, k+1) with column 0 = self and sentinel `n` for unused slots.
    /// Deterministic per seed (mirrors `datasets.sampled_neighbors`).
    pub fn sampled_neighbors(&self, max_neighbors: usize, seed: u64) -> Vec<Vec<u32>> {
        let n = self.num_nodes;
        let mut rng = crate::util::Rng::new(seed);
        let nbrs = self.neighbor_lists();
        let mut idx = vec![vec![n as u32; max_neighbors + 1]; n];
        for (i, row) in idx.iter_mut().enumerate() {
            row[0] = i as u32;
            let candidates = &nbrs[i];
            if candidates.len() <= max_neighbors {
                row[1..1 + candidates.len()].copy_from_slice(candidates);
            } else {
                let picks = rng.sample_indices(candidates.len(), max_neighbors);
                for (slot, &p) in picks.iter().enumerate() {
                    row[1 + slot] = candidates[p];
                }
            }
        }
        idx
    }

    /// Dense 0/1 mask of the sampled neighborhood (for the dense GrAx3
    /// mapping and the simulator's operand sizing).
    pub fn sampled_adjacency(&self, max_neighbors: usize, seed: u64,
                             capacity: usize) -> Mat {
        let n = self.num_nodes;
        assert!(capacity >= n);
        let idx = self.sampled_neighbors(max_neighbors, seed);
        let mut mask = Mat::zeros(capacity, capacity);
        for (i, row) in idx.iter().enumerate() {
            for &j in row {
                if (j as usize) < n {
                    mask[(i, j as usize)] = 1.0;
                }
            }
        }
        mask
    }
}

/// NodePad: zero-pad a feature matrix to `capacity` rows (paper Fig. 11).
pub fn pad_features(x: &Mat, capacity: usize) -> Mat {
    assert!(capacity >= x.rows, "NodePad capacity {} < rows {}", capacity, x.rows);
    let mut out = Mat::zeros(capacity, x.cols);
    out.data[..x.rows * x.cols].copy_from_slice(&x.data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Graph {
        // 0 - 1 - 2
        Graph::new(3, &[(0, 1), (1, 2)])
    }

    #[test]
    fn canonicalizes_edges() {
        let g = Graph::new(4, &[(2, 1), (1, 2), (3, 3), (0, 1)]);
        assert_eq!(g.edges(), &[(0, 1), (1, 2)]); // dedup + drop self + sort
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range() {
        Graph::new(2, &[(0, 5)]);
    }

    #[test]
    fn degrees_include_self() {
        let g = path3();
        assert_eq!(g.degrees_with_self(), vec![2.0, 3.0, 2.0]);
    }

    #[test]
    fn adjacency_symmetric_self_looped() {
        let g = path3();
        let a = g.adjacency(3);
        assert_eq!(a[(0, 1)], 1.0);
        assert_eq!(a[(1, 0)], 1.0);
        assert_eq!(a[(0, 0)], 1.0);
        assert_eq!(a[(0, 2)], 0.0);
    }

    #[test]
    fn norm_matches_hand_computation() {
        let g = path3();
        let norm = g.norm_adjacency(3);
        // deg = [2, 3, 2]; norm[0][1] = 1/sqrt(2*3)
        let want = 1.0 / (6.0f32).sqrt();
        assert!((norm[(0, 1)] - want).abs() < 1e-6);
        assert!((norm[(0, 0)] - 0.5).abs() < 1e-6);
        // symmetric
        assert_eq!(norm[(0, 1)], norm[(1, 0)]);
    }

    #[test]
    fn norm_equals_matrix_formula() {
        // D^{-1/2}(A+I)D^{-1/2} computed densely must match the O(m) build.
        let g = Graph::new(5, &[(0, 1), (0, 2), (1, 3), (2, 4), (3, 4)]);
        let a = g.adjacency(5);
        let deg = g.degrees_with_self();
        let dense = Mat::from_fn(5, 5, |i, j| {
            a[(i, j)] / (deg[i].sqrt() * deg[j].sqrt())
        });
        assert!(g.norm_adjacency(5).max_abs_diff(&dense) < 1e-6);
    }

    #[test]
    fn norm_csr_equals_dense_norm_bitwise() {
        let g = Graph::new(7, &[(0, 1), (0, 6), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (1, 4)]);
        for cap in [7usize, 10] {
            let dense = g.norm_adjacency(cap);
            let csr = g.norm_csr(cap);
            assert_eq!(csr.rows, cap);
            assert_eq!(csr.to_dense(), dense, "cap {cap}");
            // entries are exactly the dense non-zeros (diagonal included)
            assert_eq!(
                csr.nnz(),
                dense.data.iter().filter(|&&v| v != 0.0).count()
            );
        }
        // isolated node keeps only its self loop
        let iso = Graph::new(3, &[(0, 1)]);
        let csr = iso.norm_csr(4);
        assert_eq!(csr.row_entries(2).0, &[2]);
        assert_eq!(csr.row_entries(2).1, &[1.0]);
        assert!(csr.row_entries(3).0.is_empty(), "padded row stays empty");
    }

    #[test]
    fn nodepad_rows_disconnected() {
        let g = path3();
        let a = g.adjacency(5);
        let norm = g.norm_adjacency(5);
        for j in 0..5 {
            assert_eq!(a[(3, j)], 0.0);
            assert_eq!(a[(4, j)], 0.0);
            assert_eq!(norm[(3, j)], 0.0);
        }
        // no phantom self loops
        assert_eq!(a[(4, 4)], 0.0);
    }

    #[test]
    #[should_panic(expected = "NodePad capacity")]
    fn capacity_below_n_panics() {
        path3().adjacency(2);
    }

    #[test]
    fn neg_bias_masks_non_edges() {
        let g = path3();
        let nb = g.neg_bias(4);
        assert_eq!(nb[(0, 1)], 0.0); // edge
        assert_eq!(nb[(0, 0)], 0.0); // self loop
        assert_eq!(nb[(0, 2)], crate::ops::NEG_MASK); // non-edge
        assert_eq!(nb[(0, 3)], crate::ops::NEG_MASK); // phantom column
        assert_eq!(nb[(3, 3)], 0.0); // phantom diagonal keeps softmax finite
    }

    #[test]
    fn sampled_neighbors_deterministic_and_capped() {
        let g = Graph::new(6, &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (1, 2)]);
        let a = g.sampled_neighbors(3, 9);
        let b = g.sampled_neighbors(3, 9);
        assert_eq!(a, b);
        assert_eq!(a[0][0], 0); // self first
        let valid = a[0].iter().filter(|&&j| (j as usize) < 6).count();
        assert_eq!(valid, 4); // self + 3 sampled (node 0 has 5 neighbors)
        for &j in &a[0][1..] {
            if (j as usize) < 6 {
                assert!(g.neighbor_lists()[0].contains(&j));
            }
        }
    }

    #[test]
    fn sampled_adjacency_matches_indices() {
        let g = Graph::new(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let idx = g.sampled_neighbors(2, 3);
        let mask = g.sampled_adjacency(2, 3, 5);
        for (i, row) in idx.iter().enumerate() {
            let mut want = vec![0.0f32; 5];
            for &j in row {
                if (j as usize) < 5 {
                    want[j as usize] = 1.0;
                }
            }
            assert_eq!(mask.row(i), &want[..], "row {i}");
        }
    }

    #[test]
    fn pad_features_zero_tail() {
        let x = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f32 + 1.0);
        let p = pad_features(&x, 4);
        assert_eq!(p.row(0), x.row(0));
        assert_eq!(p.row(1), x.row(1));
        assert_eq!(p.row(2), &[0.0; 3]);
        assert_eq!(p.row(3), &[0.0; 3]);
    }
}
