//! Dynamic-graph event streams — the workloads of the paper's motivating
//! applications (Fig. 1): on-device knowledge-graph churn (RAG assistants)
//! and event-based vision sliding windows.
//!
//! A stream yields [`GraphEvent`]s that the server applies through GrAd;
//! the generators are deterministic per seed so serving benchmarks are
//! reproducible.

use crate::util::Rng;

/// One structural update + an inference trigger policy.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphEvent {
    AddEdge(usize, usize),
    RemoveEdge(usize, usize),
    AddNode,
    /// Run inference over the current graph (a query arrival).
    Query,
}

/// Deterministic events-per-query schedule (the `churn` knob): exactly
/// `churn` mutations per query on average, accumulated with fractional
/// debt so e.g. `churn = 0.5` alternates 0 and 1 mutations per query,
/// and grouped `burst` queries at a time so benchmarks can sweep both
/// steady low-churn and bursty high-churn regimes reproducibly.
#[derive(Debug, Clone)]
struct ChurnSchedule {
    /// Mutations per query.
    churn: f64,
    /// Queries per cycle (mutations arrive in one burst before them).
    burst: usize,
    /// Fractional mutation debt carried between cycles.
    debt: f64,
    pending_mutations: usize,
    pending_queries: usize,
}

/// Knowledge-graph churn: entities join over time, facts (edges) are
/// added with preferential attachment and occasionally retracted; queries
/// arrive between update bursts (paper Fig. 10's "on-device knowledge
/// graph" example).
pub struct KnowledgeGraphStream {
    rng: Rng,
    num_nodes: usize,
    capacity: usize,
    /// Live edges (for retractions). Kept small by sampling.
    live_edges: Vec<(usize, usize)>,
    /// Degree-proportional sampling pool (preferential attachment).
    endpoint_pool: Vec<usize>,
    query_ratio: f64,
    /// Deterministic mutations-per-query schedule; `None` keeps the
    /// legacy probabilistic mix driven by `query_ratio`.
    schedule: Option<ChurnSchedule>,
}

impl KnowledgeGraphStream {
    pub fn new(initial_nodes: usize, capacity: usize, query_ratio: f64,
               seed: u64) -> Self {
        assert!(initial_nodes >= 2 && capacity >= initial_nodes);
        KnowledgeGraphStream {
            rng: Rng::new(seed),
            num_nodes: initial_nodes,
            capacity,
            live_edges: Vec::new(),
            endpoint_pool: (0..initial_nodes).collect(),
            query_ratio: query_ratio.clamp(0.0, 1.0),
            schedule: None,
        }
    }

    /// A stream with a deterministic `churn` (mutations per query): each
    /// cycle emits `round(churn)` mutations (fractional debt carried)
    /// followed by one query. Mutation *kinds* still come from the
    /// seeded RNG, so the stream stays reproducible end to end.
    pub fn with_churn(initial_nodes: usize, capacity: usize, churn: f64,
                      seed: u64) -> Self {
        assert!(churn >= 0.0, "churn is a mutations-per-query ratio");
        let mut s = KnowledgeGraphStream::new(initial_nodes, capacity, 0.0, seed);
        s.schedule = Some(ChurnSchedule {
            churn,
            burst: 1,
            debt: 0.0,
            pending_mutations: 0,
            pending_queries: 0,
        });
        s
    }

    /// Burst mode for a churn-scheduled stream: mutations for `burst`
    /// queries arrive as one block, then the `burst` queries — the
    /// event-vision regime (bulk window slide, then inference) at a
    /// controllable rate.
    pub fn with_burst(mut self, burst: usize) -> Self {
        let s = self
            .schedule
            .as_mut()
            .expect("with_burst needs a churn schedule (use with_churn)");
        s.burst = burst.max(1);
        self
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    /// One structural mutation (never a query), advancing the generator
    /// state exactly like the legacy probabilistic path.
    fn mutation(&mut self) -> GraphEvent {
        let roll = self.rng.f64();
        if roll < 0.08 && self.num_nodes < self.capacity {
            // new entity
            let id = self.num_nodes;
            self.num_nodes += 1;
            self.endpoint_pool.push(id);
            return GraphEvent::AddNode;
        }
        if roll < 0.18 && !self.live_edges.is_empty() {
            // fact retraction
            let k = self.rng.usize(self.live_edges.len());
            let (u, v) = self.live_edges.swap_remove(k);
            return GraphEvent::RemoveEdge(u, v);
        }
        // new fact with preferential attachment
        let u = self.endpoint_pool[self.rng.usize(self.endpoint_pool.len())];
        let mut v = self.rng.usize(self.num_nodes);
        if v == u {
            v = (v + 1) % self.num_nodes;
        }
        self.endpoint_pool.push(u); // reinforce degree
        self.endpoint_pool.push(v);
        if self.endpoint_pool.len() > 4096 {
            // bound the pool; forget old mass uniformly
            let drop = self.rng.usize(self.endpoint_pool.len());
            self.endpoint_pool.swap_remove(drop);
        }
        self.live_edges.push((u, v));
        if self.live_edges.len() > 8192 {
            self.live_edges.swap_remove(0);
        }
        GraphEvent::AddEdge(u, v)
    }
}

impl Iterator for KnowledgeGraphStream {
    type Item = GraphEvent;

    fn next(&mut self) -> Option<GraphEvent> {
        if let Some(mut s) = self.schedule.take() {
            // deterministic schedule: a burst of mutations, then queries
            if s.pending_mutations == 0 && s.pending_queries == 0 {
                s.debt += s.churn * s.burst as f64;
                s.pending_mutations = s.debt.floor() as usize;
                s.debt -= s.pending_mutations as f64;
                s.pending_queries = s.burst;
            }
            let ev = if s.pending_mutations > 0 {
                s.pending_mutations -= 1;
                self.mutation()
            } else {
                s.pending_queries -= 1;
                GraphEvent::Query
            };
            self.schedule = Some(s);
            return Some(ev);
        }
        if self.rng.chance(self.query_ratio) {
            return Some(GraphEvent::Query);
        }
        Some(self.mutation())
    }
}

/// Event-camera sliding-window stream: each "frame" replaces a slice of
/// the event nodes with fresh ones connected by spatiotemporal proximity
/// (AEGNN-style). Produces bursts of updates followed by a query — the
/// high-rate regime GrAd's no-recompile property exists for.
pub struct EventVisionStream {
    rng: Rng,
    num_nodes: usize,
    /// how many nodes each new frame replaces
    churn: usize,
    /// spatial positions of live events (for locality-based wiring)
    pos: Vec<(f64, f64)>,
    next_replace: usize,
    pending: Vec<GraphEvent>,
}

impl EventVisionStream {
    pub fn new(num_nodes: usize, churn: usize, seed: u64) -> Self {
        assert!(churn <= num_nodes && num_nodes > 4);
        let mut rng = Rng::new(seed);
        let pos = (0..num_nodes)
            .map(|_| (rng.f64(), rng.f64()))
            .collect();
        EventVisionStream {
            rng,
            num_nodes,
            churn,
            pos,
            next_replace: 0,
            pending: Vec::new(),
        }
    }

    /// K nearest-ish neighbors for a position (approximate: samples a
    /// candidate pool rather than exact kNN — matches the event-graph
    /// construction used on-device where exactness is not needed).
    fn wire(&mut self, node: usize, k: usize) -> Vec<usize> {
        let (x, y) = self.pos[node];
        let mut best: Vec<(f64, usize)> = Vec::new();
        for _ in 0..32 {
            let cand = self.rng.usize(self.num_nodes);
            if cand == node {
                continue;
            }
            let (cx, cy) = self.pos[cand];
            let d2 = (x - cx).powi(2) + (y - cy).powi(2);
            best.push((d2, cand));
        }
        best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        best.dedup_by_key(|e| e.1);
        best.truncate(k);
        best.into_iter().map(|(_, i)| i).collect()
    }
}

impl Iterator for EventVisionStream {
    type Item = GraphEvent;

    fn next(&mut self) -> Option<GraphEvent> {
        if let Some(ev) = self.pending.pop() {
            return Some(ev);
        }
        // new frame: replace `churn` nodes round-robin, rewire each to
        // 3 spatial neighbors, then query.
        let mut events = vec![GraphEvent::Query];
        for _ in 0..self.churn {
            let node = self.next_replace;
            self.next_replace = (self.next_replace + 1) % self.num_nodes;
            self.pos[node] = (self.rng.f64(), self.rng.f64());
            for nbr in self.wire(node, 3) {
                events.push(GraphEvent::AddEdge(node, nbr));
            }
        }
        self.pending = events;
        self.pending.pop()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kg_stream_deterministic() {
        let a: Vec<_> = KnowledgeGraphStream::new(10, 50, 0.3, 7).take(100).collect();
        let b: Vec<_> = KnowledgeGraphStream::new(10, 50, 0.3, 7).take(100).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn kg_stream_mixes_events() {
        let evs: Vec<_> = KnowledgeGraphStream::new(10, 500, 0.3, 1).take(500).collect();
        let queries = evs.iter().filter(|e| matches!(e, GraphEvent::Query)).count();
        let adds = evs.iter().filter(|e| matches!(e, GraphEvent::AddEdge(..))).count();
        let nodes = evs.iter().filter(|e| matches!(e, GraphEvent::AddNode)).count();
        assert!(queries > 50, "queries {queries}");
        assert!(adds > 100, "adds {adds}");
        assert!(nodes > 0, "nodes {nodes}");
        // query ratio approximately honored
        let ratio = queries as f64 / 500.0;
        assert!((ratio - 0.3).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn kg_stream_respects_capacity() {
        let evs: Vec<_> = KnowledgeGraphStream::new(4, 6, 0.0, 3).take(2000).collect();
        let nodes = evs.iter().filter(|e| matches!(e, GraphEvent::AddNode)).count();
        assert!(nodes <= 2, "added {nodes} nodes beyond capacity 6");
    }

    #[test]
    fn kg_edges_within_node_range() {
        let mut n = 12;
        for ev in KnowledgeGraphStream::new(12, 40, 0.2, 5).take(1000) {
            match ev {
                GraphEvent::AddNode => n += 1,
                GraphEvent::AddEdge(u, v) | GraphEvent::RemoveEdge(u, v) => {
                    assert!(u < n && v < n, "({u},{v}) with n={n}");
                    assert_ne!(u, v);
                }
                GraphEvent::Query => {}
            }
        }
    }

    #[test]
    fn churn_schedule_is_exact_and_deterministic() {
        let a: Vec<_> =
            KnowledgeGraphStream::with_churn(10, 200, 2.0, 11).take(300).collect();
        let b: Vec<_> =
            KnowledgeGraphStream::with_churn(10, 200, 2.0, 11).take(300).collect();
        assert_eq!(a, b);
        // cycle = 2 mutations + 1 query, exactly
        for chunk in a.chunks(3) {
            if chunk.len() < 3 {
                break;
            }
            assert!(!matches!(chunk[0], GraphEvent::Query));
            assert!(!matches!(chunk[1], GraphEvent::Query));
            assert!(matches!(chunk[2], GraphEvent::Query));
        }
    }

    #[test]
    fn fractional_churn_carries_debt() {
        // churn 0.5: queries alternate with single mutations — over 100
        // events exactly 1 mutation per 2 queries
        let evs: Vec<_> =
            KnowledgeGraphStream::with_churn(10, 200, 0.5, 3).take(99).collect();
        let queries = evs.iter().filter(|e| matches!(e, GraphEvent::Query)).count();
        let muts = evs.len() - queries;
        assert!((queries as i64 - 2 * muts as i64).abs() <= 2,
                "{queries} queries vs {muts} mutations");
        // zero churn: pure queries
        let evs: Vec<_> =
            KnowledgeGraphStream::with_churn(10, 200, 0.0, 3).take(20).collect();
        assert!(evs.iter().all(|e| matches!(e, GraphEvent::Query)));
    }

    #[test]
    fn burst_mode_groups_mutations_before_queries() {
        // burst 4 at churn 2: cycles of 8 mutations then 4 queries
        let evs: Vec<_> = KnowledgeGraphStream::with_churn(10, 500, 2.0, 9)
            .with_burst(4)
            .take(120)
            .collect();
        for cycle in evs.chunks(12) {
            if cycle.len() < 12 {
                break;
            }
            assert!(cycle[..8].iter().all(|e| !matches!(e, GraphEvent::Query)),
                    "burst head must be mutations");
            assert!(cycle[8..].iter().all(|e| matches!(e, GraphEvent::Query)),
                    "burst tail must be queries");
        }
    }

    #[test]
    fn churned_edges_stay_in_node_range() {
        let mut n = 12;
        for ev in KnowledgeGraphStream::with_churn(12, 60, 3.0, 5).take(800) {
            match ev {
                GraphEvent::AddNode => n += 1,
                GraphEvent::AddEdge(u, v) | GraphEvent::RemoveEdge(u, v) => {
                    assert!(u < n && v < n, "({u},{v}) with n={n}");
                    assert_ne!(u, v);
                }
                GraphEvent::Query => {}
            }
        }
        assert!(n <= 60);
    }

    #[test]
    fn ev_stream_emits_bursts_with_queries() {
        let evs: Vec<_> = EventVisionStream::new(64, 8, 9).take(400).collect();
        let queries = evs.iter().filter(|e| matches!(e, GraphEvent::Query)).count();
        let adds = evs.iter().filter(|e| matches!(e, GraphEvent::AddEdge(..))).count();
        assert!(queries >= 10, "queries {queries}");
        assert!(adds > 5 * queries, "burst size too small: {adds}/{queries}");
    }

    #[test]
    fn ev_stream_edges_in_range() {
        for ev in EventVisionStream::new(32, 4, 2).take(500) {
            if let GraphEvent::AddEdge(u, v) = ev {
                assert!(u < 32 && v < 32);
                assert_ne!(u, v);
            }
        }
    }
}
