//! Dynamic-graph event streams — the workloads of the paper's motivating
//! applications (Fig. 1): on-device knowledge-graph churn (RAG assistants)
//! and event-based vision sliding windows.
//!
//! A stream yields [`GraphEvent`]s that the server applies through GrAd;
//! the generators are deterministic per seed so serving benchmarks are
//! reproducible.

use crate::util::Rng;

/// One structural update + an inference trigger policy.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphEvent {
    AddEdge(usize, usize),
    RemoveEdge(usize, usize),
    AddNode,
    /// Run inference over the current graph (a query arrival).
    Query,
}

/// Knowledge-graph churn: entities join over time, facts (edges) are
/// added with preferential attachment and occasionally retracted; queries
/// arrive between update bursts (paper Fig. 10's "on-device knowledge
/// graph" example).
pub struct KnowledgeGraphStream {
    rng: Rng,
    num_nodes: usize,
    capacity: usize,
    /// Live edges (for retractions). Kept small by sampling.
    live_edges: Vec<(usize, usize)>,
    /// Degree-proportional sampling pool (preferential attachment).
    endpoint_pool: Vec<usize>,
    query_ratio: f64,
}

impl KnowledgeGraphStream {
    pub fn new(initial_nodes: usize, capacity: usize, query_ratio: f64,
               seed: u64) -> Self {
        assert!(initial_nodes >= 2 && capacity >= initial_nodes);
        KnowledgeGraphStream {
            rng: Rng::new(seed),
            num_nodes: initial_nodes,
            capacity,
            live_edges: Vec::new(),
            endpoint_pool: (0..initial_nodes).collect(),
            query_ratio: query_ratio.clamp(0.0, 1.0),
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }
}

impl Iterator for KnowledgeGraphStream {
    type Item = GraphEvent;

    fn next(&mut self) -> Option<GraphEvent> {
        if self.rng.chance(self.query_ratio) {
            return Some(GraphEvent::Query);
        }
        let roll = self.rng.f64();
        if roll < 0.08 && self.num_nodes < self.capacity {
            // new entity
            let id = self.num_nodes;
            self.num_nodes += 1;
            self.endpoint_pool.push(id);
            return Some(GraphEvent::AddNode);
        }
        if roll < 0.18 && !self.live_edges.is_empty() {
            // fact retraction
            let k = self.rng.usize(self.live_edges.len());
            let (u, v) = self.live_edges.swap_remove(k);
            return Some(GraphEvent::RemoveEdge(u, v));
        }
        // new fact with preferential attachment
        let u = self.endpoint_pool[self.rng.usize(self.endpoint_pool.len())];
        let mut v = self.rng.usize(self.num_nodes);
        if v == u {
            v = (v + 1) % self.num_nodes;
        }
        self.endpoint_pool.push(u); // reinforce degree
        self.endpoint_pool.push(v);
        if self.endpoint_pool.len() > 4096 {
            // bound the pool; forget old mass uniformly
            let drop = self.rng.usize(self.endpoint_pool.len());
            self.endpoint_pool.swap_remove(drop);
        }
        self.live_edges.push((u, v));
        if self.live_edges.len() > 8192 {
            self.live_edges.swap_remove(0);
        }
        Some(GraphEvent::AddEdge(u, v))
    }
}

/// Event-camera sliding-window stream: each "frame" replaces a slice of
/// the event nodes with fresh ones connected by spatiotemporal proximity
/// (AEGNN-style). Produces bursts of updates followed by a query — the
/// high-rate regime GrAd's no-recompile property exists for.
pub struct EventVisionStream {
    rng: Rng,
    num_nodes: usize,
    /// how many nodes each new frame replaces
    churn: usize,
    /// spatial positions of live events (for locality-based wiring)
    pos: Vec<(f64, f64)>,
    next_replace: usize,
    pending: Vec<GraphEvent>,
}

impl EventVisionStream {
    pub fn new(num_nodes: usize, churn: usize, seed: u64) -> Self {
        assert!(churn <= num_nodes && num_nodes > 4);
        let mut rng = Rng::new(seed);
        let pos = (0..num_nodes)
            .map(|_| (rng.f64(), rng.f64()))
            .collect();
        EventVisionStream {
            rng,
            num_nodes,
            churn,
            pos,
            next_replace: 0,
            pending: Vec::new(),
        }
    }

    /// K nearest-ish neighbors for a position (approximate: samples a
    /// candidate pool rather than exact kNN — matches the event-graph
    /// construction used on-device where exactness is not needed).
    fn wire(&mut self, node: usize, k: usize) -> Vec<usize> {
        let (x, y) = self.pos[node];
        let mut best: Vec<(f64, usize)> = Vec::new();
        for _ in 0..32 {
            let cand = self.rng.usize(self.num_nodes);
            if cand == node {
                continue;
            }
            let (cx, cy) = self.pos[cand];
            let d2 = (x - cx).powi(2) + (y - cy).powi(2);
            best.push((d2, cand));
        }
        best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        best.dedup_by_key(|e| e.1);
        best.truncate(k);
        best.into_iter().map(|(_, i)| i).collect()
    }
}

impl Iterator for EventVisionStream {
    type Item = GraphEvent;

    fn next(&mut self) -> Option<GraphEvent> {
        if let Some(ev) = self.pending.pop() {
            return Some(ev);
        }
        // new frame: replace `churn` nodes round-robin, rewire each to
        // 3 spatial neighbors, then query.
        let mut events = vec![GraphEvent::Query];
        for _ in 0..self.churn {
            let node = self.next_replace;
            self.next_replace = (self.next_replace + 1) % self.num_nodes;
            self.pos[node] = (self.rng.f64(), self.rng.f64());
            for nbr in self.wire(node, 3) {
                events.push(GraphEvent::AddEdge(node, nbr));
            }
        }
        self.pending = events;
        self.pending.pop()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kg_stream_deterministic() {
        let a: Vec<_> = KnowledgeGraphStream::new(10, 50, 0.3, 7).take(100).collect();
        let b: Vec<_> = KnowledgeGraphStream::new(10, 50, 0.3, 7).take(100).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn kg_stream_mixes_events() {
        let evs: Vec<_> = KnowledgeGraphStream::new(10, 500, 0.3, 1).take(500).collect();
        let queries = evs.iter().filter(|e| matches!(e, GraphEvent::Query)).count();
        let adds = evs.iter().filter(|e| matches!(e, GraphEvent::AddEdge(..))).count();
        let nodes = evs.iter().filter(|e| matches!(e, GraphEvent::AddNode)).count();
        assert!(queries > 50, "queries {queries}");
        assert!(adds > 100, "adds {adds}");
        assert!(nodes > 0, "nodes {nodes}");
        // query ratio approximately honored
        let ratio = queries as f64 / 500.0;
        assert!((ratio - 0.3).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn kg_stream_respects_capacity() {
        let evs: Vec<_> = KnowledgeGraphStream::new(4, 6, 0.0, 3).take(2000).collect();
        let nodes = evs.iter().filter(|e| matches!(e, GraphEvent::AddNode)).count();
        assert!(nodes <= 2, "added {nodes} nodes beyond capacity 6");
    }

    #[test]
    fn kg_edges_within_node_range() {
        let mut n = 12;
        for ev in KnowledgeGraphStream::new(12, 40, 0.2, 5).take(1000) {
            match ev {
                GraphEvent::AddNode => n += 1,
                GraphEvent::AddEdge(u, v) | GraphEvent::RemoveEdge(u, v) => {
                    assert!(u < n && v < n, "({u},{v}) with n={n}");
                    assert_ne!(u, v);
                }
                GraphEvent::Query => {}
            }
        }
    }

    #[test]
    fn ev_stream_emits_bursts_with_queries() {
        let evs: Vec<_> = EventVisionStream::new(64, 8, 9).take(400).collect();
        let queries = evs.iter().filter(|e| matches!(e, GraphEvent::Query)).count();
        let adds = evs.iter().filter(|e| matches!(e, GraphEvent::AddEdge(..))).count();
        assert!(queries >= 10, "queries {queries}");
        assert!(adds > 5 * queries, "burst size too small: {adds}/{queries}");
    }

    #[test]
    fn ev_stream_edges_in_range() {
        for ev in EventVisionStream::new(32, 4, 2).take(500) {
            if let GraphEvent::AddEdge(u, v) = ev {
                assert!(u < 32 && v < 32);
                assert_ne!(u, v);
            }
        }
    }
}
