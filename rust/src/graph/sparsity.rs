//! GraSp: sparsity bitmaps + Zero-Value Compression (paper Fig. 13).
//!
//! ZVC [Rhu et al., HPCA'18] stores only the non-zero values plus a
//! 1-bit-per-element bitmap. The NPU's DMA engine moves the compressed
//! stream; the compute pipeline uses the bitmap to skip zero work. This
//! module is the codec + the footprint accounting the simulator charges;
//! `npu::sim` consumes `ZvcStats` to model the latency/energy win.

use crate::tensor::Mat;

/// A ZVC-compressed block: bitmap + packed non-zeros.
#[derive(Debug, Clone, PartialEq)]
pub struct Zvc {
    /// Total element count (bitmap length).
    pub len: usize,
    /// 1 bit per element, LSB-first within each byte.
    pub bitmap: Vec<u8>,
    /// The non-zero values, in scan order.
    pub values: Vec<f32>,
}

impl Zvc {
    /// Compress a dense f32 slice.
    pub fn compress(data: &[f32]) -> Zvc {
        let mut bitmap = vec![0u8; data.len().div_ceil(8)];
        let mut values = Vec::new();
        for (i, &v) in data.iter().enumerate() {
            if v != 0.0 {
                bitmap[i / 8] |= 1 << (i % 8);
                values.push(v);
            }
        }
        Zvc { len: data.len(), bitmap, values }
    }

    pub fn compress_mat(m: &Mat) -> Zvc {
        Zvc::compress(&m.data)
    }

    /// Decompress back to dense.
    pub fn decompress(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        let mut cursor = 0;
        for (i, slot) in out.iter_mut().enumerate() {
            if self.bitmap[i / 8] & (1 << (i % 8)) != 0 {
                *slot = self.values[cursor];
                cursor += 1;
            }
        }
        debug_assert_eq!(cursor, self.values.len());
        out
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Compressed size: bitmap + packed values.
    pub fn bytes(&self) -> usize {
        self.bitmap.len() + self.values.len() * 4
    }

    /// Dense size this replaces.
    pub fn dense_bytes(&self) -> usize {
        self.len * 4
    }

    pub fn stats(&self) -> ZvcStats {
        ZvcStats {
            elements: self.len,
            nnz: self.nnz(),
            dense_bytes: self.dense_bytes(),
            compressed_bytes: self.bytes(),
        }
    }
}

/// Footprint numbers the NPU simulator charges for a GraSp transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ZvcStats {
    pub elements: usize,
    pub nnz: usize,
    pub dense_bytes: usize,
    pub compressed_bytes: usize,
}

impl ZvcStats {
    /// Estimate stats without materializing a codec pass — used by the
    /// simulator for operands it only knows the sparsity of.
    pub fn estimate(elements: usize, density: f64) -> ZvcStats {
        let nnz = (elements as f64 * density).round() as usize;
        ZvcStats {
            elements,
            nnz,
            dense_bytes: elements * 4,
            compressed_bytes: elements.div_ceil(8) + nnz * 4,
        }
    }

    pub fn density(&self) -> f64 {
        if self.elements == 0 {
            0.0
        } else {
            self.nnz as f64 / self.elements as f64
        }
    }

    /// DMA bytes saved vs dense (can be negative for dense data, in which
    /// case the runtime ships the dense form — `effective_bytes` models
    /// that fallback, like real ZVC DMA engines do).
    pub fn effective_bytes(&self) -> usize {
        self.compressed_bytes.min(self.dense_bytes)
    }

    /// Fraction of MAC work skippable by the zero-skip pipeline.
    pub fn skip_fraction(&self) -> f64 {
        1.0 - self.density()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn roundtrip_known() {
        let data = [0.0, 1.5, 0.0, 0.0, -2.0, 3.0, 0.0, 0.0, 7.0];
        let z = Zvc::compress(&data);
        assert_eq!(z.nnz(), 4);
        assert_eq!(z.decompress(), data);
    }

    #[test]
    fn all_zero_compresses_to_bitmap_only() {
        let z = Zvc::compress(&[0.0; 64]);
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.bytes(), 8); // 64 bits
        assert_eq!(z.decompress(), vec![0.0; 64]);
    }

    #[test]
    fn dense_data_grows_slightly() {
        let data: Vec<f32> = (1..=32).map(|i| i as f32).collect();
        let z = Zvc::compress(&data);
        assert_eq!(z.bytes(), 4 + 128); // bitmap overhead
        assert!(z.stats().effective_bytes() == z.dense_bytes());
    }

    #[test]
    fn cora_norm_sparsity_wins_big() {
        // a 99.8%-sparse matrix like Cora's norm mask compresses ~30x
        let g = crate::graph::Graph::new(
            200,
            &(0..300)
                .map(|i| ((i % 200) as u32, ((i * 7 + 1) % 200) as u32))
                .collect::<Vec<_>>(),
        );
        let m = g.norm_adjacency(200);
        let z = Zvc::compress_mat(&m);
        let s = z.stats();
        assert!(s.density() < 0.03, "density {}", s.density());
        assert!(
            (s.dense_bytes as f64 / s.effective_bytes() as f64) > 5.0,
            "ratio {}",
            s.dense_bytes as f64 / s.effective_bytes() as f64
        );
    }

    #[test]
    fn estimate_matches_codec() {
        let mut data = vec![0.0f32; 1000];
        for i in (0..1000).step_by(10) {
            data[i] = 1.0;
        }
        let real = Zvc::compress(&data).stats();
        let est = ZvcStats::estimate(1000, 0.1);
        assert_eq!(real.nnz, est.nnz);
        assert_eq!(real.compressed_bytes, est.compressed_bytes);
        assert!((real.skip_fraction() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn prop_roundtrip_arbitrary() {
        forall("zvc roundtrip", 60, |g| {
            let n = g.usize(0, 200);
            let data: Vec<f32> = (0..n)
                .map(|_| {
                    if g.chance(0.7) {
                        0.0
                    } else {
                        g.small_f32()
                    }
                })
                .collect();
            let z = Zvc::compress(&data);
            assert_eq!(z.decompress(), data);
            assert_eq!(z.nnz(), data.iter().filter(|&&x| x != 0.0).count());
            // compressed never bigger than bitmap + all values
            assert!(z.bytes() <= n.div_ceil(8) + n * 4);
        });
    }
}
