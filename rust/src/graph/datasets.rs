//! Dataset twins and synthetic workload graphs.
//!
//! The *canonical* Cora/Citeseer twins (with trained weights) are built by
//! the python AOT path and shipped in `artifacts/*.gnnt` — use
//! [`Dataset::load_gnnt`] for anything that touches the PJRT artifacts.
//! This module additionally provides a native generator with the same
//! planted-partition structure for simulator benches and examples that
//! need graphs at arbitrary scales without artifacts (the generators do
//! not need to be bit-identical with python; the .gnnt file is the source
//! of truth where it matters).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Graph;
use crate::tensor::Mat;
use crate::util::Rng;

/// Published statistics mirrored by the twins (paper §V).
#[derive(Debug, Clone, Copy)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub nodes: usize,
    pub edges: usize,
    pub classes: usize,
    pub features: usize,
    /// NodePad capacity the artifacts were compiled at.
    pub capacity: usize,
}

pub const CORA: DatasetSpec = DatasetSpec {
    name: "cora",
    nodes: 2708,
    edges: 5429,
    classes: 7,
    features: 1433,
    capacity: 3000,
};

pub const CITESEER: DatasetSpec = DatasetSpec {
    name: "citeseer",
    nodes: 3327,
    edges: 4732,
    classes: 6,
    features: 3703,
    capacity: 3500,
};

pub fn spec(name: &str) -> Result<DatasetSpec> {
    Ok(match name {
        "cora" => CORA,
        "citeseer" => CITESEER,
        other => bail!("unknown dataset {other:?} (cora|citeseer)"),
    })
}

/// An attributed node-classification dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub graph: Graph,
    pub features: Mat,
    pub labels: Vec<i32>,
    pub train_mask: Vec<bool>,
    pub val_mask: Vec<bool>,
    pub test_mask: Vec<bool>,
    /// The exact neighbor sample exported at AOT time (rows of k+1 gather
    /// indices, sentinel = n), if loaded from a .gnnt file.
    pub nbr_idx: Option<Vec<i32>>,
    /// Columns in `nbr_idx` (k+1).
    pub nbr_width: usize,
}

impl Dataset {
    pub fn num_nodes(&self) -> usize {
        self.graph.num_nodes()
    }

    pub fn num_features(&self) -> usize {
        self.features.cols
    }

    pub fn num_classes(&self) -> usize {
        (self.labels.iter().copied().max().unwrap_or(-1) + 1) as usize
    }

    /// Load the canonical twin exported by `make artifacts`.
    pub fn load_gnnt(dir: &Path, name: &str) -> Result<Dataset> {
        let path = dir.join(format!("{name}.gnnt"));
        let tensors = crate::runtime::io::read_gnnt(&path)
            .with_context(|| format!("loading dataset {}", path.display()))?;
        let features = tensors
            .get("features")
            .context("missing 'features'")?
            .to_mat()?;
        let labels = tensors.get("labels").context("missing 'labels'")?;
        let labels = labels.as_i32()?.to_vec();
        let edges_t = tensors.get("edges").context("missing 'edges'")?;
        let flat = edges_t.as_i32()?;
        let edges: Vec<(u32, u32)> = flat
            .chunks_exact(2)
            .map(|c| (c[0] as u32, c[1] as u32))
            .collect();
        let graph = Graph::new(features.rows, &edges);
        let mask = |key: &str| -> Result<Vec<bool>> {
            Ok(tensors
                .get(key)
                .with_context(|| format!("missing {key:?}"))?
                .as_u8()?
                .iter()
                .map(|&b| b != 0)
                .collect())
        };
        let (nbr_idx, nbr_width) = match tensors.get("nbr_idx") {
            Some(t) => {
                let w = t.shape().get(1).copied().unwrap_or(0);
                (Some(t.as_i32()?.to_vec()), w)
            }
            None => (None, 0),
        };
        Ok(Dataset {
            name: name.to_string(),
            graph,
            labels,
            train_mask: mask("train_mask")?,
            val_mask: mask("val_mask")?,
            test_mask: mask("test_mask")?,
            features,
            nbr_idx,
            nbr_width,
        })
    }

    /// Accuracy of row-wise-argmax predictions on a node mask.
    pub fn accuracy(&self, logits: &Mat, mask: &[bool]) -> f64 {
        let preds = logits.argmax_rows();
        let mut hit = 0usize;
        let mut total = 0usize;
        for (i, &m) in mask.iter().enumerate() {
            if m && i < preds.len() {
                total += 1;
                if preds[i] as i32 == self.labels[i] {
                    hit += 1;
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            hit as f64 / total as f64
        }
    }
}

/// Native planted-partition generator (simulator benches, examples).
///
/// Matches the twin construction: homophilous edge placement, class-
/// signature sparse features, balanced train split.
pub fn synthesize(
    name: &str,
    nodes: usize,
    edges: usize,
    classes: usize,
    features: usize,
    seed: u64,
) -> Dataset {
    assert!(classes >= 2 && nodes >= classes);
    let mut rng = Rng::new(seed);
    const HOMOPHILY: f64 = 0.72;
    const DENSITY: f64 = 0.0127;

    // labels: roughly balanced with noise
    let mut labels: Vec<i32> = (0..nodes).map(|i| (i % classes) as i32).collect();
    rng.shuffle(&mut labels);
    let mut by_class: Vec<Vec<u32>> = vec![Vec::new(); classes];
    for (i, &c) in labels.iter().enumerate() {
        by_class[c as usize].push(i as u32);
    }

    // planted-partition edges
    let mut seen = std::collections::BTreeSet::new();
    let mut edge_list = Vec::with_capacity(edges);
    let max_possible = nodes * (nodes - 1) / 2;
    let target = edges.min(max_possible);
    while edge_list.len() < target {
        let (u, v) = if rng.chance(HOMOPHILY) {
            let c = rng.usize(classes);
            let members = &by_class[c];
            if members.len() < 2 {
                continue;
            }
            let pick = rng.sample_indices(members.len(), 2);
            (members[pick[0]], members[pick[1]])
        } else {
            (rng.usize(nodes) as u32, rng.usize(nodes) as u32)
        };
        if u == v {
            continue;
        }
        let key = (u.min(v), u.max(v));
        if seen.insert(key) {
            edge_list.push(key);
        }
    }
    let graph = Graph::new(nodes, &edge_list);

    // class-signature features, row-normalized
    let sig = (features as f64 * 0.08).max(4.0) as usize;
    let mut feats = Mat::zeros(nodes, features);
    for i in 0..nodes {
        let c = labels[i] as usize;
        let row = feats.row_mut(i);
        let (sig_lo, sig_hi) = ((c * sig) % features, ((c + 1) * sig - 1) % features + 1);
        for (j, x) in row.iter_mut().enumerate() {
            let in_sig = if sig_lo < sig_hi {
                j >= sig_lo && j < sig_hi
            } else {
                j >= sig_lo || j < sig_hi
            };
            let p = if in_sig { (DENSITY * 3.0).min(0.9) } else { DENSITY * 0.55 };
            if rng.chance(p) {
                *x = 1.0;
            }
        }
        let sum: f32 = row.iter().sum();
        if sum > 0.0 {
            for x in row.iter_mut() {
                *x /= sum;
            }
        }
    }

    // balanced train split, then val/test blocks
    let train_per_class = (20).min(nodes / classes / 2).max(1);
    let mut train_mask = vec![false; nodes];
    for members in &by_class {
        let mut m = members.clone();
        rng.shuffle(&mut m);
        for &i in m.iter().take(train_per_class) {
            train_mask[i as usize] = true;
        }
    }
    let mut rest: Vec<usize> = (0..nodes).filter(|&i| !train_mask[i]).collect();
    rng.shuffle(&mut rest);
    let n_eval = rest.len() / 3;
    let mut val_mask = vec![false; nodes];
    let mut test_mask = vec![false; nodes];
    for &i in rest.iter().take(n_eval) {
        val_mask[i] = true;
    }
    for &i in rest.iter().skip(n_eval).take(n_eval) {
        test_mask[i] = true;
    }

    Dataset {
        name: name.to_string(),
        graph,
        features: feats,
        labels,
        train_mask,
        val_mask,
        test_mask,
        nbr_idx: None,
        nbr_width: 0,
    }
}

/// The Fig. 4/5 microbenchmark graph: "1354 nodes and 5429 edges".
pub fn fig4_graph(seed: u64) -> Dataset {
    synthesize("fig4", 1354, 5429, 7, 1433, seed)
}

/// Deterministic preferential-attachment generator for out-of-core
/// sweeps (the `paging` bench drives this at 1M+ nodes; the pool-based
/// sampler is O(edges), so 10M-node graphs stay tractable).
///
/// Each new node attaches `avg_degree / 2` edges to existing nodes with
/// probability proportional to current degree, yielding the familiar
/// heavy-tailed Barabási–Albert degree distribution that stresses page
/// locality far harder than the planted-partition generator.
pub fn synthesize_power_law(
    name: &str,
    nodes: usize,
    avg_degree: usize,
    classes: usize,
    features: usize,
    seed: u64,
) -> Dataset {
    power_law(name, nodes, avg_degree, classes, features, seed, true)
}

/// Same topology/labels/splits as [`synthesize_power_law`] but with an
/// empty `[0, features]` feature matrix: `num_features()` still reports
/// `features`, yet no RAM is spent on rows. Pair with
/// [`power_law_feature_row`] to stream rows straight into a
/// [`crate::storage::PagedStore`] — the out-of-core serving path never
/// needs the matrix resident.
pub fn synthesize_power_law_headless(
    name: &str,
    nodes: usize,
    avg_degree: usize,
    classes: usize,
    features: usize,
    seed: u64,
) -> Dataset {
    power_law(name, nodes, avg_degree, classes, features, seed, false)
}

/// The deterministic feature row the power-law generators assign to
/// `node` — callable independently so disk stores can be built by
/// streaming rows without ever materializing the matrix.
pub fn power_law_feature_row(seed: u64, node: usize, out: &mut [f32]) {
    out.fill(0.0);
    let features = out.len();
    if features == 0 {
        return;
    }
    let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(node as u64 + 1));
    let nnz = (features / 16).clamp(1, 32).min(features);
    let w = 1.0 / nnz as f32;
    for _ in 0..nnz {
        out[rng.usize(features)] += w;
    }
}

fn power_law(
    name: &str,
    nodes: usize,
    avg_degree: usize,
    classes: usize,
    features: usize,
    seed: u64,
    materialize: bool,
) -> Dataset {
    assert!(classes >= 2 && nodes >= classes && avg_degree >= 2);
    let m = (avg_degree / 2).max(1);
    let mut rng = Rng::new(seed);
    let seed_n = (m + 1).min(nodes);

    // endpoint pool: one slot per degree unit, so uniform draws from it
    // are degree-proportional attachment
    let mut edge_list: Vec<(u32, u32)> = Vec::with_capacity(nodes.saturating_mul(m));
    let mut pool: Vec<u32> = Vec::with_capacity(2 * nodes.saturating_mul(m));
    for v in 1..seed_n {
        edge_list.push(((v - 1) as u32, v as u32));
        pool.push((v - 1) as u32);
        pool.push(v as u32);
    }
    if seed_n == 1 {
        pool.push(0);
    }
    let mut targets: Vec<u32> = Vec::with_capacity(m);
    for v in seed_n..nodes {
        targets.clear();
        let want = m.min(v);
        let mut attempts = 0usize;
        while targets.len() < want && attempts < 16 * m {
            attempts += 1;
            let t = pool[rng.usize(pool.len())];
            if t as usize != v && !targets.contains(&t) {
                targets.push(t);
            }
        }
        for &t in &targets {
            edge_list.push((t, v as u32));
            pool.push(t);
            pool.push(v as u32);
        }
        if targets.is_empty() {
            pool.push(v as u32); // keep every node reachable by attachment
        }
    }
    let graph = Graph::new(nodes, &edge_list);

    // per-node deterministic labels + splits: independent of iteration
    // order and of whether features are materialized
    let mut labels = Vec::with_capacity(nodes);
    let mut train_mask = vec![false; nodes];
    let mut val_mask = vec![false; nodes];
    let mut test_mask = vec![false; nodes];
    for i in 0..nodes {
        let mut nrng =
            Rng::new(seed ^ 0xD6E8_FEB8_6659_FD93u64.wrapping_mul(i as u64 + 1));
        labels.push(nrng.usize(classes) as i32);
        match nrng.usize(100) {
            0 | 1 => train_mask[i] = true,
            2..=11 => val_mask[i] = true,
            12..=21 => test_mask[i] = true,
            _ => {}
        }
    }

    let feats = if materialize {
        let mut feats = Mat::zeros(nodes, features);
        for i in 0..nodes {
            power_law_feature_row(seed, i, feats.row_mut(i));
        }
        feats
    } else {
        Mat::zeros(0, features)
    };

    Dataset {
        name: name.to_string(),
        graph,
        features: feats,
        labels,
        train_mask,
        val_mask,
        test_mask,
        nbr_idx: None,
        nbr_width: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_paper() {
        assert_eq!(CORA.nodes, 2708);
        assert_eq!(CORA.edges, 5429);
        assert_eq!(CORA.capacity, 3000); // 2708 + 292 per paper §V
        assert_eq!(CITESEER.features, 3703);
        assert!(spec("pubmed").is_err());
    }

    #[test]
    fn synthesize_matches_requested_stats() {
        let ds = synthesize("t", 300, 600, 5, 64, 1);
        assert_eq!(ds.num_nodes(), 300);
        assert_eq!(ds.graph.num_edges(), 600);
        assert_eq!(ds.num_classes(), 5);
        assert_eq!(ds.num_features(), 64);
    }

    #[test]
    fn synthesize_deterministic() {
        let a = synthesize("t", 100, 200, 4, 32, 7);
        let b = synthesize("t", 100, 200, 4, 32, 7);
        assert_eq!(a.graph.edges(), b.graph.edges());
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn synthesize_homophilous() {
        let ds = synthesize("t", 400, 1200, 4, 16, 3);
        let same: usize = ds
            .graph
            .edges()
            .iter()
            .filter(|&&(s, d)| ds.labels[s as usize] == ds.labels[d as usize])
            .count();
        let frac = same as f64 / ds.graph.num_edges() as f64;
        assert!(frac > 0.6, "homophily {frac}");
    }

    #[test]
    fn features_sparse_and_normalized() {
        let ds = synthesize("t", 200, 300, 4, 256, 5);
        let density = 1.0 - ds.features.sparsity();
        assert!(density < 0.08, "density {density}");
        // non-empty rows sum to 1
        for i in 0..20 {
            let s: f32 = ds.features.row(i).iter().sum();
            assert!(s == 0.0 || (s - 1.0).abs() < 1e-4, "row {i} sums {s}");
        }
    }

    #[test]
    fn masks_disjoint() {
        let ds = synthesize("t", 150, 250, 3, 32, 9);
        for i in 0..150 {
            let c = [ds.train_mask[i], ds.val_mask[i], ds.test_mask[i]]
                .iter()
                .filter(|&&b| b)
                .count();
            assert!(c <= 1, "node {i} in {c} splits");
        }
        assert!(ds.train_mask.iter().filter(|&&b| b).count() > 0);
    }

    #[test]
    fn accuracy_helper() {
        let ds = synthesize("t", 10, 12, 2, 8, 11);
        // logits that perfectly one-hot the labels
        let mut logits = Mat::zeros(10, 2);
        for i in 0..10 {
            logits[(i, ds.labels[i] as usize)] = 1.0;
        }
        let all = vec![true; 10];
        assert_eq!(ds.accuracy(&logits, &all), 1.0);
    }

    #[test]
    fn fig4_graph_scale() {
        let ds = fig4_graph(0);
        assert_eq!(ds.num_nodes(), 1354);
        assert_eq!(ds.graph.num_edges(), 5429);
    }

    #[test]
    fn power_law_matches_requested_stats() {
        let ds = synthesize_power_law("pl", 2000, 8, 5, 64, 42);
        assert_eq!(ds.num_nodes(), 2000);
        assert_eq!(ds.num_features(), 64);
        assert_eq!(ds.num_classes(), 5);
        let avg = 2.0 * ds.graph.num_edges() as f64 / ds.num_nodes() as f64;
        assert!((avg - 8.0).abs() < 1.0, "avg degree {avg}");
        assert!(ds.train_mask.iter().any(|&b| b));
        assert!(ds.test_mask.iter().any(|&b| b));
    }

    #[test]
    fn power_law_deterministic() {
        let a = synthesize_power_law("pl", 500, 6, 4, 32, 9);
        let b = synthesize_power_law("pl", 500, 6, 4, 32, 9);
        assert_eq!(a.graph.edges(), b.graph.edges());
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn power_law_heavy_tail() {
        let ds = synthesize_power_law("pl", 3000, 8, 4, 16, 3);
        let mut degree = vec![0usize; ds.num_nodes()];
        for &(s, d) in ds.graph.edges() {
            degree[s as usize] += 1;
            degree[d as usize] += 1;
        }
        let max = degree.iter().copied().max().unwrap();
        let avg = 2.0 * ds.graph.num_edges() as f64 / ds.num_nodes() as f64;
        // preferential attachment concentrates degree on early nodes far
        // beyond anything the planted-partition generator produces
        assert!(
            max as f64 > 5.0 * avg,
            "max degree {max} vs avg {avg} — no heavy tail"
        );
    }

    #[test]
    fn power_law_headless_matches_dense() {
        let dense = synthesize_power_law("pl", 400, 6, 3, 48, 7);
        let lean = synthesize_power_law_headless("pl", 400, 6, 3, 48, 7);
        assert_eq!(dense.graph.edges(), lean.graph.edges());
        assert_eq!(dense.labels, lean.labels);
        assert_eq!(lean.features.rows, 0);
        assert_eq!(lean.num_features(), 48);
        // streaming rows reproduces the dense matrix exactly
        let mut row = vec![0.0f32; 48];
        for i in [0usize, 17, 399] {
            power_law_feature_row(7, i, &mut row);
            assert_eq!(&row[..], dense.features.row(i), "row {i}");
        }
    }
}
