//! Compressed Sparse Row adjacency — the memory-efficient storage the
//! CPU side iterates over (degree math, incremental updates, streaming).

use super::Graph;

/// CSR over the *undirected* graph: each edge appears in both rows.
/// Self loops are not stored (GraphConv adds them arithmetically).
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    /// Row offsets, length n+1.
    pub indptr: Vec<u32>,
    /// Column indices, sorted within each row.
    pub indices: Vec<u32>,
}

impl Csr {
    pub fn from_graph(g: &Graph) -> Csr {
        let n = g.num_nodes();
        let mut counts = vec![0u32; n + 1];
        for &(s, d) in g.edges() {
            counts[s as usize + 1] += 1;
            counts[d as usize + 1] += 1;
        }
        let mut indptr = counts;
        for i in 1..=n {
            indptr[i] += indptr[i - 1];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; indptr[n] as usize];
        for &(s, d) in g.edges() {
            indices[cursor[s as usize] as usize] = d;
            cursor[s as usize] += 1;
            indices[cursor[d as usize] as usize] = s;
            cursor[d as usize] += 1;
        }
        // sort each row for deterministic iteration + binary search
        for i in 0..n {
            let (a, b) = (indptr[i] as usize, indptr[i + 1] as usize);
            indices[a..b].sort_unstable();
        }
        Csr { indptr, indices }
    }

    pub fn num_nodes(&self) -> usize {
        self.indptr.len() - 1
    }

    /// Directed entry count (2 × undirected edges).
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.indices[self.indptr[i] as usize..self.indptr[i + 1] as usize]
    }

    pub fn degree(&self, i: usize) -> usize {
        (self.indptr[i + 1] - self.indptr[i]) as usize
    }

    pub fn has_edge(&self, s: usize, d: usize) -> bool {
        self.neighbors(s).binary_search(&(d as u32)).is_ok()
    }

    /// Bytes of the CSR arrays — the GraphSplit cost model's measure of
    /// what crossing the CPU→NPU boundary with raw structure would cost.
    pub fn bytes(&self) -> usize {
        (self.indptr.len() + self.indices.len()) * 4
    }

    /// [`degree_order`] over this adjacency.
    pub fn degree_order(&self) -> Vec<u32> {
        degree_order(&self.indptr)
    }

    /// [`rcm_order`] over this adjacency.
    pub fn rcm_order(&self) -> Vec<u32> {
        rcm_order(&self.indptr, &self.indices)
    }
}

// ---------------------------------------------------------------------------
// Node orderings — the CacheG locality pass
// ---------------------------------------------------------------------------
//
// Free functions over raw indptr/indices slices so both this adjacency
// and `tensor::CsrMat` operands (which carry values) can be ordered
// without conversion. Every function returns a permutation in
// `perm[new] = old` convention: position `new` of the reordered node
// space holds original node `old`.

/// Stable degree-descending node order (`perm[new] = old`). Hub rows
/// come first, so nnz-balanced lane dispatch drains them while light
/// tail rows are still plentiful — ties keep their original relative
/// order, making the permutation deterministic across runs.
pub fn degree_order(indptr: &[u32]) -> Vec<u32> {
    let n = indptr.len().saturating_sub(1);
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(indptr[i as usize + 1] - indptr[i as usize]));
    order
}

/// Reverse Cuthill–McKee order (`perm[new] = old`): BFS from a
/// minimum-degree seed per connected component, neighbors enqueued in
/// ascending-degree order, final sequence reversed. Clusters every
/// node's neighborhood into nearby row indices (bandwidth reduction), so
/// SpMM's gather of neighbor feature rows walks memory near-sequentially
/// — the CacheG locality effect, as a compile-time pass.
pub fn rcm_order(indptr: &[u32], indices: &[u32]) -> Vec<u32> {
    let n = indptr.len().saturating_sub(1);
    let deg = |i: usize| indptr[i + 1] - indptr[i];
    let mut seeds: Vec<u32> = (0..n as u32).collect();
    seeds.sort_by_key(|&i| deg(i as usize));
    let mut visited = vec![false; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut nbuf: Vec<u32> = Vec::new();
    for &s in &seeds {
        if visited[s as usize] {
            continue;
        }
        visited[s as usize] = true;
        let mut head = order.len();
        order.push(s);
        while head < order.len() {
            let u = order[head] as usize;
            head += 1;
            nbuf.clear();
            for &v in &indices[indptr[u] as usize..indptr[u + 1] as usize] {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    nbuf.push(v);
                }
            }
            nbuf.sort_by_key(|&v| deg(v as usize));
            order.extend_from_slice(&nbuf);
        }
    }
    order.reverse();
    order
}

/// Inverse of a permutation: `perm[new] = old` ⇒ `inv[old] = new`.
pub fn inverse_permutation(perm: &[u32]) -> Vec<u32> {
    let mut inv = vec![0u32; perm.len()];
    for (new, &old) in perm.iter().enumerate() {
        inv[old as usize] = new as u32;
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    fn star() -> Graph {
        Graph::new(5, &[(0, 1), (0, 2), (0, 3), (0, 4)])
    }

    #[test]
    fn star_structure() {
        let csr = Csr::from_graph(&star());
        assert_eq!(csr.num_nodes(), 5);
        assert_eq!(csr.nnz(), 8);
        assert_eq!(csr.neighbors(0), &[1, 2, 3, 4]);
        assert_eq!(csr.neighbors(3), &[0]);
        assert_eq!(csr.degree(0), 4);
        assert_eq!(csr.degree(2), 1);
    }

    #[test]
    fn has_edge_both_directions() {
        let csr = Csr::from_graph(&star());
        assert!(csr.has_edge(0, 3));
        assert!(csr.has_edge(3, 0));
        assert!(!csr.has_edge(1, 2));
    }

    #[test]
    fn empty_graph() {
        let csr = Csr::from_graph(&Graph::new(3, &[]));
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.neighbors(1), &[] as &[u32]);
    }

    fn assert_valid_permutation(perm: &[u32], n: usize) {
        assert_eq!(perm.len(), n);
        let mut seen = vec![false; n];
        for &p in perm {
            assert!(!seen[p as usize], "node {p} appears twice");
            seen[p as usize] = true;
        }
    }

    #[test]
    fn degree_order_is_descending_and_stable() {
        let g = Graph::new(6, &[(0, 1), (0, 2), (0, 3), (4, 5), (1, 2)]);
        let csr = Csr::from_graph(&g);
        let order = csr.degree_order();
        assert_valid_permutation(&order, 6);
        for w in order.windows(2) {
            assert!(
                csr.degree(w[0] as usize) >= csr.degree(w[1] as usize),
                "degree order not descending"
            );
        }
        // ties keep original node order: nodes 1 and 2 both have degree 2
        let p1 = order.iter().position(|&v| v == 1).unwrap();
        let p2 = order.iter().position(|&v| v == 2).unwrap();
        assert!(p1 < p2, "stable tie-break violated");
    }

    /// Max |inv[u] - inv[v]| over edges — what RCM minimizes.
    fn bandwidth(csr: &Csr, perm: &[u32]) -> usize {
        let inv = inverse_permutation(perm);
        let mut bw = 0usize;
        for u in 0..csr.num_nodes() {
            for &v in csr.neighbors(u) {
                bw = bw.max((inv[u] as i64 - inv[v as usize] as i64).unsigned_abs() as usize);
            }
        }
        bw
    }

    #[test]
    fn rcm_reduces_bandwidth_on_shuffled_path() {
        // a path graph relabeled by a stride permutation: identity order
        // has bandwidth ~n/2, RCM must recover the chain layout
        let n = 41usize;
        let relabel: Vec<u32> = (0..n as u32).map(|i| (i * 17) % n as u32).collect();
        let edges: Vec<(u32, u32)> =
            (0..n - 1).map(|i| (relabel[i], relabel[i + 1])).collect();
        let csr = Csr::from_graph(&Graph::new(n, &edges));
        let identity: Vec<u32> = (0..n as u32).collect();
        let rcm = csr.rcm_order();
        assert_valid_permutation(&rcm, n);
        let before = bandwidth(&csr, &identity);
        let after = bandwidth(&csr, &rcm);
        assert!(after < before, "rcm bandwidth {after} !< identity {before}");
        assert_eq!(after, 1, "a path graph relabels to bandwidth 1");
    }

    #[test]
    fn rcm_covers_disconnected_components_and_isolates() {
        let g = Graph::new(9, &[(0, 1), (1, 2), (4, 5), (5, 6)]);
        // nodes 3, 7, 8 are isolated
        let csr = Csr::from_graph(&g);
        let rcm = csr.rcm_order();
        assert_valid_permutation(&rcm, 9);
    }

    #[test]
    fn inverse_permutation_roundtrips() {
        let perm = vec![3u32, 0, 4, 1, 2];
        let inv = inverse_permutation(&perm);
        for (new, &old) in perm.iter().enumerate() {
            assert_eq!(inv[old as usize] as usize, new);
        }
        assert_eq!(inverse_permutation(&inv), perm);
    }

    #[test]
    fn prop_csr_consistent_with_edge_list() {
        forall("csr consistency", 50, |g| {
            let n = g.dim(40);
            let m = g.usize(0, 3 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (g.rng().usize(n) as u32, g.rng().usize(n) as u32))
                .collect();
            let graph = Graph::new(n, &edges);
            let csr = Csr::from_graph(&graph);
            // nnz == 2m
            assert_eq!(csr.nnz(), 2 * graph.num_edges());
            // symmetric
            for &(s, d) in graph.edges() {
                assert!(csr.has_edge(s as usize, d as usize));
                assert!(csr.has_edge(d as usize, s as usize));
            }
            // degrees sum to nnz
            let total: usize = (0..n).map(|i| csr.degree(i)).sum();
            assert_eq!(total, csr.nnz());
            // degrees_with_self agrees
            let deg = graph.degrees_with_self();
            for i in 0..n {
                assert_eq!(deg[i], csr.degree(i) as f32 + 1.0);
            }
        });
    }
}
