//! Compressed Sparse Row adjacency — the memory-efficient storage the
//! CPU side iterates over (degree math, incremental updates, streaming).

use super::Graph;

/// CSR over the *undirected* graph: each edge appears in both rows.
/// Self loops are not stored (GraphConv adds them arithmetically).
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    /// Row offsets, length n+1.
    pub indptr: Vec<u32>,
    /// Column indices, sorted within each row.
    pub indices: Vec<u32>,
}

impl Csr {
    pub fn from_graph(g: &Graph) -> Csr {
        let n = g.num_nodes();
        let mut counts = vec![0u32; n + 1];
        for &(s, d) in g.edges() {
            counts[s as usize + 1] += 1;
            counts[d as usize + 1] += 1;
        }
        let mut indptr = counts;
        for i in 1..=n {
            indptr[i] += indptr[i - 1];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; indptr[n] as usize];
        for &(s, d) in g.edges() {
            indices[cursor[s as usize] as usize] = d;
            cursor[s as usize] += 1;
            indices[cursor[d as usize] as usize] = s;
            cursor[d as usize] += 1;
        }
        // sort each row for deterministic iteration + binary search
        for i in 0..n {
            let (a, b) = (indptr[i] as usize, indptr[i + 1] as usize);
            indices[a..b].sort_unstable();
        }
        Csr { indptr, indices }
    }

    pub fn num_nodes(&self) -> usize {
        self.indptr.len() - 1
    }

    /// Directed entry count (2 × undirected edges).
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.indices[self.indptr[i] as usize..self.indptr[i + 1] as usize]
    }

    pub fn degree(&self, i: usize) -> usize {
        (self.indptr[i + 1] - self.indptr[i]) as usize
    }

    pub fn has_edge(&self, s: usize, d: usize) -> bool {
        self.neighbors(s).binary_search(&(d as u32)).is_ok()
    }

    /// Bytes of the CSR arrays — the GraphSplit cost model's measure of
    /// what crossing the CPU→NPU boundary with raw structure would cost.
    pub fn bytes(&self) -> usize {
        (self.indptr.len() + self.indices.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    fn star() -> Graph {
        Graph::new(5, &[(0, 1), (0, 2), (0, 3), (0, 4)])
    }

    #[test]
    fn star_structure() {
        let csr = Csr::from_graph(&star());
        assert_eq!(csr.num_nodes(), 5);
        assert_eq!(csr.nnz(), 8);
        assert_eq!(csr.neighbors(0), &[1, 2, 3, 4]);
        assert_eq!(csr.neighbors(3), &[0]);
        assert_eq!(csr.degree(0), 4);
        assert_eq!(csr.degree(2), 1);
    }

    #[test]
    fn has_edge_both_directions() {
        let csr = Csr::from_graph(&star());
        assert!(csr.has_edge(0, 3));
        assert!(csr.has_edge(3, 0));
        assert!(!csr.has_edge(1, 2));
    }

    #[test]
    fn empty_graph() {
        let csr = Csr::from_graph(&Graph::new(3, &[]));
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.neighbors(1), &[] as &[u32]);
    }

    #[test]
    fn prop_csr_consistent_with_edge_list() {
        forall("csr consistency", 50, |g| {
            let n = g.dim(40);
            let m = g.usize(0, 3 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (g.rng().usize(n) as u32, g.rng().usize(n) as u32))
                .collect();
            let graph = Graph::new(n, &edges);
            let csr = Csr::from_graph(&graph);
            // nnz == 2m
            assert_eq!(csr.nnz(), 2 * graph.num_edges());
            // symmetric
            for &(s, d) in graph.edges() {
                assert!(csr.has_edge(s as usize, d as usize));
                assert!(csr.has_edge(d as usize, s as usize));
            }
            // degrees sum to nnz
            let total: usize = (0..n).map(|i| csr.degree(i)).sum();
            assert_eq!(total, csr.nnz());
            // degrees_with_self agrees
            let deg = graph.degrees_with_self();
            for i in 0..n {
                assert_eq!(deg[i], csr.degree(i) as f32 + 1.0);
            }
        });
    }
}
