//! SymG: packed-triangular storage for the symmetric normalization matrix
//! (paper Fig. 15).
//!
//! The GraphConv norm matrix is symmetric, so only the upper triangle and
//! the diagonal need DRAM residency — n(n+1)/2 elements instead of n²,
//! halving both the memory footprint and the DMA traffic the simulator
//! charges for fetching it (the savings CacheG then amortizes across
//! layers).

use crate::tensor::Mat;

/// Upper-triangular (row-major, including diagonal) packed symmetric matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct SymG {
    n: usize,
    packed: Vec<f32>,
}

impl SymG {
    /// Pack a symmetric matrix. Panics if the input is not square or not
    /// symmetric within `tol` (catching accidental use on attention masks,
    /// which are *not* symmetric after sampling).
    pub fn pack(m: &Mat, tol: f32) -> SymG {
        assert_eq!(m.rows, m.cols, "SymG needs a square matrix");
        let n = m.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                assert!(
                    (m[(i, j)] - m[(j, i)]).abs() <= tol,
                    "not symmetric at ({i},{j}): {} vs {}",
                    m[(i, j)],
                    m[(j, i)]
                );
            }
        }
        let mut packed = Vec::with_capacity(n * (n + 1) / 2);
        for i in 0..n {
            packed.extend_from_slice(&m.row(i)[i..]);
        }
        SymG { n, packed }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Packed index of (i ≤ j).
    #[inline]
    fn pidx(&self, i: usize, j: usize) -> usize {
        debug_assert!(i <= j && j < self.n);
        // row i starts after sum_{r<i} (n - r) = i(2n - i + 1)/2 entries
        i * (2 * self.n - i + 1) / 2 + (j - i)
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let (a, b) = if i <= j { (i, j) } else { (j, i) };
        self.packed[self.pidx(a, b)]
    }

    /// Expand back to a dense matrix (what the DMA engine reconstructs in
    /// SRAM after a compressed transfer).
    pub fn unpack(&self) -> Mat {
        Mat::from_fn(self.n, self.n, |i, j| self.get(i, j))
    }

    /// Stored bytes (the DMA-traffic win vs `4n²`).
    pub fn bytes(&self) -> usize {
        self.packed.len() * 4
    }

    /// Dense bytes this replaces.
    pub fn dense_bytes(&self) -> usize {
        self.n * self.n * 4
    }

    /// Compression ratio achieved (≈ 2 for large n).
    pub fn ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.bytes() as f64
    }

    /// `out = self @ rhs` without unpacking — symmetric matmul reading
    /// each packed entry once and scattering to both (i,j) and (j,i).
    pub fn matmul(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.n, rhs.rows, "symg matmul dims");
        let mut out = Mat::zeros(self.n, rhs.cols);
        let cols = rhs.cols;
        for i in 0..self.n {
            // diagonal
            let dii = self.get(i, i);
            if dii != 0.0 {
                let r = rhs.row(i);
                let o = out.row_mut(i);
                for c in 0..cols {
                    o[c] += dii * r[c];
                }
            }
            for j in (i + 1)..self.n {
                let v = self.packed[self.pidx(i, j)];
                if v == 0.0 {
                    continue;
                }
                // out[i] += v * rhs[j]; out[j] += v * rhs[i]
                let (ri, rj) = (i * cols, j * cols);
                for c in 0..cols {
                    out.data[ri + c] += v * rhs.data[rj + c];
                }
                for c in 0..cols {
                    out.data[rj + c] += v * rhs.data[ri + c];
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::util::propcheck::forall;

    fn sym_from_graph(n: usize, edges: &[(u32, u32)]) -> (Mat, SymG) {
        let g = Graph::new(n, edges);
        let m = g.norm_adjacency(n);
        let s = SymG::pack(&m, 0.0);
        (m, s)
    }

    #[test]
    fn roundtrip_exact() {
        let (m, s) = sym_from_graph(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (0, 5)]);
        assert_eq!(s.unpack(), m);
    }

    #[test]
    fn halves_storage() {
        let (_, s) = sym_from_graph(100, &[(0, 1), (5, 7)]);
        assert_eq!(s.bytes(), 100 * 101 / 2 * 4);
        assert!(s.ratio() > 1.9 && s.ratio() <= 2.0);
    }

    #[test]
    fn get_is_symmetric_access() {
        let (m, s) = sym_from_graph(5, &[(0, 4), (1, 3)]);
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(s.get(i, j), m[(i, j)]);
                assert_eq!(s.get(j, i), s.get(i, j));
            }
        }
    }

    #[test]
    #[should_panic(expected = "not symmetric")]
    fn rejects_asymmetric() {
        let mut m = Mat::zeros(3, 3);
        m[(0, 1)] = 1.0; // no mirror
        SymG::pack(&m, 1e-9);
    }

    #[test]
    #[should_panic(expected = "square")]
    fn rejects_non_square() {
        SymG::pack(&Mat::zeros(2, 3), 0.0);
    }

    #[test]
    fn prop_packed_matmul_matches_dense() {
        forall("symg matmul", 40, |g| {
            let n = g.dim(24);
            let f = g.dim(12);
            let m = g.usize(0, 2 * n + 1);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (g.rng().usize(n) as u32, g.rng().usize(n) as u32))
                .collect();
            let graph = Graph::new(n, &edges);
            let dense = graph.norm_adjacency(n);
            let sym = SymG::pack(&dense, 0.0);
            let rhs = Mat::from_vec(n, f, g.vec_f32(n * f));
            let want = dense.matmul(&rhs);
            let got = sym.matmul(&rhs);
            assert!(
                got.max_abs_diff(&want) < 1e-4,
                "diff {}",
                got.max_abs_diff(&want)
            );
        });
    }
}
