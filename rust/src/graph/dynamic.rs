//! GrAd + NodePad: dynamic-graph support (paper Figs. 10–11).
//!
//! A [`DynamicGraph`] owns a mutable edge set with a fixed NodePad
//! capacity and *incrementally* maintains the masks that the compiled
//! artifacts take as runtime inputs — the whole point of GrAd is that an
//! edge update is a cheap mask edit, not a model recompile.
//!
//! Masks come in two representations, both **lazy**: the dense
//! capacity² matrices ([`DynamicGraph::norm`]/[`DynamicGraph::neg_bias`])
//! materialize on first request and are then edited in place per update
//! (adding edge (u,v) changes deg(u)/deg(v), which rescales row/col u and
//! v — O(deg u + deg v) touched entries instead of an n² rebuild); the
//! CSR norm ([`DynamicGraph::norm_csr`]) is rebuilt O(n + m) from the
//! live neighbor sets when dirty. Sparse-aggregation engines only ever
//! ask for the CSR form, so they never allocate a capacity² buffer at
//! all — which is exactly what lets shard memory scale with nnz.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

use super::Graph;
use crate::tensor::{CsrMat, Mat};

/// Mutable graph with incrementally-maintained GrAd masks.
#[derive(Debug, Clone)]
pub struct DynamicGraph {
    capacity: usize,
    num_nodes: usize,
    edges: BTreeSet<(u32, u32)>,
    /// Per-node neighbor sets (undirected, no self).
    nbrs: Vec<BTreeSet<u32>>,
    /// Dense norm mask (capacity × capacity), materialized lazily and
    /// then maintained incrementally.
    norm: Option<Mat>,
    /// Dense additive attention mask, lazy + incremental like `norm`.
    neg_bias: Option<Mat>,
    /// CSR norm, rebuilt O(n + m) on demand when structure changed.
    norm_csr: Option<CsrMat>,
    /// Update statistics (for the serving metrics).
    pub updates: usize,
}

impl DynamicGraph {
    /// Start from an initial graph. `capacity` is the NodePad size every
    /// mask is laid out at (the compiled model's static input shape).
    /// Masks are not materialized here — the first `norm()`/`neg_bias()`/
    /// `norm_csr()` call builds its representation.
    pub fn new(initial: &Graph, capacity: usize) -> Result<DynamicGraph> {
        if capacity < initial.num_nodes() {
            bail!(
                "NodePad capacity {} < initial nodes {}",
                capacity,
                initial.num_nodes()
            );
        }
        let mut nbrs = vec![BTreeSet::new(); capacity];
        for &(s, d) in initial.edges() {
            nbrs[s as usize].insert(d);
            nbrs[d as usize].insert(s);
        }
        Ok(DynamicGraph {
            capacity,
            num_nodes: initial.num_nodes(),
            edges: initial.edges().iter().copied().collect(),
            nbrs,
            norm: None,
            neg_bias: None,
            norm_csr: None,
            updates: 0,
        })
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        let key = (u.min(v) as u32, u.max(v) as u32);
        self.edges.contains(&key)
    }

    /// Live neighbor set of `u` (undirected, no self loops) — the
    /// incrementally-maintained sets the mask updates run on, exposed so
    /// consumers (fleet shards, halo accounting) never rebuild adjacency
    /// from a snapshot.
    pub fn neighbors(&self, u: usize) -> &BTreeSet<u32> {
        &self.nbrs[u]
    }

    /// The GrAd norm mask, ready to feed the `*_grad` artifacts.
    /// Materializes the dense capacity² matrix on first call; sparse
    /// engines use [`DynamicGraph::norm_csr`] instead and never pay this.
    pub fn norm(&mut self) -> &Mat {
        if self.norm.is_none() {
            self.norm = Some(self.snapshot().norm_adjacency(self.capacity));
        }
        self.norm.as_ref().unwrap()
    }

    /// The GrAd norm as a CSR operand (the `SpMM` binding): same values
    /// as [`DynamicGraph::norm`], O(nnz) storage, rebuilt O(n + m) from
    /// the live neighbor sets only when the structure changed since the
    /// last call.
    pub fn norm_csr(&mut self) -> &CsrMat {
        if self.norm_csr.is_none() {
            self.norm_csr = Some(self.snapshot().norm_csr(self.capacity));
        }
        self.norm_csr.as_ref().unwrap()
    }

    /// The GrAx1 additive mask for GAT artifacts (lazy like `norm`).
    pub fn neg_bias(&mut self) -> &Mat {
        if self.neg_bias.is_none() {
            self.neg_bias = Some(self.snapshot().neg_bias(self.capacity));
        }
        self.neg_bias.as_ref().unwrap()
    }

    /// Recompute row/col `u` of the dense norm mask (and its diagonal) —
    /// called for the two endpoints of an update and only them. A no-op
    /// until the dense mask has been materialized.
    fn refresh_norm_node(&mut self, u: usize) {
        let du = self.nbrs[u].len() as f32 + 1.0;
        let inv_u = 1.0 / du.sqrt();
        let entries: Vec<(usize, f32)> = self.nbrs[u]
            .iter()
            .map(|&v| {
                let v = v as usize;
                let dv = self.nbrs[v].len() as f32 + 1.0;
                (v, inv_u * (1.0 / dv.sqrt()))
            })
            .collect();
        let cap = self.capacity;
        if let Some(norm) = self.norm.as_mut() {
            // clear the row & column
            for j in 0..cap {
                norm[(u, j)] = 0.0;
                norm[(j, u)] = 0.0;
            }
            for &(v, val) in &entries {
                norm[(u, v)] = val;
                norm[(v, u)] = val;
            }
            norm[(u, u)] = inv_u * inv_u;
        }
    }

    /// Whether the dense capacity² norm has ever been materialized —
    /// sparse-aggregation engines must keep this false (the no-n×n-slab
    /// guarantee is testable, not aspirational).
    pub fn dense_norm_materialized(&self) -> bool {
        self.norm.is_some()
    }

    /// Every structure update lands here: the dense masks are edited in
    /// place (when materialized); the CSR form is invalidated wholesale
    /// (its rebuild is O(n + m), cheaper than in-place array surgery).
    fn note_structure_change(&mut self) {
        self.norm_csr = None;
        self.updates += 1;
    }

    /// Add a node (must stay within capacity). New nodes start isolated;
    /// NodePad guarantees the compiled shape already accommodates them.
    pub fn add_node(&mut self) -> Result<usize> {
        if self.num_nodes == self.capacity {
            bail!(
                "NodePad capacity {} exhausted — recompile with a larger \
                 capacity (the failure mode NodePad exists to avoid)",
                self.capacity
            );
        }
        let id = self.num_nodes;
        self.num_nodes += 1;
        // isolated node: self-loop only
        self.refresh_norm_node(id);
        if let Some(nb) = self.neg_bias.as_mut() {
            nb[(id, id)] = 0.0;
        }
        self.note_structure_change();
        Ok(id)
    }

    /// Add an undirected edge. Returns false if it already existed.
    pub fn add_edge(&mut self, u: usize, v: usize) -> Result<bool> {
        self.check_nodes(u, v)?;
        let key = (u.min(v) as u32, u.max(v) as u32);
        if !self.edges.insert(key) {
            return Ok(false);
        }
        self.nbrs[u].insert(v as u32);
        self.nbrs[v].insert(u as u32);
        self.refresh_norm_node(u);
        self.refresh_norm_node(v);
        if let Some(nb) = self.neg_bias.as_mut() {
            nb[(u, v)] = 0.0;
            nb[(v, u)] = 0.0;
        }
        self.note_structure_change();
        Ok(true)
    }

    /// Remove an undirected edge. Returns false if absent.
    pub fn remove_edge(&mut self, u: usize, v: usize) -> Result<bool> {
        self.check_nodes(u, v)?;
        let key = (u.min(v) as u32, u.max(v) as u32);
        if !self.edges.remove(&key) {
            return Ok(false);
        }
        self.nbrs[u].remove(&(v as u32));
        self.nbrs[v].remove(&(u as u32));
        self.refresh_norm_node(u);
        self.refresh_norm_node(v);
        if let Some(nb) = self.neg_bias.as_mut() {
            nb[(u, v)] = crate::ops::NEG_MASK;
            nb[(v, u)] = crate::ops::NEG_MASK;
        }
        self.note_structure_change();
        Ok(true)
    }

    fn check_nodes(&self, u: usize, v: usize) -> Result<()> {
        if u >= self.num_nodes || v >= self.num_nodes {
            bail!(
                "node out of range: ({u},{v}) with {} active nodes",
                self.num_nodes
            );
        }
        if u == v {
            bail!("self loops are implicit in GraphConv; refusing ({u},{u})");
        }
        Ok(())
    }

    /// Snapshot the current structure as an immutable [`Graph`].
    pub fn snapshot(&self) -> Graph {
        let edges: Vec<(u32, u32)> = self.edges.iter().copied().collect();
        Graph::new(self.num_nodes, &edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    fn base() -> DynamicGraph {
        let g = Graph::new(4, &[(0, 1), (1, 2)]);
        DynamicGraph::new(&g, 6).unwrap()
    }

    #[test]
    fn masks_match_full_rebuild_after_updates() {
        let mut dg = base();
        // materialize first so the updates run the *incremental* path
        let _ = dg.norm();
        let _ = dg.neg_bias();
        dg.add_edge(2, 3).unwrap();
        dg.add_edge(0, 3).unwrap();
        dg.remove_edge(1, 2).unwrap();
        let want_norm = dg.snapshot().norm_adjacency(6);
        assert!(
            dg.norm().max_abs_diff(&want_norm) < 1e-6,
            "incremental norm drifted"
        );
        let want_bias = dg.snapshot().neg_bias(6);
        assert!(dg.neg_bias().max_abs_diff(&want_bias) < 1e-6);
    }

    #[test]
    fn lazy_masks_build_correctly_after_updates() {
        // the other ordering: churn first, masks requested afterwards
        let mut dg = base();
        dg.add_edge(2, 3).unwrap();
        dg.remove_edge(0, 1).unwrap();
        let want_norm = dg.snapshot().norm_adjacency(6);
        assert!(dg.norm().max_abs_diff(&want_norm) < 1e-6);
        let want_bias = dg.snapshot().neg_bias(6);
        assert!(dg.neg_bias().max_abs_diff(&want_bias) < 1e-6);
    }

    #[test]
    fn norm_csr_tracks_churn_and_matches_dense() {
        let mut dg = base();
        assert_eq!(dg.norm_csr().to_dense(), dg.snapshot().norm_adjacency(6));
        dg.add_edge(2, 3).unwrap();
        dg.add_edge(0, 2).unwrap();
        dg.remove_edge(1, 2).unwrap();
        let got = dg.norm_csr().clone();
        assert_eq!(got.to_dense(), dg.snapshot().norm_adjacency(6));
        // unchanged structure: the cached CSR is reused (same contents)
        assert_eq!(dg.norm_csr(), &got);
        let id = dg.add_node().unwrap();
        dg.add_edge(id, 0).unwrap();
        assert_eq!(dg.norm_csr().to_dense(), dg.snapshot().norm_adjacency(6));
    }

    #[test]
    fn add_edge_idempotent() {
        let mut dg = base();
        assert!(dg.add_edge(0, 2).unwrap());
        assert!(!dg.add_edge(0, 2).unwrap());
        assert!(!dg.add_edge(2, 0).unwrap()); // either direction
        assert_eq!(dg.num_edges(), 3);
    }

    #[test]
    fn remove_missing_edge_is_noop() {
        let mut dg = base();
        assert!(!dg.remove_edge(0, 3).unwrap());
        assert_eq!(dg.num_edges(), 2);
    }

    #[test]
    fn add_node_until_capacity() {
        let mut dg = base();
        assert_eq!(dg.add_node().unwrap(), 4);
        assert_eq!(dg.add_node().unwrap(), 5);
        let err = dg.add_node().unwrap_err().to_string();
        assert!(err.contains("capacity"), "{err}");
    }

    #[test]
    fn new_node_connects_correctly() {
        let mut dg = base();
        let id = dg.add_node().unwrap();
        dg.add_edge(id, 0).unwrap();
        let want = dg.snapshot().norm_adjacency(6);
        assert!(dg.norm().max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn rejects_self_loop_and_out_of_range() {
        let mut dg = base();
        assert!(dg.add_edge(1, 1).is_err());
        assert!(dg.add_edge(0, 4).is_err()); // node 4 not active yet
    }

    #[test]
    fn capacity_below_initial_rejected() {
        let g = Graph::new(4, &[(0, 1)]);
        assert!(DynamicGraph::new(&g, 3).is_err());
    }

    /// Interleaved AddNode/AddEdge/RemoveEdge sequences, checked against
    /// a plain mirror model: the CSR of the snapshot must keep its
    /// invariants (sorted, deduplicated, symmetric, self-loop-free rows
    /// that match the mirror edge set exactly) and the incrementally-
    /// maintained masks must equal a from-scratch rebuild — i.e. every
    /// update invalidated exactly what it had to.
    #[test]
    fn prop_interleaved_grad_preserves_csr_and_masks() {
        use crate::graph::Csr;
        forall("grad interleaved node/edge round-trips", 20, |gen| {
            let n0 = gen.usize(2, 8);
            let cap = n0 + gen.usize(1, 6);
            let mut dg = DynamicGraph::new(&Graph::new(n0, &[]), cap).unwrap();
            // materialize the dense masks so updates take the incremental
            // in-place path (the lazy rebuild has its own test)
            let _ = dg.norm();
            let _ = dg.neg_bias();
            // mirror model: plain node count + undirected edge set
            let mut nodes = n0;
            let mut edges = std::collections::BTreeSet::new();
            for _ in 0..gen.usize(1, 40) {
                match gen.usize(0, 3) {
                    0 if nodes < cap => {
                        assert_eq!(dg.add_node().unwrap(), nodes);
                        nodes += 1;
                    }
                    1 => {
                        let u = gen.rng().usize(nodes);
                        let v = gen.rng().usize(nodes);
                        if u == v {
                            continue;
                        }
                        let key = (u.min(v) as u32, u.max(v) as u32);
                        let changed = edges.insert(key);
                        assert_eq!(
                            dg.add_edge(u, v).unwrap(),
                            changed,
                            "add_edge changed-ness must match the mirror"
                        );
                    }
                    _ => {
                        let u = gen.rng().usize(nodes);
                        let v = gen.rng().usize(nodes);
                        if u == v {
                            continue;
                        }
                        let key = (u.min(v) as u32, u.max(v) as u32);
                        let removed = edges.remove(&key);
                        assert_eq!(dg.remove_edge(u, v).unwrap(), removed);
                    }
                }
            }
            assert_eq!(dg.num_nodes(), nodes);
            assert_eq!(dg.num_edges(), edges.len());

            // CSR invariants on the snapshot
            let snap = dg.snapshot();
            let csr = Csr::from_graph(&snap);
            assert_eq!(csr.num_nodes(), nodes);
            assert_eq!(csr.nnz(), 2 * edges.len());
            for i in 0..nodes {
                let row = csr.neighbors(i);
                for w in row.windows(2) {
                    assert!(w[0] < w[1], "row {i} not strictly sorted: {row:?}");
                }
                for &j in row {
                    assert_ne!(j as usize, i, "self loop surfaced in CSR");
                    assert!(csr.has_edge(j as usize, i), "asymmetric CSR");
                }
            }
            for &(u, v) in &edges {
                assert!(csr.has_edge(u as usize, v as usize));
            }

            // mask invalidation: incremental == rebuild after the whole
            // interleaving, at full NodePad capacity
            let want_norm = snap.norm_adjacency(cap);
            assert!(
                dg.norm().max_abs_diff(&want_norm) < 1e-5,
                "norm drifted {}",
                dg.norm().max_abs_diff(&want_norm)
            );
            let want_bias = snap.neg_bias(cap);
            assert!(dg.neg_bias().max_abs_diff(&want_bias) < 1e-5);

            // the CSR norm tracks the same structure exactly
            assert_eq!(dg.norm_csr().to_dense(), snap.norm_adjacency(cap));
        });
    }

    /// The duplicate-add case above never counts as applied; make the
    /// `updates` telemetry contract explicit for an interleaved sequence.
    #[test]
    fn updates_counter_tracks_effective_changes() {
        let mut dg = base();
        let before = dg.updates;
        assert!(dg.add_edge(0, 2).unwrap());
        assert!(!dg.add_edge(0, 2).unwrap()); // duplicate: not counted
        dg.add_node().unwrap();
        assert!(dg.remove_edge(0, 2).unwrap());
        assert!(!dg.remove_edge(0, 2).unwrap()); // absent: not counted
        assert_eq!(dg.updates - before, 3);
    }

    #[test]
    fn prop_incremental_equals_rebuild() {
        forall("grad incremental == rebuild", 25, |gen| {
            let n = gen.usize(2, 12);
            let cap = n + gen.usize(0, 4);
            let graph = Graph::new(n, &[]);
            let mut dg = DynamicGraph::new(&graph, cap).unwrap();
            let _ = dg.norm();
            let _ = dg.neg_bias();
            for _ in 0..gen.usize(1, 30) {
                let u = gen.rng().usize(n);
                let v = gen.rng().usize(n);
                if u == v {
                    continue;
                }
                if gen.chance(0.7) {
                    dg.add_edge(u, v).unwrap();
                } else {
                    dg.remove_edge(u, v).unwrap();
                }
            }
            let want = dg.snapshot().norm_adjacency(cap);
            assert!(
                dg.norm().max_abs_diff(&want) < 1e-5,
                "drift {}",
                dg.norm().max_abs_diff(&want)
            );
            let want_nb = dg.snapshot().neg_bias(cap);
            assert!(dg.neg_bias().max_abs_diff(&want_nb) < 1e-5);
        });
    }
}
