//! TOML-subset parser.
//!
//! Supported grammar (everything the manifest and run configs need):
//! - `[section]` / `[section.sub.sub2]` table headers
//! - `key = value` with string (`"…"` or `'…'`), integer, float, boolean,
//!   and flat arrays of those
//! - `#` comments, blank lines
//!
//! Not supported (rejected with errors, not silently misparsed): inline
//! tables, multi-line strings, datetimes, dotted keys, array-of-tables.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`tiles = 4` as f64).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: dotted section path → (key → value).
#[derive(Debug, Clone, Default)]
pub struct Document {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Document> {
        let mut doc = Document::default();
        let mut current = String::new(); // root section ""
        doc.sections.entry(current.clone()).or_default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let ctx = || format!("line {}: {raw:?}", lineno + 1);
            if let Some(rest) = line.strip_prefix('[') {
                if line.starts_with("[[") {
                    bail!("{}: array-of-tables unsupported", ctx());
                }
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("{}: unterminated section", ctx()))?
                    .trim();
                if name.is_empty() {
                    bail!("{}: empty section name", ctx());
                }
                current = name.to_string();
                doc.sections.entry(current.clone()).or_default();
            } else {
                let eq = line
                    .find('=')
                    .ok_or_else(|| anyhow!("{}: expected key = value", ctx()))?;
                let key = line[..eq].trim();
                if key.is_empty() {
                    bail!("{}: empty key", ctx());
                }
                if key.contains('.') {
                    bail!("{}: dotted keys unsupported", ctx());
                }
                let value = parse_value(line[eq + 1..].trim())
                    .with_context(ctx)?;
                let section = doc.sections.get_mut(&current).unwrap();
                if section.insert(key.to_string(), value).is_some() {
                    bail!("{}: duplicate key {key:?} in [{current}]", ctx());
                }
            }
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<Document> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Document::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Every section name in the document (the root section is `""`).
    /// Schema layers use this to reject unknown sections loudly instead
    /// of silently ignoring a typo'd `[topolgy]`.
    pub fn section_names(&self) -> Vec<&str> {
        self.sections.keys().map(|k| k.as_str()).collect()
    }

    /// All section names with the given first path component, e.g.
    /// `sections_under("artifact")` → `["artifact.gcn_stagr_cora", …]`.
    pub fn sections_under(&self, prefix: &str) -> Vec<&str> {
        let dotted = format!("{prefix}.");
        self.sections
            .keys()
            .filter(|k| k.starts_with(&dotted))
            .map(|k| k.as_str())
            .collect()
    }

    pub fn section(&self, name: &str) -> Option<&BTreeMap<String, Value>> {
        self.sections.get(name)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// Typed accessors with good error messages.
    pub fn str_of(&self, section: &str, key: &str) -> Result<&str> {
        self.get(section, key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("missing string [{section}] {key}"))
    }

    pub fn int_of(&self, section: &str, key: &str) -> Result<i64> {
        self.get(section, key)
            .and_then(Value::as_int)
            .ok_or_else(|| anyhow!("missing integer [{section}] {key}"))
    }

    pub fn float_of(&self, section: &str, key: &str) -> Result<f64> {
        self.get(section, key)
            .and_then(Value::as_float)
            .ok_or_else(|| anyhow!("missing float [{section}] {key}"))
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key)
            .and_then(Value::as_float)
            .unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string must not start a comment.
    let mut in_str: Option<char> = None;
    for (i, c) in line.char_indices() {
        match (in_str, c) {
            (None, '#') => return &line[..i],
            (None, '"') | (None, '\'') => in_str = Some(c),
            (Some(q), c) if c == q => in_str = None,
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    // strings
    for quote in ['"', '\''] {
        if let Some(rest) = s.strip_prefix(quote) {
            let inner = rest
                .strip_suffix(quote)
                .ok_or_else(|| anyhow!("unterminated string: {s:?}"))?;
            if inner.contains(quote) {
                bail!("stray quote inside string: {s:?}");
            }
            return Ok(Value::Str(inner.to_string()));
        }
    }
    // arrays
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array: {s:?}"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = split_top_level(inner)?
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {s:?}")
}

/// Split an array body on commas that are not inside strings.
fn split_top_level(s: &str) -> Result<Vec<&str>> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str: Option<char> = None;
    for (i, c) in s.char_indices() {
        match (in_str, c) {
            (None, ',') => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            (None, '"') | (None, '\'') => in_str = Some(c),
            (Some(q), c) if c == q => in_str = None,
            _ => {}
        }
    }
    if in_str.is_some() {
        bail!("unterminated string in array: {s:?}");
    }
    parts.push(&s[start..]);
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = Document::parse(
            r#"
# generated
[dataset.cora]
path = 'cora.gnnt'
nodes = 2708
capacity = 3000

[artifact.gcn_stagr_cora]
inputs = 'norm,x,w1,b1,w2,b2'
shapes = '2708x2708;2708x1433'
"#,
        )
        .unwrap();
        assert_eq!(doc.str_of("dataset.cora", "path").unwrap(), "cora.gnnt");
        assert_eq!(doc.int_of("dataset.cora", "nodes").unwrap(), 2708);
        assert_eq!(
            doc.sections_under("artifact"),
            vec!["artifact.gcn_stagr_cora"]
        );
    }

    #[test]
    fn value_types() {
        let doc = Document::parse(
            "a = 1\nb = 2.5\nc = true\nd = \"x\"\ne = [1, 2, 3]\nf = -7\ng = 1_000",
        )
        .unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_int(), Some(1));
        assert_eq!(doc.get("", "b").unwrap().as_float(), Some(2.5));
        assert_eq!(doc.get("", "a").unwrap().as_float(), Some(1.0)); // int→float ok
        assert_eq!(doc.get("", "c").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("", "d").unwrap().as_str(), Some("x"));
        assert_eq!(
            doc.get("", "e").unwrap().as_array().unwrap().len(),
            3
        );
        assert_eq!(doc.get("", "f").unwrap().as_int(), Some(-7));
        assert_eq!(doc.get("", "g").unwrap().as_int(), Some(1000));
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = Document::parse("# top\n\nx = 5 # trailing\ns = \"has # inside\"\n").unwrap();
        assert_eq!(doc.int_of("", "x").unwrap(), 5);
        assert_eq!(doc.str_of("", "s").unwrap(), "has # inside");
    }

    #[test]
    fn single_quoted_strings() {
        let doc = Document::parse("p = 'a/b.gnnt'").unwrap();
        assert_eq!(doc.str_of("", "p").unwrap(), "a/b.gnnt");
    }

    #[test]
    fn errors_are_loud() {
        assert!(Document::parse("[unclosed").is_err());
        assert!(Document::parse("novalue =").is_err());
        assert!(Document::parse("= 3").is_err());
        assert!(Document::parse("x = \"unterminated").is_err());
        assert!(Document::parse("[[aot]]").is_err());
        assert!(Document::parse("a.b = 1").is_err());
        assert!(Document::parse("x = 1\nx = 2").is_err());
        assert!(Document::parse("x = @nope").is_err());
    }

    #[test]
    fn array_of_strings_with_commas() {
        let doc = Document::parse("xs = [\"a,b\", 'c']").unwrap();
        let arr = doc.get("", "xs").unwrap().as_array().unwrap().to_vec();
        assert_eq!(arr[0].as_str(), Some("a,b"));
        assert_eq!(arr[1].as_str(), Some("c"));
    }

    #[test]
    fn missing_keys_reported_with_location() {
        let doc = Document::parse("[hw]\ntiles = 2").unwrap();
        let err = doc.str_of("hw", "name").unwrap_err().to_string();
        assert!(err.contains("[hw] name"), "{err}");
    }
}
