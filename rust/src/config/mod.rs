//! Configuration system: a TOML-subset parser (serde/toml are unavailable
//! offline) plus the typed hardware & run configurations built on it.
//!
//! The same parser reads `artifacts/manifest.toml` (written by the python
//! AOT path) and user-supplied run configs (see `configs/*.toml`).

pub mod parse;
pub mod schema;

pub use parse::{Document, Value};
pub use schema::{DeviceKind, HardwareConfig, RunConfig};
