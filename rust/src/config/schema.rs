//! Typed hardware & run configurations.
//!
//! The NPU presets model the paper's two testbeds at the architectural
//! level described in §IV (FlexNN-like: DPU tile array + DSP + local SRAM
//! + DMA) with constants from Intel's public product briefs:
//!
//! - **Series 2** (Core Ultra 256V, "NPU4"): 4 NPU tiles, ~48 plat TOPS
//!   INT8 → 4096 INT8 MACs/tile at ~1.46 GHz.
//! - **Series 1** (Core Ultra 165H, "NPU3720"): 2 NPU tiles, ~11.5 plat
//!   TOPS INT8 → 4096 INT8 MACs/tile at ~1.4 GHz.
//!
//! DSP throughput and the DMA/SRAM constants are calibrated once against
//! the paper's own Fig. 4/5 latency-breakdown percentages and then frozen
//! (DESIGN.md §7). CPU/GPU models cover the Fig. 22/23 comparisons.

use anyhow::{bail, Result};

use super::parse::Document;

/// Which execution engine a device model simulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// NPU: DPU tile array + DSP (the simulator's full pipeline).
    Npu,
    /// Host CPU cost model (control-flow friendly, lower parallelism).
    Cpu,
    /// Integrated GPU cost model (high FLOPs, per-op launch overhead).
    Gpu,
}

impl std::fmt::Display for DeviceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeviceKind::Npu => write!(f, "NPU"),
            DeviceKind::Cpu => write!(f, "CPU"),
            DeviceKind::Gpu => write!(f, "GPU"),
        }
    }
}

/// Hardware model parameters (one per simulated device).
#[derive(Debug, Clone)]
pub struct HardwareConfig {
    pub name: String,
    pub kind: DeviceKind,

    // ---- DPU (NPU) / compute core (CPU, GPU) ----
    /// NPU tiles (paper: Series 2 has 4, Series 1 has 2). 1 for CPU/GPU.
    pub tiles: usize,
    /// INT8 MACs per tile per cycle (FP16 = half, FP32 = quarter).
    pub macs_per_tile_int8: usize,
    /// DPU / core clock in GHz.
    pub clock_ghz: f64,
    /// Elementwise vector lanes per tile per cycle (f32 lanes).
    pub vector_lanes: usize,

    // ---- DSP (control-heavy ops) ----
    /// DSP clock in GHz (paper: "runs at a lower frequency than the DPU").
    pub dsp_clock_ghz: f64,
    /// Elements the DSP retires per cycle for *vectorizable* ops.
    pub dsp_lanes: usize,
    /// Cycles per element for control-heavy ops (Select/Gather/branching):
    /// models the serialization the paper attributes to the DSP.
    pub dsp_control_cycles_per_elem: f64,

    // ---- memory system ----
    /// Local SRAM (activations + weights) per tile, bytes.
    pub sram_bytes_per_tile: usize,
    /// DRAM↔SRAM DMA bandwidth, GB/s.
    pub dma_gbps: f64,
    /// Fixed DMA transfer setup latency, µs.
    pub dma_setup_us: f64,
    /// Host→device transfer bandwidth for GraphSplit boundary crossings
    /// GB/s (shared-memory SoC: high, but not free).
    pub xfer_gbps: f64,
    /// Fixed per-crossing latency (driver + fence), µs.
    pub xfer_setup_us: f64,

    // ---- per-op overheads ----
    /// Fixed scheduling overhead per op (command issue), µs.
    pub op_overhead_us: f64,

    // ---- energy model (DESIGN.md §7) ----
    /// Energy per INT8 MAC, picojoules (FP16 2x, FP32 4x).
    pub pj_per_mac_int8: f64,
    /// Energy per DSP element-op, picojoules.
    pub pj_per_dsp_elem: f64,
    /// Energy per byte moved over DMA (DRAM), picojoules.
    pub pj_per_dram_byte: f64,
    /// Energy per byte touched in SRAM, picojoules.
    pub pj_per_sram_byte: f64,
    /// Idle/static power, watts (charged over op latency).
    pub static_watts: f64,
}

impl HardwareConfig {
    /// Intel Core Ultra Series 2 NPU ("256V", NPU4-like): 4 tiles.
    pub fn npu_series2() -> Self {
        HardwareConfig {
            name: "npu-series2".into(),
            kind: DeviceKind::Npu,
            tiles: 4,
            macs_per_tile_int8: 4096,
            clock_ghz: 1.46,
            vector_lanes: 512,
            dsp_clock_ghz: 0.97,
            dsp_lanes: 8,
            dsp_control_cycles_per_elem: 6.0,
            sram_bytes_per_tile: 2 * 1024 * 1024,
            dma_gbps: 34.0, // LPDDR5X-8533 share
            dma_setup_us: 1.2,
            xfer_gbps: 40.0,
            xfer_setup_us: 12.0,
            op_overhead_us: 2.0,
            pj_per_mac_int8: 0.25,
            pj_per_dsp_elem: 2.0,
            pj_per_dram_byte: 18.0,
            pj_per_sram_byte: 0.6,
            static_watts: 0.25,
        }
    }

    /// Intel Core Ultra Series 1 NPU ("165H", NPU3720-like): 2 tiles.
    pub fn npu_series1() -> Self {
        HardwareConfig {
            name: "npu-series1".into(),
            kind: DeviceKind::Npu,
            tiles: 2,
            macs_per_tile_int8: 4096,
            clock_ghz: 1.40,
            vector_lanes: 512,
            dsp_clock_ghz: 0.85,
            dsp_lanes: 8,
            dsp_control_cycles_per_elem: 6.0,
            sram_bytes_per_tile: 2 * 1024 * 1024,
            dma_gbps: 28.0, // LPDDR5-6400 share
            dma_setup_us: 1.4,
            xfer_gbps: 32.0,
            xfer_setup_us: 14.0,
            op_overhead_us: 2.2,
            pj_per_mac_int8: 0.30,
            pj_per_dsp_elem: 2.2,
            pj_per_dram_byte: 20.0,
            pj_per_sram_byte: 0.7,
            static_watts: 0.3,
        }
    }

    /// Host CPU model (Core Ultra P-cores, AVX2): strong on control flow,
    /// weak on dense MACs relative to the NPU; no DSP split.
    pub fn cpu() -> Self {
        HardwareConfig {
            name: "cpu".into(),
            kind: DeviceKind::Cpu,
            tiles: 6, // P-cores used by the inference runtime
            macs_per_tile_int8: 64,
            clock_ghz: 3.8,
            vector_lanes: 16,
            // CPU executes "DSP-class" ops on the same cores: fast.
            dsp_clock_ghz: 3.8,
            dsp_lanes: 16,
            dsp_control_cycles_per_elem: 1.0,
            sram_bytes_per_tile: 2 * 1024 * 1024, // L2 slice
            dma_gbps: 60.0,                       // cache-hierarchy fill
            dma_setup_us: 0.05,
            xfer_gbps: f64::INFINITY, // no crossing: it *is* the host
            xfer_setup_us: 0.0,
            op_overhead_us: 0.3,
            pj_per_mac_int8: 6.0,
            pj_per_dsp_elem: 6.0,
            pj_per_dram_byte: 25.0,
            pj_per_sram_byte: 1.0,
            static_watts: 9.0,
        }
    }

    /// Integrated Arc GPU model: high dense throughput, per-op launch
    /// overhead that dominates small control-heavy graphs.
    pub fn gpu() -> Self {
        HardwareConfig {
            name: "gpu".into(),
            kind: DeviceKind::Gpu,
            tiles: 8, // Xe cores
            macs_per_tile_int8: 1024,
            clock_ghz: 2.2,
            vector_lanes: 128,
            dsp_clock_ghz: 2.2,
            dsp_lanes: 128,
            dsp_control_cycles_per_elem: 2.5,
            sram_bytes_per_tile: 192 * 1024,
            dma_gbps: 50.0,
            dma_setup_us: 0.8,
            xfer_gbps: 25.0,
            xfer_setup_us: 8.0,
            op_overhead_us: 12.0, // kernel-launch latency
            pj_per_mac_int8: 1.2,
            pj_per_dsp_elem: 3.0,
            pj_per_dram_byte: 20.0,
            pj_per_sram_byte: 0.8,
            static_watts: 5.0,
        }
    }

    /// Canonical preset names — **the** device name table. Every layer
    /// that parses a device name (CLI `--devices`, fleet rosters,
    /// deployment-spec topologies) resolves through [`Self::preset`], so
    /// this list is the single source of truth for what's valid.
    pub fn preset_names() -> &'static [&'static str] {
        &["series2", "series1", "cpu", "gpu"]
    }

    /// Look up a preset by name. The error lists every valid name (and
    /// accepted aliases) so an operator can fix a roster without reading
    /// source.
    pub fn preset(name: &str) -> Result<Self> {
        Ok(match name {
            "npu-series2" | "series2" | "npu" => Self::npu_series2(),
            "npu-series1" | "series1" => Self::npu_series1(),
            "cpu" => Self::cpu(),
            "gpu" => Self::gpu(),
            other => bail!(
                "unknown hardware preset {other:?} — valid names: \
                 series2 (aliases npu-series2, npu), series1 (alias \
                 npu-series1), cpu, gpu"
            ),
        })
    }

    /// All presets (for the device-comparison figures).
    pub fn all_presets() -> Vec<Self> {
        vec![
            Self::npu_series2(),
            Self::npu_series1(),
            Self::cpu(),
            Self::gpu(),
        ]
    }

    /// MACs per cycle for a dtype across all tiles.
    pub fn macs_per_cycle(&self, dtype_bytes: usize) -> f64 {
        let per_tile = match dtype_bytes {
            1 => self.macs_per_tile_int8 as f64,
            2 => self.macs_per_tile_int8 as f64 / 2.0,
            _ => self.macs_per_tile_int8 as f64 / 4.0,
        };
        per_tile * self.tiles as f64
    }

    /// Peak dense-MAC throughput in TOPS for a dtype (2 ops per MAC).
    pub fn tops(&self, dtype_bytes: usize) -> f64 {
        2.0 * self.macs_per_cycle(dtype_bytes) * self.clock_ghz / 1e3
    }

    /// Total SRAM bytes.
    pub fn sram_bytes(&self) -> usize {
        self.sram_bytes_per_tile * self.tiles
    }

    /// Apply overrides from a TOML `[hardware]` section (experiments /
    /// ablations tune constants without recompiling).
    pub fn with_overrides(mut self, doc: &Document, section: &str) -> Self {
        if let Some(v) = doc.get(section, "tiles").and_then(|v| v.as_int()) {
            self.tiles = v as usize;
        }
        self.clock_ghz = doc.float_or(section, "clock_ghz", self.clock_ghz);
        self.dsp_clock_ghz = doc.float_or(section, "dsp_clock_ghz", self.dsp_clock_ghz);
        self.dma_gbps = doc.float_or(section, "dma_gbps", self.dma_gbps);
        self.op_overhead_us = doc.float_or(section, "op_overhead_us", self.op_overhead_us);
        self
    }
}

/// A full run configuration (CLI + config file).
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Dataset name ("cora" | "citeseer").
    pub dataset: String,
    /// Model family ("gcn" | "gat" | "sage_mean" | "sage_max").
    pub model: String,
    /// Optimization variant (model-specific; see `ops::build`).
    pub variant: String,
    /// Hardware preset for the simulated timing.
    pub hardware: HardwareConfig,
    /// Artifacts directory.
    pub artifacts_dir: std::path::PathBuf,
    /// NodePad capacity override (0 = dataset default).
    pub capacity: usize,
    /// Iterations for latency measurements.
    pub iters: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "cora".into(),
            model: "gcn".into(),
            variant: "stagr".into(),
            hardware: HardwareConfig::npu_series2(),
            artifacts_dir: "artifacts".into(),
            capacity: 0,
            iters: 10,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series2_tops_matches_product_brief() {
        // Intel quotes ~48 platform TOPS INT8 for the Series 2 NPU.
        let hw = HardwareConfig::npu_series2();
        let tops = hw.tops(1);
        assert!((40.0..56.0).contains(&tops), "INT8 TOPS {tops}");
    }

    #[test]
    fn series1_tops_matches_product_brief() {
        // Intel quotes ~11.5 NPU TOPS for Series 1 — ours is 2 tiles.
        let hw = HardwareConfig::npu_series1();
        let tops = hw.tops(1);
        assert!((9.0..26.0).contains(&tops), "INT8 TOPS {tops}");
    }

    #[test]
    fn int8_doubles_fp16_throughput() {
        let hw = HardwareConfig::npu_series2();
        assert_eq!(hw.macs_per_cycle(1), 2.0 * hw.macs_per_cycle(2));
        assert_eq!(hw.macs_per_cycle(2), 2.0 * hw.macs_per_cycle(4));
    }

    #[test]
    fn series2_has_double_tiles() {
        assert_eq!(HardwareConfig::npu_series2().tiles, 4);
        assert_eq!(HardwareConfig::npu_series1().tiles, 2);
    }

    #[test]
    fn npu_dense_beats_cpu_and_gpu_beats_cpu() {
        let npu = HardwareConfig::npu_series2().tops(2);
        let gpu = HardwareConfig::gpu().tops(2);
        let cpu = HardwareConfig::cpu().tops(2);
        assert!(npu > gpu && gpu > cpu, "npu {npu} gpu {gpu} cpu {cpu}");
    }

    #[test]
    fn dsp_slower_than_dpu_on_npu() {
        let hw = HardwareConfig::npu_series2();
        assert!(hw.dsp_clock_ghz < hw.clock_ghz);
    }

    #[test]
    fn preset_lookup() {
        assert!(HardwareConfig::preset("npu-series2").is_ok());
        assert!(HardwareConfig::preset("series1").is_ok());
        assert!(HardwareConfig::preset("tpu").is_err());
    }

    #[test]
    fn overrides_apply() {
        let doc = Document::parse("[hardware]\ntiles = 8\ndma_gbps = 99.0").unwrap();
        let hw = HardwareConfig::npu_series2().with_overrides(&doc, "hardware");
        assert_eq!(hw.tiles, 8);
        assert_eq!(hw.dma_gbps, 99.0);
    }
}
