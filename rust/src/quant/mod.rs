//! QuantGr: symmetric static INT8 quantization (paper §IV-C).
//!
//! Mirrors `python/compile/quantize.py`: scales are computed once during
//! calibration (zero point 0, equal positive/negative range), weights ship
//! pre-quantized in the artifacts, activations are quantized in-graph with
//! the baked static scales. This module provides the rust-side calibration
//! (for models quantized on the fly by the coordinator) and the error
//! telemetry the accuracy bench reports.

use crate::tensor::Mat;

/// Symmetric scale mapping |x| ≤ absmax onto int8 [−127, 127].
pub fn scale_for(absmax: f32) -> f32 {
    if absmax > 0.0 {
        absmax / 127.0
    } else {
        1.0
    }
}

/// Calibration: absmax scale of a tensor, optionally percentile-clipped.
pub fn calibrate(m: &Mat, percentile: f64) -> f32 {
    assert!((0.0..=100.0).contains(&percentile));
    if m.data.is_empty() {
        return 1.0;
    }
    if percentile >= 100.0 {
        let absmax = m.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        return scale_for(absmax);
    }
    let mut mags: Vec<f32> = m.data.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((percentile / 100.0) * (mags.len() - 1) as f64).round() as usize;
    scale_for(mags[idx.min(mags.len() - 1)])
}

/// Quantize to int8 with round-to-nearest and clamping.
pub fn quantize(m: &Mat, scale: f32) -> Vec<i8> {
    m.data
        .iter()
        .map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8)
        .collect()
}

/// Dequantize back to f32.
pub fn dequantize(q: &[i8], scale: f32, rows: usize, cols: usize) -> Mat {
    assert_eq!(q.len(), rows * cols);
    Mat::from_vec(rows, cols, q.iter().map(|&v| v as f32 * scale).collect())
}

/// INT8 × INT8 → INT32 → FP32 MatMul (the QuantGr datapath, exact
/// integer accumulation as on the DPU).
pub fn qmatmul(xq: &[i8], wq: &[i8], m: usize, k: usize, n: usize,
               x_scale: f32, w_scale: f32) -> Mat {
    assert_eq!(xq.len(), m * k);
    assert_eq!(wq.len(), k * n);
    let mut out = Mat::zeros(m, n);
    let s = x_scale * w_scale;
    for i in 0..m {
        for j in 0..n {
            let mut acc: i32 = 0;
            for kk in 0..k {
                acc += xq[i * k + kk] as i32 * wq[kk * n + j] as i32;
            }
            out[(i, j)] = acc as f32 * s;
        }
    }
    out
}

/// Quantization-error telemetry for EXPERIMENTS.md / the accuracy bench.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantError {
    pub max_abs_err: f32,
    pub rel_err: f32,
    /// Fraction of rows whose argmax (prediction) is unchanged.
    pub argmax_agreement: f64,
}

pub fn quant_error(reference: &Mat, quantized: &Mat) -> QuantError {
    assert_eq!(reference.shape(), quantized.shape());
    let max_abs_err = reference.max_abs_diff(quantized);
    let denom = reference.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let a = reference.argmax_rows();
    let b = quantized.argmax_rows();
    let agree = a.iter().zip(&b).filter(|(x, y)| x == y).count();
    QuantError {
        max_abs_err,
        rel_err: if denom > 0.0 { max_abs_err / denom } else { 0.0 },
        argmax_agreement: agree as f64 / a.len().max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::util::Rng;

    fn rand_mat(seed: u64, r: usize, c: usize, scale: f32) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(r, c, |_, _| ((rng.f64() * 2.0 - 1.0) as f32) * scale)
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let m = rand_mat(1, 13, 7, 3.0);
        let s = calibrate(&m, 100.0);
        let q = quantize(&m, s);
        let back = dequantize(&q, s, 13, 7);
        assert!(m.max_abs_diff(&back) <= s / 2.0 + 1e-6);
    }

    #[test]
    fn symmetric_range_hit() {
        let m = Mat::from_vec(1, 2, vec![-5.0, 5.0]);
        let s = calibrate(&m, 100.0);
        let q = quantize(&m, s);
        assert_eq!(q, vec![-127, 127]);
    }

    #[test]
    fn percentile_clips_outliers() {
        let mut data = vec![0.01f32; 999];
        data.push(100.0); // outlier
        let m = Mat::from_vec(1, 1000, data);
        let full = calibrate(&m, 100.0);
        let clipped = calibrate(&m, 99.0);
        assert!(clipped < full / 100.0);
    }

    #[test]
    fn qmatmul_matches_f32_for_exact_ints() {
        // integers ≤127 with scale 1 are exactly representable
        let xq: Vec<i8> = vec![1, 2, 3, 4, 5, 6];
        let wq: Vec<i8> = vec![1, 0, 0, 1, 1, 1];
        let out = qmatmul(&xq, &wq, 2, 3, 2, 1.0, 1.0);
        // [[1,2,3],[4,5,6]] @ [[1,0],[0,1],[1,1]] = [[4,5],[10,11]]
        assert_eq!(out.data, vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn int32_accumulation_exact_at_large_k() {
        let k = 4096;
        let xq = vec![127i8; k];
        let wq = vec![127i8; k];
        let out = qmatmul(&xq, &wq, 1, k, 1, 1.0, 1.0);
        assert_eq!(out.data[0], (127i64 * 127 * k as i64) as f32);
    }

    #[test]
    fn quant_error_telemetry() {
        let a = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let b = Mat::from_vec(2, 2, vec![0.9, 0.0, 0.0, 1.1]);
        let e = quant_error(&a, &b);
        assert!((e.max_abs_err - 0.1).abs() < 1e-6);
        assert_eq!(e.argmax_agreement, 1.0);
    }

    #[test]
    fn prop_quantized_matmul_close_to_f32() {
        forall("qmatmul close to f32", 20, |g| {
            let m = g.dim(12);
            let k = g.dim(24);
            let n = g.dim(8);
            let x = Mat::from_vec(m, k, g.vec_f32(m * k));
            let w = Mat::from_vec(k, n, g.vec_f32(k * n));
            let sx = calibrate(&x, 100.0);
            let sw = calibrate(&w, 100.0);
            let got = qmatmul(&quantize(&x, sx), &quantize(&w, sw), m, k, n, sx, sw);
            let want = x.matmul(&w);
            // error bound: k * (sx/2 * |w|max + sw/2 * |x|max) loose form
            let bound = (k as f32) * (sx + sw) * 3.0 + 1e-3;
            assert!(
                got.max_abs_diff(&want) < bound,
                "err {} bound {}",
                got.max_abs_diff(&want),
                bound
            );
        });
    }
}
