//! Minimal argument parser (clap is unavailable offline): subcommand +
//! `--flag value` / `--switch` options with typed accessors.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: `prog <subcommand> [--key value|--switch] [positional…]`.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw argv (excluding the program name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn str_opt(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize_opt(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_opt(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    /// Comma-separated list option: `--devices series2,cpu` →
    /// `["series2", "cpu"]`. Empty segments are dropped.
    pub fn str_list_opt(&self, key: &str, default: &str) -> Vec<String> {
        self.str_opt(key, default)
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect()
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig20 --dataset citeseer --iters 5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("fig20"));
        assert_eq!(a.str_opt("dataset", "cora"), "citeseer");
        assert_eq!(a.usize_opt("iters", 1).unwrap(), 5);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("serve --rate=100.5");
        assert_eq!(a.f64_opt("rate", 1.0).unwrap(), 100.5);
    }

    #[test]
    fn defaults_when_missing() {
        let a = parse("fig4");
        assert_eq!(a.str_opt("hw", "series2"), "series2");
        assert_eq!(a.usize_opt("n", 7).unwrap(), 7);
    }

    #[test]
    fn positional_args() {
        let a = parse("inspect model.hlo.txt");
        assert_eq!(a.positional, vec!["model.hlo.txt"]);
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse("x --iters soon");
        assert!(a.usize_opt("iters", 1).is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = parse("run --fast");
        assert!(a.has("fast"));
    }

    #[test]
    fn list_option_splits_on_commas() {
        let a = parse("fleet --devices series2,series1,cpu");
        assert_eq!(
            a.str_list_opt("devices", "series2"),
            vec!["series2", "series1", "cpu"]
        );
        assert_eq!(a.str_list_opt("missing", "a,b"), vec!["a", "b"]);
        let b = parse("fleet --devices series2,,");
        assert_eq!(b.str_list_opt("devices", "x"), vec!["series2"]);
    }
}
