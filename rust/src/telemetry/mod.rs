//! End-to-end telemetry: query tracing, per-op plan profiling, and the
//! cost-model calibration loop.
//!
//! GraNNite's techniques are all justified by knowing where time goes on
//! the accelerator (GraphSplit's cost model, EffOp's control-path
//! accounting, GraSp's density pricing) — but a serving deployment could
//! only report end-of-run histograms. This module makes a single query
//! observable end to end:
//!
//! - **Span recorder** ([`Recorder`] over per-worker [`SpanRing`]s):
//!   typed spans `admission → queue → batch → engine round → halo →
//!   per-op kernel`, keyed by the trace ID minted at
//!   [`crate::serve::Serving::query`] (the query id) and propagated
//!   through router fan-out, so a fleet query stitches into one
//!   [`Trace`] across shard rings.
//! - **Plan profiler** ([`profile::PlanProfiler`], attached to
//!   [`crate::engine::PlanInstance`]): per-step wall time keyed by
//!   `OpKind` and row bucket, paired with the [`crate::npu::cost`]
//!   prediction — surfaced as a [`profile::CalibrationReport`] and a
//!   fitted [`crate::npu::cost::CostScales`] the cost model can apply.
//! - **Exporters** ([`export`]): Prometheus text format and JSON lines
//!   over [`crate::metrics::Snapshot`] + trace/calibration data.
//!
//! Overhead contract: telemetry is always compiled and **off by
//! default**. A disabled [`Recorder`] is `Option::None` inside — every
//! call is a branch, no `Instant::now()`, no lock, no allocation — and a
//! disabled [`Telemetry::plan_profiler`] returns `None`, so the planned
//! engine's zero-steady-state-allocation proof
//! (`rust/tests/plan_alloc.rs`) extends over the disabled paths.
//! Enabled, each worker owns a fixed-capacity ring (allocated once, at
//! `recorder()` time) and recording is one short mutex on a ring no
//! other worker touches.

pub mod export;
pub mod profile;

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::ops::ExecPlan;

pub use profile::{CalibrationReport, CalibrationRow, PlanProfiler, StepObs};

/// Shard id spans recorded by the fleet router carry (the router is not
/// a shard; `usize::MAX` can never collide with a worker index).
pub const ROUTER_SHARD: usize = usize::MAX;

/// Fibonacci-hash multiplier for deterministic per-trace sampling: a
/// trace is sampled iff `trace_id * PHI64 <= threshold`, so every worker
/// makes the same keep/drop call for one trace without coordination.
const PHI64: u64 = 0x9E37_79B9_7F4A_7C15;

/// Telemetry knobs, normally set via the `[telemetry]` spec section
/// ([`crate::serve::spec::TelemetrySpec`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TelemetryConfig {
    /// Master switch; `false` (the default) keeps every hot path
    /// branch-only and allocation-free.
    pub enabled: bool,
    /// Span capacity of each per-worker ring (oldest spans overwritten).
    pub ring_capacity: usize,
    /// Fraction of traces recorded, in (0, 1]; 1.0 records everything.
    pub sample_rate: f64,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { enabled: false, ring_capacity: 4096, sample_rate: 1.0 }
    }
}

/// What a span measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Admission decision (point span; value = pending depth).
    Admission,
    /// Time from enqueue to the start of the serving inference round.
    Queue,
    /// Batch assembly: flush start to inference start (value = batch size).
    Batch,
    /// One engine inference round (the query's compute latency).
    EngineRound,
    /// Halo exchange charged to this round (value = bytes shipped).
    Halo,
    /// One plan step (fused chain / kernel) inside the round.
    Op,
    /// Router fan-out decision (point span; value = target shard).
    Route,
}

impl SpanKind {
    /// Stable lowercase mnemonic (exporter label / CLI column).
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Admission => "admission",
            SpanKind::Queue => "queue",
            SpanKind::Batch => "batch",
            SpanKind::EngineRound => "engine_round",
            SpanKind::Halo => "halo",
            SpanKind::Op => "op",
            SpanKind::Route => "route",
        }
    }
}

/// One recorded span. `start_us` is relative to the owning
/// [`Telemetry`]'s epoch, so spans from different worker rings share a
/// clock and stitch into ordered traces.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    /// Trace (= query) id this span belongs to.
    pub trace_id: u64,
    /// Recording worker (or [`ROUTER_SHARD`]).
    pub shard: usize,
    /// What was measured.
    pub kind: SpanKind,
    /// Static detail label (op kind name, "admit"/"shed", …).
    pub label: &'static str,
    /// Start, µs since the telemetry epoch.
    pub start_us: f64,
    /// Duration, µs (0 for point spans).
    pub dur_us: f64,
    /// Kind-specific magnitude (batch size, halo bytes, pending depth).
    pub value: u64,
}

#[derive(Debug)]
struct RingInner {
    spans: Vec<Span>,
    head: usize,
    total: u64,
}

/// Fixed-capacity span ring. The backing `Vec` is allocated once at
/// construction; `push` never allocates (fill phase appends into reserved
/// capacity, wrap phase overwrites in place).
#[derive(Debug)]
pub struct SpanRing {
    cap: usize,
    inner: Mutex<RingInner>,
}

impl SpanRing {
    fn new(cap: usize) -> SpanRing {
        let cap = cap.max(1);
        SpanRing {
            cap,
            inner: Mutex::new(RingInner {
                spans: Vec::with_capacity(cap),
                head: 0,
                total: 0,
            }),
        }
    }

    fn push(&self, span: Span) {
        let mut g = self.inner.lock().unwrap();
        if g.spans.len() < self.cap {
            g.spans.push(span);
        } else {
            let h = g.head;
            g.spans[h] = span;
        }
        g.head = (g.head + 1) % self.cap;
        g.total += 1;
    }

    /// All retained spans (unordered) plus the total ever pushed.
    fn snapshot(&self) -> (Vec<Span>, u64) {
        let g = self.inner.lock().unwrap();
        (g.spans.clone(), g.total)
    }
}

#[derive(Clone)]
struct RecorderInner {
    ring: Arc<SpanRing>,
    epoch: Instant,
    shard: usize,
    threshold: u64,
}

/// A worker's handle for recording spans. Cloneable; a disabled recorder
/// (from a disabled [`Telemetry`]) is a `None` inside and every method
/// is a branch-only no-op — no clock read, no lock, no allocation.
#[derive(Clone)]
pub struct Recorder {
    inner: Option<RecorderInner>,
}

impl Recorder {
    /// A recorder that drops everything (what disabled telemetry hands
    /// out).
    pub fn disabled() -> Recorder {
        Recorder { inner: None }
    }

    /// Whether spans are actually being kept.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Microseconds since the telemetry epoch; `0.0` when disabled (the
    /// disabled path must not touch the clock).
    #[inline]
    pub fn now_us(&self) -> f64 {
        match &self.inner {
            Some(r) => r.epoch.elapsed().as_secs_f64() * 1e6,
            None => 0.0,
        }
    }

    /// Whether `trace_id` falls inside the sample (deterministic across
    /// workers); `false` when disabled.
    #[inline]
    pub fn sampled(&self, trace_id: u64) -> bool {
        match &self.inner {
            Some(r) => trace_id.wrapping_mul(PHI64) <= r.threshold,
            None => false,
        }
    }

    /// Record one span (dropped when disabled or the trace is sampled
    /// out).
    #[inline]
    pub fn record(
        &self,
        trace_id: u64,
        kind: SpanKind,
        label: &'static str,
        start_us: f64,
        dur_us: f64,
        value: u64,
    ) {
        if let Some(r) = &self.inner {
            if trace_id.wrapping_mul(PHI64) <= r.threshold {
                r.ring.push(Span {
                    trace_id,
                    shard: r.shard,
                    kind,
                    label,
                    start_us,
                    dur_us,
                    value,
                });
            }
        }
    }
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Recorder(enabled={})", self.enabled())
    }
}

/// One stitched trace: every retained span sharing a trace id, ordered
/// by start time, possibly spanning several shard rings (a fleet query).
#[derive(Debug, Clone)]
pub struct Trace {
    /// The query id minted at [`crate::serve::Serving::query`].
    pub trace_id: u64,
    /// Member spans, sorted by `start_us`.
    pub spans: Vec<Span>,
}

impl Trace {
    /// Queue + engine time of the query itself (the spans recorded under
    /// this trace's own id, not batch-mates') — the sort key for
    /// "slowest traces".
    pub fn latency_us(&self) -> f64 {
        self.spans
            .iter()
            .filter(|s| matches!(s.kind, SpanKind::Queue | SpanKind::EngineRound))
            .map(|s| s.dur_us)
            .sum()
    }

    /// Number of distinct recording workers (router excluded).
    pub fn shard_count(&self) -> usize {
        let mut shards: Vec<usize> = self
            .spans
            .iter()
            .map(|s| s.shard)
            .filter(|&s| s != ROUTER_SHARD)
            .collect();
        shards.sort_unstable();
        shards.dedup();
        shards.len()
    }
}

/// The deployment-wide telemetry hub: owns the epoch, hands out
/// per-worker [`Recorder`]s and per-shard [`profile::ProfileSink`]s, and
/// assembles traces and the calibration report on demand.
pub struct Telemetry {
    cfg: TelemetryConfig,
    epoch: Instant,
    rings: Mutex<Vec<Arc<SpanRing>>>,
    sinks: Mutex<Vec<(usize, Arc<profile::ProfileSink>)>>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry").field("cfg", &self.cfg).finish()
    }
}

impl Telemetry {
    /// A telemetry hub with the given knobs (shared across every worker
    /// of one deployment).
    pub fn new(cfg: TelemetryConfig) -> Arc<Telemetry> {
        Arc::new(Telemetry {
            cfg,
            epoch: Instant::now(),
            rings: Mutex::new(Vec::new()),
            sinks: Mutex::new(Vec::new()),
        })
    }

    /// The off-by-default hub: recorders are no-ops, profilers are
    /// `None`, nothing is retained.
    pub fn disabled() -> Arc<Telemetry> {
        Telemetry::new(TelemetryConfig::default())
    }

    /// Master switch state.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The knobs this hub was built with.
    pub fn config(&self) -> &TelemetryConfig {
        &self.cfg
    }

    /// A recorder for worker `shard`. Enabled hubs allocate the ring
    /// here (once, outside any hot path) and register it for
    /// [`Telemetry::traces`]; disabled hubs return the no-op recorder.
    pub fn recorder(&self, shard: usize) -> Recorder {
        if !self.cfg.enabled {
            return Recorder::disabled();
        }
        let ring = Arc::new(SpanRing::new(self.cfg.ring_capacity));
        self.rings.lock().unwrap().push(Arc::clone(&ring));
        let rate = self.cfg.sample_rate;
        let threshold = if rate >= 1.0 {
            u64::MAX
        } else {
            (rate.max(0.0) * u64::MAX as f64) as u64
        };
        Recorder {
            inner: Some(RecorderInner { ring, epoch: self.epoch, shard, threshold }),
        }
    }

    /// A per-plan profiler feeding shard `shard`'s calibration sink, or
    /// `None` when disabled (the engine then skips all timing). Multiple
    /// plans on one shard (the incremental engine's tile cache) share
    /// one sink, so their observations merge.
    pub fn plan_profiler(&self, shard: usize, plan: &ExecPlan) -> Option<PlanProfiler> {
        if !self.cfg.enabled {
            return None;
        }
        let sink = self.sink_for(shard);
        Some(PlanProfiler::new(sink, plan))
    }

    fn sink_for(&self, shard: usize) -> Arc<profile::ProfileSink> {
        let mut sinks = self.sinks.lock().unwrap();
        if let Some((_, s)) = sinks.iter().find(|(id, _)| *id == shard) {
            return Arc::clone(s);
        }
        let s = Arc::new(profile::ProfileSink::new(shard));
        sinks.push((shard, Arc::clone(&s)));
        s
    }

    /// Per-step observations of shard `shard`'s most recent engine
    /// round, consumed (the shard loop turns these into `Op` spans).
    pub fn drain_last_round(&self, shard: usize) -> Vec<StepObs> {
        let sinks = self.sinks.lock().unwrap();
        match sinks.iter().find(|(id, _)| *id == shard) {
            Some((_, s)) => s.drain_last_round(),
            None => Vec::new(),
        }
    }

    /// Every retained span across all worker rings (unordered).
    pub fn spans(&self) -> Vec<Span> {
        let rings = self.rings.lock().unwrap();
        let mut out = Vec::new();
        for ring in rings.iter() {
            let (spans, _) = ring.snapshot();
            out.extend(spans);
        }
        out
    }

    /// Total spans ever recorded vs retained (rings overwrite oldest).
    pub fn span_counts(&self) -> (u64, usize) {
        let rings = self.rings.lock().unwrap();
        let mut total = 0u64;
        let mut kept = 0usize;
        for ring in rings.iter() {
            let (spans, t) = ring.snapshot();
            total += t;
            kept += spans.len();
        }
        (total, kept)
    }

    /// Stitch retained spans into per-query traces, slowest first.
    pub fn traces(&self) -> Vec<Trace> {
        let mut by_id: std::collections::BTreeMap<u64, Vec<Span>> =
            std::collections::BTreeMap::new();
        for span in self.spans() {
            by_id.entry(span.trace_id).or_default().push(span);
        }
        let mut traces: Vec<Trace> = by_id
            .into_iter()
            .map(|(trace_id, mut spans)| {
                spans.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
                Trace { trace_id, spans }
            })
            .collect();
        traces.sort_by(|a, b| b.latency_us().total_cmp(&a.latency_us()));
        traces
    }

    /// The predicted-vs-observed calibration report, merged across every
    /// shard's profile sink.
    pub fn calibration(&self) -> CalibrationReport {
        let sinks = self.sinks.lock().unwrap();
        let parts: Vec<Arc<profile::ProfileSink>> =
            sinks.iter().map(|(_, s)| Arc::clone(s)).collect();
        drop(sinks);
        profile::CalibrationReport::merged(&parts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert() {
        let tel = Telemetry::disabled();
        assert!(!tel.enabled());
        let rec = tel.recorder(0);
        assert!(!rec.enabled());
        assert_eq!(rec.now_us(), 0.0);
        rec.record(1, SpanKind::Queue, "queue", 0.0, 5.0, 0);
        assert!(tel.spans().is_empty());
        assert!(tel.traces().is_empty());
    }

    #[test]
    fn ring_wraps_at_capacity() {
        let tel = Telemetry::new(TelemetryConfig {
            enabled: true,
            ring_capacity: 8,
            sample_rate: 1.0,
        });
        let rec = tel.recorder(0);
        for i in 0..20u64 {
            rec.record(i, SpanKind::Queue, "queue", i as f64, 1.0, 0);
        }
        let (total, kept) = tel.span_counts();
        assert_eq!(total, 20);
        assert_eq!(kept, 8, "ring retains exactly its capacity");
        // the retained spans are the most recent 8
        let mut ids: Vec<u64> = tel.spans().iter().map(|s| s.trace_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (12..20).collect::<Vec<u64>>());
    }

    #[test]
    fn traces_stitch_across_rings_and_sort_by_latency() {
        let tel = Telemetry::new(TelemetryConfig {
            enabled: true,
            ring_capacity: 64,
            sample_rate: 1.0,
        });
        let r0 = tel.recorder(0);
        let r1 = tel.recorder(1);
        let router = tel.recorder(ROUTER_SHARD);
        router.record(7, SpanKind::Route, "route", 0.0, 0.0, 0);
        r0.record(7, SpanKind::Queue, "queue", 1.0, 4.0, 0);
        r0.record(7, SpanKind::EngineRound, "round", 5.0, 10.0, 0);
        r1.record(7, SpanKind::Halo, "halo", 2.0, 1.0, 64);
        router.record(9, SpanKind::Route, "route", 20.0, 0.0, 1);
        r1.record(9, SpanKind::Queue, "queue", 21.0, 1.0, 0);
        r1.record(9, SpanKind::EngineRound, "round", 22.0, 2.0, 0);

        let traces = tel.traces();
        assert_eq!(traces.len(), 2);
        let slow = &traces[0];
        assert_eq!(slow.trace_id, 7, "slowest first");
        assert_eq!(slow.spans.len(), 4);
        assert_eq!(slow.shard_count(), 2, "stitched across two shard rings");
        assert!((slow.latency_us() - 14.0).abs() < 1e-9);
        // sorted by start time
        for w in slow.spans.windows(2) {
            assert!(w[0].start_us <= w[1].start_us);
        }
    }

    #[test]
    fn sampling_is_deterministic_and_roughly_proportional() {
        let tel = Telemetry::new(TelemetryConfig {
            enabled: true,
            ring_capacity: 4096,
            sample_rate: 0.25,
        });
        let r0 = tel.recorder(0);
        let r1 = tel.recorder(1);
        let mut kept = 0;
        for id in 1..=1000u64 {
            assert_eq!(r0.sampled(id), r1.sampled(id), "workers agree on {id}");
            if r0.sampled(id) {
                kept += 1;
            }
            r0.record(id, SpanKind::Queue, "queue", id as f64, 1.0, 0);
        }
        assert_eq!(tel.spans().len(), kept, "record honors the sample");
        assert!((150..350).contains(&kept), "~25% of 1000, got {kept}");
    }
}
