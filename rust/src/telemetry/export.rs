//! Telemetry exporters: Prometheus text format and JSON lines.
//!
//! Both exporters are pure functions over already-collected data
//! ([`crate::metrics::Snapshot`], [`super::Trace`],
//! [`super::profile::CalibrationReport`]) — no I/O, no locks — so the
//! CLI, a scrape endpoint, or a test can render the same state. Each
//! comes with a small structural validator ([`validate_prometheus`],
//! [`validate_json_lines`]); the `grannite trace` example job runs the
//! validators over live exporter output so a formatting regression fails
//! CI, not a dashboard. The monitor's scrape endpoint serves these same
//! renderings live — `GET /metrics` is [`prometheus`] and `GET /traces`
//! is [`json_lines`] over the deployment's current state (see
//! [`crate::monitor`]), so what CI validates is byte-for-byte what an
//! operator scrapes.

use anyhow::{bail, Result};

use super::profile::CalibrationReport;
use super::{Span, Trace, ROUTER_SHARD};
use crate::metrics::Snapshot;
use crate::util::json_escape;

/// A finite float as a JSON/Prometheus number (`null`/`NaN` never occur
/// in practice; non-finite values render as 0 to keep scrapes parseable).
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "0".to_string()
    }
}

fn shard_label(s: &Snapshot) -> String {
    match s.shard {
        Some(i) => i.to_string(),
        None => "all".to_string(),
    }
}

/// Render per-shard snapshots plus the calibration table in the
/// Prometheus text exposition format (counters, gauges, and summary
/// quantiles, all under the `grannite_` prefix).
pub fn prometheus(shards: &[Snapshot], cal: &CalibrationReport) -> String {
    let mut out = String::with_capacity(4096);
    let header = |name: &str, kind: &str, help: &str, out: &mut String| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    };

    header("grannite_queries_total", "counter", "Queries served.", &mut out);
    for s in shards {
        out.push_str(&format!(
            "grannite_queries_total{{shard=\"{}\"}} {}\n",
            shard_label(s),
            s.queries
        ));
    }
    header("grannite_rejected_total", "counter", "Queries shed at admission.", &mut out);
    for s in shards {
        out.push_str(&format!(
            "grannite_rejected_total{{shard=\"{}\"}} {}\n",
            shard_label(s),
            s.rejected
        ));
    }
    header("grannite_halo_bytes_total", "counter",
           "Boundary feature bytes exchanged between shards.", &mut out);
    for s in shards {
        out.push_str(&format!(
            "grannite_halo_bytes_total{{shard=\"{}\"}} {}\n",
            shard_label(s),
            s.halo_bytes
        ));
    }
    header("grannite_throughput_qps", "gauge", "Observed queries per second.", &mut out);
    for s in shards {
        out.push_str(&format!(
            "grannite_throughput_qps{{shard=\"{}\"}} {}\n",
            shard_label(s),
            num(s.throughput_qps)
        ));
    }
    header("grannite_latency_us", "summary",
           "End-to-end query latency, microseconds.", &mut out);
    for s in shards {
        if let Some(lat) = &s.latency {
            let shard = shard_label(s);
            for (q, v) in [("0.5", lat.p50), ("0.95", lat.p95), ("0.99", lat.p99)] {
                out.push_str(&format!(
                    "grannite_latency_us{{shard=\"{shard}\",quantile=\"{q}\"}} {}\n",
                    num(v)
                ));
            }
            out.push_str(&format!(
                "grannite_latency_us_count{{shard=\"{shard}\"}} {}\n",
                lat.n
            ));
        }
    }
    header("grannite_queue_us", "summary",
           "Time from enqueue to inference start, microseconds.", &mut out);
    for s in shards {
        if let Some(q) = &s.queue {
            let shard = shard_label(s);
            out.push_str(&format!(
                "grannite_queue_us{{shard=\"{shard}\",quantile=\"0.5\"}} {}\n",
                num(q.p50)
            ));
            out.push_str(&format!(
                "grannite_queue_us{{shard=\"{shard}\",quantile=\"0.99\"}} {}\n",
                num(q.p99)
            ));
        }
    }
    header("grannite_cache_hit_rate", "gauge",
           "Fraction of activation rows served from the layer cache.", &mut out);
    for s in shards {
        out.push_str(&format!(
            "grannite_cache_hit_rate{{shard=\"{}\"}} {}\n",
            shard_label(s),
            num(s.cache_hit_rate())
        ));
    }
    header("grannite_feature_cache_hit_rate", "gauge",
           "Fraction of feature-store page lookups served from the page cache.", &mut out);
    for s in shards {
        out.push_str(&format!(
            "grannite_feature_cache_hit_rate{{shard=\"{}\"}} {}\n",
            shard_label(s),
            num(s.feature_cache_hit_rate())
        ));
    }
    header("grannite_page_faults_total", "counter",
           "Feature-store page lookups that went to disk.", &mut out);
    for s in shards {
        out.push_str(&format!(
            "grannite_page_faults_total{{shard=\"{}\"}} {}\n",
            shard_label(s),
            s.page_faults
        ));
    }
    header("grannite_storage_read_bytes_total", "counter",
           "Bytes the paged feature store read from disk.", &mut out);
    for s in shards {
        out.push_str(&format!(
            "grannite_storage_read_bytes_total{{shard=\"{}\"}} {}\n",
            shard_label(s),
            s.storage_bytes_read
        ));
    }

    header("grannite_cost_ratio", "gauge",
           "Observed/predicted per-op cost ratio (median).", &mut out);
    for r in &cal.rows {
        out.push_str(&format!(
            "grannite_cost_ratio{{kind=\"{}\",bucket=\"{}\"}} {}\n",
            r.kind, r.bucket, num(r.ratio_p50)
        ));
    }
    header("grannite_cost_scale", "gauge",
           "Fitted per-op-kind cost-model scale factor.", &mut out);
    for (kind, f) in cal.scales().iter() {
        out.push_str(&format!(
            "grannite_cost_scale{{kind=\"{kind}\"}} {}\n",
            num(f)
        ));
    }
    out
}

fn span_json(s: &Span) -> String {
    let shard = if s.shard == ROUTER_SHARD {
        "null".to_string()
    } else {
        s.shard.to_string()
    };
    format!(
        "{{\"shard\":{shard},\"kind\":\"{}\",\"label\":\"{}\",\
         \"start_us\":{},\"dur_us\":{},\"value\":{}}}",
        s.kind.name(),
        json_escape(s.label),
        num(s.start_us),
        num(s.dur_us),
        s.value
    )
}

/// Render the full telemetry state as JSON lines: one `snapshot` object
/// per shard, one `calibration` object per table row, one `trace` object
/// per stitched trace — each a self-describing single-line record.
pub fn json_lines(traces: &[Trace], shards: &[Snapshot], cal: &CalibrationReport) -> String {
    let mut out = String::with_capacity(4096);
    for s in shards {
        out.push_str(&format!(
            "{{\"type\":\"snapshot\",\"snapshot\":{}}}\n",
            s.to_json()
        ));
    }
    for r in &cal.rows {
        out.push_str(&format!(
            "{{\"type\":\"calibration\",\"kind\":\"{}\",\"bucket\":{},\
             \"runs\":{},\"predicted_us\":{},\"observed_us\":{},\
             \"ratio_p50\":{},\"ratio_p99\":{}}}\n",
            json_escape(&r.kind),
            r.bucket,
            r.runs,
            num(r.predicted_us),
            num(r.observed_us),
            num(r.ratio_p50),
            num(r.ratio_p99)
        ));
    }
    for t in traces {
        let spans: Vec<String> = t.spans.iter().map(span_json).collect();
        out.push_str(&format!(
            "{{\"type\":\"trace\",\"trace_id\":{},\"latency_us\":{},\
             \"spans\":[{}]}}\n",
            t.trace_id,
            num(t.latency_us()),
            spans.join(",")
        ));
    }
    out
}

/// Structural check over Prometheus text output: every non-comment line
/// must be `name[{labels}] value` with a legal metric name, balanced
/// quoted labels, and a parseable float. Returns the sample count.
pub fn validate_prometheus(text: &str) -> Result<usize> {
    let mut samples = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = match line.rsplit_once(' ') {
            Some(parts) => parts,
            None => bail!("line {}: no value separator: {line:?}", ln + 1),
        };
        if value.parse::<f64>().is_err() {
            bail!("line {}: unparseable value {value:?}", ln + 1);
        }
        let name = match series.split_once('{') {
            Some((name, rest)) => {
                let labels = match rest.strip_suffix('}') {
                    Some(l) => l,
                    None => bail!("line {}: unclosed label set: {series:?}", ln + 1),
                };
                if labels.matches('"').count() % 2 != 0 {
                    bail!("line {}: unbalanced label quotes: {labels:?}", ln + 1);
                }
                for pair in labels.split(',') {
                    let (_, v) = match pair.split_once('=') {
                        Some(kv) => kv,
                        None => bail!("line {}: label without '=': {pair:?}", ln + 1),
                    };
                    if !(v.starts_with('"') && v.ends_with('"') && v.len() >= 2) {
                        bail!("line {}: unquoted label value: {pair:?}", ln + 1);
                    }
                }
                name
            }
            None => series,
        };
        let mut chars = name.chars();
        let head_ok = chars
            .next()
            .map(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
            .unwrap_or(false);
        if !head_ok || !chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':') {
            bail!("line {}: illegal metric name {name:?}", ln + 1);
        }
        samples += 1;
    }
    if samples == 0 {
        bail!("no samples in Prometheus output");
    }
    Ok(samples)
}

/// Structural check over JSON-lines output: every line must be one
/// object with balanced braces/brackets outside string literals and
/// properly terminated strings. Returns the line count.
pub fn validate_json_lines(text: &str) -> Result<usize> {
    let mut lines = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if !line.starts_with('{') || !line.ends_with('}') {
            bail!("line {}: not a JSON object: {line:?}", ln + 1);
        }
        let (mut brace, mut bracket) = (0i64, 0i64);
        let mut in_str = false;
        let mut escape = false;
        for c in line.chars() {
            if in_str {
                if escape {
                    escape = false;
                } else if c == '\\' {
                    escape = true;
                } else if c == '"' {
                    in_str = false;
                }
                continue;
            }
            match c {
                '"' => in_str = true,
                '{' => brace += 1,
                '}' => brace -= 1,
                '[' => bracket += 1,
                ']' => bracket -= 1,
                _ => {}
            }
            if brace < 0 || bracket < 0 {
                bail!("line {}: unbalanced nesting: {line:?}", ln + 1);
            }
        }
        if in_str {
            bail!("line {}: unterminated string: {line:?}", ln + 1);
        }
        if brace != 0 || bracket != 0 {
            bail!("line {}: unbalanced nesting: {line:?}", ln + 1);
        }
        lines += 1;
    }
    if lines == 0 {
        bail!("no records in JSON-lines output");
    }
    Ok(lines)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;
    use crate::telemetry::{SpanKind, Telemetry, TelemetryConfig};

    fn sample_state() -> (Vec<Trace>, Vec<Snapshot>, CalibrationReport) {
        let m = Metrics::new_shard(0);
        m.record_query(120.0, 4.0, 2);
        m.record_halo(256, 3.0);
        let tel = Telemetry::new(TelemetryConfig {
            enabled: true,
            ring_capacity: 64,
            sample_rate: 1.0,
        });
        let rec = tel.recorder(0);
        rec.record(1, SpanKind::Queue, "queue", 0.0, 4.0, 0);
        rec.record(1, SpanKind::EngineRound, "round", 4.0, 116.0, 0);
        rec.record(1, SpanKind::Op, "MatMul", 5.0, 50.0, 0);
        (tel.traces(), vec![m.snapshot()], tel.calibration())
    }

    #[test]
    fn prometheus_output_validates() {
        let (_, shards, cal) = sample_state();
        let text = prometheus(&shards, &cal);
        let n = validate_prometheus(&text).unwrap();
        assert!(n >= 5, "expected several samples, got {n}:\n{text}");
        assert!(text.contains("grannite_queries_total{shard=\"0\"} 1"));
        assert!(text.contains("# TYPE grannite_latency_us summary"));
        assert!(text.contains("grannite_feature_cache_hit_rate{shard=\"0\"} 0"));
        assert!(text.contains("grannite_page_faults_total{shard=\"0\"} 0"));
        assert!(text.contains("grannite_storage_read_bytes_total{shard=\"0\"} 0"));
    }

    #[test]
    fn json_lines_output_validates() {
        let (traces, shards, cal) = sample_state();
        let text = json_lines(&traces, &shards, &cal);
        let n = validate_json_lines(&text).unwrap();
        assert_eq!(n, shards.len() + cal.rows.len() + traces.len());
        assert!(text.contains("\"type\":\"snapshot\""));
        assert!(text.contains("\"type\":\"trace\""));
        assert!(text.contains("\"kind\":\"engine_round\""));
    }

    #[test]
    fn validators_reject_malformed_output() {
        assert!(validate_prometheus("").is_err());
        assert!(validate_prometheus("1metric 5\n").is_err());
        assert!(validate_prometheus("m{a=\"b\" 5\n").is_err(), "unclosed labels");
        assert!(validate_prometheus("m{a=b} 5\n").is_err(), "unquoted label");
        assert!(validate_prometheus("m notafloat\n").is_err());
        assert!(validate_prometheus("ok_metric{x=\"y\"} 1.5\n").is_ok());

        assert!(validate_json_lines("").is_err());
        assert!(validate_json_lines("[1,2]\n").is_err(), "not an object");
        assert!(validate_json_lines("{\"a\":[1,2}\n").is_err(), "unbalanced");
        assert!(validate_json_lines("{\"a\":\"unterminated}\n").is_err());
        assert!(validate_json_lines("{\"a\":{\"b\":[1,2]}}\n").is_ok());
    }
}
