//! Per-op plan profiling and the predicted-vs-observed calibration loop.
//!
//! A [`PlanProfiler`] rides inside one [`crate::engine::PlanInstance`]:
//! at attach time it walks the compiled plan once, resolving each step's
//! op-kind mnemonic, row bucket (next power of two — the same geometry
//! the tile cache keys on) and the [`crate::npu::cost::op_cost`]
//! prediction for the reference device
//! ([`crate::config::HardwareConfig::npu_series2`], the cost model every
//! placement decision prices against). At run time `observe` is a plain
//! slot store (no lock, no allocation) and `flush` folds the round into
//! the shard's shared [`ProfileSink`] under one short lock.
//!
//! The sink aggregates per `(kind, bucket)` slot: exact run counts and
//! predicted/observed sums plus a bounded [`Reservoir`] of
//! observed/predicted ratios — which is exactly the signal the ROADMAP's
//! self-tuning `auto` engine needs, surfaced as a [`CalibrationReport`]
//! and a fitted per-kind [`CostScales`].

use std::sync::{Arc, Mutex};

use crate::config::HardwareConfig;
use crate::npu::cost::{op_cost, CostOpts, CostScales};
use crate::ops::plan::{rc, StepKind};
use crate::ops::ExecPlan;
use crate::util::reservoir::Reservoir;
use crate::util::timing::Stats;

/// Ratio samples retained per `(kind, bucket)` slot.
const RATIO_CAP: usize = 128;

/// Per-round observations retained for span emission when the shard loop
/// is not draining (e.g. the bench harness) — bounds sink memory.
const LAST_ROUND_CAP: usize = 4096;

/// One step observation of the most recent engine round.
#[derive(Debug, Clone, Copy)]
pub struct StepObs {
    /// Op-kind mnemonic of the step (fused chains report the tail op).
    pub kind: &'static str,
    /// Observed wall time, µs.
    pub dur_us: f64,
}

#[derive(Debug)]
struct Slot {
    kind: &'static str,
    bucket: usize,
    runs: u64,
    predicted_sum: f64,
    observed_sum: f64,
    /// observed/predicted per run (bounded, deterministic).
    ratios: Reservoir,
}

#[derive(Debug)]
struct SinkInner {
    slots: Vec<Slot>,
    last_round: Vec<StepObs>,
}

/// One shard's profile aggregation point, shared by every plan instance
/// the shard executes (the incremental engine's whole tile cache feeds
/// one sink).
#[derive(Debug)]
pub struct ProfileSink {
    shard: usize,
    inner: Mutex<SinkInner>,
}

impl ProfileSink {
    pub(crate) fn new(shard: usize) -> ProfileSink {
        ProfileSink {
            shard,
            inner: Mutex::new(SinkInner { slots: Vec::new(), last_round: Vec::new() }),
        }
    }

    /// Find-or-create the slot index for `(kind, bucket)`.
    fn slot_index(&self, kind: &'static str, bucket: usize) -> usize {
        let mut g = self.inner.lock().unwrap();
        if let Some(i) = g
            .slots
            .iter()
            .position(|s| s.kind == kind && s.bucket == bucket)
        {
            return i;
        }
        // deterministic per-slot seed: same (shard, kind, bucket) →
        // same reservoir stream across runs
        let seed = 0x7e1e_c0de
            ^ (self.shard as u64).rotate_left(32)
            ^ (bucket as u64).rotate_left(16)
            ^ kind.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
        g.slots.push(Slot {
            kind,
            bucket,
            runs: 0,
            predicted_sum: 0.0,
            observed_sum: 0.0,
            ratios: Reservoir::new(RATIO_CAP, seed),
        });
        g.slots.len() - 1
    }

    /// Per-step observations of the most recent flushed round, consumed.
    pub(crate) fn drain_last_round(&self) -> Vec<StepObs> {
        std::mem::take(&mut self.inner.lock().unwrap().last_round)
    }
}

#[derive(Debug, Clone, Copy)]
struct StepMeta {
    kind: &'static str,
    predicted_us: f64,
    slot: usize,
}

/// Per-plan-instance profiler: `observe` per step, `flush` per round.
#[derive(Debug)]
pub struct PlanProfiler {
    sink: Arc<ProfileSink>,
    meta: Vec<StepMeta>,
    /// Last observed µs per step; negative = not observed this round.
    last: Vec<f64>,
}

impl PlanProfiler {
    pub(crate) fn new(sink: Arc<ProfileSink>, plan: &ExecPlan) -> PlanProfiler {
        let hw = HardwareConfig::npu_series2();
        let g = &plan.graph;
        let meta = plan
            .steps
            .iter()
            .map(|step| {
                let tail = &g.ops[step.op];
                let kind = tail.kind.name();
                let (rows, _cols) = rc(&tail.shape).unwrap_or((1, 1));
                let bucket = rows.max(1).next_power_of_two();
                // a fused chain executes all member ops in one loop —
                // its prediction is the sum of the members' costs
                let predicted_us = match &step.kind {
                    StepKind::Chain(chain) => chain
                        .ops
                        .iter()
                        .map(|&id| {
                            op_cost(g, id, &hw, g.ops[id].kind.default_engine(),
                                    CostOpts::default())
                            .us
                        })
                        .sum(),
                    _ => op_cost(g, step.op, &hw, tail.kind.default_engine(),
                                 CostOpts::default())
                        .us,
                };
                let slot = sink.slot_index(kind, bucket);
                StepMeta { kind, predicted_us, slot }
            })
            .collect::<Vec<_>>();
        let last = vec![-1.0; meta.len()];
        PlanProfiler { sink, meta, last }
    }

    /// Record step `si`'s wall time for this round (no lock, no
    /// allocation — a single slot store on the engine's hot path).
    #[inline]
    pub fn observe(&mut self, si: usize, us: f64) {
        if let Some(v) = self.last.get_mut(si) {
            *v = us;
        }
    }

    /// Fold the round's observations into the shard sink (one lock per
    /// round) and reset for the next round.
    pub fn flush(&mut self) {
        let mut g = self.sink.inner.lock().unwrap();
        for (meta, us) in self.meta.iter().zip(self.last.iter_mut()) {
            if *us < 0.0 {
                continue;
            }
            let slot = &mut g.slots[meta.slot];
            slot.runs += 1;
            slot.predicted_sum += meta.predicted_us;
            slot.observed_sum += *us;
            if meta.predicted_us > 0.0 {
                slot.ratios.record(*us / meta.predicted_us);
            }
            if g.last_round.len() < LAST_ROUND_CAP {
                g.last_round.push(StepObs { kind: meta.kind, dur_us: *us });
            }
            *us = -1.0;
        }
    }
}

/// One `(op kind, row bucket)` line of the calibration table.
#[derive(Debug, Clone)]
pub struct CalibrationRow {
    /// Op-kind mnemonic ([`crate::ops::OpKind::name`]).
    pub kind: String,
    /// Row-count bucket (next power of two of the step's output rows).
    pub bucket: usize,
    /// Exact number of observed executions.
    pub runs: u64,
    /// Mean predicted µs per execution ([`crate::npu::cost::op_cost`]).
    pub predicted_us: f64,
    /// Mean observed wall µs per execution.
    pub observed_us: f64,
    /// Median observed/predicted ratio.
    pub ratio_p50: f64,
    /// Tail observed/predicted ratio.
    pub ratio_p99: f64,
}

/// The cost model's audit: per-(kind, bucket) predicted vs observed,
/// merged across shards, plus the fitted per-kind scale factors.
#[derive(Debug, Clone, Default)]
pub struct CalibrationReport {
    /// Table rows, sorted by kind then bucket.
    pub rows: Vec<CalibrationRow>,
}

impl CalibrationReport {
    /// True when no execution was observed — [`Self::scales`] would fit
    /// nothing and scaled costing falls back to the raw model. The
    /// autotuner checks this to report whether its ranking is
    /// calibrated or model-only.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub(crate) fn merged(sinks: &[Arc<ProfileSink>]) -> CalibrationReport {
        // (kind, bucket) → (runs, pred_sum, obs_sum, pooled ratios)
        let mut merged: std::collections::BTreeMap<
            (&'static str, usize),
            (u64, f64, f64, Vec<f64>),
        > = std::collections::BTreeMap::new();
        for sink in sinks {
            let g = sink.inner.lock().unwrap();
            for slot in &g.slots {
                if slot.runs == 0 {
                    continue;
                }
                let e = merged
                    .entry((slot.kind, slot.bucket))
                    .or_insert((0, 0.0, 0.0, Vec::new()));
                e.0 += slot.runs;
                e.1 += slot.predicted_sum;
                e.2 += slot.observed_sum;
                e.3.extend_from_slice(slot.ratios.samples());
            }
        }
        let rows = merged
            .into_iter()
            .map(|((kind, bucket), (runs, pred, obs, ratios))| {
                let (p50, p99) = if ratios.is_empty() {
                    (0.0, 0.0)
                } else {
                    let s = Stats::from_samples(&ratios);
                    (s.p50, s.p99)
                };
                CalibrationRow {
                    kind: kind.to_string(),
                    bucket,
                    runs,
                    predicted_us: pred / runs as f64,
                    observed_us: obs / runs as f64,
                    ratio_p50: p50,
                    ratio_p99: p99,
                }
            })
            .collect();
        CalibrationReport { rows }
    }

    /// Fitted per-kind multiplicative corrections: total observed over
    /// total predicted, bucket-pooled. Feed to
    /// [`crate::npu::cost::op_cost_scaled`] to close the loop.
    pub fn scales(&self) -> CostScales {
        let mut pred: std::collections::BTreeMap<&str, f64> = std::collections::BTreeMap::new();
        let mut obs: std::collections::BTreeMap<&str, f64> = std::collections::BTreeMap::new();
        for r in &self.rows {
            *pred.entry(r.kind.as_str()).or_default() += r.predicted_us * r.runs as f64;
            *obs.entry(r.kind.as_str()).or_default() += r.observed_us * r.runs as f64;
        }
        let mut scales = CostScales::default();
        for (kind, p) in pred {
            if p > 0.0 {
                scales.set(kind, obs[kind] / p);
            }
        }
        scales
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::build::{self, GnnDims};

    fn plan() -> ExecPlan {
        let d = GnnDims::model(32, 80, 16, 4);
        ExecPlan::compile(&build::gcn_stagr(d, "stagr")).unwrap()
    }

    #[test]
    fn profiler_aggregates_into_calibration_rows() {
        let sink = Arc::new(ProfileSink::new(0));
        let p = plan();
        let mut prof = PlanProfiler::new(Arc::clone(&sink), &p);
        for round in 0..3 {
            for si in 0..p.steps.len() {
                prof.observe(si, 10.0 + round as f64);
            }
            prof.flush();
        }
        let report = CalibrationReport::merged(&[Arc::clone(&sink)]);
        assert!(!report.rows.is_empty());
        for row in &report.rows {
            assert_eq!(row.runs % 3, 0, "{}: every step ran 3 rounds", row.kind);
            assert!(row.observed_us > 0.0 && row.predicted_us > 0.0);
            assert!(row.ratio_p50 > 0.0);
        }
        // every executed step kind appears in the table
        let kinds: std::collections::BTreeSet<&str> =
            report.rows.iter().map(|r| r.kind.as_str()).collect();
        for step in &p.steps {
            let name = p.graph.ops[step.op].kind.name();
            assert!(kinds.contains(name), "missing kind {name}");
        }
    }

    #[test]
    fn unobserved_steps_do_not_pollute_the_sink() {
        let sink = Arc::new(ProfileSink::new(1));
        let p = plan();
        let mut prof = PlanProfiler::new(Arc::clone(&sink), &p);
        prof.observe(0, 5.0);
        prof.flush();
        prof.flush(); // second flush with nothing observed: no-op
        let report = CalibrationReport::merged(&[sink]);
        let total_runs: u64 = report.rows.iter().map(|r| r.runs).sum();
        assert_eq!(total_runs, 1, "only the one observed step counted");
    }

    #[test]
    fn scales_fit_observed_over_predicted() {
        let sink = Arc::new(ProfileSink::new(0));
        let p = plan();
        let mut prof = PlanProfiler::new(Arc::clone(&sink), &p);
        // observe exactly 2× the prediction for every step
        let preds: Vec<f64> = prof.meta.iter().map(|m| m.predicted_us).collect();
        for (si, pred) in preds.iter().enumerate() {
            prof.observe(si, pred * 2.0);
        }
        prof.flush();
        let scales = CalibrationReport::merged(&[sink]).scales();
        for (kind, f) in scales.iter() {
            assert!((f - 2.0).abs() < 1e-6, "{kind}: fitted {f}");
        }
        assert!((scales.factor("MatMul") - 2.0).abs() < 1e-6);
        assert_eq!(scales.factor("NoSuchKind"), 1.0, "unknown kinds pass through");
    }

    #[test]
    fn last_round_drains_once() {
        let sink = Arc::new(ProfileSink::new(0));
        let p = plan();
        let mut prof = PlanProfiler::new(Arc::clone(&sink), &p);
        for si in 0..p.steps.len() {
            prof.observe(si, 1.0);
        }
        prof.flush();
        let obs = sink.drain_last_round();
        assert_eq!(obs.len(), p.steps.len());
        assert!(sink.drain_last_round().is_empty(), "drained");
    }
}
