//! `serve` — the unified serving front door.
//!
//! Four PRs of serving machinery (single-leader [`crate::server`],
//! sharded [`crate::fleet`], planned and incremental engines) grew a
//! combinatorial construction surface: one constructor per
//! (engine × topology) cell, each re-parsing its own flags. This module
//! collapses that matrix into
//!
//! ```text
//! DeploymentSpec ──Deployment::launch(spec, data)──▶ Box<dyn Serving>
//!       │                    │
//!       │                    ├─ shards = 1 → ServerHandle (single leader)
//!       │                    └─ shards > 1 → Fleet (routed shard workers)
//!       └─ [engine] name ──EngineRegistry──▶ EngineFactory (one per engine)
//! ```
//!
//! - [`spec::DeploymentSpec`]: one typed, TOML-round-trippable value for
//!   model, engine, topology, aggregation, quant, batching, admission.
//! - [`Serving`]: the object-safe trait both front ends implement — the
//!   single-leader server **is** the 1-shard topology at the API level,
//!   and a caller holding `Box<dyn Serving>` cannot tell which it got
//!   (property-tested in `rust/tests/serve_spec.rs`).
//! - [`registry::EngineRegistry`]: engine name → factory. A new engine
//!   is one factory impl + one `register` call — no edits to `server/`,
//!   `fleet/`, or `main.rs`.
//! - [`tune::TunedDeployment`]: `Deployment::autotune` searches the spec
//!   space (engine × aggregation × quant × shards) with the calibrated
//!   cost model and short live probes, so nobody has to hand-pick a
//!   spec; the runtime-adaptive `auto` engine handles whatever the
//!   tuner couldn't foresee.

pub mod registry;
pub mod spec;
pub mod tune;

pub use registry::{
    BoxedEngine, EngineFactory, EngineInit, EngineRegistry, LaunchContext, ShardFactory,
};
pub use spec::{
    BatchSpec, DeploymentSpec, EngineSpec, KernelSpec, MonitorSpec, SloSpec,
    TelemetrySpec, Topology, TuningSpec,
};
pub use tune::{Objective, TunedDeployment, TuningReport, TuningRow};

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::fleet::{Fleet, FleetPlan, ShardConfig};
use crate::graph::datasets::Dataset;
use crate::metrics::Snapshot;
use crate::server::{QueryResponse, ServerHandle, Update};

/// A running deployment, whatever its topology: the object-safe serving
/// surface implemented by both [`ServerHandle`] (1 shard) and [`Fleet`]
/// (N shards).
///
/// Blocking waits are **provided methods** ([`Serving::query_wait`],
/// [`Serving::query_deadline`]) built on [`Serving::query`], so no
/// caller hand-rolls a `recv` loop and deadline shedding is accounted
/// uniformly through the admission path ([`Serving::record_shed`]).
pub trait Serving: Send {
    /// Apply a GrAd structure update, ordered before any later query.
    fn update(&self, u: Update) -> Result<()>;

    /// Submit a query (`None` = full graph, answered like the
    /// single-leader server); returns the response channel.
    fn query(&self, node: Option<usize>)
             -> Result<Receiver<Result<QueryResponse, String>>>;

    /// Barrier every shard; returns the applied version vector (length
    /// [`Serving::num_shards`]).
    fn sync(&self) -> Result<Vec<u64>>;

    /// Deployment-wide metrics (exact merge across shards).
    fn metrics(&self) -> Snapshot;

    /// Per-shard labeled snapshots.
    fn shard_metrics(&self) -> Vec<Snapshot>;

    /// Worker count (1 for the single-leader server).
    fn num_shards(&self) -> usize;

    /// Count one caller-abandoned query against the owning shard's
    /// admission accounting (`rejected` in [`Snapshot`]) — the hook
    /// [`Serving::query_deadline`] sheds through.
    fn record_shed(&self, node: Option<usize>);

    /// The deployment's telemetry hub (span rings, plan-profiler sinks,
    /// calibration report), when the topology carries one. The provided
    /// default returns `None` so bare test doubles stay one-method
    /// impls; both built-in topologies override it.
    fn telemetry(&self) -> Option<std::sync::Arc<crate::telemetry::Telemetry>> {
        None
    }

    /// The deployment's operational monitor (history rings, SLO state,
    /// watchdog, flight recorder), when the spec activated one. Same
    /// default-`None` contract as [`Serving::telemetry`].
    fn monitor(&self) -> Option<crate::monitor::Monitor> {
        None
    }

    /// Liveness + SLO verdict from the monitor: `None` when no monitor
    /// is active, otherwise the same report `GET /health` serves (a
    /// wedged shard, a recorded panic, or an active SLO breach all flip
    /// `healthy` to false).
    fn health(&self) -> Option<crate::monitor::HealthReport> {
        self.monitor().and_then(|m| m.health())
    }

    /// Stop every worker and join them; the first failure (e.g. a shard
    /// panic message) surfaces as the `Err`.
    fn shutdown(self: Box<Self>) -> Result<()>;

    /// Blocking convenience: query and wait indefinitely.
    fn query_wait(&self, node: Option<usize>) -> Result<QueryResponse> {
        let rx = self.query(node)?;
        rx.recv()
            .map_err(|_| anyhow!("serving dropped the response channel"))?
            .map_err(|e| anyhow!(e))
    }

    /// Blocking with a deadline: wait at most `deadline` for the answer,
    /// then abandon the query and count it as shed on the owning shard
    /// (the response, if it ever arrives, lands in a dropped channel).
    ///
    /// Accounting note: unlike an admission rejection, the worker may
    /// still answer the abandoned query — work done (`queries`) and the
    /// caller-visible failure (`rejected`) are tracked independently, so
    /// a deadline miss can appear in both counters.
    fn query_deadline(&self, node: Option<usize>, deadline: Duration)
                      -> Result<QueryResponse> {
        let rx = self.query(node)?;
        match rx.recv_timeout(deadline) {
            Ok(r) => r.map_err(|e| anyhow!(e)),
            Err(RecvTimeoutError::Timeout) => {
                self.record_shed(node);
                Err(anyhow!(
                    "query deadline of {deadline:?} exceeded — abandoned and \
                     counted as shed"
                ))
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(anyhow!("serving dropped the response channel"))
            }
        }
    }
}

/// What a deployment serves: an in-memory dataset (offline engines) or
/// an AOT artifacts directory (the `coordinator` engine; also yields
/// the dataset twin for placement planning).
pub enum DataSource {
    /// An in-memory dataset (synthesized twin or loaded `.gnnt`).
    Dataset(Dataset),
    /// `make artifacts` output: manifest + weights + dataset twins.
    Artifacts {
        /// Artifacts directory (contains `manifest.toml`).
        dir: std::path::PathBuf,
        /// Dataset name inside the manifest (`cora`, `citeseer`, …).
        dataset: String,
    },
}

impl DataSource {
    /// Resolve to the dataset that drives placement and the offline
    /// engines. Missing artifacts fail here, before any thread spawns.
    pub fn dataset(&self) -> Result<Dataset> {
        match self {
            DataSource::Dataset(ds) => Ok(ds.clone()),
            DataSource::Artifacts { dir, dataset } => {
                if !dir.join("manifest.toml").exists() {
                    anyhow::bail!(
                        "artifacts manifest {}/manifest.toml not found — run \
                         `make artifacts`, or serve offline with \
                         DataSource::Dataset and engine plan | incremental | \
                         local",
                        dir.display()
                    );
                }
                Dataset::load_gnnt(dir, dataset)
            }
        }
    }

    /// The artifacts directory, when this source carries one (drivers
    /// that already resolved the dataset pass it to
    /// [`Deployment::launch_at`] so nothing resolves twice).
    pub fn artifacts_dir(&self) -> Option<std::path::PathBuf> {
        match self {
            DataSource::Dataset(_) => None,
            DataSource::Artifacts { dir, .. } => Some(dir.clone()),
        }
    }
}

/// The front door: validates a [`DeploymentSpec`], plans placement,
/// resolves the engine factory, and spawns the topology.
pub struct Deployment;

impl Deployment {
    /// Launch `spec` over `data` with the built-in engine registry.
    pub fn launch(spec: &DeploymentSpec, data: &DataSource) -> Result<Box<dyn Serving>> {
        Deployment::launch_with(&EngineRegistry::builtin(), spec, data)
    }

    /// [`Deployment::launch`] with a caller-extended registry (how a
    /// test-only or downstream engine plugs in without touching
    /// `server/`, `fleet/`, or the CLI).
    pub fn launch_with(
        registry: &EngineRegistry,
        spec: &DeploymentSpec,
        data: &DataSource,
    ) -> Result<Box<dyn Serving>> {
        Deployment::launch_at(registry, spec, &data.dataset()?,
                              data.artifacts_dir(), None)
    }

    /// The lower-level entry: launch over an **already-resolved**
    /// dataset, optionally with an **already-computed** placement (the
    /// one [`Deployment::plan`] returned for a report). Drivers that
    /// resolve the [`DataSource`] themselves use this so the dataset is
    /// loaded and the cost-model planning pass run exactly once per
    /// launch; a supplied plan that doesn't match the spec's resolved
    /// capacity and shard count is rejected, never silently replanned.
    pub fn launch_at(
        registry: &EngineRegistry,
        spec: &DeploymentSpec,
        ds: &Dataset,
        artifacts: Option<std::path::PathBuf>,
        plan: Option<FleetPlan>,
    ) -> Result<Box<dyn Serving>> {
        let capacity = spec.resolved_capacity(ds.num_nodes())?;
        // validate at the *resolved* capacity so derived capacities hit
        // the same budget checks an explicit one would
        let mut resolved = spec.clone();
        resolved.capacity = capacity;
        resolved.validate_with(registry)?;

        let mut cfg = resolved.fleet_config()?;
        // one telemetry hub per launch: every worker ring and profile
        // sink shares this hub's epoch, so cross-shard spans stitch
        cfg.telemetry = crate::telemetry::Telemetry::new(resolved.telemetry.config());
        // one monitor per launch (the operational surface): created only
        // when the spec asks — the disabled default keeps every hot path
        // branch-only. Binding happens *before* workers spawn so a bad
        // scrape address fails the launch instead of a background thread.
        let monitor = if resolved.monitor_active() {
            let m = crate::monitor::Monitor::new(resolved.monitor_config());
            if !resolved.monitor.addr.is_empty() {
                m.bind(&resolved.monitor.addr)?;
            }
            m.set_telemetry(std::sync::Arc::clone(&cfg.telemetry));
            m
        } else {
            crate::monitor::Monitor::disabled()
        };
        cfg.monitor = monitor.clone();
        let plan = match plan {
            Some(p) if p.owner.len() == capacity
                && p.shards.len() == cfg.devices.len() => p,
            Some(p) => anyhow::bail!(
                "supplied FleetPlan does not match the spec: plan covers {} \
                 capacity slots / {} shards, spec resolves to {capacity} / \
                 {} — pass the plan from Deployment::plan on the same spec, \
                 or None to replan",
                p.owner.len(),
                p.shards.len(),
                cfg.devices.len(),
            ),
            None => Fleet::plan_for(&ds.graph, capacity, ds.num_features(),
                                    ds.num_classes(), &cfg)?,
        };
        let ctx = LaunchContext {
            spec: &resolved,
            dataset: ds,
            capacity,
            artifacts,
        };
        let mut make = registry.get(&resolved.engine.name)?.prepare(&ctx)?;

        let serving: Box<dyn Serving> = if resolved.topology.shards == 1 {
            // the single-leader server is the 1-shard topology: same
            // engine factory, same batching and admission, no halo
            let init = make(&plan.shards[0]);
            let config = ShardConfig {
                batch: cfg.batch.clone(),
                admission: cfg.admission,
                halo: None,
                telemetry: std::sync::Arc::clone(&cfg.telemetry),
                monitor: cfg.monitor.clone(),
            };
            Box::new(ServerHandle::spawn_with(init, config))
        } else {
            Box::new(Fleet::spawn(plan, &ds.graph, ds.num_features(), &cfg, make))
        };
        // start sampling (and the scrape endpoint) only after every
        // shard registered, so the first tick sees the full topology
        monitor.start();
        Ok(serving)
    }

    /// The placement a spec would launch with (deterministic — the same
    /// plan `launch` spawns), for inspection and reporting without
    /// starting any worker.
    pub fn plan(spec: &DeploymentSpec, ds: &Dataset) -> Result<FleetPlan> {
        let capacity = spec.resolved_capacity(ds.num_nodes())?;
        let cfg = spec.fleet_config()?;
        Fleet::plan_for(&ds.graph, capacity, ds.num_features(), ds.num_classes(), &cfg)
    }
}
