//! The typed deployment specification — one declarative object that
//! names everything the old constructor matrix (the per-engine
//! `Fleet::spawn_*` lattice, removed after the PR 5 migration),
//! `ServerHandle::spawn`, and per-subsystem CLI flag parsing spread out.
//!
//! A [`DeploymentSpec`] is the paper's "configurable pipeline" framing
//! made concrete: which execution engine (StaGr plans, QuantGr INT8,
//! delta-driven incremental, PJRT coordinator), which topology (the
//! single-leader server is *literally* `shards = 1`), which aggregation
//! lowering (GraSp sparse vs dense), and which admission/batching policy
//! — all in one value that round-trips through the crate's TOML-subset
//! parser ([`crate::config::parse`]), validates with actionable errors,
//! and launches through [`crate::serve::Deployment::launch`].

#![deny(missing_docs)]

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::parse::{Document, Value};
use crate::config::HardwareConfig;
use crate::fleet::{AdmissionConfig, FleetConfig};
use crate::ops::build::Aggregation;
use crate::server::ServerConfig;

/// Dense-aggregation mask budget: a deployment whose engine would
/// materialize a `capacity × capacity` f32 mask larger than this is
/// rejected at validation time with a pointer at the sparse path, instead
/// of OOMing a shard at first inference.
pub const DENSE_MASK_BUDGET_BYTES: usize = 512 << 20;

/// Bytes of the dense `capacity²` f32 aggregation mask (saturating, so a
/// preposterous capacity still produces a finite, rejectable number).
pub fn dense_mask_bytes(capacity: usize) -> usize {
    capacity.saturating_mul(capacity).saturating_mul(4)
}

/// Which inference engine a deployment runs, plus engine-specific knobs.
///
/// `name` selects a factory from the
/// [`EngineRegistry`](crate::serve::EngineRegistry) (built-ins: `local`,
/// `plan`, `incremental`, `coordinator`); `options` is an open key→value
/// table the selected factory interprets (e.g. `cost_margin` for
/// `incremental`, `artifact` for `coordinator`), so registering engine #5
/// never changes this type.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineSpec {
    /// Registered engine name.
    pub name: String,
    /// Engine-specific options (free keys under `[engine]` in TOML).
    pub options: BTreeMap<String, Value>,
}

impl EngineSpec {
    /// Spec for a registered engine with no options.
    pub fn named(name: &str) -> EngineSpec {
        EngineSpec { name: name.to_string(), options: BTreeMap::new() }
    }

    /// Builder: attach one engine option.
    pub fn with_option(mut self, key: &str, value: Value) -> EngineSpec {
        self.options.insert(key.to_string(), value);
        self
    }

    /// String option, if present.
    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(Value::as_str)
    }

    /// Float option (integer literals accepted), if present. A value of
    /// the wrong type is a loud error, not a silent default.
    pub fn f64_opt(&self, key: &str) -> Result<Option<f64>> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v.as_float().map(Some).ok_or_else(|| {
                anyhow!("[engine] {key} must be a number, got {v:?}")
            }),
        }
    }

    /// Non-negative integer option, if present.
    pub fn usize_opt(&self, key: &str) -> Result<Option<usize>> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => match v.as_int() {
                Some(i) if i >= 0 => Ok(Some(i as usize)),
                _ => bail!("[engine] {key} must be a non-negative integer, got {v:?}"),
            },
        }
    }
}

impl Default for EngineSpec {
    fn default() -> Self {
        EngineSpec::named("plan")
    }
}

/// Shard topology: how many workers serve the logical graph and which
/// simulated devices they pin to. `shards = 1` **is** the single-leader
/// server — [`crate::serve::Deployment::launch`] returns a
/// [`crate::server::ServerHandle`] for it and a [`crate::fleet::Fleet`]
/// otherwise, behind the same [`crate::serve::Serving`] object.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    /// Worker count (≥ 1).
    pub shards: usize,
    /// Device preset names, cycled over the shards (see
    /// [`HardwareConfig::preset_names`]).
    pub devices: Vec<String>,
    /// Stored bytes per feature element on the halo link (2 = FP16).
    pub dtype_bytes: usize,
}

impl Topology {
    /// `n` identical Series-2 NPU shards (the clean scaling sweep).
    pub fn homogeneous(n: usize) -> Topology {
        Topology { shards: n.max(1), ..Topology::default() }
    }

    /// `n` shards cycling the full device zoo (NPU2, NPU1, iGPU, CPU) —
    /// the heterogeneous placement the cost model exists for.
    pub fn zoo(n: usize) -> Topology {
        Topology {
            shards: n.max(1),
            devices: ["series2", "series1", "gpu", "cpu"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            dtype_bytes: 2,
        }
    }

    /// The device roster cycled to `shards` length, every name resolved
    /// through the one device table ([`HardwareConfig::preset`]).
    pub fn roster(&self) -> Result<Vec<HardwareConfig>> {
        if self.devices.is_empty() {
            bail!(
                "topology.devices is empty — pick from: {}",
                HardwareConfig::preset_names().join(" | ")
            );
        }
        (0..self.shards.max(1))
            .map(|i| {
                let name = &self.devices[i % self.devices.len()];
                HardwareConfig::preset(name)
                    .with_context(|| format!("topology.devices entry {i}"))
            })
            .collect()
    }
}

impl Default for Topology {
    fn default() -> Self {
        Topology { shards: 1, devices: vec!["series2".to_string()], dtype_bytes: 2 }
    }
}

/// Query batching window (the coalescing the paper's batcher does).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchSpec {
    /// Largest batch one inference round answers.
    pub max_batch: usize,
    /// Longest a query waits for peers to coalesce, microseconds.
    pub max_wait_us: u64,
}

impl BatchSpec {
    /// The equivalent worker-loop config.
    pub fn server_config(&self) -> ServerConfig {
        ServerConfig {
            max_batch: self.max_batch,
            max_wait: Duration::from_micros(self.max_wait_us),
        }
    }
}

impl Default for BatchSpec {
    fn default() -> Self {
        let d = ServerConfig::default();
        BatchSpec {
            max_batch: d.max_batch,
            max_wait_us: d.max_wait.as_micros() as u64,
        }
    }
}

/// Telemetry knobs (`[telemetry]` in TOML): query tracing + per-op plan
/// profiling + the cost-model calibration loop, **off by default** —
/// disabled telemetry keeps every hot path branch-only and
/// allocation-free (see [`crate::telemetry`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TelemetrySpec {
    /// Master switch.
    pub enabled: bool,
    /// Span capacity of each per-worker ring (oldest spans overwritten).
    pub ring_capacity: usize,
    /// Fraction of traces recorded, in (0, 1]; 1.0 records everything.
    pub sample_rate: f64,
}

impl TelemetrySpec {
    /// Lower to the telemetry layer's runtime config.
    pub fn config(&self) -> crate::telemetry::TelemetryConfig {
        crate::telemetry::TelemetryConfig {
            enabled: self.enabled,
            ring_capacity: self.ring_capacity,
            sample_rate: self.sample_rate,
        }
    }
}

impl Default for TelemetrySpec {
    fn default() -> Self {
        let d = crate::telemetry::TelemetryConfig::default();
        TelemetrySpec {
            enabled: d.enabled,
            ring_capacity: d.ring_capacity,
            sample_rate: d.sample_rate,
        }
    }
}

/// Service-level objective (`[slo]` in TOML): what "healthy" means for
/// this deployment, evaluated by the monitor thread with fast/slow
/// multi-window burn rates (see [`crate::monitor::slo`]) and surfaced
/// through [`crate::serve::Serving::health`]. Off by default; enabling
/// it implies monitor sampling even without a `[monitor]` section.
#[derive(Debug, Clone, PartialEq)]
pub struct SloSpec {
    /// Master switch: evaluate the objective and surface breaches.
    pub enabled: bool,
    /// Latency objective in microseconds: the `quantile` latency must
    /// stay at or below this.
    pub latency_us: usize,
    /// Which latency quantile the objective targets, strictly inside
    /// (0, 1) — e.g. `0.95` for a p95 objective.
    pub quantile: f64,
    /// Availability target, strictly inside (0, 1) — e.g. `0.999`. The
    /// error budget is `1 − availability`; burn rates are measured
    /// against it.
    pub availability: f64,
    /// Fast burn window, milliseconds (catches sudden regressions).
    pub fast_window_ms: usize,
    /// Slow burn window, milliseconds (filters blips; must exceed the
    /// fast window).
    pub slow_window_ms: usize,
    /// Burn-rate threshold: a breach requires the budget to burn faster
    /// than this multiple of sustainable in **both** windows; must be
    /// > 1 (a threshold ≤ 1 alerts on exactly-on-budget behavior).
    pub burn_threshold: f64,
    /// Feed an active breach to the shard engines as queue pressure
    /// (waives the `auto` engine's anti-flap cooldown so it can switch
    /// strategies immediately).
    pub pressure: bool,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec {
            enabled: false,
            latency_us: 50_000,
            quantile: 0.95,
            availability: 0.999,
            fast_window_ms: 5_000,
            slow_window_ms: 60_000,
            burn_threshold: 2.0,
            pressure: true,
        }
    }
}

impl SloSpec {
    /// Lower to the monitor's runtime parameters (validated fields are
    /// assumed in-range past this point).
    pub fn params(&self) -> crate::monitor::SloParams {
        crate::monitor::SloParams {
            latency_us: self.latency_us as f64,
            quantile: self.quantile,
            availability: self.availability,
            fast_window_ms: self.fast_window_ms as u64,
            slow_window_ms: self.slow_window_ms as u64,
            burn_threshold: self.burn_threshold,
        }
    }
}

/// Monitor knobs (`[monitor]` in TOML): the sampling thread behind the
/// history rings, health watchdog, flight recorder and scrape endpoint
/// (see [`crate::monitor`]). Off by default — with the section absent
/// the hot path performs no extra clock read, lock, or allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct MonitorSpec {
    /// Master switch for the sampling thread (also implied by a
    /// non-empty `addr` or an enabled `[slo]`).
    pub enabled: bool,
    /// Sampling interval, milliseconds. Also the stall-watchdog
    /// threshold: a shard whose heartbeat is older than one interval is
    /// flagged wedged.
    pub interval_ms: usize,
    /// Samples retained per shard history ring (oldest overwritten).
    pub history: usize,
    /// Scrape endpoint bind address (`"127.0.0.1:9898"`); empty = no
    /// HTTP listener. Serves `GET /metrics`, `/health`, `/traces`,
    /// `/events`.
    pub addr: String,
}

impl Default for MonitorSpec {
    fn default() -> Self {
        MonitorSpec {
            enabled: false,
            interval_ms: 250,
            history: 240,
            addr: String::new(),
        }
    }
}

/// Autotuner + runtime-adaptive engine knobs (`[tuning]` in TOML).
///
/// The same section feeds two consumers: `Deployment::autotune` (how
/// many live probes, how long each runs, which objective ranks the
/// candidates) and the `auto` engine (the hysteresis band and cooldown
/// that keep its runtime plan↔incremental switching from flapping).
/// Defaults are usable without a `[tuning]` section at all.
#[derive(Debug, Clone, PartialEq)]
pub struct TuningSpec {
    /// What the tuner optimizes: `"latency"` (p50 query latency) or
    /// `"throughput"` (answered queries per second).
    pub objective: String,
    /// Queries issued per live probe (and per calibration probe) during
    /// autotuning; must be ≥ 1 — a zero-query probe measures nothing.
    pub probe_budget: usize,
    /// How many cost-model-ranked candidates are confirmed with live
    /// probes through the real launch path; must be ≥ 1.
    pub top_k: usize,
    /// `auto` engine: mutations-per-round at or below which it favors
    /// the incremental (delta-driven) strategy.
    pub hysteresis_low: f64,
    /// `auto` engine: mutations-per-round at or above which it favors
    /// the full planned recompute; must exceed `hysteresis_low` (the gap
    /// is the dead band that prevents flapping).
    pub hysteresis_high: f64,
    /// `auto` engine: minimum inference rounds between two strategy
    /// switches, whatever the signals say.
    pub cooldown_rounds: usize,
}

impl Default for TuningSpec {
    fn default() -> Self {
        TuningSpec {
            objective: "latency".to_string(),
            probe_budget: 64,
            top_k: 3,
            hysteresis_low: 1.0,
            hysteresis_high: 8.0,
            cooldown_rounds: 4,
        }
    }
}

/// Out-of-core feature storage (`[storage]` in TOML): where a shard's
/// node features live and how much of them stay resident.
///
/// `backend = "memory"` (the default) keeps the NodePad-padded feature
/// matrix in RAM exactly as before. `backend = "paged"` puts it in a
/// page-aligned `.gnnt`-compatible file (see [`crate::storage`]) and
/// serves gathers through a fixed-capacity page cache with TinyLFU
/// admission — resident footprint becomes `cache_pages × page_rows ×
/// features × 4` bytes instead of `capacity × features × 4`, which is
/// what lets a 10M-node graph serve inside single-digit-GiB RAM.
/// Currently the `incremental` engine is the paged consumer; engines
/// that materialize the full feature matrix reject `"paged"` at
/// validation with a pointer here.
#[derive(Debug, Clone, PartialEq)]
pub struct StorageSpec {
    /// `"memory"` (resident feature matrix) or `"paged"` (file-backed
    /// store behind a page cache).
    pub backend: String,
    /// Rows per cache page (read granularity, not a file property —
    /// the same store file serves any `page_rows`).
    pub page_rows: usize,
    /// Page-cache capacity **per shard**, in pages.
    pub cache_pages: usize,
    /// Pre-built store file to open (`""` = spill the launched
    /// dataset's features to a temp store, deleted on shutdown). Lets
    /// 10M-node deployments launch from a headless dataset whose
    /// features exist only on disk.
    pub path: String,
}

impl StorageSpec {
    /// Is the file-backed paged tier selected?
    pub fn is_paged(&self) -> bool {
        self.backend == "paged"
    }

    /// Resident page-cache bytes per shard this spec allows for a
    /// `width`-column feature matrix (the sizing number README's
    /// guidance is written around).
    pub fn cache_bytes(&self, width: usize) -> usize {
        self.cache_pages
            .saturating_mul(self.page_rows)
            .saturating_mul(width)
            .saturating_mul(4)
    }
}

impl Default for StorageSpec {
    fn default() -> Self {
        StorageSpec {
            backend: "memory".to_string(),
            page_rows: 64,
            cache_pages: 1024,
            path: String::new(),
        }
    }
}

/// Kernel-layer knobs (`[kernels]` in TOML): which microkernel paths the
/// engines dispatch and how sparse rows are scheduled across lanes.
/// Strings are kept verbatim here and only lowered (and therefore
/// validated) by [`KernelSpec::kernel_config`], so an invalid value is
/// reported with the parser's actionable message, not a silent default.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelSpec {
    /// SIMD microkernel dispatch: `"auto"`, `"on"` or `"off"` (`"off"`
    /// is the scalar oracle path; the blocked kernels are bit-comparable
    /// with it, so `"auto"` dispatches them).
    pub simd: String,
    /// CacheG-style node reordering computed at plan-compile time:
    /// `"none"`, `"degree"` (hubs first, lane balance) or `"rcm"`
    /// (bandwidth reduction, gather locality). The sharded serving
    /// engines currently support `"none"` only — their factories reject
    /// the rest at validation.
    pub reorder: String,
    /// Chunks-per-lane granularity of the nnz-balanced SpMM dispenser
    /// (≥ 1; higher = finer work-stealing at more dispatch overhead).
    pub degree_bins: usize,
}

impl KernelSpec {
    /// Lower (and validate) to the plan compiler's [`KernelConfig`] —
    /// the one place spec strings become typed kernel modes.
    pub fn kernel_config(&self) -> Result<crate::ops::plan::KernelConfig> {
        if self.degree_bins == 0 {
            bail!(
                "kernels.degree_bins must be ≥ 1 (got 0) — it is the \
                 chunks-per-lane granularity of the nnz-balanced scheduler, \
                 and the default ({}) is a good start",
                crate::engine::kernels::DEGREE_BINS_DEFAULT
            );
        }
        Ok(crate::ops::plan::KernelConfig {
            simd: crate::ops::plan::SimdMode::parse(&self.simd)?,
            reorder: crate::ops::plan::ReorderMode::parse(&self.reorder)?,
            degree_bins: self.degree_bins,
        })
    }
}

impl Default for KernelSpec {
    fn default() -> Self {
        let d = crate::ops::plan::KernelConfig::default();
        KernelSpec {
            simd: d.simd.name().to_string(),
            reorder: d.reorder.name().to_string(),
            degree_bins: d.degree_bins,
        }
    }
}

/// One typed deployment: everything
/// [`crate::serve::Deployment::launch`] needs to serve a graph, and
/// nothing it has to re-parse per subsystem.
///
/// The TOML shape mirrors the struct — top-level scalars plus
/// `[engine]`, `[kernels]`, `[topology]`, `[batch]`, `[admission]`,
/// `[telemetry]`, `[slo]`, `[monitor]`, `[tuning]` tables — and
/// `parse_toml(to_toml(spec)) == spec` holds for every spec that
/// passes [`DeploymentSpec::validate`] (the subset has no string
/// escapes, so validation rejects embedded quotes; tested in
/// `rust/tests/serve_spec.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentSpec {
    /// Model family. Offline engines synthesize GCN plans, so they
    /// require `"gcn"`; the `coordinator` engine serves whatever
    /// artifact `[engine] artifact` names.
    pub model: String,
    /// NodePad capacity (node-id space). `0` derives
    /// `nodes + nodes/8` from the launched graph.
    pub capacity: usize,
    /// Aggregation lowering: GraSp sparse SpMM, dense MatMul, or
    /// density-resolved `auto`.
    pub aggregation: Aggregation,
    /// QuantGr INT8 (`plan` engine only): compile the quantized graph
    /// and pre-quantize weights to the i8 datapath.
    pub quant: bool,
    /// Which engine factory builds the per-shard workers.
    pub engine: EngineSpec,
    /// Kernel dispatch + scheduling knobs compiled into every plan.
    pub kernels: KernelSpec,
    /// Shard count + device roster.
    pub topology: Topology,
    /// Query-coalescing window.
    pub batch: BatchSpec,
    /// Per-shard load shedding (0 = unbounded, the single-leader
    /// historical behavior).
    pub admission: AdmissionConfig,
    /// Query tracing + plan profiling (off by default).
    pub telemetry: TelemetrySpec,
    /// Latency/availability objective the monitor evaluates (off by
    /// default).
    pub slo: SloSpec,
    /// Monitor sampling thread + scrape endpoint (off by default).
    pub monitor: MonitorSpec,
    /// Autotuner probes/objective + `auto` engine switching bands.
    pub tuning: TuningSpec,
    /// Feature-storage tier: resident matrix or paged file-backed store.
    pub storage: StorageSpec,
}

impl Default for DeploymentSpec {
    fn default() -> Self {
        DeploymentSpec {
            model: "gcn".to_string(),
            capacity: 0,
            aggregation: Aggregation::Auto,
            quant: false,
            engine: EngineSpec::default(),
            kernels: KernelSpec::default(),
            topology: Topology::default(),
            batch: BatchSpec::default(),
            admission: AdmissionConfig::unbounded(),
            telemetry: TelemetrySpec::default(),
            slo: SloSpec::default(),
            monitor: MonitorSpec::default(),
            tuning: TuningSpec::default(),
            storage: StorageSpec::default(),
        }
    }
}

impl DeploymentSpec {
    /// Parse a spec from TOML-subset text. Unknown sections and keys are
    /// loud errors (a typo'd knob must not silently become a default).
    pub fn parse_toml(text: &str) -> Result<DeploymentSpec> {
        let doc = Document::parse(text)?;
        DeploymentSpec::from_doc(&doc)
    }

    /// [`Self::parse_toml`] from a file, with the path in every error.
    pub fn load(path: &std::path::Path) -> Result<DeploymentSpec> {
        let doc = Document::load(path)?;
        DeploymentSpec::from_doc(&doc)
            .with_context(|| format!("deployment spec {}", path.display()))
    }

    /// Parse from an already-loaded [`Document`].
    pub fn from_doc(doc: &Document) -> Result<DeploymentSpec> {
        const SECTIONS: &[&str] = &[
            "",
            "engine",
            "kernels",
            "topology",
            "batch",
            "admission",
            "telemetry",
            "slo",
            "monitor",
            "tuning",
            "storage",
        ];
        for section in doc.section_names() {
            if !SECTIONS.contains(&section) {
                bail!(
                    "unknown section [{section}] — a deployment spec has \
                     [engine], [kernels], [topology], [batch], [admission], \
                     [telemetry], [slo], [monitor], [tuning], [storage] and \
                     the top-level keys model, capacity, aggregation, quant"
                );
            }
        }
        let mut spec = DeploymentSpec::default();

        check_keys(doc, "", &["model", "capacity", "aggregation", "quant"])?;
        if let Some(v) = doc.get("", "model") {
            spec.model = str_of(v, "", "model")?.to_string();
        }
        if let Some(v) = doc.get("", "capacity") {
            spec.capacity = usize_of(v, "", "capacity")?;
        }
        if let Some(v) = doc.get("", "aggregation") {
            spec.aggregation = Aggregation::parse(str_of(v, "", "aggregation")?)?;
        }
        if let Some(v) = doc.get("", "quant") {
            spec.quant = bool_of(v, "", "quant")?;
        }

        if let Some(table) = doc.section("engine") {
            let mut engine = EngineSpec::named(&spec.engine.name);
            for (key, value) in table {
                if key == "name" {
                    engine.name = str_of(value, "engine", "name")?.to_string();
                } else {
                    engine.options.insert(key.clone(), value.clone());
                }
            }
            spec.engine = engine;
        }

        if let Some(_table) = doc.section("kernels") {
            check_keys(doc, "kernels", &["simd", "reorder", "degree_bins"])?;
            if let Some(v) = doc.get("kernels", "simd") {
                spec.kernels.simd = str_of(v, "kernels", "simd")?.to_string();
            }
            if let Some(v) = doc.get("kernels", "reorder") {
                spec.kernels.reorder = str_of(v, "kernels", "reorder")?.to_string();
            }
            if let Some(v) = doc.get("kernels", "degree_bins") {
                spec.kernels.degree_bins = usize_of(v, "kernels", "degree_bins")?;
            }
        }

        if let Some(_table) = doc.section("topology") {
            check_keys(doc, "topology", &["shards", "devices", "dtype_bytes"])?;
            if let Some(v) = doc.get("topology", "shards") {
                spec.topology.shards = usize_of(v, "topology", "shards")?;
            }
            if let Some(v) = doc.get("topology", "devices") {
                let arr = v.as_array().ok_or_else(|| {
                    anyhow!("[topology] devices must be an array of preset names")
                })?;
                spec.topology.devices = arr
                    .iter()
                    .map(|d| {
                        d.as_str().map(str::to_string).ok_or_else(|| {
                            anyhow!("[topology] devices entries must be strings, got {d:?}")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(v) = doc.get("topology", "dtype_bytes") {
                spec.topology.dtype_bytes = usize_of(v, "topology", "dtype_bytes")?;
            }
        }

        if let Some(_table) = doc.section("batch") {
            check_keys(doc, "batch", &["max_batch", "max_wait_us"])?;
            if let Some(v) = doc.get("batch", "max_batch") {
                spec.batch.max_batch = usize_of(v, "batch", "max_batch")?;
            }
            if let Some(v) = doc.get("batch", "max_wait_us") {
                spec.batch.max_wait_us = usize_of(v, "batch", "max_wait_us")? as u64;
            }
        }

        if let Some(_table) = doc.section("admission") {
            check_keys(doc, "admission", &["max_pending"])?;
            if let Some(v) = doc.get("admission", "max_pending") {
                spec.admission.max_pending = usize_of(v, "admission", "max_pending")?;
            }
        }

        if let Some(_table) = doc.section("telemetry") {
            check_keys(
                doc,
                "telemetry",
                &["enabled", "ring_capacity", "sample_rate"],
            )?;
            if let Some(v) = doc.get("telemetry", "enabled") {
                spec.telemetry.enabled = bool_of(v, "telemetry", "enabled")?;
            }
            if let Some(v) = doc.get("telemetry", "ring_capacity") {
                spec.telemetry.ring_capacity =
                    usize_of(v, "telemetry", "ring_capacity")?;
            }
            if let Some(v) = doc.get("telemetry", "sample_rate") {
                spec.telemetry.sample_rate = v.as_float().ok_or_else(|| {
                    anyhow!("[telemetry] sample_rate must be a number, got {v:?}")
                })?;
            }
        }

        if let Some(_table) = doc.section("slo") {
            check_keys(
                doc,
                "slo",
                &[
                    "enabled",
                    "latency_us",
                    "quantile",
                    "availability",
                    "fast_window_ms",
                    "slow_window_ms",
                    "burn_threshold",
                    "pressure",
                ],
            )?;
            if let Some(v) = doc.get("slo", "enabled") {
                spec.slo.enabled = bool_of(v, "slo", "enabled")?;
            }
            if let Some(v) = doc.get("slo", "latency_us") {
                spec.slo.latency_us = usize_of(v, "slo", "latency_us")?;
            }
            if let Some(v) = doc.get("slo", "quantile") {
                spec.slo.quantile = v.as_float().ok_or_else(|| {
                    anyhow!("[slo] quantile must be a number, got {v:?}")
                })?;
            }
            if let Some(v) = doc.get("slo", "availability") {
                spec.slo.availability = v.as_float().ok_or_else(|| {
                    anyhow!("[slo] availability must be a number, got {v:?}")
                })?;
            }
            if let Some(v) = doc.get("slo", "fast_window_ms") {
                spec.slo.fast_window_ms = usize_of(v, "slo", "fast_window_ms")?;
            }
            if let Some(v) = doc.get("slo", "slow_window_ms") {
                spec.slo.slow_window_ms = usize_of(v, "slo", "slow_window_ms")?;
            }
            if let Some(v) = doc.get("slo", "burn_threshold") {
                spec.slo.burn_threshold = v.as_float().ok_or_else(|| {
                    anyhow!("[slo] burn_threshold must be a number, got {v:?}")
                })?;
            }
            if let Some(v) = doc.get("slo", "pressure") {
                spec.slo.pressure = bool_of(v, "slo", "pressure")?;
            }
        }

        if let Some(_table) = doc.section("monitor") {
            check_keys(doc, "monitor", &["enabled", "interval_ms", "history", "addr"])?;
            if let Some(v) = doc.get("monitor", "enabled") {
                spec.monitor.enabled = bool_of(v, "monitor", "enabled")?;
            }
            if let Some(v) = doc.get("monitor", "interval_ms") {
                spec.monitor.interval_ms = usize_of(v, "monitor", "interval_ms")?;
            }
            if let Some(v) = doc.get("monitor", "history") {
                spec.monitor.history = usize_of(v, "monitor", "history")?;
            }
            if let Some(v) = doc.get("monitor", "addr") {
                spec.monitor.addr = str_of(v, "monitor", "addr")?.to_string();
            }
        }

        if let Some(_table) = doc.section("tuning") {
            check_keys(
                doc,
                "tuning",
                &[
                    "objective",
                    "probe_budget",
                    "top_k",
                    "hysteresis_low",
                    "hysteresis_high",
                    "cooldown_rounds",
                ],
            )?;
            if let Some(v) = doc.get("tuning", "objective") {
                spec.tuning.objective = str_of(v, "tuning", "objective")?.to_string();
            }
            if let Some(v) = doc.get("tuning", "probe_budget") {
                spec.tuning.probe_budget = usize_of(v, "tuning", "probe_budget")?;
            }
            if let Some(v) = doc.get("tuning", "top_k") {
                spec.tuning.top_k = usize_of(v, "tuning", "top_k")?;
            }
            if let Some(v) = doc.get("tuning", "hysteresis_low") {
                spec.tuning.hysteresis_low = v.as_float().ok_or_else(|| {
                    anyhow!("[tuning] hysteresis_low must be a number, got {v:?}")
                })?;
            }
            if let Some(v) = doc.get("tuning", "hysteresis_high") {
                spec.tuning.hysteresis_high = v.as_float().ok_or_else(|| {
                    anyhow!("[tuning] hysteresis_high must be a number, got {v:?}")
                })?;
            }
            if let Some(v) = doc.get("tuning", "cooldown_rounds") {
                spec.tuning.cooldown_rounds =
                    usize_of(v, "tuning", "cooldown_rounds")?;
            }
        }

        if let Some(_table) = doc.section("storage") {
            check_keys(
                doc,
                "storage",
                &["backend", "page_rows", "cache_pages", "path"],
            )?;
            if let Some(v) = doc.get("storage", "backend") {
                spec.storage.backend = str_of(v, "storage", "backend")?.to_string();
            }
            if let Some(v) = doc.get("storage", "page_rows") {
                spec.storage.page_rows = usize_of(v, "storage", "page_rows")?;
            }
            if let Some(v) = doc.get("storage", "cache_pages") {
                spec.storage.cache_pages = usize_of(v, "storage", "cache_pages")?;
            }
            if let Some(v) = doc.get("storage", "path") {
                spec.storage.path = str_of(v, "storage", "path")?.to_string();
            }
        }

        Ok(spec)
    }

    /// Emit the spec as TOML-subset text that [`Self::parse_toml`]
    /// reads back to an equal value.
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        out.push_str("# grannite deployment spec\n");
        out.push_str(&format!("model = \"{}\"\n", self.model));
        out.push_str(&format!("capacity = {}\n", self.capacity));
        out.push_str(&format!("aggregation = \"{}\"\n", self.aggregation.name()));
        out.push_str(&format!("quant = {}\n", self.quant));
        out.push_str("\n[engine]\n");
        out.push_str(&format!("name = \"{}\"\n", self.engine.name));
        for (key, value) in &self.engine.options {
            out.push_str(&format!("{key} = {}\n", emit_value(value)));
        }
        out.push_str("\n[kernels]\n");
        out.push_str(&format!("simd = \"{}\"\n", self.kernels.simd));
        out.push_str(&format!("reorder = \"{}\"\n", self.kernels.reorder));
        out.push_str(&format!("degree_bins = {}\n", self.kernels.degree_bins));
        out.push_str("\n[topology]\n");
        out.push_str(&format!("shards = {}\n", self.topology.shards));
        let devices: Vec<String> = self
            .topology
            .devices
            .iter()
            .map(|d| format!("\"{d}\""))
            .collect();
        out.push_str(&format!("devices = [{}]\n", devices.join(", ")));
        out.push_str(&format!("dtype_bytes = {}\n", self.topology.dtype_bytes));
        out.push_str("\n[batch]\n");
        out.push_str(&format!("max_batch = {}\n", self.batch.max_batch));
        out.push_str(&format!("max_wait_us = {}\n", self.batch.max_wait_us));
        out.push_str("\n[admission]\n");
        out.push_str(&format!("max_pending = {}\n", self.admission.max_pending));
        out.push_str("\n[telemetry]\n");
        out.push_str(&format!("enabled = {}\n", self.telemetry.enabled));
        out.push_str(&format!(
            "ring_capacity = {}\n",
            self.telemetry.ring_capacity
        ));
        out.push_str(&format!(
            "sample_rate = {}\n",
            emit_value(&Value::Float(self.telemetry.sample_rate))
        ));
        out.push_str("\n[slo]\n");
        out.push_str(&format!("enabled = {}\n", self.slo.enabled));
        out.push_str(&format!("latency_us = {}\n", self.slo.latency_us));
        out.push_str(&format!(
            "quantile = {}\n",
            emit_value(&Value::Float(self.slo.quantile))
        ));
        out.push_str(&format!(
            "availability = {}\n",
            emit_value(&Value::Float(self.slo.availability))
        ));
        out.push_str(&format!("fast_window_ms = {}\n", self.slo.fast_window_ms));
        out.push_str(&format!("slow_window_ms = {}\n", self.slo.slow_window_ms));
        out.push_str(&format!(
            "burn_threshold = {}\n",
            emit_value(&Value::Float(self.slo.burn_threshold))
        ));
        out.push_str(&format!("pressure = {}\n", self.slo.pressure));
        out.push_str("\n[monitor]\n");
        out.push_str(&format!("enabled = {}\n", self.monitor.enabled));
        out.push_str(&format!("interval_ms = {}\n", self.monitor.interval_ms));
        out.push_str(&format!("history = {}\n", self.monitor.history));
        out.push_str(&format!("addr = \"{}\"\n", self.monitor.addr));
        out.push_str("\n[tuning]\n");
        out.push_str(&format!("objective = \"{}\"\n", self.tuning.objective));
        out.push_str(&format!("probe_budget = {}\n", self.tuning.probe_budget));
        out.push_str(&format!("top_k = {}\n", self.tuning.top_k));
        out.push_str(&format!(
            "hysteresis_low = {}\n",
            emit_value(&Value::Float(self.tuning.hysteresis_low))
        ));
        out.push_str(&format!(
            "hysteresis_high = {}\n",
            emit_value(&Value::Float(self.tuning.hysteresis_high))
        ));
        out.push_str(&format!(
            "cooldown_rounds = {}\n",
            self.tuning.cooldown_rounds
        ));
        out.push_str("\n[storage]\n");
        out.push_str(&format!("backend = \"{}\"\n", self.storage.backend));
        out.push_str(&format!("page_rows = {}\n", self.storage.page_rows));
        out.push_str(&format!("cache_pages = {}\n", self.storage.cache_pages));
        out.push_str(&format!("path = \"{}\"\n", self.storage.path));
        out
    }

    /// Structural validation (everything checkable without an engine
    /// registry). Every rejection names the offending key and what would
    /// fix it.
    pub fn validate(&self) -> Result<()> {
        if self.model.is_empty() {
            bail!("model is empty — offline engines serve \"gcn\"");
        }
        // the TOML subset has no string escapes, so a quote inside any
        // string would make to_toml() emit text parse_toml() rejects —
        // fail loudly here instead of at reload time
        quote_free("model", &self.model)?;
        quote_free("[engine] name", &self.engine.name)?;
        for (key, value) in &self.engine.options {
            if let Value::Str(s) = value {
                quote_free(&format!("[engine] {key}"), s)?;
            }
        }
        for d in &self.topology.devices {
            quote_free("topology.devices entry", d)?;
        }
        // lowering validates the mode strings (actionable per-key
        // messages from the kernel-mode parsers) and degree_bins ≥ 1
        self.kernels.kernel_config()?;
        if self.topology.shards == 0 {
            bail!(
                "topology.shards must be ≥ 1 (got 0) — the single-leader \
                 server is shards = 1, not 0"
            );
        }
        self.topology.roster()?;
        if ![1, 2, 4].contains(&self.topology.dtype_bytes) {
            bail!(
                "topology.dtype_bytes must be 1 (INT8), 2 (FP16) or 4 \
                 (FP32), got {}",
                self.topology.dtype_bytes
            );
        }
        if self.batch.max_batch == 0 {
            bail!("batch.max_batch must be ≥ 1 (got 0)");
        }
        if self.telemetry.ring_capacity == 0 {
            bail!(
                "telemetry.ring_capacity must be ≥ 1 (got 0) — disable \
                 telemetry with enabled = false instead of a zero ring"
            );
        }
        if !(self.telemetry.sample_rate > 0.0 && self.telemetry.sample_rate <= 1.0)
        {
            bail!(
                "telemetry.sample_rate must be in (0, 1], got {} — 1.0 \
                 records every trace",
                self.telemetry.sample_rate
            );
        }
        if !(self.slo.quantile > 0.0 && self.slo.quantile < 1.0) {
            bail!(
                "slo.quantile must be strictly inside (0, 1), got {} — e.g. \
                 0.95 targets the p95 latency",
                self.slo.quantile
            );
        }
        if !(self.slo.availability > 0.0 && self.slo.availability < 1.0) {
            bail!(
                "slo.availability must be strictly inside (0, 1), got {} — \
                 1.0 leaves a zero error budget, which every burn rate \
                 divides by",
                self.slo.availability
            );
        }
        if self.slo.latency_us == 0 {
            bail!(
                "slo.latency_us must be ≥ 1 (got 0) — a zero-microsecond \
                 latency objective is unmeetable; disable the SLO with \
                 enabled = false instead"
            );
        }
        if self.slo.fast_window_ms == 0 || self.slo.slow_window_ms == 0 {
            bail!(
                "slo windows must be ≥ 1 ms (got fast = {} ms, slow = {} \
                 ms) — a zero-length window can never accumulate a burn \
                 rate",
                self.slo.fast_window_ms,
                self.slo.slow_window_ms
            );
        }
        if self.slo.fast_window_ms >= self.slo.slow_window_ms {
            bail!(
                "slo.fast_window_ms ({} ms) must be shorter than \
                 slo.slow_window_ms ({} ms) — the fast window catches \
                 sudden regressions, the slow window filters blips",
                self.slo.fast_window_ms,
                self.slo.slow_window_ms
            );
        }
        if !(self.slo.burn_threshold > 1.0 && self.slo.burn_threshold.is_finite()) {
            bail!(
                "slo.burn_threshold must be > 1 (got {}) — a threshold ≤ 1 \
                 fires on exactly-on-budget behavior; 2.0 alerts when the \
                 budget burns twice as fast as sustainable",
                self.slo.burn_threshold
            );
        }
        if self.monitor.interval_ms == 0 {
            bail!(
                "monitor.interval_ms must be ≥ 1 (got 0) — disable the \
                 monitor with enabled = false instead of a zero interval"
            );
        }
        if self.monitor.history < 2 {
            bail!(
                "monitor.history must be ≥ 2 (got {}) — windowed rates \
                 need at least two samples to difference",
                self.monitor.history
            );
        }
        quote_free("[monitor] addr", &self.monitor.addr)?;
        if !self.monitor.addr.is_empty()
            && self.monitor.addr.parse::<std::net::SocketAddr>().is_err()
        {
            bail!(
                "monitor.addr {:?} is not a bindable socket address — use \
                 \"host:port\" like \"127.0.0.1:9898\" (port 0 picks a \
                 free port), or \"\" for no scrape endpoint",
                self.monitor.addr
            );
        }
        if !matches!(self.tuning.objective.as_str(), "latency" | "throughput") {
            bail!(
                "tuning.objective must be \"latency\" or \"throughput\", \
                 got {:?}",
                self.tuning.objective
            );
        }
        if self.tuning.probe_budget == 0 {
            bail!(
                "tuning.probe_budget must be ≥ 1 (got 0) — a zero-query \
                 live probe cannot rank candidates"
            );
        }
        if self.tuning.top_k == 0 {
            bail!("tuning.top_k must be ≥ 1 (got 0) — at least the cost-model \
                   winner gets a live probe");
        }
        let (lo, hi) = (self.tuning.hysteresis_low, self.tuning.hysteresis_high);
        if !(lo.is_finite() && hi.is_finite() && lo >= 0.0 && lo < hi) {
            bail!(
                "tuning hysteresis band must satisfy 0 ≤ hysteresis_low < \
                 hysteresis_high (got low = {lo}, high = {hi}) — the gap is \
                 the dead band that keeps the auto engine from flapping"
            );
        }
        if !matches!(self.storage.backend.as_str(), "memory" | "paged") {
            bail!(
                "storage.backend must be \"memory\" (resident feature \
                 matrix) or \"paged\" (file-backed page cache), got {:?}",
                self.storage.backend
            );
        }
        if self.storage.page_rows == 0 {
            bail!(
                "storage.page_rows must be ≥ 1 (got 0) — it is the rows-per-\
                 page read granularity; 64 rows is a good default"
            );
        }
        if self.storage.cache_pages == 0 {
            bail!(
                "storage.cache_pages must be ≥ 1 (got 0) — a zero-page cache \
                 cannot serve a gather; use backend = \"memory\" to keep \
                 features fully resident instead"
            );
        }
        quote_free("[storage] path", &self.storage.path)?;
        if !self.storage.path.is_empty() && !self.storage.is_paged() {
            bail!(
                "storage.path {:?} is set but storage.backend is \
                 \"memory\" — a store file is only read by the paged \
                 backend; set backend = \"paged\" or drop the path",
                self.storage.path
            );
        }
        Ok(())
    }

    /// Full validation: structure, engine-name resolution against the
    /// registry (the error lists every registered engine), then the
    /// selected factory's own checks (quant support, model support,
    /// dense-mask budget, option types).
    pub fn validate_with(&self, registry: &crate::serve::EngineRegistry) -> Result<()> {
        self.validate()?;
        let factory = registry.get(&self.engine.name)?;
        factory.validate(self)
    }

    /// The NodePad capacity this spec serves a graph of `nodes` at:
    /// `capacity = 0` derives `nodes + nodes/8` slack, an explicit
    /// capacity must cover the graph.
    pub fn resolved_capacity(&self, nodes: usize) -> Result<usize> {
        if self.capacity == 0 {
            Ok(nodes + nodes / 8)
        } else if self.capacity < nodes {
            bail!(
                "capacity {} is smaller than the graph's {nodes} nodes — \
                 raise it or set capacity = 0 to derive nodes + 12.5% \
                 NodePad slack",
                self.capacity
            )
        } else {
            Ok(self.capacity)
        }
    }

    /// Is the monitor subsystem active for this spec? True when the
    /// `[monitor]` section is enabled, when a scrape address is set, or
    /// when an `[slo]` objective needs the sampling thread that
    /// evaluates it. False (the default) keeps the monitor a branch-only
    /// no-op on every hot path.
    pub fn monitor_active(&self) -> bool {
        self.monitor.enabled || !self.monitor.addr.is_empty() || self.slo.enabled
    }

    /// Lower the `[monitor]` + `[slo]` sections to the monitor's runtime
    /// config (meaningful only when [`DeploymentSpec::monitor_active`]).
    pub fn monitor_config(&self) -> crate::monitor::MonitorConfig {
        crate::monitor::MonitorConfig {
            interval: std::time::Duration::from_millis(
                self.monitor.interval_ms.max(1) as u64,
            ),
            history: self.monitor.history,
            slo: if self.slo.enabled { Some(self.slo.params()) } else { None },
            pressure: self.slo.pressure,
            events: 128,
        }
    }

    /// Lower the spec to the fleet layer's runtime config. Devices
    /// resolve through [`Topology::roster`] →
    /// [`HardwareConfig::preset`] — the one name→device table the CLI
    /// and [`FleetConfig::from_names`] also use.
    pub fn fleet_config(&self) -> Result<FleetConfig> {
        let mut cfg = FleetConfig::homogeneous(1);
        cfg.devices = self.topology.roster()?;
        cfg.batch = self.batch.server_config();
        cfg.admission = self.admission;
        cfg.dtype_bytes = self.topology.dtype_bytes;
        cfg.aggregation = self.aggregation;
        Ok(cfg)
    }
}

/// The TOML subset cannot represent embedded quotes; reject them at
/// validation so specs stay serializable.
fn quote_free(what: &str, s: &str) -> Result<()> {
    if s.contains('"') || s.contains('\'') {
        bail!(
            "{what} value {s:?} contains a quote character — not \
             representable in the TOML-subset spec format"
        );
    }
    Ok(())
}

/// Reject unknown keys in a fixed-schema section.
fn check_keys(doc: &Document, section: &str, known: &[&str]) -> Result<()> {
    if let Some(table) = doc.section(section) {
        for key in table.keys() {
            if !known.contains(&key.as_str()) {
                let at = if section.is_empty() { "top level".to_string() } else { format!("[{section}]") };
                bail!("unknown key {key:?} at {at} — expected one of: {}", known.join(", "));
            }
        }
    }
    Ok(())
}

fn str_of<'v>(v: &'v Value, section: &str, key: &str) -> Result<&'v str> {
    v.as_str()
        .ok_or_else(|| anyhow!("[{section}] {key} must be a string, got {v:?}"))
}

fn usize_of(v: &Value, section: &str, key: &str) -> Result<usize> {
    match v.as_int() {
        Some(i) if i >= 0 => Ok(i as usize),
        _ => bail!("[{section}] {key} must be a non-negative integer, got {v:?}"),
    }
}

fn bool_of(v: &Value, section: &str, key: &str) -> Result<bool> {
    v.as_bool()
        .ok_or_else(|| anyhow!("[{section}] {key} must be true or false, got {v:?}"))
}

/// Emit a [`Value`] so the TOML-subset parser reads the same value back
/// (floats always carry a decimal point so they stay floats).
fn emit_value(v: &Value) -> String {
    match v {
        Value::Str(s) => format!("\"{s}\""),
        Value::Int(i) => i.to_string(),
        Value::Float(f) => {
            let s = format!("{f}");
            if s.contains('.') || s.contains('e') || s.contains("inf") || s.contains("NaN") {
                s
            } else {
                format!("{s}.0")
            }
        }
        Value::Bool(b) => b.to_string(),
        Value::Array(items) => {
            let inner: Vec<String> = items.iter().map(emit_value).collect();
            format!("[{}]", inner.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_single_leader_plan() {
        let spec = DeploymentSpec::default();
        assert_eq!(spec.engine.name, "plan");
        assert_eq!(spec.topology.shards, 1);
        spec.validate().unwrap();
    }

    #[test]
    fn empty_document_parses_to_default() {
        assert_eq!(DeploymentSpec::parse_toml("").unwrap(), DeploymentSpec::default());
    }

    #[test]
    fn unknown_section_and_keys_are_loud() {
        let err = DeploymentSpec::parse_toml("[topolgy]\nshards = 2")
            .unwrap_err()
            .to_string();
        assert!(err.contains("[topolgy]"), "{err}");
        let err = DeploymentSpec::parse_toml("[topology]\nshard = 2")
            .unwrap_err()
            .to_string();
        assert!(err.contains("\"shard\"") && err.contains("shards"), "{err}");
    }

    #[test]
    fn roster_cycles_and_rejects_unknowns() {
        let t = Topology { shards: 5, ..Topology::zoo(5) };
        let roster = t.roster().unwrap();
        assert_eq!(roster.len(), 5);
        assert_eq!(roster[4].name, roster[0].name, "roster cycles");
        let bad = Topology {
            devices: vec!["tpu".to_string()],
            ..Topology::default()
        };
        let err = bad.roster().unwrap_err();
        assert!(format!("{err:#}").contains("series2"), "{err:#}");
    }

    #[test]
    fn quoted_strings_are_rejected_at_validation() {
        let mut s = DeploymentSpec::default();
        s.model = "g\"cn".into();
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("quote"), "{err}");

        let mut s = DeploymentSpec::default();
        s.engine = EngineSpec::named("plan")
            .with_option("artifact", Value::Str("a'b".into()));
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("quote"), "{err}");
    }

    #[test]
    fn storage_section_parses_and_validates() {
        let spec = DeploymentSpec::parse_toml(
            "[storage]\nbackend = \"paged\"\npage_rows = 16\n\
             cache_pages = 8\npath = \"/tmp/feat.gnnt\"",
        )
        .unwrap();
        assert!(spec.storage.is_paged());
        assert_eq!(spec.storage.page_rows, 16);
        assert_eq!(spec.storage.cache_bytes(10), 8 * 16 * 10 * 4);
        spec.validate().unwrap();

        let mut bad = DeploymentSpec::default();
        bad.storage.backend = "disk".into();
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("\"paged\""), "{err}");

        let mut bad = DeploymentSpec::default();
        bad.storage.path = "feat.gnnt".into();
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("backend"), "{err}");

        let mut bad = DeploymentSpec::default();
        bad.storage.backend = "paged".into();
        bad.storage.cache_pages = 0;
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("cache_pages"), "{err}");
    }

    #[test]
    fn float_emission_round_trips() {
        assert_eq!(emit_value(&Value::Float(2.0)), "2.0");
        assert_eq!(emit_value(&Value::Float(0.75)), "0.75");
        let doc = Document::parse("x = 2.0").unwrap();
        assert_eq!(doc.get("", "x"), Some(&Value::Float(2.0)));
    }
}
