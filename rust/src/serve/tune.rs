//! The spec-space autotuner: `Deployment::autotune` searches the
//! deployment spec space for the best way to serve a dataset, instead of
//! making the user hand-pick engine × aggregation × quant × shard count.
//!
//! Three stages, cheapest first:
//!
//! 1. **Enumerate + prune.** Candidate specs are generated around the
//!    base spec (engine family, aggregation lowering, QuantGr INT8,
//!    shard count) and pruned by the same
//!    [`DeploymentSpec::validate_with`] a launch would run — a candidate
//!    the registry would reject (dense mask over budget, quant on an
//!    engine without a MAC datapath) never costs a probe.
//! 2. **Score with the calibrated cost model.** Every surviving
//!    candidate's model graph is priced with
//!    [`crate::npu::cost::graph_cost_scaled`] on its own device roster —
//!    per-shard compute prorated by owned nodes, plus the placement's
//!    halo estimate — using [`CostScales`] fitted from a short
//!    telemetry-enabled probe of the base spec. When the probe observed
//!    nothing (or telemetry is unavailable) the scales are empty and the
//!    score falls back to the raw model, exactly as
//!    [`crate::npu::cost::op_cost_scaled`] documents.
//! 3. **Confirm top-K live.** The `top_k` best-scored candidates are
//!    launched through the real [`Deployment::launch`] path and driven
//!    with a short deterministic query/update workload; the winner is
//!    the best *observed* objective (`latency` = mean µs per query,
//!    `throughput` = queries per second). The model proposes, the
//!    probe disposes — a candidate the cost model loves but that loses
//!    on the wire never wins.
//!
//! The model score is a **full-recompute bound**: delta-driven engines
//! (`incremental`, `auto`) are priced as if every round recomputed
//! everything, so their caching advantage shows up only in the live
//! probes. That is deliberate — how much caching helps depends on the
//! probe workload's churn, which stage 2 cannot know.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::npu::cost::{graph_cost_scaled, CostOpts, CostScales};
use crate::ops::build::{self, Aggregation, GnnDims};
use crate::serve::spec::TuningSpec;
use crate::serve::{DataSource, Deployment, DeploymentSpec, EngineRegistry, Serving};
use crate::server::Update;

/// What the tuner ranks live probes by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Minimize mean per-query latency (µs).
    Latency,
    /// Maximize sustained queries per second.
    Throughput,
}

impl Objective {
    /// Parse a `[tuning] objective` name (the spec layer has already
    /// validated it; this keeps the mapping in one place).
    pub fn from_name(name: &str) -> Result<Objective> {
        match name {
            "latency" => Ok(Objective::Latency),
            "throughput" => Ok(Objective::Throughput),
            other => bail!(
                "unknown tuning objective {other:?} — \
                 pick \"latency\" or \"throughput\""
            ),
        }
    }

    /// The spec-level name.
    pub fn name(self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Throughput => "throughput",
        }
    }

    /// Is observed score `a` better than `b` under this objective?
    fn better(self, a: f64, b: f64) -> bool {
        match self {
            Objective::Latency => a < b,
            Objective::Throughput => a > b,
        }
    }

    /// Unit suffix for report rendering.
    fn unit(self) -> &'static str {
        match self {
            Objective::Latency => "µs/query",
            Objective::Throughput => "qps",
        }
    }
}

/// One ranked line of the tuning report.
#[derive(Debug, Clone)]
pub struct TuningRow {
    /// Human-readable candidate summary (`plan int8 sparse ×2`).
    pub label: String,
    /// Engine factory name.
    pub engine: String,
    /// Shard count.
    pub shards: usize,
    /// Stage-2 model score: estimated worst-shard round µs.
    pub predicted_us: f64,
    /// Stage-3 observed objective, when this candidate was probed and
    /// the probe succeeded (`latency` = mean µs/query, `throughput` =
    /// qps).
    pub observed: Option<f64>,
    /// Why the probe was skipped or failed (`None` when it ran clean).
    pub note: Option<String>,
}

/// The autotuner's full ranking, winner first.
#[derive(Debug, Clone)]
pub struct TuningReport {
    /// The objective the ranking is ordered by.
    pub objective: Objective,
    /// All scored candidates: probed rows first (by observed objective),
    /// then unprobed rows by model score.
    pub rows: Vec<TuningRow>,
    /// Whether stage 2 priced candidates with fitted [`CostScales`]
    /// (false = no calibration observations; raw model used).
    pub calibrated: bool,
    /// Candidates rejected by spec/registry validation, with reasons —
    /// the prune stage's receipts.
    pub pruned: Vec<String>,
}

impl TuningReport {
    /// Fixed-width table for terminal output (`grannite tune`).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "objective: {}   cost model: {}\n",
            self.objective.name(),
            if self.calibrated { "calibrated" } else { "uncalibrated (unit scales)" },
        ));
        out.push_str(&format!(
            "{:<4} {:<26} {:>14} {:>18}\n",
            "rank", "candidate", "predicted µs", "observed"
        ));
        for (i, r) in self.rows.iter().enumerate() {
            let observed = match (r.observed, &r.note) {
                (Some(v), _) => format!("{v:.1} {}", self.objective.unit()),
                (None, Some(note)) => note.clone(),
                (None, None) => "-".to_string(),
            };
            out.push_str(&format!(
                "{:<4} {:<26} {:>14.1} {:>18}\n",
                i + 1,
                r.label,
                r.predicted_us,
                observed
            ));
        }
        for p in &self.pruned {
            out.push_str(&format!("pruned: {p}\n"));
        }
        out
    }
}

/// What [`Deployment::autotune`] returns: the winning spec plus the
/// ranking that justified it.
pub struct TunedDeployment {
    /// The winner — a complete, validated spec; launch it like any
    /// hand-written one.
    pub spec: DeploymentSpec,
    /// The full ranked report.
    pub report: TuningReport,
}

impl TunedDeployment {
    /// Launch the winning spec (sugar for [`Deployment::launch`]).
    pub fn launch(&self, data: &DataSource) -> Result<Box<dyn Serving>> {
        Deployment::launch(&self.spec, data)
    }
}

/// One enumerated spec-space point, pre-probe.
struct Candidate {
    spec: DeploymentSpec,
    label: String,
    predicted_us: f64,
}

impl Deployment {
    /// Search the spec space around `base` for the best deployment of
    /// `data` under `base.tuning.objective`. See the module docs for the
    /// three stages. `base` supplies everything the search holds fixed:
    /// the model, capacity, batching, admission, device roster, and the
    /// `[tuning]` knobs (`objective`, `probe_budget`, `top_k`).
    pub fn autotune(base: &DeploymentSpec, data: &DataSource) -> Result<TunedDeployment> {
        Deployment::autotune_with(&EngineRegistry::builtin(), base, data)
    }

    /// [`Deployment::autotune`] with a caller-extended registry.
    pub fn autotune_with(
        registry: &EngineRegistry,
        base: &DeploymentSpec,
        data: &DataSource,
    ) -> Result<TunedDeployment> {
        let objective = Objective::from_name(&base.tuning.objective)?;
        let ds = data.dataset()?;
        let budget = base.tuning.probe_budget;

        // stage 0: fit CostScales from a short telemetry-enabled probe
        // of the base spec (unit scales when nothing was observed)
        let scales = calibration_probe(registry, base, &ds, budget)
            .unwrap_or_default();
        let calibrated = !scales.is_empty();

        // stage 1: enumerate + prune
        let mut pruned = Vec::new();
        let mut candidates = Vec::new();
        for spec in enumerate(registry, base, &ds)? {
            let label = label_of(&spec);
            match spec.validate_with(registry) {
                Ok(()) => candidates.push((spec, label)),
                Err(e) => pruned.push(format!("{label}: {e:#}")),
            }
        }
        if candidates.is_empty() {
            bail!(
                "autotune pruned every candidate — first rejection: {}",
                pruned.first().map(String::as_str).unwrap_or("(none enumerated)")
            );
        }

        // stage 2: model score, cheapest ranking
        let mut scored: Vec<Candidate> = candidates
            .into_iter()
            .map(|(spec, label)| {
                let predicted_us = model_score(&spec, &ds, &scales)?;
                Ok(Candidate { spec, label, predicted_us })
            })
            .collect::<Result<_>>()?;
        scored.sort_by(|a, b| a.predicted_us.total_cmp(&b.predicted_us));

        // stage 3: confirm top-K through the real launch path
        let top_k = base.tuning.top_k.min(scored.len());
        let mut rows = Vec::with_capacity(scored.len());
        let mut winner: Option<(usize, f64)> = None;
        for (i, c) in scored.iter().enumerate() {
            let (observed, note) = if i < top_k {
                match live_probe(registry, &c.spec, &ds, budget, objective) {
                    Ok(v) => (Some(v), None),
                    Err(e) => (None, Some(format!("probe failed: {e:#}"))),
                }
            } else {
                (None, None)
            };
            if let Some(v) = observed {
                let improves = match winner {
                    None => true,
                    Some((_, best)) => objective.better(v, best),
                };
                if improves {
                    winner = Some((i, v));
                }
            }
            rows.push(TuningRow {
                label: c.label.clone(),
                engine: c.spec.engine.name.clone(),
                shards: c.spec.topology.shards,
                predicted_us: c.predicted_us,
                observed,
                note,
            });
        }
        // every probe failing still yields an answer: the model's pick
        let winner_idx = winner.map(|(i, _)| i).unwrap_or(0);

        // winner first; then probed rows by observed objective; then
        // unprobed rows by model score (already in predicted order)
        let mut order: Vec<usize> = (0..rows.len()).collect();
        order.sort_by(|&a, &b| {
            let key = |i: usize| {
                (
                    usize::from(i != winner_idx),
                    usize::from(rows[i].observed.is_none()),
                )
            };
            key(a).cmp(&key(b)).then_with(|| match (rows[a].observed, rows[b].observed) {
                (Some(x), Some(y)) => {
                    if objective.better(x, y) {
                        std::cmp::Ordering::Less
                    } else {
                        std::cmp::Ordering::Greater
                    }
                }
                _ => rows[a].predicted_us.total_cmp(&rows[b].predicted_us),
            })
        });
        let rows: Vec<TuningRow> = order.iter().map(|&i| rows[i].clone()).collect();
        let spec = scored.swap_remove(winner_idx).spec;
        Ok(TunedDeployment {
            spec,
            report: TuningReport { objective, rows, calibrated, pruned },
        })
    }
}

/// The candidate spec space around `base`: engine family × aggregation
/// lowering × quant × shard count, everything else inherited. Engine
/// options are carried over only where the target engine accepts them
/// (per [`EngineRegistry::options_for`]) so e.g. a base `tile_min`
/// doesn't disqualify the plan candidates.
fn enumerate(
    registry: &EngineRegistry,
    base: &DeploymentSpec,
    ds: &crate::graph::datasets::Dataset,
) -> Result<Vec<DeploymentSpec>> {
    // `local` answers by label voting and `coordinator` needs AOT
    // artifacts — neither is exchangeable with the synthesized-GCN
    // engines, so the search stays inside the offline-GCN family
    const ENGINES: &[(&str, &[bool])] =
        &[("plan", &[false, true]), ("incremental", &[false]), ("auto", &[false])];
    let mut shard_counts = vec![1usize, 2, 4];
    shard_counts.push(base.topology.shards);
    shard_counts.sort_unstable();
    shard_counts.dedup();
    // a shard must own at least one node
    shard_counts.retain(|&s| s >= 1 && s <= ds.num_nodes());

    let capacity = base.resolved_capacity(ds.num_nodes())?;
    let mut out = Vec::new();
    for &(engine, quants) in ENGINES {
        let accepted = registry.options_for(engine).unwrap_or(&[]);
        for &quant in quants {
            for agg in [Aggregation::Sparse, Aggregation::Dense] {
                for &shards in &shard_counts {
                    let mut spec = base.clone();
                    spec.capacity = capacity;
                    spec.engine.name = engine.to_string();
                    spec.engine
                        .options
                        .retain(|k, _| accepted.contains(&k.as_str()));
                    spec.quant = quant;
                    spec.aggregation = agg;
                    spec.topology.shards = shards;
                    out.push(spec);
                }
            }
        }
    }
    Ok(out)
}

/// `plan int8 sparse ×2`-style candidate summary.
fn label_of(spec: &DeploymentSpec) -> String {
    format!(
        "{}{} {} ×{}",
        spec.engine.name,
        if spec.quant { " int8" } else { "" },
        spec.aggregation.name(),
        spec.topology.shards,
    )
}

/// Stage-2 score: estimated worst-shard round µs. Per shard, the
/// candidate's model graph is priced on that shard's device with
/// [`graph_cost_scaled`], prorated by the shard's owned-node fraction
/// (the placement layer's compute model), plus the placement's halo
/// estimate for the link.
fn model_score(
    spec: &DeploymentSpec,
    ds: &crate::graph::datasets::Dataset,
    scales: &CostScales,
) -> Result<f64> {
    let capacity = spec.resolved_capacity(ds.num_nodes())?;
    let density = (2.0 * ds.graph.num_edges() as f64 + ds.num_nodes() as f64)
        / (capacity as f64 * capacity as f64);
    let agg = spec.aggregation.resolve(density);
    let dims = GnnDims::model(capacity, ds.graph.num_edges(), ds.num_features(),
                              ds.num_classes());
    let g = build::gcn_stagr_with(dims, "tune", agg);
    let opts = CostOpts {
        spmm_density: density,
        // QuantGr candidates run the INT8 datapath
        dense_dtype_bytes: if spec.quant { 1 } else { 0 },
        ..CostOpts::default()
    };
    let roster = spec.topology.roster()?;
    let plan = Deployment::plan(spec, ds)
        .with_context(|| format!("placement for candidate {}", label_of(spec)))?;
    let mut worst: f64 = 0.0;
    for (shard, hw) in plan.shards.iter().zip(&roster) {
        let full_round = graph_cost_scaled(&g, hw, opts, scales);
        let owned_frac = shard.nodes.len() as f64 / capacity as f64;
        worst = worst.max(full_round * owned_frac + shard.est_halo_us);
    }
    Ok(worst)
}

/// Stage-0 probe: launch the base spec with telemetry forced on, drive
/// the deterministic probe workload, and fit [`CostScales`] from the
/// observed per-op executions. Any failure (engine without a plan to
/// profile, launch error) degrades to `Err` → unit scales at the caller.
fn calibration_probe(
    registry: &EngineRegistry,
    base: &DeploymentSpec,
    ds: &crate::graph::datasets::Dataset,
    budget: usize,
) -> Result<CostScales> {
    let mut spec = base.clone();
    spec.telemetry.enabled = true;
    spec.telemetry.sample_rate = 1.0;
    let serving = Deployment::launch_at(registry, &spec, ds, None, None)?;
    let result = drive_workload(serving.as_ref(), ds, budget);
    let scales = serving
        .telemetry()
        .map(|t| t.calibration().scales())
        .unwrap_or_default();
    serving.shutdown()?;
    result?;
    Ok(scales)
}

/// Stage-3 probe: launch the candidate for real and measure the
/// objective over the deterministic workload.
fn live_probe(
    registry: &EngineRegistry,
    spec: &DeploymentSpec,
    ds: &crate::graph::datasets::Dataset,
    budget: usize,
    objective: Objective,
) -> Result<f64> {
    let serving = Deployment::launch_at(registry, spec, ds, None, None)?;
    let t0 = Instant::now();
    let result = drive_workload(serving.as_ref(), ds, budget);
    let wall = t0.elapsed();
    let shutdown = serving.shutdown();
    let lat_sum = result?;
    shutdown?;
    Ok(match objective {
        Objective::Latency => lat_sum / budget.max(1) as f64,
        Objective::Throughput => budget as f64 / wall.as_secs_f64().max(1e-9),
    })
}

/// The deterministic probe workload every stage shares: `budget`
/// queries round-robined over the nodes, one GrAd edge mutation every
/// fourth step (so delta-driven engines see churn, not a frozen graph).
/// Returns the summed query latency in µs.
fn drive_workload(
    serving: &dyn Serving,
    ds: &crate::graph::datasets::Dataset,
    budget: usize,
) -> Result<f64> {
    let n = ds.num_nodes();
    let mut lat_sum = 0.0;
    for i in 0..budget {
        if i % 4 == 3 {
            let (u, mut v) = (i % n, (i * 7 + 3) % n);
            if u == v {
                v = (v + 1) % n;
            }
            serving.update(Update::AddEdge(u, v))?;
        }
        let r = serving.query_wait(Some(i % n))?;
        lat_sum += r.latency_us;
    }
    Ok(lat_sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::synthesize;

    fn twin() -> crate::graph::datasets::Dataset {
        synthesize("tune", 40, 90, 4, 12, 11)
    }

    fn base(budget: usize) -> DeploymentSpec {
        let mut spec = DeploymentSpec::default();
        spec.capacity = 48;
        spec.tuning.probe_budget = budget;
        spec.tuning.top_k = 2;
        spec
    }

    #[test]
    fn enumerate_covers_engines_and_prunes_nothing_valid() {
        let reg = EngineRegistry::builtin();
        let ds = twin();
        let specs = enumerate(&reg, &base(8), &ds).unwrap();
        let engines: std::collections::BTreeSet<&str> =
            specs.iter().map(|s| s.engine.name.as_str()).collect();
        assert_eq!(
            engines.into_iter().collect::<Vec<_>>(),
            vec!["auto", "incremental", "plan"]
        );
        // quant only enumerated for plan
        assert!(specs.iter().all(|s| !s.quant || s.engine.name == "plan"));
        // candidate labels are unique — the report is unambiguous
        let labels: std::collections::BTreeSet<String> =
            specs.iter().map(label_of).collect();
        assert_eq!(labels.len(), specs.len());
    }

    #[test]
    fn base_engine_options_survive_only_where_accepted() {
        let reg = EngineRegistry::builtin();
        let ds = twin();
        let mut b = base(8);
        b.engine = crate::serve::spec::EngineSpec::named("incremental")
            .with_option("tile_min", crate::config::parse::Value::Int(16));
        for spec in enumerate(&reg, &b, &ds).unwrap() {
            let has = spec.engine.options.contains_key("tile_min");
            match spec.engine.name.as_str() {
                "incremental" | "auto" => assert!(has, "{}", label_of(&spec)),
                other => assert!(!has, "{other} must drop tile_min"),
            }
            spec.validate_with(&reg).unwrap();
        }
    }

    #[test]
    fn model_score_prefers_sparse_on_a_sparse_graph() {
        let ds = twin();
        let scales = CostScales::default();
        let mut sparse = base(8);
        sparse.aggregation = Aggregation::Sparse;
        let mut dense = base(8);
        dense.aggregation = Aggregation::Dense;
        let s = model_score(&sparse, &ds, &scales).unwrap();
        let d = model_score(&dense, &ds, &scales).unwrap();
        assert!(
            s < d,
            "twin density is far below the SpMM crossover: sparse {s} vs dense {d}"
        );
    }

    #[test]
    fn scales_move_the_score() {
        let ds = twin();
        let spec = base(8);
        let unit = model_score(&spec, &ds, &CostScales::default()).unwrap();
        let mut scales = CostScales::default();
        for kind in ["MatMul", "SpMM", "Add", "Mul", "Relu", "Div", "Rsqrt",
                     "ReduceSumRows", "BroadcastCol", "Transpose"] {
            scales.set(kind, 3.0);
        }
        let scaled = model_score(&spec, &ds, &scales).unwrap();
        assert!(scaled > unit * 1.5, "calibration must reprice: {scaled} vs {unit}");
    }

    #[test]
    fn autotune_returns_a_launchable_winner_with_ranked_report() {
        let ds = twin();
        let tuned = Deployment::autotune(&base(6), &DataSource::Dataset(ds.clone()))
            .unwrap();
        // the report ranks every candidate, winner first and probed
        assert!(tuned.report.rows.len() >= 4);
        assert!(tuned.report.rows[0].observed.is_some(), "winner was probed");
        assert_eq!(tuned.report.rows[0].engine, tuned.spec.engine.name);
        let rendered = tuned.report.render();
        assert!(rendered.contains("objective: latency"), "{rendered}");
        assert!(rendered.contains("rank"), "{rendered}");
        // the winner is a complete spec: it validates and launches
        tuned.spec.validate_with(&EngineRegistry::builtin()).unwrap();
        let serving = tuned.launch(&DataSource::Dataset(ds)).unwrap();
        let r = serving.query_wait(Some(0)).unwrap();
        assert!(r.prediction >= 0);
        serving.shutdown().unwrap();
    }
}
