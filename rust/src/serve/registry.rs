//! The engine registry: `EngineSpec -> Box<dyn EngineFactory>`.
//!
//! This replaced the hand-written `Fleet::spawn_*` constructor lattice
//! (removed after the PR 5 migration): every engine is a factory keyed by name,
//! [`crate::serve::Deployment::launch`] looks the name up once, and the
//! factory hands back one per-shard constructor closure per
//! [`ShardSpec`]. Adding engine #6 is a new [`EngineFactory`] impl plus
//! one `register` call — no edits to `server/`, `fleet/`, or `main.rs`
//! (property-tested with a dummy engine in `rust/tests/serve_spec.rs`).
//!
//! Factory contract: [`EngineFactory::prepare`] runs **once per launch**
//! on the launching thread — the place to compile an
//! [`crate::ops::plan::ExecPlan`] once and `Arc`-share it across shards —
//! while the returned per-shard closures run **inside** the shard threads
//! (PJRT handles are not `Send`, the same contract
//! [`crate::fleet::Fleet::spawn`] has always had).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::engine::WorkerPool;
use crate::fleet::{AutoConfig, AutoEngine, LocalEngine, PlanEngine, ShardSpec};
use crate::graph::datasets::Dataset;
use crate::incremental::{IncrementalConfig, IncrementalEngine};
use crate::ops::build::Aggregation;
use crate::serve::spec::{dense_mask_bytes, DeploymentSpec, DENSE_MASK_BUDGET_BYTES};
use crate::server::{CoordinatorEngine, InferenceEngine};
use crate::storage::{spill_path, PagedFeatures, PagedStore};

/// A shard engine behind the registry: the object-safe form every
/// factory produces (`impl InferenceEngine for Box<dyn InferenceEngine>`
/// lets [`crate::fleet::Fleet::spawn`] consume it unchanged).
pub type BoxedEngine = Box<dyn InferenceEngine>;

/// One shard's engine constructor; runs inside the shard thread.
pub type EngineInit = Box<dyn FnOnce() -> Result<BoxedEngine> + Send>;

/// Per-launch shard-constructor maker: called once per [`ShardSpec`].
pub type ShardFactory = Box<dyn FnMut(&ShardSpec) -> EngineInit>;

/// Everything a factory may need at launch time.
pub struct LaunchContext<'a> {
    /// The validated spec (capacity already resolved).
    pub spec: &'a DeploymentSpec,
    /// The resolved dataset (graph + features + labels).
    pub dataset: &'a Dataset,
    /// Resolved NodePad capacity (≥ the dataset's node count).
    pub capacity: usize,
    /// AOT artifacts directory, when launched from
    /// [`crate::serve::DataSource::Artifacts`].
    pub artifacts: Option<std::path::PathBuf>,
}

impl LaunchContext<'_> {
    /// Should a shard run a parallel in-shard worker pool? Only the
    /// single-leader topology: N shards already parallelize across
    /// threads, and N machine-sized pools would oversubscribe.
    pub fn parallel_pool(&self) -> bool {
        self.spec.topology.shards == 1
    }
}

/// Builds per-shard engines for one engine name. Implementations are
/// registered in an [`EngineRegistry`]; `validate` runs before any
/// thread spawns so misconfigurations fail fast with actionable errors.
pub trait EngineFactory: Send + Sync {
    /// Registry key (`[engine] name = "…"` selects it).
    fn name(&self) -> &str;

    /// Engine-specific spec validation (quant support, model support,
    /// option types, capacity budgets). Default: anything goes.
    fn validate(&self, _spec: &DeploymentSpec) -> Result<()> {
        Ok(())
    }

    /// The `[engine]` option keys this engine accepts. Surfaced through
    /// [`EngineRegistry::options_for`] and quoted by the unknown-option
    /// rejection, so a typo'd knob names its real spelling. Default:
    /// a closed empty set (no options).
    fn options(&self) -> &'static [&'static str] {
        &[]
    }

    /// Called once per launch; returns the per-shard constructor maker.
    fn prepare(&self, ctx: &LaunchContext) -> Result<ShardFactory>;
}

/// Name → factory table. [`EngineRegistry::builtin`] carries the five
/// in-tree engines; tests and downstream scenarios extend it with
/// [`EngineRegistry::register`].
pub struct EngineRegistry {
    factories: BTreeMap<String, Box<dyn EngineFactory>>,
}

impl EngineRegistry {
    /// An empty registry (test harnesses).
    pub fn empty() -> EngineRegistry {
        EngineRegistry { factories: BTreeMap::new() }
    }

    /// The built-in engines: `local` (label voting, artifact-free),
    /// `plan` (compiled GCN `ExecPlan`, optionally QuantGr INT8),
    /// `incremental` (delta-driven frontier recompute), `auto`
    /// (runtime-adaptive plan/incremental switcher), `coordinator`
    /// (PJRT artifacts).
    pub fn builtin() -> EngineRegistry {
        let mut reg = EngineRegistry::empty();
        reg.register(Box::new(LocalFactory));
        reg.register(Box::new(PlanFactory));
        reg.register(Box::new(IncrementalFactory));
        reg.register(Box::new(AutoFactory));
        reg.register(Box::new(CoordinatorFactory));
        reg
    }

    /// Register (or replace) a factory under its own name.
    pub fn register(&mut self, factory: Box<dyn EngineFactory>) {
        self.factories.insert(factory.name().to_string(), factory);
    }

    /// Look an engine up; the error lists every registered name.
    pub fn get(&self, name: &str) -> Result<&dyn EngineFactory> {
        self.factories.get(name).map(|f| f.as_ref()).ok_or_else(|| {
            anyhow!(
                "unknown engine {name:?} — registered engines: {}",
                self.names().join(" | ")
            )
        })
    }

    /// Registered engine names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    /// The `[engine]` option keys `name` accepts (empty slice = the
    /// engine is closed over zero options). Errors like [`Self::get`]
    /// when the engine is unknown.
    pub fn options_for(&self, name: &str) -> Result<&'static [&'static str]> {
        Ok(self.get(name)?.options())
    }
}

/// Shared guard: engines that materialize the dense `capacity²` mask
/// must fit the budget (the sparse path never allocates it). Called at
/// validate time for an explicit `dense`, and again at prepare time
/// with the graph-resolved aggregation so an `auto` that resolves dense
/// on a dense-enough graph hits the same wall.
fn check_dense_budget(engine: &str, agg: Aggregation, capacity: usize) -> Result<()> {
    if agg == Aggregation::Dense && capacity > 0 {
        let bytes = dense_mask_bytes(capacity);
        if bytes > DENSE_MASK_BUDGET_BYTES {
            bail!(
                "engine {engine:?} with dense aggregation at capacity \
                 {capacity} would materialize a {} dense mask (budget {}) — \
                 use aggregation = \"sparse\" (CSR SpMM, O(nnz) memory) or \
                 reduce capacity",
                crate::util::human_bytes(bytes),
                crate::util::human_bytes(DENSE_MASK_BUDGET_BYTES),
            );
        }
    }
    Ok(())
}

/// The aggregation a launch over `ds` at `capacity` actually runs:
/// `Auto` resolved against the same padded-mask density the plan
/// builders use.
fn resolve_aggregation(agg: Aggregation, ds: &Dataset, capacity: usize) -> Aggregation {
    let capacity = capacity.max(ds.num_nodes());
    let density = (2.0 * ds.graph.num_edges() as f64 + ds.num_nodes() as f64)
        / (capacity as f64 * capacity as f64);
    agg.resolve(density)
}

/// Offline engines synthesize GCN plans; anything else needs artifacts.
fn check_offline_model(engine: &str, spec: &DeploymentSpec) -> Result<()> {
    if spec.model != "gcn" {
        bail!(
            "engine {engine:?} synthesizes offline GCN weights — model \
             must be \"gcn\", got {:?} (serve other models through engine \
             \"coordinator\" with AOT artifacts)",
            spec.model
        );
    }
    Ok(())
}

/// Lower the `[kernels]` section for a sharded serving engine. The
/// SIMD/degree-bin knobs compile straight into the shared plan; a node
/// reordering would have to permute every shard's live GrAd bindings and
/// un-permute served outputs, which the sharded engines do not do —
/// reject it here with a pointer at the paths that *do* reorder.
fn serving_kernel_config(
    engine: &str,
    spec: &DeploymentSpec,
) -> Result<crate::ops::plan::KernelConfig> {
    let cfg = spec.kernels.kernel_config()?;
    if cfg.reorder != crate::ops::plan::ReorderMode::None {
        bail!(
            "engine {engine:?} does not support kernels.reorder = {:?} — \
             serving shards bind live GrAd-mutable graphs, which a \
             compile-time permutation cannot follow; set reorder = \
             \"none\" (the degree/rcm locality passes apply to static \
             plan runs via ops::plan::Reordering, exercised by the \
             spmm_scaling bench)",
            spec.kernels.reorder
        );
    }
    Ok(cfg)
}

fn shard_pool(parallel: bool) -> Arc<WorkerPool> {
    Arc::new(if parallel { WorkerPool::default_parallel() } else { WorkerPool::serial() })
}

/// Engines that bind the full `x_pad` feature matrix into a compiled
/// plan cannot serve from a page cache — reject `[storage] backend =
/// "paged"` at validation with a pointer at the engine that can.
fn check_memory_backend(engine: &str, spec: &DeploymentSpec) -> Result<()> {
    if spec.storage.is_paged() {
        bail!(
            "engine {engine:?} binds the full feature matrix into its \
             compiled plan and cannot serve [storage] backend = \"paged\" \
             — use engine \"incremental\" (its layer-0 gather reads \
             through the page cache), or backend = \"memory\""
        );
    }
    Ok(())
}

/// Resolve `[storage]` for a paged launch: open the named store file
/// (validating its geometry against the launched dataset), or spill the
/// dataset's features to a temp store deleted when the last shard drops
/// its handle.
fn open_or_spill_store(ctx: &LaunchContext) -> Result<Arc<PagedStore>> {
    let st = &ctx.spec.storage;
    let width = ctx.dataset.num_features();
    if st.path.is_empty() {
        if ctx.dataset.features.rows < ctx.dataset.num_nodes() {
            bail!(
                "[storage] has no path but dataset {:?} is headless ({} \
                 feature rows in RAM for {} nodes) — spilling would build \
                 an all-zero store and every query would silently serve \
                 zero features; pre-build the store (stream rows into \
                 storage::PagedStore, e.g. via \
                 graph::datasets::power_law_feature_row) and point \
                 [storage] path at it",
                ctx.dataset.name,
                ctx.dataset.features.rows,
                ctx.dataset.num_nodes()
            );
        }
        let path = spill_path(&format!("{}-features", ctx.dataset.name));
        let mut store =
            PagedStore::create_from_mat(&path, &ctx.dataset.features, ctx.capacity)?;
        store.set_delete_on_drop(true);
        Ok(Arc::new(store))
    } else {
        let store = PagedStore::open(std::path::Path::new(&st.path))?;
        if store.width() != width {
            bail!(
                "[storage] path {:?} holds {}-wide feature rows but the \
                 launched dataset has {} features — rebuild the store from \
                 this dataset (PagedStore::create_from_mat) or fix the path",
                st.path,
                store.width(),
                width
            );
        }
        if store.rows() < ctx.capacity {
            bail!(
                "[storage] path {:?} holds {} rows but the deployment's \
                 NodePad capacity is {} — rebuild the store at ≥ capacity \
                 rows (GrAd node adds write into the padding region)",
                st.path,
                store.rows(),
                ctx.capacity
            );
        }
        Ok(Arc::new(store))
    }
}

/// Engines with a closed option set reject anything else — the spec
/// layer's "a typo'd knob must not silently become a default" contract,
/// enforced uniformly across factories. A near-miss (edit distance ≤ 2,
/// the fat-finger radius) names the option it was probably meant to be.
fn check_known_options(engine: &str, spec: &DeploymentSpec, known: &[&str]) -> Result<()> {
    for key in spec.engine.options.keys() {
        if !known.contains(&key.as_str()) {
            if known.is_empty() {
                bail!("engine {engine:?} takes no [engine] options, got {key:?}");
            }
            let hint = known
                .iter()
                .map(|k| (edit_distance(key, k), *k))
                .min()
                .filter(|(d, _)| *d <= 2)
                .map(|(_, k)| format!(" — did you mean {k:?}?"))
                .unwrap_or_default();
            bail!(
                "engine {engine:?} does not take option {key:?}{hint} — known \
                 options: {}",
                known.join(", ")
            );
        }
    }
    Ok(())
}

/// Levenshtein distance (option keys are short, the O(len²) DP is fine).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, ca) in a.iter().enumerate() {
        let mut row = vec![i + 1];
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            row.push(sub.min(prev[j + 1] + 1).min(row[j] + 1));
        }
        prev = row;
    }
    prev[b.len()]
}

// ---------------------------------------------------------------------------
// local — deterministic label voting, no artifacts, no MACs
// ---------------------------------------------------------------------------

struct LocalFactory;

impl EngineFactory for LocalFactory {
    fn name(&self) -> &str {
        "local"
    }

    fn validate(&self, spec: &DeploymentSpec) -> Result<()> {
        check_offline_model("local", spec)?;
        check_memory_backend("local", spec)?;
        check_known_options("local", spec, &[])?;
        if spec.quant {
            bail!(
                "engine \"local\" is label voting (no MAC datapath) — quant \
                 = true has nothing to quantize; use engine \"plan\" for \
                 QuantGr INT8"
            );
        }
        Ok(())
    }

    fn prepare(&self, ctx: &LaunchContext) -> Result<ShardFactory> {
        Ok(local_shards(ctx.dataset, ctx.capacity))
    }
}

/// Per-shard [`LocalEngine`] constructors.
pub(crate) fn local_shards(ds: &Dataset, capacity: usize) -> ShardFactory {
    let ds = ds.clone();
    Box::new(move |spec: &ShardSpec| {
        let ds = ds.clone();
        let owned = spec.nodes.clone();
        Box::new(move || {
            Ok(Box::new(LocalEngine::shard(&ds, capacity, owned)?) as BoxedEngine)
        })
    })
}

// ---------------------------------------------------------------------------
// plan — compiled GCN ExecPlan, FP32 or QuantGr INT8
// ---------------------------------------------------------------------------

struct PlanFactory;

impl EngineFactory for PlanFactory {
    fn name(&self) -> &str {
        "plan"
    }

    fn validate(&self, spec: &DeploymentSpec) -> Result<()> {
        check_offline_model("plan", spec)?;
        check_memory_backend("plan", spec)?;
        check_known_options("plan", spec, &[])?;
        serving_kernel_config("plan", spec)?;
        check_dense_budget("plan", spec.aggregation, spec.capacity)
    }

    fn prepare(&self, ctx: &LaunchContext) -> Result<ShardFactory> {
        plan_shards(
            ctx.dataset,
            ctx.capacity,
            ctx.spec.aggregation,
            ctx.spec.quant,
            ctx.parallel_pool(),
            serving_kernel_config("plan", ctx.spec)?,
        )
    }
}

/// Per-shard [`PlanEngine`] constructors sharing **one** compiled plan +
/// weight set.
pub(crate) fn plan_shards(
    ds: &Dataset,
    capacity: usize,
    agg: Aggregation,
    quant: bool,
    parallel: bool,
    kernels: crate::ops::plan::KernelConfig,
) -> Result<ShardFactory> {
    // an Auto that resolves dense on this graph pays the same mask
    // budget an explicit dense would
    check_dense_budget("plan", resolve_aggregation(agg, ds, capacity), capacity)?;
    let (plan, weights) = if quant {
        PlanEngine::compile_quant_parts_cfg(ds, capacity, agg, kernels)?
    } else {
        PlanEngine::compile_parts_cfg(ds, capacity, agg, kernels)?
    };
    let ds = ds.clone();
    Ok(Box::new(move |spec: &ShardSpec| {
        let ds = ds.clone();
        let owned = spec.nodes.clone();
        let plan = Arc::clone(&plan);
        let weights = weights.clone();
        Box::new(move || {
            let pool = shard_pool(parallel);
            Ok(Box::new(PlanEngine::from_parts(&ds, capacity, owned, pool, plan, weights)?)
                as BoxedEngine)
        })
    }))
}

// ---------------------------------------------------------------------------
// incremental — delta-driven frontier recompute over an activation cache
// ---------------------------------------------------------------------------

struct IncrementalFactory;

impl EngineFactory for IncrementalFactory {
    fn name(&self) -> &str {
        "incremental"
    }

    fn validate(&self, spec: &DeploymentSpec) -> Result<()> {
        check_offline_model("incremental", spec)?;
        check_dense_budget("incremental", spec.aggregation, spec.capacity)?;
        if spec.quant {
            bail!(
                "engine \"incremental\" serves FP32 tiles — quant = true is \
                 unsupported; use engine \"plan\" for QuantGr INT8"
            );
        }
        // option types are validated here so a bad spec fails at
        // validate time, not inside a shard thread
        let _ = self.config(spec)?;
        Ok(())
    }

    fn options(&self) -> &'static [&'static str] {
        INCREMENTAL_OPTIONS
    }

    fn prepare(&self, ctx: &LaunchContext) -> Result<ShardFactory> {
        let cfg = self.config(ctx.spec)?;
        check_dense_budget(
            "incremental",
            resolve_aggregation(cfg.aggregation, ctx.dataset, ctx.capacity),
            ctx.capacity,
        )?;
        if ctx.spec.storage.is_paged() {
            // one store file, one Arc'd pread handle; every shard gets a
            // private page cache + prefetcher over it
            let store = open_or_spill_store(ctx)?;
            return Ok(incremental_paged_shards(
                ctx.dataset,
                ctx.capacity,
                cfg,
                ctx.parallel_pool(),
                store,
                ctx.spec.storage.page_rows,
                ctx.spec.storage.cache_pages,
            ));
        }
        Ok(incremental_shards(ctx.dataset, ctx.capacity, cfg, ctx.parallel_pool()))
    }
}

/// The frontier-recompute knobs; also accepted by `auto`, which forwards
/// them to its inner incremental engine.
const INCREMENTAL_OPTIONS: &[&str] = &["cost_margin", "tile_min"];

/// `[engine]` options + `[kernels]` section → [`IncrementalConfig`]
/// (defaults preserved); shared by the `incremental` and `auto`
/// factories.
fn incremental_config(engine: &str, spec: &DeploymentSpec) -> Result<IncrementalConfig> {
    let mut cfg = IncrementalConfig { aggregation: spec.aggregation, ..Default::default() };
    cfg.kernels = serving_kernel_config(engine, spec)?;
    if let Some(m) = spec.engine.f64_opt("cost_margin")? {
        cfg.cost_margin = m;
    }
    if let Some(t) = spec.engine.usize_opt("tile_min")? {
        cfg.tile_min = t;
    }
    check_known_options(engine, spec, INCREMENTAL_OPTIONS)?;
    Ok(cfg)
}

impl IncrementalFactory {
    fn config(&self, spec: &DeploymentSpec) -> Result<IncrementalConfig> {
        incremental_config("incremental", spec)
    }
}

/// Per-shard [`IncrementalEngine`] constructors.
pub(crate) fn incremental_shards(
    ds: &Dataset,
    capacity: usize,
    cfg: IncrementalConfig,
    parallel: bool,
) -> ShardFactory {
    let ds = ds.clone();
    Box::new(move |spec: &ShardSpec| {
        let ds = ds.clone();
        let owned = spec.nodes.clone();
        Box::new(move || {
            let pool = shard_pool(parallel);
            Ok(Box::new(IncrementalEngine::shard(&ds, capacity, owned, pool, cfg)?)
                as BoxedEngine)
        })
    })
}

/// Per-shard [`IncrementalEngine`] constructors reading features
/// through a shared [`PagedStore`]: the shards share the file handle
/// (`pread` needs no lock), not the cache — each shard's admission
/// frequencies track its own owned region.
pub(crate) fn incremental_paged_shards(
    ds: &Dataset,
    capacity: usize,
    cfg: IncrementalConfig,
    parallel: bool,
    store: Arc<PagedStore>,
    page_rows: usize,
    cache_pages: usize,
) -> ShardFactory {
    let ds = ds.clone();
    Box::new(move |spec: &ShardSpec| {
        let ds = ds.clone();
        let owned = spec.nodes.clone();
        let store = Arc::clone(&store);
        Box::new(move || {
            let pool = shard_pool(parallel);
            let features =
                Box::new(PagedFeatures::new(store, page_rows, cache_pages).with_prefetch());
            Ok(Box::new(IncrementalEngine::shard_with_source(
                &ds, capacity, owned, pool, cfg, features,
            )?) as BoxedEngine)
        })
    })
}

// ---------------------------------------------------------------------------
// auto — runtime-adaptive plan/incremental switcher
// ---------------------------------------------------------------------------

struct AutoFactory;

impl EngineFactory for AutoFactory {
    fn name(&self) -> &str {
        "auto"
    }

    fn validate(&self, spec: &DeploymentSpec) -> Result<()> {
        check_offline_model("auto", spec)?;
        check_memory_backend("auto", spec)?;
        check_dense_budget("auto", spec.aggregation, spec.capacity)?;
        if spec.quant {
            bail!(
                "engine \"auto\" switches between FP32 plan and incremental \
                 strategies — quant = true would make answers depend on \
                 which strategy is active; use engine \"plan\" for QuantGr \
                 INT8"
            );
        }
        // hysteresis/cooldown live in [tuning] and are validated by the
        // spec layer; only the inner incremental knobs are [engine] options
        let _ = incremental_config("auto", spec)?;
        Ok(())
    }

    fn options(&self) -> &'static [&'static str] {
        INCREMENTAL_OPTIONS
    }

    fn prepare(&self, ctx: &LaunchContext) -> Result<ShardFactory> {
        let inc_cfg = incremental_config("auto", ctx.spec)?;
        check_dense_budget(
            "auto",
            resolve_aggregation(ctx.spec.aggregation, ctx.dataset, ctx.capacity),
            ctx.capacity,
        )?;
        // compile the plan strategy once; every shard's inner PlanEngine
        // shares it, exactly like the plain "plan" engine — with the same
        // kernel knobs as the incremental strategy, so a runtime switch
        // never changes the dispatched microkernels
        let (plan, weights) = PlanEngine::compile_parts_cfg(
            ctx.dataset,
            ctx.capacity,
            ctx.spec.aggregation,
            inc_cfg.kernels,
        )?;
        let auto_cfg = AutoConfig::from_tuning(&ctx.spec.tuning);
        let ds = ctx.dataset.clone();
        let capacity = ctx.capacity;
        let parallel = ctx.parallel_pool();
        Ok(Box::new(move |spec: &ShardSpec| {
            let ds = ds.clone();
            let owned = spec.nodes.clone();
            let plan = Arc::clone(&plan);
            let weights = weights.clone();
            Box::new(move || {
                let pool = shard_pool(parallel);
                let plan_eng = PlanEngine::from_parts(
                    &ds,
                    capacity,
                    owned.clone(),
                    Arc::clone(&pool),
                    plan,
                    weights,
                )?;
                let inc_eng =
                    IncrementalEngine::shard(&ds, capacity, owned, pool, inc_cfg)?;
                Ok(Box::new(AutoEngine::from_engines(plan_eng, inc_eng, auto_cfg))
                    as BoxedEngine)
            })
        }))
    }
}

// ---------------------------------------------------------------------------
// coordinator — PJRT artifacts (the real-numerics path)
// ---------------------------------------------------------------------------

struct CoordinatorFactory;

impl EngineFactory for CoordinatorFactory {
    fn name(&self) -> &str {
        "coordinator"
    }

    fn validate(&self, spec: &DeploymentSpec) -> Result<()> {
        if spec.quant {
            bail!(
                "engine \"coordinator\" serves whatever artifact [engine] \
                 artifact names — for INT8, point it at a *_quant_* \
                 artifact instead of setting quant = true"
            );
        }
        check_memory_backend("coordinator", spec)?;
        check_known_options("coordinator", spec, self.options())?;
        if let Some(v) = spec.engine.options.get("artifact") {
            if v.as_str().is_none() {
                bail!("[engine] artifact must be a string, got {v:?}");
            }
        }
        Ok(())
    }

    fn options(&self) -> &'static [&'static str] {
        &["artifact"]
    }

    fn prepare(&self, ctx: &LaunchContext) -> Result<ShardFactory> {
        let dir = ctx.artifacts.clone().ok_or_else(|| {
            anyhow!(
                "engine \"coordinator\" serves AOT artifacts — launch with \
                 DataSource::Artifacts {{ dir, dataset }} (after `make \
                 artifacts`), or pick an offline engine: plan | \
                 incremental | local"
            )
        })?;
        let dataset = ctx.dataset.name.clone();
        let artifact = match ctx.spec.engine.str_opt("artifact") {
            Some(a) => a.to_string(),
            None if ctx.spec.model == "gcn" => format!("gcn_grad_{dataset}"),
            None => bail!(
                "engine \"coordinator\" with model {:?} needs an explicit \
                 [engine] artifact = \"…\" (only gcn has a default \
                 GrAd artifact)",
                ctx.spec.model
            ),
        };
        let parallel = ctx.parallel_pool();
        Ok(Box::new(move |_spec: &ShardSpec| {
            let dir = dir.clone();
            let dataset = dataset.clone();
            let artifact = artifact.clone();
            Box::new(move || {
                let pool = shard_pool(parallel);
                let coordinator =
                    crate::coordinator::Coordinator::open_with_pool(&dir, &dataset, pool)?;
                Ok(Box::new(CoordinatorEngine { coordinator, artifact }) as BoxedEngine)
            })
        }))
    }
}
