//! In-tree benchmark harness (criterion is unavailable offline) and the
//! paper-figure drivers shared by `rust/benches/*` and the CLI.

pub mod figures;

use std::time::Instant;

use crate::util::timing::Stats;

/// Measure a closure: `warmup` unrecorded runs, then `iters` samples.
pub fn run_bench<T>(name: &str, warmup: usize, iters: usize,
                    mut f: impl FnMut() -> T) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let stats = Stats::from_samples(&samples);
    println!("bench {name:40} {stats}");
    stats
}

/// Pretty banner for bench binaries.
pub fn banner(title: &str) {
    println!("\n=== {title} ===\n");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_bench_collects_iters() {
        let mut count = 0;
        let stats = run_bench("t", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(stats.n, 5);
    }

    #[test]
    #[should_panic]
    fn zero_iters_panics() {
        run_bench("t", 0, 0, || ());
    }
}
