//! Paper-figure harnesses: each function regenerates one table/figure of
//! the evaluation section (DESIGN.md §6 maps figure → function). All are
//! pure-simulator (no artifacts needed) except the accuracy table, which
//! executes the real PJRT artifacts.
//!
//! EXPERIMENTS.md records the paper-vs-measured comparison produced by
//! these exact functions (`make figures`).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::HardwareConfig;
use crate::graph::datasets::{self, DatasetSpec};
use crate::npu::{simulate, SimOptions};
use crate::ops::build::{self, GatVariant, GnnDims, QuantScales};
use crate::ops::{OpGraph, Stage};
use crate::util::table::{pct, Table};

/// Mask densities for a dataset spec (edge structure at dataset scale).
fn densities(spec: &DatasetSpec) -> BTreeMap<String, f64> {
    let n = spec.nodes as f64;
    let m = spec.edges as f64;
    let adj = (2.0 * m + n) / (n * n);
    let mut out = BTreeMap::new();
    out.insert("norm".into(), adj);
    out.insert("norm_pad".into(), (2.0 * m + n) / (spec.capacity as f64).powi(2));
    out.insert("adj".into(), adj);
    out.insert("neg_bias".into(), 1.0 - adj);
    out.insert("mask".into(), ((crate::SAGE_MAX_NEIGHBORS + 1) as f64 * n) / (n * n));
    // bag-of-words feature density (twins match Cora's ~1.3-1.5%)
    out.insert("x".into(), 0.015);
    out.insert("x_pad".into(), 0.015);
    out
}

fn fmt_us(us: f64) -> String {
    crate::util::human_us(us)
}

// ---------------------------------------------------------------------------
// Fig. 4 — preprocessing vs GNN-compute breakdown, DPU vs DSP
// ---------------------------------------------------------------------------

/// Fig. 4 workload: single GraphConv / GraphAttn layer, 1433 → 64 feats,
/// 1354 nodes / 5429 edges, out-of-the-box mapping on the Series-2 NPU.
pub fn fig4(hw: &HardwareConfig) -> Table {
    let dims = GnnDims::fig4(1354, 5429);
    let mut t = Table::new(
        "Fig. 4 — execution latency breakdown (out-of-the-box mapping)",
        &["layer", "stage/engine", "latency", "share"],
    );
    for (name, g) in [
        ("GraphConv", build::gcn_baseline(dims)),
        ("GraphAttn", build::gat(dims, GatVariant::Baseline)),
    ] {
        let r = simulate(&g, hw, &SimOptions::default());
        let split = r.by_stage_engine();
        for ((stage, engine), us) in &split {
            t.row(&[
                name.into(),
                format!("{stage}/{engine}"),
                fmt_us(*us),
                pct(us / r.total_us),
            ]);
        }
        let pre: f64 = split
            .iter()
            .filter(|((s, _), _)| s == "preprocess")
            .map(|(_, v)| v)
            .sum();
        t.row(&[
            name.into(),
            "TOTAL (preprocess share)".into(),
            fmt_us(r.total_us),
            pct(pre / r.total_us),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig. 5 — GNN-compute breakdown across operations
// ---------------------------------------------------------------------------

/// Fig. 5: op-level latency breakdown of the *compute* stage.
pub fn fig5(hw: &HardwareConfig) -> Table {
    let dims = GnnDims::fig4(1354, 5429);
    let mut t = Table::new(
        "Fig. 5 — GNN compute latency by operation (out-of-the-box)",
        &["layer", "op", "latency", "share of compute"],
    );
    for (name, g) in [
        ("GraphConv", build::gcn_baseline(dims)),
        ("GraphAttn", build::gat(dims, GatVariant::Baseline)),
    ] {
        let r = simulate(&g, hw, &SimOptions::default());
        let compute_total: f64 = r
            .records
            .iter()
            .filter(|rec| rec.stage == Stage::Compute)
            .map(|rec| rec.wall_us)
            .sum();
        let mut by_kind: BTreeMap<&'static str, f64> = BTreeMap::new();
        for rec in r.records.iter().filter(|rec| rec.stage == Stage::Compute) {
            *by_kind.entry(rec.kind).or_insert(0.0) += rec.wall_us;
        }
        let mut rows: Vec<_> = by_kind.into_iter().collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for (kind, us) in rows.iter().take(8) {
            t.row(&[
                name.into(),
                (*kind).into(),
                fmt_us(*us),
                pct(us / compute_total),
            ]);
        }
        let dsp = r.dsp_fraction(Stage::Compute);
        t.row(&[name.into(), "DSP share".into(), "-".into(), pct(dsp)]);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig. 20 — progressive optimization speedups
// ---------------------------------------------------------------------------

/// One (label, graph, options) configuration of the Fig. 20 ladder.
pub struct LadderStep {
    pub label: &'static str,
    pub graph: OpGraph,
    pub opts: SimOptions,
}

/// GraphSplit placement for a graph (preprocessing → CPU etc.), as
/// SimOptions. This is the Fig. 20 "enabled" baseline: the model already
/// runs, with per-inference CPU preprocessing + transfer overhead.
fn graphsplit_opts(g: &OpGraph, base: &SimOptions) -> SimOptions {
    use crate::coordinator::{partition, CostModel};
    let cm = CostModel::profile(
        g,
        &HardwareConfig::npu_series2(),
        &HardwareConfig::cpu(),
    );
    let p = partition(g, &cm);
    SimOptions { placement: Some(p.placement), ..base.clone() }
}

/// The Fig. 20 ladder for one dataset spec. Each step composes on the
/// previous unless the paper says otherwise (SAGE: EffOp and GrAx3
/// target the same op and are not cumulative).
pub fn fig20_ladder(spec: &DatasetSpec) -> Vec<(&'static str, Vec<LadderStep>)> {
    let d = GnnDims::model(spec.nodes, spec.edges, spec.features, spec.classes);
    let dpad = GnnDims::model(spec.capacity, spec.edges, spec.features, spec.classes);
    let dens = densities(spec);
    let base_opts = SimOptions { mask_density: dens.clone(), ..Default::default() };
    let grasp_opts = SimOptions {
        grasp: true,
        symg: true,
        cacheg: true,
        mask_density: dens.clone(),
        ..Default::default()
    };
    let quant_opts = SimOptions { dense_dtype_bytes: 1, ..grasp_opts.clone() };

    let gcn_base_graph = build::gcn_baseline(d);
    let gcn_base_opts = graphsplit_opts(&gcn_base_graph, &base_opts);
    let gcn = vec![
        LadderStep {
            // "enabled" baseline: GraphSplit keeps preprocessing on the
            // CPU *per inference* (recomputing the norm for every query)
            label: "baseline (CPU preprocess each inference)",
            graph: gcn_base_graph,
            opts: gcn_base_opts,
        },
        LadderStep {
            // StaGr: the norm mask is precomputed ONCE (static graph) —
            // preprocessing disappears from the per-inference path
            label: "+ StaGr + GraphSplit",
            graph: build::gcn_stagr(d, "stagr"),
            opts: base_opts.clone(),
        },
        LadderStep {
            label: "+ GrAd + NodePad",
            graph: build::gcn_stagr(dpad, "grad"),
            opts: base_opts.clone(),
        },
        LadderStep {
            label: "+ GraSp (+SymG+CacheG)",
            graph: build::gcn_stagr(dpad, "grad"),
            opts: pad_density(grasp_opts.clone(), spec),
        },
        LadderStep {
            label: "+ QuantGr",
            graph: build::gcn_quant(dpad, QuantScales::default()),
            opts: pad_density(quant_opts.clone(), spec),
        },
    ];

    let gat = vec![
        LadderStep {
            // enabled via the StaGr attention mask; Select/Softmax still
            // on the DSP — what EffOp then attacks
            label: "baseline (DSP Select/Softmax)",
            graph: build::gat(d, GatVariant::BaselineMasked),
            opts: base_opts.clone(),
        },
        LadderStep {
            label: "+ EffOp",
            graph: build::gat(d, GatVariant::EffOp),
            opts: base_opts.clone(),
        },
        LadderStep {
            label: "+ GrAx1 + GrAx2",
            graph: build::gat(d, GatVariant::Grax),
            opts: base_opts.clone(),
        },
    ];

    let sage = vec![
        LadderStep {
            label: "baseline (sequential DSP gather)",
            graph: build::sage_max_baseline(d),
            opts: base_opts.clone(),
        },
        LadderStep {
            label: "+ GrAx3 (mask-mul + max-pool)",
            graph: build::sage_max_grax3(d),
            opts: base_opts.clone(),
        },
    ];

    vec![("GCN", gcn), ("GAT", gat), ("SAGE-max", sage)]
}

fn pad_density(mut opts: SimOptions, spec: &DatasetSpec) -> SimOptions {
    // the padded grad graphs read `norm_pad`-shaped masks but the builder
    // names the input `norm`; register the padded density under both
    let n = spec.capacity as f64;
    let m = spec.edges as f64;
    let adj = (2.0 * m + spec.nodes as f64) / (n * n);
    opts.mask_density.insert("norm".into(), adj);
    opts
}

/// Fig. 20: progressive speedups on the Series-2 NPU.
pub fn fig20(spec: &DatasetSpec, hw: &HardwareConfig) -> Table {
    let mut t = Table::new(
        format!("Fig. 20 — progressive GraNNite speedups ({})", spec.name),
        &["model", "configuration", "latency", "speedup vs baseline"],
    );
    for (model, steps) in fig20_ladder(spec) {
        let mut baseline_us = None;
        for step in steps {
            let r = simulate(&step.graph, hw, &step.opts);
            let base = *baseline_us.get_or_insert(r.total_us);
            t.row(&[
                model.into(),
                step.label.into(),
                fmt_us(r.total_us),
                format!("{:.2}x", base / r.total_us),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Fig. 21 — Series 1 vs Series 2
// ---------------------------------------------------------------------------

/// Fig. 21: GCN performance across the two NPU generations.
pub fn fig21() -> Table {
    let mut t = Table::new(
        "Fig. 21 — GCN throughput: Series 1 vs Series 2 NPU",
        &["dataset", "configuration", "series1", "series2", "S2/S1"],
    );
    let s1 = HardwareConfig::npu_series1();
    let s2 = HardwareConfig::npu_series2();
    for spec in [datasets::CORA, datasets::CITESEER] {
        for (model, steps) in fig20_ladder(&spec) {
            if model != "GCN" {
                continue;
            }
            for step in steps {
                let r1 = simulate(&step.graph, &s1, &step.opts);
                let r2 = simulate(&step.graph, &s2, &step.opts);
                t.row(&[
                    spec.name.into(),
                    step.label.into(),
                    format!("{:.1} inf/s", r1.throughput()),
                    format!("{:.1} inf/s", r2.throughput()),
                    format!("{:.2}x", r2.throughput() / r1.throughput()),
                ]);
            }
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Fig. 22 / Fig. 23 — device comparison (latency, energy)
// ---------------------------------------------------------------------------

/// Per-model device configurations: the NPU runs the best GraNNite
/// mapping; the CPU/GPU rows run *their* best mappings too (INT8 VNNI on
/// the CPU, FP16 on the GPU, gathered SAGE aggregation on both — the
/// fair comparison the paper makes via OpenVINO device plugins).
fn device_configs(spec: &DatasetSpec)
    -> Vec<(&'static str, OpGraph, SimOptions, OpGraph, OpGraph)> {
    let d = GnnDims::model(spec.nodes, spec.edges, spec.features, spec.classes);
    let dens = densities(spec);
    let npu_opts = SimOptions {
        grasp: true,
        symg: true,
        cacheg: true,
        mask_density: dens,
        ..Default::default()
    };
    vec![
        (
            "GCN (GraphConv)",
            build::gcn_stagr(d, "stagr"),
            npu_opts.clone(),
            build::gcn_stagr(d, "stagr"), // CPU (oneDNN bf16-class)
            build::gcn_stagr(d, "stagr"), // GPU (FP16)
        ),
        (
            "GAT (GraphAttn)",
            build::gat(d, GatVariant::Grax),
            npu_opts.clone(),
            build::gat(d, GatVariant::Grax),
            build::gat(d, GatVariant::Grax),
        ),
        (
            "GraphSAGE (mean)",
            build::sage_mean(d),
            npu_opts.clone(),
            build::sage_mean(d),
            build::sage_mean(d),
        ),
    ]
}

fn host_run(g: &OpGraph, hw: &HardwareConfig, dtype_bytes: usize) -> crate::npu::SimReport {
    let opts = SimOptions { dense_dtype_bytes: dtype_bytes, ..Default::default() };
    simulate(g, hw, &opts)
}

/// Fig. 22: throughput of CPU / GPU / NPU per GNN layer type.
pub fn fig22(spec: &DatasetSpec) -> Table {
    let mut t = Table::new(
        format!("Fig. 22 — device throughput comparison ({})", spec.name),
        &["model", "device", "latency", "speedup vs CPU"],
    );
    for (model, npu_graph, npu_opts, cpu_graph, gpu_graph) in device_configs(spec) {
        let npu = simulate(&npu_graph, &HardwareConfig::npu_series2(), &npu_opts);
        let cpu = host_run(&cpu_graph, &HardwareConfig::cpu(), 2);
        let gpu = host_run(&gpu_graph, &HardwareConfig::gpu(), 2);
        for (dev, r) in [("CPU", &cpu), ("GPU", &gpu), ("NPU", &npu)] {
            t.row(&[
                model.into(),
                dev.into(),
                fmt_us(r.total_us),
                format!("{:.2}x", cpu.total_us / r.total_us),
            ]);
        }
    }
    t
}

/// Fig. 23: normalized energy per inference.
pub fn fig23() -> Table {
    let mut t = Table::new(
        "Fig. 23 — normalized GCN energy per inference",
        &["dataset", "device", "energy (mJ)", "vs NPU"],
    );
    for spec in [datasets::CORA, datasets::CITESEER] {
        let configs = device_configs(&spec);
        let (_, npu_graph, npu_opts, cpu_graph, gpu_graph) = &configs[0]; // GCN
        let npu = simulate(npu_graph, &HardwareConfig::npu_series2(), npu_opts);
        let cpu = host_run(cpu_graph, &HardwareConfig::cpu(), 2);
        let gpu = host_run(gpu_graph, &HardwareConfig::gpu(), 2);
        for (dev, r) in [("CPU", &cpu), ("GPU", &gpu), ("NPU", &npu)] {
            t.row(&[
                spec.name.into(),
                dev.into(),
                format!("{:.3}", r.energy_mj()),
                format!("{:.2}x", r.energy_pj / npu.energy_pj),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// GraphSplit ablation (DESIGN.md calls this out as a design-choice bench)
// ---------------------------------------------------------------------------

/// Compare all-NPU vs GraphSplit vs all-CPU estimated latency.
pub fn graphsplit_ablation(spec: &DatasetSpec) -> Table {
    use crate::coordinator::{partition, CostModel};
    use crate::npu::Placement;

    let d = GnnDims::model(spec.nodes, spec.edges, spec.features, spec.classes);
    let hw = HardwareConfig::npu_series2();
    let host = HardwareConfig::cpu();
    let mut t = Table::new(
        format!("GraphSplit ablation ({})", spec.name),
        &["model", "placement", "est. latency", "crossings"],
    );
    for (name, g) in [
        ("gcn_baseline", build::gcn_baseline(d)),
        ("gat_baseline", build::gat(d, GatVariant::Baseline)),
    ] {
        let cm = CostModel::profile(&g, &hw, &host);
        let all_accel = crate::coordinator::graphsplit::all_accel(&g);
        let (accel_us, _) = crate::coordinator::graphsplit::estimate(&g, &cm, &all_accel);
        let all_host: Vec<Placement> = vec![Placement::Host; g.len()];
        let (host_us, _) = crate::coordinator::graphsplit::estimate(&g, &cm, &all_host);
        let p = partition(&g, &cm);
        t.row(&[name.into(), "all-NPU".into(), fmt_us(accel_us), "0".into()]);
        t.row(&[name.into(), "all-CPU".into(), fmt_us(host_us), "0".into()]);
        t.row(&[
            name.into(),
            "GraphSplit".into(),
            fmt_us(p.est_us),
            p.crossings.to_string(),
        ]);
    }
    t
}

/// Run everything that doesn't need artifacts; returns all tables.
pub fn all_simulated() -> Result<Vec<Table>> {
    let hw = HardwareConfig::npu_series2();
    Ok(vec![
        fig4(&hw),
        fig5(&hw),
        fig20(&datasets::CORA, &hw),
        fig20(&datasets::CITESEER, &hw),
        fig21(),
        fig22(&datasets::CORA),
        fig22(&datasets::CITESEER),
        fig23(),
        graphsplit_ablation(&datasets::CORA),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_has_total_rows() {
        let t = fig4(&HardwareConfig::npu_series2());
        let md = t.markdown();
        assert!(md.contains("GraphConv"));
        assert!(md.contains("GraphAttn"));
        assert!(md.contains("TOTAL"));
    }

    #[test]
    fn fig20_shows_monotone_gcn_gains_at_quant() {
        let t = fig20(&datasets::CORA, &HardwareConfig::npu_series2());
        let md = t.markdown();
        assert!(md.contains("QuantGr"));
        assert!(md.contains("baseline"));
    }

    #[test]
    fn fig21_covers_both_datasets() {
        let md = fig21().markdown();
        assert!(md.contains("cora") && md.contains("citeseer"));
    }

    #[test]
    fn all_simulated_produces_nine_tables() {
        let tables = all_simulated().unwrap();
        assert_eq!(tables.len(), 9);
        for t in &tables {
            assert!(!t.is_empty());
        }
    }
}
