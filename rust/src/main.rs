//! `grannite` — the leader binary: figure harnesses, accuracy evaluation,
//! GraphSplit inspection, and the dynamic-graph server.
//!
//! ```text
//! grannite fig4|fig5|fig20|fig21|fig22|fig23   # paper figures (simulator)
//! grannite accuracy  [--dataset cora]          # PJRT accuracy table
//! grannite infer     [--artifact NAME]         # one real inference
//! grannite split     [--model gcn --variant baseline]  # GraphSplit report
//! grannite serve     [--events N --query-ratio Q]      # dynamic KG demo
//! grannite artifacts                           # list loaded artifacts
//! ```

use anyhow::{bail, Context, Result};
use grannite::bench::figures;
use grannite::cli::Args;
use grannite::config::HardwareConfig;
use grannite::coordinator::Coordinator;
use grannite::graph::datasets;
use grannite::util::Table;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let hw = HardwareConfig::preset(&args.str_opt("hw", "series2"))?;
    let artifacts = std::path::PathBuf::from(args.str_opt("artifacts", "artifacts"));
    let dataset = args.str_opt("dataset", "cora");

    match args.subcommand.as_deref() {
        Some("fig4") => figures::fig4(&hw).print(),
        Some("fig5") => figures::fig5(&hw).print(),
        Some("fig20") => {
            let spec = datasets::spec(&dataset)?;
            figures::fig20(&spec, &hw).print();
        }
        Some("fig21") => figures::fig21().print(),
        Some("fig22") => {
            figures::fig22(&datasets::spec(&dataset)?).print();
        }
        Some("fig23") => figures::fig23().print(),
        Some("ablation") => {
            figures::graphsplit_ablation(&datasets::spec(&dataset)?).print();
        }
        Some("figures") => {
            for t in figures::all_simulated()? {
                t.print();
            }
        }
        Some("artifacts") => {
            let rt = grannite::runtime::Runtime::open(&artifacts)?;
            let mut t = Table::new("AOT artifacts", &["name", "inputs"]);
            for name in rt.artifact_names() {
                let info = rt.artifact(name)?;
                t.row(&[name.to_string(), info.inputs.join(",")]);
            }
            t.print();
        }
        Some("infer") => {
            let mut c = Coordinator::open(&artifacts, &dataset)?;
            let artifact = args.str_opt("artifact", &format!("gcn_stagr_{dataset}"));
            let (logits, us) = grannite::util::timing::time_once(|| c.infer(&artifact));
            let logits = logits?;
            let mask = c.state.dataset.test_mask.clone();
            let acc = c.state.dataset.accuracy(&logits, &mask);
            println!(
                "{artifact}: {}x{} logits in {} — test acc {:.3}",
                logits.rows,
                logits.cols,
                grannite::util::human_us(us),
                acc
            );
        }
        Some("accuracy") => {
            let mut c = Coordinator::open(&artifacts, &dataset)?;
            accuracy_table(&mut c, &dataset)?.print();
        }
        Some("split") => {
            let model = args.str_opt("model", "gcn");
            let variant = args.str_opt("variant", "baseline");
            let c = Coordinator::open(&artifacts, &dataset)?;
            let (g, p) = c.graphsplit(&model, &variant, &hw)?;
            let mut t = Table::new(
                format!("GraphSplit — {model}/{variant} on {dataset}"),
                &["op", "stage", "placement"],
            );
            for (id, op) in g.ops.iter().enumerate() {
                if op.kind == grannite::ops::OpKind::Input {
                    continue;
                }
                t.row(&[
                    format!("#{id} {}", op.kind.name()),
                    op.stage.to_string(),
                    format!("{:?}", p.placement[id]),
                ]);
            }
            t.print();
            println!(
                "estimated latency {} with {} boundary crossings",
                grannite::util::human_us(p.est_us),
                p.crossings
            );
        }
        Some("serve") => {
            let events = args.usize_opt("events", 2000)?;
            let query_ratio = args.f64_opt("query-ratio", 0.3)?;
            let engine = args.str_opt("engine", "coordinator");
            let agg = grannite::ops::build::Aggregation::parse(
                &args.str_opt("aggregation", "auto"),
            )?;
            serve_demo(&artifacts, &dataset, events, query_ratio, &engine, agg)?;
        }
        Some("fleet") => {
            let shards = args.usize_opt("shards", 4)?;
            let nodes = args.usize_opt("nodes", 512)?;
            let edges = args.usize_opt("edges", 2048)?;
            let events = args.usize_opt("events", 4000)?;
            let query_ratio = args.f64_opt("query-ratio", 0.4)?;
            let devices = args.str_list_opt("devices", "series2,series1,gpu,cpu");
            let engine = args.str_opt("engine", "local");
            let agg = grannite::ops::build::Aggregation::parse(
                &args.str_opt("aggregation", "auto"),
            )?;
            fleet_demo(shards, nodes, edges, events, query_ratio, &devices, &engine, agg)?;
        }
        Some(other) => bail!("unknown subcommand {other:?} — run without args for help"),
        None => println!("{}", HELP.trim()),
    }
    Ok(())
}

const HELP: &str = r#"
grannite — GNN execution on resource-constrained NPUs (paper reproduction)

subcommands:
  fig4 | fig5 | fig20 | fig21 | fig22 | fig23   regenerate a paper figure
  figures                                        all of the above
  ablation           GraphSplit placement ablation
  artifacts          list AOT artifacts
  infer              run one planned-engine inference (--artifact NAME)
  accuracy           accuracy table over all artifacts (--dataset cora)
  split              GraphSplit placement report (--model, --variant)
  serve              dynamic knowledge-graph serving demo
                     (--engine coordinator|plan|incremental; plan and
                      incremental run offline, no artifacts needed;
                      --aggregation dense|sparse|auto)
  fleet              sharded multi-device serving demo (offline, no artifacts)
                     (--shards N --devices series2,cpu,… --nodes --edges
                      --events --query-ratio --engine local|plan|incremental
                      --aggregation dense|sparse|auto)

common options: --dataset cora|citeseer  --hw series1|series2|cpu|gpu
                --artifacts DIR
"#;

/// The per-artifact accuracy table (the paper's quality-loss claims).
fn accuracy_table(c: &mut Coordinator, dataset: &str) -> Result<Table> {
    let mut t = Table::new(
        format!("Accuracy on the {dataset} twin (PJRT execution)"),
        &["artifact", "test acc", "Δ vs reference variant"],
    );
    let groups: &[&[&str]] = &[
        &["gcn_stagr", "gcn_grad", "gcn_baseline", "gcn_quant"],
        &["gat_baseline", "gat_effop", "gat_grax"],
        &["sage_mean"],
        &["sage_max_baseline", "sage_max_grax3"],
    ];
    for artifacts in groups {
        let mut reference: Option<f64> = None;
        for base in *artifacts {
            let name = format!("{base}_{dataset}");
            if c.runtime.artifact(&name).is_err() {
                continue;
            }
            let acc = c
                .evaluate(&name)
                .with_context(|| format!("evaluating {name}"))?;
            let delta = match reference {
                None => {
                    reference = Some(acc);
                    "(reference)".to_string()
                }
                Some(r) => format!("{:+.3}", acc - r),
            };
            t.row(&[name, format!("{acc:.3}"), delta]);
        }
    }
    Ok(t)
}

/// Dynamic KG serving demo. `--engine coordinator` serves the real PJRT
/// artifacts; `--engine plan` and `--engine incremental` run fully
/// offline at the dataset's published scale (synthesized twin +
/// deterministic weights), the latter through the delta-driven
/// [`grannite::incremental::IncrementalEngine`]. `--aggregation`
/// (dense|sparse|auto) picks the offline engines' aggregation lowering.
fn serve_demo(artifacts: &std::path::Path, dataset: &str, events: usize,
              query_ratio: f64, engine: &str,
              agg: grannite::ops::build::Aggregation) -> Result<()> {
    use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
    use grannite::server::{CoordinatorEngine, ServerConfig, ServerHandle, Update};

    let spec = datasets::spec(dataset)?;
    let server = match engine {
        "coordinator" => {
            let artifact = format!("gcn_grad_{dataset}");
            let ds_name = dataset.to_string();
            let artifacts = artifacts.to_path_buf();
            ServerHandle::spawn(
                move || {
                    let coordinator = Coordinator::open(&artifacts, &ds_name)?;
                    Ok(CoordinatorEngine { coordinator, artifact })
                },
                ServerConfig::default(),
            )
        }
        "plan" => {
            let ds = datasets::synthesize(
                "serve", spec.nodes, spec.edges, spec.classes, spec.features, 42,
            );
            let capacity = spec.capacity;
            ServerHandle::spawn(
                move || {
                    let pool =
                        std::sync::Arc::new(grannite::engine::WorkerPool::serial());
                    grannite::fleet::PlanEngine::full_with(&ds, capacity, pool, agg)
                },
                ServerConfig::default(),
            )
        }
        "incremental" => {
            let ds = datasets::synthesize(
                "serve", spec.nodes, spec.edges, spec.classes, spec.features, 42,
            );
            let capacity = spec.capacity;
            ServerHandle::spawn(
                move || {
                    let pool =
                        std::sync::Arc::new(grannite::engine::WorkerPool::serial());
                    grannite::incremental::IncrementalEngine::full(
                        &ds,
                        capacity,
                        pool,
                        grannite::incremental::IncrementalConfig {
                            aggregation: agg,
                            ..Default::default()
                        },
                    )
                },
                ServerConfig::default(),
            )
        }
        other => bail!("--engine must be coordinator|plan|incremental, got {other:?}"),
    };
    println!("engine: {engine} (aggregation: {})", agg.name());

    let stream = KnowledgeGraphStream::new(spec.nodes, spec.capacity, query_ratio, 42);
    let mut responses = Vec::new();
    for ev in stream.take(events) {
        match ev {
            GraphEvent::AddEdge(u, v) => server.update(Update::AddEdge(u, v))?,
            GraphEvent::RemoveEdge(u, v) => server.update(Update::RemoveEdge(u, v))?,
            GraphEvent::AddNode => server.update(Update::AddNode)?,
            GraphEvent::Query => responses.push(server.query(None)?),
        }
    }
    let mut ok = 0;
    for rx in responses {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let snap = server.metrics.snapshot();
    println!("served {ok} queries over {events} events");
    println!(
        "latency: {}",
        snap.latency
            .as_ref()
            .map(|s| s.to_string())
            .unwrap_or_else(|| "n/a".into())
    );
    println!(
        "mask updates: {}  mean batch: {:.1}  throughput: {:.1} q/s",
        snap.mask_updates, snap.mean_batch, snap.throughput_qps
    );
    if snap.dma_bytes_dense > 0 {
        println!(
            "mask DMA: shipped {} of {} dense-equivalent ({} saved)",
            grannite::util::human_bytes(snap.dma_bytes_shipped),
            grannite::util::human_bytes(snap.dma_bytes_dense),
            grannite::util::human_bytes(snap.dma_bytes_saved()),
        );
    }
    if snap.eligible_rows > 0 {
        let fr = snap
            .frontier
            .as_ref()
            .map(|f| format!("{:.1}/{:.0}", f.mean, f.max))
            .unwrap_or_else(|| "n/a".into());
        println!(
            "incremental: recompute ratio {:.3}  cache hit rate {:.3}  \
             frontier mean/max {fr}",
            snap.recompute_ratio(),
            snap.cache_hit_rate()
        );
    }
    server.shutdown()?;
    Ok(())
}

/// Sharded serving demo over a synthetic knowledge graph — fully
/// offline. `--engine local` uses the label-voting
/// [`grannite::fleet::LocalEngine`]; `--engine plan` serves a real GCN
/// [`grannite::ops::plan::ExecPlan`] per shard (the planned executor).
/// `--aggregation dense|sparse|auto` overrides the SpMM-vs-dense
/// crossover for the plan/incremental engines (bench reproducibility).
#[allow(clippy::too_many_arguments)]
fn fleet_demo(shards: usize, nodes: usize, edges: usize, events: usize,
              query_ratio: f64, device_names: &[String], engine: &str,
              agg: grannite::ops::build::Aggregation) -> Result<()> {
    use grannite::fleet::{Fleet, FleetConfig};
    use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
    use grannite::server::Update;

    if device_names.is_empty() {
        bail!("--devices needs at least one preset name (series2|series1|gpu|cpu)");
    }
    let roster: Vec<String> = (0..shards.max(1))
        .map(|i| device_names[i % device_names.len()].clone())
        .collect();
    let mut cfg = FleetConfig::from_names(&roster)?;
    cfg.aggregation = agg;
    let capacity = nodes + nodes / 8;
    let ds = grannite::graph::datasets::synthesize("fleet", nodes, edges, 6, 64, 42);
    let fleet = match engine {
        "local" => Fleet::spawn_local(&ds, capacity, &cfg)?,
        "plan" => Fleet::spawn_planned(&ds, capacity, &cfg)?,
        "incremental" => Fleet::spawn_incremental(
            &ds,
            capacity,
            &cfg,
            grannite::incremental::IncrementalConfig {
                aggregation: agg,
                ..Default::default()
            },
        )?,
        other => bail!("--engine must be local|plan|incremental, got {other:?}"),
    };
    println!("engine: {engine} (aggregation: {})", agg.name());

    let mut t = Table::new(
        format!("fleet placement — {shards} shards over {nodes} nodes"),
        &["shard", "device", "owned", "rate µs/node", "halo in/out", "est round"],
    );
    for s in &fleet.plan.shards {
        t.row(&[
            format!("#{}", s.id),
            s.device.name.clone(),
            s.num_owned().to_string(),
            format!("{:.3}", s.per_node_us),
            format!("{}/{}", s.halo_in, s.halo_out),
            grannite::util::human_us(s.est_compute_us + s.est_halo_us),
        ]);
    }
    t.print();
    println!(
        "cut edges: {}  halo {}/round  est round {}",
        fleet.plan.cut_edges,
        grannite::util::human_bytes(fleet.plan.halo_bytes_per_round),
        grannite::util::human_us(fleet.plan.est_round_us)
    );

    let stream = KnowledgeGraphStream::new(nodes, capacity, query_ratio, 7);
    let mut rng = grannite::util::Rng::new(3);
    let mut pending = Vec::new();
    for ev in stream.take(events) {
        match ev {
            GraphEvent::AddEdge(u, v) => fleet.update(Update::AddEdge(u, v))?,
            GraphEvent::RemoveEdge(u, v) => fleet.update(Update::RemoveEdge(u, v))?,
            GraphEvent::AddNode => fleet.update(Update::AddNode)?,
            GraphEvent::Query => pending.push(fleet.query(Some(rng.usize(nodes)))?),
        }
    }
    let mut ok = 0;
    for rx in pending {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }

    let mut pt = Table::new(
        "per-shard serving metrics",
        &["shard", "queries", "rejected", "p50", "p99", "halo bytes",
          "recompute", "cache hit"],
    );
    for snap in fleet.shard_metrics() {
        let (p50, p99) = snap
            .latency
            .as_ref()
            .map(|l| (grannite::util::human_us(l.p50), grannite::util::human_us(l.p99)))
            .unwrap_or_else(|| ("n/a".into(), "n/a".into()));
        let (recomp, hit) = if snap.eligible_rows > 0 {
            (
                format!("{:.3}", snap.recompute_ratio()),
                format!("{:.3}", snap.cache_hit_rate()),
            )
        } else {
            ("n/a".into(), "n/a".into())
        };
        pt.row(&[
            snap.shard.map(|s| format!("#{s}")).unwrap_or_default(),
            snap.queries.to_string(),
            snap.rejected.to_string(),
            p50,
            p99,
            grannite::util::human_bytes(snap.halo_bytes),
            recomp,
            hit,
        ]);
    }
    pt.print();

    let (expected, applied) = (fleet.expected_versions(), fleet.applied_versions());
    let totals = fleet.metrics();
    println!("answered {ok} queries over {events} events");
    println!(
        "aggregate: {:.1} q/s  mean batch {:.1}  halo {} over {} rounds",
        totals.throughput_qps,
        totals.mean_batch,
        grannite::util::human_bytes(totals.halo_bytes),
        totals.halo_rounds
    );
    if totals.dma_bytes_dense > 0 {
        println!(
            "mask DMA: shipped {} of {} dense-equivalent ({} saved via CSR/ZVC/SymG)",
            grannite::util::human_bytes(totals.dma_bytes_shipped),
            grannite::util::human_bytes(totals.dma_bytes_dense),
            grannite::util::human_bytes(totals.dma_bytes_saved()),
        );
    }
    if totals.eligible_rows > 0 {
        println!(
            "incremental: recompute ratio {:.3}  cache hit rate {:.3}",
            totals.recompute_ratio(),
            totals.cache_hit_rate()
        );
    }
    println!("version vector: sequenced {expected:?} applied {applied:?}");
    fleet.shutdown()?;
    Ok(())
}
