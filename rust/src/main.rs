//! `grannite` — the leader binary: figure harnesses, accuracy evaluation,
//! GraphSplit inspection, and the dynamic-graph server.
//!
//! ```text
//! grannite fig4|fig5|fig20|fig21|fig22|fig23   # paper figures (simulator)
//! grannite accuracy  [--dataset cora]          # PJRT accuracy table
//! grannite infer     [--artifact NAME]         # one real inference
//! grannite split     [--model gcn --variant baseline]  # GraphSplit report
//! grannite serve     [--spec file.toml …]      # dynamic KG serving demo
//! grannite fleet     [--spec file.toml …]      # sharded serving demo
//! grannite trace     [--spec file.toml …]      # telemetry: traces + calibration
//! grannite tune      [--spec file.toml …]      # spec-space autotuner report
//! grannite top       [--spec file.toml …]      # live monitor dashboard
//! grannite monitor   [--spec file.toml …]      # serve + scrape endpoint
//! grannite artifacts                           # list loaded artifacts
//! ```
//!
//! Both serving subcommands build one [`grannite::serve::DeploymentSpec`]
//! (from `--spec file.toml` plus flag overrides) and launch it through
//! [`grannite::serve::Deployment::launch`] — the CLI owns no engine or
//! topology construction of its own.

use anyhow::{bail, Context, Result};
use grannite::bench::figures;
use grannite::cli::Args;
use grannite::config::HardwareConfig;
use grannite::coordinator::Coordinator;
use grannite::graph::datasets;
use grannite::serve::DeploymentSpec;
use grannite::util::Table;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let hw = HardwareConfig::preset(&args.str_opt("hw", "series2"))?;
    let artifacts = std::path::PathBuf::from(args.str_opt("artifacts", "artifacts"));
    let dataset = args.str_opt("dataset", "cora");

    match args.subcommand.as_deref() {
        Some("fig4") => figures::fig4(&hw).print(),
        Some("fig5") => figures::fig5(&hw).print(),
        Some("fig20") => {
            let spec = datasets::spec(&dataset)?;
            figures::fig20(&spec, &hw).print();
        }
        Some("fig21") => figures::fig21().print(),
        Some("fig22") => {
            figures::fig22(&datasets::spec(&dataset)?).print();
        }
        Some("fig23") => figures::fig23().print(),
        Some("ablation") => {
            figures::graphsplit_ablation(&datasets::spec(&dataset)?).print();
        }
        Some("figures") => {
            for t in figures::all_simulated()? {
                t.print();
            }
        }
        Some("artifacts") => {
            let rt = grannite::runtime::Runtime::open(&artifacts)?;
            let mut t = Table::new("AOT artifacts", &["name", "inputs"]);
            for name in rt.artifact_names() {
                let info = rt.artifact(name)?;
                t.row(&[name.to_string(), info.inputs.join(",")]);
            }
            t.print();
        }
        Some("infer") => {
            let mut c = Coordinator::open(&artifacts, &dataset)?;
            let artifact = args.str_opt("artifact", &format!("gcn_stagr_{dataset}"));
            let (logits, us) = grannite::util::timing::time_once(|| c.infer(&artifact));
            let logits = logits?;
            let mask = c.state.dataset.test_mask.clone();
            let acc = c.state.dataset.accuracy(&logits, &mask);
            println!(
                "{artifact}: {}x{} logits in {} — test acc {:.3}",
                logits.rows,
                logits.cols,
                grannite::util::human_us(us),
                acc
            );
        }
        Some("accuracy") => {
            let mut c = Coordinator::open(&artifacts, &dataset)?;
            accuracy_table(&mut c, &dataset)?.print();
        }
        Some("split") => {
            let model = args.str_opt("model", "gcn");
            let variant = args.str_opt("variant", "baseline");
            let c = Coordinator::open(&artifacts, &dataset)?;
            let (g, p) = c.graphsplit(&model, &variant, &hw)?;
            let mut t = Table::new(
                format!("GraphSplit — {model}/{variant} on {dataset}"),
                &["op", "stage", "placement"],
            );
            for (id, op) in g.ops.iter().enumerate() {
                if op.kind == grannite::ops::OpKind::Input {
                    continue;
                }
                t.row(&[
                    format!("#{id} {}", op.kind.name()),
                    op.stage.to_string(),
                    format!("{:?}", p.placement[id]),
                ]);
            }
            t.print();
            println!(
                "estimated latency {} with {} boundary crossings",
                grannite::util::human_us(p.est_us),
                p.crossings
            );
        }
        Some("serve") => {
            // single-leader default over the published dataset twin; the
            // coordinator engine serves real artifacts, everything else
            // runs offline
            let mut spec = deployment_spec(&args, 1, "coordinator")?;
            let events = args.usize_opt("events", 2000)?;
            let query_ratio = args.f64_opt("query-ratio", 0.3)?;
            let dspec = datasets::spec(&dataset)?;
            if spec.capacity == 0 {
                spec.capacity = dspec.capacity;
            }
            let data = if spec.engine.name == "coordinator" {
                grannite::serve::DataSource::Artifacts {
                    dir: artifacts.clone(),
                    dataset: dataset.clone(),
                }
            } else {
                grannite::serve::DataSource::Dataset(datasets::synthesize(
                    "serve", dspec.nodes, dspec.edges, dspec.classes,
                    dspec.features, 42,
                ))
            };
            serving_demo(&spec, &data, events, query_ratio)?;
        }
        Some("fleet") => {
            // sharded default over a synthetic knowledge graph (offline)
            let spec = deployment_spec(&args, 4, "local")?;
            let nodes = args.usize_opt("nodes", 512)?;
            let edges = args.usize_opt("edges", 2048)?;
            let events = args.usize_opt("events", 4000)?;
            let query_ratio = args.f64_opt("query-ratio", 0.4)?;
            // capacity = 0 derives nodes + 12.5% NodePad slack inside the
            // spec layer — no CLI-side duplicate of that formula
            let ds = datasets::synthesize("fleet", nodes, edges, 6, 64, 42);
            serving_demo(&spec, &grannite::serve::DataSource::Dataset(ds), events,
                         query_ratio)?;
        }
        Some("trace") => {
            // end-to-end telemetry demo: force-enable tracing on the
            // spec, drive a churn+query workload, then print the slowest
            // stitched traces, the cost-model calibration table, and
            // validated exporter output
            let mut spec = deployment_spec(&args, 4, "incremental")?;
            spec.telemetry.enabled = true;
            let nodes = args.usize_opt("nodes", 256)?;
            let edges = args.usize_opt("edges", 1024)?;
            let events = args.usize_opt("events", 800)?;
            let query_ratio = args.f64_opt("query-ratio", 0.4)?;
            let top = args.usize_opt("top", 3)?;
            let raw = args.has("raw");
            let ds = datasets::synthesize("trace", nodes, edges, 6, 64, 42);
            trace_demo(&spec, &ds, events, query_ratio, top, raw)?;
        }
        Some("tune") => {
            // spec-space autotuner: the spec is the *base point* of the
            // search (capacity, roster, batching, [tuning] knobs); the
            // tuner varies engine × aggregation × quant × shards around it
            let spec = deployment_spec(&args, 1, "plan")?;
            let nodes = args.usize_opt("nodes", 256)?;
            let edges = args.usize_opt("edges", 1024)?;
            let ds = datasets::synthesize("tune", nodes, edges, 6, 64, 42);
            tune_demo(&spec, &ds)?;
        }
        Some("top") => {
            // live operational dashboard over the monitor's history rings:
            // drive a workload burst per tick and render windowed rates
            let mut spec = deployment_spec(&args, 4, "local")?;
            spec.monitor.enabled = true;
            let ticks = args.usize_opt("ticks", 12)?;
            let nodes = args.usize_opt("nodes", 256)?;
            let edges = args.usize_opt("edges", 1024)?;
            let query_ratio = args.f64_opt("query-ratio", 0.5)?;
            let ds = datasets::synthesize("top", nodes, edges, 6, 64, 42);
            top_demo(&spec, &ds, ticks, query_ratio)?;
        }
        Some("monitor") => {
            // serve with the scrape endpoint up for --duration-ms, then
            // self-scrape and validate the endpoint's own output
            let mut spec = deployment_spec(&args, 4, "local")?;
            spec.monitor.enabled = true;
            if let Some(a) = args.options.get("addr") {
                spec.monitor.addr = a.clone();
            }
            if spec.monitor.addr.is_empty() {
                spec.monitor.addr = "127.0.0.1:9898".to_string();
            }
            let duration_ms = args.usize_opt("duration-ms", 2_000)?;
            let nodes = args.usize_opt("nodes", 256)?;
            let edges = args.usize_opt("edges", 1024)?;
            let query_ratio = args.f64_opt("query-ratio", 0.5)?;
            let ds = datasets::synthesize("monitor", nodes, edges, 6, 64, 42);
            monitor_demo(&spec, &ds, duration_ms, query_ratio)?;
        }
        Some(other) => bail!("unknown subcommand {other:?} — run without args for help"),
        None => println!("{}", HELP.trim()),
    }
    Ok(())
}

const HELP: &str = r#"
grannite — GNN execution on resource-constrained NPUs (paper reproduction)

subcommands:
  fig4 | fig5 | fig20 | fig21 | fig22 | fig23   regenerate a paper figure
  figures                                        all of the above
  ablation           GraphSplit placement ablation
  artifacts          list AOT artifacts
  infer              run one planned-engine inference (--artifact NAME)
  accuracy           accuracy table over all artifacts (--dataset cora)
  split              GraphSplit placement report (--model, --variant)
  serve              dynamic knowledge-graph serving demo (single leader
                     by default; coordinator serves artifacts, every other
                     engine runs offline)
  fleet              sharded multi-device serving demo (offline, no
                     artifacts; --nodes --edges size the synthetic graph)
  trace              end-to-end telemetry demo: tracing force-enabled,
                     prints the slowest stitched traces (admission/queue/
                     batch/engine/halo/per-op spans), the cost-model
                     calibration table, and validated Prometheus +
                     JSON-lines exporter output (--top N, --raw dumps
                     the exporter text)
  tune               spec-space autotuner: enumerate engine × aggregation
                     × quant × shards around the base spec, score with
                     the calibrated cost model, confirm top-K with live
                     probes, print the ranked report and the winning spec
                     ([tuning] sets objective/probe_budget/top_k;
                     --nodes --edges size the synthetic graph)
  top                live operational dashboard over the monitor's
                     history rings: per-shard windowed QPS / shed rate /
                     latency percentiles, heartbeat ages, SLO burn
                     status, recent flight-recorder events (--ticks N
                     renders, one per monitor interval)
  monitor            serve with the scrape endpoint up (--addr HOST:PORT,
                     default 127.0.0.1:9898) for --duration-ms, then
                     self-scrape GET /metrics + /health and validate the
                     Prometheus output — the CI endpoint check

both serving subcommands construct through serve::Deployment::launch from
one deployment spec:
  --spec file.toml   load a DeploymentSpec (see examples/specs/*.toml)
  --engine NAME      override [engine] name (local|plan|incremental|auto|
                     coordinator, or anything registered)
  --shards N         override [topology] shards (1 = single leader)
  --devices a,b,…    override [topology] devices (series2|series1|gpu|cpu)
  --aggregation dense|sparse|auto    --quant    --capacity N
  --max-pending N    per-shard admission bound (0 = unbounded)
  --events N --query-ratio Q         workload shape
  [storage] in the spec picks the feature tier: backend = "memory"
                     (default, fully resident) or "paged" (file-backed
                     store + admission-controlled page cache; engine
                     "incremental" only — see examples/specs/paged_10m.toml)

common options: --dataset cora|citeseer  --hw series1|series2|cpu|gpu
                --artifacts DIR
"#;

/// The per-artifact accuracy table (the paper's quality-loss claims).
fn accuracy_table(c: &mut Coordinator, dataset: &str) -> Result<Table> {
    let mut t = Table::new(
        format!("Accuracy on the {dataset} twin (PJRT execution)"),
        &["artifact", "test acc", "Δ vs reference variant"],
    );
    let groups: &[&[&str]] = &[
        &["gcn_stagr", "gcn_grad", "gcn_baseline", "gcn_quant"],
        &["gat_baseline", "gat_effop", "gat_grax"],
        &["sage_mean"],
        &["sage_max_baseline", "sage_max_grax3"],
    ];
    for artifacts in groups {
        let mut reference: Option<f64> = None;
        for base in *artifacts {
            let name = format!("{base}_{dataset}");
            if c.runtime.artifact(&name).is_err() {
                continue;
            }
            let acc = c
                .evaluate(&name)
                .with_context(|| format!("evaluating {name}"))?;
            let delta = match reference {
                None => {
                    reference = Some(acc);
                    "(reference)".to_string()
                }
                Some(r) => format!("{:+.3}", acc - r),
            };
            t.row(&[name, format!("{acc:.3}"), delta]);
        }
    }
    Ok(t)
}

/// Build the [`DeploymentSpec`] for a serving subcommand: start from
/// `--spec file.toml` (or the subcommand's defaults), then apply flag
/// overrides — every flag re-parses through the same spec layer, so
/// there is exactly one construction path.
fn deployment_spec(args: &Args, default_shards: usize, default_engine: &str)
                   -> Result<DeploymentSpec> {
    use grannite::serve::{EngineSpec, Topology};

    let mut spec = match args.options.get("spec") {
        Some(path) => DeploymentSpec::load(std::path::Path::new(path))?,
        None => DeploymentSpec {
            engine: EngineSpec::named(default_engine),
            topology: if default_shards <= 1 {
                Topology::homogeneous(1)
            } else {
                Topology::zoo(default_shards)
            },
            ..DeploymentSpec::default()
        },
    };
    if let Some(e) = args.options.get("engine") {
        spec.engine.name = e.clone();
    }
    if args.options.contains_key("aggregation") {
        spec.aggregation = grannite::ops::build::Aggregation::parse(
            &args.str_opt("aggregation", "auto"),
        )?;
    }
    if args.options.contains_key("shards") {
        spec.topology.shards = args.usize_opt("shards", spec.topology.shards)?;
    }
    if args.options.contains_key("devices") {
        spec.topology.devices = args.str_list_opt("devices", "");
    }
    if args.options.contains_key("capacity") {
        spec.capacity = args.usize_opt("capacity", spec.capacity)?;
    }
    if args.options.contains_key("max-pending") {
        spec.admission.max_pending = args.usize_opt("max-pending", 0)?;
    }
    // accept both the switch form (--quant) and the value form
    // (--quant=true / --quant false) — a mis-typed value must not
    // silently serve FP32
    if args.has("quant") {
        spec.quant = true;
    } else if let Some(v) = args.options.get("quant") {
        spec.quant = match v.as_str() {
            "true" | "1" => true,
            "false" | "0" => false,
            other => bail!("--quant expects true|false, got {other:?}"),
        };
    }
    Ok(spec)
}

/// The serving demo, engine- and topology-agnostic: launch the spec
/// through [`Deployment::launch`], stream a churn+query workload at it,
/// and report placement, per-shard metrics, and aggregates.
fn serving_demo(spec: &DeploymentSpec, data: &grannite::serve::DataSource,
                events: usize, query_ratio: f64) -> Result<()> {
    use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
    use grannite::serve::{Deployment, EngineRegistry, Serving};
    use grannite::server::Update;

    let ds = data.dataset()?;
    let nodes = ds.num_nodes();
    // fail fast on an invalid spec (bad engine, shards = 0, quant on the
    // wrong engine, …) before printing any placement report
    let registry = EngineRegistry::builtin();
    {
        let mut resolved = spec.clone();
        resolved.capacity = spec.resolved_capacity(nodes)?;
        resolved.validate_with(&registry)?;
    }
    let plan = Deployment::plan(spec, &ds)?;
    println!(
        "engine: {} (aggregation: {}, quant: {})",
        spec.engine.name,
        spec.aggregation.name(),
        spec.quant
    );

    let mut t = Table::new(
        format!("placement — {} shard(s) over {nodes} nodes", plan.num_shards()),
        &["shard", "device", "owned", "rate µs/node", "halo in/out", "est round"],
    );
    for s in &plan.shards {
        t.row(&[
            format!("#{}", s.id),
            s.device.name.clone(),
            s.num_owned().to_string(),
            format!("{:.3}", s.per_node_us),
            format!("{}/{}", s.halo_in, s.halo_out),
            grannite::util::human_us(s.est_compute_us + s.est_halo_us),
        ]);
    }
    t.print();
    println!(
        "cut edges: {}  halo {}/round  est round {}",
        plan.cut_edges,
        grannite::util::human_bytes(plan.halo_bytes_per_round),
        grannite::util::human_us(plan.est_round_us)
    );

    // the dataset and the plan are already resolved for the placement
    // report — hand both to the launcher so nothing is computed twice
    let serving = Deployment::launch_at(&registry, spec, &ds,
                                        data.artifacts_dir(), Some(plan.clone()))?;
    let capacity = plan.owner.len();
    let stream = KnowledgeGraphStream::new(nodes, capacity, query_ratio, 7);
    let mut rng = grannite::util::Rng::new(3);
    let mut pending = Vec::new();
    for ev in stream.take(events) {
        match ev {
            GraphEvent::AddEdge(u, v) => serving.update(Update::AddEdge(u, v))?,
            GraphEvent::RemoveEdge(u, v) => serving.update(Update::RemoveEdge(u, v))?,
            GraphEvent::AddNode => serving.update(Update::AddNode)?,
            GraphEvent::Query => pending.push(serving.query(Some(rng.usize(nodes)))?),
        }
    }
    let mut ok = 0;
    for rx in pending {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }

    let mut pt = Table::new(
        "per-shard serving metrics",
        &["shard", "queries", "rejected", "p50", "p99", "halo bytes",
          "recompute", "cache hit", "pg hit"],
    );
    for snap in serving.shard_metrics() {
        let (p50, p99) = snap
            .latency
            .as_ref()
            .map(|l| (grannite::util::human_us(l.p50), grannite::util::human_us(l.p99)))
            .unwrap_or_else(|| ("n/a".into(), "n/a".into()));
        let (recomp, hit) = if snap.eligible_rows > 0 {
            (
                format!("{:.3}", snap.recompute_ratio()),
                format!("{:.3}", snap.cache_hit_rate()),
            )
        } else {
            ("n/a".into(), "n/a".into())
        };
        let pg = if snap.page_hits + snap.page_faults > 0 {
            format!("{:.3}", snap.feature_cache_hit_rate())
        } else {
            "n/a".into()
        };
        pt.row(&[
            snap.shard.map(|s| format!("#{s}")).unwrap_or_default(),
            snap.queries.to_string(),
            snap.rejected.to_string(),
            p50,
            p99,
            grannite::util::human_bytes(snap.halo_bytes),
            recomp,
            hit,
            pg,
        ]);
    }
    pt.print();

    let totals = serving.metrics();
    println!("answered {ok} queries over {events} events");
    println!(
        "aggregate: {:.1} q/s  mean batch {:.1}  halo {} over {} rounds",
        totals.throughput_qps,
        totals.mean_batch,
        grannite::util::human_bytes(totals.halo_bytes),
        totals.halo_rounds
    );
    if totals.dma_bytes_dense > 0 {
        println!(
            "mask DMA: shipped {} of {} dense-equivalent ({} saved via CSR/ZVC/SymG)",
            grannite::util::human_bytes(totals.dma_bytes_shipped),
            grannite::util::human_bytes(totals.dma_bytes_dense),
            grannite::util::human_bytes(totals.dma_bytes_saved()),
        );
    }
    if totals.eligible_rows > 0 {
        let fr = totals
            .frontier
            .as_ref()
            .map(|f| format!("{:.1}/{:.0}", f.mean, f.max))
            .unwrap_or_else(|| "n/a".into());
        println!(
            "incremental: recompute ratio {:.3}  cache hit rate {:.3}  \
             frontier mean/max {fr}",
            totals.recompute_ratio(),
            totals.cache_hit_rate()
        );
    }
    if totals.page_hits + totals.page_faults > 0 {
        println!(
            "storage: feature-cache hit rate {:.3}  page faults {}  \
             disk read {}",
            totals.feature_cache_hit_rate(),
            totals.page_faults,
            grannite::util::human_bytes(totals.storage_bytes_read as usize)
        );
    }
    println!("applied version vector: {:?}", serving.sync()?);
    serving.shutdown()?;
    Ok(())
}

/// The `tune` subcommand body: run the three-stage autotuner over a
/// synthetic knowledge graph, print the ranked report, the winning spec
/// as TOML (paste-able into `--spec`), and a short verification run of
/// the winner through the real launch path.
fn tune_demo(spec: &DeploymentSpec,
             ds: &grannite::graph::datasets::Dataset) -> Result<()> {
    use grannite::serve::{DataSource, Deployment, Serving};

    println!(
        "autotuning over {} nodes / {} edges (objective: {}, probe budget {}, \
         top-{} live probes)",
        ds.num_nodes(),
        ds.graph.num_edges(),
        spec.tuning.objective,
        spec.tuning.probe_budget,
        spec.tuning.top_k
    );
    let data = DataSource::Dataset(ds.clone());
    let tuned = Deployment::autotune(spec, &data)?;
    println!("\n{}", tuned.report.render());
    println!("winning spec:\n{}", tuned.spec.to_toml());

    // verification: the winner must launch and answer through the same
    // path any hand-written spec would
    let serving = tuned.launch(&data)?;
    let mut ok = 0usize;
    for i in 0..16 {
        if serving.query_wait(Some(i % ds.num_nodes())).is_ok() {
            ok += 1;
        }
    }
    let totals = serving.metrics();
    println!(
        "winner verified: {ok}/16 probe queries answered at {:.1} q/s",
        totals.throughput_qps
    );
    serving.shutdown()?;
    Ok(())
}

/// The `trace` subcommand body: launch with telemetry enabled, drive a
/// churn+query workload, then print the slowest stitched traces
/// (flamegraph-style span breakdowns), the predicted-vs-observed
/// calibration table, and exporter output — which is **validated**
/// (Prometheus text format + JSON lines), so this doubles as the CI
/// exporter-parses check.
fn trace_demo(spec: &grannite::serve::DeploymentSpec,
              ds: &grannite::graph::datasets::Dataset, events: usize,
              query_ratio: f64, top: usize, raw: bool) -> Result<()> {
    use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
    use grannite::serve::{DataSource, Deployment, Serving};
    use grannite::server::Update;
    use grannite::telemetry::{export, SpanKind, ROUTER_SHARD};
    use grannite::util::human_us;

    let serving = Deployment::launch(spec, &DataSource::Dataset(ds.clone()))?;
    let tel = serving.telemetry().ok_or_else(|| {
        anyhow::anyhow!("this deployment carries no telemetry hub")
    })?;
    println!(
        "telemetry: enabled (ring capacity {}, sample rate {})",
        tel.config().ring_capacity,
        tel.config().sample_rate
    );

    let nodes = ds.num_nodes();
    let stream = KnowledgeGraphStream::new(nodes, nodes + nodes / 8, query_ratio, 7);
    let mut rng = grannite::util::Rng::new(3);
    let mut pending = Vec::new();
    for ev in stream.take(events) {
        match ev {
            GraphEvent::AddEdge(u, v) => serving.update(Update::AddEdge(u, v))?,
            GraphEvent::RemoveEdge(u, v) => {
                serving.update(Update::RemoveEdge(u, v))?
            }
            GraphEvent::AddNode => serving.update(Update::AddNode)?,
            GraphEvent::Query => {
                pending.push(serving.query(Some(rng.usize(nodes)))?)
            }
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    println!("answered {ok} queries over {events} events");

    // slowest stitched traces, flamegraph-style
    let traces = tel.traces();
    let (total, kept) = tel.span_counts();
    println!(
        "\n{} traces stitched from {kept} retained spans ({total} recorded); \
         slowest {}:",
        traces.len(),
        top.min(traces.len())
    );
    for tr in traces.iter().take(top) {
        let origin =
            tr.spans.first().map(|s| s.start_us).unwrap_or(0.0);
        println!(
            "trace {:>6}  {}  — {} spans over {} shard(s)",
            tr.trace_id,
            human_us(tr.latency_us()),
            tr.spans.len(),
            tr.shard_count()
        );
        for s in &tr.spans {
            let who = if s.shard == ROUTER_SHARD {
                "router".to_string()
            } else {
                format!("shard {}", s.shard)
            };
            let detail = match s.kind {
                SpanKind::Route => format!("→ shard {}", s.value),
                SpanKind::Admission => format!("{} (pending {})", s.label, s.value),
                SpanKind::Batch => format!("size {}", s.value),
                SpanKind::Halo => {
                    format!("{}", grannite::util::human_bytes(s.value as usize))
                }
                SpanKind::Op => s.label.to_string(),
                SpanKind::Queue | SpanKind::EngineRound => String::new(),
            };
            let indent = if s.kind == SpanKind::Op { "  " } else { "" };
            println!(
                "    {who:<9} {indent}{:<12} +{:<9} {:<9} {detail}",
                s.kind.name(),
                human_us(s.start_us - origin),
                human_us(s.dur_us),
            );
        }
    }

    // predicted-vs-observed calibration, per executed (op kind, bucket)
    let cal = tel.calibration();
    let mut ct = Table::new(
        "cost-model calibration — observed/predicted per op kind × row bucket",
        &["kind", "bucket", "runs", "pred µs/run", "obs µs/run", "ratio p50",
          "ratio p99"],
    );
    for r in &cal.rows {
        ct.row(&[
            r.kind.clone(),
            r.bucket.to_string(),
            r.runs.to_string(),
            format!("{:.2}", r.predicted_us),
            format!("{:.2}", r.observed_us),
            format!("{:.3}", r.ratio_p50),
            format!("{:.3}", r.ratio_p99),
        ]);
    }
    ct.print();
    let scales = cal.scales();
    if !scales.is_empty() {
        let fitted: Vec<String> = scales
            .iter()
            .map(|(k, f)| format!("{k}={f:.3}"))
            .collect();
        println!(
            "fitted cost scales (apply via npu::cost::op_cost_scaled): {}",
            fitted.join("  ")
        );
    }

    // exporters — validated, so a malformed emission fails the command
    let shards = serving.shard_metrics();
    let prom = export::prometheus(&shards, &cal);
    let prom_samples = export::validate_prometheus(&prom)
        .context("prometheus exporter output failed validation")?;
    let jl = export::json_lines(&traces, &shards, &cal);
    let jl_records = export::validate_json_lines(&jl)
        .context("json-lines exporter output failed validation")?;
    println!(
        "\nexporters validated: {prom_samples} prometheus samples, \
         {jl_records} json-lines records"
    );
    if raw {
        println!("\n--- prometheus ---\n{prom}");
        println!("--- json lines ---\n{jl}");
    }

    serving.sync()?;
    serving.shutdown()?;
    Ok(())
}

/// The `top` subcommand body: launch with the monitor on, drive one
/// workload burst per tick, and render the operational dashboard —
/// per-shard windowed rates out of the history rings, heartbeat ages,
/// SLO burn status, and the latest flight-recorder breadcrumbs.
fn top_demo(spec: &DeploymentSpec, ds: &grannite::graph::datasets::Dataset,
            ticks: usize, query_ratio: f64) -> Result<()> {
    use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
    use grannite::serve::{DataSource, Deployment, Serving};
    use grannite::server::Update;

    let serving = Deployment::launch(spec, &DataSource::Dataset(ds.clone()))?;
    let monitor = serving.monitor().ok_or_else(|| {
        anyhow::anyhow!("spec did not activate the monitor")
    })?;
    let interval =
        std::time::Duration::from_millis(spec.monitor.interval_ms.max(1) as u64);
    let nodes = ds.num_nodes();
    let capacity = spec.resolved_capacity(nodes)?;
    println!(
        "grannite top — {} shard(s), sampling every {:?}, {ticks} tick(s)",
        serving.num_shards(),
        interval
    );
    let mut stream = KnowledgeGraphStream::new(nodes, capacity, query_ratio, 7);
    let mut rng = grannite::util::Rng::new(3);
    for tick in 1..=ticks {
        // one workload burst per tick, then let the sampler observe it
        let mut pending = Vec::new();
        for ev in stream.by_ref().take(200) {
            match ev {
                GraphEvent::AddEdge(u, v) => serving.update(Update::AddEdge(u, v))?,
                GraphEvent::RemoveEdge(u, v) => {
                    serving.update(Update::RemoveEdge(u, v))?
                }
                GraphEvent::AddNode => serving.update(Update::AddNode)?,
                GraphEvent::Query => {
                    pending.push(serving.query(Some(rng.usize(nodes)))?)
                }
            }
        }
        for rx in pending {
            let _ = rx.recv();
        }
        std::thread::sleep(interval);
        monitor.sample_now();
        render_top(&monitor, tick, ticks);
        // out-of-core footer (merged snapshot, exact counters): only
        // paged deployments report feature-store traffic
        let totals = serving.metrics();
        if totals.page_hits + totals.page_faults > 0 {
            println!(
                "storage: feature-cache hit rate {:.3}  page faults {}  \
                 disk read {}",
                totals.feature_cache_hit_rate(),
                totals.page_faults,
                grannite::util::human_bytes(totals.storage_bytes_read as usize)
            );
        }
    }
    serving.shutdown()?;
    Ok(())
}

/// One `grannite top` frame, rendered from the monitor's public state.
fn render_top(monitor: &grannite::monitor::Monitor, tick: usize, ticks: usize) {
    use grannite::monitor::{Sample, WindowRates};
    use grannite::util::{human_bytes, human_us};

    let Some(health) = monitor.health() else { return };
    let us = |v: Option<f64>| v.map(human_us).unwrap_or_else(|| "n/a".into());
    let slo_line = match &health.slo {
        Some(s) => format!(
            "slo {}: q{:.0} {} vs objective {} — burn fast {:.2}×/{:.2}× \
             slow {:.2}×/{:.2}× (avail/lat)",
            if s.breached { "BREACHED" } else { "ok" },
            s.quantile * 100.0,
            us(s.latency_q_us),
            human_us(s.objective_us),
            s.fast.availability_burn,
            s.fast.latency_burn,
            s.slow.availability_burn,
            s.slow.latency_burn,
        ),
        None => "slo: none configured".to_string(),
    };
    println!(
        "\n[tick {tick}/{ticks}] +{:.1}s  {}  {}",
        health.at_ms as f64 / 1e3,
        if health.healthy { "HEALTHY" } else { "UNHEALTHY" },
        slo_line
    );

    // windowed rates over each ring's trailing samples
    let window_rates = |hist: &[Sample]| -> Option<WindowRates> {
        let refs: Vec<&Sample> = hist.iter().collect();
        let tail = &refs[refs.len().saturating_sub(8)..];
        WindowRates::over(tail)
    };
    let mut t = Table::new(
        "windowed rates (trailing ring samples)",
        &["shard", "qps", "shed", "p50", "p95", "p99", "halo B/s", "beat ms",
          "state"],
    );
    let mut rows: Vec<(String, Option<WindowRates>, String, String)> = monitor
        .shard_histories()
        .into_iter()
        .map(|(id, hist)| {
            let sh = health.shards.iter().find(|s| s.id == id);
            (
                format!("#{id}"),
                window_rates(&hist),
                sh.map(|s| s.beat_age_ms.to_string()).unwrap_or_default(),
                match sh {
                    Some(s) if s.wedged => "WEDGED".to_string(),
                    Some(_) => "ok".to_string(),
                    None => String::new(),
                },
            )
        })
        .collect();
    rows.push((
        "fleet".to_string(),
        window_rates(&monitor.fleet_history()),
        String::new(),
        if health.panicked { "PANICKED".to_string() } else { String::new() },
    ));
    for (label, w, beat, state) in rows {
        match w {
            Some(w) => t.row(&[
                label,
                format!("{:.1}", w.qps),
                format!("{:.3}", w.shed_rate),
                us(w.p50_us),
                us(w.p95_us),
                us(w.p99_us),
                human_bytes(w.halo_bps as usize),
                beat,
                state,
            ]),
            None => t.row(&[
                label,
                "–".into(),
                "–".into(),
                "–".into(),
                "–".into(),
                "–".into(),
                "–".into(),
                beat,
                state,
            ]),
        };
    }
    t.print();

    let events = monitor.events();
    if !events.is_empty() {
        println!("recent events:");
        for e in events.iter().rev().take(4).rev() {
            println!("{}", e.render());
        }
    }
}

/// The `monitor` subcommand body: serve with the scrape endpoint bound,
/// keep a workload running for `duration_ms`, then scrape the
/// deployment's **own** endpoint over TCP and validate what it serves —
/// the same check the CI examples job makes with curl.
fn monitor_demo(spec: &DeploymentSpec, ds: &grannite::graph::datasets::Dataset,
                duration_ms: usize, query_ratio: f64) -> Result<()> {
    use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
    use grannite::serve::{DataSource, Deployment, Serving};
    use grannite::server::Update;
    use std::time::{Duration, Instant};

    let serving = Deployment::launch(spec, &DataSource::Dataset(ds.clone()))?;
    let monitor = serving.monitor().ok_or_else(|| {
        anyhow::anyhow!("spec did not activate the monitor")
    })?;
    let addr = monitor.addr().ok_or_else(|| {
        anyhow::anyhow!("no scrape address bound — set [monitor] addr or --addr")
    })?;
    println!(
        "serving {} shard(s); scrape endpoint http://{addr} \
         (/metrics /health /traces /events) for {duration_ms} ms",
        serving.num_shards()
    );

    let nodes = ds.num_nodes();
    let capacity = spec.resolved_capacity(nodes)?;
    let mut stream = KnowledgeGraphStream::new(nodes, capacity, query_ratio, 7);
    let mut rng = grannite::util::Rng::new(3);
    let deadline = Instant::now() + Duration::from_millis(duration_ms as u64);
    let mut answered = 0usize;
    while Instant::now() < deadline {
        let mut pending = Vec::new();
        for ev in stream.by_ref().take(100) {
            match ev {
                GraphEvent::AddEdge(u, v) => serving.update(Update::AddEdge(u, v))?,
                GraphEvent::RemoveEdge(u, v) => {
                    serving.update(Update::RemoveEdge(u, v))?
                }
                GraphEvent::AddNode => serving.update(Update::AddNode)?,
                GraphEvent::Query => {
                    pending.push(serving.query(Some(rng.usize(nodes)))?)
                }
            }
        }
        for rx in pending {
            if matches!(rx.recv(), Ok(Ok(_))) {
                answered += 1;
            }
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    println!("answered {answered} queries while the endpoint was up");

    // self-scrape: validate what the endpoint actually serves over TCP
    let (status, metrics_body) = http_get(addr, "/metrics")?;
    anyhow::ensure!(
        status.contains("200"),
        "GET /metrics returned {status:?}"
    );
    let samples = grannite::telemetry::export::validate_prometheus(&metrics_body)
        .context("scraped /metrics failed Prometheus validation")?;
    let (health_status, health_body) = http_get(addr, "/health")?;
    println!(
        "self-scrape: /metrics {samples} samples (validated); /health {}",
        health_status.trim()
    );
    println!("{}", health_body.trim());
    serving.shutdown()?;
    Ok(())
}

/// Minimal HTTP GET against the deployment's own scrape endpoint:
/// returns `(status line, body)`.
fn http_get(addr: std::net::SocketAddr, path: &str) -> Result<(String, String)> {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting to scrape endpoint {addr}"))?;
    s.set_read_timeout(Some(std::time::Duration::from_secs(5)))?;
    write!(
        s,
        "GET {path} HTTP/1.1\r\nHost: grannite\r\nConnection: close\r\n\r\n"
    )?;
    let mut raw = String::new();
    s.read_to_string(&mut raw)?;
    let status = raw.lines().next().unwrap_or("").to_string();
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, body))
}
