//! `.gnnt` tensor-container IO — the rust mirror of
//! `python/compile/gnnt.py` (keep the two in sync; format doc there).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"GNNT";
const VERSION: u32 = 1;

/// Read all tensors from a `.gnnt` file.
pub fn read_gnnt(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_gnnt(&bytes).with_context(|| format!("parsing {}", path.display()))
}

/// Parse a `.gnnt` byte stream.
pub fn parse_gnnt(bytes: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut r = Cursor { buf: bytes, pos: 0 };
    if r.take(4)? != MAGIC.as_slice() {
        bail!("bad magic");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported version {version}");
    }
    let count = r.u32()?;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let name_len = r.u16()? as usize;
        let name = std::str::from_utf8(r.take(name_len)?)
            .context("tensor name not utf-8")?
            .to_string();
        let dtype = r.u8()?;
        let ndim = r.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        let n: usize = if ndim == 0 { 1 } else { shape.iter().product() };
        let tensor = match dtype {
            0 => {
                let raw = r.take(n * 4)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::F32 { shape, data }
            }
            1 => {
                let raw = r.take(n)?;
                Tensor::I8 { shape, data: raw.iter().map(|&b| b as i8).collect() }
            }
            2 => {
                let raw = r.take(n * 4)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::I32 { shape, data }
            }
            3 => {
                let raw = r.take(n)?;
                Tensor::U8 { shape, data: raw.to_vec() }
            }
            4 => {
                let raw = r.take(n * 2)?;
                let data = raw
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes([c[0], c[1]]))
                    .collect();
                Tensor::F16 { shape, data }
            }
            other => bail!("unknown dtype code {other}"),
        };
        out.insert(name, tensor);
    }
    Ok(out)
}

/// Write tensors to a `.gnnt` file (used by rust-side tests/tools).
pub fn write_gnnt(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        let code: u8 = match t {
            Tensor::F32 { .. } => 0,
            Tensor::I8 { .. } => 1,
            Tensor::I32 { .. } => 2,
            Tensor::U8 { .. } => 3,
            Tensor::F16 { .. } => 4,
            // CSR tensors are in-memory only (rebuilt from the graph);
            // densifying here would silently explode the container.
            Tensor::Csr { .. } => {
                bail!("CSR tensor {name:?} is not .gnnt-serializable")
            }
        };
        f.write_all(&[code, t.shape().len() as u8])?;
        for &d in t.shape() {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        match t {
            Tensor::F32 { data, .. } => {
                for v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            Tensor::I8 { data, .. } => {
                let raw: Vec<u8> = data.iter().map(|&v| v as u8).collect();
                f.write_all(&raw)?;
            }
            Tensor::I32 { data, .. } => {
                for v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            Tensor::U8 { data, .. } => f.write_all(data)?,
            Tensor::F16 { data, .. } => {
                for v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            Tensor::Csr { .. } => unreachable!("rejected above"),
        }
    }
    Ok(())
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated file: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(tensors: BTreeMap<String, Tensor>) -> BTreeMap<String, Tensor> {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "gnnt_{}_{:?}.gnnt",
            std::process::id(),
            std::thread::current().id()
        ));
        write_gnnt(&path, &tensors).unwrap();
        let back = read_gnnt(&path).unwrap();
        std::fs::remove_file(&path).ok();
        back
    }

    #[test]
    fn roundtrip_all_dtypes() {
        let mut t = BTreeMap::new();
        t.insert("f".into(), Tensor::F32 { shape: vec![2, 2], data: vec![1.5, -2.0, 0.0, 3.25] });
        t.insert("i8".into(), Tensor::I8 { shape: vec![3], data: vec![-127, 0, 127] });
        t.insert("i32".into(), Tensor::I32 { shape: vec![2], data: vec![-5, 100000] });
        t.insert("u8".into(), Tensor::U8 { shape: vec![4], data: vec![0, 1, 1, 0] });
        t.insert("f16".into(), Tensor::F16 { shape: vec![1], data: vec![0x3C00] });
        let back = roundtrip(t.clone());
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_shape_roundtrip() {
        let mut t = BTreeMap::new();
        t.insert("s".into(), Tensor::F32 { shape: vec![], data: vec![3.25] });
        assert_eq!(roundtrip(t.clone()), t);
    }

    #[test]
    fn bad_magic_rejected() {
        let err = parse_gnnt(b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").unwrap_err();
        assert!(err.to_string().contains("magic"));
    }

    #[test]
    fn bad_version_rejected() {
        let mut bytes = MAGIC.to_vec();
        bytes.extend(99u32.to_le_bytes());
        bytes.extend(0u32.to_le_bytes());
        assert!(parse_gnnt(&bytes).unwrap_err().to_string().contains("version"));
    }

    #[test]
    fn truncation_rejected() {
        let mut t = BTreeMap::new();
        t.insert("x".into(), Tensor::F32 { shape: vec![8], data: vec![1.0; 8] });
        let dir = std::env::temp_dir();
        let path = dir.join(format!("trunc_{}.gnnt", std::process::id()));
        write_gnnt(&path, &t).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 3);
        std::fs::remove_file(&path).ok();
        assert!(parse_gnnt(&bytes).unwrap_err().to_string().contains("truncated"));
    }

    #[test]
    fn negative_i8_survives() {
        let mut t = BTreeMap::new();
        t.insert("q".into(), Tensor::I8 { shape: vec![2], data: vec![-1, -128] });
        assert_eq!(roundtrip(t.clone()), t);
    }

    #[test]
    fn reads_python_written_artifact_if_present() {
        // integration with the real AOT output (skipped when absent)
        let path = std::path::Path::new("artifacts/cora.gnnt");
        if !path.exists() {
            return;
        }
        let t = read_gnnt(path).unwrap();
        let feats = t.get("features").unwrap();
        assert_eq!(feats.shape(), &[2708, 1433]);
        assert_eq!(t.get("labels").unwrap().shape(), &[2708]);
        assert_eq!(t.get("edges").unwrap().shape(), &[5429, 2]);
        assert_eq!(t.get("nbr_idx").unwrap().shape(), &[2708, 11]);
    }
}
