//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! request path — the rust half of the HLO-text interchange
//! (see /opt/xla-example/README.md for the gotchas this encodes).
//!
//! One [`Runtime`] owns the PJRT CPU client, the artifact manifest, and a
//! compile cache (one compiled executable per model variant, as the
//! architecture prescribes). Python never runs here.

pub mod io;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Document;
use crate::tensor::Tensor;

/// Metadata for one AOT artifact (a `[artifact.*]` manifest section).
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: PathBuf,
    pub model: String,
    pub dataset: String,
    /// Input binding names, in parameter order.
    pub inputs: Vec<String>,
    /// Input shapes (dims per input, same order).
    pub shapes: Vec<Vec<usize>>,
    /// Input dtypes ("float32", "int8", …), same order.
    pub dtypes: Vec<String>,
}

/// The PJRT-backed model runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    artifacts: BTreeMap<String, ArtifactInfo>,
    cache: Mutex<BTreeMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// Dataset + weights sections from the manifest (typed lookups).
    pub manifest: Document,
}

impl Runtime {
    /// Open the artifacts directory (requires `make artifacts` output).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.toml");
        let manifest = Document::load(&manifest_path)
            .context("artifacts missing — run `make artifacts` first")?;
        let mut artifacts = BTreeMap::new();
        for section in manifest.sections_under("artifact") {
            let name = section.trim_start_matches("artifact.").to_string();
            let rel = manifest.str_of(section, "path")?;
            let inputs: Vec<String> = manifest
                .str_of(section, "inputs")?
                .split(',')
                .map(|s| s.to_string())
                .collect();
            let shapes: Vec<Vec<usize>> = manifest
                .str_of(section, "shapes")?
                .split(';')
                .map(|s| {
                    s.split('x')
                        .filter(|p| !p.is_empty())
                        .map(|p| p.parse::<usize>().map_err(|e| anyhow!("{e}")))
                        .collect::<Result<Vec<usize>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            let dtypes: Vec<String> = manifest
                .str_of(section, "dtypes")?
                .split(',')
                .map(|s| s.to_string())
                .collect();
            if inputs.len() != shapes.len() || inputs.len() != dtypes.len() {
                bail!("manifest {section}: inputs/shapes/dtypes disagree");
            }
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name,
                    path: dir.join(rel),
                    model: manifest.str_of(section, "model")?.to_string(),
                    dataset: manifest.str_of(section, "dataset")?.to_string(),
                    inputs,
                    shapes,
                    dtypes,
                },
            );
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            artifacts,
            cache: Mutex::new(BTreeMap::new()),
            manifest,
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have: {:?})",
                                   self.artifact_names()))
    }

    /// Load + compile an artifact (cached after the first call).
    pub fn load(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let info = self.artifact(name)?;
        // HLO *text* interchange: xla_extension 0.5.1 rejects jax≥0.5
        // serialized protos (64-bit instruction ids); the text parser
        // reassigns ids and round-trips cleanly.
        let proto = xla::HloModuleProto::from_text_file(
            info.path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", info.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on positional tensors. Returns the first
    /// output (the logits) as a Tensor.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Tensor> {
        let info = self.artifact(name)?;
        if inputs.len() != info.inputs.len() {
            bail!(
                "{name}: expected {} inputs ({:?}), got {}",
                info.inputs.len(),
                info.inputs,
                inputs.len()
            );
        }
        for (i, t) in inputs.iter().enumerate() {
            if t.shape() != info.shapes[i].as_slice() {
                bail!(
                    "{name}: input {} ({}) shape {:?} != expected {:?}",
                    i,
                    info.inputs[i],
                    t.shape(),
                    info.shapes[i]
                );
            }
        }
        let exe = self.load(name)?;
        let literals = inputs
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = out.to_tuple1().context("unwrapping result tuple")?;
        literal_to_tensor(&out)
    }

    /// Execute with named bindings, ordered per the manifest.
    pub fn execute_named(&self, name: &str,
                         bindings: &BTreeMap<String, Tensor>) -> Result<Tensor> {
        let info = self.artifact(name)?;
        let inputs = info
            .inputs
            .iter()
            .map(|n| {
                bindings
                    .get(n)
                    .cloned()
                    .ok_or_else(|| anyhow!("{name}: missing binding {n:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        self.execute(name, &inputs)
    }
}

/// Convert a [`Tensor`] into a PJRT literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(match t {
        Tensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        Tensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        Tensor::I8 { shape, data } => {
            let bytes: Vec<u8> = data.iter().map(|&v| v as u8).collect();
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S8,
                shape,
                &bytes,
            )?
        }
        Tensor::U8 { shape, data } => {
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U8,
                shape,
                data,
            )?
        }
        Tensor::F16 { shape, data } => {
            let bytes: Vec<u8> =
                data.iter().flat_map(|v| v.to_le_bytes()).collect();
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F16,
                shape,
                &bytes,
            )?
        }
    })
}

/// Convert a PJRT literal back into a [`Tensor`] (f32/i32 outputs).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
        xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
        other => bail!("unsupported output element type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from("artifacts");
        if p.join("manifest.toml").exists() {
            Some(p)
        } else {
            None
        }
    }

    #[test]
    fn manifest_parses_and_lists_artifacts() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(&dir).unwrap();
        let names = rt.artifact_names();
        assert!(names.iter().any(|n| n.starts_with("gcn_stagr_cora")), "{names:?}");
        assert!(names.iter().any(|n| n.starts_with("gat_grax_cora")));
        let info = rt.artifact("gcn_stagr_cora").unwrap();
        assert_eq!(info.inputs[0], "norm");
        assert_eq!(info.shapes[0], vec![2708, 2708]);
    }

    #[test]
    fn unknown_artifact_error_lists_options() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(&dir).unwrap();
        let err = rt.artifact("nonexistent").unwrap_err().to_string();
        assert!(err.contains("unknown artifact"));
    }

    #[test]
    fn tensor_literal_roundtrip_f32() {
        let t = Tensor::F32 { shape: vec![2, 3], data: vec![1., 2., 3., 4., 5., 6.] };
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn tensor_literal_roundtrip_i32() {
        let t = Tensor::I32 { shape: vec![4], data: vec![-1, 0, 7, 100] };
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_to_tensor(&lit).unwrap(), t);
    }

    #[test]
    fn i8_literal_created_with_correct_shape() {
        let t = Tensor::I8 { shape: vec![2, 2], data: vec![-1, 2, -3, 4] };
        let lit = tensor_to_literal(&t).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
    }
}
