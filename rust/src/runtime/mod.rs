//! Artifact runtime: load the AOT manifest (`make artifacts` output) and
//! execute models on the request path through the **planned engine**.
//!
//! Earlier revisions shipped each artifact as lowered HLO text executed
//! through a PJRT client; that put an external XLA toolchain on the
//! serving path for numerics this crate can produce itself. The runtime
//! now rebuilds every artifact's op graph from the manifest metadata
//! (model / variant / input shapes), compiles it **once** into an
//! [`ExecPlan`] (frozen topo order, liveness-shared buffer arena, fused
//! elementwise chains, INT8 lowering — see [`crate::ops::plan`]), and
//! keeps one warm [`PlanInstance`] per artifact so steady-state execution
//! allocates nothing. The HLO files remain on disk as the interchange
//! record; the `.gnnt` weights files are the numerics source of truth
//! (quant scales included).
//!
//! One [`Runtime`] owns the manifest, the compiled-plan cache, and the
//! shared worker pool — one compiled executable per model variant, as the
//! architecture prescribes. Python never runs here.

pub mod io;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Document;
use crate::engine::{PlanInstance, WorkerPool};
use crate::ops::build::{self, GnnDims, QuantScales};
use crate::ops::plan::ExecPlan;
use crate::ops::OpGraph;
use crate::tensor::Tensor;

/// Metadata for one AOT artifact (a `[artifact.*]` manifest section).
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: PathBuf,
    pub model: String,
    pub dataset: String,
    /// Model variant ("stagr", "grax3", …) when the manifest records it;
    /// older manifests fall back to name-derived heuristics.
    pub variant: Option<String>,
    /// Input binding names, in parameter order.
    pub inputs: Vec<String>,
    /// Input shapes (dims per input, same order).
    pub shapes: Vec<Vec<usize>>,
    /// Input dtypes ("float32", "int8", …), same order.
    pub dtypes: Vec<String>,
}

/// The plan-backed model runtime.
pub struct Runtime {
    dir: PathBuf,
    artifacts: BTreeMap<String, ArtifactInfo>,
    pool: Arc<WorkerPool>,
    plans: Mutex<BTreeMap<String, Arc<ExecPlan>>>,
    /// One warm instance per artifact: arena buffers + INT8 weight cache
    /// survive across calls, so repeat inference is allocation-free.
    /// Per-artifact mutexes: concurrent callers serialize only on the
    /// *same* artifact, not on the registry.
    instances: Mutex<BTreeMap<String, Arc<Mutex<PlanInstance>>>>,
    /// Dataset + weights sections from the manifest (typed lookups).
    pub manifest: Document,
}

impl Runtime {
    /// Open the artifacts directory (requires `make artifacts` output)
    /// with a machine-sized worker pool. When many runtimes coexist (one
    /// per fleet shard), use [`Runtime::open_with_pool`] with
    /// [`WorkerPool::serial`] instead — shards already parallelize across
    /// threads, and N full-size pools would oversubscribe the host.
    pub fn open(dir: &Path) -> Result<Runtime> {
        Runtime::open_with_pool(dir, Arc::new(WorkerPool::default_parallel()))
    }

    /// [`Runtime::open`] with an explicit (possibly shared) worker pool.
    pub fn open_with_pool(dir: &Path, pool: Arc<WorkerPool>) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.toml");
        let manifest = Document::load(&manifest_path)
            .context("artifacts missing — run `make artifacts` first")?;
        let mut artifacts = BTreeMap::new();
        for section in manifest.sections_under("artifact") {
            let name = section.trim_start_matches("artifact.").to_string();
            let rel = manifest.str_of(section, "path")?;
            let inputs: Vec<String> = manifest
                .str_of(section, "inputs")?
                .split(',')
                .map(|s| s.to_string())
                .collect();
            let shapes: Vec<Vec<usize>> = manifest
                .str_of(section, "shapes")?
                .split(';')
                .map(|s| {
                    s.split('x')
                        .filter(|p| !p.is_empty())
                        .map(|p| p.parse::<usize>().map_err(|e| anyhow!("{e}")))
                        .collect::<Result<Vec<usize>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            let dtypes: Vec<String> = manifest
                .str_of(section, "dtypes")?
                .split(',')
                .map(|s| s.to_string())
                .collect();
            if inputs.len() != shapes.len() || inputs.len() != dtypes.len() {
                bail!("manifest {section}: inputs/shapes/dtypes disagree");
            }
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name,
                    path: dir.join(rel),
                    model: manifest.str_of(section, "model")?.to_string(),
                    dataset: manifest.str_of(section, "dataset")?.to_string(),
                    variant: manifest
                        .str_of(section, "variant")
                        .ok()
                        .map(|s| s.to_string()),
                    inputs,
                    shapes,
                    dtypes,
                },
            );
        }
        Ok(Runtime {
            dir: dir.to_path_buf(),
            artifacts,
            pool,
            plans: Mutex::new(BTreeMap::new()),
            instances: Mutex::new(BTreeMap::new()),
            manifest,
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have: {:?})",
                                   self.artifact_names()))
    }

    /// Rebuild + compile an artifact's plan (cached after the first call).
    pub fn load(&self, name: &str) -> Result<Arc<ExecPlan>> {
        if let Some(plan) = self.plans.lock().unwrap().get(name) {
            return Ok(plan.clone());
        }
        let info = self.artifact(name)?;
        let graph = self
            .graph_for(info)
            .with_context(|| format!("rebuilding op graph for {name}"))?;
        let plan = Arc::new(
            ExecPlan::compile(&graph)
                .with_context(|| format!("compiling plan for {name}"))?,
        );
        self.plans.lock().unwrap().insert(name.to_string(), plan.clone());
        Ok(plan)
    }

    /// Execute an artifact on positional tensors. Returns the first
    /// output (the logits) as a Tensor.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Tensor> {
        let info = self.artifact(name)?;
        if inputs.len() != info.inputs.len() {
            bail!(
                "{name}: expected {} inputs ({:?}), got {}",
                info.inputs.len(),
                info.inputs,
                inputs.len()
            );
        }
        for (i, t) in inputs.iter().enumerate() {
            if t.shape() != info.shapes[i].as_slice() {
                bail!(
                    "{name}: input {} ({}) shape {:?} != expected {:?}",
                    i,
                    info.inputs[i],
                    t.shape(),
                    info.shapes[i]
                );
            }
        }
        let mut bindings: BTreeMap<String, Tensor> = BTreeMap::new();
        for (i, t) in inputs.iter().enumerate() {
            bindings.insert(info.inputs[i].clone(), t.clone());
        }
        self.execute_bound(name, &bindings)
    }

    /// Execute with named bindings (extra bindings are allowed and
    /// ignored); shapes are validated against the manifest.
    pub fn execute_named(&self, name: &str,
                         bindings: &BTreeMap<String, Tensor>) -> Result<Tensor> {
        self.execute_bound(name, bindings)
    }

    fn execute_bound(&self, name: &str,
                     bindings: &BTreeMap<String, Tensor>) -> Result<Tensor> {
        let info = self.artifact(name)?;
        for (i, input) in info.inputs.iter().enumerate() {
            let t = bindings
                .get(input)
                .ok_or_else(|| anyhow!("{name}: missing binding {input:?}"))?;
            if !shapes_compatible(t.shape(), &info.shapes[i]) {
                bail!(
                    "{name}: binding {input:?} shape {:?} != expected {:?}",
                    t.shape(),
                    info.shapes[i]
                );
            }
        }
        self.execute_bound_unchecked(name, bindings)
    }

    fn execute_bound_unchecked(&self, name: &str,
                               bindings: &BTreeMap<String, Tensor>) -> Result<Tensor> {
        let plan = self.load(name)?;
        // hold the registry lock only to fetch/create the artifact's
        // instance; the inference itself locks just that instance
        let inst = {
            let mut instances = self.instances.lock().unwrap();
            Arc::clone(instances.entry(name.to_string()).or_insert_with(|| {
                Arc::new(Mutex::new(PlanInstance::new(
                    plan,
                    Arc::clone(&self.pool),
                )))
            }))
        };
        let mut inst = inst.lock().unwrap();
        inst.run(bindings)
            .with_context(|| format!("executing {name}"))?;
        let (data, r, c) = inst.output_view(0)?;
        Ok(Tensor::F32 { shape: vec![r, c], data: data.to_vec() })
    }

    // ------------------------------------------------------------------
    // manifest metadata → op graph
    // ------------------------------------------------------------------

    /// Model dimensions recovered from the artifact's input shapes.
    fn dims_for(&self, info: &ArtifactInfo) -> Result<GnnDims> {
        fn shape_of<'a>(info: &'a ArtifactInfo, n: &str) -> Option<&'a [usize]> {
            info.inputs
                .iter()
                .position(|x| x == n)
                .map(|i| info.shapes[i].as_slice())
        }
        let x = shape_of(info, "x")
            .or_else(|| shape_of(info, "x_pad"))
            .ok_or_else(|| anyhow!("{}: no feature input", info.name))?;
        if x.len() != 2 {
            bail!("{}: feature input must be 2-D, got {x:?}", info.name);
        }
        let (n, f) = (x[0], x[1]);
        // layers = highest numbered bias input (b1, b2, …)
        let mut layers = 0usize;
        for nm in &info.inputs {
            if let Some(rest) = nm.strip_prefix('b') {
                if let Ok(l) = rest.parse::<usize>() {
                    layers = layers.max(l);
                }
            }
        }
        if layers == 0 {
            bail!("{}: no bias inputs to infer layer count", info.name);
        }
        let last_dim = |s: &[usize]| s.last().copied().unwrap_or(0);
        let classes = shape_of(info, &format!("b{layers}"))
            .map(last_dim)
            .ok_or_else(|| anyhow!("{}: missing b{layers}", info.name))?;
        let hidden = if layers > 1 {
            shape_of(info, "b1").map(last_dim).unwrap_or(crate::HIDDEN)
        } else {
            classes
        };
        let m = shape_of(info, "edges").map(|s| s[0]).unwrap_or(0);
        let k = shape_of(info, "nbr_idx")
            .and_then(|s| s.get(1).copied())
            .unwrap_or(crate::SAGE_MAX_NEIGHBORS + 1);
        Ok(GnnDims { n, m, f, hidden, classes, k, layers })
    }

    /// Rebuild the artifact's op graph: model from the manifest, variant
    /// recovered from the artifact name, dims from the input shapes, and
    /// (for QuantGr variants) the calibration scales from the weights file.
    fn graph_for(&self, info: &ArtifactInfo) -> Result<OpGraph> {
        let dims = self.dims_for(info)?;
        // legacy manifests recorded sage artifacts under model "sage"
        let model = if info.model == "sage" {
            if info.name.starts_with("sage_mean") {
                "sage_mean".to_string()
            } else {
                "sage_max".to_string()
            }
        } else {
            info.model.clone()
        };
        // variant = name minus "<model>_" prefix minus "_<dataset>" suffix;
        // fall back by trimming trailing segments (custom dataset tags)
        let rest = info
            .name
            .strip_prefix(&model)
            .unwrap_or(&info.name)
            .trim_start_matches('_');
        let ds_suffix = format!("_{}", info.dataset);
        let variant = match rest.strip_suffix(&ds_suffix) {
            Some(v) => v.to_string(),
            None if rest == info.dataset => String::new(),
            None => rest.to_string(),
        };
        let mut candidates: Vec<String> = Vec::new();
        // a manifest-recorded variant beats every name-derived heuristic
        if let Some(v) = &info.variant {
            if !v.is_empty() {
                candidates.push(v.clone());
            }
        }
        if !variant.is_empty() && !candidates.contains(&variant) {
            candidates.push(variant.clone());
            let mut v = variant.clone();
            while let Some(p) = v.rfind('_') {
                v.truncate(p);
                if !v.is_empty() && !candidates.contains(&v) {
                    candidates.push(v.clone());
                }
            }
        }
        candidates.push("stagr".to_string());
        candidates.push("baseline".to_string());

        let has_input = |n: &str| info.inputs.iter().any(|i| i == n);
        let mut last_err = anyhow!("{}: no graph variant matched", info.name);
        for cand in &candidates {
            let mut g = if cand.starts_with("quant") {
                if model != "gcn" {
                    continue;
                }
                build::gcn_quant(dims, self.quant_scales(info))
            } else if model == "sage_mean" && has_input("nbr_idx") {
                // Cora-scale sage artifacts ship the gathered formulation
                build::sage_mean_gathered(dims)
            } else {
                match build::build(&model, cand, dims) {
                    Ok(g) => g,
                    Err(e) => {
                        last_err = e;
                        continue;
                    }
                }
            };
            // NodePad artifacts record padded input names (norm_pad, x_pad)
            for op in &mut g.ops {
                if op.kind == crate::ops::OpKind::Input
                    && !has_input(op.name.as_str())
                {
                    let padded = format!("{}_pad", op.name);
                    if has_input(padded.as_str()) {
                        op.name = padded;
                    }
                }
            }
            // the rebuilt graph must bind exactly what the artifact takes
            let wanted: Vec<String> =
                g.inputs().into_iter().map(|(_, n)| n.to_string()).collect();
            if wanted.iter().all(|n| has_input(n.as_str())) {
                return Ok(g);
            }
            last_err = anyhow!(
                "{}: variant {cand:?} needs inputs {wanted:?}, artifact has {:?}",
                info.name,
                info.inputs
            );
        }
        Err(last_err)
    }

    /// QuantGr static scales from the weights file's `scales` tensor
    /// (`[act1, w1, act2, w2]`, written by `python -m compile.aot`).
    fn quant_scales(&self, info: &ArtifactInfo) -> QuantScales {
        let path = self
            .dir
            .join(format!("weights_{}_{}.gnnt", info.model, info.dataset));
        if let Ok(tensors) = io::read_gnnt(&path) {
            if let Some(Tensor::F32 { data, .. }) = tensors.get("scales") {
                if data.len() == 4 {
                    return QuantScales {
                        act1: data[0],
                        w1: data[1],
                        act2: data[2],
                        w2: data[3],
                    };
                }
            }
        }
        QuantScales::default()
    }
}

/// Manifest-vs-binding shape compatibility: exact match, or the
/// deliberate rank normalization between a 1-D vector `[n]` and a row
/// vector `[1, n]` (biases bind either way across the python/rust layers).
fn shapes_compatible(bound: &[usize], expected: &[usize]) -> bool {
    if bound == expected {
        return true;
    }
    match (bound, expected) {
        ([n], [one, m]) | ([one, m], [n]) => *one == 1 && n == m,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::exec::{self, Bindings};
    use crate::tensor::Mat;
    use crate::util::Rng;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from("artifacts");
        if p.join("manifest.toml").exists() {
            Some(p)
        } else {
            None
        }
    }

    /// Synthetic manifest in a temp dir — exercises the whole open →
    /// rebuild → compile → execute path with no `make artifacts` output.
    fn tiny_runtime() -> (Runtime, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "grannite-rt-test-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"
[artifact.gcn_stagr_tiny]
path = "gcn_stagr_tiny.hlo.txt"
model = "gcn"
dataset = "tiny"
inputs = "norm,x,w1,b1,w2,b2"
shapes = "8x8;8x6;6x5;5;5x3;3"
dtypes = "float32,float32,float32,float32,float32,float32"
"#;
        std::fs::write(dir.join("manifest.toml"), manifest).unwrap();
        (Runtime::open(&dir).unwrap(), dir)
    }

    fn tiny_inputs(seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        let mut rand = |r: usize, c: usize| {
            Mat::from_fn(r, c, |_, _| (rng.f64() - 0.5) as f32)
        };
        vec![
            Tensor::from_mat(&rand(8, 8)),
            Tensor::from_mat(&rand(8, 6)),
            Tensor::from_mat(&rand(6, 5)),
            // biases bind 1-D, exactly as the python-written manifest records
            Tensor::F32 { shape: vec![5], data: rand(1, 5).data },
            Tensor::from_mat(&rand(5, 3)),
            Tensor::F32 { shape: vec![3], data: rand(1, 3).data },
        ]
    }

    #[test]
    fn synthetic_manifest_executes_and_matches_oracle() {
        let (rt, dir) = tiny_runtime();
        let inputs = tiny_inputs(5);
        let out = rt.execute("gcn_stagr_tiny", &inputs).unwrap();
        assert_eq!(out.shape(), &[8, 3]);

        // oracle comparison: same graph, same bindings ((1,n) biases)
        let info = rt.artifact("gcn_stagr_tiny").unwrap();
        let mut b: Bindings = Bindings::new();
        for (i, name) in info.inputs.iter().enumerate() {
            let t = match &inputs[i] {
                Tensor::F32 { shape, data } if shape.len() == 1 => {
                    Tensor::F32 { shape: vec![1, shape[0]], data: data.clone() }
                }
                other => other.clone(),
            };
            b.insert(name.clone(), t);
        }
        let dims = GnnDims { n: 8, m: 0, f: 6, hidden: 5, classes: 3, k: 11, layers: 2 };
        let g = build::gcn_stagr(dims, "stagr");
        let want = exec::execute_mat(&g, &b).unwrap();
        let got = out.to_mat().unwrap();
        assert!(want.max_abs_diff(&got) < 1e-4);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn repeat_execution_reuses_the_compiled_plan() {
        let (rt, dir) = tiny_runtime();
        let inputs = tiny_inputs(9);
        let a = rt.execute("gcn_stagr_tiny", &inputs).unwrap();
        assert_eq!(rt.plans.lock().unwrap().len(), 1);
        let c = rt.execute("gcn_stagr_tiny", &inputs).unwrap();
        assert_eq!(a, c, "warm instance must be deterministic");
        assert_eq!(rt.plans.lock().unwrap().len(), 1, "plan compiled once");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn shape_validation_still_enforced() {
        let (rt, dir) = tiny_runtime();
        let mut inputs = tiny_inputs(1);
        inputs[0] = Tensor::from_mat(&Mat::zeros(4, 4));
        let err = rt.execute("gcn_stagr_tiny", &inputs).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn named_bindings_shape_validated() {
        let (rt, dir) = tiny_runtime();
        let inputs = tiny_inputs(3);
        let names = rt.artifact("gcn_stagr_tiny").unwrap().inputs.clone();
        let mut b: BTreeMap<String, Tensor> = BTreeMap::new();
        for (n, t) in names.iter().zip(&inputs) {
            b.insert(n.clone(), t.clone());
        }
        // transposed x: same element count, wrong geometry → rejected
        b.insert("x".into(), Tensor::F32 { shape: vec![6, 8], data: vec![0.0; 48] });
        let err = rt
            .execute_named("gcn_stagr_tiny", &b)
            .unwrap_err()
            .to_string();
        assert!(err.contains("shape"), "{err}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn shape_compat_rank_normalization() {
        assert!(shapes_compatible(&[5], &[1, 5]));
        assert!(shapes_compatible(&[1, 5], &[5]));
        assert!(shapes_compatible(&[2, 3], &[2, 3]));
        assert!(!shapes_compatible(&[3, 2], &[2, 3]));
        assert!(!shapes_compatible(&[5], &[5, 1]));
    }

    #[test]
    fn unknown_artifact_error_lists_options() {
        let (rt, dir) = tiny_runtime();
        let err = rt.artifact("nonexistent").unwrap_err().to_string();
        assert!(err.contains("unknown artifact"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn manifest_parses_and_lists_artifacts() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(&dir).unwrap();
        let names = rt.artifact_names();
        assert!(names.iter().any(|n| n.starts_with("gcn_stagr_cora")), "{names:?}");
        assert!(names.iter().any(|n| n.starts_with("gat_grax_cora")));
        let info = rt.artifact("gcn_stagr_cora").unwrap();
        assert_eq!(info.inputs[0], "norm");
        assert_eq!(info.shapes[0], vec![2708, 2708]);
    }

    #[test]
    fn real_artifacts_compile_to_plans() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(&dir).unwrap();
        for name in rt.artifact_names() {
            rt.load(name)
                .unwrap_or_else(|e| panic!("plan for {name}: {e:#}"));
        }
    }
}
