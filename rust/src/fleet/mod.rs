//! `fleet` — sharded multi-device serving of one logical graph.
//!
//! The single-leader [`crate::server`] owns one engine on one device; a
//! [`Fleet`] serves the same logical graph from **N shard workers**, each
//! pinned to a simulated device chosen by the paper's cost model:
//!
//! 1. **Placement** ([`placement`]): GraphSplit's
//!    communication-vs-compute cost model, lifted from ops to nodes —
//!    each device roster entry is probed with [`crate::npu::cost`] on the
//!    real model graph, shards are sized proportional to device speed,
//!    and cut points are refined by local search on
//!    `max_shard(compute + halo)`. Heterogeneous NPU/CPU/GPU placement
//!    falls out of the cost model, exactly as in the paper's §IV Step 1.
//! 2. **Halo exchange** ([`halo`]): every cut edge forces boundary-node
//!    features across the host link each round; the traffic is charged
//!    with the same `xfer_gbps`/`xfer_setup_us` parameters GraphSplit
//!    boundary crossings pay, and lands in per-shard metrics.
//! 3. **Shard workers** ([`shard`]): the old server leader loop,
//!    generalized — per-shard batching, admission control, panic-safe
//!    shutdown. The single-leader server is now the one-shard special
//!    case.
//! 4. **Routing** ([`router`]): queries go to the shard that owns the
//!    node; GrAd updates fan out over the same ordered channels, tracked
//!    by a version vector so convergence is checkable.
//!
//! ## Scaling model
//!
//! Per inference round, shard `s` costs
//! `owned(s) · rate(device_s) + link(halo_in(s) · features · dtype)`,
//! and the fleet's round latency is the max over shards. Compute shrinks
//! linearly with the shard count while halo traffic grows with the cut —
//! the planner's whole job is to stop cutting where the link cost
//! overtakes the compute win. `grannite fleet` and
//! `benches/fleet_scaling.rs` sweep this tradeoff 1→8 shards.

pub mod admission;
pub mod auto;
pub mod engine;
pub mod halo;
pub mod placement;
pub mod router;
pub mod shard;

pub use admission::{Admission, AdmissionConfig};
pub use auto::{AutoConfig, AutoEngine, Strategy};
pub use engine::{synthesize_weights, PlanEngine};
pub use halo::{build_halos, link_cost_us, HaloSpec};
pub use placement::{per_node_us, plan, FleetPlan, ShardSpec, Workload};
pub use router::Router;
pub use shard::{ShardConfig, ShardEvent, ShardWorker};

use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::HardwareConfig;
use crate::coordinator::ModelState;
use crate::graph::{datasets::Dataset, Graph};
use crate::metrics::Snapshot;
use crate::server::{InferenceEngine, QueryResponse, ServerConfig, Update};
use crate::tensor::Mat;

/// Fleet-level tuning: one shard per device roster entry.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub devices: Vec<HardwareConfig>,
    pub batch: ServerConfig,
    pub admission: AdmissionConfig,
    /// Stored bytes per feature element on the link (2 = FP16).
    pub dtype_bytes: usize,
    /// How plan-backed shards lower the aggregation
    /// (`--aggregation dense|sparse|auto`; auto resolves by density).
    pub aggregation: crate::ops::build::Aggregation,
    /// Deployment-wide telemetry hub, shared by every shard worker and
    /// the router (disabled by default — see [`crate::telemetry`]).
    pub telemetry: Arc<crate::telemetry::Telemetry>,
    /// Deployment-wide operational monitor: heartbeats, history rings,
    /// SLO evaluation, scrape endpoint (disabled by default — see
    /// [`crate::monitor`]).
    pub monitor: crate::monitor::Monitor,
}

impl FleetConfig {
    /// `n` identical Series-2 NPU shards (the clean scaling sweep).
    pub fn homogeneous(n: usize) -> FleetConfig {
        FleetConfig {
            devices: vec![HardwareConfig::npu_series2(); n.max(1)],
            batch: ServerConfig::default(),
            admission: AdmissionConfig::unbounded(),
            dtype_bytes: 2,
            aggregation: crate::ops::build::Aggregation::Auto,
            telemetry: crate::telemetry::Telemetry::disabled(),
            monitor: crate::monitor::Monitor::disabled(),
        }
    }

    /// `n` shards cycling the full device zoo (NPU2, NPU1, iGPU, CPU) —
    /// the heterogeneous placement the cost model exists for.
    pub fn heterogeneous(n: usize) -> FleetConfig {
        let zoo = [
            HardwareConfig::npu_series2(),
            HardwareConfig::npu_series1(),
            HardwareConfig::gpu(),
            HardwareConfig::cpu(),
        ];
        FleetConfig {
            devices: (0..n.max(1)).map(|i| zoo[i % zoo.len()].clone()).collect(),
            ..FleetConfig::homogeneous(1)
        }
    }

    /// Parse a device-name roster (`--devices series2,cpu,…`, spec
    /// topologies). Resolves through [`HardwareConfig::preset`] — the
    /// one name→device table — so an unknown name lists every valid
    /// option, prefixed with which roster entry was wrong.
    pub fn from_names(names: &[String]) -> Result<FleetConfig> {
        if names.is_empty() {
            anyhow::bail!(
                "device roster is empty — pick from: {}",
                HardwareConfig::preset_names().join(" | ")
            );
        }
        let mut devices = Vec::with_capacity(names.len());
        for (i, n) in names.iter().enumerate() {
            devices.push(
                HardwareConfig::preset(n)
                    .with_context(|| format!("device roster entry {i}"))?,
            );
        }
        Ok(FleetConfig { devices, ..FleetConfig::homogeneous(1) })
    }
}

/// A running fleet: plan + router + shard workers.
pub struct Fleet {
    pub plan: FleetPlan,
    router: Router,
    telemetry: Arc<crate::telemetry::Telemetry>,
    monitor: crate::monitor::Monitor,
}

impl Fleet {
    /// Plan the placement for a workload without spawning anything.
    pub fn plan_for(graph: &Graph, capacity: usize, features: usize,
                    classes: usize, cfg: &FleetConfig) -> Result<FleetPlan> {
        let w = Workload {
            capacity,
            features,
            classes,
            dtype_bytes: cfg.dtype_bytes,
        };
        plan(graph, &w, &cfg.devices)
    }

    /// Spawn one worker per shard of `plan`. `make` builds, per shard, a
    /// factory that will run *inside* that shard's thread (PJRT handles
    /// are not `Send`, same contract as [`crate::server::ServerHandle`]).
    pub fn spawn<E, M>(plan: FleetPlan, graph: &Graph, features: usize,
                       cfg: &FleetConfig, mut make: M) -> Fleet
    where
        E: InferenceEngine,
        M: FnMut(&ShardSpec) -> Box<dyn FnOnce() -> Result<E> + Send>,
    {
        let halos = build_halos(&plan, graph, features, cfg.dtype_bytes);
        let mut workers = Vec::with_capacity(plan.num_shards());
        for (spec, halo) in plan.shards.iter().zip(halos) {
            let factory = make(spec);
            workers.push(ShardWorker::spawn(
                spec.id,
                factory,
                ShardConfig {
                    batch: cfg.batch.clone(),
                    admission: cfg.admission,
                    halo: Some(halo),
                    telemetry: Arc::clone(&cfg.telemetry),
                    monitor: cfg.monitor.clone(),
                },
            ));
        }
        let mut router = Router::new(plan.owner.clone(), workers);
        router.set_recorder(
            cfg.telemetry.recorder(crate::telemetry::ROUTER_SHARD),
        );
        Fleet {
            plan,
            router,
            telemetry: Arc::clone(&cfg.telemetry),
            monitor: cfg.monitor.clone(),
        }
    }

    pub fn update(&self, u: Update) -> Result<()> {
        self.router.update(u)
    }

    pub fn query(&self, node: Option<usize>)
                 -> Result<Receiver<Result<QueryResponse, String>>> {
        self.router.query(node)
    }

    /// Barrier all shards; returns the applied version vector.
    pub fn sync(&self) -> Result<Vec<u64>> {
        self.router.sync()
    }

    pub fn expected_versions(&self) -> Vec<u64> {
        self.router.expected_versions()
    }

    pub fn applied_versions(&self) -> Vec<u64> {
        self.router.applied_versions()
    }

    /// Exact fleet-wide metrics (raw samples merged across shards).
    pub fn metrics(&self) -> Snapshot {
        self.router.metrics()
    }

    /// Per-shard labeled snapshots.
    pub fn shard_metrics(&self) -> Vec<Snapshot> {
        self.router.shard_metrics()
    }

    pub fn num_shards(&self) -> usize {
        self.router.num_shards()
    }

    pub fn shutdown(self) -> Result<()> {
        let result = self.router.shutdown();
        if result.is_err() && self.monitor.enabled() {
            // a worker died abnormally: dump the flight recorder so the
            // breadcrumbs survive the process
            eprintln!("{}", self.monitor.post_mortem());
        }
        self.monitor.stop();
        result
    }
}

/// The sharded topology behind the unified serving API: everything
/// delegates to the router, and blocking waits come from the trait's
/// provided methods ([`crate::serve::Serving::query_wait`],
/// [`crate::serve::Serving::query_deadline`]).
impl crate::serve::Serving for Fleet {
    fn update(&self, u: Update) -> Result<()> {
        self.router.update(u)
    }

    fn query(&self, node: Option<usize>)
             -> Result<Receiver<Result<QueryResponse, String>>> {
        self.router.query(node)
    }

    fn sync(&self) -> Result<Vec<u64>> {
        self.router.sync()
    }

    fn metrics(&self) -> Snapshot {
        self.router.metrics()
    }

    fn shard_metrics(&self) -> Vec<Snapshot> {
        self.router.shard_metrics()
    }

    fn num_shards(&self) -> usize {
        self.router.num_shards()
    }

    fn record_shed(&self, node: Option<usize>) {
        self.router.record_shed(node);
    }

    fn telemetry(&self) -> Option<Arc<crate::telemetry::Telemetry>> {
        Some(Arc::clone(&self.telemetry))
    }

    fn monitor(&self) -> Option<crate::monitor::Monitor> {
        if self.monitor.enabled() {
            Some(self.monitor.clone())
        } else {
            None
        }
    }

    fn shutdown(self: Box<Self>) -> Result<()> {
        Fleet::shutdown(*self)
    }
}

/// A deterministic, artifact-free inference engine: neighbor label
/// voting over the live GrAd graph. Each shard holds a full structural
/// replica (updates fan out; masks are cheap) but only computes logits
/// for its *owned* nodes — which is what makes per-shard work shrink as
/// the fleet grows, and what the halo exchange pays for on real
/// hardware. Predictions depend only on graph structure + labels, so a
/// 1-shard fleet, an N-shard fleet, and the single-leader server agree
/// exactly on every owned answer.
pub struct LocalEngine {
    state: ModelState,
    labels: Vec<i32>,
    classes: usize,
    owned: std::ops::Range<usize>,
    /// Memoized live halo-import count; only structure updates change
    /// it, so [`Self::apply`] invalidates and the per-round query in the
    /// shard hot loop is O(1) between updates.
    halo_cache: std::cell::Cell<Option<usize>>,
}

impl LocalEngine {
    /// Engine answering for `owned` only (a fleet shard).
    pub fn shard(ds: &Dataset, capacity: usize, owned: std::ops::Range<usize>)
                 -> Result<LocalEngine> {
        let labels = ds.labels.clone();
        let classes = ds.num_classes().max(2);
        let state = ModelState::from_dataset(ds.clone(), capacity)?;
        Ok(LocalEngine {
            state,
            labels,
            classes,
            owned,
            halo_cache: std::cell::Cell::new(None),
        })
    }

    /// Engine answering for every node (the single-leader server).
    pub fn full(ds: &Dataset, capacity: usize) -> Result<LocalEngine> {
        let owned = 0..capacity.max(ds.num_nodes());
        LocalEngine::shard(ds, capacity, owned)
    }

    fn label_of(&self, node: usize) -> i32 {
        self.labels
            .get(node)
            .copied()
            .unwrap_or((node % self.classes) as i32)
    }
}

impl InferenceEngine for LocalEngine {
    fn apply(&mut self, update: &Update) -> Result<u64> {
        match update {
            Update::AddEdge(u, v) => {
                self.state.add_edge(*u, *v)?;
            }
            Update::RemoveEdge(u, v) => {
                self.state.remove_edge(*u, *v)?;
            }
            Update::AddNode => {
                self.state.add_node()?;
            }
        }
        self.halo_cache.set(None);
        Ok(self.state.graph_version())
    }

    fn infer(&mut self) -> Result<Mat> {
        // O(owned · degree) via the dynamic graph's live neighbor sets —
        // no per-round snapshot, so per-shard work genuinely shrinks as
        // the fleet grows
        let n = self.state.num_active_nodes();
        let mut logits = Mat::zeros(n, self.classes);
        for i in self.owned.start.min(n)..self.owned.end.min(n) {
            // self vote (weight 2) keeps isolated nodes deterministic
            let own = self.label_of(i) as usize % self.classes;
            logits[(i, own)] += 2.0;
            for &j in self.state.neighbors(i) {
                let c = self.label_of(j as usize) as usize % self.classes;
                logits[(i, c)] += 1.0;
            }
        }
        Ok(logits)
    }

    fn num_nodes(&self) -> usize {
        self.state.num_active_nodes()
    }

    /// Live halo imports: distinct non-owned neighbors of the owned
    /// active range, so the shard worker's halo accounting tracks GrAd
    /// churn instead of the spawn-time cut. Memoized between updates —
    /// the hot loop asks every round.
    fn halo_imports(&self) -> Option<usize> {
        if let Some(cached) = self.halo_cache.get() {
            return Some(cached);
        }
        let n = self.state.num_active_nodes();
        let mut imports = std::collections::BTreeSet::new();
        for i in self.owned.start.min(n)..self.owned.end.min(n) {
            for &j in self.state.neighbors(i) {
                if !self.owned.contains(&(j as usize)) {
                    imports.insert(j);
                }
            }
        }
        self.halo_cache.set(Some(imports.len()));
        Some(imports.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::synthesize;
    use crate::serve::{
        DataSource, Deployment, DeploymentSpec, EngineSpec, Serving, Topology,
    };
    use crate::server::ServerHandle;

    fn twin() -> Dataset {
        synthesize("fleet-eq", 60, 150, 4, 12, 17)
    }

    fn spec_for(engine: &str, topology: Topology, capacity: usize) -> DeploymentSpec {
        DeploymentSpec {
            engine: EngineSpec::named(engine),
            topology,
            capacity,
            ..DeploymentSpec::default()
        }
    }

    /// The same GrAd churn applied through any serving front end.
    fn churn(mut apply: impl FnMut(Update)) {
        for i in 0..10 {
            apply(Update::AddEdge(i, (i + 7) % 60));
        }
        apply(Update::RemoveEdge(0, 7));
        apply(Update::AddNode);
        apply(Update::AddEdge(60, 3));
    }

    fn predictions_via_server(ds: &Dataset) -> Vec<i32> {
        let ds2 = ds.clone();
        let server = ServerHandle::spawn(
            move || LocalEngine::full(&ds2, 64),
            ServerConfig::default(),
        );
        churn(|u| server.update(u).unwrap());
        let preds: Vec<i32> = (0..61)
            .map(|n| server.query_wait(Some(n)).unwrap().prediction)
            .collect();
        server.shutdown().unwrap();
        preds
    }

    fn predictions_via_launch(ds: &Dataset, topology: Topology) -> Vec<i32> {
        let spec = spec_for("local", topology, 64);
        let serving =
            Deployment::launch(&spec, &DataSource::Dataset(ds.clone())).unwrap();
        churn(|u| serving.update(u).unwrap());
        let preds: Vec<i32> = (0..61)
            .map(|n| serving.query_wait(Some(n)).unwrap().prediction)
            .collect();
        serving.shutdown().unwrap();
        preds
    }

    #[test]
    fn single_shard_launch_reproduces_the_server() {
        let ds = twin();
        let server = predictions_via_server(&ds);
        let launched = predictions_via_launch(&ds, Topology::homogeneous(1));
        assert_eq!(server, launched, "shards = 1 must equal the old server");
    }

    #[test]
    fn sharded_fleet_reproduces_the_server() {
        let ds = twin();
        let server = predictions_via_server(&ds);
        for shards in [2, 4] {
            let fleet = predictions_via_launch(&ds, Topology::zoo(shards));
            assert_eq!(
                server, fleet,
                "{shards}-shard fleet must agree with the single leader"
            );
        }
    }

    #[test]
    fn heterogeneous_fleet_uses_distinct_device_kinds() {
        let ds = twin();
        let spec = spec_for("local", Topology::zoo(4), 64);
        let plan = Deployment::plan(&spec, &ds).unwrap();
        let kinds: std::collections::BTreeSet<String> = plan
            .shards
            .iter()
            .map(|s| s.device.kind.to_string())
            .collect();
        assert!(kinds.len() >= 2, "expected ≥2 device kinds, got {kinds:?}");
        let fleet =
            Deployment::launch(&spec, &DataSource::Dataset(ds.clone())).unwrap();
        // drive a little traffic so halo accounting fires
        churn(|u| fleet.update(u).unwrap());
        for n in (0..60).step_by(5) {
            let _ = fleet.query_wait(Some(n)).unwrap();
        }
        let snap = fleet.metrics();
        assert!(snap.queries >= 12);
        assert!(
            snap.halo_bytes > 0,
            "multi-shard serving must report halo traffic"
        );
        fleet.shutdown().unwrap();
    }

    #[test]
    fn live_halo_matches_plan_at_spawn() {
        // the boundary-import count is derived three ways — the planner
        // (halo_counts over contiguous ranges), the halo schedule
        // (build_halos over the edge list), and the live engine
        // (halo_imports over the dynamic neighbor sets). Before any
        // churn they must all agree, per shard.
        let ds = twin();
        let cfg = FleetConfig::homogeneous(3);
        let plan = Fleet::plan_for(&ds.graph, 64, ds.num_features(),
                                   ds.num_classes(), &cfg)
            .unwrap();
        let halos = build_halos(&plan, &ds.graph, ds.num_features(),
                                cfg.dtype_bytes);
        for (spec, halo) in plan.shards.iter().zip(&halos) {
            assert_eq!(
                halo.num_imported(),
                spec.halo_in,
                "schedule vs plan, shard {}",
                spec.id
            );
            let eng = LocalEngine::shard(&ds, 64, spec.nodes.clone()).unwrap();
            assert_eq!(
                eng.halo_imports(),
                Some(spec.halo_in),
                "live vs plan, shard {}",
                spec.id
            );
        }
    }

    #[test]
    fn version_vector_converges_under_churn() {
        // router internals (expected vs applied) need a concrete Fleet —
        // built from the same registry shard factory the launcher uses
        let ds = twin();
        let cfg = FleetConfig::homogeneous(3);
        let plan = Fleet::plan_for(&ds.graph, 64, ds.num_features(),
                                   ds.num_classes(), &cfg)
            .unwrap();
        let make = crate::serve::registry::local_shards(&ds, 64);
        let fleet = Fleet::spawn(plan, &ds.graph, ds.num_features(), &cfg, make);
        churn(|u| fleet.update(u).unwrap());
        let applied = fleet.sync().unwrap();
        assert_eq!(applied, fleet.expected_versions());
        assert!(applied.iter().all(|&v| v == 13), "{applied:?}");
        fleet.shutdown().unwrap();
    }

    #[test]
    fn planned_fleet_predictions_are_shard_count_invariant() {
        // the plan-backed engines must agree across fleet sizes exactly
        // like LocalEngine does — same plan, same synthesized weights
        let ds = synthesize("plan-fleet", 40, 90, 4, 10, 23);
        let mut reference: Option<Vec<i32>> = None;
        for shards in [1usize, 3] {
            let spec = spec_for("plan", Topology::homogeneous(shards), 48);
            let fleet =
                Deployment::launch(&spec, &DataSource::Dataset(ds.clone())).unwrap();
            fleet.update(Update::AddEdge(0, 11)).unwrap();
            fleet.update(Update::AddNode).unwrap();
            let preds: Vec<i32> = (0..41)
                .map(|n| fleet.query_wait(Some(n)).unwrap().prediction)
                .collect();
            match &reference {
                None => reference = Some(preds),
                Some(r) => assert_eq!(r, &preds, "{shards}-shard fleet diverged"),
            }
            fleet.shutdown().unwrap();
        }
    }

    #[test]
    fn add_node_is_owned_and_answerable() {
        let ds = twin();
        let spec = spec_for("local", Topology::homogeneous(2), 64);
        let plan = Deployment::plan(&spec, &ds).unwrap();
        let fleet =
            Deployment::launch(&spec, &DataSource::Dataset(ds.clone())).unwrap();
        // node 60 is inactive until AddNode lands
        let err = fleet.query_wait(Some(60)).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        fleet.update(Update::AddNode).unwrap();
        let r = fleet.query_wait(Some(60)).unwrap();
        assert_eq!(r.shard, plan.owner[60]);
        fleet.shutdown().unwrap();
    }
}
