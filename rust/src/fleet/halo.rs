//! Halo exchange: the explicit boundary-feature traffic between shards.
//!
//! A node owned by shard A whose neighbor lives on shard B cannot be
//! aggregated without B's feature row — partition-parallel GNN execution
//! always ships a one-hop "halo" ring of boundary features each round
//! (EnGN and the Abadal et al. survey both charge this traffic
//! explicitly; so do we). The exchange is charged against the *host
//! link* of the importing shard's device — the same `xfer_gbps` /
//! `xfer_setup_us` parameters GraphSplit boundary crossings pay in
//! [`crate::npu::cost`] — and recorded per shard in
//! [`crate::metrics::Metrics`] (`halo_bytes`, `halo_us`) so benches can
//! report exactly how much of the fleet's round time is communication.

use std::collections::BTreeMap;

use crate::config::HardwareConfig;
use crate::graph::Graph;

use super::placement::FleetPlan;

/// One shard's halo-exchange schedule, built at plan time. The
/// `bytes_per_round`/`link_us_per_round` pair is the *planned* charge;
/// when the engine can report its live import count
/// ([`crate::server::InferenceEngine::halo_imports`]), the shard worker
/// recosts each round from `bytes_per_import` and the link parameters so
/// the accounting follows GrAd churn instead of the spawn-time cut.
#[derive(Debug, Clone)]
pub struct HaloSpec {
    pub shard: usize,
    /// peer shard → node ids whose features this shard imports from it.
    pub imports: BTreeMap<usize, Vec<usize>>,
    /// peer shard → owned node ids that peer imports from this shard.
    pub exports: BTreeMap<usize, Vec<usize>>,
    /// Feature bytes this shard pulls over the link per inference round
    /// (plan-time estimate).
    pub bytes_per_round: usize,
    /// Simulated link time for those bytes on this shard's device (µs).
    pub link_us_per_round: f64,
    /// Link payload per imported node (features × dtype bytes).
    pub bytes_per_import: usize,
    /// Per-crossing link setup (0 for host shards — shared memory).
    pub xfer_setup_us: f64,
    /// Link time per byte (0 for host shards).
    pub us_per_byte: f64,
}

impl HaloSpec {
    /// A shard with no boundary (single-shard fleets, isolated ranges).
    pub fn empty(shard: usize) -> HaloSpec {
        HaloSpec {
            shard,
            imports: BTreeMap::new(),
            exports: BTreeMap::new(),
            bytes_per_round: 0,
            link_us_per_round: 0.0,
            bytes_per_import: 0,
            xfer_setup_us: 0.0,
            us_per_byte: 0.0,
        }
    }

    /// Link cost of shipping `bytes` this round (0 for an empty round).
    pub fn cost_us(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.xfer_setup_us + bytes as f64 * self.us_per_byte
        }
    }

    /// Total import slots across peers. Imports are unique per shard
    /// (a node is pulled once no matter how many local consumers), so
    /// this equals the distinct boundary nodes this shard pays for.
    pub fn num_imported(&self) -> usize {
        self.imports.values().map(Vec::len).sum()
    }

    /// Total export *transmissions*: a node shipped to two peers counts
    /// twice (each peer's pull is a separate transfer). This can exceed
    /// [`crate::fleet::ShardSpec::halo_out`], which counts the distinct
    /// owned boundary nodes.
    pub fn num_exported(&self) -> usize {
        self.exports.values().map(Vec::len).sum()
    }
}

/// Host-link cost of moving `bytes` onto `hw`: the GraphSplit boundary
/// formula (`setup + bytes / bandwidth`). Zero bytes cost nothing — no
/// fence is issued for an empty exchange. A CPU shard imports for free
/// (`xfer_gbps = ∞`): it *is* the host, shared memory is its link.
pub fn link_cost_us(hw: &HardwareConfig, bytes: usize) -> f64 {
    if bytes == 0 || hw.xfer_gbps.is_infinite() {
        return 0.0;
    }
    hw.xfer_setup_us + bytes as f64 / (hw.xfer_gbps * 1e3)
}

/// Build every shard's halo schedule from the plan and the graph.
/// `features × dtype_bytes` is the per-node payload on the link.
pub fn build_halos(plan: &FleetPlan, graph: &Graph, features: usize,
                   dtype_bytes: usize) -> Vec<HaloSpec> {
    let k = plan.num_shards();
    let mut specs: Vec<HaloSpec> = (0..k).map(HaloSpec::empty).collect();
    // collect unique (importer, owner, node) triples via sorted sets
    let mut import_sets: Vec<BTreeMap<usize, std::collections::BTreeSet<usize>>> =
        vec![BTreeMap::new(); k];
    for &(u, v) in graph.edges() {
        let (u, v) = (u as usize, v as usize);
        let (su, sv) = (plan.owner[u], plan.owner[v]);
        if su == sv {
            continue;
        }
        // undirected edge: each side imports the other's feature row
        import_sets[su].entry(sv).or_default().insert(v);
        import_sets[sv].entry(su).or_default().insert(u);
    }
    for (s, sets) in import_sets.into_iter().enumerate() {
        let mut total = 0usize;
        for (peer, nodes) in sets {
            let nodes: Vec<usize> = nodes.into_iter().collect();
            total += nodes.len();
            specs[peer].exports.insert(s, nodes.clone());
            specs[s].imports.insert(peer, nodes);
        }
        let device = &plan.shards[s].device;
        specs[s].bytes_per_import = features * dtype_bytes;
        if !device.xfer_gbps.is_infinite() {
            specs[s].xfer_setup_us = device.xfer_setup_us;
            specs[s].us_per_byte = 1.0 / (device.xfer_gbps * 1e3);
        }
        specs[s].bytes_per_round = total * features * dtype_bytes;
        specs[s].link_us_per_round = link_cost_us(device, specs[s].bytes_per_round);
    }
    specs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::placement::{plan, Workload};
    use crate::graph::datasets::synthesize;

    #[test]
    fn link_cost_formula() {
        let hw = HardwareConfig::npu_series2();
        assert_eq!(link_cost_us(&hw, 0), 0.0);
        let c = link_cost_us(&hw, 40_000);
        // setup 12µs + 40_000 B / (40 GB/s → 40_000 B/µs) = 13µs
        assert!((c - (hw.xfer_setup_us + 1.0)).abs() < 1e-9, "{c}");
        let cpu = HardwareConfig::cpu();
        assert_eq!(link_cost_us(&cpu, 1 << 20), 0.0, "host imports are free");
    }

    #[test]
    fn path_graph_two_shards_exchange_one_pair() {
        // 0-1-2-3 split as {0,1} | {2,3}: the cut edge (1,2) means shard 0
        // imports node 2 and shard 1 imports node 1.
        let g = Graph::new(4, &[(0, 1), (1, 2), (2, 3)]);
        let devices = vec![HardwareConfig::npu_series2(); 2];
        let w = Workload { capacity: 4, features: 8, classes: 2, dtype_bytes: 2 };
        let mut p = plan(&g, &w, &devices).unwrap();
        // force the symmetric split regardless of what local search chose
        p.owner = vec![0, 0, 1, 1];
        p.shards[0].nodes = 0..2;
        p.shards[1].nodes = 2..4;
        let halos = build_halos(&p, &g, w.features, w.dtype_bytes);
        assert_eq!(halos[0].imports[&1], vec![2]);
        assert_eq!(halos[1].imports[&0], vec![1]);
        assert_eq!(halos[0].exports[&1], vec![1]);
        assert_eq!(halos[0].bytes_per_round, 8 * 2);
        assert!(halos[0].link_us_per_round > 0.0);
    }

    #[test]
    fn imports_and_exports_are_symmetric() {
        let ds = synthesize("h", 300, 1200, 4, 16, 21);
        let devices = vec![HardwareConfig::npu_series2(); 3];
        let w = Workload { capacity: 300, features: 16, classes: 4, dtype_bytes: 2 };
        let p = plan(&ds.graph, &w, &devices).unwrap();
        let halos = build_halos(&p, &ds.graph, w.features, w.dtype_bytes);
        for h in &halos {
            for (&peer, nodes) in &h.imports {
                // everything I import from you, you export to me
                assert_eq!(halos[peer].exports[&h.shard], *nodes);
                // and you own it
                for &n in nodes {
                    assert_eq!(p.owner[n], peer);
                }
            }
        }
        let total_imports: usize = halos.iter().map(|h| h.num_imported()).sum();
        let total_exports: usize = halos.iter().map(|h| h.num_exported()).sum();
        assert_eq!(total_imports, total_exports);
        assert!(total_imports > 0, "3 shards on a connected graph must cut");
    }

    #[test]
    fn single_shard_halo_is_empty() {
        let ds = synthesize("h1", 50, 150, 3, 8, 2);
        let w = Workload { capacity: 50, features: 8, classes: 3, dtype_bytes: 2 };
        let p = plan(&ds.graph, &w, &[HardwareConfig::npu_series2()]).unwrap();
        let halos = build_halos(&p, &ds.graph, 8, 2);
        assert_eq!(halos.len(), 1);
        assert_eq!(halos[0].bytes_per_round, 0);
        assert_eq!(halos[0].link_us_per_round, 0.0);
    }
}
