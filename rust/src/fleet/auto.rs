//! The runtime-adaptive `auto` engine: one shard engine that carries
//! **both** offline strategies — the full planned recompute
//! ([`PlanEngine`]) and the delta-driven frontier path
//! ([`IncrementalEngine`]) — and switches between them from observed
//! telemetry instead of a launch-time guess.
//!
//! The paper's own results motivate this: which Step-2 technique wins
//! flips with the workload. Low churn makes the incremental frontier a
//! tiny fraction of the graph (recompute `O(|dirty|)` instead of
//! `O(|V|)`); churn-dominated streams pay the frontier bookkeeping for
//! nothing and want the straight-line plan; and a graph whose live
//! density crosses the sparse/dense line stops benefiting from frontier
//! gathers entirely. The signals:
//!
//! - **churn rate** — GrAd updates per inference round, smoothed with an
//!   EWMA so one quiet round inside a burst doesn't read as a regime
//!   change;
//! - **live density** — [`PlanEngine::live_density`], the same
//!   `(2·edges + nodes)/capacity²` the plan builders resolve
//!   [`Aggregation::Auto`](crate::ops::build::Aggregation) against;
//! - **queue depth** — the shard worker's backlog, delivered through
//!   [`InferenceEngine::note_queue_depth`].
//!
//! Switching is damped twice so the engine never flaps: a **hysteresis
//! band** (`hysteresis_low` ≤ dead band ≤ `hysteresis_high`, from the
//! spec's `[tuning]` section) and a **cooldown** of at least
//! `cooldown_rounds` rounds between switches. A deep queue waives the
//! cooldown — a backlog is proof the current strategy is not keeping up,
//! and waiting out the cooldown just grows it. An active SLO breach
//! rides the same hook: the shard loop adds
//! [`SLO_PRESSURE_BOOST`](crate::monitor::SLO_PRESSURE_BOOST) to the
//! reported depth while the monitor's breach flag is up (`[slo]`
//! `pressure = true`), so a burning error budget reads as a maximally
//! deep queue and the engine may react immediately.
//!
//! Both inner engines see every update (applies are cheap mask/frontier
//! bookkeeping; inference is what costs), so a switch needs no state
//! migration: the plan engine rebinds its mask on the next round, the
//! incremental engine's accumulated frontier is exactly the recompute it
//! owes. Both synthesize the same deterministic weights
//! ([`synthesize_weights`](crate::fleet::engine::synthesize_weights)),
//! so answers are strategy-independent — property-tested at every switch
//! point in this module's tests, and end to end (serving topologies,
//! metrics gauges) in `rust/tests/auto_tune.rs`.

use anyhow::Result;

use crate::incremental::IncrementalEngine;
use crate::metrics::RoundStats;
use crate::ops::build::SPMM_DENSITY_THRESHOLD;
use crate::server::{InferenceEngine, Update};
use crate::tensor::Mat;

use super::engine::PlanEngine;

/// EWMA weight of the newest round's mutation count (0.5 halves the
/// influence of each older round — bursts register within ~2 rounds,
/// single outlier rounds don't).
const CHURN_EWMA_ALPHA: f64 = 0.5;

/// Which inner strategy the `auto` engine is executing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Full planned recompute every round ([`PlanEngine`]).
    Plan,
    /// Delta-driven frontier recompute ([`IncrementalEngine`]).
    Incremental,
}

impl Strategy {
    /// The [`RoundStats::active_strategy`] gauge code.
    pub fn code(self) -> u8 {
        match self {
            Strategy::Plan => RoundStats::STRATEGY_PLAN,
            Strategy::Incremental => RoundStats::STRATEGY_INCREMENTAL,
        }
    }

    fn other(self) -> Strategy {
        match self {
            Strategy::Plan => Strategy::Incremental,
            Strategy::Incremental => Strategy::Plan,
        }
    }
}

/// Switching policy for the [`AutoEngine`] (lowered from the deployment
/// spec's `[tuning]` section by the `auto` engine factory).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoConfig {
    /// Smoothed mutations-per-round at or below which the incremental
    /// strategy is preferred.
    pub hysteresis_low: f64,
    /// Smoothed mutations-per-round at or above which the planned full
    /// recompute is preferred; the gap to `hysteresis_low` is the dead
    /// band where the current strategy is kept.
    pub hysteresis_high: f64,
    /// Minimum inference rounds between two switches.
    pub cooldown_rounds: usize,
    /// Queue backlog at which the cooldown is waived (the shard is
    /// demonstrably behind; react now).
    pub queue_pressure: usize,
}

impl Default for AutoConfig {
    fn default() -> Self {
        let t = crate::serve::spec::TuningSpec::default();
        AutoConfig {
            hysteresis_low: t.hysteresis_low,
            hysteresis_high: t.hysteresis_high,
            cooldown_rounds: t.cooldown_rounds,
            queue_pressure: 8,
        }
    }
}

impl AutoConfig {
    /// The switching policy a `[tuning]` section describes.
    pub fn from_tuning(t: &crate::serve::spec::TuningSpec) -> AutoConfig {
        AutoConfig {
            hysteresis_low: t.hysteresis_low,
            hysteresis_high: t.hysteresis_high,
            cooldown_rounds: t.cooldown_rounds,
            ..AutoConfig::default()
        }
    }
}

/// The adaptive engine. See the module docs for the switching model.
pub struct AutoEngine {
    plan: PlanEngine,
    incremental: IncrementalEngine,
    cfg: AutoConfig,
    active: Strategy,
    /// GrAd updates applied since the last inference round.
    updates_since_round: usize,
    /// EWMA of mutations per round (the smoothed churn signal).
    churn_ewma: f64,
    rounds_since_switch: usize,
    queue_depth: usize,
    /// Switches performed since the last `round_stats` drain.
    pending_switches: usize,
    total_switches: usize,
    last_stats: Option<RoundStats>,
}

impl AutoEngine {
    /// Wrap two pre-built inner engines (the factory path: the plan is
    /// compiled once per launch and shared across shards).
    pub fn from_engines(
        plan: PlanEngine,
        incremental: IncrementalEngine,
        cfg: AutoConfig,
    ) -> AutoEngine {
        AutoEngine {
            plan,
            incremental,
            cfg,
            // churn starts at 0 — below the band — so serving opens on
            // the incremental path and earns the plan path with churn
            active: Strategy::Incremental,
            updates_since_round: 0,
            churn_ewma: 0.0,
            // no switch debt at launch: a burst in the very first rounds
            // may switch immediately
            rounds_since_switch: cfg.cooldown_rounds,
            queue_depth: 0,
            pending_switches: 0,
            total_switches: 0,
            last_stats: None,
        }
    }

    /// Shard engine over `ds` at `capacity`, answering for `owned` only;
    /// compiles its own plan (fleets share one compile through the
    /// registry's `auto` factory instead).
    pub fn shard(
        ds: &crate::graph::datasets::Dataset,
        capacity: usize,
        owned: std::ops::Range<usize>,
        pool: std::sync::Arc<crate::engine::WorkerPool>,
        inc_cfg: crate::incremental::IncrementalConfig,
        cfg: AutoConfig,
    ) -> Result<AutoEngine> {
        let plan =
            PlanEngine::shard(ds, capacity, owned.clone(), std::sync::Arc::clone(&pool))?;
        let incremental = IncrementalEngine::shard(ds, capacity, owned, pool, inc_cfg)?;
        Ok(AutoEngine::from_engines(plan, incremental, cfg))
    }

    /// Engine answering for every node (the single-leader server).
    pub fn full(
        ds: &crate::graph::datasets::Dataset,
        capacity: usize,
        pool: std::sync::Arc<crate::engine::WorkerPool>,
        inc_cfg: crate::incremental::IncrementalConfig,
        cfg: AutoConfig,
    ) -> Result<AutoEngine> {
        let capacity = capacity.max(ds.num_nodes());
        AutoEngine::shard(ds, capacity, 0..capacity, pool, inc_cfg, cfg)
    }

    /// The strategy the next round will execute (before any pending
    /// re-decision).
    pub fn active_strategy(&self) -> Strategy {
        self.active
    }

    /// Strategy switches performed over this engine's lifetime.
    pub fn total_switches(&self) -> usize {
        self.total_switches
    }

    /// The smoothed churn signal (mutations per round, EWMA).
    pub fn churn_signal(&self) -> f64 {
        self.churn_ewma
    }

    /// Re-decide the active strategy from the smoothed churn, the live
    /// density, and the queue backlog. Called at the top of every
    /// inference round.
    fn decide(&mut self) {
        let churn = self.updates_since_round as f64;
        self.churn_ewma =
            CHURN_EWMA_ALPHA * churn + (1.0 - CHURN_EWMA_ALPHA) * self.churn_ewma;
        // past the sparse/dense crossover the frontier covers most of the
        // graph every round — delta bookkeeping cannot pay for itself,
        // whatever the churn rate says
        let want = if self.plan.live_density() >= SPMM_DENSITY_THRESHOLD {
            Strategy::Plan
        } else if self.churn_ewma >= self.cfg.hysteresis_high {
            Strategy::Plan
        } else if self.churn_ewma <= self.cfg.hysteresis_low {
            Strategy::Incremental
        } else {
            self.active // dead band: keep what runs
        };
        let cooldown_over = self.rounds_since_switch >= self.cfg.cooldown_rounds
            || self.queue_depth >= self.cfg.queue_pressure;
        if want != self.active && cooldown_over {
            debug_assert_eq!(want, self.active.other());
            self.active = want;
            self.pending_switches += 1;
            self.total_switches += 1;
            self.rounds_since_switch = 0;
        }
    }
}

impl InferenceEngine for AutoEngine {
    /// Both inner engines see every update, so a later switch needs no
    /// state migration. Both validate against the same
    /// [`crate::coordinator::ModelState`] rules at the same capacity, so
    /// they accept and reject identically; the planned engine applies
    /// first and an error there leaves the incremental engine untouched.
    fn apply(&mut self, update: &Update) -> Result<u64> {
        let v = self.plan.apply(update)?;
        self.incremental.apply(update)?;
        self.updates_since_round += 1;
        Ok(v)
    }

    fn infer(&mut self) -> Result<Mat> {
        self.decide();
        self.updates_since_round = 0;
        let out = match self.active {
            Strategy::Plan => self.plan.infer()?,
            Strategy::Incremental => self.incremental.infer()?,
        };
        self.rounds_since_switch = self.rounds_since_switch.saturating_add(1);
        // the inactive engine's stale accounting must not leak into a
        // later round's stats when strategies swap
        let inner = match self.active {
            Strategy::Plan => {
                let _ = self.incremental.round_stats();
                self.plan.round_stats()
            }
            Strategy::Incremental => {
                let _ = self.plan.round_stats();
                self.incremental.round_stats()
            }
        };
        let mut stats = inner.unwrap_or_default();
        stats.engine_switches = std::mem::take(&mut self.pending_switches);
        stats.active_strategy = self.active.code();
        self.last_stats = Some(stats);
        Ok(out)
    }

    fn num_nodes(&self) -> usize {
        self.plan.num_nodes()
    }

    fn halo_imports(&self) -> Option<usize> {
        match self.active {
            Strategy::Plan => self.plan.halo_imports(),
            Strategy::Incremental => self.incremental.halo_imports(),
        }
    }

    fn round_stats(&mut self) -> Option<RoundStats> {
        self.last_stats.take()
    }

    fn attach_telemetry(
        &mut self,
        telemetry: &std::sync::Arc<crate::telemetry::Telemetry>,
        shard: usize,
    ) {
        // only the active strategy executes a round, so profiling both
        // never double-counts a step
        self.plan.attach_telemetry(telemetry, shard);
        self.incremental.attach_telemetry(telemetry, shard);
    }

    fn note_queue_depth(&mut self, pending: usize) {
        self.queue_depth = pending;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::WorkerPool;
    use crate::graph::datasets::synthesize;
    use crate::incremental::IncrementalConfig;
    use std::sync::Arc;

    fn engine(cfg: AutoConfig) -> AutoEngine {
        let ds = synthesize("auto-engine", 40, 90, 4, 12, 7);
        AutoEngine::full(
            &ds,
            48,
            Arc::new(WorkerPool::serial()),
            IncrementalConfig::default(),
            cfg,
        )
        .unwrap()
    }

    fn tight() -> AutoConfig {
        AutoConfig {
            hysteresis_low: 1.0,
            hysteresis_high: 4.0,
            cooldown_rounds: 2,
            queue_pressure: 8,
        }
    }

    #[test]
    fn opens_incremental_and_switches_under_burst() {
        let mut e = engine(tight());
        assert_eq!(e.active_strategy(), Strategy::Incremental);
        let _ = e.infer().unwrap();
        // a churn burst: 10 mutations before the next round
        for i in 0..10 {
            e.apply(&Update::AddEdge(i % 40, (i * 7 + 1) % 40)).unwrap();
        }
        let _ = e.infer().unwrap();
        assert_eq!(e.active_strategy(), Strategy::Plan, "burst must switch");
        let rs = InferenceEngine::round_stats(&mut e).unwrap();
        assert_eq!(rs.engine_switches, 1);
        assert_eq!(rs.active_strategy, RoundStats::STRATEGY_PLAN);
        assert_eq!(e.total_switches(), 1);
    }

    #[test]
    fn cooldown_and_dead_band_prevent_flapping() {
        let mut e = engine(tight());
        for i in 0..10 {
            e.apply(&Update::AddEdge(i % 40, (i * 7 + 1) % 40)).unwrap();
        }
        let _ = e.infer().unwrap();
        assert_eq!(e.active_strategy(), Strategy::Plan);
        // quiet rounds: the EWMA decays (5 → 2.5 → …) through the dead
        // band; cooldown holds the first eligible switch back, and no
        // round may ever switch twice
        let mut switches_seen = 0;
        for _ in 0..6 {
            let _ = e.infer().unwrap();
            let rs = InferenceEngine::round_stats(&mut e).unwrap();
            assert!(rs.engine_switches <= 1, "one switch per round at most");
            switches_seen += rs.engine_switches;
        }
        assert_eq!(e.active_strategy(), Strategy::Incremental);
        assert_eq!(switches_seen, 1, "decay causes exactly one switch back");
    }

    #[test]
    fn queue_pressure_waives_the_cooldown() {
        let cfg = AutoConfig { cooldown_rounds: 1000, ..tight() };
        let mut e = engine(cfg);
        let _ = e.infer().unwrap();
        // consume the launch grace so the giant cooldown now binds
        for i in 0..10 {
            e.apply(&Update::AddEdge(i % 40, (i * 7 + 1) % 40)).unwrap();
        }
        let _ = e.infer().unwrap();
        assert_eq!(e.active_strategy(), Strategy::Plan);
        // churn stops; without pressure the 1000-round cooldown pins plan
        for _ in 0..5 {
            let _ = e.infer().unwrap();
        }
        assert_eq!(e.active_strategy(), Strategy::Plan, "cooldown holds");
        // a deep backlog waives it
        e.note_queue_depth(9);
        let _ = e.infer().unwrap();
        assert_eq!(e.active_strategy(), Strategy::Incremental);
    }

    #[test]
    fn answers_match_both_inner_strategies() {
        let ds = synthesize("auto-engine", 40, 90, 4, 12, 7);
        let pool = Arc::new(WorkerPool::serial());
        let mut auto_eng = engine(tight());
        let mut plan = PlanEngine::full(&ds, 48, Arc::clone(&pool)).unwrap();
        let script: Vec<Update> = (0..33)
            .map(|i| Update::AddEdge((i * 3) % 40, (i * 11 + 2) % 40))
            .collect();
        for (r, u) in script.iter().enumerate() {
            auto_eng.apply(u).unwrap();
            plan.apply(u).unwrap();
            // burst shape: rounds 0-7 one mutation each (the EWMA settles
            // at ~1, incremental), then chunks of 8 mutations per round
            // (EWMA 0.5·8 + 0.5·1 ≈ 4.5 crosses hysteresis_high = 4 on
            // the first burst round) — both regimes and the switch point
            // in one script
            if r < 8 || r % 8 == 0 {
                let a = auto_eng.infer().unwrap();
                let b = plan.infer().unwrap();
                assert_eq!(a.shape(), b.shape());
                for i in 0..a.rows {
                    for j in 0..a.cols {
                        let d = (a[(i, j)] - b[(i, j)]).abs();
                        assert!(d < 1e-4, "round {r} ({i},{j}) drift {d}");
                    }
                }
            }
        }
        assert!(auto_eng.total_switches() > 0, "script must cross the band");
    }

    #[test]
    fn high_density_forces_the_plan_path() {
        // a tiny capacity makes the padded density blow past the
        // sparse/dense crossover once edges pile in
        let ds = synthesize("auto-dense", 12, 50, 3, 6, 5);
        let mut e = AutoEngine::full(
            &ds,
            12,
            Arc::new(WorkerPool::serial()),
            IncrementalConfig::default(),
            tight(),
        )
        .unwrap();
        assert!(e.plan.live_density() >= SPMM_DENSITY_THRESHOLD);
        let _ = e.infer().unwrap();
        assert_eq!(
            e.active_strategy(),
            Strategy::Plan,
            "past the crossover churn is irrelevant"
        );
    }
}
