//! Per-shard admission control: bound the number of queries a shard
//! worker lets accumulate in its batcher, shedding the excess instead of
//! letting queue latency grow without bound.
//!
//! The serving path answers *every* pending query with one full-graph
//! inference, so a shard's queue depth is the number of batching windows
//! of debt it carries. Under overload the right move is to reject at
//! arrival (the caller sees a fast, explicit error and can retry against
//! a replica) rather than time out after queueing — the classic
//! load-shedding argument, applied per shard so one hot partition cannot
//! drag the whole fleet's tail latency up.

/// Admission policy knobs for one shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionConfig {
    /// Maximum queries waiting in the shard's batcher before new
    /// arrivals are shed. `0` disables shedding (unbounded queue).
    pub max_pending: usize,
}

impl AdmissionConfig {
    /// No shedding: the single-leader server's historical behavior.
    pub fn unbounded() -> AdmissionConfig {
        AdmissionConfig { max_pending: 0 }
    }

    /// Shed when more than `max_pending` queries are already waiting.
    pub fn bounded(max_pending: usize) -> AdmissionConfig {
        AdmissionConfig { max_pending }
    }
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig::unbounded()
    }
}

/// Mutable admission state owned by one shard worker thread.
#[derive(Debug)]
pub struct Admission {
    cfg: AdmissionConfig,
    /// Queries admitted into the batcher.
    pub admitted: usize,
    /// Queries shed at arrival.
    pub shed: usize,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Admission {
        Admission { cfg, admitted: 0, shed: 0 }
    }

    /// Decide whether a query arriving while `pending` queries wait in
    /// the batcher may enter. Callers must count a `false` into
    /// [`crate::metrics::Metrics::record_rejected`] and answer the query
    /// with an explicit rejection.
    pub fn admit(&mut self, pending: usize) -> bool {
        if self.cfg.max_pending > 0 && pending >= self.cfg.max_pending {
            self.shed += 1;
            false
        } else {
            self.admitted += 1;
            true
        }
    }

    pub fn config(&self) -> AdmissionConfig {
        self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_admits_everything() {
        let mut a = Admission::new(AdmissionConfig::unbounded());
        for pending in [0, 10, 10_000] {
            assert!(a.admit(pending));
        }
        assert_eq!(a.admitted, 3);
        assert_eq!(a.shed, 0);
    }

    #[test]
    fn bounded_sheds_at_limit() {
        let mut a = Admission::new(AdmissionConfig::bounded(4));
        assert!(a.admit(0));
        assert!(a.admit(3));
        assert!(!a.admit(4));
        assert!(!a.admit(5));
        assert_eq!(a.admitted, 2);
        assert_eq!(a.shed, 2);
    }

    #[test]
    fn recovers_when_queue_drains() {
        let mut a = Admission::new(AdmissionConfig::bounded(2));
        assert!(!a.admit(2));
        assert!(a.admit(1), "queue drained below the bound → admit again");
    }
}
