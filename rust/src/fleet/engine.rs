//! Plan-backed serving engine: fleet shards (and the single-leader
//! server) running a **real GCN [`ExecPlan`]** offline — no PJRT
//! artifacts, but the genuine planned-executor hot path: compiled-once
//! plan, arena-reused buffers, fused chains, NodePad-padded shapes so
//! GrAd updates never recompile.
//!
//! Weights are synthesized deterministically from the model dimensions,
//! so every shard of a fleet — and a 1-shard fleet vs the single-leader
//! server — computes identical logits, which keeps the fleet equivalence
//! suite meaningful while exercising the production execution path.

use std::cell::Cell;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::ModelState;
use crate::engine::{PlanInstance, WorkerPool};
use crate::graph::datasets::Dataset;
use crate::ops::build::{self, GnnDims};
use crate::ops::exec::Bindings;
use crate::ops::plan::ExecPlan;
use crate::server::{InferenceEngine, Update};
use crate::tensor::{Mat, Tensor};
use crate::util::Rng;

/// Deterministic offline GCN weights: a pure function of
/// `(features, classes, capacity)`, so every shard of a fleet — and
/// every engine family serving the same dataset ([`PlanEngine`],
/// [`crate::incremental::IncrementalEngine`]) — computes identical
/// logits without any artifact files.
pub fn synthesize_weights(features: usize, classes: usize, capacity: usize) -> Bindings {
    let mut rng = Rng::new(
        0x9AE1_6A3B_2F90_404Fu64
            ^ ((features as u64) << 24)
            ^ ((classes as u64) << 8)
            ^ capacity as u64,
    );
    let mut rand_mat = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.8 - 0.4) as f32)
    };
    let mut weights = Bindings::new();
    weights.insert("w1".into(), Tensor::from_mat(&rand_mat(features, crate::HIDDEN)));
    weights.insert("b1".into(), Tensor::from_mat(&rand_mat(1, crate::HIDDEN)));
    weights.insert("w2".into(), Tensor::from_mat(&rand_mat(crate::HIDDEN, classes)));
    weights.insert("b2".into(), Tensor::from_mat(&rand_mat(1, classes)));
    weights
}

/// A shard engine executing a NodePad-padded GCN plan over the live
/// GrAd graph. See the module docs.
pub struct PlanEngine {
    state: ModelState,
    instance: PlanInstance,
    bindings: Bindings,
    /// Graph version the norm/x bindings were refreshed at.
    bound_version: Option<u64>,
    owned: std::ops::Range<usize>,
    classes: usize,
    halo_cache: Cell<Option<usize>>,
}

impl PlanEngine {
    /// Compile the NodePad-padded plan and synthesize the deterministic
    /// weights for `ds` at `capacity`. The plan is `Arc`-shareable and the
    /// weights clone cheaply, so a fleet compiles **once** and hands both
    /// to every shard factory instead of redoing the analysis per shard.
    pub fn compile_parts(
        ds: &Dataset,
        capacity: usize,
    ) -> Result<(Arc<ExecPlan>, Bindings)> {
        let capacity = capacity.max(ds.num_nodes());
        let classes = ds.num_classes().max(2);
        let features = ds.num_features();
        // NodePad: compile at capacity so AddNode never changes shapes
        let dims = GnnDims::model(capacity, ds.graph.num_edges(), features, classes);
        let graph = build::gcn_stagr(dims, "grad");
        let plan = Arc::new(ExecPlan::compile(&graph)?);
        Ok((plan, synthesize_weights(features, classes, capacity)))
    }

    /// Engine over a pre-compiled plan + weight set (see
    /// [`PlanEngine::compile_parts`]), answering for `owned` only.
    pub fn from_parts(
        ds: &Dataset,
        capacity: usize,
        owned: std::ops::Range<usize>,
        pool: Arc<WorkerPool>,
        plan: Arc<ExecPlan>,
        weights: Bindings,
    ) -> Result<PlanEngine> {
        let capacity = capacity.max(ds.num_nodes());
        let classes = ds.num_classes().max(2);
        let state = ModelState::from_dataset(ds.clone(), capacity)?;
        Ok(PlanEngine {
            state,
            instance: PlanInstance::new(plan, pool),
            bindings: weights,
            bound_version: None,
            owned,
            classes,
            halo_cache: Cell::new(None),
        })
    }

    /// Engine answering for `owned` only (a fleet shard), compiling its
    /// own plan. `pool` sizes the in-shard worker pool (shards already
    /// parallelize across threads, so [`WorkerPool::serial`] is the usual
    /// choice). Fleets share one compile via [`PlanEngine::compile_parts`].
    pub fn shard(
        ds: &Dataset,
        capacity: usize,
        owned: std::ops::Range<usize>,
        pool: Arc<WorkerPool>,
    ) -> Result<PlanEngine> {
        let (plan, weights) = PlanEngine::compile_parts(ds, capacity)?;
        PlanEngine::from_parts(ds, capacity, owned, pool, plan, weights)
    }

    /// Engine answering for every node (the single-leader server).
    pub fn full(ds: &Dataset, capacity: usize, pool: Arc<WorkerPool>) -> Result<PlanEngine> {
        let owned = 0..capacity.max(ds.num_nodes());
        PlanEngine::shard(ds, capacity, owned, pool)
    }

    /// Compiled-plan introspection (bench/report hooks).
    pub fn plan(&self) -> &Arc<ExecPlan> {
        self.instance.plan()
    }

    /// Refresh the CacheG-cached mask/feature bindings if GrAd moved.
    fn refresh(&mut self) -> Result<()> {
        let v = self.state.graph_version();
        if self.bound_version == Some(v) {
            return Ok(());
        }
        let norm = self.state.binding("norm_pad", "gcn")?;
        let x = self.state.binding("x_pad", "gcn")?;
        self.bindings.insert("norm".into(), norm);
        self.bindings.insert("x".into(), x);
        self.bound_version = Some(v);
        Ok(())
    }
}

impl InferenceEngine for PlanEngine {
    fn apply(&mut self, update: &Update) -> Result<u64> {
        match update {
            Update::AddEdge(u, v) => {
                self.state.add_edge(*u, *v)?;
            }
            Update::RemoveEdge(u, v) => {
                self.state.remove_edge(*u, *v)?;
            }
            Update::AddNode => {
                self.state.add_node()?;
            }
        }
        self.halo_cache.set(None);
        Ok(self.state.graph_version())
    }

    fn infer(&mut self) -> Result<Mat> {
        self.refresh()?;
        self.instance.run(&self.bindings)?;
        // slice the active rows out of the capacity-padded logits
        let n = self.state.num_active_nodes();
        let (data, _rows, cols) = self.instance.output_view(0)?;
        Ok(Mat::from_vec(n, cols, data[..n * cols].to_vec()))
    }

    fn num_nodes(&self) -> usize {
        self.state.num_active_nodes()
    }

    fn halo_imports(&self) -> Option<usize> {
        if let Some(cached) = self.halo_cache.get() {
            return Some(cached);
        }
        let n = self.state.num_active_nodes();
        let mut imports = std::collections::BTreeSet::new();
        for i in self.owned.start.min(n)..self.owned.end.min(n) {
            for &j in self.state.neighbors(i) {
                if !self.owned.contains(&(j as usize)) {
                    imports.insert(j);
                }
            }
        }
        self.halo_cache.set(Some(imports.len()));
        Some(imports.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::synthesize;
    use crate::ops::exec;

    fn ds() -> Dataset {
        synthesize("plan-engine", 30, 70, 4, 12, 19)
    }

    #[test]
    fn infer_matches_reference_executor() {
        let ds = ds();
        let mut eng = PlanEngine::full(&ds, 36, Arc::new(WorkerPool::serial())).unwrap();
        let logits = eng.infer().unwrap();
        assert_eq!(logits.shape(), (30, 4));

        // oracle: same graph, same bindings (engine state is fresh)
        let dims = GnnDims::model(36, ds.graph.num_edges(), 12, 4);
        let g = build::gcn_stagr(dims, "grad");
        let want = exec::execute_mat(&g, &eng.bindings).unwrap();
        for i in 0..30 {
            for j in 0..4 {
                let d = (want[(i, j)] - logits[(i, j)]).abs();
                assert!(d < 1e-4, "({i},{j}) drift {d}");
            }
        }
    }

    #[test]
    fn updates_change_inference_without_recompile() {
        let ds = ds();
        let mut eng = PlanEngine::full(&ds, 36, Arc::new(WorkerPool::serial())).unwrap();
        let before = eng.infer().unwrap();
        eng.apply(&Update::AddEdge(0, 17)).unwrap();
        eng.apply(&Update::AddNode).unwrap();
        let after = eng.infer().unwrap();
        assert_eq!(after.rows, 31, "AddNode activates a padded row");
        let mut moved = 0.0f32;
        for i in 0..30 {
            for j in 0..4 {
                moved = moved.max((before[(i, j)] - after[(i, j)]).abs());
            }
        }
        assert!(moved > 1e-7, "edge add must change logits");
    }

    #[test]
    fn shards_agree_with_full_engine() {
        let ds = ds();
        let pool = Arc::new(WorkerPool::serial());
        let mut full = PlanEngine::full(&ds, 36, Arc::clone(&pool)).unwrap();
        let mut shard = PlanEngine::shard(&ds, 36, 0..15, pool).unwrap();
        let a = full.infer().unwrap();
        let b = shard.infer().unwrap();
        assert_eq!(a, b, "plan logits are shard-independent");
        assert!(shard.halo_imports().unwrap() > 0);
    }
}
