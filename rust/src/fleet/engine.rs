//! Plan-backed serving engine: fleet shards (and the single-leader
//! server) running a **real GCN [`ExecPlan`]** offline — no PJRT
//! artifacts, but the genuine planned-executor hot path: compiled-once
//! plan, arena-reused buffers, fused chains, NodePad-padded shapes so
//! GrAd updates never recompile.
//!
//! Aggregation compiles sparse by default at citation-graph density
//! ([`Aggregation::Auto`]): the plan's `norm` input binds a CSR tensor,
//! so each shard's mask memory scales with the graph's nnz instead of
//! capacity² (shards hold a full structural replica — updates fan out to
//! everyone — so the CSR is global, not sliced to the owned range), and
//! the mask-compression win (CSR vs dense, or ZVC+SymG on the dense
//! path) is reported per round through
//! [`crate::metrics::RoundStats::dma_bytes_shipped`].
//!
//! Weights are synthesized deterministically from the model dimensions,
//! so every shard of a fleet — and a 1-shard fleet vs the single-leader
//! server — computes identical logits, which keeps the fleet equivalence
//! suite meaningful while exercising the production execution path.

use std::cell::Cell;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::ModelState;
use crate::engine::{PlanInstance, WorkerPool};
use crate::graph::datasets::Dataset;
use crate::metrics::RoundStats;
use crate::ops::build::{self, Aggregation, GnnDims};
use crate::ops::exec::Bindings;
use crate::ops::plan::{ExecPlan, KernelConfig};
use crate::server::{InferenceEngine, Update};
use crate::tensor::{Mat, Tensor};
use crate::util::Rng;

/// Deterministic offline GCN weights: a pure function of
/// `(features, classes, capacity)`, so every shard of a fleet — and
/// every engine family serving the same dataset ([`PlanEngine`],
/// [`crate::incremental::IncrementalEngine`]) — computes identical
/// logits without any artifact files.
pub fn synthesize_weights(features: usize, classes: usize, capacity: usize) -> Bindings {
    let mut rng = Rng::new(
        0x9AE1_6A3B_2F90_404Fu64
            ^ ((features as u64) << 24)
            ^ ((classes as u64) << 8)
            ^ capacity as u64,
    );
    let mut rand_mat = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.8 - 0.4) as f32)
    };
    let mut weights = Bindings::new();
    weights.insert("w1".into(), Tensor::from_mat(&rand_mat(features, crate::HIDDEN)));
    weights.insert("b1".into(), Tensor::from_mat(&rand_mat(1, crate::HIDDEN)));
    weights.insert("w2".into(), Tensor::from_mat(&rand_mat(crate::HIDDEN, classes)));
    weights.insert("b2".into(), Tensor::from_mat(&rand_mat(1, classes)));
    weights
}

/// A shard engine executing a NodePad-padded GCN plan over the live
/// GrAd graph. See the module docs.
pub struct PlanEngine {
    state: ModelState,
    instance: PlanInstance,
    bindings: Bindings,
    /// Graph version the norm/x bindings were refreshed at.
    bound_version: Option<u64>,
    owned: std::ops::Range<usize>,
    classes: usize,
    /// Compiled with SpMM aggregation (binds the CSR mask)?
    sparse: bool,
    /// Mask-traffic accounting of the latest refresh, drained through
    /// [`InferenceEngine::round_stats`]. Rounds that reuse the bound
    /// mask (no GrAd churn) ship nothing — the CacheG story.
    pending_round: Option<RoundStats>,
    halo_cache: Cell<Option<usize>>,
}

impl PlanEngine {
    /// Compile the NodePad-padded plan and synthesize the deterministic
    /// weights for `ds` at `capacity`, resolving [`Aggregation::Auto`]
    /// against the padded-mask density (→ sparse at any realistic graph).
    /// The plan is `Arc`-shareable and the weights clone cheaply, so a
    /// fleet compiles **once** and hands both to every shard factory
    /// instead of redoing the analysis per shard.
    pub fn compile_parts(
        ds: &Dataset,
        capacity: usize,
    ) -> Result<(Arc<ExecPlan>, Bindings)> {
        PlanEngine::compile_parts_with(ds, capacity, Aggregation::Auto)
    }

    /// [`PlanEngine::compile_parts`] with an explicit aggregation mode
    /// (the `--aggregation dense|sparse|auto` operator override).
    pub fn compile_parts_with(
        ds: &Dataset,
        capacity: usize,
        agg: Aggregation,
    ) -> Result<(Arc<ExecPlan>, Bindings)> {
        PlanEngine::compile_parts_cfg(ds, capacity, agg, KernelConfig::default())
    }

    /// [`PlanEngine::compile_parts_with`] with explicit kernel knobs
    /// (SIMD dispatch, degree-binned scheduling) baked into the plan —
    /// what a `[kernels]` spec section lowers to.
    pub fn compile_parts_cfg(
        ds: &Dataset,
        capacity: usize,
        agg: Aggregation,
        kernels: KernelConfig,
    ) -> Result<(Arc<ExecPlan>, Bindings)> {
        let capacity = capacity.max(ds.num_nodes());
        let classes = ds.num_classes().max(2);
        let features = ds.num_features();
        let density = (2.0 * ds.graph.num_edges() as f64 + ds.num_nodes() as f64)
            / (capacity as f64 * capacity as f64);
        // NodePad: compile at capacity so AddNode never changes shapes
        let dims = GnnDims::model(capacity, ds.graph.num_edges(), features, classes);
        let graph = build::gcn_stagr_with(dims, "grad", agg.resolve(density));
        let plan = Arc::new(ExecPlan::compile_with(&graph, kernels)?);
        Ok((plan, synthesize_weights(features, classes, capacity)))
    }

    /// [`PlanEngine::compile_parts_with`] for the **QuantGr INT8**
    /// variant: compiles `gcn_quant` at NodePad capacity and hands back
    /// quantized bindings — weights pre-quantized to the `w1q`/`w2q`
    /// int8 inputs the plan's i8×i8→i32 kernels consume, with symmetric
    /// static scales calibrated from the synthesized weights and the
    /// dataset features (activation-2 range estimated from the layer-1
    /// fan-in; serving equivalence across shard counts is exact either
    /// way because every shard shares these parts).
    pub fn compile_quant_parts(
        ds: &Dataset,
        capacity: usize,
        agg: Aggregation,
    ) -> Result<(Arc<ExecPlan>, Bindings)> {
        PlanEngine::compile_quant_parts_cfg(ds, capacity, agg, KernelConfig::default())
    }

    /// [`PlanEngine::compile_quant_parts`] with explicit kernel knobs
    /// baked into the INT8 plan.
    pub fn compile_quant_parts_cfg(
        ds: &Dataset,
        capacity: usize,
        agg: Aggregation,
        kernels: KernelConfig,
    ) -> Result<(Arc<ExecPlan>, Bindings)> {
        use crate::quant::{calibrate, quantize, scale_for};

        let capacity = capacity.max(ds.num_nodes());
        let classes = ds.num_classes().max(2);
        let features = ds.num_features();
        let density = (2.0 * ds.graph.num_edges() as f64 + ds.num_nodes() as f64)
            / (capacity as f64 * capacity as f64);
        let weights = synthesize_weights(features, classes, capacity);
        let w1 = weights.get("w1").expect("synthesized w1").to_mat()?;
        let w2 = weights.get("w2").expect("synthesized w2").to_mat()?;
        let (sw1, sw2) = (calibrate(&w1, 100.0), calibrate(&w2, 100.0));
        let sa1 = calibrate(&ds.features, 100.0);
        // layer-1 output magnitude estimate: absmax(x)·absmax(w1)·√fan_in
        // (random-sign cancellation) — loose enough to avoid clipping
        let sa2 = scale_for(
            (127.0 * sa1) * (127.0 * sw1) * (features.max(1) as f32).sqrt(),
        );
        let scales = build::QuantScales { act1: sa1, w1: sw1, act2: sa2, w2: sw2 };

        let mut bindings = Bindings::new();
        bindings.insert(
            "w1q".into(),
            Tensor::I8 { shape: vec![features, crate::HIDDEN], data: quantize(&w1, sw1) },
        );
        bindings.insert(
            "w2q".into(),
            Tensor::I8 { shape: vec![crate::HIDDEN, classes], data: quantize(&w2, sw2) },
        );
        bindings.insert("b1".into(), weights.get("b1").expect("b1").clone());
        bindings.insert("b2".into(), weights.get("b2").expect("b2").clone());

        let dims = GnnDims::model(capacity, ds.graph.num_edges(), features, classes);
        let graph = build::gcn_quant_with(dims, scales, agg.resolve(density));
        let plan = Arc::new(ExecPlan::compile_with(&graph, kernels)?);
        Ok((plan, bindings))
    }

    /// Engine over a pre-compiled plan + weight set (see
    /// [`PlanEngine::compile_parts`]), answering for `owned` only.
    pub fn from_parts(
        ds: &Dataset,
        capacity: usize,
        owned: std::ops::Range<usize>,
        pool: Arc<WorkerPool>,
        plan: Arc<ExecPlan>,
        weights: Bindings,
    ) -> Result<PlanEngine> {
        let capacity = capacity.max(ds.num_nodes());
        let classes = ds.num_classes().max(2);
        let state = ModelState::from_dataset(ds.clone(), capacity)?;
        let sparse = plan.is_sparse();
        Ok(PlanEngine {
            state,
            instance: PlanInstance::new(plan, pool),
            bindings: weights,
            bound_version: None,
            owned,
            classes,
            sparse,
            pending_round: None,
            halo_cache: Cell::new(None),
        })
    }

    /// Engine answering for `owned` only (a fleet shard), compiling its
    /// own plan. `pool` sizes the in-shard worker pool (shards already
    /// parallelize across threads, so [`WorkerPool::serial`] is the usual
    /// choice). Fleets share one compile via [`PlanEngine::compile_parts`].
    pub fn shard(
        ds: &Dataset,
        capacity: usize,
        owned: std::ops::Range<usize>,
        pool: Arc<WorkerPool>,
    ) -> Result<PlanEngine> {
        let (plan, weights) = PlanEngine::compile_parts(ds, capacity)?;
        PlanEngine::from_parts(ds, capacity, owned, pool, plan, weights)
    }

    /// Engine answering for every node (the single-leader server).
    pub fn full(ds: &Dataset, capacity: usize, pool: Arc<WorkerPool>) -> Result<PlanEngine> {
        PlanEngine::full_with(ds, capacity, pool, Aggregation::Auto)
    }

    /// [`PlanEngine::full`] with an explicit aggregation mode.
    pub fn full_with(
        ds: &Dataset,
        capacity: usize,
        pool: Arc<WorkerPool>,
        agg: Aggregation,
    ) -> Result<PlanEngine> {
        let owned = 0..capacity.max(ds.num_nodes());
        let (plan, weights) = PlanEngine::compile_parts_with(ds, capacity, agg)?;
        PlanEngine::from_parts(ds, capacity, owned, pool, plan, weights)
    }

    /// Compiled-plan introspection (bench/report hooks).
    pub fn plan(&self) -> &Arc<ExecPlan> {
        self.instance.plan()
    }

    /// Does this engine aggregate through SpMM (CSR mask bindings)?
    pub fn is_sparse(&self) -> bool {
        self.sparse
    }

    /// Live padded-mask density of the GrAd graph this engine serves —
    /// the same `(2·edges + nodes) / capacity²` formula the plan
    /// builders resolve [`Aggregation::Auto`] against, but computed from
    /// the *current* counters so it tracks churn. The adaptive `auto`
    /// engine reads it as a switching signal.
    pub fn live_density(&self) -> f64 {
        let cap = (self.state.capacity as f64).max(1.0);
        (2.0 * self.state.num_edges() as f64
            + self.state.num_active_nodes() as f64)
            / (cap * cap)
    }

    /// Refresh the CacheG-cached mask/feature bindings if GrAd moved,
    /// and account the mask bytes the re-fetch shipped: CSR arrays on
    /// the sparse path; GraSp (ZVC) over the SymG-packed upper triangle
    /// on the dense path (the norm is symmetric) — real codec math on
    /// real nnz counts, not sampled estimates.
    fn refresh(&mut self) -> Result<()> {
        let v = self.state.graph_version();
        if self.bound_version == Some(v) {
            return Ok(());
        }
        let cap = self.state.capacity;
        let dense_bytes = cap * cap * 4;
        let norm = if self.sparse {
            self.state.binding("norm_csr_pad", "gcn")?
        } else {
            self.state.binding("norm_pad", "gcn")?
        };
        let shipped = if self.sparse {
            // Tensor::bytes of a CSR binding is its compressed footprint
            norm.bytes().min(dense_bytes)
        } else {
            // SymG: the norm is symmetric, so only its j ≥ i entries ship
            // — exactly one diagonal entry per active node plus one
            // strict-upper entry per undirected edge, O(1) from the live
            // counters. ZVC on the n(n+1)/2 packed elements adds 1 bit
            // each; stored values cost 4 bytes.
            let upper = self.state.num_edges() + self.state.num_active_nodes();
            let packed_elems = cap * (cap + 1) / 2;
            (packed_elems.div_ceil(8) + upper * 4).min(dense_bytes)
        };
        let x = self.state.binding("x_pad", "gcn")?;
        self.bindings.insert("norm".into(), norm);
        self.bindings.insert("x".into(), x);
        self.bound_version = Some(v);
        self.pending_round = Some(RoundStats {
            dma_bytes_dense: dense_bytes,
            dma_bytes_shipped: shipped,
            ..Default::default()
        });
        Ok(())
    }
}

impl InferenceEngine for PlanEngine {
    fn apply(&mut self, update: &Update) -> Result<u64> {
        match update {
            Update::AddEdge(u, v) => {
                self.state.add_edge(*u, *v)?;
            }
            Update::RemoveEdge(u, v) => {
                self.state.remove_edge(*u, *v)?;
            }
            Update::AddNode => {
                self.state.add_node()?;
            }
        }
        self.halo_cache.set(None);
        Ok(self.state.graph_version())
    }

    fn infer(&mut self) -> Result<Mat> {
        self.refresh()?;
        self.instance.run(&self.bindings)?;
        // slice the active rows out of the capacity-padded logits
        let n = self.state.num_active_nodes();
        let (data, _rows, cols) = self.instance.output_view(0)?;
        Ok(Mat::from_vec(n, cols, data[..n * cols].to_vec()))
    }

    fn num_nodes(&self) -> usize {
        self.state.num_active_nodes()
    }

    fn halo_imports(&self) -> Option<usize> {
        if let Some(cached) = self.halo_cache.get() {
            return Some(cached);
        }
        let n = self.state.num_active_nodes();
        let mut imports = std::collections::BTreeSet::new();
        for i in self.owned.start.min(n)..self.owned.end.min(n) {
            for &j in self.state.neighbors(i) {
                if !self.owned.contains(&(j as usize)) {
                    imports.insert(j);
                }
            }
        }
        self.halo_cache.set(Some(imports.len()));
        Some(imports.len())
    }

    /// Mask-traffic accounting: reported once per GrAd-driven mask
    /// re-fetch (rounds that reuse the bound mask ship nothing, exactly
    /// like a CacheG-pinned operand).
    fn round_stats(&mut self) -> Option<RoundStats> {
        self.pending_round.take()
    }

    /// Attach per-step plan profiling: a no-op for a disabled hub
    /// (`plan_profiler` returns `None`, keeping [`PlanInstance::run`]
    /// timer-free and allocation-free).
    fn attach_telemetry(
        &mut self,
        telemetry: &Arc<crate::telemetry::Telemetry>,
        shard: usize,
    ) {
        let plan = Arc::clone(self.instance.plan());
        self.instance.attach_profiler(telemetry.plan_profiler(shard, &plan));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::synthesize;
    use crate::ops::exec;

    fn ds() -> Dataset {
        synthesize("plan-engine", 30, 70, 4, 12, 19)
    }

    #[test]
    fn infer_matches_reference_executor() {
        let ds = ds();
        let mut eng = PlanEngine::full(&ds, 36, Arc::new(WorkerPool::serial())).unwrap();
        // Auto resolves sparse at this density — the default engine is
        // the SpMM path, and the oracle below still agrees (its MatMul
        // densifies the CSR binding)
        assert!(eng.is_sparse(), "auto must pick sparse at 0.13 density");
        let logits = eng.infer().unwrap();
        assert_eq!(logits.shape(), (30, 4));
        // the sparse engine never materialized the capacity² dense mask
        assert!(!eng.state.dense_norm_materialized());
        // and reported the mask-compression gauge for the first bind
        let rs = InferenceEngine::round_stats(&mut eng).unwrap();
        assert_eq!(rs.dma_bytes_dense, 36 * 36 * 4);
        assert!(rs.dma_bytes_shipped < rs.dma_bytes_dense);
        // no churn → no re-fetch → nothing further to report
        let _ = eng.infer().unwrap();
        assert!(InferenceEngine::round_stats(&mut eng).is_none());

        // oracle: same graph, same bindings (engine state is fresh)
        let dims = GnnDims::model(36, ds.graph.num_edges(), 12, 4);
        let g = build::gcn_stagr(dims, "grad");
        let want = exec::execute_mat(&g, &eng.bindings).unwrap();
        for i in 0..30 {
            for j in 0..4 {
                let d = (want[(i, j)] - logits[(i, j)]).abs();
                assert!(d < 1e-4, "({i},{j}) drift {d}");
            }
        }
    }

    #[test]
    fn updates_change_inference_without_recompile() {
        let ds = ds();
        let mut eng = PlanEngine::full(&ds, 36, Arc::new(WorkerPool::serial())).unwrap();
        let before = eng.infer().unwrap();
        eng.apply(&Update::AddEdge(0, 17)).unwrap();
        eng.apply(&Update::AddNode).unwrap();
        let after = eng.infer().unwrap();
        assert_eq!(after.rows, 31, "AddNode activates a padded row");
        let mut moved = 0.0f32;
        for i in 0..30 {
            for j in 0..4 {
                moved = moved.max((before[(i, j)] - after[(i, j)]).abs());
            }
        }
        assert!(moved > 1e-7, "edge add must change logits");
    }

    #[test]
    fn shards_agree_with_full_engine() {
        let ds = ds();
        let pool = Arc::new(WorkerPool::serial());
        let mut full = PlanEngine::full(&ds, 36, Arc::clone(&pool)).unwrap();
        let mut shard = PlanEngine::shard(&ds, 36, 0..15, pool).unwrap();
        let a = full.infer().unwrap();
        let b = shard.infer().unwrap();
        assert_eq!(a, b, "plan logits are shard-independent");
        assert!(shard.halo_imports().unwrap() > 0);
    }

    #[test]
    fn quant_parts_serve_int8_and_are_shard_invariant() {
        let ds = ds();
        let pool = Arc::new(WorkerPool::serial());
        let (plan, weights) =
            PlanEngine::compile_quant_parts(&ds, 36, Aggregation::Auto).unwrap();
        assert!(
            weights.get("w1q").is_some() && weights.get("w2q").is_some(),
            "quant parts must carry pre-quantized int8 weights"
        );
        let mut full = PlanEngine::from_parts(
            &ds, 36, 0..36, Arc::clone(&pool), Arc::clone(&plan), weights.clone(),
        )
        .unwrap();
        let mut shard =
            PlanEngine::from_parts(&ds, 36, 0..15, pool, plan, weights).unwrap();
        let a = full.infer().unwrap();
        assert_eq!(a.shape(), (30, 4));
        // the INT8 datapath must produce real (non-zero, finite) logits
        let absmax = a.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(absmax > 0.0 && absmax.is_finite(), "degenerate INT8 logits");
        let b = shard.infer().unwrap();
        assert_eq!(a, b, "INT8 logits are shard-independent");
    }

    #[test]
    fn sparse_and_dense_engines_agree_under_churn() {
        let ds = ds();
        let pool = Arc::new(WorkerPool::serial());
        let mut sparse =
            PlanEngine::full_with(&ds, 36, Arc::clone(&pool), Aggregation::Sparse)
                .unwrap();
        let mut dense =
            PlanEngine::full_with(&ds, 36, pool, Aggregation::Dense).unwrap();
        assert!(sparse.is_sparse());
        assert!(!dense.is_sparse());
        let churn = [
            Update::AddEdge(0, 17),
            Update::AddEdge(3, 25),
            Update::AddNode,
            Update::AddEdge(30, 4),
            Update::RemoveEdge(0, 17),
        ];
        for u in &churn {
            sparse.apply(u).unwrap();
            dense.apply(u).unwrap();
        }
        let a = sparse.infer().unwrap();
        let b = dense.infer().unwrap();
        // identical values through either kernel (same accumulation order)
        assert_eq!(a, b, "sparse vs dense aggregation diverged");
        // dense-path round stats credit ZVC+SymG, sparse credits CSR —
        // both are genuine savings vs the dense mask. (Which wins depends
        // on scale: the ZVC bitmap is O(n²) bits, so CSR pulls ahead as
        // capacity grows; at this toy size either may be smaller.)
        let rs = InferenceEngine::round_stats(&mut sparse).unwrap();
        let rd = InferenceEngine::round_stats(&mut dense).unwrap();
        assert!(rs.dma_bytes_shipped < rs.dma_bytes_dense);
        assert!(rd.dma_bytes_shipped < rd.dma_bytes_dense);
    }
}
