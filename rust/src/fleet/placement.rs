//! Fleet placement: GraphSplit's cost model, lifted from ops to *nodes*.
//!
//! The paper's GraphSplit (§IV, Step 1) decides where each op runs by
//! comparing per-device compute cost against the host-link transfer cost
//! of every boundary crossing. A fleet asks the same question one level
//! up: which *partition of the graph's nodes* goes to which device, given
//! that every cut edge forces boundary-node features across the link each
//! round (the halo exchange, [`super::halo`]).
//!
//! The planner probes each candidate device with the paper's op-level
//! cost functions ([`crate::npu::cost`]) on the real model graph — so a
//! Series-2 NPU, a Series-1 NPU, a CPU, and an iGPU each get an honest
//! per-node rate — then sizes contiguous shards proportional to device
//! speed and refines the cut points by local search on the round cost
//! `max_shard(compute + halo_link)`. Heterogeneous placement falls out:
//! slow devices get small shards, and cuts migrate toward low-degree
//! regions where the halo is cheap. Local search over an offline cost
//! model is exactly the paper's GraphSplit recipe (optimal partitioning
//! is NP-hard).

use anyhow::{bail, Result};

use crate::config::{DeviceKind, HardwareConfig};
use crate::graph::Graph;
use crate::npu::cost::{op_cost, CostOpts};
use crate::ops::build::{self, GnnDims};
use crate::ops::OpKind;

use super::halo::link_cost_us;

/// One shard's slice of the fleet plan.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    pub id: usize,
    /// Device model this shard is pinned to.
    pub device: HardwareConfig,
    /// Owned node ids (contiguous in capacity space; NodePad slots
    /// beyond the initial graph are pre-assigned so `AddNode` has an
    /// owner from the start).
    pub nodes: std::ops::Range<usize>,
    /// Cost-model rate for this device on this model (µs per node per
    /// inference round).
    pub per_node_us: f64,
    /// Estimated compute per round: owned nodes × rate.
    pub est_compute_us: f64,
    /// Boundary nodes whose features this shard must import per round.
    pub halo_in: usize,
    /// Owned nodes whose features peers import from this shard.
    pub halo_out: usize,
    /// Simulated host-link time for this shard's imports (µs/round).
    pub est_halo_us: f64,
}

impl ShardSpec {
    pub fn owns(&self, node: usize) -> bool {
        self.nodes.contains(&node)
    }

    pub fn num_owned(&self) -> usize {
        self.nodes.len()
    }
}

/// A complete fleet placement.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    pub shards: Vec<ShardSpec>,
    /// node id → owning shard, length = capacity.
    pub owner: Vec<usize>,
    /// Undirected edges whose endpoints live on different shards.
    pub cut_edges: usize,
    /// Estimated per-round latency: `max_shard(compute + halo)`.
    pub est_round_us: f64,
    /// Feature bytes crossing shard boundaries per round (all shards).
    pub halo_bytes_per_round: usize,
}

impl FleetPlan {
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn owner_of(&self, node: usize) -> Option<usize> {
        self.owner.get(node).copied()
    }
}

/// Per-node inference rate of `hw` on a 2-layer GCN at the workload's
/// dimensions, from the paper's op-level cost functions: build the StaGr
/// op graph, cost every non-input op on the device, divide by n. The
/// NPU probes at its FP16 datapath, CPU/GPU at FP32 — the same widths
/// [`crate::coordinator::CostModel::profile`] uses.
pub fn per_node_us(hw: &HardwareConfig, nodes: usize, edges: usize,
                   features: usize, classes: usize) -> Result<f64> {
    let dims = GnnDims::model(nodes.max(2), edges.max(1), features.max(1),
                              classes.max(2));
    let g = build::build("gcn", "stagr", dims)?;
    let opts = CostOpts {
        dense_dtype_bytes: if hw.kind == DeviceKind::Npu { 2 } else { 4 },
        ..Default::default()
    };
    let mut us = 0.0;
    for (id, op) in g.ops.iter().enumerate() {
        if op.kind == OpKind::Input {
            continue;
        }
        us += op_cost(&g, id, hw, op.kind.default_engine(), opts).us;
    }
    Ok(us / nodes.max(2) as f64)
}

/// Workload description the planner needs beyond the graph itself.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// NodePad capacity: the node-id space being partitioned.
    pub capacity: usize,
    /// Feature width (drives halo bytes and the compute probe).
    pub features: usize,
    pub classes: usize,
    /// Stored bytes per feature element on the link (2 = FP16).
    pub dtype_bytes: usize,
}

/// Plan a fleet: assign every capacity slot to one of `devices.len()`
/// shards (one shard per roster entry, in order).
pub fn plan(graph: &Graph, w: &Workload, devices: &[HardwareConfig])
            -> Result<FleetPlan> {
    if devices.is_empty() {
        bail!("fleet plan needs at least one device");
    }
    if w.capacity < graph.num_nodes() {
        bail!("capacity {} < graph nodes {}", w.capacity, graph.num_nodes());
    }
    let k = devices.len().min(w.capacity);
    let edges = graph.num_edges();

    // 1. probe each device's rate with the paper's cost functions
    let mut rates = Vec::with_capacity(k);
    for hw in &devices[..k] {
        rates.push(per_node_us(hw, w.capacity, edges, w.features, w.classes)?);
    }

    // 2. initial contiguous cuts sized ∝ device speed
    let speeds: Vec<f64> = rates.iter().map(|r| 1.0 / r.max(1e-12)).collect();
    let total_speed: f64 = speeds.iter().sum();
    let mut cuts = vec![0usize; k + 1];
    let mut acc = 0.0;
    for i in 0..k {
        acc += speeds[i] / total_speed;
        cuts[i + 1] = ((acc * w.capacity as f64).round() as usize).min(w.capacity);
    }
    cuts[k] = w.capacity;
    for i in 1..k {
        // repair rounding collapses: every shard keeps ≥1 slot (the
        // bounds are consistent because capacity ≥ k)
        cuts[i] = cuts[i].clamp(cuts[i - 1] + 1, w.capacity - (k - i));
    }

    // 3. local search over cut points on the round cost
    let nbrs = graph.neighbor_lists();
    let mut best = round_cost(&cuts, &rates, &nbrs, w, devices);
    for _round in 0..6 {
        let mut improved = false;
        for i in 1..k {
            for delta in [-64isize, -16, -4, -1, 1, 4, 16, 64] {
                let cand = cuts[i] as isize + delta;
                if cand <= cuts[i - 1] as isize || cand >= cuts[i + 1] as isize {
                    continue;
                }
                let mut trial = cuts.clone();
                trial[i] = cand as usize;
                let c = round_cost(&trial, &rates, &nbrs, w, devices);
                if c + 1e-12 < best {
                    best = c;
                    cuts = trial;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }

    // 4. materialize the plan
    let owner: Vec<usize> = (0..w.capacity)
        .map(|n| owner_of_cuts(&cuts, n))
        .collect();
    let mut cut_edges = 0;
    for &(u, v) in graph.edges() {
        if owner[u as usize] != owner[v as usize] {
            cut_edges += 1;
        }
    }
    let mut shards = Vec::with_capacity(k);
    let mut halo_total_bytes = 0usize;
    for i in 0..k {
        let (halo_in, halo_out) = halo_counts(&cuts, i, &nbrs);
        let bytes = halo_in * w.features * w.dtype_bytes;
        halo_total_bytes += bytes;
        let est_halo_us = link_cost_us(&devices[i], bytes);
        shards.push(ShardSpec {
            id: i,
            device: devices[i].clone(),
            nodes: cuts[i]..cuts[i + 1],
            per_node_us: rates[i],
            est_compute_us: (cuts[i + 1] - cuts[i]) as f64 * rates[i],
            halo_in,
            halo_out,
            est_halo_us,
        });
    }
    Ok(FleetPlan {
        shards,
        owner,
        cut_edges,
        est_round_us: best,
        halo_bytes_per_round: halo_total_bytes,
    })
}

fn owner_of_cuts(cuts: &[usize], node: usize) -> usize {
    // cuts is sorted; k is small — linear scan beats binary search here
    for i in 1..cuts.len() {
        if node < cuts[i] {
            return i - 1;
        }
    }
    cuts.len() - 2
}

/// (imported boundary nodes, exported boundary nodes) for shard `i`.
fn halo_counts(cuts: &[usize], i: usize, nbrs: &[Vec<u32>]) -> (usize, usize) {
    let (lo, hi) = (cuts[i], cuts[i + 1]);
    let mut imports = std::collections::BTreeSet::new();
    let mut exports = std::collections::BTreeSet::new();
    for u in lo..hi.min(nbrs.len()) {
        for &v in &nbrs[u] {
            let v = v as usize;
            if v < lo || v >= hi {
                imports.insert(v);
                exports.insert(u);
            }
        }
    }
    (imports.len(), exports.len())
}

/// `max_shard(compute + halo_link)` for a candidate set of cuts.
fn round_cost(cuts: &[usize], rates: &[f64], nbrs: &[Vec<u32>], w: &Workload,
              devices: &[HardwareConfig]) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..cuts.len() - 1 {
        let owned = cuts[i + 1] - cuts[i];
        let (halo_in, _) = halo_counts(cuts, i, nbrs);
        let halo_us =
            link_cost_us(&devices[i], halo_in * w.features * w.dtype_bytes);
        worst = worst.max(owned as f64 * rates[i] + halo_us);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::synthesize;

    fn workload(capacity: usize) -> Workload {
        Workload { capacity, features: 32, classes: 4, dtype_bytes: 2 }
    }

    #[test]
    fn plan_covers_every_slot_exactly_once() {
        let ds = synthesize("p", 200, 600, 4, 32, 9);
        let devices = vec![
            HardwareConfig::npu_series2(),
            HardwareConfig::npu_series1(),
            HardwareConfig::gpu(),
            HardwareConfig::cpu(),
        ];
        let p = plan(&ds.graph, &workload(240), &devices).unwrap();
        assert_eq!(p.owner.len(), 240);
        assert_eq!(p.num_shards(), 4);
        let mut covered = 0;
        for s in &p.shards {
            assert!(s.num_owned() > 0, "shard {} owns nothing", s.id);
            covered += s.num_owned();
            for n in s.nodes.clone() {
                assert_eq!(p.owner[n], s.id);
            }
        }
        assert_eq!(covered, 240);
    }

    #[test]
    fn faster_devices_own_more_nodes() {
        let ds = synthesize("p2", 300, 900, 4, 32, 11);
        let devices = vec![HardwareConfig::npu_series2(), HardwareConfig::cpu()];
        let p = plan(&ds.graph, &workload(300), &devices).unwrap();
        let npu = p.shards[0].num_owned();
        let cpu = p.shards[1].num_owned();
        assert!(
            npu > cpu,
            "cost model should give the NPU the bigger shard ({npu} vs {cpu})"
        );
    }

    #[test]
    fn single_shard_has_no_halo() {
        let ds = synthesize("p3", 100, 300, 3, 16, 5);
        let devices = vec![HardwareConfig::npu_series2()];
        let p = plan(&ds.graph, &workload(120), &devices).unwrap();
        assert_eq!(p.cut_edges, 0);
        assert_eq!(p.halo_bytes_per_round, 0);
        assert_eq!(p.shards[0].halo_in, 0);
        assert_eq!(p.shards[0].nodes, 0..120);
    }

    #[test]
    fn multi_shard_reports_cut_and_halo() {
        let ds = synthesize("p4", 400, 1600, 4, 32, 7);
        let devices = vec![HardwareConfig::npu_series2(); 4];
        let p = plan(&ds.graph, &workload(400), &devices).unwrap();
        assert!(p.cut_edges > 0, "a connected synth graph must have cut edges");
        assert!(p.halo_bytes_per_round > 0);
        // halo bytes are boundary nodes × features × dtype
        let total_imports: usize = p.shards.iter().map(|s| s.halo_in).sum();
        assert_eq!(p.halo_bytes_per_round, total_imports * 32 * 2);
    }

    #[test]
    fn sharding_reduces_estimated_round_cost() {
        // large enough that compute dominates the halo link setup cost —
        // the regime the fleet exists for
        let ds = synthesize("p5", 2000, 8000, 4, 32, 13);
        let one = plan(&ds.graph, &workload(2000),
                       &[HardwareConfig::npu_series2()]).unwrap();
        let four = plan(&ds.graph, &workload(2000),
                        &vec![HardwareConfig::npu_series2(); 4]).unwrap();
        assert!(
            four.est_round_us < one.est_round_us,
            "4 shards {} should beat 1 shard {}",
            four.est_round_us,
            one.est_round_us
        );
    }

    #[test]
    fn empty_roster_rejected() {
        let ds = synthesize("p6", 20, 40, 2, 8, 3);
        assert!(plan(&ds.graph, &workload(20), &[]).is_err());
    }

    #[test]
    fn per_node_rate_orders_devices_sanely() {
        let npu = per_node_us(&HardwareConfig::npu_series2(), 512, 2000, 64, 4)
            .unwrap();
        let cpu = per_node_us(&HardwareConfig::cpu(), 512, 2000, 64, 4).unwrap();
        assert!(npu > 0.0 && cpu > 0.0);
        assert!(npu < cpu, "NPU {npu} should out-rate CPU {cpu} on GCN");
    }
}
