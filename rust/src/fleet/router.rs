//! The fleet front door: fans GrAd updates to every shard, routes each
//! query to the shard that owns the queried node, and tracks a version
//! vector so cross-shard consistency is observable.
//!
//! Consistency model: every shard keeps a full structural replica (GrAd
//! makes a structure update an O(deg) mask edit, so replicating
//! *structure* is cheap — it is *features* that are partitioned and
//! shipped as halos). The router sends updates to all shards over the
//! same ordered channels that carry queries, so each shard applies every
//! update that was sequenced before any later query — the single-leader
//! consistency story, preserved per shard. The version vector
//! (`expected[s]` = updates the router has sequenced to shard `s`,
//! `applied[s]` = updates shard `s` has processed) makes convergence a
//! checkable property instead of a hope.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;

use anyhow::{anyhow, Result};

use crate::metrics::{Metrics, Snapshot};
use crate::server::{QueryResponse, Update};
use crate::telemetry::{Recorder, SpanKind};

use super::shard::ShardWorker;

/// Routes requests across a set of spawned shard workers.
pub struct Router {
    /// node id → owning shard (capacity space, from the [`super::placement::FleetPlan`]).
    owner: Vec<usize>,
    shards: Vec<ShardWorker>,
    /// Updates sequenced to each shard (the router's half of the vector).
    expected: Vec<AtomicU64>,
    next_id: AtomicU64,
    /// Route-decision spans land here under the query's trace id (shard =
    /// [`crate::telemetry::ROUTER_SHARD`]); disabled by default.
    recorder: Recorder,
}

impl Router {
    pub fn new(owner: Vec<usize>, shards: Vec<ShardWorker>) -> Router {
        assert!(!shards.is_empty(), "router needs at least one shard");
        let expected = shards.iter().map(|_| AtomicU64::new(0)).collect();
        Router {
            owner,
            shards,
            expected,
            next_id: AtomicU64::new(1),
            recorder: Recorder::disabled(),
        }
    }

    /// Attach a telemetry recorder (the fleet passes its hub's
    /// [`crate::telemetry::ROUTER_SHARD`] recorder here at spawn).
    pub fn set_recorder(&mut self, recorder: Recorder) {
        self.recorder = recorder;
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard answers queries for `node`. Nodes beyond the plan's
    /// capacity fall through to shard 0, whose engine rejects them with
    /// the same out-of-range error the single-leader server produced.
    pub fn owner_of(&self, node: usize) -> usize {
        self.owner.get(node).copied().unwrap_or(0)
    }

    /// Sequence a GrAd update to every shard (structure is replicated;
    /// channel order guarantees it lands before any later query). Every
    /// *live* shard is sequenced even if one has died — surviving
    /// replicas must not diverge because of an early-return on a dead
    /// peer — and `expected` only counts sends that were accepted, so
    /// the vector stays meaningful per shard. The first failure is
    /// still reported.
    pub fn update(&self, u: Update) -> Result<()> {
        let mut first_err = None;
        for (s, shard) in self.shards.iter().enumerate() {
            match shard.update(u.clone()) {
                Ok(()) => {
                    self.expected[s].fetch_add(1, Ordering::AcqRel);
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Submit a query; `None` means "the full graph" and routes like the
    /// single-leader server: answered from node 0's owner.
    pub fn query(&self, node: Option<usize>)
                 -> Result<Receiver<Result<QueryResponse, String>>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let shard = self.owner_of(node.unwrap_or(0));
        self.recorder.record(
            id,
            SpanKind::Route,
            "route",
            self.recorder.now_us(),
            0.0,
            shard as u64,
        );
        self.shards[shard].query_with_id(id, node)
    }

    /// Blocking convenience: query and wait (router-level tests; serving
    /// callers go through [`crate::serve::Serving::query_wait`]).
    pub fn query_wait(&self, node: Option<usize>) -> Result<QueryResponse> {
        let rx = self.query(node)?;
        rx.recv()
            .map_err(|_| anyhow!("shard dropped response"))?
            .map_err(|e| anyhow!(e))
    }

    /// Count one caller-abandoned (deadline-shed) query against the
    /// shard that owns `node`, through the same `rejected` accounting
    /// the admission path uses.
    pub fn record_shed(&self, node: Option<usize>) {
        let shard = self.owner_of(node.unwrap_or(0));
        self.shards[shard].metrics.record_rejected();
    }

    /// Barrier every shard: returns the applied version vector once every
    /// previously-sequenced event has been processed fleet-wide.
    pub fn sync(&self) -> Result<Vec<u64>> {
        self.shards.iter().map(|s| s.sync()).collect()
    }

    /// Updates sequenced per shard (the router's send-side counts).
    pub fn expected_versions(&self) -> Vec<u64> {
        self.expected.iter().map(|v| v.load(Ordering::Acquire)).collect()
    }

    /// Updates applied per shard (the workers' receive-side counts).
    pub fn applied_versions(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.applied_version()).collect()
    }

    /// Exact fleet-wide aggregate (raw samples merged across shards).
    pub fn metrics(&self) -> Snapshot {
        Metrics::merged(self.shards.iter().map(|s| s.metrics.as_ref()))
    }

    /// Per-shard labeled snapshots.
    pub fn shard_metrics(&self) -> Vec<Snapshot> {
        self.shards.iter().map(|s| s.metrics.snapshot()).collect()
    }

    /// Stop every shard and join them all. Every worker is joined even if
    /// an early one failed; the first failure is returned (with the other
    /// failures appended) so a crash on shard 3 cannot hide behind a
    /// clean shutdown on shard 0.
    pub fn shutdown(mut self) -> Result<()> {
        let mut failures: Vec<String> = Vec::new();
        for shard in self.shards.drain(..) {
            if let Err(e) = shard.shutdown() {
                failures.push(format!("{e:#}"));
            }
        }
        if failures.is_empty() {
            Ok(())
        } else {
            Err(anyhow!("{}", failures.join("; ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::admission::AdmissionConfig;
    use crate::fleet::shard::ShardConfig;
    use crate::server::{InferenceEngine, ServerConfig};
    use crate::tensor::Mat;
    use std::time::Duration;

    /// Engine that stamps predictions with its shard id so routing is
    /// observable: prediction = shard * 100 + node (mod classes=1000…
    /// just use wide logits).
    struct Stamp {
        shard: usize,
        nodes: usize,
    }

    impl InferenceEngine for Stamp {
        fn apply(&mut self, _: &crate::server::Update) -> anyhow::Result<u64> {
            Ok(0)
        }
        fn infer(&mut self) -> anyhow::Result<Mat> {
            let classes = 1000;
            let mut m = Mat::zeros(self.nodes, classes);
            for i in 0..self.nodes {
                m[(i, (self.shard * 100 + i) % classes)] = 1.0;
            }
            Ok(m)
        }
        fn num_nodes(&self) -> usize {
            self.nodes
        }
    }

    fn cfg() -> ShardConfig {
        ShardConfig {
            batch: ServerConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            admission: AdmissionConfig::unbounded(),
            halo: None,
            telemetry: crate::telemetry::Telemetry::disabled(),
            monitor: crate::monitor::Monitor::disabled(),
        }
    }

    fn two_shard_router() -> Router {
        // nodes 0..5 on shard 0, 5..10 on shard 1
        let owner: Vec<usize> = (0..10).map(|n| usize::from(n >= 5)).collect();
        let shards = vec![
            ShardWorker::spawn(0, || Ok(Stamp { shard: 0, nodes: 10 }), cfg()),
            ShardWorker::spawn(1, || Ok(Stamp { shard: 1, nodes: 10 }), cfg()),
        ];
        Router::new(owner, shards)
    }

    #[test]
    fn queries_reach_the_owning_shard() {
        let r = two_shard_router();
        let a = r.query_wait(Some(2)).unwrap();
        assert_eq!(a.shard, 0);
        assert_eq!(a.prediction, 2);
        let b = r.query_wait(Some(7)).unwrap();
        assert_eq!(b.shard, 1);
        assert_eq!(b.prediction, 107);
        r.shutdown().unwrap();
    }

    #[test]
    fn none_routes_like_the_single_leader() {
        let r = two_shard_router();
        let a = r.query_wait(None).unwrap();
        assert_eq!(a.shard, 0, "full-graph queries answer from node 0's owner");
        r.shutdown().unwrap();
    }

    #[test]
    fn version_vector_converges_after_fanout() {
        let r = two_shard_router();
        for i in 0..7 {
            r.update(crate::server::Update::AddEdge(i, i + 1)).unwrap();
        }
        assert_eq!(r.expected_versions(), vec![7, 7]);
        let applied = r.sync().unwrap();
        assert_eq!(applied, vec![7, 7], "all shards caught up after barrier");
        assert_eq!(r.applied_versions(), vec![7, 7]);
        r.shutdown().unwrap();
    }

    #[test]
    fn out_of_capacity_query_rejected_by_engine() {
        let r = two_shard_router();
        let err = r.query_wait(Some(999)).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        r.shutdown().unwrap();
    }

    #[test]
    fn merged_metrics_count_across_shards() {
        let r = two_shard_router();
        let _ = r.query_wait(Some(1)).unwrap();
        let _ = r.query_wait(Some(8)).unwrap();
        let _ = r.query_wait(Some(9)).unwrap();
        let snap = r.metrics();
        assert_eq!(snap.queries, 3);
        let per = r.shard_metrics();
        assert_eq!(per[0].shard, Some(0));
        assert_eq!(per[0].queries, 1);
        assert_eq!(per[1].queries, 2);
        r.shutdown().unwrap();
    }

    #[test]
    fn shutdown_propagates_any_shard_failure() {
        struct Bad;
        impl InferenceEngine for Bad {
            fn apply(&mut self, _: &crate::server::Update) -> anyhow::Result<u64> {
                Ok(0)
            }
            fn infer(&mut self) -> anyhow::Result<Mat> {
                panic!("shard 1 died");
            }
            fn num_nodes(&self) -> usize {
                10
            }
        }
        let owner: Vec<usize> = (0..10).map(|n| usize::from(n >= 5)).collect();
        let shards = vec![
            ShardWorker::spawn(0, || Ok(Stamp { shard: 0, nodes: 10 }), cfg()),
            ShardWorker::spawn(1, || Ok(Bad), cfg()),
        ];
        let r = Router::new(owner, shards);
        // trip the bad shard
        let _ = r.query_wait(Some(7));
        let err = r.shutdown().unwrap_err().to_string();
        assert!(err.contains("shard 1 died"), "{err}");
    }
}
