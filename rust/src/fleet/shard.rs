//! The shard worker: one thread, one engine, one partition.
//!
//! This is the fleet's generalization of the old `server` leader loop —
//! the single-leader [`crate::server::ServerHandle`] is now literally a
//! one-shard fleet with no halo and unbounded admission. Each worker:
//!
//! - owns its [`InferenceEngine`] (constructed *inside* the thread: PJRT
//!   handles are not `Send`),
//! - applies GrAd updates in arrival order and publishes its applied
//!   count as its component of the fleet's version vector,
//! - coalesces queries through a [`Batcher`], shedding load at arrival
//!   when the [`Admission`] bound is hit,
//! - charges its [`HaloSpec`] link traffic before every inference round,
//! - records admission/queue/batch/engine-round/halo/per-op spans into
//!   its own telemetry ring when tracing is enabled (branch-only no-ops
//!   otherwise — see [`crate::telemetry`]),
//! - and on panic rejects every in-flight query explicitly (counted in
//!   `Metrics::rejected`) before surfacing the panic message as an `Err`
//!   from [`ShardWorker::shutdown`] — a crash must never strand callers
//!   on a response channel.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::{Batcher, Request};
use crate::metrics::Metrics;
use crate::server::{InferenceEngine, QueryResponse, ServerConfig, Update};

use super::admission::{Admission, AdmissionConfig};
use super::halo::HaloSpec;

/// Events a shard worker consumes, in arrival order.
pub enum ShardEvent {
    Update(Update),
    Query {
        req: Request,
        resp: Sender<Result<QueryResponse, String>>,
    },
    /// Ordered barrier: replies with the shard's applied-update count
    /// once every earlier event has been processed.
    Sync(Sender<u64>),
    Shutdown,
}

/// Tuning for one shard worker.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    pub batch: ServerConfig,
    pub admission: AdmissionConfig,
    /// Boundary traffic charged per inference round (None = no halo).
    pub halo: Option<HaloSpec>,
    /// Deployment-wide telemetry hub; a disabled hub hands this worker a
    /// no-op recorder and no profiler, keeping the loop branch-only.
    pub telemetry: Arc<crate::telemetry::Telemetry>,
    /// Deployment-wide monitor; a disabled monitor hands this worker an
    /// inert heartbeat pulse (same branch-only contract).
    pub monitor: crate::monitor::Monitor,
}

impl ShardConfig {
    /// The single-leader server's historical behavior: no halo, no shed,
    /// no telemetry, no monitor.
    pub fn leader(batch: ServerConfig) -> ShardConfig {
        ShardConfig {
            batch,
            admission: AdmissionConfig::unbounded(),
            halo: None,
            telemetry: crate::telemetry::Telemetry::disabled(),
            monitor: crate::monitor::Monitor::disabled(),
        }
    }
}

/// Handle to one spawned shard worker.
pub struct ShardWorker {
    pub id: usize,
    tx: Sender<ShardEvent>,
    pub metrics: Arc<Metrics>,
    join: Option<JoinHandle<Result<()>>>,
    applied: Arc<AtomicU64>,
}

impl ShardWorker {
    /// Spawn the worker thread. `factory` runs inside it.
    pub fn spawn<F, E>(id: usize, factory: F, config: ShardConfig) -> ShardWorker
    where
        F: FnOnce() -> Result<E> + Send + 'static,
        E: InferenceEngine,
    {
        let (tx, rx) = channel::<ShardEvent>();
        let metrics = Arc::new(Metrics::new_shard(id));
        let applied = Arc::new(AtomicU64::new(0));
        let (m, a) = (metrics.clone(), applied.clone());
        // register with the monitor here (not in the thread) so shard
        // registration order is deterministic; the pulse moves into the
        // worker, which beats it every loop iteration
        let pulse = config.monitor.register_shard(id, metrics.clone());
        let join = std::thread::spawn(move || {
            run_shard(id, factory, rx, m, a, config, pulse)
        });
        ShardWorker { id, tx, metrics, join: Some(join), applied }
    }

    pub fn send(&self, ev: ShardEvent) -> Result<()> {
        self.tx
            .send(ev)
            .map_err(|_| anyhow!("shard {} stopped", self.id))
    }

    /// Apply a GrAd update (ordered before any later query to this shard).
    pub fn update(&self, u: Update) -> Result<()> {
        self.send(ShardEvent::Update(u))
    }

    /// Submit a query with a caller-assigned id; returns the response
    /// channel. Routing (which shard owns the node) is the router's job.
    pub fn query_with_id(&self, id: u64, node: Option<usize>)
                         -> Result<Receiver<Result<QueryResponse, String>>> {
        let (resp_tx, resp_rx) = channel();
        self.send(ShardEvent::Query {
            req: Request { id, node, enqueued: Instant::now() },
            resp: resp_tx,
        })?;
        Ok(resp_rx)
    }

    /// Updates this shard has applied (its version-vector component).
    pub fn applied_version(&self) -> u64 {
        self.applied.load(Ordering::Acquire)
    }

    /// Ordered barrier: blocks until every event sent before this call
    /// has been processed; returns the applied-update count.
    pub fn sync(&self) -> Result<u64> {
        let (tx, rx) = channel();
        self.send(ShardEvent::Sync(tx))?;
        rx.recv().map_err(|_| anyhow!("shard {} died during sync", self.id))
    }

    /// Stop the worker and join it. A worker panic surfaces as an `Err`
    /// carrying the panic message — never a hang, never a swallowed join.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(ShardEvent::Shutdown);
        match self.join.take() {
            None => Ok(()),
            Some(j) => match j.join() {
                Ok(r) => r,
                // run_shard catches panics and converts them to Err, so a
                // panicking join here means the panic escaped catch_unwind
                // (e.g. a panic while poisoning); still surface it.
                Err(payload) => Err(anyhow!(
                    "shard worker panicked: {}",
                    panic_message(&payload)
                )),
            },
        }
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(ShardEvent::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Best-effort extraction of a panic payload's message.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

type Waiting = std::collections::BTreeMap<u64, Sender<Result<QueryResponse, String>>>;

fn run_shard<F, E>(id: usize, factory: F, rx: Receiver<ShardEvent>,
                   metrics: Arc<Metrics>, applied: Arc<AtomicU64>,
                   config: ShardConfig, pulse: crate::monitor::Pulse)
                   -> Result<()>
where
    F: FnOnce() -> Result<E>,
    E: InferenceEngine,
{
    let mut engine = match catch_unwind(AssertUnwindSafe(factory)) {
        Ok(Ok(e)) => e,
        Ok(Err(e)) => {
            let msg = format!("shard {id} engine init failed: {e:#}");
            pulse.panicked(&msg);
            reject_all(&rx, &mut Waiting::new(), &metrics, &msg);
            return Err(anyhow!(msg));
        }
        Err(payload) => {
            let msg = format!(
                "shard {id} engine init panicked: {}",
                panic_message(&payload)
            );
            pulse.panicked(&msg);
            reject_all(&rx, &mut Waiting::new(), &metrics, &msg);
            return Err(anyhow!(msg));
        }
    };
    engine.attach_telemetry(&config.telemetry, id);
    let batcher = Batcher::new(config.batch.max_batch, config.batch.max_wait);
    let mut admission = Admission::new(config.admission);
    let mut waiting = Waiting::new();

    let result = catch_unwind(AssertUnwindSafe(|| {
        shard_loop(
            id,
            &mut engine,
            &rx,
            &batcher,
            &mut waiting,
            &mut admission,
            &metrics,
            &applied,
            &config,
            &pulse,
        )
    }));
    match result {
        Ok(r) => r,
        Err(payload) => {
            let msg =
                format!("shard {id} worker panicked: {}", panic_message(&payload));
            pulse.panicked(&msg);
            reject_all(&rx, &mut waiting, &metrics, &msg);
            Err(anyhow!(msg))
        }
    }
}

/// Reject every in-flight query — both those already handed to the
/// batcher (their responders live in `waiting`) and those still queued in
/// the event channel — with an explicit error, counting each rejection.
fn reject_all(rx: &Receiver<ShardEvent>, waiting: &mut Waiting,
              metrics: &Metrics, msg: &str) {
    for (_, resp) in std::mem::take(waiting) {
        metrics.record_rejected();
        let _ = resp.send(Err(msg.to_string()));
    }
    while let Ok(ev) = rx.try_recv() {
        match ev {
            ShardEvent::Query { resp, .. } => {
                metrics.record_rejected();
                let _ = resp.send(Err(msg.to_string()));
            }
            // dropping the responder makes a concurrent sync() error out
            ShardEvent::Sync(_) => {}
            ShardEvent::Update(_) | ShardEvent::Shutdown => {}
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn shard_loop<E: InferenceEngine>(
    id: usize, engine: &mut E, rx: &Receiver<ShardEvent>, batcher: &Batcher,
    waiting: &mut Waiting, admission: &mut Admission, metrics: &Metrics,
    applied: &Arc<AtomicU64>, config: &ShardConfig,
    pulse: &crate::monitor::Pulse,
) -> Result<()> {
    use crate::telemetry::SpanKind;
    let recorder = config.telemetry.recorder(id);
    let mut open = true;
    while open || batcher.pending() > 0 {
        // heartbeat: the ≤1 ms ingest timeout below means a healthy
        // shard beats far faster than any monitor interval; a stale
        // stamp is the watchdog's wedge signal
        pulse.touch();
        // ingest events for up to the batching window
        match rx.recv_timeout(config.batch.max_wait.min(Duration::from_millis(1))) {
            Ok(ShardEvent::Update(u)) => {
                match engine.apply(&u) {
                    Ok(_) => metrics.record_mask_update(),
                    // capacity exhaustion etc: drop the update, count it
                    Err(_) => metrics.record_rejected(),
                }
                // the version vector counts *sequenced* updates, applied
                // or shed — convergence means "nothing outstanding", not
                // "nothing ever failed"
                let v = applied.fetch_add(1, Ordering::AcqRel) + 1;
                batcher.note_update(v);
            }
            Ok(ShardEvent::Query { req, resp }) => {
                if let Some(n) = req.node {
                    if n >= engine.num_nodes() {
                        metrics.record_rejected();
                        let _ = resp.send(Err(format!(
                            "node {n} out of range ({} active)",
                            engine.num_nodes()
                        )));
                        continue;
                    }
                }
                if !admission.admit(batcher.pending()) {
                    metrics.record_rejected();
                    recorder.record(
                        req.id,
                        SpanKind::Admission,
                        "shed",
                        recorder.now_us(),
                        0.0,
                        batcher.pending() as u64,
                    );
                    let _ = resp.send(Err(format!(
                        "shard {id} overloaded: {} queries pending (cap {})",
                        batcher.pending(),
                        admission.config().max_pending
                    )));
                    continue;
                }
                recorder.record(
                    req.id,
                    SpanKind::Admission,
                    "admit",
                    recorder.now_us(),
                    0.0,
                    batcher.pending() as u64,
                );
                waiting.insert(req.id, resp);
                batcher.submit(req);
            }
            Ok(ShardEvent::Sync(tx)) => {
                let _ = tx.send(applied.load(Ordering::Acquire));
            }
            Ok(ShardEvent::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                open = false;
                batcher.close();
            }
            Err(RecvTimeoutError::Timeout) => {}
        }

        // flush a batch if ready
        if let Some(batch) = batcher.try_batch() {
            let flush_us = recorder.now_us();
            // batch-level spans (halo, batch assembly, per-op breakdown)
            // hang off the first request's trace: the whole round is that
            // query's critical path, batch-mates share it for free.
            let trace0 = batch.requests.first().map(|r| r.id).unwrap_or(0);
            // halo exchange precedes the round: boundary features must be
            // resident before aggregation can touch cut edges. Prefer the
            // engine's live import count (tracks GrAd churn); fall back
            // to the plan-time schedule for engines that can't report it.
            if let Some(h) = &config.halo {
                let (bytes, us) = match engine.halo_imports() {
                    Some(n) => {
                        let b = n * h.bytes_per_import;
                        (b, h.cost_us(b))
                    }
                    None => (h.bytes_per_round, h.link_us_per_round),
                };
                if bytes > 0 {
                    metrics.record_halo(bytes, us);
                    recorder.record(
                        trace0,
                        SpanKind::Halo,
                        "halo",
                        recorder.now_us(),
                        us,
                        bytes as u64,
                    );
                }
            }
            // the queue depth *behind* this batch is the backlog signal
            // adaptive engines fold into their strategy choice; an
            // active SLO breach rides along as a synthetic deep queue
            // so `auto` engines may switch strategy without cooldown
            engine.note_queue_depth(batcher.pending() + pulse.pressure_boost());
            let t0 = Instant::now();
            let t0_us = recorder.now_us();
            let result = engine.infer();
            let latency_us = t0.elapsed().as_secs_f64() * 1e6;
            let size = batch.requests.len();
            match result {
                Ok(logits) => {
                    if let Some(rs) = engine.round_stats() {
                        metrics.record_round(&rs);
                    }
                    if recorder.enabled() {
                        recorder.record(
                            trace0,
                            SpanKind::Batch,
                            "flush",
                            flush_us,
                            (t0_us - flush_us).max(0.0),
                            size as u64,
                        );
                        // the profiler stashed per-step wall times during
                        // infer(); replay them as Op spans at cumulative
                        // offsets inside the engine round.
                        let mut off = t0_us;
                        for obs in config.telemetry.drain_last_round(id) {
                            recorder
                                .record(trace0, SpanKind::Op, obs.kind, off, obs.dur_us, 0);
                            off += obs.dur_us;
                        }
                    }
                    let preds = logits.argmax_rows();
                    for req in batch.requests {
                        let node = req.node.unwrap_or(0);
                        let queue_us =
                            req.enqueued.elapsed().as_secs_f64() * 1e6 - latency_us;
                        let queue_us = queue_us.max(0.0);
                        metrics.record_query(latency_us, queue_us, size);
                        recorder.record(
                            req.id,
                            SpanKind::Queue,
                            "queue",
                            t0_us - queue_us,
                            queue_us,
                            0,
                        );
                        recorder.record(
                            req.id,
                            SpanKind::EngineRound,
                            "round",
                            t0_us,
                            latency_us,
                            size as u64,
                        );
                        if let Some(resp) = waiting.remove(&req.id) {
                            let _ = resp.send(Ok(QueryResponse {
                                id: req.id,
                                shard: id,
                                prediction: preds
                                    .get(node)
                                    .map(|&p| p as i32)
                                    .unwrap_or(-1),
                                latency_us,
                                batch_size: size,
                            }));
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("shard {id} inference failed: {e:#}");
                    for req in batch.requests {
                        metrics.record_rejected();
                        if let Some(resp) = waiting.remove(&req.id) {
                            let _ = resp.send(Err(msg.clone()));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;

    /// Deterministic engine: prediction = (node + applied updates) % 4.
    struct Versioned {
        nodes: usize,
        version: u64,
    }

    impl InferenceEngine for Versioned {
        fn apply(&mut self, _u: &Update) -> Result<u64> {
            self.version += 1;
            Ok(self.version)
        }
        fn infer(&mut self) -> Result<Mat> {
            let mut m = Mat::zeros(self.nodes, 4);
            for i in 0..self.nodes {
                m[(i, (i + self.version as usize) % 4)] = 1.0;
            }
            Ok(m)
        }
        fn num_nodes(&self) -> usize {
            self.nodes
        }
    }

    fn cfg() -> ShardConfig {
        ShardConfig::leader(ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        })
    }

    fn spawn_versioned() -> ShardWorker {
        ShardWorker::spawn(0, || Ok(Versioned { nodes: 10, version: 0 }), cfg())
    }

    #[test]
    fn orders_updates_before_queries() {
        let w = spawn_versioned();
        w.update(Update::AddNode).unwrap();
        w.update(Update::AddNode).unwrap();
        let r = w.query_with_id(1, Some(3)).unwrap().recv().unwrap().unwrap();
        assert_eq!(r.prediction, 1); // (3 + 2) % 4
        assert_eq!(r.shard, 0);
        assert_eq!(w.applied_version(), 2);
        w.shutdown().unwrap();
    }

    #[test]
    fn sync_is_an_ordered_barrier() {
        let w = spawn_versioned();
        for _ in 0..5 {
            w.update(Update::AddEdge(0, 1)).unwrap();
        }
        assert_eq!(w.sync().unwrap(), 5);
        w.shutdown().unwrap();
    }

    #[test]
    fn admission_sheds_when_bounded() {
        // a long batching window lets the queue build while the worker is
        // still ingesting, so arrivals past the bound hit the shed path
        let w = ShardWorker::spawn(
            1,
            || Ok(Versioned { nodes: 4, version: 0 }),
            ShardConfig {
                batch: ServerConfig {
                    max_batch: 100,
                    max_wait: Duration::from_millis(50),
                },
                admission: AdmissionConfig::bounded(2),
                halo: None,
                telemetry: crate::telemetry::Telemetry::disabled(),
                monitor: crate::monitor::Monitor::disabled(),
            },
        );
        let rxs: Vec<_> = (0..12)
            .map(|i| w.query_with_id(i, Some(0)).unwrap())
            .collect();
        let (mut ok, mut shed) = (0usize, 0usize);
        for rx in rxs {
            match rx.recv().unwrap() {
                Err(e) if e.contains("overloaded") => shed += 1,
                Ok(_) => ok += 1,
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(shed > 0, "bounded admission should shed under backlog");
        assert_eq!(ok + shed, 12);
        assert!(w.metrics.snapshot().rejected >= shed);
        w.shutdown().unwrap();
    }

    #[test]
    fn halo_charged_once_per_round() {
        let mut halo = HaloSpec::empty(0);
        halo.bytes_per_round = 1024;
        halo.link_us_per_round = 5.0;
        let w = ShardWorker::spawn(
            0,
            || Ok(Versioned { nodes: 10, version: 0 }),
            ShardConfig {
                batch: ServerConfig {
                    max_batch: 8,
                    max_wait: Duration::from_millis(2),
                },
                admission: AdmissionConfig::unbounded(),
                halo: Some(halo),
                telemetry: crate::telemetry::Telemetry::disabled(),
                monitor: crate::monitor::Monitor::disabled(),
            },
        );
        let _ = w.query_with_id(1, Some(0)).unwrap().recv().unwrap().unwrap();
        let snap = w.metrics.snapshot();
        assert_eq!(snap.halo_rounds, 1);
        assert_eq!(snap.halo_bytes, 1024);
        w.shutdown().unwrap();
    }

    #[test]
    fn panic_rejects_in_flight_and_surfaces_in_shutdown() {
        struct Exploding;
        impl InferenceEngine for Exploding {
            fn apply(&mut self, _: &Update) -> Result<u64> {
                Ok(0)
            }
            fn infer(&mut self) -> Result<Mat> {
                panic!("mask buffer corrupted");
            }
            fn num_nodes(&self) -> usize {
                8
            }
        }
        let w = ShardWorker::spawn(2, || Ok(Exploding), cfg());
        let rx = w.query_with_id(1, Some(0)).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("mask buffer corrupted"), "{err}");
        assert!(w.metrics.snapshot().rejected >= 1);
        let shut = w.shutdown().unwrap_err().to_string();
        assert!(shut.contains("mask buffer corrupted"), "{shut}");
    }

    #[test]
    fn engine_init_failure_rejects_queued_queries() {
        let w: ShardWorker = ShardWorker::spawn(
            3,
            || -> Result<Versioned> {
                std::thread::sleep(Duration::from_millis(10));
                Err(anyhow!("no artifacts"))
            },
            cfg(),
        );
        let rx = w.query_with_id(1, Some(0)).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("engine init failed"), "{err}");
        let shut = w.shutdown().unwrap_err().to_string();
        assert!(shut.contains("no artifacts"), "{shut}");
    }
}
