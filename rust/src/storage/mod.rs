//! `storage` — out-of-core node features: a paged, file-backed store
//! behind the [`FeatureSource`] trait.
//!
//! Every engine that consumes node features does so through a gather
//! (`rows → row-major tile buffer`), so the storage tier hides behind
//! one trait with exactly that shape: [`FeatureSource::gather`] fills a
//! tile buffer from whatever holds the rows — RAM ([`MemoryFeatures`],
//! the NodePad-padded `x_pad` matrix every plan binds today) or disk
//! ([`PagedFeatures`], a page cache over a [`PagedStore`] file). The
//! binding layer cannot tell them apart; the difference is that the
//! paged backend's resident set is `cache_pages × page_rows` rows
//! instead of the full `capacity × width` matrix, which is what lets a
//! deployment serve graphs larger than host RAM.
//!
//! The tier's three pieces:
//!
//! - [`store`] — the on-disk layout (`.gnnt`-compatible, page-aligned
//!   payload) and `pread`-style offset reads; one shared handle serves
//!   every shard.
//! - [`cache`] — CacheG generalized to pages: fixed-capacity,
//!   TinyLFU-lite admission, epoch-versioned invalidation so GrAd churn
//!   drops exactly the dirtied pages.
//! - [`prefetch`] — frontier-driven background reads: the incremental
//!   round plan and fleet halo lists are known before the gather runs,
//!   so their pages are staged while the engine binds tiles.
//!
//! Selected per deployment by the `[storage]` spec section
//! (`backend = "memory" | "paged"`); the warm-hit path of both backends
//! is allocation-free (`tests/plan_alloc.rs` proves it under the
//! counting allocator).

pub mod cache;
pub mod prefetch;
pub mod store;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::engine::kernels;
use crate::tensor::Mat;

pub use cache::{FreqSketch, PageCache};
pub use prefetch::Prefetcher;
pub use store::{spill_path, PagedStore, PAGE_ALIGN};

/// Cumulative storage-tier counters, drained per round into
/// [`crate::metrics::RoundStats`] (feature-cache hits/faults and disk
/// bytes read). The in-memory backend reports zeros — there is no
/// storage tier to hit or miss.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StorageStats {
    /// Row gathers served from the resident page cache.
    pub hits: u64,
    /// Row gathers that had to touch the store file (page faults).
    pub faults: u64,
    /// Bytes read from the store file (direct + prefetched).
    pub bytes_read: u64,
}

/// Where feature rows come from — RAM or a paged on-disk store. The
/// consuming engines only ever gather, stage, and (under GrAd feature
/// churn) write single rows, so that is the whole contract.
pub trait FeatureSource: Send {
    /// Total rows (the NodePad capacity).
    fn rows(&self) -> usize;

    /// Feature width per row.
    fn width(&self) -> usize;

    /// Gather `rows` into `out` (row-major, `rows.len() × width`); the
    /// tile-buffer contract of [`kernels::gather_rows`]. Warm paths are
    /// allocation-free.
    fn gather(&mut self, rows: &[usize], out: &mut [f32]) -> Result<()>;

    /// Prefetch hint: these rows will be gathered soon (the next
    /// round's frontier ring / halo imports). Default no-op.
    fn stage(&mut self, _rows: &[usize]) {}

    /// Overwrite one row (GrAd feature churn), invalidating any cached
    /// copy so the next gather sees the fresh values.
    fn write_row(&mut self, row: usize, values: &[f32]) -> Result<()>;

    /// Drop cached copies of `rows` without writing (e.g. a GrAd
    /// `AddNode` activating a padding row). Default no-op.
    fn invalidate_rows(&mut self, _rows: &[usize]) {}

    /// Drain the counters accumulated since the last call.
    fn take_stats(&mut self) -> StorageStats {
        StorageStats::default()
    }

    /// Materialize the full matrix (oracle/debug path — allocates).
    fn to_mat(&mut self) -> Result<Mat> {
        let (rows, width) = (self.rows(), self.width());
        let idx: Vec<usize> = (0..rows).collect();
        let mut out = Mat::zeros(rows, width);
        self.gather(&idx, &mut out.data)?;
        Ok(out)
    }
}

/// The in-RAM backend: the NodePad-padded feature matrix, gathered with
/// the same SIMD-friendly kernel the plans bind directly.
#[derive(Debug)]
pub struct MemoryFeatures {
    x_pad: Mat,
}

impl MemoryFeatures {
    /// Wrap an already-padded `capacity × width` matrix.
    pub fn new(x_pad: Mat) -> MemoryFeatures {
        MemoryFeatures { x_pad }
    }

    /// Pad `features` with zero rows up to `capacity` (the `x_pad`
    /// layout) and wrap it.
    pub fn padded(features: &Mat, capacity: usize) -> MemoryFeatures {
        MemoryFeatures { x_pad: crate::graph::pad_features(features, capacity) }
    }
}

impl FeatureSource for MemoryFeatures {
    fn rows(&self) -> usize {
        self.x_pad.rows
    }

    fn width(&self) -> usize {
        self.x_pad.cols
    }

    fn gather(&mut self, rows: &[usize], out: &mut [f32]) -> Result<()> {
        if let Some(&bad) = rows.iter().find(|&&r| r >= self.x_pad.rows) {
            bail!("gather row {bad} past capacity {} — rows are 0..capacity", self.x_pad.rows);
        }
        kernels::gather_rows(&self.x_pad.data, self.x_pad.cols, rows, out);
        Ok(())
    }

    fn write_row(&mut self, row: usize, values: &[f32]) -> Result<()> {
        if row >= self.x_pad.rows {
            bail!("write_row {row} past capacity {}", self.x_pad.rows);
        }
        if values.len() != self.x_pad.cols {
            bail!("write_row got {} values, width is {}", values.len(), self.x_pad.cols);
        }
        self.x_pad.row_mut(row).copy_from_slice(values);
        Ok(())
    }

    fn to_mat(&mut self) -> Result<Mat> {
        Ok(self.x_pad.clone())
    }
}

/// The out-of-core backend: an admission-controlled [`PageCache`] over
/// a shared [`PagedStore`] file, with optional frontier-driven
/// prefetch. Resident footprint is `cache_pages × page_rows × width`
/// floats regardless of graph size.
#[derive(Debug)]
pub struct PagedFeatures {
    store: Arc<PagedStore>,
    cache: PageCache,
    prefetch: Option<Prefetcher>,
    /// Stamped page-dedup scratch for [`FeatureSource::stage`].
    seen: Vec<u32>,
    stamp: u32,
    /// `pread` byte scratch (one page).
    scratch: Vec<u8>,
    hits: u64,
    faults: u64,
    bytes_read: u64,
}

impl PagedFeatures {
    /// A paged source over `store` with `cache_pages` resident pages of
    /// `page_rows` rows each.
    pub fn new(store: Arc<PagedStore>, page_rows: usize, cache_pages: usize) -> PagedFeatures {
        let cache = PageCache::new(store.rows(), store.width(), page_rows, cache_pages);
        let num_pages = cache.num_pages();
        let scratch = vec![0u8; page_rows * store.width() * 4];
        PagedFeatures {
            store,
            cache,
            prefetch: None,
            seen: vec![0; num_pages],
            stamp: 0,
            scratch,
            hits: 0,
            faults: 0,
            bytes_read: 0,
        }
    }

    /// Enable the background prefetch worker (one thread per source,
    /// i.e. per shard).
    pub fn with_prefetch(mut self) -> PagedFeatures {
        let page_rows = self.cache.page_rows();
        self.prefetch = Some(Prefetcher::spawn(Arc::clone(&self.store), page_rows));
        self
    }

    /// The shared backing store.
    pub fn store(&self) -> &Arc<PagedStore> {
        &self.store
    }

    /// Currently resident valid pages (test/metrics gauge).
    pub fn resident_pages(&self) -> usize {
        self.cache.valid_pages()
    }

    /// Next dedup stamp, handling wraparound.
    fn next_stamp(&mut self) -> u32 {
        if self.stamp == u32::MAX {
            self.seen.fill(0);
            self.stamp = 0;
        }
        self.stamp += 1;
        self.stamp
    }
}

impl FeatureSource for PagedFeatures {
    fn rows(&self) -> usize {
        self.store.rows()
    }

    fn width(&self) -> usize {
        self.store.width()
    }

    fn gather(&mut self, rows: &[usize], out: &mut [f32]) -> Result<()> {
        let width = self.store.width();
        let capacity = self.store.rows();
        for (i, &row) in rows.iter().enumerate() {
            if row >= capacity {
                bail!("gather row {row} past store capacity {capacity} — rows are 0..capacity");
            }
            let dst = &mut out[i * width..(i + 1) * width];
            let page = self.cache.page_of(row);
            self.cache.touch(page);
            if let Some(cached) = self.cache.row(row) {
                dst.copy_from_slice(cached);
                self.hits += 1;
                continue;
            }
            self.faults += 1;
            // fill the page from staging if prefetched, else from disk
            let store = &self.store;
            let prefetch = self.prefetch.as_ref();
            let scratch = &mut self.scratch;
            let row0 = page * self.cache.page_rows();
            let count = self.cache.rows_in_page(page);
            let mut disk_bytes = 0u64;
            let admitted = self.cache.admit(page, |buf| -> Result<()> {
                if let Some(pf) = prefetch {
                    if pf.take(page, buf).is_some() {
                        return Ok(());
                    }
                }
                disk_bytes = store.read_rows(row0, count, buf, scratch)? as u64;
                Ok(())
            })?;
            self.bytes_read += disk_bytes;
            if admitted {
                let cached = self.cache.row(row).expect("admitted page must serve");
                dst.copy_from_slice(cached);
            } else {
                // admission rejected (cold one-touch page): read around
                // the cache, single row
                self.bytes_read +=
                    self.store.read_rows(row, 1, dst, &mut self.scratch)? as u64;
            }
        }
        Ok(())
    }

    fn stage(&mut self, rows: &[usize]) {
        if self.prefetch.is_none() || rows.is_empty() {
            return;
        }
        let stamp = self.next_stamp();
        // Vec::new is allocation-free until the first push, so a fully
        // warm request (every page resident) stays on the zero-alloc
        // contract
        let mut misses: Vec<u32> = Vec::new();
        for &row in rows {
            let page = self.cache.page_of(row);
            if self.seen[page] == stamp {
                continue;
            }
            self.seen[page] = stamp;
            if self.cache.get(page).is_none() {
                misses.push(page as u32);
            }
        }
        if !misses.is_empty() {
            self.prefetch.as_ref().unwrap().request(misses);
        }
    }

    fn write_row(&mut self, row: usize, values: &[f32]) -> Result<()> {
        self.store.write_row(row, values, &mut self.scratch)?;
        self.cache.invalidate_rows(&[row]);
        // the staging pool may hold a pre-write copy of the page (staged
        // but never taken, e.g. when admission read around the cache) —
        // purge it or the next miss would re-admit stale values
        if let Some(pf) = &self.prefetch {
            pf.invalidate_page(self.cache.page_of(row));
        }
        Ok(())
    }

    fn invalidate_rows(&mut self, rows: &[usize]) {
        self.cache.invalidate_rows(rows);
        if let Some(pf) = &self.prefetch {
            let mut last = usize::MAX;
            for &row in rows {
                let page = self.cache.page_of(row);
                if page != last {
                    pf.invalidate_page(page);
                    last = page;
                }
            }
        }
    }

    fn take_stats(&mut self) -> StorageStats {
        if let Some(pf) = &self.prefetch {
            self.bytes_read += pf.drain_bytes_read();
        }
        let stats = StorageStats {
            hits: self.hits,
            faults: self.faults,
            bytes_read: self.bytes_read,
        };
        self.hits = 0;
        self.faults = 0;
        self.bytes_read = 0;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_mat(rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |i, j| (i * 17 + j) as f32 * 0.5 - 4.0)
    }

    fn paged(x: &Mat, capacity: usize, page_rows: usize, cache_pages: usize) -> PagedFeatures {
        let path = spill_path("src-test");
        let mut store = PagedStore::create_from_mat(&path, x, capacity).unwrap();
        store.set_delete_on_drop(true);
        PagedFeatures::new(Arc::new(store), page_rows, cache_pages)
    }

    fn gather_all(src: &mut dyn FeatureSource, rows: &[usize]) -> Vec<f32> {
        let mut out = vec![0f32; rows.len() * src.width()];
        src.gather(rows, &mut out).unwrap();
        out
    }

    #[test]
    fn memory_and_paged_gathers_agree_even_under_eviction() {
        let x = demo_mat(30, 5);
        let mut mem = MemoryFeatures::padded(&x, 32);
        // 2-slot cache over 8 pages: every gather pattern evicts
        let mut pg = paged(&x, 32, 4, 2);
        let patterns: Vec<Vec<usize>> = vec![
            (0..32).collect(),
            vec![31, 0, 17, 17, 3, 29],
            vec![5; 8],
            (0..32).rev().collect(),
        ];
        for rows in &patterns {
            assert_eq!(
                gather_all(&mut mem, rows),
                gather_all(&mut pg, rows),
                "pattern {rows:?} diverged"
            );
        }
        let st = pg.take_stats();
        assert!(st.faults > 0, "2-slot cache must fault");
        assert!(st.hits > 0, "repeated rows must hit");
        assert!(st.bytes_read > 0);
        // counters drained
        assert_eq!(pg.take_stats(), StorageStats::default());
    }

    #[test]
    fn warm_cache_serves_without_disk_reads() {
        let x = demo_mat(16, 3);
        let mut pg = paged(&x, 16, 4, 4); // whole matrix fits
        let rows: Vec<usize> = (0..16).collect();
        let _ = gather_all(&mut pg, &rows);
        let _ = pg.take_stats();
        let again = gather_all(&mut pg, &rows);
        let st = pg.take_stats();
        assert_eq!(st.faults, 0, "warm cache must not fault");
        assert_eq!(st.bytes_read, 0, "warm cache must not touch the disk");
        assert_eq!(st.hits, 16);
        assert_eq!(again, gather_all(&mut MemoryFeatures::padded(&x, 16), &rows));
    }

    #[test]
    fn write_row_invalidates_precisely_and_readers_see_fresh_values() {
        let x = demo_mat(16, 3);
        let mut a = paged(&x, 16, 4, 4);
        let rows: Vec<usize> = (0..16).collect();
        let _ = gather_all(&mut a, &rows); // warm every page
        // a second source over the SAME file (another shard's cache)
        let mut b = PagedFeatures::new(Arc::clone(a.store()), 4, 4);
        let _ = gather_all(&mut b, &rows); // also warm
        let stale = gather_all(&mut b, &[5]);
        let fresh = [7.5f32, -2.0, 11.0];
        a.write_row(5, &fresh).unwrap();
        // the writer's own cache dropped exactly page 1
        assert_eq!(a.resident_pages(), 3);
        assert_eq!(&gather_all(&mut a, &[5])[..], &fresh);
        // the other cache still holds the stale page — THE stale-read
        // hazard — until it is told to invalidate (in a fleet, the same
        // update fans out to every shard, which replays the write)
        assert_eq!(gather_all(&mut b, &[5]), stale, "b unexpectedly saw the write");
        b.invalidate_rows(&[5]);
        assert_eq!(&gather_all(&mut b, &[5])[..], &fresh);
    }

    #[test]
    fn write_row_purges_staged_prefetch_copies() {
        let x = demo_mat(16, 3);
        let mut pg = paged(&x, 16, 4, 4).with_prefetch();
        let rows: Vec<usize> = (0..16).collect();
        // stage every page but gather nothing — the staged copies sit
        // in the pool untaken, exactly the stale-read hazard
        pg.stage(&rows);
        // prefetch bytes are accounted when a page installs, so the
        // drained counter reaching the full store proves staging is done
        let all_bytes = (16 * 3 * 4) as u64;
        let mut total = 0u64;
        for _ in 0..500 {
            total += pg.take_stats().bytes_read;
            if total >= all_bytes {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(total >= all_bytes, "staging never completed");
        let fresh = [40.0f32, 41.0, 42.0];
        pg.write_row(5, &fresh).unwrap();
        // the miss path prefers staged pages — a stale staged copy of
        // page 1 would be admitted and served here
        assert_eq!(&gather_all(&mut pg, &[5])[..], &fresh, "gather served pre-write bytes");
        // invalidate_rows must purge staging the same way
        pg.stage(&rows);
        std::thread::sleep(std::time::Duration::from_millis(20));
        let mut scratch = vec![0u8; 64];
        pg.store().write_row(9, &[9.0, 9.5, 10.0], &mut scratch).unwrap();
        pg.invalidate_rows(&[9]);
        assert_eq!(&gather_all(&mut pg, &[9])[..], &[9.0, 9.5, 10.0]);
    }

    #[test]
    fn out_of_bounds_gather_errors_instead_of_panicking() {
        let x = demo_mat(10, 3);
        let mut mem = MemoryFeatures::padded(&x, 12);
        let mut pg = paged(&x, 12, 4, 2);
        let mut out = vec![0f32; 2 * 3];
        let err = mem.gather(&[0, 12], &mut out).unwrap_err();
        assert!(err.to_string().contains("12"), "memory error names the row: {err}");
        let err = pg.gather(&[0, 99], &mut out).unwrap_err();
        assert!(err.to_string().contains("99"), "paged error names the row: {err}");
        // in-bounds gathers still work after the failed call
        let mut one = vec![0f32; 3];
        pg.gather(&[3], &mut one).unwrap();
        assert_eq!(one, x.row(3));
    }

    #[test]
    fn to_mat_round_trips_through_the_trait() {
        let x = demo_mat(10, 4);
        let mut mem = MemoryFeatures::padded(&x, 12);
        let mut pg = paged(&x, 12, 4, 1);
        assert_eq!(mem.to_mat().unwrap(), pg.to_mat().unwrap());
        assert_eq!((pg.rows(), pg.width()), (12, 4));
    }

    #[test]
    fn stage_then_gather_uses_the_staged_pages() {
        let x = demo_mat(64, 3);
        let mut pg = paged(&x, 64, 4, 16).with_prefetch();
        let rows: Vec<usize> = (0..64).collect();
        pg.stage(&rows);
        // give the worker a moment, then gather — correctness must not
        // depend on the race, only the bytes accounting moves around
        std::thread::sleep(std::time::Duration::from_millis(20));
        let got = gather_all(&mut pg, &rows);
        assert_eq!(got, gather_all(&mut MemoryFeatures::padded(&x, 64), &rows));
        let st = pg.take_stats();
        assert!(st.bytes_read > 0, "someone must have read the disk");
    }
}
