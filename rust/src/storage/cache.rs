//! `cache` — fixed-capacity page cache with TinyLFU-lite admission and
//! epoch-versioned invalidation.
//!
//! CacheG generalized to the storage tier: where `incremental::cache`
//! versions *activation rows*, this caches *feature pages* (runs of
//! `page_rows` contiguous rows) under the same epoch scheme — a slot is
//! valid iff its stamp equals the cache epoch, `invalidate_all` is an
//! O(1) epoch bump, and precise invalidation stamps single slots to 0
//! (the never-valid epoch), so GrAd feature churn drops exactly the
//! dirtied pages and nothing else.
//!
//! Admission is TinyLFU-lite: a 4-row count-min sketch of page access
//! frequencies gates every fill. A missed page only displaces the clock
//! victim when its estimated frequency is at least the victim's —
//! one-touch scan pages cannot wash a hot working set out of a small
//! cache (the classic LRU burst-pollution failure). Rejected fills are
//! not errors: the caller reads around the cache and correctness is
//! unaffected.
//!
//! Every post-construction operation is allocation-free — the warm-hit
//! path (lookup + row copy) is on the zero-steady-state-allocation
//! contract `tests/plan_alloc.rs` enforces.

/// Empty/invalid sentinel for slot↔page maps.
const EMPTY: u32 = u32::MAX;

/// TinyLFU-lite frequency sketch: 4 hash rows of saturating 8-bit
/// counters, halved every `sample` touches so stale popularity decays.
#[derive(Debug)]
pub struct FreqSketch {
    counters: Vec<u8>,
    mask: u64,
    touches: u64,
    sample: u64,
}

/// splitmix64 — cheap, well-mixed stateless hash for sketch rows.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

const ROW_SALTS: [u64; 4] = [0xA11C_E001, 0xA11C_E002, 0xA11C_E003, 0xA11C_E004];

impl FreqSketch {
    /// Sketch sized for `slots` cache entries (≥ 8× slots counters per
    /// row, power of two for mask indexing).
    pub fn new(slots: usize) -> FreqSketch {
        let w = (slots.max(8) * 8).next_power_of_two();
        FreqSketch {
            counters: vec![0; w * 4],
            mask: (w - 1) as u64,
            touches: 0,
            // decay period ≈ 8 accesses per counter column, the
            // TinyLFU "sample size" that keeps estimates fresh
            sample: (w as u64) * 8,
        }
    }

    /// Record one access to `key`.
    pub fn touch(&mut self, key: u64) {
        let w = (self.mask + 1) as usize;
        for (row, salt) in ROW_SALTS.iter().enumerate() {
            let idx = row * w + (mix(key ^ salt) & self.mask) as usize;
            self.counters[idx] = self.counters[idx].saturating_add(1);
        }
        self.touches += 1;
        if self.touches >= self.sample {
            self.touches = 0;
            for c in &mut self.counters {
                *c >>= 1;
            }
        }
    }

    /// Estimated access count (count-min: min over the hash rows).
    pub fn estimate(&self, key: u64) -> u8 {
        let w = (self.mask + 1) as usize;
        ROW_SALTS
            .iter()
            .enumerate()
            .map(|(row, salt)| self.counters[row * w + (mix(key ^ salt) & self.mask) as usize])
            .min()
            .unwrap_or(0)
    }
}

/// Fixed-capacity feature-page cache (see the module docs).
///
/// Geometry: the backing matrix has `num_rows × width` entries split
/// into `⌈num_rows / page_rows⌉` pages; the cache holds at most `slots`
/// of them, each in a preallocated arena segment.
#[derive(Debug)]
pub struct PageCache {
    page_rows: usize,
    width: usize,
    num_rows: usize,
    num_pages: usize,
    slots: usize,
    /// Page arena: `slots × page_rows × width`.
    data: Vec<f32>,
    /// Per slot: cached page id, or [`EMPTY`].
    slot_page: Vec<u32>,
    /// Per slot: epoch stamp (valid iff `== epoch`; 0 = never valid).
    slot_epoch: Vec<u64>,
    /// Per backing page: owning slot, or [`EMPTY`].
    page_slot: Vec<u32>,
    /// Current epoch; starts at 1 so stamp 0 is never valid.
    epoch: u64,
    /// Clock hand for victim selection.
    hand: usize,
    sketch: FreqSketch,
}

impl PageCache {
    /// Cache for a `num_rows × width` backing matrix, `page_rows` rows
    /// per page, at most `slots` resident pages.
    pub fn new(num_rows: usize, width: usize, page_rows: usize, slots: usize) -> PageCache {
        assert!(page_rows > 0, "page_rows must be ≥ 1");
        assert!(slots > 0, "cache needs ≥ 1 page slot");
        let num_pages = num_rows.div_ceil(page_rows);
        let slots = slots.min(num_pages.max(1));
        PageCache {
            page_rows,
            width,
            num_rows,
            num_pages,
            slots,
            data: vec![0.0; slots * page_rows * width],
            slot_page: vec![EMPTY; slots],
            slot_epoch: vec![0; slots],
            page_slot: vec![EMPTY; num_pages],
            epoch: 1,
            hand: 0,
            sketch: FreqSketch::new(slots),
        }
    }

    /// Page holding `row`.
    #[inline]
    pub fn page_of(&self, row: usize) -> usize {
        row / self.page_rows
    }

    /// Rows per page.
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Total pages in the backing matrix.
    pub fn num_pages(&self) -> usize {
        self.num_pages
    }

    /// Resident-page capacity.
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Currently valid resident pages (test/metrics gauge).
    pub fn valid_pages(&self) -> usize {
        (0..self.slots).filter(|&s| self.slot_valid(s)).count()
    }

    #[inline]
    fn slot_valid(&self, slot: usize) -> bool {
        self.slot_epoch[slot] == self.epoch && self.slot_page[slot] != EMPTY
    }

    /// Record an access for admission purposes (call once per page
    /// touch, hit or miss).
    #[inline]
    pub fn touch(&mut self, page: usize) {
        self.sketch.touch(page as u64);
    }

    /// The cached page, if resident and valid: `rows_in_page × width`
    /// row-major floats. Allocation-free.
    #[inline]
    pub fn get(&self, page: usize) -> Option<&[f32]> {
        let slot = self.page_slot[page];
        if slot == EMPTY {
            return None;
        }
        let slot = slot as usize;
        if !self.slot_valid(slot) || self.slot_page[slot] != page as u32 {
            return None;
        }
        let seg = self.page_rows * self.width;
        Some(&self.data[slot * seg..(slot + 1) * seg])
    }

    /// One cached feature row, if its page is resident. Allocation-free.
    #[inline]
    pub fn row(&self, row: usize) -> Option<&[f32]> {
        let page = self.page_of(row);
        let pg = self.get(page)?;
        let off = (row - page * self.page_rows) * self.width;
        Some(&pg[off..off + self.width])
    }

    /// Rows actually present in `page` (the last page may be partial).
    #[inline]
    pub fn rows_in_page(&self, page: usize) -> usize {
        self.page_rows.min(self.num_rows - page * self.page_rows)
    }

    /// Try to admit `page`, filling its arena segment via `fill`
    /// (handed `rows_in_page × width` floats). Returns `Ok(false)` when
    /// the TinyLFU duel rejects the page (caller reads around the
    /// cache), `Ok(true)` on admission. A failed `fill` leaves the slot
    /// invalid and propagates the error.
    pub fn admit<E>(
        &mut self,
        page: usize,
        fill: impl FnOnce(&mut [f32]) -> Result<(), E>,
    ) -> Result<bool, E> {
        debug_assert!(page < self.num_pages);
        let slot = match self.pick_slot(page) {
            Some(s) => s,
            None => return Ok(false),
        };
        // unmap whatever the slot held; map the new page only when the
        // fill lands, so an IO error cannot leave a valid garbage slot
        let old = self.slot_page[slot];
        if old != EMPTY {
            self.page_slot[old as usize] = EMPTY;
        }
        self.slot_page[slot] = EMPTY;
        self.slot_epoch[slot] = 0;
        let seg = self.page_rows * self.width;
        let live = self.rows_in_page(page) * self.width;
        fill(&mut self.data[slot * seg..slot * seg + live])?;
        self.slot_page[slot] = page as u32;
        self.slot_epoch[slot] = self.epoch;
        self.page_slot[page] = slot as u32;
        Ok(true)
    }

    /// Choose the slot for `page`: a stale/free slot if any, else the
    /// clock victim — admitted only if the candidate's sketch estimate
    /// is at least the victim's.
    fn pick_slot(&mut self, page: usize) -> Option<usize> {
        // revalidating the page's own (invalidated) slot is free
        let own = self.page_slot[page];
        if own != EMPTY {
            return Some(own as usize);
        }
        for i in 0..self.slots {
            let s = (self.hand + i) % self.slots;
            if !self.slot_valid(s) {
                self.hand = (s + 1) % self.slots;
                return Some(s);
            }
        }
        let victim = self.hand;
        self.hand = (self.hand + 1) % self.slots;
        let vpage = self.slot_page[victim] as u64;
        if self.sketch.estimate(page as u64) >= self.sketch.estimate(vpage) {
            Some(victim)
        } else {
            None
        }
    }

    /// Precisely invalidate the pages holding `rows` (GrAd churn: only
    /// the dirtied pages drop; everything else stays warm).
    pub fn invalidate_rows(&mut self, rows: &[usize]) {
        for &row in rows {
            let page = self.page_of(row);
            let slot = self.page_slot[page];
            if slot != EMPTY {
                self.slot_epoch[slot as usize] = 0;
            }
        }
    }

    /// Drop every resident page at once (epoch bump, O(slots) only via
    /// the lazy validity checks — no arena writes).
    pub fn invalidate_all(&mut self) {
        self.epoch += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fill pattern: row-major value encodes (row, col).
    fn fill_for(page: usize, page_rows: usize, width: usize) -> Vec<f32> {
        let mut v = Vec::new();
        for r in 0..page_rows {
            for c in 0..width {
                v.push((page * page_rows + r) as f32 * 100.0 + c as f32);
            }
        }
        v
    }

    fn admit_ok(c: &mut PageCache, page: usize) -> bool {
        let want = fill_for(page, c.page_rows(), 3);
        c.admit::<()>(page, |dst| {
            dst.copy_from_slice(&want[..dst.len()]);
            Ok(())
        })
        .unwrap()
    }

    #[test]
    fn hit_returns_the_filled_rows_and_partial_last_page_is_short() {
        let mut c = PageCache::new(10, 3, 4, 2); // pages: 4,4,2 rows
        assert_eq!(c.num_pages(), 3);
        c.touch(2);
        assert!(admit_ok(&mut c, 2), "empty cache must admit");
        assert_eq!(c.rows_in_page(2), 2);
        assert_eq!(c.row(9).unwrap(), &[900.0, 901.0, 902.0]);
        assert!(c.row(0).is_none(), "page 0 never admitted");
    }

    #[test]
    fn eviction_is_admission_gated_by_frequency() {
        let mut c = PageCache::new(16, 3, 4, 2); // 4 pages, 2 slots
        for _ in 0..5 {
            c.touch(0);
            c.touch(1);
        }
        assert!(admit_ok(&mut c, 0));
        assert!(admit_ok(&mut c, 1));
        // a one-touch page must not displace the hot working set
        c.touch(2);
        assert!(!admit_ok(&mut c, 2), "cold page washed out a hot one");
        assert!(c.get(0).is_some() && c.get(1).is_some());
        // ...but once it gets hotter than the victim, it wins the duel
        for _ in 0..9 {
            c.touch(2);
        }
        assert!(admit_ok(&mut c, 2), "hot page must eventually be admitted");
        assert!(c.get(2).is_some());
        assert_eq!(c.valid_pages(), 2);
    }

    #[test]
    fn invalidate_rows_drops_exactly_the_dirty_page() {
        let mut c = PageCache::new(16, 3, 4, 4);
        for p in 0..4 {
            c.touch(p);
            assert!(admit_ok(&mut c, p));
        }
        assert_eq!(c.valid_pages(), 4);
        c.invalidate_rows(&[5]); // page 1
        assert!(c.get(1).is_none(), "dirty page must drop");
        assert!(c.get(0).is_some() && c.get(2).is_some() && c.get(3).is_some());
        assert_eq!(c.valid_pages(), 3);
        // the dropped page revalidates in place on the next fill
        assert!(admit_ok(&mut c, 1));
        assert_eq!(c.valid_pages(), 4);
    }

    #[test]
    fn invalidate_all_is_an_epoch_bump() {
        let mut c = PageCache::new(8, 2, 4, 2);
        c.touch(0);
        assert!(admit_ok(&mut c, 0));
        c.invalidate_all();
        assert!(c.get(0).is_none());
        assert_eq!(c.valid_pages(), 0);
        // slots are reusable immediately
        c.touch(1);
        assert!(admit_ok(&mut c, 1));
        assert!(c.get(1).is_some());
    }

    #[test]
    fn failed_fill_leaves_the_slot_invalid() {
        let mut c = PageCache::new(8, 2, 4, 2);
        c.touch(0);
        let err = c.admit(0, |_| Err("disk gone")).unwrap_err();
        assert_eq!(err, "disk gone");
        assert!(c.get(0).is_none(), "half-filled slot must not serve");
    }

    #[test]
    fn sketch_decays_and_estimates_monotonically() {
        let mut s = FreqSketch::new(4);
        for _ in 0..10 {
            s.touch(7);
        }
        assert!(s.estimate(7) >= 8);
        assert_eq!(s.estimate(8), 0);
        for _ in 0..s.sample {
            s.touch(1);
        }
        assert!(s.estimate(7) < 10, "decay never halved the counters");
    }
}
