//! `prefetch` — frontier-driven page prefetch.
//!
//! The incremental engine's round plan (k-hop frontier rings) and a
//! fleet shard's halo import list are both known **before** the round's
//! layer-0 gather runs, so the pages they will touch can be read while
//! the engine is still binding tiles and gathering the norm mask. A
//! [`Prefetcher`] owns one background thread issuing `pread`s against
//! the shared [`PagedStore`] into a small staging pool; the miss path
//! drains staged pages into the cache with a memcpy instead of a
//! blocking disk read.
//!
//! The staging pool is bounded (requests past the pool size are simply
//! not staged — the miss path falls back to a direct read), and a fully
//! warm request is free: callers skip pages already resident before
//! handing the list over, so a zero-miss round sends nothing and
//! allocates nothing.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::storage::store::PagedStore;

/// Staging slots per prefetcher: bounds both memory (`slots × page
/// bytes`) and the useful lookahead depth.
const STAGE_SLOTS: usize = 32;

const EMPTY: u32 = u32::MAX;

struct StageSlot {
    /// Staged page id, or [`EMPTY`].
    page: u32,
    /// Live rows in the staged page (last page may be partial).
    rows: u32,
    data: Vec<f32>,
}

struct Staging {
    slots: Vec<StageSlot>,
    /// Round-robin write cursor.
    cursor: usize,
    /// Bytes read from disk by the worker since the last drain.
    bytes_read: u64,
    /// Invalidation fence: bumped by [`Prefetcher::invalidate_page`].
    /// The worker snapshots it before a read and refuses to install the
    /// bytes if it moved — a page read that raced a write can never be
    /// staged, so staging never serves pre-write values.
    epoch: u64,
}

enum Job {
    Pages(Vec<u32>),
    Stop,
}

/// Background page reader over a shared [`PagedStore`] (see the module
/// docs).
pub struct Prefetcher {
    tx: Sender<Job>,
    staging: Arc<Mutex<Staging>>,
    worker: Option<JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn the worker for `store` at `page_rows`-row page granularity.
    pub fn spawn(store: Arc<PagedStore>, page_rows: usize) -> Prefetcher {
        let width = store.width();
        let staging = Arc::new(Mutex::new(Staging {
            slots: (0..STAGE_SLOTS)
                .map(|_| StageSlot {
                    page: EMPTY,
                    rows: 0,
                    data: vec![0.0; page_rows * width],
                })
                .collect(),
            cursor: 0,
            bytes_read: 0,
            epoch: 0,
        }));
        let (tx, rx): (Sender<Job>, Receiver<Job>) = mpsc::channel();
        let pool = Arc::clone(&staging);
        let worker = std::thread::Builder::new()
            .name("grannite-prefetch".into())
            .spawn(move || {
                let mut scratch = vec![0u8; page_rows * width * 4];
                let mut local = vec![0f32; page_rows * width];
                while let Ok(Job::Pages(pages)) = rx.recv() {
                    for &page in &pages {
                        let page = page as usize;
                        let row0 = page * page_rows;
                        if row0 >= store.rows() {
                            continue;
                        }
                        let count = page_rows.min(store.rows() - row0);
                        // short lock: dedup + fence snapshot, no IO
                        let epoch = {
                            let pool = pool.lock().unwrap();
                            if pool.slots.iter().any(|s| s.page == page as u32) {
                                continue; // already staged
                            }
                            pool.epoch
                        };
                        // the blocking pread runs OUTSIDE the lock so
                        // foreground take()/miss paths never serialize
                        // behind background disk IO
                        let dst = &mut local[..count * width];
                        if store.read_rows(row0, count, dst, &mut scratch).is_err() {
                            continue;
                        }
                        let mut pool = pool.lock().unwrap();
                        if pool.epoch != epoch {
                            // an invalidation raced the read — these
                            // bytes may predate a write; drop them and
                            // let the miss path read the fresh store
                            continue;
                        }
                        let cur = pool.cursor;
                        pool.cursor = (cur + 1) % STAGE_SLOTS;
                        let slot = &mut pool.slots[cur];
                        slot.page = page as u32;
                        slot.rows = count as u32;
                        slot.data[..count * width].copy_from_slice(dst);
                        pool.bytes_read += (count * width * 4) as u64;
                    }
                }
            })
            .expect("spawning prefetch worker");
        Prefetcher { tx, staging, worker: Some(worker) }
    }

    /// Queue `pages` for background reads. Callers pre-filter pages
    /// already resident in their cache; an empty list is never sent.
    pub fn request(&self, pages: Vec<u32>) {
        if !pages.is_empty() {
            let _ = self.tx.send(Job::Pages(pages));
        }
    }

    /// Drain a staged page into `dst` (`rows_in_page × width` floats).
    /// Returns the live row count, or `None` when the page is not
    /// staged (caller reads the disk directly). Allocation-free.
    pub fn take(&self, page: usize, dst: &mut [f32]) -> Option<usize> {
        let mut pool = self.staging.lock().unwrap();
        let slot = pool.slots.iter_mut().find(|s| s.page == page as u32)?;
        let rows = slot.rows as usize;
        let live = dst.len().min(slot.data.len());
        dst[..live].copy_from_slice(&slot.data[..live]);
        slot.page = EMPTY;
        Some(rows)
    }

    /// Purge any staged copy of `page` and fence in-flight reads: a
    /// read the worker started before this call will not be installed.
    /// The owning source's write/invalidate paths call this so staging
    /// can never re-serve pre-write bytes (the staged-then-never-taken
    /// page would otherwise be admitted into the cache stale).
    pub fn invalidate_page(&self, page: usize) {
        let mut pool = self.staging.lock().unwrap();
        pool.epoch += 1;
        for s in pool.slots.iter_mut() {
            if s.page == page as u32 {
                s.page = EMPTY;
            }
        }
    }

    /// Bytes the worker has read from disk since the last call
    /// (accounted into the owning source's storage stats).
    pub fn drain_bytes_read(&self) -> u64 {
        let mut pool = self.staging.lock().unwrap();
        std::mem::take(&mut pool.bytes_read)
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Stop);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for Prefetcher {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Prefetcher").finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::store::spill_path;
    use crate::tensor::Mat;

    #[test]
    fn staged_pages_are_taken_once_and_match_the_store() {
        let x = Mat::from_fn(20, 3, |i, j| (i * 10 + j) as f32);
        let path = spill_path("prefetch-test");
        let mut store = PagedStore::create_from_mat(&path, &x, 20).unwrap();
        store.set_delete_on_drop(true);
        let store = Arc::new(store);
        let pf = Prefetcher::spawn(Arc::clone(&store), 4);
        pf.request(vec![1, 3]);
        // the worker runs asynchronously; poll briefly for the stage
        let mut buf = vec![0f32; 4 * 3];
        let mut got = None;
        for _ in 0..200 {
            if let Some(rows) = pf.take(1, &mut buf) {
                got = Some(rows);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(got, Some(4), "page 1 never staged");
        for r in 0..4 {
            assert_eq!(&buf[r * 3..(r + 1) * 3], x.row(4 + r));
        }
        // taken pages are consumed
        assert!(pf.take(1, &mut buf).is_none());
        assert!(pf.drain_bytes_read() >= (4 * 3 * 4) as u64);
    }

    #[test]
    fn invalidate_page_purges_the_staged_copy() {
        let x = Mat::from_fn(20, 3, |i, j| (i * 10 + j) as f32);
        let path = spill_path("prefetch-inval-test");
        let mut store = PagedStore::create_from_mat(&path, &x, 20).unwrap();
        store.set_delete_on_drop(true);
        let store = Arc::new(store);
        let pf = Prefetcher::spawn(Arc::clone(&store), 4);
        pf.request(vec![2]);
        // bytes are accounted in the same critical section that installs
        // the slot, so observing them proves the page is staged
        let page_bytes = (4 * 3 * 4) as u64;
        let mut total = 0u64;
        for _ in 0..500 {
            total += pf.drain_bytes_read();
            if total >= page_bytes {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(total >= page_bytes, "page 2 never staged");
        pf.invalidate_page(2);
        let mut buf = vec![0f32; 4 * 3];
        assert!(pf.take(2, &mut buf).is_none(), "invalidated page still staged");
        // the cleared slot defeats the worker's dedup, so a re-request
        // re-reads the store instead of being skipped as already staged
        pf.request(vec![2]);
        let mut got = None;
        for _ in 0..500 {
            if let Some(rows) = pf.take(2, &mut buf) {
                got = Some(rows);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(got, Some(4), "page 2 never re-staged after invalidation");
        for r in 0..4 {
            assert_eq!(&buf[r * 3..(r + 1) * 3], x.row(8 + r));
        }
    }
}
