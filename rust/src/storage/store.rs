//! `store` — the page-aligned, file-backed node-feature store.
//!
//! On disk a store is a **valid `.gnnt` container** (the same format
//! `runtime::io` reads and `python/compile/gnnt.py` writes) holding two
//! tensors: a `U8` filler named `_pad` and the `F32` feature matrix
//! `x_pad` of shape `capacity × width`. The filler is sized so the
//! `x_pad` payload begins exactly on a [`PAGE_ALIGN`]-byte boundary —
//! `runtime::io::read_gnnt` can still slurp the whole file (tooling,
//! debugging), while the serving path never does: rows are fetched with
//! `pread`-style [`std::os::unix::fs::FileExt::read_at`] offset reads,
//! so one shared [`PagedStore`] handle serves every shard thread with no
//! seek state and no locks.
//!
//! The payload is plain row-major `f32` little-endian, identical to the
//! in-memory `x_pad` binding — a "page" is purely a *read granularity*
//! (`page_rows` contiguous rows) chosen by the cache tier, not a file
//! format property, so the same store file serves any page size.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::Mat;

/// Byte alignment of the feature payload inside the store file. 4 KiB
/// matches the kernel page size the `pread` calls ultimately hit.
pub const PAGE_ALIGN: u64 = 4096;

/// Filler-tensor magic: the `_pad` tensor's first bytes carry this tag
/// plus the store geometry, so `open` can validate a file was written
/// by [`PagedStore::create`] (and not an arbitrary `.gnnt` artifact).
const PAD_MAGIC: &[u8; 8] = b"GRNSTOR1";

/// A file-backed `capacity × width` feature matrix read by offset.
///
/// Shared across shards behind an `Arc`: reads ([`PagedStore::read_rows`])
/// and row write-through ([`PagedStore::write_row`]) both take `&self`
/// (positioned IO needs no seek cursor).
pub struct PagedStore {
    file: File,
    path: PathBuf,
    data_offset: u64,
    rows: usize,
    width: usize,
    delete_on_drop: bool,
}

/// Header bytes before the filler payload: magic(4) + version(4) +
/// count(4) + `_pad` record prefix (name_len(2) + "_pad"(4) + dtype(1) +
/// ndim(1) + shape(4)).
const PAD_PREFIX: u64 = 12 + 2 + 4 + 1 + 1 + 4;
/// `x_pad` record prefix after the filler payload: name_len(2) +
/// "x_pad"(5) + dtype(1) + ndim(1) + shape(2×4).
const XPAD_PREFIX: u64 = 2 + 5 + 1 + 1 + 8;

/// Filler payload length so the `x_pad` data lands on [`PAGE_ALIGN`].
fn pad_len() -> u64 {
    let unpadded = PAD_PREFIX + XPAD_PREFIX;
    let mut k = (PAGE_ALIGN - unpadded % PAGE_ALIGN) % PAGE_ALIGN;
    // the filler must hold the magic + geometry (8 + 16 bytes)
    while k < 24 {
        k += PAGE_ALIGN;
    }
    k
}

impl PagedStore {
    /// Create a store at `path`, streaming rows from `fill` (called once
    /// per row with a zeroed `width`-wide scratch) — the full matrix is
    /// **never materialized in RAM**, which is what lets benches build
    /// million-row stores inside a budget the dense path would blow.
    pub fn create(
        path: &Path,
        rows: usize,
        width: usize,
        mut fill: impl FnMut(usize, &mut [f32]),
    ) -> Result<PagedStore> {
        if rows == 0 || width == 0 {
            bail!("paged store needs rows > 0 and width > 0 (got {rows}×{width})");
        }
        let k = pad_len();
        {
            let f = File::create(path)
                .with_context(|| format!("creating feature store {}", path.display()))?;
            let mut w = BufWriter::new(f);
            // .gnnt container header: 2 tensors, `_pad` first
            w.write_all(b"GNNT")?;
            w.write_all(&1u32.to_le_bytes())?;
            w.write_all(&2u32.to_le_bytes())?;
            // `_pad`: U8 filler carrying the store tag + geometry
            w.write_all(&4u16.to_le_bytes())?;
            w.write_all(b"_pad")?;
            w.write_all(&[3u8, 1u8])?; // dtype U8, 1-D
            w.write_all(&(k as u32).to_le_bytes())?;
            w.write_all(PAD_MAGIC)?;
            w.write_all(&(rows as u64).to_le_bytes())?;
            w.write_all(&(width as u64).to_le_bytes())?;
            w.write_all(&vec![0u8; k as usize - 24])?;
            // `x_pad`: F32 rows × width, payload page-aligned from here
            w.write_all(&5u16.to_le_bytes())?;
            w.write_all(b"x_pad")?;
            w.write_all(&[0u8, 2u8])?; // dtype F32, 2-D
            w.write_all(&(rows as u32).to_le_bytes())?;
            w.write_all(&(width as u32).to_le_bytes())?;
            let mut row = vec![0f32; width];
            let mut raw = vec![0u8; width * 4];
            for i in 0..rows {
                row.fill(0.0);
                fill(i, &mut row);
                for (src, dst) in row.iter().zip(raw.chunks_exact_mut(4)) {
                    dst.copy_from_slice(&src.to_le_bytes());
                }
                w.write_all(&raw)?;
            }
            w.flush()?;
        }
        PagedStore::open(path)
    }

    /// Create a store from an in-memory feature matrix, NodePad-padded
    /// with zero rows up to `capacity` (the `x_pad` layout every engine
    /// binds).
    pub fn create_from_mat(path: &Path, x: &Mat, capacity: usize) -> Result<PagedStore> {
        if capacity < x.rows {
            bail!("store capacity {} < feature rows {}", capacity, x.rows);
        }
        PagedStore::create(path, capacity, x.cols, |i, out| {
            if i < x.rows {
                out.copy_from_slice(x.row(i));
            }
        })
    }

    /// Open an existing store file, recovering its geometry from the
    /// header (rejects plain `.gnnt` artifacts with an actionable error).
    pub fn open(path: &Path) -> Result<PagedStore> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("opening feature store {}", path.display()))?;
        let mut head = vec![0u8; PAD_PREFIX as usize + 24];
        file.seek(SeekFrom::Start(0))?;
        file.read_exact(&mut head)
            .with_context(|| format!("reading store header {}", path.display()))?;
        if &head[0..4] != b"GNNT" {
            bail!("{} is not a .gnnt container", path.display());
        }
        let u32_at = |b: &[u8], o: usize| {
            u32::from_le_bytes([b[o], b[o + 1], b[o + 2], b[o + 3]])
        };
        let u64_at = |b: &[u8], o: usize| {
            let mut a = [0u8; 8];
            a.copy_from_slice(&b[o..o + 8]);
            u64::from_le_bytes(a)
        };
        let p = PAD_PREFIX as usize;
        if &head[12..14] != 4u16.to_le_bytes().as_slice() || &head[14..18] != b"_pad" {
            bail!(
                "{} is a .gnnt container but not a paged feature store \
                 (missing the `_pad` filler tensor; build one with \
                 `PagedStore::create`)",
                path.display()
            );
        }
        let k = u32_at(&head, 18 + 2) as u64; // dtype+ndim skipped: shape at 20
        if &head[p..p + 8] != PAD_MAGIC {
            bail!(
                "{} has a `_pad` tensor without the {:?} store tag",
                path.display(),
                std::str::from_utf8(PAD_MAGIC).unwrap()
            );
        }
        let rows = u64_at(&head, p + 8) as usize;
        let width = u64_at(&head, p + 16) as usize;
        let data_offset = PAD_PREFIX + k + XPAD_PREFIX;
        if data_offset % PAGE_ALIGN != 0 {
            bail!("{}: payload offset {data_offset} is not page-aligned", path.display());
        }
        let need = data_offset + (rows * width * 4) as u64;
        let have = file.metadata()?.len();
        if have < need {
            bail!(
                "{}: truncated store — {rows}×{width} needs {need} bytes, file has {have}",
                path.display()
            );
        }
        Ok(PagedStore {
            file,
            path: path.to_path_buf(),
            data_offset,
            rows,
            width,
            delete_on_drop: false,
        })
    }

    /// Remove the backing file when this handle drops (launch-time
    /// spill files; pre-built stores opened by path keep theirs).
    pub fn set_delete_on_drop(&mut self, yes: bool) {
        self.delete_on_drop = yes;
    }

    /// Total rows (the NodePad capacity).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Feature width per row.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Backing file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read `count` rows starting at `row0` into `dst` (row-major), via
    /// one positioned read. `scratch` must hold `count·width·4` bytes.
    /// Returns the bytes read. Allocation-free.
    pub fn read_rows(
        &self,
        row0: usize,
        count: usize,
        dst: &mut [f32],
        scratch: &mut [u8],
    ) -> Result<usize> {
        if row0 + count > self.rows {
            bail!("read_rows {row0}+{count} past store end {}", self.rows);
        }
        let nbytes = count * self.width * 4;
        let raw = &mut scratch[..nbytes];
        let off = self.data_offset + (row0 * self.width * 4) as u64;
        self.file
            .read_exact_at(raw, off)
            .with_context(|| format!("pread {nbytes}B at {off} from {}", self.path.display()))?;
        for (src, dst) in raw.chunks_exact(4).zip(dst[..count * self.width].iter_mut()) {
            *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
        }
        Ok(nbytes)
    }

    /// Write one row through to the file (GrAd feature churn). `scratch`
    /// must hold `width·4` bytes. Callers own cache invalidation: every
    /// cache layered over this store must drop the row's page.
    pub fn write_row(&self, row: usize, values: &[f32], scratch: &mut [u8]) -> Result<()> {
        if row >= self.rows {
            bail!("write_row {row} past store end {}", self.rows);
        }
        if values.len() != self.width {
            bail!("write_row got {} values, store width is {}", values.len(), self.width);
        }
        let raw = &mut scratch[..self.width * 4];
        for (src, dst) in values.iter().zip(raw.chunks_exact_mut(4)) {
            dst.copy_from_slice(&src.to_le_bytes());
        }
        let off = self.data_offset + (row * self.width * 4) as u64;
        self.file
            .write_all_at(raw, off)
            .with_context(|| format!("pwrite row {row} to {}", self.path.display()))?;
        Ok(())
    }
}

impl Drop for PagedStore {
    fn drop(&mut self) {
        if self.delete_on_drop {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

impl std::fmt::Debug for PagedStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedStore")
            .field("path", &self.path)
            .field("rows", &self.rows)
            .field("width", &self.width)
            .field("data_offset", &self.data_offset)
            .finish()
    }
}

/// A unique temp-file path for launch-time feature spills.
pub fn spill_path(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "grannite_{tag}_{}_{seq}.gnnt",
        std::process::id()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::io::read_gnnt;
    use crate::tensor::Tensor;

    fn demo_mat(rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |i, j| (i * 31 + j) as f32 * 0.25 - 3.0)
    }

    #[test]
    fn payload_is_page_aligned_and_gnnt_readable() {
        let x = demo_mat(13, 7);
        let path = spill_path("store-test");
        let store = PagedStore::create_from_mat(&path, &x, 20).unwrap();
        assert_eq!(store.data_offset % PAGE_ALIGN, 0, "payload not page-aligned");
        assert_eq!((store.rows(), store.width()), (20, 7));
        // the whole file still parses as a standard .gnnt container
        let tensors = read_gnnt(&path).unwrap();
        match tensors.get("x_pad").unwrap() {
            Tensor::F32 { shape, data } => {
                assert_eq!(shape, &[20, 7]);
                assert_eq!(&data[..13 * 7], &x.data[..]);
                assert!(data[13 * 7..].iter().all(|&v| v == 0.0), "padding not zero");
            }
            other => panic!("x_pad stored as {other:?}"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn read_rows_round_trips_and_open_recovers_geometry() {
        let x = demo_mat(9, 5);
        let path = spill_path("store-test");
        {
            PagedStore::create_from_mat(&path, &x, 9).unwrap();
        }
        let store = PagedStore::open(&path).unwrap();
        assert_eq!((store.rows(), store.width()), (9, 5));
        let mut dst = vec![0f32; 4 * 5];
        let mut scratch = vec![0u8; 4 * 5 * 4];
        let nb = store.read_rows(3, 4, &mut dst, &mut scratch).unwrap();
        assert_eq!(nb, 4 * 5 * 4);
        for r in 0..4 {
            assert_eq!(&dst[r * 5..(r + 1) * 5], x.row(3 + r), "row {}", 3 + r);
        }
        assert!(store.read_rows(7, 4, &mut dst, &mut scratch).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_row_is_read_back() {
        let x = demo_mat(6, 3);
        let path = spill_path("store-test");
        let store = PagedStore::create_from_mat(&path, &x, 6).unwrap();
        let fresh = [9.5f32, -1.25, 0.5];
        let mut scratch = vec![0u8; 3 * 4];
        store.write_row(2, &fresh, &mut scratch).unwrap();
        let mut dst = vec![0f32; 3];
        store.read_rows(2, 1, &mut dst, &mut scratch).unwrap();
        assert_eq!(dst, fresh);
        assert!(store.write_row(6, &fresh, &mut scratch).is_err());
        assert!(store.write_row(0, &[1.0], &mut scratch).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn plain_gnnt_artifacts_are_rejected_actionably() {
        let path = spill_path("store-test");
        let mut t = std::collections::BTreeMap::new();
        t.insert("x".to_string(), Tensor::from_mat(&demo_mat(2, 2)));
        crate::runtime::io::write_gnnt(&path, &t).unwrap();
        let err = PagedStore::open(&path).unwrap_err().to_string();
        assert!(err.contains("_pad"), "unhelpful error: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn delete_on_drop_removes_the_spill() {
        let path = spill_path("store-test");
        {
            let mut s = PagedStore::create_from_mat(&path, &demo_mat(2, 2), 2).unwrap();
            s.set_delete_on_drop(true);
        }
        assert!(!path.exists(), "spill file survived drop");
    }
}
