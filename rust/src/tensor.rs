//! Tensor substrate shared by the graph, ops, quant and runtime layers:
//! a row-major f32 matrix (`Mat`), a compressed-sparse-row matrix
//! (`CsrMat`) for the sparsity-dominated aggregation operands, and a
//! small dtype-tagged tensor (`Tensor`) mirroring the `.gnnt` container's
//! dtypes (plus the in-memory-only CSR variant the SpMM path binds).

use anyhow::{bail, Result};

/// Element types supported across the stack (kept in sync with
/// `python/compile/gnnt.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I8,
    I32,
    U8,
    /// Raw IEEE f16 bits (stored as u16; the simulator only needs sizes).
    F16,
}

impl DType {
    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 => 2,
            DType::I8 | DType::U8 => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I8 => "i8",
            DType::I32 => "i32",
            DType::U8 => "u8",
            DType::F16 => "f16",
        }
    }
}

/// Row-major f32 matrix — the workhorse of the reference executor and the
/// CPU-side (GraphSplit) preprocessing.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Mat {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Matrix product `self @ rhs` (blocked, see `matmul_into`).
    pub fn matmul(&self, rhs: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, rhs.cols);
        self.matmul_into(rhs, &mut out);
        out
    }

    /// `out = self @ rhs`, cache-blocked ikj loop (the hot path of the
    /// reference executor; see EXPERIMENTS.md §Perf for tuning history).
    ///
    /// The GraSp-style zero-skip branch pays off on sparse structure masks
    /// (norm rows are ~99.8% zero) but costs a per-element compare on dense
    /// operands, so the kernel is picked per call from a sampled density.
    pub fn matmul_into(&self, rhs: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, rhs.rows, "matmul inner dims");
        assert_eq!((out.rows, out.cols), (self.rows, rhs.cols));
        let skip = self.sample_density() < SKIP_DENSITY_THRESHOLD;
        matmul_block(
            &self.data,
            self.rows,
            self.cols,
            &rhs.data,
            rhs.cols,
            &mut out.data,
            skip,
        );
    }

    /// Estimated fraction of nonzero entries from a strided sample (at
    /// most [`DENSITY_SAMPLES`] probes) — cheap enough to run per matmul.
    pub fn sample_density(&self) -> f64 {
        sample_density(&self.data)
    }

    /// Transpose.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise combine with another matrix of identical shape.
    pub fn zip(&self, other: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
        assert_eq!(self.shape(), other.shape(), "zip shape mismatch");
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    /// Add a row vector to every row (broadcast bias add).
    pub fn add_row_broadcast(&self, bias: &[f32]) -> Mat {
        assert_eq!(bias.len(), self.cols, "bias width");
        let mut out = self.clone();
        for i in 0..out.rows {
            for (x, b) in out.row_mut(i).iter_mut().zip(bias) {
                *x += b;
            }
        }
        out
    }

    /// Fraction of exactly-zero entries (GraSp telemetry).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f64
            / self.data.len() as f64
    }

    /// Max |a - b| against another matrix.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Row-wise argmax (predictions from logits).
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|i| {
                let row = self.row(i);
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap_or(0)
            })
            .collect()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Below this lhs density the zero-skip matmul kernel wins; above it the
/// branch-free dense kernel does (measured crossover is broad, ~0.2–0.4).
pub const SKIP_DENSITY_THRESHOLD: f64 = 0.25;

/// Probe budget for [`sample_density`].
pub const DENSITY_SAMPLES: usize = 1024;

/// Estimated nonzero fraction of a slice from a strided sample.
pub fn sample_density(data: &[f32]) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    let stride = (data.len() / DENSITY_SAMPLES).max(1);
    let mut nonzero = 0usize;
    let mut count = 0usize;
    let mut i = 0usize;
    while i < data.len() && count < DENSITY_SAMPLES {
        if data[i] != 0.0 {
            nonzero += 1;
        }
        count += 1;
        i += stride;
    }
    nonzero as f64 / count as f64
}

/// What the caller already knows about a matmul lhs' density — the
/// planner records one of these per `MatMul` step so steady-state runs
/// skip the per-call [`sample_density`] probe for operands whose density
/// class is static (computed activations are dense by construction).
///
/// A wrong hint only costs throughput, never correctness: the zero-skip
/// and branch-free kernels accumulate in the same per-element order and
/// agree bitwise on identical inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DensityHint {
    /// Density unknown (external inputs): probe per call.
    #[default]
    Sample,
    /// Known-sparse operand: force the GraSp zero-skip kernel.
    Skip,
    /// Known-dense activation: force the branch-free kernel, no probe.
    NoSkip,
}

impl DensityHint {
    /// Resolve to the kernel's `skip` flag, probing only when unknown.
    #[inline]
    pub fn resolve(self, data: &[f32]) -> bool {
        match self {
            DensityHint::Sample => sample_density(data) < SKIP_DENSITY_THRESHOLD,
            DensityHint::Skip => true,
            DensityHint::NoSkip => false,
        }
    }
}

/// `out = a @ b` over raw row-major slices: `a` is `rows×k`, `b` is `k×n`,
/// `out` is `rows×n`. Cache-blocked ikj loop; `skip` selects the
/// GraSp-style zero-skip variant (identical accumulation order, so both
/// kernels produce bitwise-equal results on finite inputs).
///
/// Shared by [`Mat::matmul_into`] and the planned engine's row-sharded
/// parallel matmul (each worker calls this on a disjoint row block).
pub fn matmul_block(
    a: &[f32],
    rows: usize,
    k: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
    skip: bool,
) {
    assert_eq!(a.len(), rows * k, "matmul lhs size");
    assert_eq!(b.len(), k * n, "matmul rhs size");
    assert_eq!(out.len(), rows * n, "matmul out size");
    out.fill(0.0);
    const BK: usize = 64;
    let mut k0 = 0usize;
    while k0 < k {
        let k1 = (k0 + BK).min(k);
        for i in 0..rows {
            let a_row = &a[i * k..i * k + k];
            let out_row = &mut out[i * n..i * n + n];
            if skip {
                for kk in k0..k1 {
                    let av = a_row[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let b_row = &b[kk * n..kk * n + n];
                    for j in 0..n {
                        out_row[j] += av * b_row[j];
                    }
                }
            } else {
                for kk in k0..k1 {
                    let av = a_row[kk];
                    let b_row = &b[kk * n..kk * n + n];
                    for j in 0..n {
                        out_row[j] += av * b_row[j];
                    }
                }
            }
        }
        k0 = k1;
    }
}

/// Register-tile height of [`matmul_block_simd`] (output rows held in
/// accumulators at once).
pub const MM_TILE_ROWS: usize = 4;
/// Register-tile width of [`matmul_block_simd`] — two 8-wide vector
/// lanes, matching the `f32x8` shape stable Rust auto-vectorizes.
pub const MM_TILE_COLS: usize = 16;

/// [`matmul_block`] with explicit SIMD-style register blocking: 4×16
/// output tiles are loaded into stack accumulators (8 `f32x8` registers
/// after vectorization), updated across a whole k-panel, then stored —
/// cutting `out` load/store traffic 16× and reusing each `b` row across
/// 4 lhs rows. Per output element the accumulation still runs in the
/// same ascending-k order as [`matmul_block`], so the two kernels agree
/// **bitwise**: SIMD is a throughput knob, never a numerics knob, and
/// the scalar kernel stays a valid oracle fallback.
pub fn matmul_block_simd(
    a: &[f32],
    rows: usize,
    k: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
    skip: bool,
) {
    assert_eq!(a.len(), rows * k, "matmul lhs size");
    assert_eq!(b.len(), k * n, "matmul rhs size");
    assert_eq!(out.len(), rows * n, "matmul out size");
    out.fill(0.0);
    const IR: usize = MM_TILE_ROWS;
    const JW: usize = MM_TILE_COLS;
    // Wider k-panel than the scalar kernel: the tile load/store is
    // amortized over the panel, so longer panels win once out traffic
    // is out of the inner loop.
    const BK: usize = 128;
    let mut k0 = 0usize;
    while k0 < k {
        let k1 = (k0 + BK).min(k);
        let mut i = 0usize;
        while i + IR <= rows {
            let mut j = 0usize;
            while j + JW <= n {
                let mut acc = [[0.0f32; JW]; IR];
                for (r, acc_row) in acc.iter_mut().enumerate() {
                    let o = (i + r) * n + j;
                    acc_row.copy_from_slice(&out[o..o + JW]);
                }
                if skip {
                    for kk in k0..k1 {
                        let bp = &b[kk * n + j..kk * n + j + JW];
                        for (r, acc_row) in acc.iter_mut().enumerate() {
                            let av = a[(i + r) * k + kk];
                            if av == 0.0 {
                                continue;
                            }
                            for (l, &bv) in bp.iter().enumerate() {
                                acc_row[l] += av * bv;
                            }
                        }
                    }
                } else {
                    for kk in k0..k1 {
                        let bp = &b[kk * n + j..kk * n + j + JW];
                        for (r, acc_row) in acc.iter_mut().enumerate() {
                            let av = a[(i + r) * k + kk];
                            for (l, &bv) in bp.iter().enumerate() {
                                acc_row[l] += av * bv;
                            }
                        }
                    }
                }
                for (r, acc_row) in acc.iter().enumerate() {
                    let o = (i + r) * n + j;
                    out[o..o + JW].copy_from_slice(acc_row);
                }
                j += JW;
            }
            // narrow column tail: scalar, same ascending-kk order
            if j < n {
                for r in 0..IR {
                    let a_row = &a[(i + r) * k..(i + r) * k + k];
                    let out_row = &mut out[(i + r) * n..(i + r) * n + n];
                    for kk in k0..k1 {
                        let av = a_row[kk];
                        if skip && av == 0.0 {
                            continue;
                        }
                        let b_row = &b[kk * n..kk * n + n];
                        for jj in j..n {
                            out_row[jj] += av * b_row[jj];
                        }
                    }
                }
            }
            i += IR;
        }
        // short row tail: one row at a time, same ascending-kk order
        while i < rows {
            let a_row = &a[i * k..i * k + k];
            let out_row = &mut out[i * n..i * n + n];
            for kk in k0..k1 {
                let av = a_row[kk];
                if skip && av == 0.0 {
                    continue;
                }
                let b_row = &b[kk * n..kk * n + n];
                for (o, &bv) in out_row.iter_mut().zip(b_row) {
                    *o += av * bv;
                }
            }
            i += 1;
        }
        k0 = k1;
    }
}

// ---------------------------------------------------------------------------
// CSR — the sparse aggregation operand
// ---------------------------------------------------------------------------

/// Compressed-sparse-row f32 matrix — the first-class operand of the
/// `SpMM` op. GNN aggregation masks (the GraphConv norm, SAGE sampled
/// masks) are ~99.8% zero at citation-graph scale, so storing
/// `indptr/indices/values` instead of `rows·cols` floats turns the
/// O(n²·d) dense aggregation into the O(nnz·d) SpMM GraSp models, and
/// deletes the n×n buffer as the memory ceiling of every plan and shard.
///
/// Row entries are sorted by column index, which makes SpMM accumulate
/// in exactly the same k-order as the dense zero-skip matmul kernel —
/// the two paths agree bitwise on identical values, not just within
/// tolerance.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMat {
    pub rows: usize,
    pub cols: usize,
    /// Row offsets, length `rows + 1`.
    pub indptr: Vec<u32>,
    /// Column indices, sorted within each row.
    pub indices: Vec<u32>,
    /// One value per stored entry.
    pub values: Vec<f32>,
}

impl CsrMat {
    /// Build from a dense matrix, keeping exactly the non-zero entries.
    pub fn from_dense(m: &Mat) -> CsrMat {
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0u32);
        for i in 0..m.rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        CsrMat { rows: m.rows, cols: m.cols, indptr, indices, values }
    }

    /// Expand to dense (the property-test oracle's view of this operand).
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row_entries(i);
            let orow = out.row_mut(i);
            for (&c, &v) in cols.iter().zip(vals) {
                orow[c as usize] = v;
            }
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of stored entries over the dense element count.
    pub fn density(&self) -> f64 {
        let elems = self.rows * self.cols;
        if elems == 0 {
            0.0
        } else {
            self.nnz() as f64 / elems as f64
        }
    }

    /// Stored bytes (indptr + indices + values).
    pub fn bytes(&self) -> usize {
        (self.indptr.len() + self.indices.len() + self.values.len()) * 4
    }

    /// Dense bytes this replaces.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// SymG-style symmetric storage cost: for a symmetric matrix only the
    /// upper triangle (j ≥ i) needs residency — the DMA engine mirrors
    /// the lower half on expansion. This is the byte count the metrics
    /// layer credits as SymG savings on top of the CSR compression.
    pub fn symg_bytes(&self) -> usize {
        let upper: usize = (0..self.rows)
            .map(|i| {
                let (cols, _) = self.row_entries(i);
                cols.iter().filter(|&&c| c as usize >= i).count()
            })
            .sum();
        (self.indptr.len() + 2 * upper) * 4
    }

    /// True when the stored pattern + values are symmetric (within `tol`).
    pub fn is_symmetric(&self, tol: f32) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            let (cols, vals) = self.row_entries(i);
            for (&c, &v) in cols.iter().zip(vals) {
                if (self.get(c as usize, i) - v).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Entry lookup by binary search (0.0 for absent entries).
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let (cols, vals) = self.row_entries(i);
        match cols.binary_search(&(j as u32)) {
            Ok(p) => vals[p],
            Err(_) => 0.0,
        }
    }

    /// The sorted column indices + values of row `i`.
    #[inline]
    pub fn row_entries(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[i] as usize, self.indptr[i + 1] as usize);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// `self @ rhs` — serial SpMM (the engine row-shards [`spmm_rows`]
    /// across its worker pool; this is the one-shot convenience).
    pub fn spmm(&self, rhs: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, rhs.cols);
        self.spmm_into(rhs, &mut out);
        out
    }

    /// `out = self @ rhs` without allocation.
    pub fn spmm_into(&self, rhs: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, rhs.rows, "spmm inner dims");
        assert_eq!((out.rows, out.cols), (self.rows, rhs.cols));
        spmm_rows(
            &self.indptr,
            &self.indices,
            &self.values,
            0,
            self.rows,
            &rhs.data,
            rhs.cols,
            &mut out.data,
        );
    }
}

/// SpMM over a CSR row block: `out` covers rows `r0..r1` of the product
/// (`(r1-r0)·n` elements, row-major). Accumulation per output row runs in
/// ascending column order — identical to the dense zero-skip kernel's
/// k-order, so parallel row-sharding preserves bitwise agreement with the
/// dense path. Shared by [`CsrMat::spmm_into`] and the planned engine's
/// row-sharded SpMM kernel.
#[allow(clippy::too_many_arguments)]
pub fn spmm_rows(
    indptr: &[u32],
    indices: &[u32],
    values: &[f32],
    r0: usize,
    r1: usize,
    rhs: &[f32],
    n: usize,
    out: &mut [f32],
) {
    debug_assert!(r1 + 1 <= indptr.len());
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    for i in r0..r1 {
        let (a, b) = (indptr[i] as usize, indptr[i + 1] as usize);
        let orow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
        orow.fill(0.0);
        for p in a..b {
            let v = values[p];
            let brow = &rhs[indices[p] as usize * n..indices[p] as usize * n + n];
            for j in 0..n {
                orow[j] += v * brow[j];
            }
        }
    }
}

/// [`spmm_rows`] with explicit SIMD-style blocking: neighbors are
/// processed four at a time against an 8-wide accumulator block held on
/// the stack, so each output cache line is loaded/stored once per four
/// neighbors instead of once per neighbor — the output-traffic bound
/// that dominates high-degree (hub) rows. Each output element is still
/// updated by one add per neighbor in ascending column order, so results
/// are **bitwise identical** to [`spmm_rows`].
#[allow(clippy::too_many_arguments)]
pub fn spmm_rows_simd(
    indptr: &[u32],
    indices: &[u32],
    values: &[f32],
    r0: usize,
    r1: usize,
    rhs: &[f32],
    n: usize,
    out: &mut [f32],
) {
    const JW: usize = 8;
    debug_assert!(r1 + 1 <= indptr.len());
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    for i in r0..r1 {
        let (a, b) = (indptr[i] as usize, indptr[i + 1] as usize);
        let orow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
        orow.fill(0.0);
        let mut p = a;
        while p + 4 <= b {
            let (v0, v1, v2, v3) = (values[p], values[p + 1], values[p + 2], values[p + 3]);
            let b0 = &rhs[indices[p] as usize * n..indices[p] as usize * n + n];
            let b1 = &rhs[indices[p + 1] as usize * n..indices[p + 1] as usize * n + n];
            let b2 = &rhs[indices[p + 2] as usize * n..indices[p + 2] as usize * n + n];
            let b3 = &rhs[indices[p + 3] as usize * n..indices[p + 3] as usize * n + n];
            let mut j = 0usize;
            while j + JW <= n {
                let mut t = [0.0f32; JW];
                t.copy_from_slice(&orow[j..j + JW]);
                for (l, tv) in t.iter_mut().enumerate() {
                    *tv += v0 * b0[j + l];
                }
                for (l, tv) in t.iter_mut().enumerate() {
                    *tv += v1 * b1[j + l];
                }
                for (l, tv) in t.iter_mut().enumerate() {
                    *tv += v2 * b2[j + l];
                }
                for (l, tv) in t.iter_mut().enumerate() {
                    *tv += v3 * b3[j + l];
                }
                orow[j..j + JW].copy_from_slice(&t);
                j += JW;
            }
            while j < n {
                orow[j] += v0 * b0[j];
                orow[j] += v1 * b1[j];
                orow[j] += v2 * b2[j];
                orow[j] += v3 * b3[j];
                j += 1;
            }
            p += 4;
        }
        while p < b {
            let v = values[p];
            let brow = &rhs[indices[p] as usize * n..indices[p] as usize * n + n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += v * bv;
            }
            p += 1;
        }
    }
}

/// A dtype-tagged tensor (arbitrary rank) — the runtime-facing type that
/// mirrors the `.gnnt` container and PJRT literals, plus the in-memory
/// CSR variant bound to `SpMM` sparse operands (CSR tensors never hit
/// the `.gnnt` container — they are rebuilt from the graph).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I8 { shape: Vec<usize>, data: Vec<i8> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U8 { shape: Vec<usize>, data: Vec<u8> },
    F16 { shape: Vec<usize>, data: Vec<u16> },
    /// Sparse f32 matrix (always rank 2; `shape == [rows, cols]`).
    Csr { shape: Vec<usize>, mat: CsrMat },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. }
            | Tensor::I8 { shape, .. }
            | Tensor::I32 { shape, .. }
            | Tensor::U8 { shape, .. }
            | Tensor::F16 { shape, .. }
            | Tensor::Csr { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } | Tensor::Csr { .. } => DType::F32,
            Tensor::I8 { .. } => DType::I8,
            Tensor::I32 { .. } => DType::I32,
            Tensor::U8 { .. } => DType::U8,
            Tensor::F16 { .. } => DType::F16,
        }
    }

    pub fn num_elements(&self) -> usize {
        self.shape().iter().product()
    }

    /// Stored bytes: dense element count × width, except CSR tensors,
    /// which report their compressed footprint (what actually moves).
    pub fn bytes(&self) -> usize {
        match self {
            Tensor::Csr { mat, .. } => mat.bytes(),
            _ => self.num_elements() * self.dtype().size(),
        }
    }

    pub fn from_mat(m: &Mat) -> Tensor {
        Tensor::F32 { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }

    pub fn from_csr(mat: CsrMat) -> Tensor {
        Tensor::Csr { shape: vec![mat.rows, mat.cols], mat }
    }

    /// The CSR payload of a sparse tensor.
    pub fn as_csr(&self) -> Result<&CsrMat> {
        match self {
            Tensor::Csr { mat, .. } => Ok(mat),
            other => bail!("expected CSR tensor, got dense {:?}", other.dtype()),
        }
    }

    pub fn from_vec_f32(data: Vec<f32>) -> Tensor {
        Tensor::F32 { shape: vec![data.len()], data }
    }

    /// View as a 2-D f32 matrix. CSR tensors densify — the reference
    /// executor's (oracle's) view of a sparse operand.
    pub fn to_mat(&self) -> Result<Mat> {
        match self {
            Tensor::F32 { shape, data } if shape.len() == 2 => {
                Ok(Mat::from_vec(shape[0], shape[1], data.clone()))
            }
            Tensor::F32 { shape, data } if shape.len() == 1 => {
                Ok(Mat::from_vec(1, shape[0], data.clone()))
            }
            Tensor::Csr { mat, .. } => Ok(mat.to_dense()),
            other => bail!(
                "expected 1/2-D f32 tensor, got {:?} {:?}",
                other.dtype(),
                other.shape()
            ),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            other => bail!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match self {
            Tensor::I8 { data, .. } => Ok(data),
            other => bail!("expected i8 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            Tensor::U8 { data, .. } => Ok(data),
            other => bail!("expected u8 tensor, got {:?}", other.dtype()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(5, 7, |i, j| (i * 7 + j) as f32);
        let c = Mat::eye(5).matmul(&a);
        assert_eq!(c, a);
    }

    #[test]
    fn matmul_matches_naive() {
        // blocked kernel vs naive triple loop on odd shapes
        let a = Mat::from_fn(13, 67, |i, j| ((i * 31 + j * 7) % 11) as f32 - 5.0);
        let b = Mat::from_fn(67, 9, |i, j| ((i * 13 + j * 3) % 7) as f32 - 3.0);
        let got = a.matmul(&b);
        let mut want = Mat::zeros(13, 9);
        for i in 0..13 {
            for j in 0..9 {
                let mut s = 0.0;
                for k in 0..67 {
                    s += a[(i, k)] * b[(k, j)];
                }
                want[(i, j)] = s;
            }
        }
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn add_row_broadcast() {
        let a = Mat::zeros(2, 3);
        let b = a.add_row_broadcast(&[1.0, 2.0, 3.0]);
        assert_eq!(b.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(b.row(1), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn sparsity_counts_zeros() {
        let m = Mat::from_vec(1, 4, vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(m.sparsity(), 0.5);
    }

    #[test]
    fn argmax_rows_picks_max() {
        let m = Mat::from_vec(2, 3, vec![0.1, 0.9, 0.0, 3.0, -1.0, 2.0]);
        assert_eq!(m.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn tensor_roundtrip_mat() {
        let m = Mat::from_fn(3, 4, |i, j| (i + j) as f32);
        let t = Tensor::from_mat(&m);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.to_mat().unwrap(), m);
        assert_eq!(t.bytes(), 48);
    }

    #[test]
    fn tensor_dtype_mismatch_errors() {
        let t = Tensor::I32 { shape: vec![2], data: vec![1, 2] };
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn csr_roundtrip_dense() {
        let m = Mat::from_vec(
            3,
            4,
            vec![0.0, 1.5, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, -3.0, 0.0, 0.5, 0.0],
        );
        let c = CsrMat::from_dense(&m);
        assert_eq!(c.nnz(), 4);
        assert_eq!(c.to_dense(), m);
        assert_eq!(c.get(0, 1), 1.5);
        assert_eq!(c.get(1, 2), 0.0);
        assert_eq!(c.row_entries(2).0, &[0, 2]);
        assert!((c.density() - 4.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn csr_spmm_matches_dense_matmul() {
        // structure-mask-like lhs across densities; identical accumulation
        // order means exact equality with the zero-skip dense kernel
        let mut state = 0x1234_5678_9abc_def0u64;
        let mut rng = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state % 1000) as f32 / 500.0 - 1.0
        };
        for keep in [0.02f32, 0.3, 1.0] {
            let a = Mat::from_fn(17, 23, |_, _| {
                let v = rng();
                if v.abs() <= keep {
                    v
                } else {
                    0.0
                }
            });
            let b = Mat::from_fn(23, 5, |_, _| rng());
            let want = a.matmul(&b);
            let got = CsrMat::from_dense(&a).spmm(&b);
            assert_eq!(got, want, "keep {keep}");
        }
    }

    #[test]
    fn csr_bytes_and_symg_accounting() {
        // symmetric norm-like matrix: symg storage drops ~half the entries
        let g = crate::graph::Graph::new(
            30,
            &(0..40u32).map(|i| (i % 30, (i * 7 + 1) % 30)).collect::<Vec<_>>(),
        );
        let dense = g.norm_adjacency(30);
        let c = CsrMat::from_dense(&dense);
        assert!(c.is_symmetric(0.0));
        assert!(c.bytes() < c.dense_bytes());
        assert!(c.symg_bytes() < c.bytes());
        // upper-triangle count: (nnz + diagonal) / 2 entries survive
        let diag = (0..30).filter(|&i| c.get(i, i) != 0.0).count();
        let upper = (c.nnz() - diag) / 2 + diag;
        assert_eq!(c.symg_bytes(), (c.indptr.len() + 2 * upper) * 4);
    }

    #[test]
    fn csr_tensor_roundtrip_and_accessors() {
        let m = Mat::from_vec(2, 3, vec![1.0, 0.0, 0.0, 0.0, 2.0, 0.0]);
        let t = Tensor::from_csr(CsrMat::from_dense(&m));
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.num_elements(), 6);
        assert_eq!(t.to_mat().unwrap(), m);
        assert!(t.as_csr().is_ok());
        assert!(t.as_f32().is_err());
        // compressed bytes, not dense bytes
        assert_eq!(t.bytes(), (3 + 2 + 2) * 4);
        assert!(Tensor::from_mat(&m).as_csr().is_err());
    }

    #[test]
    #[should_panic(expected = "matmul inner dims")]
    fn matmul_dim_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        a.matmul(&b);
    }

    #[test]
    fn sample_density_estimates() {
        assert_eq!(Mat::zeros(8, 8).sample_density(), 0.0);
        assert_eq!(Mat::filled(8, 8, 2.0).sample_density(), 1.0);
        let half = Mat::from_fn(4, 8, |_, j| (j % 2) as f32);
        let d = half.sample_density();
        assert!((d - 0.5).abs() < 0.05, "{d}");
        // sampling stays cheap on big matrices: strided, bounded probes
        let big = Mat::from_fn(512, 512, |i, j| ((i + j) % 10 == 0) as u32 as f32);
        let d = big.sample_density();
        assert!(d > 0.02 && d < 0.3, "{d}");
    }

    #[test]
    fn skip_and_dense_kernels_agree() {
        // regression for the density-adaptive dispatch: both kernels must
        // produce identical results on sparse AND dense operands
        let mut rng_state = 88172645463325252u64;
        let mut rng = move || {
            rng_state ^= rng_state << 13;
            rng_state ^= rng_state >> 7;
            rng_state ^= rng_state << 17;
            (rng_state % 1000) as f32 / 500.0 - 1.0
        };
        for density in [0.02f32, 0.9] {
            let (m, k, n) = (17, 67, 9);
            let a: Vec<f32> = (0..m * k)
                .map(|_| {
                    let v = rng();
                    if v.abs() > density {
                        0.0
                    } else {
                        v
                    }
                })
                .collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng()).collect();
            let mut skip_out = vec![0.0f32; m * n];
            let mut dense_out = vec![0.0f32; m * n];
            matmul_block(&a, m, k, &b, n, &mut skip_out, true);
            matmul_block(&a, m, k, &b, n, &mut dense_out, false);
            assert_eq!(skip_out, dense_out, "density {density}");
            // and the auto-dispatching Mat path matches both
            let am = Mat::from_vec(m, k, a.clone());
            let bm = Mat::from_vec(k, n, b.clone());
            assert_eq!(am.matmul(&bm).data, dense_out);
        }
    }

    #[test]
    fn simd_matmul_matches_scalar_bitwise() {
        // register-blocked kernel preserves per-element accumulation
        // order, so it must agree exactly — across ragged shapes (row and
        // column tails, multi-panel k) and both skip modes
        let mut rng_state = 0x9e37_79b9_7f4a_7c15u64;
        let mut rng = move || {
            rng_state ^= rng_state << 13;
            rng_state ^= rng_state >> 7;
            rng_state ^= rng_state << 17;
            (rng_state % 1000) as f32 / 500.0 - 1.0
        };
        for (m, k, n) in [(1, 3, 1), (4, 16, 16), (7, 130, 19), (13, 257, 33)] {
            for density in [0.1f32, 1.0] {
                let a: Vec<f32> = (0..m * k)
                    .map(|_| {
                        let v = rng();
                        if v.abs() > density {
                            0.0
                        } else {
                            v
                        }
                    })
                    .collect();
                let b: Vec<f32> = (0..k * n).map(|_| rng()).collect();
                for skip in [false, true] {
                    let mut scalar = vec![0.0f32; m * n];
                    let mut simd = vec![0.0f32; m * n];
                    matmul_block(&a, m, k, &b, n, &mut scalar, skip);
                    matmul_block_simd(&a, m, k, &b, n, &mut simd, skip);
                    assert_eq!(scalar, simd, "{m}x{k}x{n} skip={skip}");
                }
            }
        }
    }

    #[test]
    fn simd_spmm_matches_scalar_bitwise() {
        // neighbor-blocked kernel keeps ascending-p per-element order;
        // exercise hub rows (≫4 neighbors), short rows, and empty rows
        let mut rng_state = 0x2545_f491_4f6c_dd1du64;
        let mut rng = move || {
            rng_state ^= rng_state << 13;
            rng_state ^= rng_state >> 7;
            rng_state ^= rng_state << 17;
            (rng_state % 1000) as f32 / 500.0 - 1.0
        };
        for n in [1usize, 7, 8, 24, 37] {
            let rows = 19usize;
            let cols = 23usize;
            let a = Mat::from_fn(rows, cols, |i, _| {
                if i % 5 == 3 {
                    return 0.0; // empty row
                }
                let v = rng();
                // row 0 is a hub: keep everything
                if i == 0 || v.abs() < 0.4 {
                    v
                } else {
                    0.0
                }
            });
            let csr = CsrMat::from_dense(&a);
            let rhs: Vec<f32> = (0..cols * n).map(|_| rng()).collect();
            let mut scalar = vec![0.0f32; rows * n];
            let mut simd = vec![0.0f32; rows * n];
            spmm_rows(&csr.indptr, &csr.indices, &csr.values, 0, rows, &rhs, n, &mut scalar);
            spmm_rows_simd(&csr.indptr, &csr.indices, &csr.values, 0, rows, &rhs, n, &mut simd);
            assert_eq!(scalar, simd, "n={n}");
        }
    }

    #[test]
    fn density_hint_resolution() {
        let dense = Mat::filled(8, 8, 1.0);
        let sparse = Mat::zeros(8, 8);
        assert!(!DensityHint::Sample.resolve(&dense.data));
        assert!(DensityHint::Sample.resolve(&sparse.data));
        // static hints never probe: they answer the same for any operand
        assert!(DensityHint::Skip.resolve(&dense.data));
        assert!(!DensityHint::NoSkip.resolve(&sparse.data));
    }

    #[test]
    fn matmul_dense_lhs_uses_dense_kernel_results() {
        // dense lhs must take the no-skip path and still be exact
        let a = Mat::from_fn(13, 29, |i, j| ((i * 31 + j * 7) % 11) as f32 - 5.0);
        assert!(a.sample_density() > SKIP_DENSITY_THRESHOLD);
        let b = Mat::from_fn(29, 5, |i, j| ((i * 13 + j * 3) % 7) as f32 - 3.0);
        let got = a.matmul(&b);
        let mut want = Mat::zeros(13, 5);
        for i in 0..13 {
            for j in 0..5 {
                let mut s = 0.0;
                for k in 0..29 {
                    s += a[(i, k)] * b[(k, j)];
                }
                want[(i, j)] = s;
            }
        }
        assert!(got.max_abs_diff(&want) < 1e-4);
    }
}
