//! Serving metrics: latency histograms, throughput windows, energy and
//! halo-traffic accounting — what the server, the fleet, and benches
//! report.
//!
//! One [`Metrics`] sink per shard worker keeps the hot path free of a
//! global lock; fleet-level reporting merges per-shard sinks at snapshot
//! time ([`Metrics::merged`]) so aggregate p50/p99 come from the raw
//! samples, not from lossy per-shard summaries.
//!
//! Sample storage is **bounded**: each distribution (latency, queue
//! time, batch size, frontier size) lives in a deterministic
//! [`Reservoir`] of [`SAMPLE_CAP`] slots, so a long-lived deployment's
//! sinks stop growing while `n`/`mean`/`min`/`max` stay exact and
//! percentiles degrade to a uniform subsample. Snapshots obey the
//! invariant `throughput_qps == queries / elapsed_s` on every path
//! (per-sink, [`Metrics::merged`], [`Snapshot::merge`]).

use std::sync::Mutex;
use std::time::Instant;

use crate::util::reservoir::{self, Reservoir};
use crate::util::timing::Stats;

/// Per-distribution reservoir capacity. Small enough that a sink is a
/// few tens of KiB forever, large enough that p99 over a subsample is
/// tight.
pub const SAMPLE_CAP: usize = 4096;

/// Thread-safe metrics sink for one serving worker (shard or leader).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// One inference round's incremental-execution accounting, reported by
/// delta-aware engines ([`crate::server::InferenceEngine::round_stats`])
/// and recorded by the shard worker after each round. The accounting
/// rule: every activation row the round consumed — as a layer input or
/// as a served output — is either a cache **hit** (reused) or a **miss**
/// (had to be recomputed).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundStats {
    /// Output rows recomputed this round (0 for cache-served rounds).
    pub recomputed_rows: usize,
    /// Output rows the round was responsible for (active ∩ owned).
    pub eligible_rows: usize,
    /// Dirty-frontier size that drove the round (= eligible on full
    /// fallback, 0 on pure cache hits).
    pub frontier: usize,
    /// Activation rows served from the layer cache.
    pub cache_hits: usize,
    /// Activation rows that had to be recomputed.
    pub cache_misses: usize,
    /// What the round's aggregation-mask traffic would have cost dense
    /// (f32 matrix bytes).
    pub dma_bytes_dense: usize,
    /// What the round actually moved: CSR arrays on the sparse path, the
    /// ZVC/SymG-compressed form on the dense path — the GraSp/SymG
    /// machinery feeding a real gauge instead of orphaned stats.
    pub dma_bytes_shipped: usize,
    /// Strategy switches the adaptive `auto` engine performed before this
    /// round (0 for every static engine; normally 0 or 1).
    pub engine_switches: usize,
    /// Strategy that executed this round: [`RoundStats::STRATEGY_STATIC`]
    /// for engines with exactly one strategy,
    /// [`RoundStats::STRATEGY_PLAN`] / [`RoundStats::STRATEGY_INCREMENTAL`]
    /// from the adaptive `auto` engine.
    pub active_strategy: u8,
    /// Feature-store page lookups served from the page cache this round
    /// (0 for in-memory feature sources — see [`crate::storage`]).
    pub page_hits: u64,
    /// Feature-store page lookups that missed the cache (each one is a
    /// disk read, foreground or drained from the prefetcher).
    pub page_faults: u64,
    /// Bytes the paged feature store read from disk this round
    /// (foreground misses plus background prefetch reads).
    pub storage_bytes_read: u64,
}

impl RoundStats {
    /// `active_strategy` for engines that have exactly one strategy.
    pub const STRATEGY_STATIC: u8 = 0;
    /// `active_strategy` when the `auto` engine ran the full planned
    /// recompute this round.
    pub const STRATEGY_PLAN: u8 = 1;
    /// `active_strategy` when the `auto` engine ran the delta-driven
    /// incremental path this round.
    pub const STRATEGY_INCREMENTAL: u8 = 2;

    /// Human name of an `active_strategy` code (None for static engines).
    pub fn strategy_name(code: u8) -> Option<&'static str> {
        match code {
            Self::STRATEGY_PLAN => Some("plan"),
            Self::STRATEGY_INCREMENTAL => Some("incremental"),
            _ => None,
        }
    }

    /// Stable one-line JSON encoding (keys in declaration order) for the
    /// telemetry exporters.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"recomputed_rows\":{},\"eligible_rows\":{},\"frontier\":{},\
             \"cache_hits\":{},\"cache_misses\":{},\"dma_bytes_dense\":{},\
             \"dma_bytes_shipped\":{},\"engine_switches\":{},\
             \"active_strategy\":{},\"page_hits\":{},\"page_faults\":{},\
             \"storage_bytes_read\":{}}}",
            self.recomputed_rows,
            self.eligible_rows,
            self.frontier,
            self.cache_hits,
            self.cache_misses,
            self.dma_bytes_dense,
            self.dma_bytes_shipped,
            self.engine_switches,
            self.active_strategy,
            self.page_hits,
            self.page_faults,
            self.storage_bytes_read,
        )
    }
}

#[derive(Debug)]
struct Inner {
    /// Shard label. Every worker-owned sink carries one — the
    /// single-leader server is shard 0 of a one-shard fleet. None only
    /// for unlabeled standalone sinks and merged snapshots.
    shard: Option<usize>,
    latencies_us: Reservoir,
    queue_us: Reservoir,
    batch_sizes: Reservoir,
    mask_updates: usize,
    queries: usize,
    rejected: usize,
    /// Halo-exchange accounting (fleet boundary traffic).
    halo_bytes: usize,
    halo_us: f64,
    halo_rounds: usize,
    /// Incremental-execution accounting (delta-aware engines).
    recomputed_rows: usize,
    eligible_rows: usize,
    cache_row_hits: usize,
    cache_row_misses: usize,
    frontier_sizes: Reservoir,
    /// Mask-traffic accounting (sparse/compressed aggregation operands).
    dma_bytes_dense: usize,
    dma_bytes_shipped: usize,
    /// Adaptive-engine accounting (the `auto` engine's strategy gauges).
    engine_switches: usize,
    active_strategy: u8,
    /// Out-of-core feature-store accounting (paged sources only).
    page_hits: u64,
    page_faults: u64,
    storage_bytes_read: u64,
    started: Option<Instant>,
}

impl Default for Inner {
    fn default() -> Inner {
        // fixed per-distribution seeds: two sinks fed the same sample
        // stream produce identical reservoirs (and so identical
        // percentile estimates) — tested below
        Inner {
            shard: None,
            latencies_us: Reservoir::new(SAMPLE_CAP, 0xA11C_E001),
            queue_us: Reservoir::new(SAMPLE_CAP, 0xA11C_E002),
            batch_sizes: Reservoir::new(SAMPLE_CAP, 0xA11C_E003),
            mask_updates: 0,
            queries: 0,
            rejected: 0,
            halo_bytes: 0,
            halo_us: 0.0,
            halo_rounds: 0,
            recomputed_rows: 0,
            eligible_rows: 0,
            cache_row_hits: 0,
            cache_row_misses: 0,
            frontier_sizes: Reservoir::new(SAMPLE_CAP, 0xA11C_E004),
            dma_bytes_dense: 0,
            dma_bytes_shipped: 0,
            engine_switches: 0,
            active_strategy: RoundStats::STRATEGY_STATIC,
            page_hits: 0,
            page_faults: 0,
            storage_bytes_read: 0,
            started: None,
        }
    }
}

/// A snapshot of aggregated serving metrics.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Which shard produced this snapshot (the single-leader server
    /// reports as shard 0; None = unlabeled standalone sink or merged).
    pub shard: Option<usize>,
    pub queries: usize,
    pub rejected: usize,
    pub mask_updates: usize,
    /// Boundary-node feature bytes shipped between shards.
    pub halo_bytes: usize,
    /// Simulated host-link time spent on halo exchange (µs).
    pub halo_us: f64,
    /// Inference rounds that performed a halo exchange.
    pub halo_rounds: usize,
    /// Output rows recomputed by delta-aware engines (raw counter; see
    /// [`Snapshot::recompute_ratio`]).
    pub recomputed_rows: usize,
    /// Output rows those engines were responsible for across rounds.
    pub eligible_rows: usize,
    /// Activation rows served from the layer cache.
    pub cache_row_hits: usize,
    /// Activation rows that had to be recomputed.
    pub cache_row_misses: usize,
    /// Dense cost of the aggregation-mask bytes rounds consumed.
    pub dma_bytes_dense: usize,
    /// Bytes actually shipped (CSR / ZVC / SymG-packed); see
    /// [`Snapshot::dma_bytes_saved`].
    pub dma_bytes_shipped: usize,
    /// Feature-store page lookups served from the page cache (0 for
    /// in-memory sources; see [`Snapshot::feature_cache_hit_rate`]).
    pub page_hits: u64,
    /// Feature-store page lookups that went to disk (plain counter —
    /// sums exactly through [`Metrics::merged`] and [`Snapshot::merge`]).
    pub page_faults: u64,
    /// Bytes the paged feature store read from disk (foreground misses
    /// plus background prefetch).
    pub storage_bytes_read: u64,
    /// Strategy switches the adaptive `auto` engine performed (plain
    /// counter — sums exactly through [`Metrics::merged`] and
    /// [`Snapshot::merge`]).
    pub engine_switches: usize,
    /// The `auto` engine's currently-active strategy (`"plan"` /
    /// `"incremental"`): per shard the last recorded round's strategy;
    /// merged snapshots report the common value, or `"mixed"` when shards
    /// disagree. `None` for static engines.
    pub active_strategy: Option<String>,
    /// Dirty-frontier size distribution (one sample per round).
    pub frontier: Option<Stats>,
    pub latency: Option<Stats>,
    pub queue: Option<Stats>,
    pub mean_batch: f64,
    pub throughput_qps: f64,
    /// Wall-clock seconds this sink has been live.
    pub elapsed_s: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        let m = Metrics::default();
        m.inner.lock().unwrap().started = Some(Instant::now());
        m
    }

    /// A sink labeled with the shard that owns it.
    pub fn new_shard(shard: usize) -> Metrics {
        let m = Metrics::new();
        m.inner.lock().unwrap().shard = Some(shard);
        m
    }

    pub fn record_query(&self, latency_us: f64, queue_us: f64, batch: usize) {
        let mut i = self.inner.lock().unwrap();
        i.latencies_us.record(latency_us);
        i.queue_us.record(queue_us);
        i.batch_sizes.record(batch as f64);
        i.queries += 1;
    }

    pub fn record_mask_update(&self) {
        self.inner.lock().unwrap().mask_updates += 1;
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Charge one halo-exchange round: `bytes` of boundary features over
    /// the host link for a simulated `us` of link time.
    pub fn record_halo(&self, bytes: usize, us: f64) {
        let mut i = self.inner.lock().unwrap();
        i.halo_bytes += bytes;
        i.halo_us += us;
        i.halo_rounds += 1;
    }

    /// Record one inference round's incremental-execution accounting.
    /// Rounds that only report DMA traffic (`eligible_rows == 0`, e.g.
    /// full-recompute plan engines crediting mask compression) do not
    /// contribute a frontier sample.
    pub fn record_round(&self, rs: &RoundStats) {
        let mut i = self.inner.lock().unwrap();
        i.recomputed_rows += rs.recomputed_rows;
        i.eligible_rows += rs.eligible_rows;
        i.cache_row_hits += rs.cache_hits;
        i.cache_row_misses += rs.cache_misses;
        i.dma_bytes_dense += rs.dma_bytes_dense;
        i.dma_bytes_shipped += rs.dma_bytes_shipped;
        i.page_hits += rs.page_hits;
        i.page_faults += rs.page_faults;
        i.storage_bytes_read += rs.storage_bytes_read;
        i.engine_switches += rs.engine_switches;
        if rs.active_strategy != RoundStats::STRATEGY_STATIC {
            i.active_strategy = rs.active_strategy;
        }
        if rs.eligible_rows > 0 {
            i.frontier_sizes.record(rs.frontier as f64);
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let i = self.inner.lock().unwrap();
        Self::snapshot_inner(&i)
    }

    fn snapshot_inner(i: &Inner) -> Snapshot {
        let elapsed = i
            .started
            .map(|s| s.elapsed().as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        Snapshot {
            shard: i.shard,
            queries: i.queries,
            rejected: i.rejected,
            mask_updates: i.mask_updates,
            halo_bytes: i.halo_bytes,
            halo_us: i.halo_us,
            halo_rounds: i.halo_rounds,
            recomputed_rows: i.recomputed_rows,
            eligible_rows: i.eligible_rows,
            cache_row_hits: i.cache_row_hits,
            cache_row_misses: i.cache_row_misses,
            dma_bytes_dense: i.dma_bytes_dense,
            dma_bytes_shipped: i.dma_bytes_shipped,
            page_hits: i.page_hits,
            page_faults: i.page_faults,
            storage_bytes_read: i.storage_bytes_read,
            engine_switches: i.engine_switches,
            active_strategy: RoundStats::strategy_name(i.active_strategy)
                .map(str::to_string),
            frontier: i.frontier_sizes.stats(),
            latency: i.latencies_us.stats(),
            queue: i.queue_us.stats(),
            mean_batch: if i.batch_sizes.is_empty() {
                0.0
            } else {
                // exact: reservoir sum/count never degrade
                i.batch_sizes.sum() / i.batch_sizes.seen() as f64
            },
            throughput_qps: i.queries as f64 / elapsed,
            elapsed_s: elapsed,
        }
    }

    /// Exact fleet-level aggregate: pools the retained samples of every
    /// sink (so p50/p99 are true percentiles over the union of the
    /// subsamples), sums the counters exactly, and computes throughput
    /// over the longest-lived sink — the same `queries / elapsed_s` rule
    /// every snapshot path uses. This is why shards keep private sinks:
    /// no serving-path lock is shared, and nothing is lost at merge time.
    pub fn merged<'a, I>(sinks: I) -> Snapshot
    where
        I: IntoIterator<Item = &'a Metrics>,
    {
        let mut lat: Vec<Reservoir> = Vec::new();
        let mut que: Vec<Reservoir> = Vec::new();
        let mut batches: Vec<Reservoir> = Vec::new();
        let mut frontiers: Vec<Reservoir> = Vec::new();
        let (mut queries, mut rejected, mut mask_updates) = (0usize, 0usize, 0usize);
        let (mut halo_bytes, mut halo_us, mut halo_rounds) = (0usize, 0.0f64, 0usize);
        let (mut recomputed, mut eligible) = (0usize, 0usize);
        let (mut row_hits, mut row_misses) = (0usize, 0usize);
        let (mut dma_dense, mut dma_shipped) = (0usize, 0usize);
        let (mut pg_hits, mut pg_faults, mut st_bytes) = (0u64, 0u64, 0u64);
        let mut switches = 0usize;
        let mut strategy: Option<String> = None;
        let mut elapsed = 1e-9f64;
        for m in sinks {
            let i = m.inner.lock().unwrap();
            lat.push(i.latencies_us.clone());
            que.push(i.queue_us.clone());
            batches.push(i.batch_sizes.clone());
            frontiers.push(i.frontier_sizes.clone());
            queries += i.queries;
            rejected += i.rejected;
            mask_updates += i.mask_updates;
            halo_bytes += i.halo_bytes;
            halo_us += i.halo_us;
            halo_rounds += i.halo_rounds;
            recomputed += i.recomputed_rows;
            eligible += i.eligible_rows;
            row_hits += i.cache_row_hits;
            row_misses += i.cache_row_misses;
            dma_dense += i.dma_bytes_dense;
            dma_shipped += i.dma_bytes_shipped;
            pg_hits += i.page_hits;
            pg_faults += i.page_faults;
            st_bytes += i.storage_bytes_read;
            switches += i.engine_switches;
            strategy = combine_strategy(
                strategy.as_deref(),
                RoundStats::strategy_name(i.active_strategy),
            );
            if let Some(s) = i.started {
                elapsed = elapsed.max(s.elapsed().as_secs_f64());
            }
        }
        Snapshot {
            shard: None,
            queries,
            rejected,
            mask_updates,
            halo_bytes,
            halo_us,
            halo_rounds,
            recomputed_rows: recomputed,
            eligible_rows: eligible,
            cache_row_hits: row_hits,
            cache_row_misses: row_misses,
            dma_bytes_dense: dma_dense,
            dma_bytes_shipped: dma_shipped,
            page_hits: pg_hits,
            page_faults: pg_faults,
            storage_bytes_read: st_bytes,
            engine_switches: switches,
            active_strategy: strategy,
            frontier: reservoir::merged_stats(&frontiers.iter().collect::<Vec<_>>()),
            latency: reservoir::merged_stats(&lat.iter().collect::<Vec<_>>()),
            queue: reservoir::merged_stats(&que.iter().collect::<Vec<_>>()),
            mean_batch: {
                let seen: usize = batches.iter().map(Reservoir::seen).sum();
                if seen == 0 {
                    0.0
                } else {
                    batches.iter().map(Reservoir::sum).sum::<f64>() / seen as f64
                }
            },
            throughput_qps: queries as f64 / elapsed,
            elapsed_s: elapsed,
        }
    }

    /// Interpolated latency quantile over this sink's retained samples
    /// (`None` before any query completed). The SLO monitor samples this
    /// per tick — see [`crate::monitor::history::Sample`].
    pub fn latency_quantile(&self, q: f64) -> Option<f64> {
        self.inner.lock().unwrap().latencies_us.quantile(q)
    }

    /// Interpolated latency quantile over the **union** of several
    /// sinks' retained samples — the deployment-level number an SLO
    /// objective is held against (a quantile of merged shards is not the
    /// mean of per-shard quantiles).
    pub fn pooled_latency_quantile<'a, I>(sinks: I, q: f64) -> Option<f64>
    where
        I: IntoIterator<Item = &'a Metrics>,
    {
        let mut pooled: Vec<f64> = Vec::new();
        for m in sinks {
            pooled.extend_from_slice(m.inner.lock().unwrap().latencies_us.samples());
        }
        if pooled.is_empty() {
            return None;
        }
        pooled.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(reservoir::quantile_sorted(&pooled, q))
    }
}

impl Snapshot {
    /// Fraction of output rows delta-aware engines recomputed (1.0 = no
    /// reuse, 0.0 = fully cache-served; 0 when no rounds were recorded).
    pub fn recompute_ratio(&self) -> f64 {
        if self.eligible_rows == 0 {
            0.0
        } else {
            self.recomputed_rows as f64 / self.eligible_rows as f64
        }
    }

    /// Fraction of consumed activation rows served from the layer cache
    /// (0 when no rounds were recorded).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_row_hits + self.cache_row_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_row_hits as f64 / total as f64
        }
    }

    /// Fraction of feature-store page lookups served from the page
    /// cache (0 when no paged source reported — in-memory deployments
    /// read 0, not 1.0, so dashboards can tell "no disk tier" from
    /// "perfectly warm"). Exact through [`Metrics::merged`] and
    /// [`Snapshot::merge`]: both sides are plain counters.
    pub fn feature_cache_hit_rate(&self) -> f64 {
        let total = self.page_hits + self.page_faults;
        if total == 0 {
            0.0
        } else {
            self.page_hits as f64 / total as f64
        }
    }

    /// DMA bytes the sparse/compressed aggregation operands saved vs
    /// shipping dense masks — the GraSp (ZVC) + SymG + CSR win as a real
    /// per-shard gauge (exact through [`Metrics::merged`]: both sides
    /// are plain counters). 0 when nothing was recorded, and never
    /// negative — engines fall back to the dense form when compression
    /// would not pay, exactly like real ZVC DMA engines.
    pub fn dma_bytes_saved(&self) -> usize {
        self.dma_bytes_dense.saturating_sub(self.dma_bytes_shipped)
    }

    /// Stable one-line JSON encoding (keys in declaration order; nested
    /// stats objects or `null`) for the telemetry exporters. All values
    /// are plain JSON numbers — non-finite floats encode as `null`.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(512);
        out.push('{');
        match self.shard {
            Some(s) => out.push_str(&format!("\"shard\":{s}")),
            None => out.push_str("\"shard\":null"),
        }
        out.push_str(&format!(
            ",\"queries\":{},\"rejected\":{},\"mask_updates\":{}",
            self.queries, self.rejected, self.mask_updates
        ));
        out.push_str(&format!(
            ",\"halo_bytes\":{},\"halo_us\":{},\"halo_rounds\":{}",
            self.halo_bytes,
            json_num(self.halo_us),
            self.halo_rounds
        ));
        out.push_str(&format!(
            ",\"recomputed_rows\":{},\"eligible_rows\":{},\
             \"cache_row_hits\":{},\"cache_row_misses\":{}",
            self.recomputed_rows, self.eligible_rows, self.cache_row_hits,
            self.cache_row_misses
        ));
        out.push_str(&format!(
            ",\"dma_bytes_dense\":{},\"dma_bytes_shipped\":{}",
            self.dma_bytes_dense, self.dma_bytes_shipped
        ));
        out.push_str(&format!(
            ",\"page_hits\":{},\"page_faults\":{},\"storage_bytes_read\":{}",
            self.page_hits, self.page_faults, self.storage_bytes_read
        ));
        out.push_str(&format!(
            ",\"engine_switches\":{},\"active_strategy\":{}",
            self.engine_switches,
            match &self.active_strategy {
                Some(s) => format!("\"{s}\""),
                None => "null".to_string(),
            }
        ));
        out.push_str(&format!(",\"frontier\":{}", stats_json(&self.frontier)));
        out.push_str(&format!(",\"latency\":{}", stats_json(&self.latency)));
        out.push_str(&format!(",\"queue\":{}", stats_json(&self.queue)));
        out.push_str(&format!(
            ",\"mean_batch\":{},\"throughput_qps\":{},\"elapsed_s\":{}}}",
            json_num(self.mean_batch),
            json_num(self.throughput_qps),
            json_num(self.elapsed_s)
        ));
        out
    }

    /// Aggregate-level merge for snapshots whose raw samples are gone
    /// (e.g. collected from remote shards). Counters are exact; latency
    /// percentiles are conservative (max of the inputs) and means are
    /// sample-weighted. The elapsed/throughput rule matches every other
    /// snapshot path: `elapsed_s` is the longest-lived input (the sinks
    /// ran concurrently, not sequentially) and `throughput_qps` is
    /// recomputed as `queries / elapsed_s` — never averaged. Prefer
    /// [`Metrics::merged`] when the sinks are in process.
    pub fn merge(&self, other: &Snapshot) -> Snapshot {
        let total_batches =
            |s: &Snapshot| if s.mean_batch > 0.0 { s.queries } else { 0 };
        let (b1, b2) = (total_batches(self), total_batches(other));
        Snapshot {
            shard: None,
            queries: self.queries + other.queries,
            rejected: self.rejected + other.rejected,
            mask_updates: self.mask_updates + other.mask_updates,
            halo_bytes: self.halo_bytes + other.halo_bytes,
            halo_us: self.halo_us + other.halo_us,
            halo_rounds: self.halo_rounds + other.halo_rounds,
            recomputed_rows: self.recomputed_rows + other.recomputed_rows,
            eligible_rows: self.eligible_rows + other.eligible_rows,
            cache_row_hits: self.cache_row_hits + other.cache_row_hits,
            cache_row_misses: self.cache_row_misses + other.cache_row_misses,
            dma_bytes_dense: self.dma_bytes_dense + other.dma_bytes_dense,
            dma_bytes_shipped: self.dma_bytes_shipped + other.dma_bytes_shipped,
            page_hits: self.page_hits + other.page_hits,
            page_faults: self.page_faults + other.page_faults,
            storage_bytes_read: self.storage_bytes_read + other.storage_bytes_read,
            engine_switches: self.engine_switches + other.engine_switches,
            active_strategy: combine_strategy(
                self.active_strategy.as_deref(),
                other.active_strategy.as_deref(),
            ),
            frontier: merge_stats(&self.frontier, &other.frontier),
            latency: merge_stats(&self.latency, &other.latency),
            queue: merge_stats(&self.queue, &other.queue),
            mean_batch: if b1 + b2 == 0 {
                0.0
            } else {
                (self.mean_batch * b1 as f64 + other.mean_batch * b2 as f64)
                    / (b1 + b2) as f64
            },
            throughput_qps: (self.queries + other.queries) as f64
                / self.elapsed_s.max(other.elapsed_s).max(1e-9),
            elapsed_s: self.elapsed_s.max(other.elapsed_s),
        }
    }
}

/// Exact gauge merge for the `auto` engine's active strategy: absent
/// inputs pass through, agreeing inputs keep their value, disagreeing
/// shards report `"mixed"` — deterministic whichever order sinks merge in.
fn combine_strategy(a: Option<&str>, b: Option<&str>) -> Option<String> {
    match (a, b) {
        (None, None) => None,
        (Some(s), None) | (None, Some(s)) => Some(s.to_string()),
        (Some(a), Some(b)) if a == b => Some(a.to_string()),
        _ => Some("mixed".to_string()),
    }
}

/// A finite f64 as a JSON number (non-finite → `null`, which the subset
/// grammar and every JSON parser accept).
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// A [`Stats`] summary as a stable JSON object (`null` when absent).
fn stats_json(s: &Option<Stats>) -> String {
    match s {
        None => "null".to_string(),
        Some(s) => format!(
            "{{\"n\":{},\"mean\":{},\"std\":{},\"min\":{},\"p50\":{},\
             \"p95\":{},\"p99\":{},\"max\":{}}}",
            s.n,
            json_num(s.mean),
            json_num(s.std),
            json_num(s.min),
            json_num(s.p50),
            json_num(s.p95),
            json_num(s.p99),
            json_num(s.max),
        ),
    }
}

/// Sample-weighted combine of two latency summaries. Percentiles take the
/// max (an upper bound: the true merged quantile of two samples never
/// exceeds the larger per-sample quantile at p ≥ 0.5).
fn merge_stats(a: &Option<Stats>, b: &Option<Stats>) -> Option<Stats> {
    match (a, b) {
        (None, None) => None,
        (Some(s), None) | (None, Some(s)) => Some(s.clone()),
        (Some(a), Some(b)) => {
            let n = a.n + b.n;
            let mean = (a.mean * a.n as f64 + b.mean * b.n as f64) / n as f64;
            let pooled_var = (a.n as f64 * (a.std.powi(2) + (a.mean - mean).powi(2))
                + b.n as f64 * (b.std.powi(2) + (b.mean - mean).powi(2)))
                / n as f64;
            Some(Stats {
                n,
                mean,
                std: pooled_var.sqrt(),
                min: a.min.min(b.min),
                p50: a.p50.max(b.p50),
                p95: a.p95.max(b.p95),
                p99: a.p99.max(b.p99),
                max: a.max.max(b.max),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_query(100.0, 5.0, 2);
        m.record_query(200.0, 15.0, 4);
        m.record_mask_update();
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.queries, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.mask_updates, 1);
        assert_eq!(s.mean_batch, 3.0);
        assert_eq!(s.latency.unwrap().mean, 150.0);
        assert_eq!(s.shard, None);
    }

    #[test]
    fn latency_quantiles_single_and_pooled() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        assert_eq!(a.latency_quantile(0.5), None, "no queries yet");
        assert_eq!(Metrics::pooled_latency_quantile([&a, &b], 0.5), None);
        // shard 0 holds 1..=50, shard 1 holds 51..=100: the pooled
        // median must land mid-range even though each shard's own
        // median sits in its half
        for v in 1..=50 {
            a.record_query(v as f64, 1.0, 1);
        }
        for v in 51..=100 {
            b.record_query(v as f64, 1.0, 1);
        }
        let ma = a.latency_quantile(0.5).unwrap();
        let pooled = Metrics::pooled_latency_quantile([&a, &b], 0.5).unwrap();
        assert!((ma - 25.5).abs() < 1e-9, "shard median {ma}");
        assert!((pooled - 50.5).abs() < 1e-9, "pooled median {pooled}");
        assert_eq!(a.latency_quantile(1.0), Some(50.0));
        assert_eq!(Metrics::pooled_latency_quantile([&a, &b], 1.0), Some(100.0));
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.queries, 0);
        assert!(s.latency.is_none());
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.halo_bytes, 0);
    }

    #[test]
    fn shard_label_survives_snapshot() {
        let m = Metrics::new_shard(3);
        assert_eq!(m.snapshot().shard, Some(3));
    }

    #[test]
    fn halo_accounting_accumulates() {
        let m = Metrics::new_shard(0);
        m.record_halo(4096, 12.5);
        m.record_halo(4096, 12.5);
        let s = m.snapshot();
        assert_eq!(s.halo_bytes, 8192);
        assert_eq!(s.halo_rounds, 2);
        assert!((s.halo_us - 25.0).abs() < 1e-9);
    }

    #[test]
    fn merged_concatenates_raw_samples() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        for v in [10.0, 20.0, 30.0] {
            a.record_query(v, 0.0, 1);
        }
        for v in [1000.0, 2000.0] {
            b.record_query(v, 0.0, 2);
        }
        a.record_halo(100, 1.0);
        b.record_halo(200, 2.0);
        let s = Metrics::merged([&a, &b]);
        assert_eq!(s.queries, 5);
        assert_eq!(s.halo_bytes, 300);
        let lat = s.latency.unwrap();
        assert_eq!(lat.n, 5);
        // exact percentile over the union, not a per-shard average
        assert_eq!(lat.max, 2000.0);
        assert_eq!(lat.min, 10.0);
        assert_eq!(s.shard, None);
    }

    #[test]
    fn snapshot_merge_is_conservative() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        a.record_query(10.0, 0.0, 1);
        b.record_query(50.0, 0.0, 1);
        b.record_rejected();
        let merged = a.snapshot().merge(&b.snapshot());
        assert_eq!(merged.queries, 2);
        assert_eq!(merged.rejected, 1);
        let lat = merged.latency.unwrap();
        assert_eq!(lat.n, 2);
        assert_eq!(lat.max, 50.0);
        assert!((lat.mean - 30.0).abs() < 1e-9);
    }

    #[test]
    fn round_stats_drive_the_incremental_gauges() {
        let m = Metrics::new_shard(0);
        // an incremental round: 10 of 100 rows recomputed, 40/50 reads hit
        m.record_round(&RoundStats {
            recomputed_rows: 10,
            eligible_rows: 100,
            frontier: 10,
            cache_hits: 40,
            cache_misses: 10,
            ..Default::default()
        });
        // a full-fallback round: everything recomputed, nothing reused
        m.record_round(&RoundStats {
            recomputed_rows: 100,
            eligible_rows: 100,
            frontier: 90,
            cache_hits: 0,
            cache_misses: 100,
            ..Default::default()
        });
        let s = m.snapshot();
        assert!((s.recompute_ratio() - 110.0 / 200.0).abs() < 1e-12);
        assert!((s.cache_hit_rate() - 40.0 / 150.0).abs() < 1e-12);
        let fr = s.frontier.unwrap();
        assert_eq!(fr.n, 2);
        assert_eq!(fr.max, 90.0);
    }

    #[test]
    fn incremental_gauges_survive_merged_and_merge() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        a.record_round(&RoundStats {
            recomputed_rows: 5,
            eligible_rows: 50,
            frontier: 5,
            cache_hits: 45,
            cache_misses: 5,
            ..Default::default()
        });
        b.record_round(&RoundStats {
            recomputed_rows: 50,
            eligible_rows: 50,
            frontier: 50,
            cache_hits: 0,
            cache_misses: 50,
            ..Default::default()
        });
        let merged = Metrics::merged([&a, &b]);
        assert_eq!(merged.recomputed_rows, 55);
        assert_eq!(merged.eligible_rows, 100);
        assert!((merged.recompute_ratio() - 0.55).abs() < 1e-12);
        assert_eq!(merged.frontier.as_ref().unwrap().n, 2);
        // aggregate-level merge keeps the counters exact too
        let coarse = a.snapshot().merge(&b.snapshot());
        assert_eq!(coarse.recomputed_rows, 55);
        assert_eq!(coarse.cache_row_hits, 45);
        assert!((coarse.cache_hit_rate() - 45.0 / 100.0).abs() < 1e-12);
    }

    #[test]
    fn empty_gauges_read_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.recompute_ratio(), 0.0);
        assert_eq!(s.cache_hit_rate(), 0.0);
        assert_eq!(s.dma_bytes_saved(), 0);
        assert!(s.frontier.is_none());
    }

    #[test]
    fn dma_savings_gauge_exact_through_merged_and_merge() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        // shard 0: a sparse round — CSR shipped instead of the dense mask
        a.record_round(&RoundStats {
            dma_bytes_dense: 10_000,
            dma_bytes_shipped: 800,
            ..Default::default()
        });
        // shard 1: a dense round where compression would not pay
        b.record_round(&RoundStats {
            dma_bytes_dense: 5_000,
            dma_bytes_shipped: 5_000,
            ..Default::default()
        });
        assert_eq!(a.snapshot().dma_bytes_saved(), 9_200);
        assert_eq!(b.snapshot().dma_bytes_saved(), 0);
        let merged = Metrics::merged([&a, &b]);
        assert_eq!(merged.dma_bytes_dense, 15_000);
        assert_eq!(merged.dma_bytes_shipped, 5_800);
        assert_eq!(merged.dma_bytes_saved(), 9_200);
        // aggregate-level merge keeps the counters exact too
        let coarse = a.snapshot().merge(&b.snapshot());
        assert_eq!(coarse.dma_bytes_saved(), 9_200);
        // dma-only rounds contribute no frontier sample
        assert!(merged.frontier.is_none());
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.record_query(50.0, 1.0, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().queries, 800);
    }

    #[test]
    fn long_lived_sink_is_bounded_with_exact_aggregates() {
        // 3× capacity: the old Vec-backed sink would hold 12288 samples
        // per distribution; the reservoir holds SAMPLE_CAP forever
        let m = Metrics::new_shard(0);
        let total = SAMPLE_CAP * 3;
        // 1024 divides total, so the stream mean is exactly 511.5
        for i in 0..total {
            m.record_query((i % 1024) as f64, 1.0, 2);
        }
        let s = m.snapshot();
        assert_eq!(s.queries, total);
        let lat = s.latency.unwrap();
        assert_eq!(lat.n, total, "exact count survives the reservoir");
        assert_eq!(lat.min, 0.0);
        assert_eq!(lat.max, 1023.0);
        assert!((lat.mean - 511.5).abs() < 1e-6, "exact mean: {}", lat.mean);
        assert!(lat.p50 > 256.0 && lat.p50 < 768.0, "subsampled p50 {}", lat.p50);
        assert_eq!(s.mean_batch, 2.0, "batch mean exact past capacity");
    }

    #[test]
    fn merged_percentiles_consistent_past_capacity() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        for i in 0..(SAMPLE_CAP + 100) {
            a.record_query((i % 100) as f64, 0.5, 1);
        }
        for _ in 0..10 {
            b.record_query(10_000.0, 0.5, 3);
        }
        let s = Metrics::merged([&a, &b]);
        let lat = s.latency.unwrap();
        assert_eq!(lat.n, SAMPLE_CAP + 110, "exact pooled count");
        assert_eq!(lat.max, 10_000.0, "exact pooled max");
        assert_eq!(lat.min, 0.0);
        // shard 1's 10 outliers cannot move the pooled median
        assert!(lat.p50 < 100.0, "p50 {}", lat.p50);
        // snapshot invariant holds on the merged path too
        assert!(
            (s.throughput_qps - s.queries as f64 / s.elapsed_s).abs()
                / s.throughput_qps.max(1e-9)
                < 1e-9,
            "throughput_qps must equal queries / elapsed_s"
        );
    }

    #[test]
    fn identical_streams_produce_identical_percentiles() {
        let feed = || {
            let m = Metrics::new();
            for i in 0..(SAMPLE_CAP * 2) {
                m.record_query((i * 13 % 997) as f64, 1.0, 1);
            }
            m.snapshot().latency.unwrap()
        };
        let (a, b) = (feed(), feed());
        assert_eq!(a.p50, b.p50, "fixed seeds make subsampling deterministic");
        assert_eq!(a.p99, b.p99);
    }

    #[test]
    fn snapshot_merge_keeps_throughput_invariant() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        for _ in 0..30 {
            a.record_query(10.0, 0.0, 1);
        }
        for _ in 0..70 {
            b.record_query(10.0, 0.0, 1);
        }
        let (sa, sb) = (a.snapshot(), b.snapshot());
        let m = sa.merge(&sb);
        assert_eq!(m.queries, 100);
        assert_eq!(m.elapsed_s, sa.elapsed_s.max(sb.elapsed_s));
        assert!(
            (m.throughput_qps - m.queries as f64 / m.elapsed_s).abs()
                / m.throughput_qps.max(1e-9)
                < 1e-6,
            "merge() recomputes throughput from the merged elapsed"
        );
    }

    #[test]
    fn json_encodings_are_stable_and_balanced() {
        let m = Metrics::new_shard(2);
        m.record_query(100.0, 5.0, 2);
        m.record_round(&RoundStats {
            recomputed_rows: 1,
            eligible_rows: 4,
            frontier: 1,
            ..Default::default()
        });
        let j = m.snapshot().to_json();
        assert!(j.starts_with("{\"shard\":2,"), "{j}");
        assert!(j.contains("\"queries\":1"));
        assert!(j.contains("\"latency\":{\"n\":1,"));
        assert!(j.contains("\"queue\":{"));
        assert!(j.ends_with('}'));
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "balanced: {j}"
        );
        // empty sink: optional stats encode as null
        let empty = Metrics::new().snapshot().to_json();
        assert!(empty.contains("\"shard\":null"));
        assert!(empty.contains("\"latency\":null"));

        let r = RoundStats {
            recomputed_rows: 3,
            eligible_rows: 9,
            frontier: 2,
            cache_hits: 5,
            cache_misses: 4,
            dma_bytes_dense: 100,
            dma_bytes_shipped: 10,
            engine_switches: 1,
            active_strategy: RoundStats::STRATEGY_INCREMENTAL,
            page_hits: 7,
            page_faults: 2,
            storage_bytes_read: 4096,
        }
        .to_json();
        assert_eq!(
            r,
            "{\"recomputed_rows\":3,\"eligible_rows\":9,\"frontier\":2,\
             \"cache_hits\":5,\"cache_misses\":4,\"dma_bytes_dense\":100,\
             \"dma_bytes_shipped\":10,\"engine_switches\":1,\
             \"active_strategy\":2,\"page_hits\":7,\"page_faults\":2,\
             \"storage_bytes_read\":4096}"
        );
    }

    #[test]
    fn storage_gauges_exact_through_merged_and_merge() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        // shard 0: a cold round (8 faults) then a warm one (8 hits)
        a.record_round(&RoundStats {
            page_faults: 8,
            storage_bytes_read: 8 * 64 * 4,
            ..Default::default()
        });
        a.record_round(&RoundStats { page_hits: 8, ..Default::default() });
        // shard 1: in-memory source — reports nothing
        b.record_round(&RoundStats::default());
        let sa = a.snapshot();
        assert_eq!(sa.page_hits, 8);
        assert_eq!(sa.page_faults, 8);
        assert_eq!(sa.storage_bytes_read, 2048);
        assert!((sa.feature_cache_hit_rate() - 0.5).abs() < 1e-12);
        // "no disk tier" reads 0, not a perfect hit rate
        assert_eq!(b.snapshot().feature_cache_hit_rate(), 0.0);
        let merged = Metrics::merged([&a, &b]);
        assert_eq!(merged.page_hits, 8);
        assert_eq!(merged.page_faults, 8);
        assert_eq!(merged.storage_bytes_read, 2048);
        // aggregate-level merge keeps the counters exact too
        let coarse = a.snapshot().merge(&b.snapshot());
        assert_eq!(coarse.page_faults, 8);
        assert!((coarse.feature_cache_hit_rate() - 0.5).abs() < 1e-12);
        let j = merged.to_json();
        assert!(j.contains("\"page_hits\":8"), "{j}");
        assert!(j.contains("\"storage_bytes_read\":2048"), "{j}");
    }

    #[test]
    fn strategy_gauges_exact_through_merged_and_merge() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        // static engines never set a strategy
        a.record_round(&RoundStats::default());
        assert_eq!(a.snapshot().active_strategy, None);
        assert_eq!(a.snapshot().engine_switches, 0);
        // shard 0 switched to plan, shard 1 is still incremental
        a.record_round(&RoundStats {
            engine_switches: 1,
            active_strategy: RoundStats::STRATEGY_PLAN,
            ..Default::default()
        });
        b.record_round(&RoundStats {
            active_strategy: RoundStats::STRATEGY_INCREMENTAL,
            ..Default::default()
        });
        b.record_round(&RoundStats {
            engine_switches: 1,
            active_strategy: RoundStats::STRATEGY_INCREMENTAL,
            ..Default::default()
        });
        assert_eq!(a.snapshot().active_strategy.as_deref(), Some("plan"));
        assert_eq!(b.snapshot().active_strategy.as_deref(), Some("incremental"));
        let merged = Metrics::merged([&a, &b]);
        assert_eq!(merged.engine_switches, 2, "switch counter sums exactly");
        assert_eq!(merged.active_strategy.as_deref(), Some("mixed"));
        // agreeing shards keep the common value
        let agree = Metrics::merged([&b]);
        assert_eq!(agree.active_strategy.as_deref(), Some("incremental"));
        // aggregate-level merge follows the same rules
        let coarse = a.snapshot().merge(&b.snapshot());
        assert_eq!(coarse.engine_switches, 2);
        assert_eq!(coarse.active_strategy.as_deref(), Some("mixed"));
        let j = merged.to_json();
        assert!(j.contains("\"engine_switches\":2"), "{j}");
        assert!(j.contains("\"active_strategy\":\"mixed\""), "{j}");
    }
}
