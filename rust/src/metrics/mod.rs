//! Serving metrics: latency histograms, throughput windows, energy and
//! halo-traffic accounting — what the server, the fleet, and benches
//! report.
//!
//! One [`Metrics`] sink per shard worker keeps the hot path free of a
//! global lock; fleet-level reporting merges per-shard sinks at snapshot
//! time ([`Metrics::merged`]) so aggregate p50/p99 come from the raw
//! samples, not from lossy per-shard summaries.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::timing::Stats;

/// Thread-safe metrics sink for one serving worker (shard or leader).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    /// Shard label. Every worker-owned sink carries one — the
    /// single-leader server is shard 0 of a one-shard fleet. None only
    /// for unlabeled standalone sinks and merged snapshots.
    shard: Option<usize>,
    latencies_us: Vec<f64>,
    queue_us: Vec<f64>,
    batch_sizes: Vec<usize>,
    mask_updates: usize,
    queries: usize,
    rejected: usize,
    /// Halo-exchange accounting (fleet boundary traffic).
    halo_bytes: usize,
    halo_us: f64,
    halo_rounds: usize,
    started: Option<Instant>,
}

/// A snapshot of aggregated serving metrics.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Which shard produced this snapshot (the single-leader server
    /// reports as shard 0; None = unlabeled standalone sink or merged).
    pub shard: Option<usize>,
    pub queries: usize,
    pub rejected: usize,
    pub mask_updates: usize,
    /// Boundary-node feature bytes shipped between shards.
    pub halo_bytes: usize,
    /// Simulated host-link time spent on halo exchange (µs).
    pub halo_us: f64,
    /// Inference rounds that performed a halo exchange.
    pub halo_rounds: usize,
    pub latency: Option<Stats>,
    pub queue: Option<Stats>,
    pub mean_batch: f64,
    pub throughput_qps: f64,
    /// Wall-clock seconds this sink has been live.
    pub elapsed_s: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        let m = Metrics::default();
        m.inner.lock().unwrap().started = Some(Instant::now());
        m
    }

    /// A sink labeled with the shard that owns it.
    pub fn new_shard(shard: usize) -> Metrics {
        let m = Metrics::new();
        m.inner.lock().unwrap().shard = Some(shard);
        m
    }

    pub fn record_query(&self, latency_us: f64, queue_us: f64, batch: usize) {
        let mut i = self.inner.lock().unwrap();
        i.latencies_us.push(latency_us);
        i.queue_us.push(queue_us);
        i.batch_sizes.push(batch);
        i.queries += 1;
    }

    pub fn record_mask_update(&self) {
        self.inner.lock().unwrap().mask_updates += 1;
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Charge one halo-exchange round: `bytes` of boundary features over
    /// the host link for a simulated `us` of link time.
    pub fn record_halo(&self, bytes: usize, us: f64) {
        let mut i = self.inner.lock().unwrap();
        i.halo_bytes += bytes;
        i.halo_us += us;
        i.halo_rounds += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        let i = self.inner.lock().unwrap();
        Self::snapshot_inner(&i)
    }

    fn snapshot_inner(i: &Inner) -> Snapshot {
        let elapsed = i
            .started
            .map(|s| s.elapsed().as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        Snapshot {
            shard: i.shard,
            queries: i.queries,
            rejected: i.rejected,
            mask_updates: i.mask_updates,
            halo_bytes: i.halo_bytes,
            halo_us: i.halo_us,
            halo_rounds: i.halo_rounds,
            latency: if i.latencies_us.is_empty() {
                None
            } else {
                Some(Stats::from_samples(&i.latencies_us))
            },
            queue: if i.queue_us.is_empty() {
                None
            } else {
                Some(Stats::from_samples(&i.queue_us))
            },
            mean_batch: if i.batch_sizes.is_empty() {
                0.0
            } else {
                i.batch_sizes.iter().sum::<usize>() as f64
                    / i.batch_sizes.len() as f64
            },
            throughput_qps: i.queries as f64 / elapsed,
            elapsed_s: elapsed,
        }
    }

    /// Exact fleet-level aggregate: concatenates the raw samples of every
    /// sink (so p50/p99 are true percentiles over all shards), sums the
    /// counters, and computes throughput over the longest-lived sink.
    /// This is why shards keep private sinks: no serving-path lock is
    /// shared, and nothing is lost at merge time.
    pub fn merged<'a, I>(sinks: I) -> Snapshot
    where
        I: IntoIterator<Item = &'a Metrics>,
    {
        let mut lat: Vec<f64> = Vec::new();
        let mut que: Vec<f64> = Vec::new();
        let mut batches: Vec<usize> = Vec::new();
        let (mut queries, mut rejected, mut mask_updates) = (0usize, 0usize, 0usize);
        let (mut halo_bytes, mut halo_us, mut halo_rounds) = (0usize, 0.0f64, 0usize);
        let mut elapsed = 1e-9f64;
        for m in sinks {
            let i = m.inner.lock().unwrap();
            lat.extend_from_slice(&i.latencies_us);
            que.extend_from_slice(&i.queue_us);
            batches.extend_from_slice(&i.batch_sizes);
            queries += i.queries;
            rejected += i.rejected;
            mask_updates += i.mask_updates;
            halo_bytes += i.halo_bytes;
            halo_us += i.halo_us;
            halo_rounds += i.halo_rounds;
            if let Some(s) = i.started {
                elapsed = elapsed.max(s.elapsed().as_secs_f64());
            }
        }
        Snapshot {
            shard: None,
            queries,
            rejected,
            mask_updates,
            halo_bytes,
            halo_us,
            halo_rounds,
            latency: if lat.is_empty() { None } else { Some(Stats::from_samples(&lat)) },
            queue: if que.is_empty() { None } else { Some(Stats::from_samples(&que)) },
            mean_batch: if batches.is_empty() {
                0.0
            } else {
                batches.iter().sum::<usize>() as f64 / batches.len() as f64
            },
            throughput_qps: queries as f64 / elapsed,
            elapsed_s: elapsed,
        }
    }
}

impl Snapshot {
    /// Aggregate-level merge for snapshots whose raw samples are gone
    /// (e.g. collected from remote shards). Counters are exact; latency
    /// percentiles are conservative (max of the inputs) and means are
    /// sample-weighted. Prefer [`Metrics::merged`] when the sinks are in
    /// process.
    pub fn merge(&self, other: &Snapshot) -> Snapshot {
        let total_batches =
            |s: &Snapshot| if s.mean_batch > 0.0 { s.queries } else { 0 };
        let (b1, b2) = (total_batches(self), total_batches(other));
        Snapshot {
            shard: None,
            queries: self.queries + other.queries,
            rejected: self.rejected + other.rejected,
            mask_updates: self.mask_updates + other.mask_updates,
            halo_bytes: self.halo_bytes + other.halo_bytes,
            halo_us: self.halo_us + other.halo_us,
            halo_rounds: self.halo_rounds + other.halo_rounds,
            latency: merge_stats(&self.latency, &other.latency),
            queue: merge_stats(&self.queue, &other.queue),
            mean_batch: if b1 + b2 == 0 {
                0.0
            } else {
                (self.mean_batch * b1 as f64 + other.mean_batch * b2 as f64)
                    / (b1 + b2) as f64
            },
            throughput_qps: (self.queries + other.queries) as f64
                / self.elapsed_s.max(other.elapsed_s).max(1e-9),
            elapsed_s: self.elapsed_s.max(other.elapsed_s),
        }
    }
}

/// Sample-weighted combine of two latency summaries. Percentiles take the
/// max (an upper bound: the true merged quantile of two samples never
/// exceeds the larger per-sample quantile at p ≥ 0.5).
fn merge_stats(a: &Option<Stats>, b: &Option<Stats>) -> Option<Stats> {
    match (a, b) {
        (None, None) => None,
        (Some(s), None) | (None, Some(s)) => Some(s.clone()),
        (Some(a), Some(b)) => {
            let n = a.n + b.n;
            let mean = (a.mean * a.n as f64 + b.mean * b.n as f64) / n as f64;
            let pooled_var = (a.n as f64 * (a.std.powi(2) + (a.mean - mean).powi(2))
                + b.n as f64 * (b.std.powi(2) + (b.mean - mean).powi(2)))
                / n as f64;
            Some(Stats {
                n,
                mean,
                std: pooled_var.sqrt(),
                min: a.min.min(b.min),
                p50: a.p50.max(b.p50),
                p95: a.p95.max(b.p95),
                p99: a.p99.max(b.p99),
                max: a.max.max(b.max),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_query(100.0, 5.0, 2);
        m.record_query(200.0, 15.0, 4);
        m.record_mask_update();
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.queries, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.mask_updates, 1);
        assert_eq!(s.mean_batch, 3.0);
        assert_eq!(s.latency.unwrap().mean, 150.0);
        assert_eq!(s.shard, None);
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.queries, 0);
        assert!(s.latency.is_none());
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.halo_bytes, 0);
    }

    #[test]
    fn shard_label_survives_snapshot() {
        let m = Metrics::new_shard(3);
        assert_eq!(m.snapshot().shard, Some(3));
    }

    #[test]
    fn halo_accounting_accumulates() {
        let m = Metrics::new_shard(0);
        m.record_halo(4096, 12.5);
        m.record_halo(4096, 12.5);
        let s = m.snapshot();
        assert_eq!(s.halo_bytes, 8192);
        assert_eq!(s.halo_rounds, 2);
        assert!((s.halo_us - 25.0).abs() < 1e-9);
    }

    #[test]
    fn merged_concatenates_raw_samples() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        for v in [10.0, 20.0, 30.0] {
            a.record_query(v, 0.0, 1);
        }
        for v in [1000.0, 2000.0] {
            b.record_query(v, 0.0, 2);
        }
        a.record_halo(100, 1.0);
        b.record_halo(200, 2.0);
        let s = Metrics::merged([&a, &b]);
        assert_eq!(s.queries, 5);
        assert_eq!(s.halo_bytes, 300);
        let lat = s.latency.unwrap();
        assert_eq!(lat.n, 5);
        // exact percentile over the union, not a per-shard average
        assert_eq!(lat.max, 2000.0);
        assert_eq!(lat.min, 10.0);
        assert_eq!(s.shard, None);
    }

    #[test]
    fn snapshot_merge_is_conservative() {
        let a = Metrics::new_shard(0);
        let b = Metrics::new_shard(1);
        a.record_query(10.0, 0.0, 1);
        b.record_query(50.0, 0.0, 1);
        b.record_rejected();
        let merged = a.snapshot().merge(&b.snapshot());
        assert_eq!(merged.queries, 2);
        assert_eq!(merged.rejected, 1);
        let lat = merged.latency.unwrap();
        assert_eq!(lat.n, 2);
        assert_eq!(lat.max, 50.0);
        assert!((lat.mean - 30.0).abs() < 1e-9);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.record_query(50.0, 1.0, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().queries, 800);
    }
}
