//! Serving metrics: latency histograms, throughput windows, energy
//! accounting — what the server and benches report.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::timing::Stats;

/// Thread-safe metrics sink for the serving path.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<f64>,
    queue_us: Vec<f64>,
    batch_sizes: Vec<usize>,
    mask_updates: usize,
    queries: usize,
    rejected: usize,
    started: Option<Instant>,
}

/// A snapshot of aggregated serving metrics.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub queries: usize,
    pub rejected: usize,
    pub mask_updates: usize,
    pub latency: Option<Stats>,
    pub queue: Option<Stats>,
    pub mean_batch: f64,
    pub throughput_qps: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        let m = Metrics::default();
        m.inner.lock().unwrap().started = Some(Instant::now());
        m
    }

    pub fn record_query(&self, latency_us: f64, queue_us: f64, batch: usize) {
        let mut i = self.inner.lock().unwrap();
        i.latencies_us.push(latency_us);
        i.queue_us.push(queue_us);
        i.batch_sizes.push(batch);
        i.queries += 1;
    }

    pub fn record_mask_update(&self) {
        self.inner.lock().unwrap().mask_updates += 1;
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        let i = self.inner.lock().unwrap();
        let elapsed = i
            .started
            .map(|s| s.elapsed().as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        Snapshot {
            queries: i.queries,
            rejected: i.rejected,
            mask_updates: i.mask_updates,
            latency: if i.latencies_us.is_empty() {
                None
            } else {
                Some(Stats::from_samples(&i.latencies_us))
            },
            queue: if i.queue_us.is_empty() {
                None
            } else {
                Some(Stats::from_samples(&i.queue_us))
            },
            mean_batch: if i.batch_sizes.is_empty() {
                0.0
            } else {
                i.batch_sizes.iter().sum::<usize>() as f64
                    / i.batch_sizes.len() as f64
            },
            throughput_qps: i.queries as f64 / elapsed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_query(100.0, 5.0, 2);
        m.record_query(200.0, 15.0, 4);
        m.record_mask_update();
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.queries, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.mask_updates, 1);
        assert_eq!(s.mean_batch, 3.0);
        assert_eq!(s.latency.unwrap().mean, 150.0);
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.queries, 0);
        assert!(s.latency.is_none());
        assert_eq!(s.mean_batch, 0.0);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.record_query(50.0, 1.0, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().queries, 800);
    }
}
