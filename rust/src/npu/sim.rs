//! The NPU simulator: schedules an op graph on a device model, charging
//! compute (DPU/DSP), DMA traffic (DRAM↔SRAM with optional GraSp/SymG
//! compression and CacheG residency), and GraphSplit boundary transfers.
//!
//! The memory model is a per-op roofline (DESIGN.md §2): every op runs at
//! `max(compute_time, streamed_bytes / DMA_bandwidth)`, where
//! `streamed_bytes` covers operands that are not SRAM-resident:
//!
//! - *graph inputs* (weights, masks, features) live in DRAM; small
//!   tensors (weights) are pinned in SRAM after first use;
//! - structure masks (`norm`/`adj`/…) are re-streamed per consumer unless
//!   **CacheG** pins them — which only fits once **SymG** (triangular
//!   packing) and/or **GraSp** (ZVC) shrink them below the pin budget:
//!   the three techniques compose exactly as the paper describes;
//! - intermediates stay in SRAM when they fit the working set; larger
//!   ones (the n×n attention matrices at Cora scale) stream to/from DRAM;
//! - GraphSplit boundary crossings pay the host-link transfer cost.

use std::collections::BTreeMap;

use crate::config::{DeviceKind, HardwareConfig};
use crate::ops::{Engine, OpGraph, OpKind, Stage};
use crate::tensor::DType;

use super::cost::{is_mask_name, op_cost, CostOpts, OpCost};

/// Elementwise DPU ops that the NPU compiler fuses into streaming chains:
/// an oversized intermediate flowing between two fusible ops never
/// materializes in DRAM (this is why EffOp's op-count increase is free
/// while its DSP elimination pays off).
///
/// This predicate is the **fusion contract** shared with the planned
/// executor ([`crate::ops::plan`]): the engine fuses exactly the chains
/// this function admits, so the simulator's cost model and the real
/// engine agree on which intermediates never materialize.
pub fn is_fusible(k: &OpKind) -> bool {
    matches!(
        k,
        OpKind::Add
            | OpKind::Sub
            | OpKind::Mul
            | OpKind::Scale(_)
            | OpKind::AddConst(_)
            | OpKind::Relu
            | OpKind::LeakyRelu(_)
            | OpKind::Exp
            | OpKind::BroadcastCol
            | OpKind::BroadcastRow
            | OpKind::Quantize { .. }
    )
}

/// Reductions can terminate a fused chain (they consume streamed tiles).
pub fn is_reducer(k: &OpKind) -> bool {
    matches!(k, OpKind::ReduceSumRows | OpKind::ReduceMaxRows | OpKind::MaskedMaxPool)
}

/// Which device executes each op (GraphSplit's output).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    Accel,
    Host,
}

/// Simulation options: which GraNNite techniques are active.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// GraSp: ZVC-compress structure masks + zero-skip their MACs.
    pub grasp: bool,
    /// SymG: triangular packing for symmetric masks (`norm*` inputs).
    pub symg: bool,
    /// CacheG: pin structure masks in SRAM across layers (needs them to
    /// fit — see module docs).
    pub cacheg: bool,
    /// Datapath width in bytes for f32 tensors (2 = FP16 default NPU
    /// datapath; QuantGr's INT8 ops carry their own width).
    pub dense_dtype_bytes: usize,
    /// Density of each named mask input (from the real dataset) —
    /// drives GraSp savings honestly.
    pub mask_density: BTreeMap<String, f64>,
    /// Per-op placement (None = everything on the accelerator).
    pub placement: Option<Vec<Placement>>,
    /// Host model used for `Placement::Host` ops + boundary transfers.
    pub host: HardwareConfig,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            grasp: false,
            symg: false,
            cacheg: false,
            dense_dtype_bytes: 2,
            mask_density: BTreeMap::new(),
            placement: None,
            host: HardwareConfig::cpu(),
        }
    }
}

impl SimOptions {
    /// All step-2 memory techniques on (the "full GraNNite" config).
    pub fn optimized() -> SimOptions {
        SimOptions { grasp: true, symg: true, cacheg: true, ..Default::default() }
    }

    /// Effective stored width of a tensor element for this run.
    fn width(&self, dtype: DType) -> usize {
        match dtype {
            DType::F32 | DType::F16 => self.dense_dtype_bytes,
            other => other.size(),
        }
    }
}

/// Per-op simulation record.
#[derive(Debug, Clone)]
pub struct OpRecord {
    pub id: usize,
    pub kind: &'static str,
    pub stage: Stage,
    pub engine: Engine,
    pub placement: Placement,
    pub compute_us: f64,
    pub dma_us: f64,
    pub xfer_us: f64,
    /// Wall-clock contribution: max(compute, dma) + xfer.
    pub wall_us: f64,
    pub energy_pj: f64,
    pub macs: usize,
}

/// Aggregated simulation result for one inference.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub graph_name: String,
    pub device: String,
    pub records: Vec<OpRecord>,
    pub total_us: f64,
    pub energy_pj: f64,
    pub dma_bytes: usize,
    pub xfer_bytes: usize,
}

impl SimReport {
    /// Latency split by (stage, engine) — the Fig. 4 view.
    pub fn by_stage_engine(&self) -> BTreeMap<(String, String), f64> {
        let mut m = BTreeMap::new();
        for r in &self.records {
            let key = (r.stage.to_string(), engine_label(r));
            *m.entry(key).or_insert(0.0) += r.wall_us;
        }
        m
    }

    /// Latency split by stage only.
    pub fn by_stage(&self) -> BTreeMap<String, f64> {
        let mut m = BTreeMap::new();
        for r in &self.records {
            *m.entry(r.stage.to_string()).or_insert(0.0) += r.wall_us;
        }
        m
    }

    /// Latency split by op mnemonic — the Fig. 5 view (wall time).
    pub fn by_kind(&self) -> BTreeMap<&'static str, f64> {
        let mut m = BTreeMap::new();
        for r in &self.records {
            *m.entry(r.kind).or_insert(0.0) += r.wall_us;
        }
        m
    }

    /// Fraction of a stage's wall time attributable to DSP-placed ops
    /// (Fig. 5's claim: ~30% of GraphAttn compute out of the box).
    pub fn dsp_fraction(&self, stage: Stage) -> f64 {
        let (mut dsp, mut total) = (0.0, 0.0);
        for r in &self.records {
            if r.stage == stage {
                total += r.wall_us;
                if r.engine == Engine::Dsp && r.placement == Placement::Accel {
                    dsp += r.wall_us;
                }
            }
        }
        if total > 0.0 {
            dsp / total
        } else {
            0.0
        }
    }

    /// Throughput in inferences/second.
    pub fn throughput(&self) -> f64 {
        1e6 / self.total_us
    }

    /// Energy in millijoules.
    pub fn energy_mj(&self) -> f64 {
        self.energy_pj / 1e9
    }
}

fn engine_label(r: &OpRecord) -> String {
    match r.placement {
        Placement::Host => "CPU".into(),
        Placement::Accel => match r.engine {
            Engine::Dpu => "DPU".into(),
            Engine::Dsp => "DSP".into(),
        },
    }
}

/// DMA bytes a graph input occupies after the active compressions.
fn input_stream_bytes(op: &crate::ops::Op, opts: &SimOptions) -> usize {
    let elems = op.num_elements();
    let width = opts.width(op.dtype);
    let dense = elems * width;
    // GraSp ZVC applies to structure masks AND node embeddings (paper
    // Fig. 13: "zero elements in node embeddings and adjacency matrices
    // are compressed").
    let compressible = is_mask_name(&op.name) || op.name.starts_with('x');
    if !compressible {
        return dense;
    }
    let mut bytes = dense;
    let mut eff_elems = elems;
    if opts.symg && op.name.starts_with("norm") {
        // triangular packing stores n(n+1)/2 of the n² entries
        bytes /= 2;
        eff_elems /= 2;
    }
    if opts.grasp {
        let density = opts.mask_density.get(&op.name).copied().unwrap_or(0.01);
        let zvc = eff_elems.div_ceil(8)
            + (eff_elems as f64 * density).ceil() as usize * width;
        // block-granular ZVC DMA engines cap out ~4x (Rhu et al., HPCA'18)
        bytes = bytes.min(zvc.max(bytes / 4));
    }
    bytes
}

/// Simulate one inference of `g` on `hw`.
pub fn simulate(g: &OpGraph, hw: &HardwareConfig, opts: &SimOptions) -> SimReport {
    let placement = opts
        .placement
        .clone()
        .unwrap_or_else(|| vec![Placement::Accel; g.len()]);
    assert_eq!(placement.len(), g.len(), "placement length mismatch");

    // SRAM budgeting: half of the total SRAM is pinning space; the
    // streaming working set is one tile's SRAM (tensors are banked per
    // tile, so an intermediate must fit a tile to stay resident).
    let pin_budget = hw.sram_bytes() / 2;
    let working_budget = hw.sram_bytes_per_tile;
    let mut pinned: BTreeMap<usize, bool> = BTreeMap::new();
    let mut pinned_bytes = 0usize;

    let mut records = Vec::with_capacity(g.len());
    let mut total_us = 0.0;
    let mut energy_pj = 0.0;
    let mut dma_bytes_total = 0usize;
    let mut xfer_bytes_total = 0usize;

    for id in g.topo_order() {
        let op = &g.ops[id];
        if op.kind == OpKind::Input {
            continue;
        }
        let place = placement[id];
        let dev = match place {
            Placement::Accel => hw,
            Placement::Host => &opts.host,
        };

        // --- compute ---
        let mut co = CostOpts {
            mask_sparsity_skip: 0.0,
            dense_dtype_bytes: opts.dense_dtype_bytes,
            spmm_density: 0.0,
        };
        if opts.grasp {
            if matches!(op.kind, OpKind::MatMul | OpKind::MaskedMaxPool) {
                let lhs = &g.ops[op.inputs[0]];
                if lhs.kind == OpKind::Input && is_mask_name(&lhs.name) {
                    let density =
                        opts.mask_density.get(&lhs.name).copied().unwrap_or(0.01);
                    // zero-skip pipelines keep fetch/decode busy: cap 75%
                    co.mask_sparsity_skip = (1.0 - density).min(0.75);
                }
            }
        }
        if op.kind == OpKind::SpMM {
            // structural sparsity: the CSR operand's density prices the
            // op whether or not the GraSp codec is on (the zeros are
            // never stored, let alone fetched or multiplied)
            let lhs = &g.ops[op.inputs[0]];
            if lhs.kind == OpKind::Input {
                co.spmm_density =
                    opts.mask_density.get(&lhs.name).copied().unwrap_or(0.01);
            }
        }
        let engine = op.kind.default_engine();
        let c: OpCost = op_cost(g, id, dev, engine, co);

        // --- memory traffic (roofline: DMA overlaps compute) ---
        let mut stream_bytes = 0usize;
        let mut xfer_us = 0.0;
        let mut mem_pj = 0.0;
        for &src in &op.inputs {
            let sop = &g.ops[src];
            let bytes_dense = sop.num_elements() * opts.width(sop.dtype);
            if sop.kind == OpKind::Input {
                if place == Placement::Host {
                    continue; // host reads its own DRAM at host rates
                }
                // SpMM sparse operands ship their CSR arrays, not a dense
                // (even ZVC-compressed) matrix: indptr + (index, value)
                // per stored entry — the DMA half of the GraSp model.
                let bytes = if op.kind == OpKind::SpMM && src == op.inputs[0] {
                    let density =
                        opts.mask_density.get(&sop.name).copied().unwrap_or(0.01);
                    let nnz =
                        (sop.num_elements() as f64 * density).ceil() as usize;
                    if opts.symg && sop.name.starts_with("norm") {
                        // symmetric masks ship the upper triangle only
                        sop.shape[0] * 4 + nnz.div_ceil(2) * 8
                    } else {
                        sop.shape[0] * 4 + nnz * 8
                    }
                } else {
                    input_stream_bytes(sop, opts)
                };
                if *pinned.get(&src).unwrap_or(&false) {
                    mem_pj += bytes as f64 * hw.pj_per_sram_byte;
                    continue;
                }
                stream_bytes += bytes;
                mem_pj += bytes as f64 * hw.pj_per_dram_byte;
                let is_weightish = bytes <= 1 << 20; // weights, bias, vectors
                let cacheable =
                    is_weightish || (opts.cacheg && is_mask_name(&sop.name));
                if cacheable && pinned_bytes + bytes <= pin_budget {
                    pinned.insert(src, true);
                    pinned_bytes += bytes;
                }
            } else if placement[src] != place {
                // GraphSplit boundary: RAW dependency crosses devices
                let link = match place {
                    Placement::Accel => hw,
                    Placement::Host => &opts.host,
                };
                xfer_us += link.xfer_setup_us
                    + bytes_dense as f64 / (link.xfer_gbps * 1e3);
                xfer_bytes_total += bytes_dense;
                mem_pj += bytes_dense as f64 * hw.pj_per_dram_byte;
            } else if place == Placement::Accel && bytes_dense > working_budget {
                // Oversized intermediate: free when it flows inside a
                // fused elementwise chain; otherwise it materializes in
                // DRAM (one write at the barrier + one read here).
                let host_fusible = |k: &OpKind| {
                    hw.kind != DeviceKind::Npu
                        && matches!(
                            k,
                            OpKind::Select
                                | OpKind::Greater
                                | OpKind::Softmax
                                | OpKind::Div
                                | OpKind::Elu
                        )
                };
                let like_fusible =
                    |k: &OpKind| is_fusible(k) || host_fusible(k);
                let fused = like_fusible(&sop.kind)
                    && (like_fusible(&op.kind)
                        || is_reducer(&op.kind)
                        || matches!(op.kind,
                                    OpKind::MatMul | OpKind::QMatMul { .. }));
                if fused {
                    mem_pj += bytes_dense as f64 * hw.pj_per_sram_byte;
                } else {
                    stream_bytes += 2 * bytes_dense;
                    mem_pj += 2.0 * bytes_dense as f64 * hw.pj_per_dram_byte;
                }
            } else {
                mem_pj += bytes_dense as f64 * hw.pj_per_sram_byte;
            }
        }
        let out_bytes = op.num_elements() * opts.width(op.dtype);
        mem_pj += out_bytes as f64 * hw.pj_per_sram_byte;

        let dma_us = if stream_bytes > 0 && place == Placement::Accel {
            dma_bytes_total += stream_bytes;
            hw.dma_setup_us + stream_bytes as f64 / (hw.dma_gbps * 1e3)
        } else if stream_bytes > 0 {
            // host-placed op touching big data: host memory bandwidth
            stream_bytes as f64 / (opts.host.dma_gbps * 1e3)
        } else {
            0.0
        };

        // roofline: streaming overlaps compute; transfers serialize
        let wall = c.us.max(dma_us) + xfer_us;
        total_us += wall;
        energy_pj += c.pj + mem_pj;
        records.push(OpRecord {
            id,
            kind: op.kind.name(),
            stage: op.stage,
            engine: c.engine,
            placement: place,
            compute_us: c.us,
            dma_us,
            xfer_us,
            wall_us: wall,
            energy_pj: c.pj + mem_pj,
            macs: c.macs,
        });
    }

    SimReport {
        graph_name: g.name.clone(),
        device: hw.name.clone(),
        records,
        total_us,
        energy_pj,
        dma_bytes: dma_bytes_total,
        xfer_bytes: xfer_bytes_total,
    }
}

/// Simulate on a non-NPU device model (CPU/GPU rows of Figs. 22–23):
/// everything placed on the device, no host split.
pub fn simulate_device(g: &OpGraph, hw: &HardwareConfig) -> SimReport {
    let opts = SimOptions {
        // CPU runtimes execute FP32; GPUs use FP16.
        dense_dtype_bytes: if hw.kind == DeviceKind::Cpu { 4 } else { 2 },
        ..Default::default()
    };
    simulate(g, hw, &opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::build::{self, GatVariant, GnnDims};

    fn dims() -> GnnDims {
        // Fig. 4 scale: 1354 nodes, 5429 edges, 1433 → 64
        GnnDims::fig4(1354, 5429)
    }

    fn hw() -> HardwareConfig {
        HardwareConfig::npu_series2()
    }

    #[test]
    fn fig4_gcn_preprocessing_dominates() {
        let g = build::gcn_baseline(dims());
        let r = simulate(&g, &hw(), &SimOptions::default());
        let by_stage = r.by_stage();
        let pre = by_stage.get("preprocess").copied().unwrap_or(0.0);
        let frac = pre / r.total_us;
        // paper Fig. 4: ~99% preprocessing for GraphConv
        assert!(frac > 0.9, "preprocess fraction {frac:.3}");
    }

    #[test]
    fn fig4_gat_preprocessing_large_but_not_total() {
        let g = build::gat(dims(), GatVariant::Baseline);
        let r = simulate(&g, &hw(), &SimOptions::default());
        let by_stage = r.by_stage();
        let pre = by_stage.get("preprocess").copied().unwrap_or(0.0);
        let frac = pre / r.total_us;
        // paper Fig. 4: ~55% for GraphAttn
        assert!((0.3..0.8).contains(&frac), "preprocess fraction {frac:.3}");
    }

    #[test]
    fn fig5_gat_compute_has_significant_dsp_share() {
        let g = build::gat(dims(), GatVariant::Baseline);
        let r = simulate(&g, &hw(), &SimOptions::default());
        let dsp = r.dsp_fraction(Stage::Compute);
        // paper Fig. 5: ~30% of GraphAttn compute on the DSP
        assert!((0.15..0.6).contains(&dsp), "dsp fraction {dsp:.3}");
    }

    #[test]
    fn fig5_gcn_compute_is_dpu_matmul() {
        let g = build::gcn_stagr(dims(), "stagr");
        let r = simulate(&g, &hw(), &SimOptions::default());
        assert!(r.dsp_fraction(Stage::Compute) < 0.05);
    }

    #[test]
    fn effop_speeds_up_gat() {
        let d = dims();
        let base = simulate(&build::gat(d, GatVariant::Baseline), &hw(),
                            &SimOptions::default());
        let eff = simulate(&build::gat(d, GatVariant::EffOp), &hw(),
                           &SimOptions::default());
        assert!(
            eff.total_us < base.total_us,
            "effop {} !< baseline {}",
            eff.total_us,
            base.total_us
        );
    }

    #[test]
    fn grax_speeds_up_effop_further() {
        let d = dims();
        let eff = simulate(&build::gat(d, GatVariant::EffOp), &hw(),
                           &SimOptions::default());
        let grax = simulate(&build::gat(d, GatVariant::Grax), &hw(),
                            &SimOptions::default());
        assert!(grax.total_us < eff.total_us,
                "grax {} !< effop {}", grax.total_us, eff.total_us);
    }

    #[test]
    fn grax3_beats_gather_baseline() {
        let d = dims();
        let base = simulate(&build::sage_max_baseline(d), &hw(),
                            &SimOptions::default());
        let gx = simulate(&build::sage_max_grax3(d), &hw(),
                          &SimOptions::default());
        assert!(gx.total_us < base.total_us,
                "grax3 {} !< baseline {}", gx.total_us, base.total_us);
    }

    #[test]
    fn quant_beats_fp16() {
        let d = dims();
        let fp = simulate(&build::gcn_stagr(d, "stagr"), &hw(),
                          &SimOptions::default());
        // QuantGr ships INT8 end to end: activations, weights and the
        // quantized mask all halve again vs the FP16 datapath.
        let mut qo = SimOptions::default();
        qo.dense_dtype_bytes = 1;
        let q = simulate(
            &build::gcn_quant(d, build::QuantScales::default()),
            &hw(),
            &qo,
        );
        assert!(q.total_us < fp.total_us, "quant {} fp {}", q.total_us, fp.total_us);
    }

    #[test]
    fn spmm_graph_beats_dense_aggregation_at_cora_density() {
        use crate::ops::build::Aggregation;
        let d = dims();
        let dense = build::gcn_stagr(d, "stagr");
        let sparse = build::gcn_stagr_with(d, "stagr", Aggregation::Sparse);
        let mut o = SimOptions::default();
        o.mask_density.insert("norm".into(), 0.004);
        let dr = simulate(&dense, &hw(), &o);
        let sr = simulate(&sparse, &hw(), &o);
        // compute: nnz·d MACs instead of n²·d; DMA: CSR arrays instead of
        // the dense mask — both collapse at 0.4% density
        assert!(
            sr.total_us < dr.total_us * 0.6,
            "spmm {} !< 0.6 × dense {}",
            sr.total_us,
            dr.total_us
        );
        assert!(sr.dma_bytes < dr.dma_bytes, "{} !< {}", sr.dma_bytes, dr.dma_bytes);
        // and even GraSp-compressed dense aggregation still loses to the
        // SpMM graph under the same codec options: the zero-skip pipeline
        // is capped at 75%, structural sparsity is not
        let mut og = SimOptions::default();
        og.grasp = true;
        og.mask_density.insert("norm".into(), 0.004);
        let dg = simulate(&dense, &hw(), &og);
        let sg = simulate(&sparse, &hw(), &og);
        assert!(sg.total_us < dg.total_us, "{} !< {}", sg.total_us, dg.total_us);
        // at near-dense masks the simulator prefers the dense path,
        // mirroring the Auto threshold
        let mut od = SimOptions::default();
        od.mask_density.insert("norm".into(), 0.9);
        let dd = simulate(&dense, &hw(), &od);
        let sd = simulate(&sparse, &hw(), &od);
        assert!(sd.total_us > dd.total_us, "{} !> {}", sd.total_us, dd.total_us);
    }

    #[test]
    fn grasp_reduces_latency_and_dma() {
        let d = dims();
        let g = build::gcn_stagr(d, "stagr");
        let base = simulate(&g, &hw(), &SimOptions::default());
        let mut o = SimOptions::default();
        o.grasp = true;
        o.mask_density.insert("norm".into(), 0.004);
        let sp = simulate(&g, &hw(), &o);
        assert!(sp.total_us < base.total_us);
        assert!(sp.dma_bytes < base.dma_bytes);
    }

    #[test]
    fn cacheg_needs_compression_then_cuts_fetches() {
        let d = GnnDims::model(2708, 5429, 1433, 7); // Cora scale, 2 layers
        let g = build::gcn_stagr(d, "stagr");
        // CacheG alone: the 29 MB norm cannot be pinned — no effect
        let mut only_cache = SimOptions::default();
        only_cache.cacheg = true;
        let oc = simulate(&g, &hw(), &only_cache);
        let base = simulate(&g, &hw(), &SimOptions::default());
        assert!((oc.dma_bytes as f64 - base.dma_bytes as f64).abs() < 1e3);
        // CacheG + GraSp + SymG: compressed mask fits and is fetched once
        let mut full = SimOptions::optimized();
        full.mask_density.insert("norm".into(), 0.002);
        let f = simulate(&g, &hw(), &full);
        assert!(f.dma_bytes < base.dma_bytes / 2,
                "{} !< {}", f.dma_bytes, base.dma_bytes / 2);
        assert!(f.total_us < base.total_us);
    }

    #[test]
    fn symg_halves_norm_traffic() {
        let d = dims();
        let g = build::gcn_stagr(d, "stagr");
        let base = simulate(&g, &hw(), &SimOptions::default());
        let mut o = SimOptions::default();
        o.symg = true;
        let s = simulate(&g, &hw(), &o);
        assert!(s.dma_bytes < base.dma_bytes);
    }

    #[test]
    fn series2_beats_series1() {
        let d = dims();
        let g = build::gcn_stagr(d, "stagr");
        let s2 = simulate(&g, &hw(), &SimOptions::default());
        let s1 = simulate(&g, &HardwareConfig::npu_series1(),
                          &SimOptions::default());
        let ratio = s1.total_us / s2.total_us;
        // paper Fig. 21: 1.6–1.7×, below the theoretical 2×
        assert!(ratio > 1.0 && ratio < 2.0, "series ratio {ratio:.2}");
    }

    #[test]
    fn npu_beats_cpu_and_gpu_on_optimized_gcn() {
        let d = dims();
        let g = build::gcn_quant(d, build::QuantScales::default());
        let mut o = SimOptions::optimized();
        o.mask_density.insert("norm".into(), 0.004);
        let npu = simulate(&g, &hw(), &o);
        let plain = build::gcn_stagr(d, "stagr");
        let cpu = simulate_device(&plain, &HardwareConfig::cpu());
        let gpu = simulate_device(&plain, &HardwareConfig::gpu());
        assert!(npu.total_us < gpu.total_us && gpu.total_us < cpu.total_us,
                "npu {} gpu {} cpu {}", npu.total_us, gpu.total_us, cpu.total_us);
    }

    #[test]
    fn npu_more_energy_efficient() {
        let d = dims();
        let g = build::gcn_quant(d, build::QuantScales::default());
        let mut o = SimOptions::optimized();
        o.mask_density.insert("norm".into(), 0.004);
        let npu = simulate(&g, &hw(), &o);
        let plain = build::gcn_stagr(d, "stagr");
        let cpu = simulate_device(&plain, &HardwareConfig::cpu());
        let gpu = simulate_device(&plain, &HardwareConfig::gpu());
        assert!(npu.energy_pj < gpu.energy_pj && npu.energy_pj < cpu.energy_pj);
    }

    #[test]
    fn graphsplit_placement_moves_preprocess_to_host() {
        let g = build::gcn_baseline(dims());
        let all_accel = simulate(&g, &hw(), &SimOptions::default());
        let placement: Vec<Placement> = g
            .ops
            .iter()
            .map(|op| {
                if op.stage == Stage::Preprocess {
                    Placement::Host
                } else {
                    Placement::Accel
                }
            })
            .collect();
        let mut o = SimOptions::default();
        o.placement = Some(placement);
        let split = simulate(&g, &hw(), &o);
        assert!(split.total_us < all_accel.total_us,
                "split {} !< accel {}", split.total_us, all_accel.total_us);
        assert!(split.xfer_bytes > 0, "boundary crossing must be charged");
    }

    #[test]
    fn report_shapes_are_consistent() {
        let g = build::gcn_stagr(dims(), "stagr");
        let r = simulate(&g, &hw(), &SimOptions::default());
        let stage_sum: f64 = r.by_stage().values().sum();
        assert!((stage_sum - r.total_us).abs() / r.total_us < 1e-9);
        assert!(r.throughput() > 0.0);
        assert!(r.energy_mj() > 0.0);
    }
}
