//! NPU simulator — the evaluation substrate standing in for the paper's
//! Intel Core Ultra NPUs (DESIGN.md §2).
//!
//! Structure (paper §IV, FlexNN-like): a DPU tile array for dense
//! MACs/vector work, a lower-clocked DSP for control-heavy ops, local
//! SRAM with explicit DMA from DRAM, and a host-transfer link crossed by
//! GraphSplit boundaries. Constants live in
//! [`crate::config::HardwareConfig`]; Series-1/Series-2/CPU/GPU presets
//! reproduce the device comparisons of Figs. 21–23.

pub mod cost;
pub mod sim;

pub use cost::{matmul_utilization, op_cost, CostOpts, OpCost};
pub use sim::{
    is_fusible, is_reducer, simulate, simulate_device, OpRecord, Placement,
    SimOptions, SimReport,
};
